"""L2 staged transformer: composition, gradients, and export surface."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.flatten_util import ravel_pytree

from compile import model
from compile.configs import CONFIGS, ModelCfg

TINY = CONFIGS["tiny"]
TINY_CLS = CONFIGS["tiny_cls"]
TINY_PALLAS = CONFIGS["tiny_pallas"]


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab,
                                      size=(cfg.micro_batch, cfg.seq)),
                         dtype=jnp.int32)
    if cfg.task == "lm":
        targets = tokens
    else:
        targets = jnp.asarray(rng.integers(0, cfg.n_classes,
                                           size=(cfg.micro_batch,)),
                              dtype=jnp.int32)
    return tokens, targets


@pytest.mark.parametrize("cfg", [TINY, TINY_CLS], ids=lambda c: c.name)
def test_stage_composition_equals_full_model(cfg):
    """Running stages sequentially == monolithic model."""
    params = model.init_all_params(cfg)
    tokens, targets = _batch(cfg)
    want = model.full_model_loss(cfg, params, tokens, targets)

    x = tokens
    for i in range(cfg.n_stages - 1):
        x = model.stage_apply(cfg, i, params[i], x)
    got = model.last_stage_loss(cfg, params[-1], x, targets)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)
    assert np.isfinite(float(got))


@pytest.mark.parametrize("cfg", [TINY, TINY_CLS], ids=lambda c: c.name)
def test_flat_stage_fns_match_pytree(cfg):
    params = model.init_all_params(cfg)
    tokens, targets = _batch(cfg)
    fns0 = model.make_stage_fns(cfg, 0)
    pf0, _ = ravel_pytree(params[0])
    (h,) = fns0["fwd"](pf0, tokens)
    want = model.stage_apply(cfg, 0, params[0], tokens)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want), rtol=1e-6)
    assert h.shape == cfg.boundary_shape

    fns1 = model.make_stage_fns(cfg, cfg.n_stages - 1)
    pf1, _ = ravel_pytree(params[-1])
    (l,) = fns1["loss"](pf1, h, targets)
    want_l = model.last_stage_loss(cfg, params[-1], h, targets)
    np.testing.assert_allclose(float(l), float(want_l), rtol=1e-6)


def test_pipeline_bwd_matches_monolithic_grad():
    """Chained stage bwd artifacts == jax.grad of the full model.

    This is THE invariant that makes the rust pipeline a correct SGD:
    stage1.lossbwd produces (loss, g_p1, g_x); feeding g_x into stage0.bwd
    must reproduce grad wrt stage-0 params.
    """
    cfg = TINY
    params = model.init_all_params(cfg)
    tokens, targets = _batch(cfg)
    pf = [ravel_pytree(p)[0] for p in params]

    # pipeline path
    fns0 = model.make_stage_fns(cfg, 0)
    fns1 = model.make_stage_fns(cfg, 1)
    (h,) = fns0["fwd"](pf[0], tokens)
    loss, gp1, gx = fns1["lossbwd"](pf[1], h, targets)
    (gp0,) = fns0["bwd"](pf[0], tokens, gx)

    # monolithic path
    def full(pf0, pf1):
        _, un0 = model.stage_unravel(cfg, 0)[1], None
        fns0_ = model.make_stage_fns(cfg, 0)
        fns1_ = model.make_stage_fns(cfg, 1)
        (h_,) = fns0_["fwd"](pf0, tokens)
        return fns1_["loss"](pf1, h_, targets)[0]

    want_l = full(pf[0], pf[1])
    g0_want = jax.grad(full, argnums=0)(pf[0], pf[1])
    g1_want = jax.grad(full, argnums=1)(pf[0], pf[1])

    np.testing.assert_allclose(float(loss), float(want_l), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gp0), np.asarray(g0_want),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gp1), np.asarray(g1_want),
                               rtol=1e-4, atol=1e-6)


def test_pallas_attention_model_matches_jnp_model():
    """cfg.attn='pallas' and 'jnp' give the same network function."""
    params = model.init_all_params(TINY)  # same seed for both cfgs
    tokens, targets = _batch(TINY)
    l_jnp = model.full_model_loss(TINY, params, tokens, targets)
    l_pls = model.full_model_loss(TINY_PALLAS, params, tokens, targets)
    np.testing.assert_allclose(float(l_pls), float(l_jnp), rtol=1e-5)


def test_grad_descent_reduces_loss():
    """A few plain-SGD steps on the tiny model reduce the loss."""
    cfg = TINY
    params = model.init_all_params(cfg)
    tokens, targets = _batch(cfg)
    pf = [ravel_pytree(p)[0] for p in params]
    fns0 = model.make_stage_fns(cfg, 0)
    fns1 = model.make_stage_fns(cfg, 1)

    losses = []
    for _ in range(5):
        (h,) = fns0["fwd"](pf[0], tokens)
        loss, gp1, gx = fns1["lossbwd"](pf[1], h, targets)
        (gp0,) = fns0["bwd"](pf[0], tokens, gx)
        pf[0] = pf[0] - 0.5 * gp0
        pf[1] = pf[1] - 0.5 * gp1
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_stage_layer_partition():
    cfg = ModelCfg("t", vocab=8, d_model=8, n_layers=7, n_heads=2, seq=8,
                   micro_batch=1, n_stages=3)
    ranges = [cfg.stage_layers(i) for i in range(3)]
    assert ranges == [(0, 3), (3, 5), (5, 7)]
    # contiguous full cover
    flat = [l for lo, hi in ranges for l in range(lo, hi)]
    assert flat == list(range(7))


def test_param_counts_positive_and_stable():
    for cfg in (TINY, TINY_CLS, CONFIGS["small"]):
        for i in range(cfg.n_stages):
            n1, _ = model.stage_unravel(cfg, i)
            n2, _ = model.stage_unravel(cfg, i)
            assert n1 == n2 > 0
