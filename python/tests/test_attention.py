"""Pallas flash-attention kernel vs jnp oracle (values and gradients)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from compile.kernels import attention, ref


def _qkv(b, h, s, d, seed):
    rng = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(rng.normal(size=(b, h, s, d)).astype("float32"))
    return mk(), mk(), mk()


@given(b=st.integers(1, 3), h=st.integers(1, 3),
       s=st.sampled_from([16, 32, 64]), d=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_flash_matches_ref(b, h, s, d, seed):
    q, k, v = _qkv(b, h, s, d, seed)
    o = attention.flash_attention(q, k, v, True, 16, 16)
    o_ref = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@given(s=st.sampled_from([16, 32]), blk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**10))
def test_block_size_invariance(s, blk, seed):
    """Output must not depend on the tiling choice."""
    q, k, v = _qkv(2, 2, s, 8, seed)
    o1 = attention.flash_attention(q, k, v, True, blk, blk)
    o2 = attention.flash_attention(q, k, v, True, s, s)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_non_causal():
    q, k, v = _qkv(2, 2, 32, 16, 7)
    o = attention.flash_attention(q, k, v, False, 16, 16)
    o_ref = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_causality():
    """Perturbing future keys/values must not change past outputs."""
    q, k, v = _qkv(1, 1, 32, 8, 11)
    o1 = attention.flash_attention(q, k, v, True, 16, 16)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    o2 = attention.flash_attention(q, k2, v2, True, 16, 16)
    np.testing.assert_allclose(np.asarray(o1[:, :, :20]),
                               np.asarray(o2[:, :, :20]), rtol=1e-6)


@pytest.mark.parametrize("wrt", [0, 1, 2])
def test_gradients_match_ref(wrt):
    q, k, v = _qkv(2, 2, 32, 8, 3)

    def f_pallas(*args):
        return jnp.sum(attention.flash_attention(*args, True, 16, 16) ** 2)

    def f_ref(*args):
        return jnp.sum(ref.attention(*args, causal=True) ** 2)

    g1 = jax.grad(f_pallas, argnums=wrt)(q, k, v)
    g2 = jax.grad(f_ref, argnums=wrt)(q, k, v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=5e-4, atol=1e-5)


def test_softmax_stability():
    """Large logits must not overflow the online softmax."""
    q, k, v = _qkv(1, 1, 16, 8, 5)
    q = q * 100.0
    o = attention.flash_attention(q, k, v, True, 8, 8)
    assert np.all(np.isfinite(np.asarray(o)))
    o_ref = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
