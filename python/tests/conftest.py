import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import settings

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")
