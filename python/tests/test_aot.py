"""AOT export surface: manifests consistent, artifacts well-formed."""

import os

import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest(name):
    path = os.path.join(ART, name, "manifest.txt")
    if not os.path.exists(path):
        pytest.skip(f"artifacts for {name} not built (run `python -m compile.aot` from python/)")
    out = {}
    with open(path) as f:
        for line in f:
            k, v = line.strip().split(" ", 1)
            out[k] = v
    return out


@pytest.mark.parametrize("name", ["tiny", "tiny_cls", "small"])
def test_manifest_consistent(name):
    m = _manifest(name)
    cfg = CONFIGS[name]
    assert int(m["n_stages"]) == cfg.n_stages
    assert m["boundary"] == "x".join(str(d) for d in cfg.boundary_shape)
    for i in range(cfg.n_stages):
        n = int(m[f"stage{i}.params"])
        want, _ = model.stage_unravel(cfg, i)
        assert n == want
        # init bin holds exactly n f32s
        init = os.path.join(ART, name, m[f"stage{i}.init"])
        assert os.path.getsize(init) == 4 * n
        # adamw artifact exists for this size
        assert os.path.exists(os.path.join(ART, name, m[f"stage{i}.adamw"]))


@pytest.mark.parametrize("name", ["tiny"])
def test_hlo_text_wellformed(name):
    m = _manifest(name)
    d = os.path.join(ART, name)
    hlo_files = [v for k, v in m.items() if v.endswith(".hlo.txt")]
    assert len(hlo_files) >= 8
    for f in set(hlo_files):
        with open(os.path.join(d, f)) as fh:
            text = fh.read()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f


def test_init_bins_finite():
    m = _manifest("tiny")
    for i in range(int(m["n_stages"])):
        arr = np.fromfile(os.path.join(ART, "tiny", m[f"stage{i}.init"]),
                          dtype="<f4")
        assert np.all(np.isfinite(arr))
        assert np.abs(arr).max() <= 1.0  # init_scale + unit LN gammas
