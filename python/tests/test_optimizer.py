"""AdamW update vs a trusted numpy re-implementation."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile import optimizer


def _np_adamw(p, m, v, g, step, lr):
    b1, b2, eps, wd = (optimizer.BETA1, optimizer.BETA2, optimizer.EPS,
                       optimizer.WEIGHT_DECAY)
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mh = m2 / (1 - b1**step)
    vh = v2 / (1 - b2**step)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m2, v2


@given(n=st.integers(1, 64), step=st.integers(1, 1000),
       seed=st.integers(0, 2**16))
def test_adamw_matches_numpy(n, step, seed):
    rng = np.random.default_rng(seed)
    p, m, g = (rng.normal(size=n).astype("float32") for _ in range(3))
    v = np.abs(rng.normal(size=n)).astype("float32")
    lr = 1e-3
    got = optimizer.adamw_update(jnp.asarray(p), jnp.asarray(m),
                                 jnp.asarray(v), jnp.asarray(g),
                                 jnp.float32(step), jnp.float32(lr))
    want = _np_adamw(p.astype("float64"), m.astype("float64"),
                     v.astype("float64"), g.astype("float64"), step, lr)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), b, rtol=2e-4, atol=1e-6)


def test_adamw_shrinks_simple_quadratic():
    p = jnp.asarray(np.ones(8, dtype="float32") * 5.0)
    m = jnp.zeros(8)
    v = jnp.zeros(8)
    for step in range(1, 200):
        g = p  # grad of p^2/2
        p, m, v = optimizer.adamw_update(p, m, v, g, jnp.float32(step),
                                         jnp.float32(0.05))
    assert float(jnp.max(jnp.abs(p))) < 1.0
