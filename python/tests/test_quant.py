"""L1 quant kernels vs pure-jnp oracle: the core correctness signal.

Hypothesis sweeps shapes and bit-widths; every property the rust codec
relies on is pinned here:
  * codes identical between Pallas kernel and oracle (integer-exact)
  * sender buffer m_new == receiver buffer m_new (bit-identical replicas)
  * codes lie in [0, 2^b - 1] (packable into b bits on the wire)
  * deterministic rounding error <= 1 quantization step
  * stochastic rounding is (empirically) unbiased and satisfies the
    Theorem 3.1 contraction E||x - Q(x)|| <= c_Q ||x||, c_Q = sqrt(d)/2^b
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref

BITS = st.sampled_from([2, 3, 4, 6, 8])
SHAPES = st.sampled_from([(7,), (4, 5), (2, 3, 8), (1, 129), (4, 32, 32),
                          (3, 1, 1), (4096,), (4097,)])


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype("float32") * scale)


def _noise(shape, seed=None):
    if seed is None:
        return jnp.full(shape, 0.5, jnp.float32)  # deterministic rounding
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(size=shape).astype("float32"))


@given(shape=SHAPES, bits=BITS, seed=st.integers(0, 2**16))
def test_quantize_matches_ref(shape, bits, seed):
    x = _rand(shape, seed)
    u = _noise(shape, seed + 1)
    lv = jnp.float32(2**bits - 1)
    scale = ref.quant_scale(x)
    got = quant.quantize(x, scale, u, lv)
    want = ref.quantize(x, scale, u, lv)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == x.shape
    codes = np.asarray(got)
    assert codes.min() >= 0 and codes.max() <= 2**bits - 1
    assert np.all(codes == np.floor(codes))


@given(shape=SHAPES, bits=BITS, seed=st.integers(0, 2**16))
def test_dequantize_matches_ref(shape, bits, seed):
    x = _rand(shape, seed)
    lv = jnp.float32(2**bits - 1)
    scale = ref.quant_scale(x)
    codes = ref.quantize(x, scale, _noise(shape), lv)
    got = quant.dequantize(codes, scale, lv)
    want = ref.dequantize(codes, scale, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@given(shape=SHAPES, bits=BITS, seed=st.integers(0, 2**16))
def test_roundtrip_error_bound(shape, bits, seed):
    """Deterministic round-to-nearest error is <= half a quantization step
    (one full step for stochastic)."""
    x = _rand(shape, seed)
    lv = jnp.float32(2**bits - 1)
    scale = ref.quant_scale(x)
    codes = quant.quantize(x, scale, _noise(shape), lv)
    xh = quant.dequantize(codes, scale, lv)
    step = 2.0 * float(scale) / float(lv)
    assert np.max(np.abs(np.asarray(xh) - np.asarray(x))) <= step * 0.5 + 1e-6


@given(shape=SHAPES, bits=BITS, seed=st.integers(0, 2**16))
def test_aq_encode_decode_replicas(shape, bits, seed):
    """Sender's advanced buffer must equal receiver's bit-for-bit: the
    entire AQ-SGD algorithm hinges on both sides holding identical m."""
    a = _rand(shape, seed)
    m = _rand(shape, seed + 1)
    u = _noise(shape, seed + 2)
    lv = jnp.float32(2**bits - 1)
    codes, scale, m_sender = quant.aq_encode(a, m, u, lv)
    m_receiver = quant.aq_decode(codes, scale, m, lv)
    np.testing.assert_array_equal(np.asarray(m_sender), np.asarray(m_receiver))
    # codes agree with oracle
    c_ref, s_ref, _ = ref.aq_encode(a, m, u, lv)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c_ref))
    assert float(scale) == float(s_ref)


@given(bits=BITS, seed=st.integers(0, 2**16))
def test_aq_error_contracts(bits, seed):
    """After an AQ step the buffer is closer to the activation than the
    quantization step bound allows: ||a - m_new|| <= step/2 * sqrt(d)."""
    shape = (64, 32)
    a = _rand(shape, seed)
    m = _rand(shape, seed + 1)
    lv = jnp.float32(2**bits - 1)
    _, scale, m_new = quant.aq_encode(a, m, _noise(shape), lv)
    step = 2.0 * float(scale) / float(lv)
    err = np.linalg.norm(np.asarray(a) - np.asarray(m_new))
    assert err <= 0.5 * step * np.sqrt(a.size) + 1e-5


@given(shape=SHAPES, bits=BITS, seed=st.integers(0, 2**16))
def test_directq_matches_ref(shape, bits, seed):
    a = _rand(shape, seed)
    u = _noise(shape, seed + 3)
    lv = jnp.float32(2**bits - 1)
    codes, scale = quant.directq_encode(a, u, lv)
    c_ref, s_ref = ref.directq_encode(a, u, lv)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(c_ref))
    assert float(scale) == float(s_ref)
    a_hat = quant.directq_decode(codes, scale, lv)
    np.testing.assert_allclose(np.asarray(a_hat),
                               np.asarray(ref.directq_decode(c_ref, s_ref, lv)),
                               rtol=1e-6)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_stochastic_rounding_unbiased(bits):
    """E[deq(Q(x))] == x for stochastic rounding (Theorem 3.1's unbiased-Q
    assumption). Averaged over many noise draws."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype("float32"))
    lv = jnp.float32(2**bits - 1)
    scale = ref.quant_scale(x)
    n_trials = 400
    acc = np.zeros(x.shape, dtype=np.float64)
    for t in range(n_trials):
        u = jnp.asarray(rng.uniform(size=x.shape).astype("float32"))
        codes = ref.quantize(x, scale, u, lv)
        acc += np.asarray(ref.dequantize(codes, scale, lv), dtype=np.float64)
    mean = acc / n_trials
    step = 2.0 * float(scale) / float(lv)
    # per-element standard error of the rounding noise: <= step/(2 sqrt(n));
    # the norm of the 256-dim bias vector concentrates at SE*sqrt(256).
    se = 0.5 * step / np.sqrt(n_trials)
    bias_norm = np.linalg.norm(mean - np.asarray(x, dtype=np.float64))
    assert bias_norm <= 2.0 * se * np.sqrt(x.size)


@pytest.mark.parametrize("bits", [4, 6, 8])
def test_cq_contraction_bound(bits):
    """E||x - Q(x)|| <= c_Q ||x|| with c_Q = sqrt(d)/2^b (footnote 3)."""
    rng = np.random.default_rng(1)
    d = 512
    x = jnp.asarray(rng.normal(size=(d,)).astype("float32"))
    lv = jnp.float32(2**bits - 1)
    scale = ref.quant_scale(x)
    errs = []
    for t in range(50):
        u = jnp.asarray(rng.uniform(size=x.shape).astype("float32"))
        xh = ref.dequantize(ref.quantize(x, scale, u, lv), scale, lv)
        errs.append(np.linalg.norm(np.asarray(xh) - np.asarray(x)))
    c_q = np.sqrt(d) / 2**bits
    assert np.mean(errs) <= c_q * np.linalg.norm(np.asarray(x)) + 1e-6


def test_zero_delta_stays_fixed():
    """When a == m the delta is 0 and the buffer must not drift."""
    a = jnp.ones((16, 16), jnp.float32)
    lv = jnp.float32(15.0)
    codes, scale, m_new = quant.aq_encode(a, a, _noise(a.shape), lv)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(a), atol=1e-7)


def test_extreme_values():
    """Large magnitudes and denormals survive the codec."""
    for mag in (1e20, 1e-20, 1.0):
        x = jnp.asarray(np.array([[mag, -mag, 0.0, mag / 3]], dtype="float32"))
        lv = jnp.float32(15.0)
        scale = ref.quant_scale(x)
        xh = quant.dequantize(quant.quantize(x, scale, _noise(x.shape), lv),
                              scale, lv)
        step = 2.0 * float(scale) / 15.0
        assert np.all(np.abs(np.asarray(xh) - np.asarray(x)) <= step)
