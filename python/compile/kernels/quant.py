"""L1 Pallas kernels: the AQ-SGD compression hot-spot.

The kernels are element-wise and bandwidth-bound; they are tiled over a 1-D
grid of BLOCK-element lanes of the flattened tensor (on real TPU hardware a
(8k, 128) VMEM tile; see DESIGN.md §Hardware-Adaptation). `interpret=True`
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, and
these artifacts are executed by the rust coordinator on the CPU client.

The per-tensor max-abs `scale` is a reduction and is computed in the
surrounding L2 jnp code (two passes over the tensor: max-abs + quantize —
the roofline-optimal schedule for a tensor that does not fit in VMEM).

`levels` (= 2^bits - 1) and `scale` enter the kernels as (1,)-shaped
operands so a single AOT artifact serves every bit-width at runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 4096


def _pad_flat(x):
    """Flatten to 1-D and zero-pad to a BLOCK multiple. Returns (xp, n)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n


def _scalar_spec():
    # A (1,)-shaped operand broadcast to every grid step.
    return pl.BlockSpec((1,), lambda i: (0,))


def _block_spec():
    return pl.BlockSpec((BLOCK,), lambda i: (i,))


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------

def _quant_kernel(x_ref, noise_ref, scale_ref, levels_ref, codes_ref):
    scale = scale_ref[0]
    levels = levels_ref[0]
    y = (x_ref[...] / scale + 1.0) * 0.5 * levels + noise_ref[...]
    codes_ref[...] = jnp.clip(jnp.floor(y), 0.0, levels)


def quantize(x, scale, noise, levels):
    """Pallas uniform quantizer. Matches ref.quantize exactly."""
    xp, n = _pad_flat(x)
    np_, _ = _pad_flat(noise)
    grid = (xp.shape[0] // BLOCK,)
    codes = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[_block_spec(), _block_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp, np_, scale.reshape(1), levels.reshape(1))
    return codes[:n].reshape(x.shape)


def _deq_kernel(codes_ref, scale_ref, levels_ref, x_ref):
    scale = scale_ref[0]
    levels = levels_ref[0]
    x_ref[...] = (codes_ref[...] / levels * 2.0 - 1.0) * scale


def dequantize(codes, scale, levels):
    cp, n = _pad_flat(codes)
    grid = (cp.shape[0] // BLOCK,)
    x = pl.pallas_call(
        _deq_kernel,
        grid=grid,
        in_specs=[_block_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(cp.shape, jnp.float32),
        interpret=True,
    )(cp, scale.reshape(1), levels.reshape(1))
    return x[:n].reshape(codes.shape)


# ---------------------------------------------------------------------------
# AQ-SGD delta codec: fused (quantize delta, dequantize, advance buffer)
# ---------------------------------------------------------------------------

def _aq_encode_kernel(a_ref, m_ref, noise_ref, scale_ref, levels_ref,
                      codes_ref, m_new_ref):
    scale = scale_ref[0]
    levels = levels_ref[0]
    delta = a_ref[...] - m_ref[...]
    y = (delta / scale + 1.0) * 0.5 * levels + noise_ref[...]
    codes = jnp.clip(jnp.floor(y), 0.0, levels)
    codes_ref[...] = codes
    m_new_ref[...] = m_ref[...] + (codes / levels * 2.0 - 1.0) * scale


def aq_encode(a, m, noise, levels):
    """Sender-side AQ-SGD boundary op. Returns (codes, scale, m_new)."""
    delta_scale = ref.quant_scale(a - m)
    ap, n = _pad_flat(a)
    mp, _ = _pad_flat(m)
    np_, _ = _pad_flat(noise)
    grid = (ap.shape[0] // BLOCK,)
    codes, m_new = pl.pallas_call(
        _aq_encode_kernel,
        grid=grid,
        in_specs=[_block_spec(), _block_spec(), _block_spec(),
                  _scalar_spec(), _scalar_spec()],
        out_specs=[_block_spec(), _block_spec()],
        out_shape=[jax.ShapeDtypeStruct(ap.shape, jnp.float32),
                   jax.ShapeDtypeStruct(ap.shape, jnp.float32)],
        interpret=True,
    )(ap, mp, np_, delta_scale.reshape(1), levels.reshape(1))
    return (codes[:n].reshape(a.shape), delta_scale,
            m_new[:n].reshape(a.shape))


def _aq_decode_kernel(codes_ref, m_ref, scale_ref, levels_ref, m_new_ref):
    scale = scale_ref[0]
    levels = levels_ref[0]
    m_new_ref[...] = m_ref[...] + (codes_ref[...] / levels * 2.0 - 1.0) * scale


def aq_decode(codes, scale, m, levels):
    """Receiver-side AQ-SGD boundary op: advance the buffer replica."""
    cp, n = _pad_flat(codes)
    mp, _ = _pad_flat(m)
    grid = (cp.shape[0] // BLOCK,)
    m_new = pl.pallas_call(
        _aq_decode_kernel,
        grid=grid,
        in_specs=[_block_spec(), _block_spec(), _scalar_spec(), _scalar_spec()],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(cp.shape, jnp.float32),
        interpret=True,
    )(cp, mp, scale.reshape(1), levels.reshape(1))
    return m_new[:n].reshape(codes.shape)


# ---------------------------------------------------------------------------
# DirectQ baseline (AC-GC / TinyScript style)
# ---------------------------------------------------------------------------

def directq_encode(a, noise, levels):
    scale = ref.quant_scale(a)
    return quantize(a, scale, noise, levels), scale


def directq_decode(codes, scale, levels):
    return dequantize(codes, scale, levels)
