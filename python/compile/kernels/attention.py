"""L1 Pallas kernel: fused causal flash attention (forward).

TPU-flavoured flash attention: the grid is (batch, heads, q-blocks); each
program streams K/V blocks through an online-softmax accumulator. On real
TPU hardware the q/k/v tiles live in VMEM and the q@k^T / p@v contractions
hit the MXU; `interpret=True` here lowers the identical schedule to plain
HLO so the CPU PJRT client can execute it (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU sizing argument).

Backward: stage gradients are produced by `jax.vjp` over the stage forward
function, so the attention op must be differentiable. Pallas primitives
have no general AD rule, so we wrap the kernel in `jax.custom_vjp` whose
backward pass recomputes attention with the pure-jnp reference (exact, and
matches the paper's recomputation-style pipeline backward which ships no
residuals between machines).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float,
                  causal: bool):
    # q_ref: [1, 1, block_q, d], k_ref/v_ref: [1, 1, S, d]
    q = q_ref[0, 0] * sm_scale                      # [bq, d]
    block_q, d = q.shape
    seq = k_ref.shape[2]
    n_kv = seq // block_k
    qi = pl.program_id(2)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m_i, l_i = carry
        k_blk = pl.load(k_ref, (0, 0, pl.dslice(j * block_k, block_k),
                                slice(None)))      # [bk, d]
        v_blk = pl.load(v_ref, (0, 0, pl.dslice(j * block_k, block_k),
                                slice(None)))
        s = q @ k_blk.T                             # [bq, bk]
        if causal:
            k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])             # [bq, bk]
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0, 0] = acc / l_i[:, None]


def _flash_attention_fwd(q, k, v, *, causal, block_q, block_k):
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (b, h, s // block_q)
    kernel = functools.partial(_flash_kernel, block_k=block_k,
                               sm_scale=1.0 / (d ** 0.5), causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal=True, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K):
    """Fused causal attention. q,k,v: [B, H, S, Dh] (f32)."""
    return _flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k)


def _fwd(q, k, v, causal, block_q, block_k):
    o = _flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                             block_k=block_k)
    return o, (q, k, v)


def _bwd(causal, block_q, block_k, res, do):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention(q, k, v, causal=causal),
                     q, k, v)
    return vjp(do)


flash_attention.defvjp(_fwd, _bwd)
