# L1: Pallas kernels for the paper's compute hot-spots.
#  - quant.py      : AQ-SGD / DirectQ uniform quantization codecs
#  - attention.py  : fused causal flash attention (forward) + custom_vjp
#  - ref.py        : pure-jnp oracles (the correctness ground truth)
from . import attention, quant, ref  # noqa: F401
