"""Pure-jnp oracles for every L1 Pallas kernel.

These are the ground truth the pytest/hypothesis suite compares the Pallas
kernels against, and the reference semantics the rust `codec` module
mirrors bit-for-bit (same rounding rule, same scale convention).

Quantization scheme (paper §4.1 "Baselines"): a tensor is normalized into
[-1, 1] by its max-abs `scale`, the range is partitioned uniformly into
2^b intervals, i.e. codes in {0, ..., 2^b - 1}:

    code = floor((x / scale + 1) / 2 * levels + u),   levels = 2^b - 1
    deq  = (code / levels * 2 - 1) * scale

`u` is the rounding offset: u = 0.5 reproduces deterministic
round-to-nearest; u ~ U[0,1) gives unbiased stochastic rounding (the
variant Theorem 3.1's `E Q(x) = x` assumption needs).
"""

import jax.numpy as jnp


def quant_scale(x, eps=1e-12):
    """Per-tensor max-abs scale (f32 scalar)."""
    return jnp.maximum(jnp.max(jnp.abs(x)), eps).astype(jnp.float32)


def quantize(x, scale, noise, levels):
    """Uniform b-bit quantization of `x` given `scale`.

    noise: same shape as x, rounding offsets in [0, 1).
    levels: f32 scalar = 2^bits - 1.
    Returns integer codes stored as f32 (PJRT-friendly; exact for b<=23).
    """
    y = (x / scale + 1.0) * 0.5 * levels + noise
    return jnp.clip(jnp.floor(y), 0.0, levels)


def dequantize(codes, scale, levels):
    return (codes / levels * 2.0 - 1.0) * scale


def directq_encode(a, noise, levels):
    """DirectQ (AC-GC/TinyScript style): quantize the activation itself."""
    scale = quant_scale(a)
    codes = quantize(a, scale, noise, levels)
    return codes, scale


def directq_decode(codes, scale, levels):
    return dequantize(codes, scale, levels)


def aq_encode(a, m, noise, levels):
    """AQ-SGD encode: quantize the *change* of the activation vs. the
    message buffer `m`, and advance the buffer.

    Returns (codes, scale, m_new) with m_new = m + deq(codes, scale).
    The receiver applies `aq_decode` with the identical (codes, scale) and
    its own replica of `m`, keeping both buffer replicas bit-identical.
    """
    delta = a - m
    scale = quant_scale(delta)
    codes = quantize(delta, scale, noise, levels)
    m_new = m + dequantize(codes, scale, levels)
    return codes, scale, m_new


def aq_decode(codes, scale, m, levels):
    return m + dequantize(codes, scale, levels)


def attention(q, k, v, causal=True):
    """Reference multi-head causal attention. q,k,v: [B, H, S, Dh]."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool))
        s = jnp.where(mask, s, jnp.finfo(s.dtype).min)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
