"""Generate golden wire-format fixtures for the rust codec tests.

Produces `rust/tests/fixtures/golden_quant.txt`: for a handful of input
vectors, the deterministic (noise = 0.5) uniform-quantization outputs of
the paper's reference semantics (`ref.py`) — scale, codes, LSB-first
packed bytes, dequantized values — so `rust/tests/golden_codec.rs` can
pin `UniformQuantizer` + `pack` byte output without running Python.

Also produces `rust/tests/fixtures/golden_frames.txt`: full serialized
`codec::frame::Frame` images (tag | header_len:u16 | payload_len:u32 |
header | payload, little-endian) for every registered boundary codec
scheme, pinned by `rust/tests/golden_frames.rs`. The frame layouts are
emulated here byte-for-byte; change a codec's wire format and this file
must be regenerated deliberately.

The rust encoder uses an algebraically-equal but differently-associated
affine form (`x * (0.5*levels/scale) + (0.5*levels + 0.5)`), so this
script also emulates that f32 arithmetic exactly and asserts the codes
match ref.py's on every fixture before writing anything; a case where the
two float orderings straddle an integer boundary would be rejected here,
never checked in.

Run from the repo root:

    python python/compile/kernels/gen_golden.py
"""

import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent))
import ref  # noqa: E402

OUT = pathlib.Path(__file__).resolve().parents[3] / "rust" / "tests" / "fixtures"

F32 = np.float32


def ref_encode(x, bits):
    """ref.py semantics with deterministic rounding (noise = 0.5)."""
    levels = F32(2**bits - 1)
    noise = np.full(x.shape, 0.5, dtype=F32)
    scale = np.asarray(ref.quant_scale(x), dtype=F32)
    codes = np.asarray(ref.quantize(x, scale, noise, levels), dtype=F32)
    deq = np.asarray(ref.dequantize(codes, scale, levels), dtype=F32)
    return scale, codes.astype(np.uint8), deq


def rust_encode_emulated(x, bits):
    """Bit-exact emulation of UniformQuantizer::encode (Rounding::Nearest)."""
    levels = F32(2**bits - 1)
    scale = np.maximum(np.max(np.abs(x)) if x.size else F32(0.0), F32(1e-12)).astype(F32)
    k = (F32(0.5) * levels / scale).astype(F32)
    c0 = (F32(0.5) * levels + F32(0.5)).astype(F32)
    y = np.clip(x * k + c0, F32(0.0), levels)
    return scale, np.trunc(y).astype(np.uint8)


def pack_lsb_first(codes, bits):
    """LSB-first bit packing, mirroring rust codec::pack::pack_into."""
    out = bytearray((len(codes) * bits + 7) // 8)
    acc = 0
    acc_bits = 0
    o = 0
    for c in codes:
        acc |= int(c) << acc_bits
        acc_bits += bits
        while acc_bits >= 8:
            out[o] = acc & 0xFF
            o += 1
            acc >>= 8
            acc_bits -= 8
    if acc_bits:
        out[o] = acc & 0xFF
    return bytes(out)


def hex32(v):
    return f"{np.float32(v).view(np.uint32):08x}"


# ---------------------------------------------------------------------------
# Frame emulation (rust/src/codec/frame.rs + the per-scheme layouts)

import struct  # noqa: E402


def frame_bytes(tag, header, payload):
    """tag:u8 | header_len:u16 LE | payload_len:u32 LE | header | payload."""
    return bytes([tag]) + struct.pack("<HI", len(header), len(payload)) + header + payload


def f32le(values):
    return np.asarray(values, dtype="<f4").tobytes()


def frame_fp32(x):
    return frame_bytes(1, struct.pack("<I", len(x)), f32le(x))


def frame_fp16(x):
    # inputs are chosen exactly f16-representable, so the rust RTNE
    # converter and numpy's cast agree bit-for-bit
    return frame_bytes(2, struct.pack("<I", len(x)), x.astype("<f2").tobytes())


def frame_directq(x, bits):
    scale, codes = rust_encode_emulated(x, bits)
    header = struct.pack("<BIf", bits, len(x), float(scale))
    return frame_bytes(3, header, pack_lsb_first(codes, bits))


def frame_topk(x, frac, bits):
    k = max(1, min(len(x), int(np.ceil(len(x) * frac))))
    order = np.argsort(-np.abs(x), kind="stable")  # magnitudes distinct by construction
    indices = np.sort(order[:k]).astype(np.uint32)
    vals = x[indices]
    scale, codes = rust_encode_emulated(vals, bits)
    header = struct.pack("<BIIf", bits, len(x), k, float(scale))
    payload = indices.astype("<u4").tobytes() + pack_lsb_first(codes, bits)
    return frame_bytes(5, header, payload)


def aq_header(bits, el, n_rec, mode=0):
    return struct.pack("<BIIB", bits, el, n_rec, mode)


def frame_aq_full(x, bits):
    """First visit: one kind-0 record carrying the raw f32 row."""
    return frame_bytes(4, aq_header(bits, len(x), 1), bytes([0]) + f32le(x))


def frame_aq_delta(x, m, bits):
    """Revisit: kind-1 record — per-example scale + packed delta codes.
    Returns (frame, m_new) with m_new advanced exactly like the rust
    decode_add path (m += codes*k - scale, all f32)."""
    delta = (x - m).astype(F32)
    scale, codes = rust_encode_emulated(delta, bits)
    levels = F32(2**bits - 1)
    k = (F32(2.0) * scale / levels).astype(F32)
    step = (codes.astype(F32) * k).astype(F32)
    m_new = (m + (step - scale).astype(F32)).astype(F32)
    payload = bytes([1]) + struct.pack("<f", float(scale)) + pack_lsb_first(codes, bits)
    return frame_bytes(4, aq_header(bits, len(x), 1), payload), m_new


def ef_deq(c, bits):
    """Bit-exact emulation of the rust DirectQ decode path: k = 2*scale /
    levels (f32), deq = code * k - scale (f32, in that op order)."""
    scale, codes = rust_encode_emulated(c, bits)
    levels = F32(2**bits - 1)
    k = (F32(2.0) * scale / levels).astype(F32)
    return ((codes.astype(F32) * k).astype(F32) - scale).astype(F32)


def frame_ef_directq_visits(gs, bits):
    """Error-feedback gradient frames over DirectQ (codec::ef): the wire
    image per visit is a plain DirectQ frame of the *compensated* value
    c = g + e, with e advanced as c - deq(c) — all f32, mirroring
    EfCodec::encode exactly. Returns [(g, frame_bytes), ...]."""
    visits = []
    e = np.zeros_like(gs[0])
    for g in gs:
        c = (g + e).astype(F32)
        visits.append((g, frame_directq(c, bits)))
        e = (c - ef_deq(c, bits)).astype(F32)
    return visits


# ---- adaptive family (tile / had / lr), PR 10 ----


def fwht_block(x):
    """Bit-exact mirror of codec::hadamard::fwht_block: radix-2
    butterflies at strides 1, 2, 4, ... then a 1/sqrt(n) rescale, every
    op in f32 in the rust loop order."""
    n = len(x)
    h = 1
    while h < n:
        i = 0
        while i < n:
            for j in range(i, i + h):
                a = F32(x[j])
                b = F32(x[j + h])
                x[j] = F32(a + b)
                x[j + h] = F32(a - b)
            i += 2 * h
        h *= 2
    if n > 1:
        s = F32(F32(1.0) / np.sqrt(F32(n)))
        for j in range(n):
            x[j] = F32(x[j] * s)


def rotate_rows(x, el):
    """codec::hadamard::rotate_rows: greedy maximal power-of-2 blocks
    per el-element row, each FWHT'd in place."""
    for r0 in range(0, len(x), el):
        row = x[r0:r0 + el]
        off = 0
        while off < len(row):
            blk = 1 << ((len(row) - off).bit_length() - 1)
            fwht_block(row[off:off + blk])
            off += blk


def frame_had_directq(x, el, bits):
    """had:<q-bits> wire image: the inner DirectQ frame of the rotated
    values (the wrapper is invisible on the wire)."""
    rot = np.array(x, dtype=F32, copy=True)
    rotate_rows(rot, el)
    return frame_directq(rot, bits)


def tile_allocate_bits(msq, budget):
    """Pure-f64 mirror of codec::tile::allocate_bits (comparisons and
    exact *4 / /4 steps only, so python floats == rust f64 exactly)."""
    n = len(msq)
    if n == 0:
        return []
    floor = 1e-24
    mean = 0.0
    for m in msq:
        mean += m
    mean /= n
    reference = mean if mean > floor else floor
    out = []
    for m in msq:
        ratio = (m if m > floor else floor) / reference
        extra = 0
        while ratio >= 4.0 and extra < 3:
            ratio /= 4.0
            extra += 1
        while ratio < 0.25 and extra > -3:
            ratio *= 4.0
            extra -= 1
        out.append(max(1, min(8, budget + extra)))
    cap = n * budget
    total = sum(out)
    while total > cap:
        pick = None
        for i, b in enumerate(out):
            if b <= 1:
                continue
            if pick is None or msq[i] < msq[pick]:
                pick = i
        if pick is None:
            break
        out[pick] -= 1
        total -= 1
    while total < cap:
        pick = None
        for i, b in enumerate(out):
            if b >= 8:
                continue
            if pick is None or msq[i] > msq[pick]:
                pick = i
        if pick is None:
            break
        out[pick] += 1
        total += 1
    return out


def frame_tile(x, el, t, budget):
    """codec::tile wire image: header budget:u8 | t:u32 | n:u32, payload
    per tile = bits:u8 | scale:f32 | packed codes."""
    tiles = []
    msq = []
    for r0 in range(0, len(x), el):
        row = x[r0:r0 + el]
        for t0 in range(0, len(row), t):
            tile = row[t0:t0 + t]
            tiles.append(tile)
            acc = 0.0  # rust accumulates (v as f64)^2 sequentially
            for v in tile:
                acc += float(v) * float(v)
            msq.append(acc / len(tile))
    bits = tile_allocate_bits(msq, budget)
    payload = b""
    for tile, b in zip(tiles, bits):
        scale, codes = rust_encode_emulated(tile, b)
        payload += bytes([b]) + struct.pack("<f", float(scale)) + pack_lsb_first(codes, b)
    header = struct.pack("<BII", budget, t, len(x))
    return frame_bytes(8, header, payload)


def lr_comb_basis(rank, el):
    """codec::lowrank::Sketch comb init: row r is unit-norm over
    positions j % rank == r (deterministic, seed-free)."""
    basis = np.zeros((rank, el), dtype=F32)
    for r in range(rank):
        count = (el - r + rank - 1) // rank
        v = F32(F32(1.0) / np.sqrt(F32(count)))
        for j in range(r, el, rank):
            basis[r, j] = v
    return basis


def frame_lr_visits(xs, ids, rank, bits):
    """codec::lowrank wire images for one record: full first visit
    (kind 0 + raw row), then a delta visit (kind 1 + rank coeffs +
    embedded DirectQ residual frame). Valid only for full + one delta:
    the sketch stays at its comb init until a delta has flowed, so no
    Oja/orthonormalize emulation is needed here."""
    assert len(xs) == 2, "emulation covers exactly full + one delta visit"
    el = len(xs[0])
    basis = lr_comb_basis(rank, el)
    visits = []
    m = None
    for x in xs:
        header = struct.pack("<BII", rank, el, len(ids))
        if m is None:
            payload = bytes([0]) + f32le(x)
            m = np.array(x, dtype=F32, copy=True)
        else:
            delta = np.empty(el, dtype=F32)
            for j in range(el):
                delta[j] = F32(F32(x[j]) - F32(m[j]))
            coeffs = []
            for r in range(rank):  # sequential f32 fold, rust dot_row order
                acc = F32(0.0)
                for j in range(el):
                    acc = F32(acc + F32(basis[r, j] * delta[j]))
                coeffs.append(acc)
            resid = np.array(delta, dtype=F32, copy=True)
            for r in range(rank):  # r-ascending, rust subtract_projection
                c = coeffs[r]
                for j in range(el):
                    resid[j] = F32(resid[j] - F32(c * basis[r, j]))
            payload = bytes([1]) + f32le(coeffs) + frame_directq(resid, bits)
        visits.append((np.array(x, dtype=F32), frame_bytes(9, header, payload)))
    return visits


def frame_cases():
    """(name, scheme spec, ids, [(x, frame_bytes), ...] per visit)."""
    rng = np.random.default_rng(0xF4A3)
    ramp = np.array([-1.0, -0.5, 0.0, 0.5, 1.0], dtype=F32)
    yield "frame_fp32_n5", "fp32", [0], [(ramp, frame_fp32(ramp))]

    h16 = rng.standard_normal(7).astype(np.float16).astype(F32)
    assert np.all(np.abs(h16) >= 6.2e-5), "pick another seed: f16 subnormal"
    yield "frame_fp16_n7", "fp16", [0], [(h16, frame_fp16(h16))]

    q4 = (rng.standard_normal(33) * 1.5).astype(F32)
    yield "frame_q4_n33", "q4", [0], [(q4, frame_directq(q4, 4))]

    q3 = (rng.standard_normal(7) * 0.25).astype(F32)
    yield "frame_q3_n7", "q3", [0], [(q3, frame_directq(q3, 3))]

    tk = np.array([(0.1 * (i + 1)) * (-1.0 if i % 2 else 1.0) for i in range(16)], dtype=F32)
    yield "frame_topk25_n16", "topk0.25@8", [0], [(tk, frame_topk(tk, 0.25, 8))]

    x0 = rng.standard_normal(6).astype(F32)
    x1 = (x0 + (0.01 * rng.standard_normal(6)).astype(F32)).astype(F32)
    f0 = frame_aq_full(x0, 2)
    f1, _m = frame_aq_delta(x1, x0, 2)  # after a full visit, m == x0 exactly
    yield "frame_aq2_el6", "aq2", [9], [(x0, f0), (x1, f1)]

    # ef: gradient frames (the --dp-codec wire format): three rounds so
    # the fixtures pin the zero-residual first frame AND the compensated
    # revisits where e = c - deq(c) feeds forward
    g4 = [(rng.standard_normal(12) * 0.02).astype(F32) for _ in range(3)]
    yield "frame_ef_q4_el12", "ef:q4", [3], frame_ef_directq_visits(g4, 4)

    g2 = [(rng.standard_normal(6) * 0.05).astype(F32) for _ in range(2)]
    yield "frame_ef_q2_el6", "ef:q2", [0], frame_ef_directq_visits(g2, 2)

    # tile: three 4-element tiles with decade-spread power so the
    # variance-driven bit map ([1, 3, 8] here, avg == budget 4) is
    # exercised, not a constant row
    tl = np.concatenate([
        rng.standard_normal(4).astype(F32) * F32(0.01),
        rng.standard_normal(4).astype(F32),
        rng.standard_normal(4).astype(F32) * F32(10.0),
    ]).astype(F32)
    yield "frame_tile4_q4_el12", "tile:4:q4", [0], [(tl, frame_tile(tl, 12, 4, 4))]

    # had: el = 12 pins the greedy 8 + 4 block decomposition and the
    # butterfly order / 1/sqrt(n) scaling inside each block
    hd = (rng.standard_normal(12) * 1.5).astype(F32)
    yield "frame_had_q4_el12", "had:q4", [0], [(hd, frame_had_directq(hd, 12, 4))]

    # lr: lossless full first visit, then a delta visit projected on the
    # pristine comb basis with the residual through the inner DirectQ
    l0 = rng.standard_normal(6).astype(F32)
    l1 = (l0 + (0.02 * rng.standard_normal(6)).astype(F32)).astype(F32)
    yield "frame_lr2_q4_el6", "lr:2:q4", [7], frame_lr_visits([l0, l1], [7], 2, 4)


def write_frames():
    lines = [
        "# Golden serialized Frame images for every boundary codec scheme.",
        "# Generated by python/compile/kernels/gen_golden.py. Do not edit.",
        "# x values are f32 bit patterns in hex; frame is the full wire",
        "# image (tag|header_len|payload_len|header|payload), hex bytes.",
        "",
    ]
    for name, scheme, ids, visits in frame_cases():
        lines += [f"case {name}", f"scheme {scheme}",
                  "ids " + " ".join(str(i) for i in ids)]
        for vi, (x, fb) in enumerate(visits):
            suffix = "" if vi == 0 else str(vi + 1)
            lines += [f"x{suffix} " + " ".join(hex32(v) for v in x),
                      f"frame{suffix} " + fb.hex()]
        lines += ["end", ""]
        print(f"{name}: scheme={scheme} visits={len(visits)} "
              f"bytes={'/'.join(str(len(fb)) for _, fb in visits)}")
    (OUT / "golden_frames.txt").write_text("\n".join(lines))
    print(f"wrote {OUT / 'golden_frames.txt'}")


def case_vectors():
    rng = np.random.default_rng(0xA25D)
    yield "normal_2bit_n33", 2, (rng.standard_normal(33) * 1.5).astype(F32)
    yield "normal_3bit_n7", 3, (rng.standard_normal(7) * 0.25).astype(F32)
    yield "normal_4bit_n64", 4, (rng.standard_normal(64) * 3.0).astype(F32)
    yield "normal_8bit_n16", 8, (rng.standard_normal(16) * 10.0).astype(F32)
    yield "zeros_4bit_n12", 4, np.zeros(12, dtype=F32)
    yield "ramp_2bit_n5", 2, np.array([-1.0, -0.5, 0.0, 0.5, 1.0], dtype=F32)
    yield "smallmag_8bit_n9", 8, (rng.standard_normal(9) * 1e-4).astype(F32)


def main():
    lines = [
        "# Golden wire-format fixtures for the uniform quantizer + bit packer.",
        "# Generated by python/compile/kernels/gen_golden.py from ref.py",
        "# (deterministic rounding, noise = 0.5). Do not edit by hand.",
        "# f32 values are IEEE-754 bit patterns in hex; packed is hex bytes.",
        "",
    ]
    for name, bits, x in case_vectors():
        scale, codes, deq = ref_encode(x, bits)
        r_scale, r_codes = rust_encode_emulated(x, bits)
        assert hex32(scale) == hex32(r_scale), f"{name}: scale mismatch"
        assert np.array_equal(codes, r_codes), (
            f"{name}: ref.py vs rust-affine codes disagree "
            f"(pick a different seed): {codes} vs {r_codes}"
        )
        packed = pack_lsb_first(codes, bits)
        lines += [
            f"case {name}",
            f"bits {bits}",
            f"n {len(x)}",
            "x " + " ".join(hex32(v) for v in x),
            f"scale {hex32(scale)}",
            "codes " + " ".join(str(int(c)) for c in codes),
            "packed " + packed.hex(),
            "deq " + " ".join(hex32(v) for v in deq),
            "end",
            "",
        ]
        print(f"{name}: bits={bits} n={len(x)} scale={float(scale):.6g} "
              f"packed={len(packed)}B")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "golden_quant.txt").write_text("\n".join(lines))
    print(f"wrote {OUT / 'golden_quant.txt'}")
    write_frames()


if __name__ == "__main__":
    main()
