"""Named model/export configurations.

Each config fully determines the shapes of every AOT artifact (HLO is
shape-static), the pipeline partitioning (K stages) and the task head.
The rust coordinator discovers everything through the emitted manifest.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    micro_batch: int
    n_stages: int
    task: str = "lm"  # "lm" (next-token) or "cls" (sequence classification)
    n_classes: int = 2
    attn: str = "jnp"  # "jnp" (fused jnp attention) or "pallas" (L1 kernel)
    d_ff_mult: int = 4
    init_scale: float = 0.02
    seed: int = 0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return self.d_ff_mult * self.d_model

    @property
    def boundary_shape(self):
        """Activation shape exchanged between pipeline stages."""
        return (self.micro_batch, self.seq, self.d_model)

    def stage_layers(self, stage: int):
        """Contiguous [lo, hi) transformer-block range owned by `stage`.

        Blocks are split as evenly as possible; the embedding joins stage 0
        and the task head joins the last stage.
        """
        assert 0 <= stage < self.n_stages
        base, rem = divmod(self.n_layers, self.n_stages)
        lo = stage * base + min(stage, rem)
        hi = lo + base + (1 if stage < rem else 0)
        return lo, hi


# Registry of exportable configurations. "tiny*" drive tests; "small" drives
# the quickstart/figure examples; "e2e" drives the end-to-end training run.
CONFIGS = {}


def _reg(cfg: ModelCfg) -> ModelCfg:
    CONFIGS[cfg.name] = cfg
    return cfg


TINY = _reg(ModelCfg("tiny", vocab=256, d_model=32, n_layers=2, n_heads=2,
                     seq=32, micro_batch=4, n_stages=2))
TINY_PALLAS = _reg(ModelCfg("tiny_pallas", vocab=256, d_model=32, n_layers=2,
                            n_heads=2, seq=32, micro_batch=4, n_stages=2,
                            attn="pallas"))
TINY_CLS = _reg(ModelCfg("tiny_cls", vocab=256, d_model=32, n_layers=2,
                         n_heads=2, seq=32, micro_batch=4, n_stages=2,
                         task="cls", n_classes=2))
SMALL = _reg(ModelCfg("small", vocab=512, d_model=128, n_layers=4, n_heads=4,
                      seq=64, micro_batch=8, n_stages=4))
SMALL_CLS = _reg(ModelCfg("small_cls", vocab=512, d_model=128, n_layers=4,
                          n_heads=4, seq=64, micro_batch=8, n_stages=4,
                          task="cls", n_classes=2))
E2E = _reg(ModelCfg("e2e", vocab=256, d_model=256, n_layers=8, n_heads=8,
                    seq=128, micro_batch=4, n_stages=4))

DEFAULT_EXPORT = ["tiny", "tiny_pallas", "tiny_cls", "small", "small_cls"]
