"""AOT exporter: lowers every L2 function to HLO *text* + a manifest.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per config `<name>` this writes under `artifacts/<name>/`:
  stage{i}_fwd.hlo.txt / stage{i}_bwd.hlo.txt       (i < K-1)
  stage{K-1}_loss.hlo.txt / stage{K-1}_lossbwd.hlo.txt
  stage{i}_init.bin            flat f32 LE initial parameters
  adamw_p{N}.hlo.txt           AdamW update per distinct param count
  aq_encode / aq_decode / dq_encode / dq_decode .hlo.txt   (L1 kernels)
  manifest.txt                 flat `key value` lines (rust-parsed)

Run: python -m compile.aot --out-dir ../artifacts [--configs a,b] [--force]
"""

import argparse
import hashlib
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc
from jax.flatten_util import ravel_pytree

from . import model, optimizer
from .configs import CONFIGS, DEFAULT_EXPORT, ModelCfg
from .kernels import quant


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def export_config(cfg: ModelCfg, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []

    def kv(k, v):
        manifest.append(f"{k} {v}")

    kv("version", 1)
    for field in ("name", "task", "vocab", "d_model", "n_layers", "n_heads",
                  "seq", "micro_batch", "n_stages", "n_classes", "attn"):
        kv(field, getattr(cfg, field))
    kv("boundary", "x".join(str(d) for d in cfg.boundary_shape))

    # ---- stage artifacts -------------------------------------------------
    all_params = model.init_all_params(cfg)
    adamw_sizes = set()
    for i in range(cfg.n_stages):
        fns = model.make_stage_fns(cfg, i)
        n = fns["param_count"]
        pf_spec = f32(n)
        x_spec = model.input_spec(cfg, i)
        b_spec = f32(*cfg.boundary_shape)
        kv(f"stage{i}.params", n)
        adamw_sizes.add(n)
        kv(f"stage{i}.adamw", f"adamw_p{n}.hlo.txt")

        last = i == cfg.n_stages - 1
        if not last or cfg.n_stages == 1:
            name = f"stage{i}_fwd.hlo.txt"
            _write(os.path.join(out_dir, name),
                   lower(fns["fwd"], pf_spec, x_spec))
            kv(f"stage{i}.fwd", name)
        if not last:
            name = f"stage{i}_bwd.hlo.txt"
            _write(os.path.join(out_dir, name),
                   lower(fns["bwd"], pf_spec, x_spec, b_spec))
            kv(f"stage{i}.bwd", name)
        else:
            t_spec = model.target_spec(cfg)
            name = f"stage{i}_loss.hlo.txt"
            _write(os.path.join(out_dir, name),
                   lower(fns["loss"], pf_spec, x_spec, t_spec))
            kv(f"stage{i}.loss", name)
            name = f"stage{i}_lossbwd.hlo.txt"
            _write(os.path.join(out_dir, name),
                   lower(fns["lossbwd"], pf_spec, x_spec, t_spec))
            kv(f"stage{i}.lossbwd", name)
            # inference head (generation case study, paper App. I)
            name = f"stage{i}_logits.hlo.txt"
            _write(os.path.join(out_dir, name),
                   lower(fns["logits"], pf_spec, x_spec))
            kv(f"stage{i}.logits", name)

        flat, _ = ravel_pytree(all_params[i])
        init_name = f"stage{i}_init.bin"
        np.asarray(flat, dtype="<f4").tofile(os.path.join(out_dir, init_name))
        kv(f"stage{i}.init", init_name)

    # ---- optimizer -------------------------------------------------------
    for n in sorted(adamw_sizes):
        name = f"adamw_p{n}.hlo.txt"
        _write(os.path.join(out_dir, name),
               lower(optimizer.adamw_fn, f32(n), f32(n), f32(n), f32(n),
                     f32(), f32()))

    # ---- quantization codecs (L1 Pallas kernels) -------------------------
    b = f32(*cfg.boundary_shape)
    s = f32()
    _write(os.path.join(out_dir, "aq_encode.hlo.txt"),
           lower(quant.aq_encode, b, b, b, s))
    kv("quant.aq_encode", "aq_encode.hlo.txt")
    _write(os.path.join(out_dir, "aq_decode.hlo.txt"),
           lower(lambda c, sc, m, lv: (quant.aq_decode(c, sc, m, lv),),
                 b, s, b, s))
    kv("quant.aq_decode", "aq_decode.hlo.txt")
    _write(os.path.join(out_dir, "dq_encode.hlo.txt"),
           lower(quant.directq_encode, b, b, s))
    kv("quant.dq_encode", "dq_encode.hlo.txt")
    _write(os.path.join(out_dir, "dq_decode.hlo.txt"),
           lower(lambda c, sc, lv: (quant.directq_decode(c, sc, lv),),
                 b, s, s))
    kv("quant.dq_decode", "dq_decode.hlo.txt")

    _write(os.path.join(out_dir, "manifest.txt"), "\n".join(manifest) + "\n")


def source_fingerprint() -> str:
    """Hash of the compile-path sources, used for make-style freshness."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_EXPORT))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    fp = source_fingerprint()
    for name in args.configs.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in CONFIGS:
            print(f"unknown config {name!r}; known: {sorted(CONFIGS)}")
            sys.exit(1)
        out = os.path.join(args.out_dir, name)
        stamp = os.path.join(out, ".fingerprint")
        if not args.force and os.path.exists(stamp):
            with open(stamp) as f:
                if f.read().strip() == fp:
                    print(f"[{name}] up to date")
                    continue
        print(f"[{name}] exporting to {out}")
        export_config(CONFIGS[name], out)
        with open(stamp, "w") as f:
            f.write(fp)


if __name__ == "__main__":
    main()
