"""L2: flat AdamW update, exported per distinct stage parameter count.

Matches the paper's App. C training setup (AdamW, warmup + linear decay —
the schedule itself lives in the rust coordinator; only the state update
is compiled). Hyper-parameters beta1/beta2/eps/weight-decay are baked at
lowering time; step and lr are runtime scalars.

The rust `optim` module also carries a native implementation; a parity
test pins the two against each other.
"""

import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8
WEIGHT_DECAY = 0.01


def adamw_update(p, m, v, g, step, lr):
    """One AdamW step over flat f32 vectors.

    step: f32 scalar, 1-based step count (for bias correction).
    Returns (p_new, m_new, v_new).
    """
    m_new = BETA1 * m + (1.0 - BETA1) * g
    v_new = BETA2 * v + (1.0 - BETA2) * g * g
    m_hat = m_new / (1.0 - BETA1 ** step)
    v_hat = v_new / (1.0 - BETA2 ** step)
    update = m_hat / (jnp.sqrt(v_hat) + EPS) + WEIGHT_DECAY * p
    return p - lr * update, m_new, v_new


def adamw_fn(p, m, v, g, step, lr):
    return adamw_update(p, m, v, g, step, lr)
