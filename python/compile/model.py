"""L2: GPT-style decoder-only transformer, partitioned into pipeline stages.

Every stage is a pure function over a *flat* f32 parameter vector (the
uniform interface the rust coordinator sees), exported AOT as HLO text:

    stage i (i < K-1):
        fwd : (params_flat, x_in)        -> x_out
        bwd : (params_flat, x_in, g_out) -> (g_params_flat, g_in)
    stage 0's bwd drops g_in (its input is token ids);
    last stage:
        lossbwd : (params_flat, x_in, targets) -> (loss, g_params_flat, g_in)
        loss    : (params_flat, x_in, targets) -> loss          (eval only)

Backward is recomputation-style (`jax.vjp` over the stage forward): the
pipeline ships no residuals between machines, exactly like the paper's
setting where only activations cross the wire.

Architecture: pre-LN blocks, learned positional embeddings, GELU MLP,
untied LM head. Attention is either fused-jnp (default; fastest under the
CPU PJRT backend) or the L1 Pallas flash kernel (cfg.attn == "pallas").
"""

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .configs import ModelCfg
from .kernels import attention as attn_kernel
from .kernels import ref as kref


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelCfg, key):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 6)
    s = cfg.init_scale
    return {
        "ln1_g": jnp.ones((d,), jnp.float32),
        "ln1_b": jnp.zeros((d,), jnp.float32),
        "wqkv": jax.random.normal(ks[0], (d, 3 * d), jnp.float32) * s,
        "bqkv": jnp.zeros((3 * d,), jnp.float32),
        "wo": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "bo": jnp.zeros((d,), jnp.float32),
        "ln2_g": jnp.ones((d,), jnp.float32),
        "ln2_b": jnp.zeros((d,), jnp.float32),
        "w1": jax.random.normal(ks[2], (d, f), jnp.float32) * s,
        "b1": jnp.zeros((f,), jnp.float32),
        "w2": jax.random.normal(ks[3], (f, d), jnp.float32) * s,
        "b2": jnp.zeros((d,), jnp.float32),
    }


def _init_embed(cfg: ModelCfg, key):
    k1, k2 = jax.random.split(key)
    s = cfg.init_scale
    return {
        "tok": jax.random.normal(k1, (cfg.vocab, cfg.d_model), jnp.float32) * s,
        "pos": jax.random.normal(k2, (cfg.seq, cfg.d_model), jnp.float32) * s,
    }


def _init_head(cfg: ModelCfg, key):
    d = cfg.d_model
    out = cfg.vocab if cfg.task == "lm" else cfg.n_classes
    return {
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
        "w": jax.random.normal(key, (d, out), jnp.float32) * cfg.init_scale,
        "b": jnp.zeros((out,), jnp.float32),
    }


def init_stage_params(cfg: ModelCfg, stage: int, key):
    """Pytree of parameters owned by `stage`."""
    lo, hi = cfg.stage_layers(stage)
    keys = jax.random.split(key, cfg.n_layers + 2)
    p = {}
    if stage == 0:
        p["embed"] = _init_embed(cfg, keys[0])
    p["blocks"] = [_init_block(cfg, keys[1 + l]) for l in range(lo, hi)]
    if stage == cfg.n_stages - 1:
        p["head"] = _init_head(cfg, keys[-1])
    return p


def init_all_params(cfg: ModelCfg, seed=None):
    seed = cfg.seed if seed is None else seed
    root = jax.random.PRNGKey(seed)
    keys = jax.random.split(root, cfg.n_stages)
    return [init_stage_params(cfg, i, keys[i]) for i in range(cfg.n_stages)]


def stage_unravel(cfg: ModelCfg, stage: int):
    """(param_count, unravel_fn) for `stage`'s flat parameter vector."""
    p = init_stage_params(cfg, stage, jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(p)
    return flat.shape[0], unravel


# ---------------------------------------------------------------------------
# Forward computation
# ---------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(cfg: ModelCfg, p, x):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = x @ p["wqkv"] + p["bqkv"]                       # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)      # [b, h, s, dh]
    k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    if cfg.attn == "pallas":
        o = attn_kernel.flash_attention(q, k, v, True)
    else:
        o = kref.attention(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ p["wo"] + p["bo"]


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _block(cfg: ModelCfg, p, x):
    x = x + _attention(cfg, p, _layernorm(x, p["ln1_g"], p["ln1_b"]))
    x = x + _mlp(p, _layernorm(x, p["ln2_g"], p["ln2_b"]))
    return x


def _embed(cfg: ModelCfg, p, tokens):
    # tokens: i32 [B, S]
    return p["tok"][tokens] + p["pos"][None, :, :]


def stage_apply(cfg: ModelCfg, stage: int, params, x):
    """Stage forward over the pytree params. `x` is tokens for stage 0,
    hidden states otherwise. Returns the outgoing hidden states."""
    if stage == 0:
        x = _embed(cfg, params["embed"], x)
    for bp in params["blocks"]:
        x = _block(cfg, bp, x)
    return x


def head_logits(cfg: ModelCfg, hp, h):
    h = _layernorm(h, hp["lnf_g"], hp["lnf_b"])
    if cfg.task == "cls":
        h = jnp.mean(h, axis=1)                            # [B, D]
    return h @ hp["w"] + hp["b"]


def head_loss(cfg: ModelCfg, hp, h, targets):
    """Mean cross-entropy. LM: next-token prediction (targets[:, t] is the
    gold token for position t+1 ... we follow the convention that `targets`
    is the input sequence itself and shift internally). CLS: targets are
    labels i32[B]."""
    logits = head_logits(cfg, hp, h)
    if cfg.task == "lm":
        lg = logits[:, :-1, :]                             # predict t+1
        tg = targets[:, 1:]
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - gold)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def last_stage_loss(cfg: ModelCfg, params, x, targets):
    h = stage_apply(cfg, cfg.n_stages - 1, params, x)
    return head_loss(cfg, params["head"], h, targets)


# ---------------------------------------------------------------------------
# Flat-parameter stage functions (the AOT export surface)
# ---------------------------------------------------------------------------

def make_stage_fns(cfg: ModelCfg, stage: int):
    """Returns a dict of flat-parameter functions for `stage`:
    {fwd, bwd} for non-last stages, {lossbwd, loss, fwd} for the last."""
    n, unravel = stage_unravel(cfg, stage)
    last = stage == cfg.n_stages - 1

    def fwd(pf, x):
        return (stage_apply(cfg, stage, unravel(pf), x),)

    fns = {"fwd": fwd, "param_count": n}

    if not last:
        if stage == 0:
            def bwd(pf, x, g):
                _, vjp = jax.vjp(lambda pf_: fwd(pf_, x)[0], pf)
                (gp,) = vjp(g)
                return (gp,)
        else:
            def bwd(pf, x, g):
                _, vjp = jax.vjp(lambda pf_, x_: fwd(pf_, x_)[0], pf, x)
                gp, gx = vjp(g)
                return (gp, gx)
        fns["bwd"] = bwd
    else:
        def loss_fn(pf, x, t):
            return last_stage_loss(cfg, unravel(pf), x, t)

        def loss(pf, x, t):
            return (loss_fn(pf, x, t),)

        def logits(pf, x):
            p = unravel(pf)
            h = stage_apply(cfg, cfg.n_stages - 1, p, x)
            return (head_logits(cfg, p["head"], h),)

        fns["logits"] = logits

        if cfg.n_stages == 1:
            # degenerate single-stage pipeline: x is tokens
            def lossbwd(pf, x, t):
                l, vjp = jax.vjp(lambda pf_: loss_fn(pf_, x, t), pf)
                (gp,) = vjp(jnp.float32(1.0))
                return (l, gp)
        else:
            def lossbwd(pf, x, t):
                l, vjp = jax.vjp(lambda pf_, x_: loss_fn(pf_, x_, t), pf, x)
                gp, gx = vjp(jnp.float32(1.0))
                return (l, gp, gx)
        fns["loss"] = loss
        fns["lossbwd"] = lossbwd
    return fns


def full_model_loss(cfg: ModelCfg, all_params, tokens, targets):
    """Monolithic (non-pipelined) loss — test oracle for stage composition."""
    x = tokens
    for i in range(cfg.n_stages):
        if i < cfg.n_stages - 1:
            x = stage_apply(cfg, i, all_params[i], x)
    return last_stage_loss(cfg, all_params[-1], x, targets)


def input_spec(cfg: ModelCfg, stage: int):
    """ShapeDtypeStruct of the stage input."""
    if stage == 0:
        return jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq), jnp.int32)
    return jax.ShapeDtypeStruct(cfg.boundary_shape, jnp.float32)


def target_spec(cfg: ModelCfg):
    if cfg.task == "lm":
        return jax.ShapeDtypeStruct((cfg.micro_batch, cfg.seq), jnp.int32)
    return jax.ShapeDtypeStruct((cfg.micro_batch,), jnp.int32)
