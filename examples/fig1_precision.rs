//! Figure 1 reproduction.
//!  (a) fine-tuning with different *direct* activation precisions vs
//!      AQ-SGD: aggressive DirectQ converges to a worse loss; AQ-SGD at
//!      the same bits tracks FP32.
//!  (b) average |activation| vs average |activation delta| during
//!      training: the delta is much smaller — the signal AQ-SGD encodes.
//!
//!     cargo run --release --example fig1_precision [-- --epochs N]

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp;
use aq_sgd::metrics::Table;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 8)?;

    let mut cfg0 = TrainConfig::defaults("tiny");
    cfg0.epochs = epochs;
    cfg0.n_micro = 3;
    cfg0.n_examples = 96;
    cfg0.lr = 2e-3;
    cfg0.warmup_steps = 10;

    let variants: Vec<(String, CodecSpec)> = vec![
        ("FP32".into(), CodecSpec::fp32()),
        ("DirectQ fw8 bw8".into(), CodecSpec::directq(8, 8)),
        ("DirectQ fw4 bw4".into(), CodecSpec::directq(4, 4)),
        ("DirectQ fw2 bw2".into(), CodecSpec::directq(2, 2)),
        ("AQ-SGD fw2 bw2".into(), CodecSpec::aqsgd(2, 2)),
    ];

    let mut runs = Vec::new();
    let mut table = Table::new(&["method", "final train loss", "diverged"]);
    for (label, c) in variants {
        let mut cfg = cfg0.clone();
        cfg.compression = c;
        println!("== {label} ==");
        let run = exp::run_variant(cfg, &label)?;
        table.row(vec![
            label.clone(),
            format!("{:.4}", run.stats.final_train_loss),
            if run.diverged { "x".into() } else { "".into() },
        ]);
        runs.push(run);
    }
    println!("\nFigure 1a — loss after {epochs} epochs, by wire precision:");
    print!("{}", table.render());
    exp::save_traces("results/fig1a_precision.csv", &runs)?;

    // Fig 1b: the AQ-SGD run's probe trace
    let aq = runs.last().unwrap();
    println!("\nFigure 1b — mean |activation| vs mean |delta| (AQ-SGD run):");
    let mut t = Table::new(&["step", "mean |a|", "mean |delta|", "ratio"]);
    for (step, a, d) in aq.probe.iter().step_by(aq.probe.len().max(8) / 8) {
        t.row(vec![
            step.to_string(),
            format!("{a:.4}"),
            format!("{d:.4}"),
            format!("{:.1}x", a / d.max(1e-9)),
        ]);
    }
    print!("{}", t.render());
    let mut csv = String::from("step,mean_abs_act,mean_abs_delta\n");
    for (s, a, d) in &aq.probe {
        csv.push_str(&format!("{s},{a:.6},{d:.6}\n"));
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig1b_delta.csv", csv)?;
    println!("probe -> results/fig1b_delta.csv");
    Ok(())
}
