//! Figure 10 reproduction: split learning with 16 non-IID clients
//! (Dirichlet 0.5) on the synthetic classification task. Clients hold
//! the cut layer; activations / activation-gradients cross the cut with
//! FP32, DirectQ or AQ-SGD compression — including paper App. H.6's
//! exact scheme, `fw2 bw8[0.2]`: 2-bit AQ forward with top-20% + 8-bit
//! backward sparsification, spelled `hybrid:aq2/topk0.2@8` in the codec
//! registry and run end-to-end below.
//!
//!     cargo run --release --example split_learning [-- --rounds N]

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::coordinator::split::SplitLearning;
use aq_sgd::data::cls;
use aq_sgd::metrics::Table;
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let rounds = cli.usize("rounds", 3)?;
    let n_clients = cli.usize("clients", 16)?;

    let mut table = Table::new(&["method", "round", "eval loss", "comm"]);
    for (label, c) in [
        ("FP32".to_string(), CodecSpec::fp32()),
        ("DirectQ fw2 bw8".to_string(), CodecSpec::directq(2, 8)),
        ("AQ-SGD fw2 bw8".to_string(), CodecSpec::aqsgd(2, 8)),
        // App. H.6's `bw8[0.2]`: top-20% backward sparsification
        ("AQ-SGD fw2 bw8[0.2]".to_string(), CodecSpec::parse("hybrid:aq2/topk0.2@8")?),
    ] {
        let mut cfg = TrainConfig::defaults("tiny_cls");
        cfg.compression = c;
        cfg.lr = 1e-3;
        cfg.warmup_steps = 5;
        cfg.n_examples = 0; // dataset provided explicitly below
        let data = cls::qnli_like(256, 32, 320, 42);
        let mut sl = SplitLearning::new(cfg, data, n_clients, 0.5, 1)?;
        println!("== {label} ({} clients) ==", sl.n_clients());
        for r in 0..rounds {
            let out = sl.round(r)?;
            println!(
                "  round {} eval {:.4} comm {}",
                r,
                out.eval_loss,
                fmt::bytes(out.comm_bytes)
            );
            table.row(vec![
                label.clone(),
                r.to_string(),
                format!("{:.4}", out.eval_loss),
                fmt::bytes(out.comm_bytes),
            ]);
        }
    }
    println!("\nFigure 10 — split learning (paper: AQ-SGD tracks FP32 in 2-bit");
    println!("forward; DirectQ converges worse):");
    print!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig10_split.csv", table.to_csv())?;
    Ok(())
}
