//! Figure 10 reproduction: split learning with 16 non-IID clients
//! (Dirichlet 0.5) on the synthetic classification task. Clients hold
//! the cut layer; activations / activation-gradients cross the cut with
//! FP32, DirectQ or AQ-SGD compression (paper App. H.6: fw2 bw8 with
//! top-20% backward sparsification — our backward uses dense bw8, and the
//! top-k codec is exercised/benchmarked in codec::topk).
//!
//!     cargo run --release --example split_learning [-- --rounds N]

use aq_sgd::util::error::Result;

use aq_sgd::codec::Compression;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::coordinator::split::SplitLearning;
use aq_sgd::data::cls;
use aq_sgd::metrics::Table;
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let rounds = cli.usize("rounds", 3)?;
    let n_clients = cli.usize("clients", 16)?;

    let mut table = Table::new(&["method", "round", "eval loss", "comm"]);
    for (label, c) in [
        ("FP32".to_string(), Compression::Fp32),
        ("DirectQ fw2 bw8".to_string(), Compression::DirectQ { fw_bits: 2, bw_bits: 8 }),
        ("AQ-SGD fw2 bw8".to_string(), Compression::AqSgd { fw_bits: 2, bw_bits: 8 }),
    ] {
        let mut cfg = TrainConfig::defaults("tiny_cls");
        cfg.compression = c;
        cfg.lr = 1e-3;
        cfg.warmup_steps = 5;
        cfg.n_examples = 0; // dataset provided explicitly below
        let data = cls::qnli_like(256, 32, 320, 42);
        let mut sl = SplitLearning::new(cfg, data, n_clients, 0.5, 1)?;
        println!("== {label} ({} clients) ==", sl.n_clients());
        for r in 0..rounds {
            let out = sl.round(r)?;
            println!(
                "  round {} eval {:.4} comm {}",
                r,
                out.eval_loss,
                fmt::bytes(out.comm_bytes)
            );
            table.row(vec![
                label.clone(),
                r.to_string(),
                format!("{:.4}", out.eval_loss),
                fmt::bytes(out.comm_bytes),
            ]);
        }
    }
    println!("\nFigure 10 — split learning (paper: AQ-SGD tracks FP32 in 2-bit");
    println!("forward; DirectQ converges worse):");
    print!("{}", table.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/fig10_split.csv", table.to_csv())?;
    Ok(())
}
