//! Table 3 reproduction: per-microbatch computation vs communication
//! breakdown of AQ-SGD (fw4 bw8) on the GPT2-1.5B regime. The paper
//! reports 45/135 ms compute and 13..63 / 25..125 ms communication as
//! bandwidth drops from 500 to 100 Mbps; the communication columns are
//! pure message-size/bandwidth arithmetic our simulator reproduces
//! exactly from the packed wire bytes.
//!
//!     cargo run --release --example table3_breakdown

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::exp::PaperRegime;
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{PipelineSim, SimConfig};
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let regime = PaperRegime::default();
    let c = CodecSpec::aqsgd(4, 8);
    let (fw_bytes, bw_bytes) = regime.msg_bytes(&c, false);

    println!(
        "AQ-SGD fw4 bw8 on GPT2-1.5B: fw message {} / bw message {}\n",
        fmt::bytes(fw_bytes),
        fmt::bytes(bw_bytes)
    );
    let mut t = Table::new(&[
        "Network",
        "fwd comp.",
        "fwd comm.",
        "bwd comp.",
        "bwd comm.",
        "comm hidden?",
    ]);
    for mbps in [500.0, 300.0, 200.0, 100.0] {
        let bw = mbps * 1e6;
        let cfg = SimConfig::uniform(
            regime.n_stages,
            regime.n_micro,
            regime.fwd_s,
            regime.bwd_s,
            fw_bytes,
            bw_bytes,
            bw,
        );
        let r = PipelineSim::run(&cfg);
        let hidden = r.fw_msg_tx_s <= regime.fwd_s && r.bw_msg_tx_s <= regime.bwd_s;
        t.row(vec![
            format!("{mbps:.0} Mbps"),
            fmt::duration_s(regime.fwd_s),
            fmt::duration_s(r.fw_msg_tx_s),
            fmt::duration_s(regime.bwd_s),
            fmt::duration_s(r.bw_msg_tx_s),
            if hidden { "yes (overlapped)".into() } else { "no (comm-bound)".into() },
        ]);
    }
    print!("{}", t.render());
    println!("\n(paper Table 3: 45/13, 45/21, 45/31, 45/63 ms fwd and 135/25..125 ms bwd)");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table3_breakdown.csv", t.to_csv())?;
    Ok(())
}
