//! Tables 2 & 5 reproduction: training throughput (seqs/s) across the
//! bandwidth ladder for FP32 / DirectQ / AQ-SGD at the paper's bit
//! configurations, in the paper's own regime (GPT2-1.5B partitioned over
//! 8 stages, 32 microbatches of 1 x 1024 x 1600; 45 ms fwd / 135 ms bwd
//! per microbatch — Table 3's measured compute times).
//!
//! DirectQ and AQ-SGD have identical steady-state message sizes (AQ-SGD's
//! delta codes are the same width), which is exactly the paper's finding
//! that AQ-SGD adds no runtime overhead (Table 2: columns match to 0.1).
//!
//!     cargo run --release --example table2_throughput [-- --deberta]
//!     cargo run --release --example table2_throughput -- --executor threads
//!     cargo run --release --example table2_throughput -- --executor events --workers 4
//!
//! `--executor threads` (or `events`) swaps the analytic sweep for the
//! *real* pipeline runtime on a scaled-down regime: workers exchange
//! actual codec frames over bandwidth-paced channel links, and measured
//! wall step times are printed next to the virtual-clock oracle's
//! prediction for the same run (the Table 2 shape — FP32 collapsing with
//! bandwidth while AQ-SGD holds — reproduced with real concurrency).
//! `events` runs the same sweep on the fixed worker pool (`--workers`).

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::Cli;
use aq_sgd::exp::PaperRegime;
use aq_sgd::metrics::Table;
use aq_sgd::net::PAPER_BANDWIDTHS;
use aq_sgd::pipeline::exec::{self, ExecConfig};
use aq_sgd::pipeline::{Executor, PipelineSim, SimConfig};
use aq_sgd::util::fmt;

fn throughput(regime: &PaperRegime, c: &CodecSpec, bandwidth_bps: f64) -> f64 {
    let (fw, bw) = regime.msg_bytes(c, false);
    let cfg = SimConfig::uniform(
        regime.n_stages,
        regime.n_micro,
        regime.fwd_s,
        regime.bwd_s,
        fw,
        bw,
        bandwidth_bps,
    );
    PipelineSim::run(&cfg).throughput(regime.n_micro, regime.micro_batch)
}

/// Scaled-down Table 2 on the real runtime (threads or events): 4
/// stages, 8 microbatches of 1 x 16Ki elements (64 KB fp32 boundary
/// messages), so a full bandwidth-ladder sweep finishes in seconds while
/// the link pacing still dominates FP32 at the slow end.
fn run_real_sweep(executor: Executor, workers: usize) -> Result<()> {
    println!("Table 2 (scaled, real {} executor): mean wall step time\n", executor.label());
    let mut t = Table::new(&["Network", "scheme", "wall step", "oracle step", "fw wire/step"]);
    for (bw, label) in PAPER_BANDWIDTHS {
        for spec in ["fp32", "aqsgd:fw4bw8", "aqsgd:fw2bw4"] {
            let mut cfg = ExecConfig::small(CodecSpec::parse(spec)?);
            cfg.n_stages = 4;
            cfg.n_micro = 8;
            cfg.micro_batch = 1;
            cfg.example_len = 16 * 1024;
            cfg.steps = 3;
            cfg.bandwidth_bps = bw;
            cfg.fwd_s = 0.002;
            cfg.bwd_s = 0.006;
            cfg.workers = workers;
            let real = exec::run(&cfg, executor)?;
            let oracle = exec::run(&cfg, Executor::Sim)?;
            // steady state (skip step 0: AQ's first epoch is full precision)
            let mean = |v: &[f64]| v[1..].iter().sum::<f64>() / (v.len() - 1) as f64;
            let fw_steady: u64 = real.steps.last().unwrap().fw_wire_bytes.iter().sum();
            t.row(vec![
                label.to_string(),
                CodecSpec::parse(spec)?.label(),
                fmt::duration_s(mean(&real.step_time_s)),
                fmt::duration_s(mean(&oracle.step_time_s)),
                fmt::bytes(fw_steady),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\n(the shape to check: FP32's wall step grows ~100x from 10 Gbps to");
    println!(" 100 Mbps while the AQ rows stay near the compute floor.)");
    Ok(())
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let executor = Executor::parse(&cli.str("executor", "sim"))?;
    if executor != Executor::Sim {
        return run_real_sweep(executor, cli.usize("workers", 4)?);
    }
    // GPT2-1.5B LM regime (Table 2) by default; --deberta switches to the
    // classification regime (Table 5 left: seq 256, micro-batch 8, lighter
    // compute per microbatch).
    let (name, regime, schemes) = if cli.bool("deberta") {
        (
            "DeBERTa-1.5B, QNLI-like (Table 5)",
            PaperRegime {
                n_micro: 8,
                micro_batch: 8,
                fwd_s: 0.030,
                bwd_s: 0.090,
                fp32_msg_bytes: 8 * 256 * 1536 * 4,
                ..Default::default()
            },
            [(2u8, 4u8), (3, 6)],
        )
    } else {
        ("GPT2-1.5B, WikiText2-like (Table 2)", PaperRegime::default(), [(3u8, 6u8), (4, 8)])
    };

    println!("{name}: throughput in sequences/s\n");
    let mut t = Table::new(&[
        "Network",
        "FP32",
        &format!(
            "DirectQ fw{} bw{} / fw{} bw{}",
            schemes[0].0, schemes[0].1, schemes[1].0, schemes[1].1
        ),
        "AQ-SGD (same bits)",
        "AQ-SGD speedup",
    ]);
    for (bw, label) in PAPER_BANDWIDTHS {
        let fp32 = throughput(&regime, &CodecSpec::fp32(), bw);
        let mut dq = Vec::new();
        let mut aq = Vec::new();
        for (f, b) in schemes {
            dq.push(throughput(&regime, &CodecSpec::directq(f, b), bw));
            aq.push(throughput(&regime, &CodecSpec::aqsgd(f, b), bw));
        }
        t.row(vec![
            label.to_string(),
            format!("{fp32:.1}"),
            format!("{:.1} / {:.1}", dq[0], dq[1]),
            format!("{:.1} / {:.1}", aq[0], aq[1]),
            format!("{:.1}x", aq[0] / fp32),
        ]);
    }
    print!("{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table2_throughput.csv", t.to_csv())?;
    println!("\ncsv -> results/table2_throughput.csv");
    println!("(paper Table 2: FP32 drops 3.8 -> 0.5 while AQ-SGD holds 4.0 -> 3.0-3.4;");
    println!(" the shape to check is the FP32 collapse and AQ-SGD's flatness.)");
    Ok(())
}
