//! Figure 9 reproduction: the robustness ablations.
//!   (a,b) number of pipeline stages K        (tiny K=2 vs small K=4)
//!   (c,d) bits in communication              (fw2bw4 / fw3bw6 / fw4bw8)
//!   (e,f) bits for the stored previous messages m ("mz": 2/4/8/f32)
//!   (g,h) model size                         (tiny vs small)
//!
//!     cargo run --release --example fig9_ablations [-- --epochs N]
//!
//! Note: panels (a,b) in the paper vary K at fixed model; our K is baked
//! per artifact config (K=2 tiny, K=4 small/e2e), so the K ablation rides
//! the model-size axis — each table says which is which.

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp;
use aq_sgd::metrics::Table;

fn base(model: &str, epochs: usize) -> TrainConfig {
    let mut cfg = TrainConfig::defaults(model);
    cfg.epochs = epochs;
    cfg.n_micro = 2;
    cfg.n_examples = 64;
    cfg.lr = 2e-3;
    cfg.warmup_steps = 8;
    cfg
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 6)?;
    let with_small = cli.bool("with-small"); // small (K=4) runs are ~20x slower
    let mut all = Vec::new();

    // ---- (c,d) bits in communication ----
    let mut t_bits = Table::new(&["bits", "DirectQ loss", "AQ-SGD loss"]);
    for (fw, bw) in [(2u8, 4u8), (3, 6), (4, 8)] {
        let mut row = vec![format!("fw{fw} bw{bw}")];
        for mk in [CodecSpec::directq(fw, bw), CodecSpec::aqsgd(fw, bw)] {
            let mut cfg = base("tiny", epochs);
            let label = format!("bits {} {}", mk.label(), fw);
            cfg.compression = mk;
            println!("== {label} ==");
            let run = exp::run_variant(cfg, &label)?;
            row.push(format!("{:.4}", run.stats.final_train_loss));
            all.push(run);
        }
        t_bits.row(row);
    }
    println!("\nFigure 9(c,d) — bits in communication (K=2 tiny):");
    print!("{}", t_bits.render());

    // ---- (e,f) bits for previous messages ----
    let mut t_m = Table::new(&["m precision", "AQ-SGD fw2 bw4 loss"]);
    for m_bits in [Some(2u8), Some(4), Some(8), None] {
        let mut cfg = base("tiny", epochs);
        cfg.compression = CodecSpec::aqsgd(2, 4);
        cfg.m_bits = m_bits;
        let label = match m_bits {
            Some(b) => format!("m{b}"),
            None => "m f32".to_string(),
        };
        println!("== {label} ==");
        let run = exp::run_variant(cfg, &label)?;
        t_m.row(vec![label, format!("{:.4}", run.stats.final_train_loss)]);
        all.push(run);
    }
    println!("\nFigure 9(e,f) — message-buffer precision (paper: m8 ~ f32, m2 degrades slightly):");
    print!("{}", t_m.render());

    // ---- adaptive compression family (tile / had / lr) at fw2 bw4 ----
    // same bit budget as the DirectQ column above, so the table isolates
    // what tiling, rotation, and low-rank deltas buy at fixed wire cost
    let mut t_adapt = Table::new(&["scheme (fw2 bw4)", "final loss", "comm MB"]);
    for spec in [
        "directq:fw2bw4",
        "tile:16:directq:fw2bw4",
        "tile:64:directq:fw2bw4",
        "had:directq:fw2bw4",
        "had:tile:64:directq:fw2bw4",
        "lr:4:directq:fw2bw4",
        "lr:8:directq:fw2bw4",
    ] {
        let mut cfg = base("tiny", epochs);
        cfg.compression = CodecSpec::parse(spec)?;
        println!("== adapt {spec} ==");
        let run = exp::run_variant(cfg, spec)?;
        t_adapt.row(vec![
            spec.to_string(),
            format!("{:.4}", run.stats.final_train_loss),
            format!("{:.2}", run.stats.comm_bytes as f64 / 1e6),
        ]);
        all.push(run);
    }
    println!("\nFigure 9 (ext) — adaptive family at a fixed fw2/bw4 budget:");
    print!("{}", t_adapt.render());

    // ---- (a,b)+(g,h) stages / model size ----
    if with_small {
        let mut t_k = Table::new(&["model (K)", "FP32", "AQ-SGD fw2 bw4", "DirectQ fw2 bw4"]);
        for model in ["tiny", "small"] {
            let mut row = vec![format!(
                "{model} (K={})",
                if model == "tiny" { 2 } else { 4 }
            )];
            for mk in [CodecSpec::fp32(), CodecSpec::aqsgd(2, 4), CodecSpec::directq(2, 4)] {
                let mut cfg = base(model, epochs.min(4));
                let label = format!("K {model} {}", mk.label());
                cfg.compression = mk;
                cfg.lr = if model == "small" { 1e-3 } else { 2e-3 };
                println!("== {label} ==");
                let run = exp::run_variant(cfg, &label)?;
                row.push(format!("{:.4}", run.stats.final_train_loss));
                all.push(run);
            }
            t_k.row(row);
        }
        println!("\nFigure 9(a,b,g,h) — stages & model size (more stages => more");
        println!("compression rounds => DirectQ degrades more; AQ-SGD holds):");
        print!("{}", t_k.render());
    } else {
        println!("\n(skipping K=4/model-size panels; pass --with-small to include)");
    }

    exp::save_traces("results/fig9_ablations.csv", &all)?;
    Ok(())
}
