//! Quickstart: fine-tune the tiny LM over a simulated 100 Mbps network
//! with AQ-SGD 2-bit forward / 4-bit backward compression, and compare
//! the bytes/time against uncompressed FP32.
//!
//!     (cd python && python -m compile.aot --out-dir ../artifacts) && cargo run --release --example quickstart

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::TrainConfig;
use aq_sgd::exp;
use aq_sgd::metrics::Table;
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let mut table = Table::new(&["method", "final loss", "wire traffic", "sim time @100Mbps"]);
    for (label, compression) in [
        ("FP32", CodecSpec::fp32()),
        ("AQ-SGD fw2 bw4", CodecSpec::aqsgd(2, 4)),
    ] {
        let mut cfg = TrainConfig::defaults("tiny");
        cfg.compression = compression;
        cfg.epochs = 6;
        cfg.n_micro = 2;
        cfg.n_examples = 48;
        cfg.lr = 2e-3;
        cfg.warmup_steps = 5;
        cfg.bandwidth_bps = 100e6;
        println!("== training {label} ==");
        let run = exp::run_variant(cfg, label)?;
        table.row(vec![
            label.to_string(),
            format!("{:.4}", run.stats.final_train_loss),
            fmt::bytes(run.stats.comm_bytes),
            fmt::duration_s(run.stats.sim_time_s),
        ]);
    }
    println!();
    print!("{}", table.render());
    println!("\nSame convergence, ~10x less traffic — the paper's headline effect.");
    Ok(())
}
