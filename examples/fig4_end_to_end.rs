//! Figure 4 reproduction: end-to-end training performance (loss vs
//! wall-clock) under different network bandwidths — the paper's headline
//! "4.3x speed-up to the same loss at 100 Mbps".
//!
//! Composition (DESIGN.md §3): the *convergence traces* are real (each
//! method trained through the PJRT artifacts — the compression numerics
//! are exact), and the *time axis* is the paper-regime step time
//! (GPT2-1.5B on 8 stages: 45/135 ms per microbatch, 6.4 MB FP32
//! boundary messages) from the event-driven simulator, per method and
//! bandwidth. AQ-SGD's first epoch is charged full-precision messages
//! (Algorithm 1 line 5).
//!
//!     cargo run --release --example fig4_end_to_end [-- --epochs N]

use aq_sgd::util::error::Result;

use aq_sgd::codec::{CodecSpec, SchemeSpec};
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp::{self, PaperRegime};
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{PipelineSim, SimConfig};
use aq_sgd::util::fmt;

/// Paper-regime step time for a method at a bandwidth.
fn step_time(regime: &PaperRegime, c: &CodecSpec, bw: f64, first_epoch: bool) -> f64 {
    let (fw, bwb) = regime.msg_bytes(c, first_epoch);
    let cfg = SimConfig::uniform(
        regime.n_stages,
        regime.n_micro,
        regime.fwd_s,
        regime.bwd_s,
        fw,
        bwb,
        bw,
    );
    PipelineSim::run(&cfg).step_time_s
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 8)?;
    let regime = PaperRegime::default();
    let bandwidths: [(f64, &str); 3] = [(10e9, "10 Gbps"), (1e9, "1 Gbps"), (100e6, "100 Mbps")];

    // one real training run per method (convergence is bandwidth-independent)
    let mut runs = Vec::new();
    for (label, c) in exp::method_grid(3, 6) {
        let mut cfg = TrainConfig::defaults("tiny");
        cfg.compression = c.clone();
        cfg.epochs = epochs;
        cfg.n_micro = 3;
        cfg.n_examples = 96;
        cfg.lr = 2e-3;
        cfg.warmup_steps = 10;
        println!("== {label} ==");
        runs.push((c, exp::run_variant(cfg, &label)?));
    }

    let target = 5.2;
    let mut t = Table::new(&["network", "method", "final loss", "time to loss 5.2"]);
    let mut headline: (f64, f64) = (0.0, 0.0); // (fp32, aq) at 100 Mbps
    for (bw, bw_label) in bandwidths {
        for (c, run) in &runs {
            // map the step axis to paper-regime time
            let t_first = step_time(&regime, c, bw, true);
            let t_rest = step_time(&regime, c, bw, false);
            let steps_per_epoch = run.recorder.rows.len() / epochs.max(1);
            let mut ttl = None;
            let mut clock = 0.0;
            for (i, row) in run.recorder.rows.iter().enumerate() {
                clock += if i < steps_per_epoch { t_first } else { t_rest };
                if ttl.is_none() && row.loss_ema <= target {
                    ttl = Some(clock);
                }
            }
            if bw_label == "100 Mbps" {
                if *c == CodecSpec::fp32() {
                    headline.0 = ttl.unwrap_or(f64::NAN);
                }
                if matches!(c.fw, SchemeSpec::Aq { .. }) {
                    headline.1 = ttl.unwrap_or(f64::NAN);
                }
            }
            t.row(vec![
                bw_label.to_string(),
                run.label.clone(),
                format!("{:.4}", run.stats.final_train_loss),
                ttl.map(fmt::duration_s).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    println!("\nFigure 4 — time to target loss (paper regime timing):");
    print!("{}", t.render());
    println!(
        "\nheadline: AQ-SGD reaches loss {target} {:.1}x faster than FP32 at 100 Mbps",
        headline.0 / headline.1
    );
    println!("(paper Fig. 4: up to 4.3x at 100 Mbps)");
    let plain: Vec<exp::RunResult> = runs.into_iter().map(|(_, r)| r).collect();
    exp::save_traces("results/fig4_end_to_end.csv", &plain)?;
    Ok(())
}
