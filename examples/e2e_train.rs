//! End-to-end system validation (EXPERIMENTS.md §E2E): train the `small`
//! transformer (~1.6M params, 4 pipeline stages) for a few hundred steps
//! on the Markov corpus with AQ-SGD fw3/bw6 over a simulated 500 Mbps
//! network; log the loss curve, throughput and communication volume.
//!
//!     (cd python && python -m compile.aot --out-dir ../artifacts) && cargo run --release --example e2e_train
//!
//! Flags: --model small|e2e  --steps N  --compression SPEC  --bandwidth B

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{parse_bandwidth, Cli, TrainConfig};
use aq_sgd::coordinator::Trainer;
use aq_sgd::exp;
use aq_sgd::runtime::Manifest;
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let model = cli.str("model", "small");
    let mut cfg = TrainConfig::defaults(&model);
    cfg.compression = CodecSpec::parse(&cli.str("compression", "aqsgd:fw3bw6"))?;
    cfg.total_steps = cli.usize("steps", 300)?;
    cfg.epochs = usize::MAX / 2; // bounded by total_steps
    cfg.n_micro = cli.usize("n-micro", 4)?;
    cfg.n_examples = cli.usize("examples", 256)?;
    cfg.lr = cli.f64("lr", 1e-3)?;
    cfg.warmup_steps = cli.usize("warmup", 30)?;
    cfg.bandwidth_bps = parse_bandwidth(&cli.str("bandwidth", "500mbps"))?;
    cfg.dataset = cli.str("dataset", "markov");

    let man = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
    println!(
        "e2e: model={} params={} stages={} boundary={:?} compression={}",
        man.name(),
        man.total_params()?,
        man.n_stages()?,
        man.boundary()?,
        cfg.compression.label()
    );
    let data = exp::make_dataset(&cfg, &man)?;
    let (train, eval) = data.split_eval(0.1);
    let mut trainer = Trainer::new(cfg)?;
    trainer.set_eval_every(25);

    let t0 = std::time::Instant::now();
    let stats = trainer.train(&train, Some(&eval))?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== loss curve (every 10 steps) ==");
    for row in trainer.recorder.rows.iter().step_by(10) {
        println!(
            "step {:>4}  epoch {:>3}  loss {:.4}  ema {:.4}  comm {:>10}  sim_t {:>8}",
            row.step,
            row.epoch,
            row.loss,
            row.loss_ema,
            fmt::bytes(row.comm_bytes),
            fmt::duration_s(row.sim_time_s)
        );
    }
    let seqs = stats.steps * trainer.cfg.n_micro * trainer.man.micro_batch()?;
    println!("\n== summary ==");
    println!("steps            {}", stats.steps);
    println!("final train loss {:.4}", stats.final_train_loss);
    println!("final eval loss  {:.4}", stats.final_eval_loss);
    println!("wire traffic     {}", fmt::bytes(stats.comm_bytes));
    println!("buffer storage   {}", fmt::bytes(stats.buffer_bytes));
    println!("sim time         {} ({:.2} seq/s on the simulated net)",
        fmt::duration_s(stats.sim_time_s), seqs as f64 / stats.sim_time_s);
    println!("wall time        {} ({:.2} seq/s on this host)",
        fmt::duration_s(wall), seqs as f64 / wall);

    trainer.recorder.save_csv("results/e2e_train.csv")?;
    println!("trace -> results/e2e_train.csv");
    Ok(())
}
