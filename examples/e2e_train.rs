//! End-to-end system validation (EXPERIMENTS.md §E2E): train the `small`
//! transformer (~1.6M params, 4 pipeline stages) for a few hundred steps
//! on the Markov corpus with AQ-SGD fw3/bw6 over a simulated 500 Mbps
//! network; log the loss curve, throughput and communication volume.
//!
//!     (cd python && python -m compile.aot --out-dir ../artifacts) && cargo run --release --example e2e_train
//!
//! Flags: --model small|e2e  --steps N  --compression SPEC  --bandwidth B
//!        --executor threads|events|sim  --workers N
//!
//! With `--executor threads` (one worker thread per stage) or
//! `--executor events` (fixed worker pool over a run queue, `--workers`)
//! the run goes through the *real* pipeline runtime (`pipeline::exec`):
//! serialized frames over channel links, first-party stage compute — no
//! AOT artifacts needed — and the loss/wire trajectory is cross-checked
//! bit-for-bit against the virtual-clock oracle.

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{parse_bandwidth, Cli, TrainConfig};
use aq_sgd::coordinator::Trainer;
use aq_sgd::exp;
use aq_sgd::pipeline::Executor;
use aq_sgd::runtime::Manifest;
use aq_sgd::util::fmt;

/// The artifact-free path: real executor (threads or events) vs
/// virtual-clock oracle.
fn run_executor(cli: &Cli, cfg: &TrainConfig) -> Result<()> {
    let stages = cli.usize("stages", 4)?;
    let el = cli.usize("el", 64)?;
    let micro_b = cli.usize("micro-batch", 2)?;
    let steps = cfg.total_steps; // --steps (default 300) — honoured as given
    println!(
        "e2e ({}): stages={stages} n_micro={} el={el} compression={} bandwidth={}",
        cfg.executor.label(),
        cfg.n_micro,
        cfg.compression.label(),
        fmt::bandwidth(cfg.bandwidth_bps)
    );
    let t0 = std::time::Instant::now();
    let (real, oracle) = exp::run_executor_with_oracle(cfg, stages, micro_b, el, steps)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== loss curve (every 5 steps) ==");
    for (i, rec) in real.steps.iter().enumerate().step_by(5) {
        println!(
            "step {:>4}  loss {:.5}  fw {:>10}  bw {:>10}  wall {:>9}  oracle {:>9}",
            i,
            rec.loss,
            fmt::bytes(rec.fw_wire_bytes.iter().sum::<u64>()),
            fmt::bytes(rec.bw_wire_bytes.iter().sum::<u64>()),
            fmt::duration_s(real.step_time_s[i]),
            fmt::duration_s(oracle.step_time_s[i]),
        );
    }
    let identical = real.bit_identical(&oracle);
    println!("\n== summary ==");
    println!("steps            {}", real.steps.len());
    println!("final train loss {:.5}", real.steps.last().map(|r| r.loss).unwrap_or(f32::NAN));
    println!(
        "wall time        {} ({} + oracle)",
        fmt::duration_s(wall),
        cfg.executor.label()
    );
    println!(
        "determinism      trajectory vs virtual-clock oracle: {}",
        if identical { "bit-identical" } else { "DIVERGED (bug!)" }
    );
    exp::check_matches_oracle(&real, &oracle)
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let model = cli.str("model", "small");
    let mut cfg = TrainConfig::defaults(&model);
    cfg.compression = CodecSpec::parse(&cli.str("compression", "aqsgd:fw3bw6"))?;
    cfg.total_steps = cli.usize("steps", 300)?;
    cfg.epochs = usize::MAX / 2; // bounded by total_steps
    cfg.n_micro = cli.usize("n-micro", 4)?;
    cfg.n_examples = cli.usize("examples", 256)?;
    cfg.lr = cli.f64("lr", 1e-3)?;
    cfg.warmup_steps = cli.usize("warmup", 30)?;
    cfg.bandwidth_bps = parse_bandwidth(&cli.str("bandwidth", "500mbps"))?;
    cfg.dataset = cli.str("dataset", "markov");
    cfg.executor = Executor::parse(&cli.str("executor", "sim"))?;
    cfg.workers = cli.usize("workers", cfg.workers)?;
    cfg.schedule = aq_sgd::pipeline::Schedule::parse(&cli.str("schedule", "gpipe"))?;

    if cfg.executor != Executor::Sim {
        return run_executor(&cli, &cfg);
    }

    let man = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
    println!(
        "e2e: model={} params={} stages={} boundary={:?} compression={}",
        man.name(),
        man.total_params()?,
        man.n_stages()?,
        man.boundary()?,
        cfg.compression.label()
    );
    let data = exp::make_dataset(&cfg, &man)?;
    let (train, eval) = data.split_eval(0.1);
    let mut trainer = Trainer::new(cfg)?;
    trainer.set_eval_every(25);

    let t0 = std::time::Instant::now();
    let stats = trainer.train(&train, Some(&eval))?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== loss curve (every 10 steps) ==");
    for row in trainer.recorder.rows.iter().step_by(10) {
        println!(
            "step {:>4}  epoch {:>3}  loss {:.4}  ema {:.4}  comm {:>10}  sim_t {:>8}",
            row.step,
            row.epoch,
            row.loss,
            row.loss_ema,
            fmt::bytes(row.comm_bytes),
            fmt::duration_s(row.sim_time_s)
        );
    }
    let seqs = stats.steps * trainer.cfg.n_micro * trainer.man.micro_batch()?;
    println!("\n== summary ==");
    println!("steps            {}", stats.steps);
    println!("final train loss {:.4}", stats.final_train_loss);
    println!("final eval loss  {:.4}", stats.final_eval_loss);
    println!("wire traffic     {}", fmt::bytes(stats.comm_bytes));
    println!("buffer storage   {}", fmt::bytes(stats.buffer_bytes));
    println!("sim time         {} ({:.2} seq/s on the simulated net)",
        fmt::duration_s(stats.sim_time_s), seqs as f64 / stats.sim_time_s);
    println!("wall time        {} ({:.2} seq/s on this host)",
        fmt::duration_s(wall), seqs as f64 / wall);

    trainer.recorder.save_csv("results/e2e_train.csv")?;
    println!("trace -> results/e2e_train.csv");
    Ok(())
}
