//! Figure 5 reproduction: AQ-SGD combined with error-compensated gradient
//! compression ("QuantizedAdam") for end-to-end communication compression
//! — pipeline activations fw3/bw6 + data-parallel model gradients at 4
//! bits.
//!
//!  (a,b) convergence of FP32 / DirectQ+GC / AQ-SGD+GC
//!  (c)   throughput with activation-only / gradient-only / both
//!        compression, in the paper's 4x8 (DP x pipeline) regime.
//!
//!     cargo run --release --example fig5_e2e_compression

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp::{self, PaperRegime};
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{PipelineSim, SimConfig};

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 8)?;

    // ---- (a,b) convergence with DP=2 + 4-bit gradient compression ----
    let mut runs = Vec::new();
    let mut t = Table::new(&["method", "final loss", "diverged"]);
    for (label, c, dp_bits) in [
        ("FP32 (no compression)".to_string(), CodecSpec::fp32(), None),
        ("DirectQ fw3 bw6 + grad4".to_string(), CodecSpec::directq(3, 6), Some(4u8)),
        ("AQ-SGD fw3 bw6 + grad4".to_string(), CodecSpec::aqsgd(3, 6), Some(4u8)),
    ] {
        let mut cfg = TrainConfig::defaults("tiny");
        cfg.compression = c;
        cfg.dp_degree = 2;
        cfg.dp_grad_bits = dp_bits;
        cfg.epochs = epochs;
        cfg.n_micro = 2;
        cfg.n_examples = 96;
        cfg.lr = 2e-3;
        cfg.warmup_steps = 10;
        println!("== {label} ==");
        let run = exp::run_variant(cfg, &label)?;
        t.row(vec![
            label.clone(),
            format!("{:.4}", run.stats.final_train_loss),
            if run.diverged { "x".into() } else { "".into() },
        ]);
        runs.push(run);
    }
    println!("\nFigure 5(a,b) — convergence with end-to-end compression:");
    print!("{}", t.render());
    exp::save_traces("results/fig5_convergence.csv", &runs)?;

    // ---- (c) throughput ablation in the paper regime (DP 4 x PP 8) ----
    let regime = PaperRegime::default();
    let dp_degree = 4;
    let grad_frac_4bit = 4.0 / 32.0;
    let mut tc = Table::new(&["configuration", "step time (s)", "throughput vs FP32"]);
    let mut base_tp = 0.0;
    for (label, act, grad4) in [
        ("no compression", CodecSpec::fp32(), false),
        ("activation compression only", CodecSpec::aqsgd(3, 6), false),
        ("gradient compression only", CodecSpec::fp32(), true),
        ("activation + gradient (end-to-end)", CodecSpec::aqsgd(3, 6), true),
    ] {
        let (fw, bw) = regime.msg_bytes(&act, false);
        let cfg = SimConfig::uniform(
            regime.n_stages,
            regime.n_micro,
            regime.fwd_s,
            regime.bwd_s,
            fw,
            bw,
            100e6,
        );
        let pipe_t = PipelineSim::run(&cfg).step_time_s;
        // per-machine gradient shard: params / n_stages
        let grad_bytes = regime.param_bytes / regime.n_stages as u64;
        let grad_bytes =
            if grad4 { (grad_bytes as f64 * grad_frac_4bit) as u64 } else { grad_bytes };
        let ar_t = PipelineSim::allreduce_time(grad_bytes, dp_degree, 100e6, 1e-3);
        let step = pipe_t + ar_t;
        let tp = (regime.n_micro * regime.micro_batch * dp_degree) as f64 / step;
        if base_tp == 0.0 {
            base_tp = tp;
        }
        tc.row(vec![label.to_string(), format!("{step:.2}"), format!("{:.1}x", tp / base_tp)]);
    }
    println!("\nFigure 5(c) — throughput at 100 Mbps, DP 4 x PP 8:");
    print!("{}", tc.render());
    println!("(paper: end-to-end compression reaches ~8.5x the no-compression throughput;");
    println!(" disabling either compression loses most of the gain.)");
    std::fs::write("results/fig5_throughput.csv", tc.to_csv())?;
    Ok(())
}
