//! Figure 5 reproduction: AQ-SGD activation compression combined with
//! error-compensated gradient compression ("QuantizedAdam") for
//! end-to-end communication compression — every traffic class (forward
//! activations, backward gradients, DP model gradients) on registry
//! codecs, every reported byte the serialized size of a real `Frame`.
//!
//!  (a,b) convergence of FP32 / DirectQ+EF / AQ-SGD+EF with DP=2
//!  (c)   throughput with activation-only / gradient-only / both
//!        compression, in the paper's 4x8 (DP x pipeline) regime —
//!        DP volume measured by encoding real ring chunk frames
//!  (d)   the same end-to-end cell through the threaded executor,
//!        cross-checked bit-for-bit against the virtual-clock oracle
//!
//!     cargo run --release --example fig5_e2e_compression

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp::{self, PaperRegime, DP_RING_CHUNK_ELEMS};
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{Executor, PipelineSim, SimConfig};
use aq_sgd::util::fmt;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 8)?;

    // Fig. 5 regimes: activation codec + error-compensated DP codec
    let act_spec = CodecSpec::aqsgd(2, 4);
    let dp_spec = CodecSpec::parse("ef:directq:fw4bw4")?;

    // ---- (a,b) convergence with DP=2 + EF 4-bit gradient frames ----
    let mut runs = Vec::new();
    let mut t = Table::new(&["method", "final loss", "diverged"]);
    for (label, c, dp) in [
        ("FP32 (no compression)".to_string(), CodecSpec::fp32(), CodecSpec::fp32()),
        ("DirectQ fw2 bw4 + ef:grad4".to_string(), CodecSpec::directq(2, 4), dp_spec.clone()),
        ("AQ-SGD fw2 bw4 + ef:grad4".to_string(), act_spec.clone(), dp_spec.clone()),
    ] {
        let mut cfg = TrainConfig::defaults("tiny");
        cfg.compression = c;
        cfg.dp_degree = 2;
        cfg.dp_codec = dp;
        cfg.epochs = epochs;
        cfg.n_micro = 2;
        cfg.n_examples = 96;
        cfg.lr = 2e-3;
        cfg.warmup_steps = 10;
        println!("== {label} ==");
        let run = exp::run_variant(cfg, &label)?;
        t.row(vec![
            label.clone(),
            format!("{:.4}", run.stats.final_train_loss),
            if run.diverged { "x".into() } else { "".into() },
        ]);
        runs.push(run);
    }
    println!("\nFigure 5(a,b) — convergence with end-to-end compression:");
    print!("{}", t.render());
    exp::save_traces("results/fig5_convergence.csv", &runs)?;

    // ---- (c) throughput ablation in the paper regime (DP 4 x PP 8) ----
    // DP gradient volume is *measured*: the shard ships as ring chunk
    // frames through the registry codec, and we sum their serialized
    // sizes (exp::measured_dp_frame_bytes) — no bits/32 arithmetic.
    let regime = PaperRegime::default();
    let dp_degree = 4;
    let shard = regime.dp_shard_elems();
    let dp_fp32 = exp::measured_dp_frame_bytes(&CodecSpec::fp32(), shard, DP_RING_CHUNK_ELEMS)?;
    let dp_ef4 = exp::measured_dp_frame_bytes(&dp_spec, shard, DP_RING_CHUNK_ELEMS)?;
    println!(
        "\nDP shard: {} elements -> {} fp32 / {} ef:grad4 on the wire (measured frames)",
        shard,
        fmt::bytes(dp_fp32),
        fmt::bytes(dp_ef4)
    );
    let mut tc = Table::new(&["configuration", "step time (s)", "throughput vs FP32"]);
    let mut base_tp = 0.0;
    for (label, act, dp_bytes) in [
        ("no compression", CodecSpec::fp32(), dp_fp32),
        ("activation compression only", act_spec.clone(), dp_fp32),
        ("gradient compression only", CodecSpec::fp32(), dp_ef4),
        ("activation + gradient (end-to-end)", act_spec.clone(), dp_ef4),
    ] {
        let (fw, bw) = regime.msg_bytes(&act, false);
        let cfg = SimConfig::uniform(
            regime.n_stages,
            regime.n_micro,
            regime.fwd_s,
            regime.bwd_s,
            fw,
            bw,
            100e6,
        );
        let pipe_t = PipelineSim::run(&cfg).step_time_s;
        // same time model the trainer charges for the implemented ring
        // (chunk-pipelined all-gather: d-1 shard volumes per edge)
        let ar_t = PipelineSim::ring_allgather_time(dp_bytes, dp_degree, 100e6, 1e-3);
        let step = pipe_t + ar_t;
        let tp = (regime.n_micro * regime.micro_batch * dp_degree) as f64 / step;
        if base_tp == 0.0 {
            base_tp = tp;
        }
        tc.row(vec![label.to_string(), format!("{step:.2}"), format!("{:.1}x", tp / base_tp)]);
    }
    println!("\nFigure 5(c) — throughput at 100 Mbps, DP 4 x PP 8:");
    print!("{}", tc.render());
    println!("(paper: end-to-end compression reaches ~8.5x the no-compression throughput;");
    println!(" disabling either compression loses most of the gain.)");
    std::fs::write("results/fig5_throughput.csv", tc.to_csv())?;

    // ---- (d) the end-to-end cell through the real threaded runtime ----
    // aqsgd:fw2bw4 activations + ef:directq:fw4bw4 DP ring frames over
    // real channel links, pinned bit-for-bit to the virtual-clock oracle.
    let mut ecfg = TrainConfig::defaults("tiny");
    ecfg.compression = act_spec;
    ecfg.dp_degree = 2;
    ecfg.dp_codec = dp_spec;
    ecfg.executor = Executor::Threads;
    ecfg.n_micro = 4;
    let (real, oracle) = exp::run_executor_with_oracle(&ecfg, 3, 2, 48, 6)?;
    let last = real.steps.last().expect("steps ran");
    println!("\nFigure 5(d) — end-to-end cell on the threaded executor (3 stages, DP 2):");
    println!(
        "  final loss {:.5}, fw {} bw {} dp {} per step (all measured frames)",
        last.loss,
        fmt::bytes(last.fw_wire_bytes.iter().sum::<u64>()),
        fmt::bytes(last.bw_wire_bytes.iter().sum::<u64>()),
        fmt::bytes(last.dp_wire_bytes.iter().sum::<u64>()),
    );
    println!(
        "  replica digests equal: {}; trajectory vs oracle: {}",
        last.replica_digests.windows(2).all(|w| w[0] == w[1]),
        if real.bit_identical(&oracle) { "bit-identical" } else { "DIVERGED (bug!)" }
    );
    exp::check_matches_oracle(&real, &oracle)?;
    Ok(())
}
