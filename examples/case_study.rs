//! Appendix I reproduction: generation case study. Fine-tune the tiny
//! byte-level LM on the embedded real-text corpus under FP32 / DirectQ /
//! AQ-SGD, then greedy-decode continuations of the same prompts and
//! print them side by side (paper Tables 6/7: AQ-SGD's continuations
//! match FP32's; DirectQ drifts).
//!
//!     cargo run --release --example case_study [-- --epochs N]

use aq_sgd::util::error::Result;

use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::coordinator::generate::{detokenize_bytes, GenerateCfg};
use aq_sgd::coordinator::Trainer;
use aq_sgd::data::lm;
use aq_sgd::exp;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 10)?;
    let prompts = ["It is a truth universally ", "My dear Mr. Bennet, ", "A single man of large "];

    let mut generations: Vec<(String, Vec<String>)> = Vec::new();
    for (label, c) in exp::method_grid(4, 8) {
        let mut cfg = TrainConfig::defaults("tiny");
        cfg.compression = c;
        cfg.dataset = "embedded".to_string();
        cfg.epochs = epochs;
        cfg.n_micro = 3;
        cfg.n_examples = 96;
        cfg.lr = 2e-3;
        cfg.warmup_steps = 10;
        println!("== fine-tuning {label} on the embedded corpus ==");
        let man = aq_sgd::runtime::Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
        let data = exp::make_dataset(&cfg, &man)?;
        let (train, _) = data.split_eval(0.1);
        let mut trainer = Trainer::new(cfg)?;
        let stats = trainer.train(&train, None)?;
        println!("   final loss {:.4}", stats.final_train_loss);

        let mut outs = Vec::new();
        for p in &prompts {
            let toks: Vec<i32> = p.bytes().map(|b| b as i32).collect();
            let gcfg = GenerateCfg { max_new_tokens: 24, ..Default::default() };
            let gen = trainer.generate(&toks, &gcfg)?;
            outs.push(detokenize_bytes(&gen));
        }
        generations.push((label, outs));
    }

    println!("\n== Appendix-I-style case study (greedy continuations) ==");
    for (pi, p) in prompts.iter().enumerate() {
        println!("\nPrompt: {p:?}");
        for (label, outs) in &generations {
            println!("  {:<18} -> {:?}", label, outs[pi]);
        }
    }
    // the paper's observation: AQ-SGD's continuation matches FP32's
    // character-for-character far more often than DirectQ's does
    let fp32 = &generations[0].1;
    let agree = |other: &Vec<String>| {
        other
            .iter()
            .zip(fp32)
            .map(|(a, b)| a.chars().zip(b.chars()).take_while(|(x, y)| x == y).count())
            .sum::<usize>()
    };
    println!(
        "\nprefix agreement with FP32: DirectQ {} chars, AQ-SGD {} chars",
        agree(&generations[1].1),
        agree(&generations[2].1)
    );
    Ok(())
}
