//! Figure 3 (and 6/7/8) reproduction: convergence (loss vs steps) of
//! FP32 / DirectQ / AQ-SGD on the four benchmark stand-ins:
//!   QNLI-like, CoLA-like (classification, fw2 bw4)
//!   WikiText2-like (markov), arXiv-like (markov, different seed)
//!     (language modeling, fw3 bw6)
//!
//! Flags:
//!   --seeds N        repeat with N seeds, report mean±std (Figure 6)
//!   --half           FP16 wire baseline alongside (Figure 8)
//!   --from-scratch   rescale-init + longer run (Figure 7 flavour)
//!   --epochs N
//!
//!     cargo run --release --example fig3_convergence

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp;
use aq_sgd::metrics::Table;
use aq_sgd::util::stats;

fn main() -> Result<()> {
    let cli = Cli::from_env();
    let epochs = cli.usize("epochs", 8)?;
    let seeds = cli.usize("seeds", 1)?;
    let half = cli.bool("half");
    let from_scratch = cli.bool("from-scratch");

    // (panel, model, dataset, fw, bw)
    let panels: [(&str, &str, &str, u8, u8); 4] = [
        ("QNLI-like", "tiny_cls", "qnli", 2, 4),
        ("CoLA-like", "tiny_cls", "cola", 2, 4),
        ("WikiText2-like", "tiny", "markov", 3, 6),
        ("arXiv-like", "tiny", "arxiv", 3, 6),
    ];

    let mut all_runs = Vec::new();
    let mut table = Table::new(&["panel", "method", "final loss", "±std", "diverged"]);
    for (panel, model, dataset, fw, bw) in panels {
        let mut methods = exp::method_grid(fw, bw);
        if half {
            methods.insert(1, ("FP16".into(), CodecSpec::fp16()));
        }
        for (label, c) in methods {
            let mut finals = Vec::new();
            let mut diverged = false;
            for seed in 0..seeds {
                let mut cfg = TrainConfig::defaults(model);
                cfg.dataset = dataset.to_string();
                cfg.compression = c.clone();
                cfg.epochs = if from_scratch { epochs * 2 } else { epochs };
                cfg.n_micro = 3;
                cfg.n_examples = 96;
                cfg.lr = if model == "tiny_cls" { 1e-3 } else { 2e-3 };
                cfg.warmup_steps = if from_scratch { 20 } else { 10 };
                cfg.seed = seed as u64;
                let full = format!("{panel} {label} s{seed}");
                println!("== {full} ==");
                let run = exp::run_variant(cfg, &full)?;
                diverged |= run.diverged;
                finals.push(run.stats.final_train_loss);
                all_runs.push(run);
            }
            table.row(vec![
                panel.to_string(),
                label.clone(),
                format!("{:.4}", stats::mean(&finals)),
                format!("{:.4}", stats::stddev(&finals)),
                if diverged { "x".into() } else { "".into() },
            ]);
        }
    }
    println!("\nFigure 3 — final losses (paper: AQ-SGD ~= FP32, DirectQ worse/diverges):");
    print!("{}", table.render());
    let out = if from_scratch {
        "results/fig7_from_scratch.csv"
    } else if half {
        "results/fig8_fp16.csv"
    } else if seeds > 1 {
        "results/fig6_convergence_std.csv"
    } else {
        "results/fig3_convergence.csv"
    };
    exp::save_traces(out, &all_runs)?;
    Ok(())
}
