//! Figure 3 (and 6/7/8) reproduction: convergence (loss vs steps) of
//! FP32 / DirectQ / AQ-SGD on the four benchmark stand-ins:
//!   QNLI-like, CoLA-like (classification, fw2 bw4)
//!   WikiText2-like (markov), arXiv-like (markov, different seed)
//!     (language modeling, fw3 bw6)
//!
//! Flags:
//!   --seeds N        repeat with N seeds, report mean±std (Figure 6)
//!   --half           FP16 wire baseline alongside (Figure 8)
//!   --from-scratch   rescale-init + longer run (Figure 7 flavour)
//!   --pareto         adaptive-family scheme x bits x bandwidth sweep
//!                    (machine-readable results/fig3_pareto.csv; add
//!                    --quick for the scheduled-CI sized run)
//!   --epochs N
//!
//!     cargo run --release --example fig3_convergence

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{Cli, TrainConfig};
use aq_sgd::exp;
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::exec::{run_virtual, ExecConfig};
use aq_sgd::util::stats;

/// The Pareto sweep behind the scheduled convergence-sweep job: every
/// compression family (plain DirectQ, AQ-SGD, tile-adaptive, Hadamard-
/// rotated tiles, low-rank delta) at three bit budgets, trained on the
/// artifact-free virtual-clock executor (first-party stage model, real
/// registry codecs — runs on any CI runner with no JAX export). Each
/// scheme trains once; the loss trajectory is independent of the
/// simulated bandwidth, so per-bandwidth comm_seconds is derived
/// (comm_bytes / bandwidth) rather than re-trained.
fn run_pareto(cli: &Cli) -> Result<()> {
    let quick = cli.bool("quick");
    let steps = cli.usize("steps", if quick { 8 } else { 40 })?;
    let bandwidths_bps: [f64; 3] = [1e9, 1e8, 1e7];
    let families = ["directq", "aqsgd", "tile:64:directq", "had:tile:64:directq", "lr:4:directq"];

    // (scheme spec, fw bits, bw bits); fp32 anchors the frontier
    let mut methods: Vec<(String, u8, u8)> = vec![("fp32".into(), 32, 32)];
    for (fw, bw) in [(2u8, 4u8), (3, 6), (4, 8)] {
        for fam in families {
            methods.push((format!("{fam}:fw{fw}bw{bw}"), fw, bw));
        }
    }

    let mut csv =
        String::from("scheme,fw_bits,bw_bits,bandwidth_bps,final_loss,comm_bytes,comm_seconds\n");
    let mut table =
        Table::new(&["scheme", "final loss", "comm MB", "s @1Gbps", "s @100Mbps", "s @10Mbps"]);
    for (spec, fw, bw) in &methods {
        let mut c = ExecConfig::small(CodecSpec::parse(spec)?);
        c.n_stages = 4;
        c.n_micro = 4;
        c.micro_batch = 2;
        c.example_len = if quick { 64 } else { 256 };
        c.steps = steps;
        c.seed = 7;
        println!("== pareto {spec} ==");
        let trace = run_virtual(&c)?;
        let last = trace.steps.last().expect("no steps recorded");
        let loss = last.loss;
        let bytes: u64 = trace
            .steps
            .iter()
            .map(|s| s.fw_wire_bytes.iter().sum::<u64>() + s.bw_wire_bytes.iter().sum::<u64>())
            .sum();
        let loss_cell = if loss.is_finite() {
            format!("{loss:.4}")
        } else {
            "diverged".to_string()
        };
        let mut row = vec![spec.clone(), loss_cell, format!("{:.3}", bytes as f64 / 1e6)];
        for bw_bps in bandwidths_bps {
            let secs = bytes as f64 / bw_bps;
            csv.push_str(&format!("{spec},{fw},{bw},{bw_bps:.0},{loss:.6},{bytes},{secs:.4}\n"));
            row.push(format!("{secs:.3}"));
        }
        table.row(row);
    }
    println!("\nFigure 3 Pareto — adaptive compression family, loss vs comm cost:");
    print!("{}", table.render());
    std::fs::create_dir_all("results")?;
    std::fs::write("results/fig3_pareto.csv", csv)?;
    println!("pareto table -> results/fig3_pareto.csv");
    Ok(())
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    if cli.bool("pareto") {
        return run_pareto(&cli);
    }
    let epochs = cli.usize("epochs", 8)?;
    let seeds = cli.usize("seeds", 1)?;
    let half = cli.bool("half");
    let from_scratch = cli.bool("from-scratch");

    // (panel, model, dataset, fw, bw)
    let panels: [(&str, &str, &str, u8, u8); 4] = [
        ("QNLI-like", "tiny_cls", "qnli", 2, 4),
        ("CoLA-like", "tiny_cls", "cola", 2, 4),
        ("WikiText2-like", "tiny", "markov", 3, 6),
        ("arXiv-like", "tiny", "arxiv", 3, 6),
    ];

    let mut all_runs = Vec::new();
    let mut table = Table::new(&["panel", "method", "final loss", "±std", "diverged"]);
    for (panel, model, dataset, fw, bw) in panels {
        let mut methods = exp::method_grid(fw, bw);
        if half {
            methods.insert(1, ("FP16".into(), CodecSpec::fp16()));
        }
        for (label, c) in methods {
            let mut finals = Vec::new();
            let mut diverged = false;
            for seed in 0..seeds {
                let mut cfg = TrainConfig::defaults(model);
                cfg.dataset = dataset.to_string();
                cfg.compression = c.clone();
                cfg.epochs = if from_scratch { epochs * 2 } else { epochs };
                cfg.n_micro = 3;
                cfg.n_examples = 96;
                cfg.lr = if model == "tiny_cls" { 1e-3 } else { 2e-3 };
                cfg.warmup_steps = if from_scratch { 20 } else { 10 };
                cfg.seed = seed as u64;
                let full = format!("{panel} {label} s{seed}");
                println!("== {full} ==");
                let run = exp::run_variant(cfg, &full)?;
                diverged |= run.diverged;
                finals.push(run.stats.final_train_loss);
                all_runs.push(run);
            }
            table.row(vec![
                panel.to_string(),
                label.clone(),
                format!("{:.4}", stats::mean(&finals)),
                format!("{:.4}", stats::stddev(&finals)),
                if diverged { "x".into() } else { "".into() },
            ]);
        }
    }
    println!("\nFigure 3 — final losses (paper: AQ-SGD ~= FP32, DirectQ worse/diverges):");
    print!("{}", table.render());
    let out = if from_scratch {
        "results/fig7_from_scratch.csv"
    } else if half {
        "results/fig8_fp16.csv"
    } else if seeds > 1 {
        "results/fig6_convergence_std.csv"
    } else {
        "results/fig3_convergence.csv"
    };
    exp::save_traces(out, &all_runs)?;
    Ok(())
}
