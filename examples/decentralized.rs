//! Appendix E scenario: decentralized / open-collaborative training over
//! *heterogeneous* slow links (DeDLOC-style 200/100/50 Mbps mixes,
//! Training-Transformers-Together 10-100 Mbps). The pipeline simulator
//! takes per-boundary bandwidths; the slowest link gates FP32 while
//! AQ-SGD stays close to the homogeneous-fast case — the setting the
//! paper argues motivates activation compression. The end-to-end column
//! adds Fig. 5's data-parallel ring (DP 4, `ef:directq:fw4bw4` gradient
//! frames) on the same slow links, with the DP volume measured off real
//! serialized chunk frames.
//!
//!     cargo run --release --example decentralized

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::exp::{self, PaperRegime, DP_RING_CHUNK_ELEMS};
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{PipelineSim, SimConfig};

const DP_DEGREE: usize = 4;

fn step_time(regime: &PaperRegime, c: &CodecSpec, links: &[f64]) -> f64 {
    let (fw, bw) = regime.msg_bytes(c, false);
    let cfg = SimConfig {
        link_bandwidths: Some(links.to_vec()),
        latency_s: 0.02, // geo-distributed RTTs
        ..SimConfig::uniform(
            regime.n_stages,
            regime.n_micro,
            regime.fwd_s,
            regime.bwd_s,
            fw,
            bw,
            1e9,
        )
    };
    PipelineSim::run(&cfg).step_time_s
}

fn throughput(regime: &PaperRegime, step_s: f64) -> f64 {
    (regime.n_micro * regime.micro_batch * DP_DEGREE) as f64 / step_s
}

fn main() -> Result<()> {
    let regime = PaperRegime::default();
    let aq = CodecSpec::aqsgd(2, 4);
    let dp_spec = CodecSpec::parse("ef:directq:fw4bw4")?;
    let shard = regime.dp_shard_elems();
    // DP gradient volume per replica: real ring chunk frames, summed
    let dp_fp32 = exp::measured_dp_frame_bytes(&CodecSpec::fp32(), shard, DP_RING_CHUNK_ELEMS)?;
    let dp_ef4 = exp::measured_dp_frame_bytes(&dp_spec, shard, DP_RING_CHUNK_ELEMS)?;
    // paper App. E cites DeDLOC's 200/100/50 Mbps heterogeneous study and
    // 10-100 Mbps volunteer links; 8 stages -> 7 boundaries
    let scenarios: [(&str, Vec<f64>); 3] = [
        ("datacenter (uniform 10 Gbps)", vec![10e9; 7]),
        ("DeDLOC-like (200/100/50 Mbps mix)",
         vec![200e6, 100e6, 50e6, 200e6, 100e6, 50e6, 200e6]),
        ("volunteer (10-100 Mbps mix)",
         vec![100e6, 50e6, 10e6, 100e6, 25e6, 50e6, 10e6]),
    ];
    let mut t =
        Table::new(&["scenario", "FP32", "AQ-SGD fw2 bw4", "end-to-end (+ef:grad4)", "speed-up"]);
    for (name, links) in scenarios {
        // the DP ring crosses the same slow fabric: its hops are gated
        // by the slowest participant link
        let slowest = links.iter().cloned().fold(f64::INFINITY, f64::min);
        let fp32 = throughput(
            &regime,
            step_time(&regime, &CodecSpec::fp32(), &links)
                + PipelineSim::ring_allgather_time(dp_fp32, DP_DEGREE, slowest, 0.02),
        );
        let act_only = throughput(
            &regime,
            step_time(&regime, &aq, &links)
                + PipelineSim::ring_allgather_time(dp_fp32, DP_DEGREE, slowest, 0.02),
        );
        let e2e = throughput(
            &regime,
            step_time(&regime, &aq, &links)
                + PipelineSim::ring_allgather_time(dp_ef4, DP_DEGREE, slowest, 0.02),
        );
        t.row(vec![
            name.to_string(),
            format!("{fp32:.2} seq/s"),
            format!("{act_only:.2} seq/s"),
            format!("{e2e:.2} seq/s"),
            format!("{:.1}x", e2e / fp32),
        ]);
    }
    println!("Appendix E — decentralized training over heterogeneous links (DP {DP_DEGREE}):\n");
    print!("{}", t.render());
    println!("\n(the slowest volunteer link gates FP32 on both traffic classes;");
    println!("compressing activations *and* DP gradients keeps geo-distributed");
    println!("training within reach of datacenter throughput — Fig. 5's regime.)");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/appE_decentralized.csv", t.to_csv())?;
    Ok(())
}
