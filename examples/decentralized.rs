//! Appendix E scenario: decentralized / open-collaborative training over
//! *heterogeneous* slow links (DeDLOC-style 200/100/50 Mbps mixes,
//! Training-Transformers-Together 10-100 Mbps). The pipeline simulator
//! takes per-boundary bandwidths; the slowest link gates FP32 while
//! AQ-SGD stays close to the homogeneous-fast case — the setting the
//! paper argues motivates activation compression.
//!
//!     cargo run --release --example decentralized

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::exp::PaperRegime;
use aq_sgd::metrics::Table;
use aq_sgd::pipeline::{PipelineSim, SimConfig};

fn throughput(regime: &PaperRegime, c: &CodecSpec, links: &[f64]) -> f64 {
    let (fw, bw) = regime.msg_bytes(c, false);
    let cfg = SimConfig {
        link_bandwidths: Some(links.to_vec()),
        latency_s: 0.02, // geo-distributed RTTs
        ..SimConfig::uniform(
            regime.n_stages,
            regime.n_micro,
            regime.fwd_s,
            regime.bwd_s,
            fw,
            bw,
            1e9,
        )
    };
    PipelineSim::run(&cfg).throughput(regime.n_micro, regime.micro_batch)
}

fn main() -> Result<()> {
    let regime = PaperRegime::default();
    // paper App. E cites DeDLOC's 200/100/50 Mbps heterogeneous study and
    // 10-100 Mbps volunteer links; 8 stages -> 7 boundaries
    let scenarios: [(&str, Vec<f64>); 3] = [
        ("datacenter (uniform 10 Gbps)", vec![10e9; 7]),
        ("DeDLOC-like (200/100/50 Mbps mix)",
         vec![200e6, 100e6, 50e6, 200e6, 100e6, 50e6, 200e6]),
        ("volunteer (10-100 Mbps mix)",
         vec![100e6, 50e6, 10e6, 100e6, 25e6, 50e6, 10e6]),
    ];
    let mut t = Table::new(&["scenario", "FP32", "AQ-SGD fw4 bw8", "speed-up"]);
    for (name, links) in scenarios {
        let fp32 = throughput(&regime, &CodecSpec::fp32(), &links);
        let aq = throughput(&regime, &CodecSpec::aqsgd(4, 8), &links);
        t.row(vec![
            name.to_string(),
            format!("{fp32:.2} seq/s"),
            format!("{aq:.2} seq/s"),
            format!("{:.1}x", aq / fp32),
        ]);
    }
    println!("Appendix E — decentralized training over heterogeneous links:\n");
    print!("{}", t.render());
    println!("\n(the slowest volunteer link gates FP32; compression keeps geo-");
    println!("distributed training within reach of datacenter throughput.)");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/appE_decentralized.csv", t.to_csv())?;
    Ok(())
}
