//! PJRT runtime: loads the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` surface (stubbed in
//! `runtime::xla` for the offline build). Everything above it works with
//! plain host `Vec<f32>` / `Vec<i32>` buffers; marshalling happens here.

pub mod manifest;
pub mod stage;
pub mod xla;

pub use manifest::Manifest;
pub use stage::{QuantRuntime, StageInput, StageRuntime};

use std::path::Path;

use crate::util::error::{Context, Result};

/// Shared PJRT client; create once per process.
pub struct Engine {
    pub client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        Ok(Engine { client: xla::PjRtClient::cpu()? })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Exe> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Exe { exe, name: path.file_name().unwrap().to_string_lossy().into_owned() })
    }
}

/// One compiled executable. All artifacts are lowered with
/// `return_tuple=True`, so outputs always come back as a tuple.
pub struct Exe {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Exe {
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

// ---------------------------------------------------------------------------
// Literal marshalling helpers
// ---------------------------------------------------------------------------

pub fn lit<T: xla::Element>(data: &[T], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    crate::ensure!(n == data.len(), "shape {dims:?} vs {} elements", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    lit(data, dims)
}

pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    lit(data, dims)
}

pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
