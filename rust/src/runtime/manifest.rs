//! Typed view over `artifacts/<config>/manifest.txt`.

use std::path::{Path, PathBuf};

use crate::util::error::{Context, Result};
use crate::util::kv::Kv;

#[derive(Clone, Debug)]
pub struct Manifest {
    kv: Kv,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = artifacts_dir.as_ref().join(model);
        let kv = Kv::load(&dir.join("manifest.txt")).with_context(|| {
            format!(
                "loading manifest for model {model:?} — did you run `python -m compile.aot` from python/? (dir: {})",
                dir.display()
            )
        })?;
        Ok(Manifest { kv, dir })
    }

    pub fn name(&self) -> &str {
        self.kv.get("name").unwrap_or("?")
    }
    pub fn task(&self) -> Result<&str> {
        self.kv.get("task")
    }
    pub fn n_stages(&self) -> Result<usize> {
        self.kv.usize("n_stages")
    }
    pub fn vocab(&self) -> Result<usize> {
        self.kv.usize("vocab")
    }
    pub fn seq(&self) -> Result<usize> {
        self.kv.usize("seq")
    }
    pub fn micro_batch(&self) -> Result<usize> {
        self.kv.usize("micro_batch")
    }
    pub fn d_model(&self) -> Result<usize> {
        self.kv.usize("d_model")
    }
    pub fn n_classes(&self) -> Result<usize> {
        self.kv.usize("n_classes")
    }

    /// [micro_batch, seq, d_model] — the boundary activation shape.
    pub fn boundary(&self) -> Result<Vec<usize>> {
        self.kv.dims("boundary")
    }
    pub fn boundary_len(&self) -> Result<usize> {
        Ok(self.boundary()?.iter().product())
    }
    /// Activation elements per example (seq * d_model).
    pub fn example_len(&self) -> Result<usize> {
        let b = self.boundary()?;
        Ok(b[1] * b[2])
    }

    pub fn stage_params(&self, stage: usize) -> Result<usize> {
        self.kv.usize(&format!("stage{stage}.params"))
    }

    /// Path of an artifact referenced by manifest key.
    pub fn path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.kv.get(key)?))
    }

    pub fn has(&self, key: &str) -> bool {
        self.kv.get_opt(key).is_some()
    }

    /// Total model parameters across stages.
    pub fn total_params(&self) -> Result<usize> {
        let mut n = 0;
        for s in 0..self.n_stages()? {
            n += self.stage_params(s)?;
        }
        Ok(n)
    }

    /// Read a stage's initial flat parameters (f32 LE).
    pub fn stage_init(&self, stage: usize) -> Result<Vec<f32>> {
        let path = self.path(&format!("stage{stage}.init"))?;
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        crate::ensure!(bytes.len() % 4 == 0);
        let n = bytes.len() / 4;
        crate::ensure!(n == self.stage_params(stage)?, "init size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}
