//! Host-side stand-in for the PJRT/XLA native bindings.
//!
//! The offline build links no XLA shared library, so this module provides
//! the same surface the runtime layer programs against: [`Literal`] (pure
//! host-memory marshalling) is implemented fully, while the client /
//! compile / execute entry points return a descriptive error. Tests and
//! benches that execute artifacts gate on both artifact presence and
//! [`BACKEND_AVAILABLE`] (via `testing::require_artifacts`), so they skip
//! cleanly instead of failing to build, link, or run. Swapping in a real
//! PJRT backend means re-implementing exactly the items in this file
//! against the C API (and flipping [`BACKEND_AVAILABLE`]) — nothing
//! above `runtime` changes.

use crate::util::error::{Error, Result};

/// Whether this build can actually execute AOT artifacts. The offline
/// stub cannot; a real PJRT binding sets this true.
pub const BACKEND_AVAILABLE: bool = false;

fn unavailable(what: &str) -> Error {
    Error::msg(format!(
        "PJRT backend unavailable in this build ({what}): the XLA native \
         bindings are stubbed for the offline environment, so AOT artifacts \
         cannot be executed"
    ))
}

// ---------------------------------------------------------------------------
// Literal: host-side tensor container (fully functional)
// ---------------------------------------------------------------------------

/// Element types a [`Literal`] can hold. Sealed to the two dtypes the AOT
/// artifacts use (f32 data, i32 token ids).
pub trait Element: Copy + Sized {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<&[Self]>;
}

impl Element for f32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::F32(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(data: Vec<Self>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[Self]> {
        match data {
            LiteralData::I32(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host tensor (or tuple of tensors) with row-major dims.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        Literal { data: T::wrap(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: LiteralData::F32(vec![v]), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.iter().map(Literal::element_count).sum(),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            crate::bail!("cannot reshape a tuple literal");
        }
        let n: i64 = dims.iter().product();
        crate::ensure!(
            n as usize == self.element_count(),
            "reshape {dims:?} vs {} elements",
            self.element_count()
        );
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Flat host copy of the elements.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| crate::err!("literal dtype mismatch"))
    }

    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        T::unwrap(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| crate::err!("literal is empty or dtype mismatch"))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(v) => Ok(v),
            _ => crate::bail!("literal is not a tuple"),
        }
    }
}

// ---------------------------------------------------------------------------
// Client / executable surface (stubbed: every entry point errors)
// ---------------------------------------------------------------------------

/// Handle to a PJRT client. Construction succeeds (it is just a handle) so
/// callers fail later with the more actionable per-artifact error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO-text module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        // the caller (Engine::load) already attaches a context naming the
        // artifact path, so don't repeat it here
        Err(unavailable("from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        let t = Literal::vec1(&[7i32, 8]);
        assert_eq!(t.get_first_element::<i32>().unwrap(), 7);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(2.5);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        assert!(s.clone().to_tuple().is_err());
        let tup = Literal { data: LiteralData::Tuple(vec![s.clone(), s]), dims: Vec::new() };
        assert_eq!(tup.to_tuple().unwrap().len(), 2);
    }

    #[test]
    fn execution_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        let err = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }
}
