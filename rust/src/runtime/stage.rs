//! Per-stage execution state: compiled artifacts + parameters + optimizer
//! state, and the L1 quantization-kernel runtime.

use super::xla;
use super::{lit_f32, lit_i32, lit_scalar, scalar_f32, to_f32, Engine, Exe, Manifest};
use crate::util::error::{Context, Result};

/// Stage input: token ids for stage 0, hidden states otherwise.
pub enum StageInput<'a> {
    Tokens(&'a [i32]),
    Hidden(&'a [f32]),
}

pub struct StageRuntime {
    pub index: usize,
    pub is_first: bool,
    pub is_last: bool,
    pub n_params: usize,
    fwd: Option<Exe>,
    bwd: Option<Exe>,
    loss: Option<Exe>,
    lossbwd: Option<Exe>,
    logits: Option<Exe>,
    adamw: Exe,
    pub params: Vec<f32>,
    pub opt_m: Vec<f32>,
    pub opt_v: Vec<f32>,
    // cached shapes
    tokens_shape: Vec<usize>,
    boundary: Vec<usize>,
    targets_shape: Vec<usize>,
}

impl StageRuntime {
    pub fn load(engine: &Engine, man: &Manifest, index: usize) -> Result<Self> {
        let k = man.n_stages()?;
        let is_first = index == 0;
        let is_last = index == k - 1;
        let n_params = man.stage_params(index)?;
        let load_opt = |key: &str| -> Result<Option<Exe>> {
            if man.has(&format!("stage{index}.{key}")) {
                Ok(Some(engine.load(&man.path(&format!("stage{index}.{key}"))?)?))
            } else {
                Ok(None)
            }
        };
        let boundary = man.boundary()?;
        let micro_batch = man.micro_batch()?;
        let seq = man.seq()?;
        let targets_shape = if man.task()? == "lm" {
            vec![micro_batch, seq]
        } else {
            vec![micro_batch]
        };
        Ok(StageRuntime {
            index,
            is_first,
            is_last,
            n_params,
            fwd: load_opt("fwd")?,
            bwd: load_opt("bwd")?,
            loss: load_opt("loss")?,
            lossbwd: load_opt("lossbwd")?,
            logits: load_opt("logits")?,
            adamw: engine.load(&man.path(&format!("stage{index}.adamw"))?)?,
            params: man.stage_init(index)?,
            opt_m: vec![0.0; n_params],
            opt_v: vec![0.0; n_params],
            tokens_shape: vec![micro_batch, seq],
            boundary,
            targets_shape,
        })
    }

    fn input_lit(&self, x: &StageInput) -> Result<xla::Literal> {
        match x {
            StageInput::Tokens(t) => lit_i32(t, &self.tokens_shape),
            StageInput::Hidden(h) => lit_f32(h, &self.boundary),
        }
    }

    /// Forward pass: returns the outgoing boundary activation.
    pub fn forward(&self, x: &StageInput) -> Result<Vec<f32>> {
        let exe = self.fwd.as_ref().context("stage has no fwd artifact")?;
        let p = lit_f32(&self.params, &[self.n_params])?;
        let out = exe.run(&[p, self.input_lit(x)?])?;
        to_f32(&out[0])
    }

    /// Backward pass (recomputation style): returns (g_params, g_input).
    /// g_input is None for stage 0 (token input).
    pub fn backward(&self, x: &StageInput, g_out: &[f32]) -> Result<(Vec<f32>, Option<Vec<f32>>)> {
        let exe = self.bwd.as_ref().context("stage has no bwd artifact")?;
        let p = lit_f32(&self.params, &[self.n_params])?;
        let g = lit_f32(g_out, &self.boundary)?;
        let out = exe.run(&[p, self.input_lit(x)?, g])?;
        let gp = to_f32(&out[0])?;
        let gx = if out.len() > 1 { Some(to_f32(&out[1])?) } else { None };
        Ok((gp, gx))
    }

    /// Last-stage loss + backward: returns (loss, g_params, g_input).
    pub fn loss_backward(
        &self,
        x: &StageInput,
        targets: &[i32],
    ) -> Result<(f32, Vec<f32>, Option<Vec<f32>>)> {
        let exe = self.lossbwd.as_ref().context("stage has no lossbwd artifact")?;
        let p = lit_f32(&self.params, &[self.n_params])?;
        let t = lit_i32(targets, &self.targets_shape)?;
        let out = exe.run(&[p, self.input_lit(x)?, t])?;
        let loss = scalar_f32(&out[0])?;
        let gp = to_f32(&out[1])?;
        let gx = if out.len() > 2 { Some(to_f32(&out[2])?) } else { None };
        Ok((loss, gp, gx))
    }

    /// Last-stage logits (inference head, [B, S, vocab] flattened).
    pub fn logits(&self, x: &StageInput) -> Result<Vec<f32>> {
        let exe = self.logits.as_ref().context("stage has no logits artifact")?;
        let p = lit_f32(&self.params, &[self.n_params])?;
        let out = exe.run(&[p, self.input_lit(x)?])?;
        to_f32(&out[0])
    }

    /// Last-stage evaluation loss (no gradients).
    pub fn eval_loss(&self, x: &StageInput, targets: &[i32]) -> Result<f32> {
        let exe = self.loss.as_ref().context("stage has no loss artifact")?;
        let p = lit_f32(&self.params, &[self.n_params])?;
        let t = lit_i32(targets, &self.targets_shape)?;
        let out = exe.run(&[p, self.input_lit(x)?, t])?;
        scalar_f32(&out[0])
    }

    /// AdamW step through the HLO artifact (step is 1-based).
    pub fn adamw_step_hlo(&mut self, grads: &[f32], step: usize, lr: f64) -> Result<()> {
        crate::ensure!(grads.len() == self.n_params);
        let out = self.adamw.run(&[
            lit_f32(&self.params, &[self.n_params])?,
            lit_f32(&self.opt_m, &[self.n_params])?,
            lit_f32(&self.opt_v, &[self.n_params])?,
            lit_f32(grads, &[self.n_params])?,
            lit_scalar(step as f32),
            lit_scalar(lr as f32),
        ])?;
        self.params = to_f32(&out[0])?;
        self.opt_m = to_f32(&out[1])?;
        self.opt_v = to_f32(&out[2])?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Runtime for the L1 Pallas quantization kernels (the `--hlo-codec`
/// boundary path). Operates on whole boundary tensors with a per-tensor
/// scale, mirroring `python/compile/kernels/quant.py`.
pub struct QuantRuntime {
    aq_encode: Exe,
    aq_decode: Exe,
    dq_encode: Exe,
    dq_decode: Exe,
    boundary: Vec<usize>,
    n: usize,
    /// deterministic rounding offsets (0.5); stochastic mode would draw
    /// fresh noise per call.
    noise: Vec<f32>,
}

impl QuantRuntime {
    pub fn load(engine: &Engine, man: &Manifest) -> Result<Self> {
        let boundary = man.boundary()?;
        let n = boundary.iter().product();
        Ok(QuantRuntime {
            aq_encode: engine.load(&man.path("quant.aq_encode")?)?,
            aq_decode: engine.load(&man.path("quant.aq_decode")?)?,
            dq_encode: engine.load(&man.path("quant.dq_encode")?)?,
            dq_decode: engine.load(&man.path("quant.dq_decode")?)?,
            boundary,
            n,
            noise: vec![0.5; n],
        })
    }

    fn levels(bits: u8) -> f32 {
        ((1u32 << bits) - 1) as f32
    }

    /// AQ-SGD encode via the Pallas kernel: (codes, scale, m_new).
    pub fn aq_encode(&self, a: &[f32], m: &[f32], bits: u8) -> Result<(Vec<u8>, f32, Vec<f32>)> {
        let out = self.aq_encode.run(&[
            lit_f32(a, &self.boundary)?,
            lit_f32(m, &self.boundary)?,
            lit_f32(&self.noise, &self.boundary)?,
            lit_scalar(Self::levels(bits)),
        ])?;
        let codes_f = to_f32(&out[0])?;
        let scale = scalar_f32(&out[1])?;
        let m_new = to_f32(&out[2])?;
        Ok((codes_f.iter().map(|&c| c as u8).collect(), scale, m_new))
    }

    /// Receiver-side buffer advance.
    pub fn aq_decode(&self, codes: &[u8], scale: f32, m: &[f32], bits: u8) -> Result<Vec<f32>> {
        let codes_f: Vec<f32> = codes.iter().map(|&c| c as f32).collect();
        let out = self.aq_decode.run(&[
            lit_f32(&codes_f, &self.boundary)?,
            lit_scalar(scale),
            lit_f32(m, &self.boundary)?,
            lit_scalar(Self::levels(bits)),
        ])?;
        to_f32(&out[0])
    }

    /// DirectQ encode: (codes, scale).
    pub fn dq_encode(&self, a: &[f32], bits: u8) -> Result<(Vec<u8>, f32)> {
        let out = self.dq_encode.run(&[
            lit_f32(a, &self.boundary)?,
            lit_f32(&self.noise, &self.boundary)?,
            lit_scalar(Self::levels(bits)),
        ])?;
        let codes_f = to_f32(&out[0])?;
        let scale = scalar_f32(&out[1])?;
        Ok((codes_f.iter().map(|&c| c as u8).collect(), scale))
    }

    pub fn dq_decode(&self, codes: &[u8], scale: f32, bits: u8) -> Result<Vec<f32>> {
        let codes_f: Vec<f32> = codes.iter().map(|&c| c as f32).collect();
        let out = self.dq_decode.run(&[
            lit_f32(&codes_f, &self.boundary)?,
            lit_scalar(scale),
            lit_scalar(Self::levels(bits)),
        ])?;
        to_f32(&out[0])
    }

    pub fn n_elements(&self) -> usize {
        self.n
    }
}
