//! Flat `key value` text format (manifest + config files). A stand-in for
//! JSON in this no-serde environment; one pair per line, `#` comments.

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Kv {
    map: HashMap<String, String>,
}

impl Kv {
    pub fn parse(text: &str) -> Self {
        let mut map = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some((k, v)) = line.split_once(char::is_whitespace) {
                map.insert(k.to_string(), v.trim().to_string());
            }
        }
        Kv { map }
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(Self::parse(&text))
    }

    pub fn get(&self, key: &str) -> Result<&str> {
        self.map
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| crate::err!("missing key {key:?}"))
    }

    pub fn get_opt(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        self.get(key)?.parse().with_context(|| format!("parsing {key}"))
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)?.parse().with_context(|| format!("parsing {key}"))
    }

    /// "4x32x32" -> [4, 32, 32]
    pub fn dims(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)?
            .split('x')
            .map(|d| d.parse().with_context(|| format!("parsing {key}")))
            .collect()
    }

    pub fn insert(&mut self, key: &str, value: impl ToString) {
        self.map.insert(key.to_string(), value.to_string());
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}
