//! Small statistics helpers used by metrics and benches.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 { return 0.0; }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() { return f64::NAN; }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) }
}

/// Exponential moving average (used for the paper's smoothed loss curves).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

pub fn mean_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() { return 0.0; }
    xs.iter().map(|x| x.abs() as f64).sum::<f64>() / xs.len() as f64
}
