//! First-party error handling (the offline environment ships no anyhow):
//! a single dynamic [`Error`] carrying a root cause plus a chain of
//! human-readable contexts, a crate-wide [`Result`] alias, a [`Context`]
//! extension trait for `Result`/`Option`, and the `err!` / `bail!` /
//! `ensure!` macros (drop-in for `anyhow!` / `bail!` / `ensure!`).
//!
//! Any `std::error::Error` converts into [`Error`] via `?`, preserving its
//! `source()` chain. Like anyhow's, [`Error`] deliberately does *not*
//! implement `std::error::Error` itself — that is what keeps the blanket
//! `From` impl coherent.

use std::fmt;

/// A dynamic error: `chain[0]` is the root cause, later entries are the
/// contexts wrapped around it (outermost last).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap this error in an outer context (consuming, like
    /// `anyhow::Error::context`).
    pub fn context(mut self, ctx: impl fmt::Display) -> Self {
        self.chain.push(ctx.to_string());
        self
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    /// Contexts outermost-first, ending at the root cause (mirrors
    /// anyhow's `chain()` ordering).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut it = self.chain();
        write!(f, "{}", it.next().unwrap_or(""))?;
        let rest: Vec<&str> = it.collect();
        if !rest.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for msg in rest {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

/// Convert any standard error (io, parse, ...) so `?` works directly.
/// The error's `source()` chain becomes the context chain.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        chain.reverse(); // root cause first
        Error { chain }
    }
}

/// Crate-wide result alias (defaults to [`Error`], like `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message to the error / `None` case.
    fn context(self, ctx: impl fmt::Display) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, ctx: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (drop-in for `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)+) => {
        $crate::util::error::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::err!($($arg)+))
    };
}

/// Return early with an error unless the condition holds (drop-in for
/// `anyhow::ensure!`; the message-less form stringifies the condition).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::err!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_port(s: &str) -> Result<u16> {
        let port: u16 = s.parse().with_context(|| format!("parsing port {s:?}"))?;
        crate::ensure!(port != 0, "port must be non-zero");
        Ok(port)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_port("8080").unwrap(), 8080);
        let e = parse_port("nope").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("parsing port \"nope\""), "{msg}");
        assert!(msg.contains("invalid digit"), "{msg}");
    }

    #[test]
    fn context_chain_order() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "mid", "root"]);
        assert_eq!(e.to_string(), "outer: mid: root");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u8> {
            crate::ensure!(flag);
            crate::bail!("bailed with {}", 42)
        }
        assert!(f(false).unwrap_err().to_string().contains("condition failed: flag"));
        assert_eq!(f(true).unwrap_err().to_string(), "bailed with 42");
        let e = crate::err!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn ensure_message_form() {
        fn f(n: usize) -> Result<()> {
            crate::ensure!(n < 10, "n too big: {n}");
            Ok(())
        }
        assert!(f(3).is_ok());
        assert_eq!(f(12).unwrap_err().to_string(), "n too big: 12");
    }
}
