//! Minimal first-party JSON (the offline build ships no serde): a
//! recursive-descent parser into a small [`Json`] value tree plus a
//! compact writer with correct string escaping. Object keys keep their
//! input order (lookup is a linear scan — the machine-readable bench
//! files this serves hold tens of entries, not millions).
//!
//! Used by the bench harness (`testing::bench::BenchSuite` writes
//! `--json` reports) and the `bench-diff` regression comparator that
//! gates CI on `BENCH_BASELINE.json`.

use crate::util::error::Result;

/// One parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers parse as f64 (adequate for ns / byte counts well
    /// below 2^53).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in input order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing non-whitespace is an
    /// error, not silently ignored).
    pub fn parse(text: &str) -> Result<Json> {
        let b = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        crate::ensure!(pos == b.len(), "trailing bytes at offset {pos} after JSON value");
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Compact serialization (round-trips through [`Json::parse`]).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => out.push_str(&fmt_number(*v)),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Format a number the way the writer emits it: integers without a
/// fraction, everything else via f64 `Display`. NaN/inf (not
/// representable in JSON) render as null.
fn fmt_number(v: f64) -> String {
    if !v.is_finite() {
        return "null".into();
    }
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// JSON string escape, appended to `out` with surrounding quotes.
pub fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: a quoted, escaped JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::new();
    escape_into(s, &mut out);
    out
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    crate::ensure!(*pos < b.len(), "unexpected end of JSON input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    crate::ensure!(
        b[*pos..].starts_with(lit.as_bytes()),
        "bad JSON literal at offset {pos}",
        pos = *pos
    );
    *pos += lit.len();
    Ok(v)
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).expect("ascii number bytes");
    let v: f64 = s.parse().map_err(|_| crate::err!("bad JSON number {s:?} at offset {start}"))?;
    Ok(Json::Num(v))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    crate::ensure!(
        *pos < b.len() && b[*pos] == b'"',
        "expected string at offset {pos}",
        pos = *pos
    );
    *pos += 1;
    let mut out = String::new();
    loop {
        crate::ensure!(*pos < b.len(), "unterminated JSON string");
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                crate::ensure!(*pos < b.len(), "unterminated JSON escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = parse_hex4(b, pos)?;
                        // combine a surrogate pair; a lone surrogate maps
                        // to the replacement character rather than erroring
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // peek the next escape without committing, so a
                            // non-low-surrogate that follows is preserved
                            let mut peek = *pos;
                            let lo = if b[*pos + 1..].starts_with(b"\\u") {
                                peek += 2;
                                Some(parse_hex4(b, &mut peek)?)
                            } else {
                                None
                            };
                            match lo {
                                Some(lo) if (0xDC00..0xE000).contains(&lo) => {
                                    *pos = peek;
                                    let code =
                                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(code).unwrap_or('\u{FFFD}')
                                }
                                // unpaired high surrogate: replace it and
                                // leave whatever follows for the main loop
                                _ => '\u{FFFD}',
                            }
                        } else {
                            char::from_u32(hi).unwrap_or('\u{FFFD}')
                        };
                        out.push(c);
                    }
                    e => crate::bail!("bad JSON escape \\{} at offset {}", e as char, *pos),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar (input is a &str, so bytes are
                // valid UTF-8 by construction)
                let rest = std::str::from_utf8(&b[*pos..]).expect("valid utf8 input");
                let c = rest.chars().next().expect("non-empty");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

/// Parse the 4 hex digits of a `\uXXXX` escape; `pos` points at the `u`
/// on entry and at the last hex digit on exit.
fn parse_hex4(b: &[u8], pos: &mut usize) -> Result<u32> {
    crate::ensure!(*pos + 4 < b.len(), "truncated \\u escape");
    let s =
        std::str::from_utf8(&b[*pos + 1..*pos + 5]).map_err(|_| crate::err!("bad \\u escape"))?;
    let v = u32::from_str_radix(s, 16).map_err(|_| crate::err!("bad \\u escape {s:?}"))?;
    *pos += 4;
    Ok(v)
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        crate::ensure!(*pos < b.len(), "unterminated JSON array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            c => crate::bail!("expected ',' or ']' at offset {}, got {:?}", *pos, c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        crate::ensure!(
            *pos < b.len() && b[*pos] == b':',
            "expected ':' after object key at offset {pos}",
            pos = *pos
        );
        *pos += 1;
        fields.push((key, parse_value(b, pos)?));
        skip_ws(b, pos);
        crate::ensure!(*pos < b.len(), "unterminated JSON object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            c => crate::bail!("expected ',' or '}}' at offset {}, got {:?}", *pos, c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_bench_schema() {
        let doc = r#"{
            "suite": "bench_codec",
            "schema": 1,
            "quick": true,
            "results": [
                {"name": "frame_encode/fp32/1MB", "mean_ns": 812345.5,
                 "bytes_per_iter": 1048576, "gb_per_s": 1.29},
                {"name": "pack/4bit/1M", "mean_ns": 2.0e5,
                 "bytes_per_iter": null, "gb_per_s": null}
            ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("suite").unwrap().as_str(), Some("bench_codec"));
        assert_eq!(v.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("quick").unwrap().as_bool(), Some(true));
        let results = v.get("results").unwrap().as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").unwrap().as_str(),
            Some("frame_encode/fp32/1MB")
        );
        assert_eq!(results[0].get("bytes_per_iter").unwrap().as_f64(), Some(1048576.0));
        assert_eq!(results[1].get("bytes_per_iter"), Some(&Json::Null));
    }

    #[test]
    fn render_parse_round_trip() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("a \"quoted\"\nname\\path".into())),
            ("n".into(), Json::Num(42.0)),
            ("x".into(), Json::Num(1.5)),
            ("flag".into(), Json::Bool(false)),
            ("none".into(), Json::Null),
            ("xs".into(), Json::Arr(vec![Json::Num(1.0), Json::Num(-2.25)])),
        ]);
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // integers render without a fraction
        assert!(text.contains("\"n\":42,"), "{text}");
    }

    #[test]
    fn malformed_documents_error_not_panic() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "{\"a\":1} trailing", "[1 2]", "\"\\q\"",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""\u0041\u00e9""#).unwrap(),
            Json::Str("Aé".into())
        );
        // surrogate pair
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("😀".into())
        );
        // lone surrogate degrades to the replacement character
        assert_eq!(Json::parse(r#""\ud83d""#).unwrap(), Json::Str("\u{FFFD}".into()));
        // ... and what follows it is preserved, not swallowed — whether a
        // plain character or a non-surrogate escape
        assert_eq!(
            Json::parse(r#""\ud800A""#).unwrap(),
            Json::Str("\u{FFFD}A".into())
        );
        assert_eq!(
            Json::parse(r#""\ud800\u0041""#).unwrap(),
            Json::Str("\u{FFFD}A".into())
        );
    }
}
