//! Dependency-free utilities (the offline environment ships no rand /
//! serde / clap / anyhow; everything here replaces those).
pub mod error;
pub mod fmt;
pub mod json;
pub mod kv;
pub mod rng;
pub mod stats;

pub use rng::Rng;
