//! Human-readable formatting for logs and bench output.

pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 { format!("{} {}", n, UNITS[0]) } else { format!("{:.2} {}", v, UNITS[u]) }
}

pub fn duration_s(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

pub fn bandwidth(bytes_per_s: f64) -> String {
    let bits = bytes_per_s * 8.0;
    if bits >= 1e9 {
        format!("{:.0} Gbps", bits / 1e9)
    } else {
        format!("{:.0} Mbps", bits / 1e6)
    }
}
