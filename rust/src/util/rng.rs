/// SplitMix64-seeded xoshiro256** PRNG (no external deps available offline).
#[derive(Clone, Debug)]
pub struct Rng { s: [u64; 4] }
impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut next = || { x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31) };
        Rng { s: [next(), next(), next(), next()] }
    }
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0]; self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2]; self.s[0] ^= self.s[3];
        self.s[2] ^= t; self.s[3] = self.s[3].rotate_left(45);
        r
    }
    /// uniform in [0, 1)
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
    /// uniform integer in [0, n)
    pub fn below(&mut self, n: usize) -> usize { (self.next_u64() % n as u64) as usize }
    /// standard normal via Box-Muller
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() { let j = self.below(i + 1); xs.swap(i, j); }
    }
}
