//! Training/throughput metrics: loss traces, comm accounting, CSV output
//! (every figure/table harness writes its rows through this module).

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::util::stats::Ema;

/// One training-trace row.
#[derive(Clone, Debug)]
pub struct TraceRow {
    pub step: usize,
    pub epoch: usize,
    pub loss: f64,
    pub loss_ema: f64,
    /// Cumulative bytes that crossed pipeline boundaries so far.
    pub comm_bytes: u64,
    /// Simulated wall-clock seconds so far (virtual network time).
    pub sim_time_s: f64,
    /// Real wall-clock seconds so far.
    pub wall_time_s: f64,
}

pub struct Recorder {
    pub label: String,
    pub rows: Vec<TraceRow>,
    ema: Ema,
    start: Instant,
    pub comm_bytes: u64,
    pub sim_time_s: f64,
    pub diverged: bool,
}

impl Recorder {
    pub fn new(label: impl Into<String>) -> Self {
        Recorder {
            label: label.into(),
            rows: Vec::new(),
            ema: Ema::new(0.05),
            start: Instant::now(),
            comm_bytes: 0,
            sim_time_s: 0.0,
            diverged: false,
        }
    }

    pub fn record(&mut self, step: usize, epoch: usize, loss: f64) {
        if !loss.is_finite() || loss > 1e4 {
            self.diverged = true;
        }
        let ema = self.ema.update(loss);
        self.rows.push(TraceRow {
            step,
            epoch,
            loss,
            loss_ema: ema,
            comm_bytes: self.comm_bytes,
            sim_time_s: self.sim_time_s,
            wall_time_s: self.start.elapsed().as_secs_f64(),
        });
    }

    pub fn final_loss(&self) -> f64 {
        self.rows.last().map(|r| r.loss_ema).unwrap_or(f64::NAN)
    }

    /// First simulated time at which the smoothed loss reaches `target`
    /// (the paper's "time to the same loss" metric; None if never).
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.rows.iter().find(|r| r.loss_ema <= target).map(|r| r.sim_time_s)
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from("step,epoch,loss,loss_ema,comm_bytes,sim_time_s,wall_time_s\n");
        for r in &self.rows {
            let _ = writeln!(
                s,
                "{},{},{:.6},{:.6},{},{:.4},{:.2}",
                r.step, r.epoch, r.loss, r.loss_ema, r.comm_bytes, r.sim_time_s, r.wall_time_s
            );
        }
        s
    }

    pub fn save_csv(&self, path: impl AsRef<Path>) -> crate::util::error::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

/// Minimal fixed-width table printer for the bench harnesses (matches the
/// row/column layout of the paper's tables).
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, "| {:<width$} ", c, width = w);
            }
            line.push('|');
            line
        };
        let header = fmt_row(&self.header, &widths);
        out.push_str(&header);
        out.push('\n');
        out.push_str(&"-".repeat(header.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recorder_tracks_divergence_and_ttl() {
        let mut r = Recorder::new("t");
        for i in 0..200 {
            r.sim_time_s = i as f64;
            r.record(i, 0, (5.0 - i as f64 * 0.5).max(0.5));
        }
        assert!(!r.diverged);
        assert!(r.final_loss() < 1.0);
        let t = r.time_to_loss(2.5).unwrap();
        assert!(t > 0.0 && t < 200.0);
        assert!(r.time_to_loss(-10.0).is_none());
        r.record(200, 1, f64::NAN);
        assert!(r.diverged);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut r = Recorder::new("t");
        r.record(0, 0, 1.0);
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("step,"));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["Network", "FP32", "AQ-SGD"]);
        t.row(vec!["10 Gbps".into(), "3.8".into(), "4.0".into()]);
        let s = t.render();
        assert!(s.contains("10 Gbps"));
        assert!(s.contains("AQ-SGD"));
        assert_eq!(t.to_csv().lines().count(), 2);
    }
}
