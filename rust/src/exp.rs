//! Shared experiment harness for the `examples/` figure & table binaries:
//! dataset factory, single-variant runner, and sweep helpers. Keeps every
//! reproduction script down to "declare the grid, print the table".

use crate::codec::CodecSpec;
use crate::config::TrainConfig;
use crate::coordinator::{TrainStats, Trainer};
use crate::data::{cls, lm, Dataset};
use crate::metrics::Recorder;
use crate::pipeline::exec::{self, ExecConfig, ExecTrace};
use crate::runtime::Manifest;
use crate::util::error::Result;

/// Build the dataset a config names ("markov" | "arxiv" | "embedded" |
/// "qnli" | "cola") with shapes taken from the model manifest.
pub fn make_dataset(cfg: &TrainConfig, man: &Manifest) -> Result<Dataset> {
    let vocab = man.vocab()?;
    let seq = man.seq()?;
    Ok(match cfg.dataset.as_str() {
        "markov" => lm::markov_corpus(vocab, seq, cfg.n_examples, cfg.seed + 100),
        "arxiv" => lm::markov_corpus(vocab, seq, cfg.n_examples, cfg.seed + 200),
        "embedded" => lm::embedded_corpus(seq, cfg.n_examples),
        "qnli" => cls::qnli_like(vocab, seq, cfg.n_examples, cfg.seed + 300),
        "cola" => cls::cola_like(vocab, seq, cfg.n_examples, cfg.seed + 400),
        other => crate::bail!("unknown dataset {other:?}"),
    })
}

/// Result of one training variant.
pub struct RunResult {
    pub label: String,
    pub stats: TrainStats,
    pub recorder: Recorder,
    pub probe: Vec<(usize, f64, f64)>,
    pub diverged: bool,
}

/// Train one variant to completion and hand back its trace.
pub fn run_variant(cfg: TrainConfig, label: &str) -> Result<RunResult> {
    let man = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
    let data = make_dataset(&cfg, &man)?;
    let (train, eval) = data.split_eval(0.125);
    let mut trainer = Trainer::new(cfg)?;
    let stats = trainer.train(&train, Some(&eval))?;
    Ok(RunResult {
        label: label.to_string(),
        diverged: trainer.recorder.diverged,
        probe: trainer.probe.rows.clone(),
        stats,
        recorder: std::mem::replace(&mut trainer.recorder, Recorder::new("")),
    })
}

/// Run the self-contained pipeline executor the config names
/// (`--executor threads|events|sim`, see `pipeline::exec`) *and* the
/// virtual-clock oracle on the same shape; returns `(real, oracle)`.
/// First-party stage compute + registry codecs, so it needs no AOT
/// artifacts and no PJRT backend; the pipeline shape — normally dictated
/// by the artifact manifest — is passed explicitly. The CLI and the
/// examples use this for the determinism cross-check
/// (`real.bit_identical(&oracle)` must hold — `tests/exec_vs_sim.rs`).
pub fn run_executor_with_oracle(
    cfg: &TrainConfig,
    n_stages: usize,
    micro_batch: usize,
    example_len: usize,
    steps: usize,
) -> Result<(ExecTrace, ExecTrace)> {
    let ec = ExecConfig::from_train(cfg, n_stages, micro_batch, example_len, steps);
    let real = exec::run(&ec, cfg.executor)?;
    let oracle = exec::run(&ec, crate::pipeline::Executor::Sim)?;
    Ok((real, oracle))
}

/// The determinism cross-check both entry points report: Ok when the
/// real trajectory is bit-identical to the oracle's, the shared error
/// otherwise. Single-sourced so the check cannot drift between the CLI
/// and the examples.
pub fn check_matches_oracle(real: &ExecTrace, oracle: &ExecTrace) -> Result<()> {
    crate::ensure!(
        real.bit_identical(oracle),
        "{} executor diverged from the virtual-clock oracle",
        real.executor.label()
    );
    Ok(())
}

/// The standard method grid of the paper's convergence figures.
pub fn method_grid(fw: u8, bw: u8) -> Vec<(String, CodecSpec)> {
    vec![
        ("FP32".into(), CodecSpec::fp32()),
        (format!("DirectQ fw{fw} bw{bw}"), CodecSpec::directq(fw, bw)),
        (format!("AQ-SGD fw{fw} bw{bw}"), CodecSpec::aqsgd(fw, bw)),
    ]
}

/// Write a CSV with one loss-trace column block per run (long format:
/// label,step,loss,loss_ema,sim_time_s).
pub fn save_traces(path: &str, runs: &[RunResult]) -> Result<()> {
    let mut out = String::from("label,step,epoch,loss,loss_ema,comm_bytes,sim_time_s\n");
    for r in runs {
        for row in &r.recorder.rows {
            out.push_str(&format!(
                "{},{},{},{:.6},{:.6},{},{:.4}\n",
                r.label, row.step, row.epoch, row.loss, row.loss_ema, row.comm_bytes, row.sim_time_s
            ));
        }
    }
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)?;
    println!("traces -> {path}");
    Ok(())
}

/// Paper-regime pipeline parameters (GPT2-1.5B on 8 V100 stages,
/// Table 3: 45 ms fwd / 135 ms bwd per microbatch, 6.4 MB boundary
/// messages at micro-batch 1 x seq 1024 x d 1600).
pub struct PaperRegime {
    pub n_stages: usize,
    pub n_micro: usize,
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub fp32_msg_bytes: u64,
    pub micro_batch: usize,
    /// total model parameter bytes (for DP gradient volume)
    pub param_bytes: u64,
}

impl Default for PaperRegime {
    fn default() -> Self {
        PaperRegime {
            n_stages: 8,
            n_micro: 32,
            fwd_s: 0.045,
            bwd_s: 0.135,
            fp32_msg_bytes: (1 * 1024 * 1600 * 4) as u64,
            micro_batch: 1,
            param_bytes: 6_000_000_000, // 1.5B params * 4B
        }
    }
}

impl PaperRegime {
    /// Forward/backward wire bytes for a compression scheme, *measured*
    /// by encoding a paper-regime-sized synthetic message through the
    /// registry-built codec (`CodecSpec::fw_wire_bytes`), not derived
    /// from a parallel formula.
    pub fn msg_bytes(&self, c: &CodecSpec, first_visit: bool) -> (u64, u64) {
        let n = (self.fp32_msg_bytes / 4) as usize;
        (c.fw_wire_bytes(n, first_visit), c.bw_wire_bytes(n))
    }

    /// Elements of one machine's DP gradient shard (params / stages).
    pub fn dp_shard_elems(&self) -> usize {
        (self.param_bytes / 4 / self.n_stages as u64) as usize
    }
}

/// Ring chunk size the regime harnesses encode DP gradients at
/// (4M elements = 16 MB fp32 per frame — large enough to amortize the
/// frame header, small enough to build without regime-sized buffers).
pub const DP_RING_CHUNK_ELEMS: usize = 1 << 22;

/// Wire bytes one replica's `n`-element DP gradient occupies under
/// `spec`, *measured* by encoding real chunk frames through the
/// registry-built gradient codec and summing their serialized sizes —
/// the ring ships the shard as `ceil(n / chunk)` frames, and every
/// reported byte is `Frame::to_bytes().len()` of one of them. (Chunks of
/// equal length produce identical-size frames for the dense gradient
/// codecs, so each distinct length is encoded once.)
pub fn measured_dp_frame_bytes(spec: &CodecSpec, n: usize, chunk: usize) -> Result<u64> {
    crate::ensure!(chunk >= 1, "dp chunk must be non-empty");
    let full = n / chunk;
    let rem = n % chunk;
    let mut total = 0u64;
    for (len, count) in [(chunk, full as u64), (rem, u64::from(rem > 0))] {
        if count == 0 {
            continue;
        }
        let (mut enc, _) = crate::codec::registry::build_mem_pair(
            &spec.fw,
            len,
            crate::codec::Rounding::Nearest,
            0xD9,
        )?;
        let mut rng = crate::util::Rng::new(0x6AAD);
        let g: Vec<f32> = (0..len).map(|_| 1e-3 * rng.normal()).collect();
        let frame = enc.encode(&[0], &g)?;
        total += frame.to_bytes().len() as u64 * count;
    }
    Ok(total)
}
