//! Experiment configuration + a tiny `--key value` CLI parser (no clap in
//! the offline environment).

use std::collections::HashMap;

use crate::codec::CodecSpec;
use crate::pipeline::{Executor, Schedule};
use crate::util::error::Result;

/// Parsed command line: positional args + `--key value` flags
/// (`--flag` with no value is "true").
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Cli {
    pub fn parse_args(args: impl Iterator<Item = String>) -> Self {
        let mut cli = Cli::default();
        let mut args = args.peekable();
        while let Some(a) = args.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = match args.peek() {
                    Some(v) if !v.starts_with("--") => args.next().unwrap(),
                    _ => "true".to_string(),
                };
                cli.flags.insert(key.to_string(), val);
            } else {
                cli.positional.push(a);
            }
        }
        cli
    }

    pub fn from_env() -> Self {
        Self::parse_args(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| crate::err!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| crate::err!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

/// Parse "500mbps" / "10gbps" / raw bits-per-second.
pub fn parse_bandwidth(s: &str) -> Result<f64> {
    let t = s.trim().to_lowercase();
    if let Some(v) = t.strip_suffix("gbps") {
        return Ok(v.trim().parse::<f64>()? * 1e9);
    }
    if let Some(v) = t.strip_suffix("mbps") {
        return Ok(v.trim().parse::<f64>()? * 1e6);
    }
    if let Some(v) = t.strip_suffix("kbps") {
        return Ok(v.trim().parse::<f64>()? * 1e3);
    }
    Ok(t.parse::<f64>()?)
}

/// Full training-run configuration consumed by the coordinator.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// artifacts/<model> directory name.
    pub model: String,
    pub artifacts_dir: String,
    /// Boundary codec spec (see `codec::registry` for the grammar).
    pub compression: CodecSpec,
    /// Stochastic rounding for the quantizers (theory wants it; paper's
    /// implementation uses deterministic — default false).
    pub stochastic_rounding: bool,
    /// Message-buffer precision (None = f32; Some(bits) = Fig 9e/f "mz").
    pub m_bits: Option<u8>,
    /// Buffer store backend: "mem" | "disk" | "quant".
    pub store: String,
    pub epochs: usize,
    /// Micro-batches per optimizer step (macro = n_micro * micro_batch).
    pub n_micro: usize,
    pub lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub seed: u64,
    pub shuffle_every_epoch: bool,
    /// Simulated link speed + latency for time accounting.
    pub bandwidth_bps: f64,
    pub latency_s: f64,
    pub schedule: Schedule,
    /// Pipeline runtime: `Sim` (single-threaded, virtual-clock time
    /// accounting), `Threads` (one worker thread per stage exchanging
    /// serialized frames), or `Events` (a fixed worker pool driving
    /// ready stages off a run queue — see `pipeline::exec`).
    pub executor: Executor,
    /// Worker-pool size for the event executor (`--workers`); ignored by
    /// the other executors. Any pool size yields the identical numeric
    /// trajectory — this only trades parallelism against thread count.
    pub workers: usize,
    /// Data-parallel degree (gradient averaging across replicas).
    pub dp_degree: usize,
    /// Gradient codec for the DP ring (`--dp-codec`, same registry
    /// grammar as `compression`; `ef:directq:fw4bw4` is the Fig. 5
    /// error-compensated regime, `fp32` = uncompressed exchange).
    pub dp_codec: CodecSpec,
    /// Dataset selector: "markov" | "embedded" | "qnli" | "cola".
    pub dataset: String,
    pub n_examples: usize,
    /// Run boundary compression through the HLO (Pallas) artifacts
    /// instead of the native rust codec.
    pub hlo_codec: bool,
}

impl TrainConfig {
    pub fn defaults(model: &str) -> Self {
        TrainConfig {
            model: model.to_string(),
            artifacts_dir: "artifacts".to_string(),
            compression: CodecSpec::fp32(),
            stochastic_rounding: false,
            m_bits: None,
            store: "mem".to_string(),
            epochs: 4,
            n_micro: 4,
            lr: 1e-3,
            warmup_steps: 20,
            total_steps: usize::MAX,
            seed: 0,
            shuffle_every_epoch: true,
            bandwidth_bps: 1e9,
            latency_s: 1e-4,
            schedule: Schedule::GPipe,
            executor: Executor::Sim,
            workers: 4,
            dp_degree: 1,
            dp_codec: CodecSpec::fp32(),
            dataset: "markov".to_string(),
            n_examples: 64,
            hlo_codec: false,
        }
    }

    pub fn from_cli(cli: &Cli) -> Result<Self> {
        let mut c = Self::defaults(&cli.str("model", "tiny"));
        c.artifacts_dir = cli.str("artifacts", "artifacts");
        c.compression = CodecSpec::parse(&cli.str("compression", "fp32"))?;
        c.stochastic_rounding = cli.bool("stochastic");
        c.m_bits = match cli.usize("m-bits", 0)? {
            0 => None,
            b => Some(b as u8),
        };
        c.store = cli.str("store", "mem");
        c.epochs = cli.usize("epochs", c.epochs)?;
        c.n_micro = cli.usize("n-micro", c.n_micro)?;
        c.lr = cli.f64("lr", c.lr)?;
        c.warmup_steps = cli.usize("warmup", c.warmup_steps)?;
        c.total_steps = cli.usize("steps", c.total_steps)?;
        c.seed = cli.usize("seed", 0)? as u64;
        c.shuffle_every_epoch = !cli.bool("shuffle-once");
        c.bandwidth_bps = parse_bandwidth(&cli.str("bandwidth", "1gbps"))?;
        c.latency_s = cli.f64("latency-ms", 0.1)? / 1e3;
        c.schedule = Schedule::parse(&cli.str("schedule", "gpipe"))?;
        c.executor = Executor::parse(&cli.str("executor", "sim"))?;
        c.workers = cli.usize("workers", c.workers)?;
        c.dp_degree = cli.usize("dp", 1)?;
        c.dp_codec = match cli.flags.get("dp-codec") {
            Some(spec) => CodecSpec::parse(spec)?,
            // legacy shorthand: --dp-bits B = error-compensated B-bit
            // DirectQ, the paper's "QuantizedAdam" regime
            None => match cli.usize("dp-bits", 0)? {
                0 => CodecSpec::fp32(),
                b => CodecSpec::parse(&format!("ef:directq:fw{b}bw{b}"))?,
            },
        };
        c.dataset = cli.str("dataset", "markov");
        c.n_examples = cli.usize("examples", c.n_examples)?;
        c.hlo_codec = cli.bool("hlo-codec");
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse_args(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn cli_parsing() {
        let c = cli("train --model small --lr 0.001 --shuffle-once --steps 100");
        assert_eq!(c.positional, vec!["train"]);
        assert_eq!(c.str("model", "x"), "small");
        assert_eq!(c.f64("lr", 0.0).unwrap(), 0.001);
        assert!(c.bool("shuffle-once"));
        assert!(!c.bool("nope"));
        assert_eq!(c.usize("steps", 0).unwrap(), 100);
    }

    #[test]
    fn bandwidth_parsing() {
        assert_eq!(parse_bandwidth("10gbps").unwrap(), 10e9);
        assert_eq!(parse_bandwidth("500Mbps").unwrap(), 500e6);
        assert_eq!(parse_bandwidth("12345").unwrap(), 12345.0);
        assert!(parse_bandwidth("fast").is_err());
    }

    #[test]
    fn train_config_from_cli() {
        let c = TrainConfig::from_cli(&cli(
            "--model tiny --compression aqsgd:fw2bw4 --bandwidth 100mbps --dp 4 --dp-bits 4 --m-bits 8",
        ))
        .unwrap();
        assert_eq!(c.compression, CodecSpec::aqsgd(2, 4));
        assert_eq!(c.bandwidth_bps, 100e6);
        assert_eq!(c.dp_degree, 4);
        // --dp-bits is shorthand for the error-compensated DirectQ regime
        assert_eq!(c.dp_codec, CodecSpec::parse("ef:directq:fw4bw4").unwrap());
        assert_eq!(c.m_bits, Some(8));
        assert_eq!(c.executor, Executor::Sim); // default
    }

    #[test]
    fn dp_codec_from_cli() {
        let c = TrainConfig::from_cli(&cli("--dp 2 --dp-codec ef:directq:fw2bw2")).unwrap();
        assert_eq!(c.dp_codec, CodecSpec::parse("ef:directq:fw2bw2").unwrap());
        // explicit --dp-codec wins over the shorthand
        let c =
            TrainConfig::from_cli(&cli("--dp 2 --dp-codec fp32 --dp-bits 4")).unwrap();
        assert_eq!(c.dp_codec, CodecSpec::fp32());
        // default is uncompressed exchange
        assert_eq!(TrainConfig::from_cli(&cli("--dp 2")).unwrap().dp_codec, CodecSpec::fp32());
        assert!(TrainConfig::from_cli(&cli("--dp 2 --dp-codec nope")).is_err());
        assert!(TrainConfig::from_cli(&cli("--dp 2 --dp-bits 9")).is_err());
    }

    #[test]
    fn executor_switch_from_cli() {
        let c = TrainConfig::from_cli(&cli("--executor Threads --schedule 1F1B")).unwrap();
        assert_eq!(c.executor, Executor::Threads);
        assert_eq!(c.schedule, Schedule::OneFOneB);
        assert!(TrainConfig::from_cli(&cli("--executor gpu")).is_err());
        let c = TrainConfig::from_cli(&cli("--executor events --workers 2")).unwrap();
        assert_eq!(c.executor, Executor::Events);
        assert_eq!(c.workers, 2);
        // pool size defaults sanely when --workers is omitted
        assert_eq!(TrainConfig::from_cli(&cli("--executor events")).unwrap().workers, 4);
        assert!(TrainConfig::from_cli(&cli("--workers four")).is_err());
    }
}
