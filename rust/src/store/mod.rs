//! Per-example activation message-buffer stores (`m(ξ)` in Algorithm 1).
//!
//! The paper (§3.3, App. G) stores ~1 TB of buffers in host memory or SSD
//! and hides the load/update latency behind forward compute. Here a store
//! holds one fixed-size f32 record per (boundary, example):
//!   * `MemStore`  — flat in-memory slabs
//!   * `DiskStore` — one file per boundary, offset-addressed records (the
//!      SSD-offload path; App. G's throughput comparison uses it)
//!   * `QuantizedMemStore` — stores records as b-bit codes (paper Fig.
//!      9e/f "mz" ablation: buffers kept in low precision)
//! plus a `Prefetcher` that overlaps the next record fetch with compute.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::mpsc;

use crate::util::error::Result;

use crate::codec::pack;
use crate::codec::quantizer::{Rounding, UniformQuantizer};
use crate::util::Rng;

/// Key: (boundary index, example id).
pub type Key = (u32, u64);

pub trait ActivationStore: Send {
    /// Fetch the buffer for `key` into `out` (resized). Returns false if
    /// the example has never been stored (first visit).
    fn get(&mut self, key: Key, out: &mut Vec<f32>) -> bool;
    fn put(&mut self, key: Key, value: &[f32]);
    fn contains(&self, key: Key) -> bool;
    /// Total bytes resident (memory or disk).
    fn resident_bytes(&self) -> u64;
    fn record_len(&self) -> usize;
}

// ---------------------------------------------------------------------------

pub struct MemStore {
    record_len: usize,
    map: HashMap<Key, Vec<f32>>,
}

impl MemStore {
    pub fn new(record_len: usize) -> Self {
        MemStore { record_len, map: HashMap::new() }
    }
}

impl ActivationStore for MemStore {
    fn get(&mut self, key: Key, out: &mut Vec<f32>) -> bool {
        match self.map.get(&key) {
            None => false,
            Some(v) => {
                out.clear();
                out.extend_from_slice(v);
                true
            }
        }
    }

    fn put(&mut self, key: Key, value: &[f32]) {
        assert_eq!(value.len(), self.record_len);
        // overwrite in place on revisit: the steady-state codec path
        // (every step after the first epoch) must not touch the allocator
        match self.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.get_mut().copy_from_slice(value);
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value.to_vec());
            }
        }
    }

    fn contains(&self, key: Key) -> bool {
        self.map.contains_key(&key)
    }

    fn resident_bytes(&self) -> u64 {
        (self.map.len() * self.record_len * 4) as u64
    }

    fn record_len(&self) -> usize {
        self.record_len
    }
}

// ---------------------------------------------------------------------------

/// Low-precision buffer store: keeps `m(ξ)` as b-bit codes + scale
/// (Fig. 9e/f). Reads dequantize; writes re-quantize (deterministic
/// rounding so both boundary sides stay identical).
pub struct QuantizedMemStore {
    record_len: usize,
    quant: UniformQuantizer,
    map: HashMap<Key, (Vec<u8>, f32)>,
    rng: Rng,
    /// per-call code scratch, reused (steady-state puts/gets are
    /// allocation-free like `MemStore`'s)
    codes: Vec<u8>,
}

impl QuantizedMemStore {
    pub fn new(record_len: usize, bits: u8) -> Self {
        QuantizedMemStore {
            record_len,
            quant: UniformQuantizer::new(bits, Rounding::Nearest),
            map: HashMap::new(),
            rng: Rng::new(0),
            codes: Vec::new(),
        }
    }
}

impl ActivationStore for QuantizedMemStore {
    fn get(&mut self, key: Key, out: &mut Vec<f32>) -> bool {
        match self.map.get(&key) {
            None => false,
            Some((packed, scale)) => {
                self.codes.resize(self.record_len, 0);
                pack::unpack_into(packed, self.quant.bits, &mut self.codes);
                out.clear();
                out.resize(self.record_len, 0.0);
                self.quant.decode(&self.codes, *scale, out);
                true
            }
        }
    }

    fn put(&mut self, key: Key, value: &[f32]) {
        assert_eq!(value.len(), self.record_len);
        self.codes.resize(value.len(), 0);
        let scale = self.quant.encode(value, &mut self.codes, &mut self.rng);
        match self.map.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                let (packed, s) = e.get_mut();
                pack::pack_into(&self.codes, self.quant.bits, packed);
                *s = scale;
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert((pack::pack(&self.codes, self.quant.bits), scale));
            }
        }
    }

    fn contains(&self, key: Key) -> bool {
        self.map.contains_key(&key)
    }

    fn resident_bytes(&self) -> u64 {
        self.map
            .values()
            .map(|(p, _)| p.len() as u64 + 4)
            .sum()
    }

    fn record_len(&self) -> usize {
        self.record_len
    }
}

// ---------------------------------------------------------------------------

/// File-backed store: one sparse file per boundary, record-addressed by
/// example id (the paper's SSD offload). A one-byte presence bitmap rides
/// in memory.
pub struct DiskStore {
    record_len: usize,
    dir: PathBuf,
    files: HashMap<u32, File>,
    present: HashMap<Key, ()>,
    bytes_written: u64,
}

impl DiskStore {
    pub fn new(dir: impl Into<PathBuf>, record_len: usize) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(DiskStore {
            record_len,
            dir,
            files: HashMap::new(),
            present: HashMap::new(),
            bytes_written: 0,
        })
    }

    fn file(&mut self, boundary: u32) -> Result<&mut File> {
        if !self.files.contains_key(&boundary) {
            let path = self.dir.join(format!("boundary{boundary}.m"));
            let f = OpenOptions::new().read(true).write(true).create(true).open(path)?;
            self.files.insert(boundary, f);
        }
        Ok(self.files.get_mut(&boundary).unwrap())
    }

    fn offset(&self, example: u64) -> u64 {
        example * self.record_len as u64 * 4
    }
}

impl ActivationStore for DiskStore {
    fn get(&mut self, key: Key, out: &mut Vec<f32>) -> bool {
        if !self.present.contains_key(&key) {
            return false;
        }
        let off = self.offset(key.1);
        let n = self.record_len;
        let f = self.file(key.0).expect("open store file");
        f.seek(SeekFrom::Start(off)).expect("seek");
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes).expect("read record");
        out.clear();
        out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])));
        true
    }

    fn put(&mut self, key: Key, value: &[f32]) {
        assert_eq!(value.len(), self.record_len);
        let off = self.offset(key.1);
        let f = self.file(key.0).expect("open store file");
        f.seek(SeekFrom::Start(off)).expect("seek");
        let mut bytes = Vec::with_capacity(value.len() * 4);
        for v in value {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&bytes).expect("write record");
        self.present.insert(key, ());
        self.bytes_written += bytes.len() as u64;
    }

    fn contains(&self, key: Key) -> bool {
        self.present.contains_key(&key)
    }

    fn resident_bytes(&self) -> u64 {
        (self.present.len() * self.record_len * 4) as u64
    }

    fn record_len(&self) -> usize {
        self.record_len
    }
}

// ---------------------------------------------------------------------------

/// Prefetcher: a worker thread that fetches the next examples' buffers
/// while the caller computes (the §3.3 "hide m(ξ) loads behind the
/// forward pass" optimization). Generic over any `ActivationStore`.
pub struct Prefetcher {
    req_tx: mpsc::Sender<Vec<Key>>,
    resp_rx: mpsc::Receiver<Vec<(Key, Option<Vec<f32>>)>>,
    handle: Option<std::thread::JoinHandle<Box<dyn ActivationStore>>>,
}

impl Prefetcher {
    pub fn new(mut store: Box<dyn ActivationStore>) -> Self {
        let (req_tx, req_rx) = mpsc::channel::<Vec<Key>>();
        let (resp_tx, resp_rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            while let Ok(keys) = req_rx.recv() {
                if keys.is_empty() {
                    break; // shutdown signal
                }
                let mut out = Vec::with_capacity(keys.len());
                for k in keys {
                    let mut buf = Vec::new();
                    let hit = store.get(k, &mut buf);
                    out.push((k, hit.then_some(buf)));
                }
                if resp_tx.send(out).is_err() {
                    break;
                }
            }
            store
        });
        Prefetcher { req_tx, resp_rx, handle: Some(handle) }
    }

    /// Kick off an async fetch of `keys`.
    pub fn request(&self, keys: Vec<Key>) {
        assert!(!keys.is_empty());
        self.req_tx.send(keys).expect("prefetcher alive");
    }

    /// Collect a previously requested batch (blocking).
    pub fn collect(&self) -> Vec<(Key, Option<Vec<f32>>)> {
        self.resp_rx.recv().expect("prefetcher alive")
    }

    /// Shut down and recover the store (so puts can continue inline).
    pub fn into_store(mut self) -> Box<dyn ActivationStore> {
        let _ = self.req_tx.send(Vec::new());
        self.handle.take().unwrap().join().expect("prefetcher join")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(store: &mut dyn ActivationStore) {
        let v: Vec<f32> = (0..store.record_len()).map(|i| i as f32 * 0.5 - 3.0).collect();
        let key = (0u32, 7u64);
        let mut out = Vec::new();
        assert!(!store.get(key, &mut out));
        assert!(!store.contains(key));
        store.put(key, &v);
        assert!(store.contains(key));
        assert!(store.get(key, &mut out));
        assert_eq!(out.len(), v.len());
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() < 1e-6);
        }
        // overwrite
        let v2: Vec<f32> = v.iter().map(|x| x * 2.0).collect();
        store.put(key, &v2);
        store.get(key, &mut out);
        assert!((out[4] - v2[4]).abs() < 1e-6);
    }

    #[test]
    fn mem_store_roundtrip() {
        roundtrip(&mut MemStore::new(64));
    }

    #[test]
    fn disk_store_roundtrip() {
        let dir = std::env::temp_dir().join(format!("aqsgd_store_test_{}", std::process::id()));
        roundtrip(&mut DiskStore::new(&dir, 64).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn disk_store_many_examples() {
        let dir = std::env::temp_dir().join(format!("aqsgd_store_many_{}", std::process::id()));
        let mut s = DiskStore::new(&dir, 16).unwrap();
        for ex in 0..100u64 {
            let v: Vec<f32> = (0..16).map(|i| (ex * 16 + i) as f32).collect();
            s.put((1, ex), &v);
        }
        let mut out = Vec::new();
        assert!(s.get((1, 42), &mut out));
        assert_eq!(out[0], 42.0 * 16.0);
        assert_eq!(s.resident_bytes(), 100 * 16 * 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quantized_store_bounded_error() {
        let mut s = QuantizedMemStore::new(128, 8);
        let mut rng = Rng::new(3);
        let v: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        s.put((0, 0), &v);
        let mut out = Vec::new();
        assert!(s.get((0, 0), &mut out));
        let scale = UniformQuantizer::scale(&v);
        let bound = 2.0 * scale / 255.0;
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() <= bound);
        }
        // 8-bit store is ~4x smaller than f32
        assert!(s.resident_bytes() < 128 * 4 / 3);
    }

    #[test]
    fn prefetcher_overlaps() {
        let mut mem = MemStore::new(8);
        for ex in 0..10 {
            mem.put((0, ex), &[ex as f32; 8]);
        }
        let pf = Prefetcher::new(Box::new(mem));
        pf.request(vec![(0, 3), (0, 99)]);
        let got = pf.collect();
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].1.as_ref().unwrap()[0], 3.0);
        assert!(got[1].1.is_none()); // miss
        let mut store = pf.into_store();
        let mut out = Vec::new();
        assert!(store.get((0, 5), &mut out));
    }
}
