//! AQ-SGD: communication-efficient pipeline-parallel fine-tuning over slow
//! networks via activation-*delta* quantization — a full-system
//! reproduction of "Fine-tuning Language Models over Slow Networks using
//! Activation Quantization with Guarantees" (NeurIPS 2022).
//!
//! Architecture (see DESIGN.md): rust owns the coordinator — pipeline
//! schedule, network simulation, message buffers, codecs, data-parallel
//! gradient compression — and executes AOT-compiled JAX/Pallas compute
//! artifacts through the PJRT C API; python never runs at training time.

pub mod codec;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod testing;
pub mod util;
