//! Epoch sampling: deterministic shuffling into micro-batches whose
//! example ids key the AQ-SGD message buffers.
//!
//! The paper notes (§3.3) that re-shuffling every epoch costs buffer
//! migration under data parallelism; `shuffle_every_epoch=false`
//! reproduces the "shuffle once" optimization.

use super::{Dataset, Task};
use crate::util::Rng;

/// A micro-batch ready for the pipeline: `tokens` is row-major
/// [micro_batch, seq]; `targets` is the label vector (CLS) or the tokens
/// again (LM — shifting happens inside the loss artifact).
#[derive(Clone, Debug)]
pub struct Batch {
    pub example_ids: Vec<u64>,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub micro_batch: usize,
    pub seq: usize,
}

pub struct EpochSampler {
    order: Vec<usize>,
    micro_batch: usize,
    shuffle_every_epoch: bool,
    rng: Rng,
    epoch: usize,
}

impl EpochSampler {
    pub fn new(
        n_examples: usize,
        micro_batch: usize,
        seed: u64,
        shuffle_every_epoch: bool,
    ) -> Self {
        Self::subset((0..n_examples).collect(), micro_batch, seed, shuffle_every_epoch)
    }

    /// Sample only the given example indices of a (larger) parent
    /// dataset — a shard held as an index view, no example cloning. The
    /// caller passes the *parent* to [`epoch_batches`](Self::epoch_batches);
    /// only the listed rows are ever visited.
    pub fn subset(
        indices: Vec<usize>,
        micro_batch: usize,
        seed: u64,
        shuffle_every_epoch: bool,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut order = indices;
        rng.shuffle(&mut order);
        EpochSampler { order, micro_batch, shuffle_every_epoch, rng, epoch: 0 }
    }

    /// Micro-batches of one epoch (drops the ragged tail, like the paper's
    /// fixed-shape training). Advances the shuffle state.
    pub fn epoch_batches(&mut self, data: &Dataset) -> Vec<Batch> {
        if self.epoch > 0 && self.shuffle_every_epoch {
            self.rng.shuffle(&mut self.order);
        }
        self.epoch += 1;
        let b = self.micro_batch;
        let seq = data.examples.first().map(|e| e.tokens.len()).unwrap_or(0);
        self.order
            .chunks_exact(b)
            .map(|chunk| {
                let mut tokens = Vec::with_capacity(b * seq);
                let mut targets = Vec::new();
                let mut ids = Vec::with_capacity(b);
                for &i in chunk {
                    let e = &data.examples[i];
                    tokens.extend_from_slice(&e.tokens);
                    ids.push(e.id);
                    if data.task == Task::Cls {
                        targets.push(e.label);
                    }
                }
                if data.task == Task::Lm {
                    targets = tokens.clone();
                }
                Batch { example_ids: ids, tokens, targets, micro_batch: b, seq }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lm::markov_corpus;

    #[test]
    fn batches_cover_epoch_once() {
        let d = markov_corpus(64, 16, 40, 1);
        let mut s = EpochSampler::new(d.len(), 4, 0, true);
        let batches = s.epoch_batches(&d);
        assert_eq!(batches.len(), 10);
        let mut seen: Vec<u64> = batches.iter().flat_map(|b| b.example_ids.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
        for b in &batches {
            assert_eq!(b.tokens.len(), 4 * 16);
            assert_eq!(b.targets.len(), 4 * 16); // LM: targets == tokens
        }
    }

    #[test]
    fn shuffle_once_keeps_order() {
        let d = markov_corpus(64, 16, 32, 1);
        let mut s = EpochSampler::new(d.len(), 4, 7, false);
        let e1: Vec<u64> = s.epoch_batches(&d).iter().flat_map(|b| b.example_ids.clone()).collect();
        let e2: Vec<u64> = s.epoch_batches(&d).iter().flat_map(|b| b.example_ids.clone()).collect();
        assert_eq!(e1, e2);

        let mut s2 = EpochSampler::new(d.len(), 4, 7, true);
        let f1: Vec<u64> =
            s2.epoch_batches(&d).iter().flat_map(|b| b.example_ids.clone()).collect();
        let f2: Vec<u64> =
            s2.epoch_batches(&d).iter().flat_map(|b| b.example_ids.clone()).collect();
        assert_eq!(f1, e1); // same seed, same first epoch
        assert_ne!(f1, f2);
    }

    #[test]
    fn subset_visits_only_its_indices() {
        let d = markov_corpus(64, 16, 40, 1);
        let view: Vec<usize> = vec![3, 9, 11, 20, 21, 22, 30, 35];
        let mut s = EpochSampler::subset(view.clone(), 4, 5, true);
        let batches = s.epoch_batches(&d);
        assert_eq!(batches.len(), 2, "8 indices at micro-batch 4");
        let mut seen: Vec<u64> = batches.iter().flat_map(|b| b.example_ids.clone()).collect();
        seen.sort_unstable();
        // markov_corpus ids equal positions, so the view maps through
        assert_eq!(seen, view.iter().map(|&i| i as u64).collect::<Vec<_>>());
    }

    #[test]
    fn cls_targets_are_labels() {
        let d = crate::data::cls::qnli_like(64, 16, 20, 2);
        let mut s = EpochSampler::new(d.len(), 5, 0, true);
        let batches = s.epoch_batches(&d);
        assert_eq!(batches[0].targets.len(), 5);
        assert!(batches[0].targets.iter().all(|&l| l == 0 || l == 1));
    }
}
