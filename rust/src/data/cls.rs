//! Synthetic sequence-classification tasks (the QNLI / CoLA stand-ins).
//!
//! The label is a (noisy) function of pattern tokens planted in the
//! sequence, so a transformer with a pooled head can reach high accuracy
//! while the task remains non-trivial at initialization.

use super::{Dataset, Example, Task};
use crate::util::Rng;

/// "QNLI-like": balanced binary task. Class-1 sequences contain a planted
/// marker bigram with probability `1 - noise`, class-0 sequences contain
/// a decoy bigram.
pub fn qnli_like(vocab: usize, seq: usize, n_examples: usize, seed: u64) -> Dataset {
    synthetic_cls(vocab, seq, n_examples, seed, 0.5, 0.05)
}

/// "CoLA-like": imbalanced (70/30, like acceptability judgments) and
/// noisier.
pub fn cola_like(vocab: usize, seq: usize, n_examples: usize, seed: u64) -> Dataset {
    synthetic_cls(vocab, seq, n_examples, seed, 0.7, 0.15)
}

pub fn synthetic_cls(
    vocab: usize,
    seq: usize,
    n_examples: usize,
    seed: u64,
    pos_frac: f64,
    noise: f64,
) -> Dataset {
    assert!(vocab >= 8 && seq >= 4);
    let mut rng = Rng::new(seed);
    let marker = [2i32, 3];
    let decoy = [4i32, 5];
    let mut examples = Vec::with_capacity(n_examples);
    for id in 0..n_examples {
        let label = if rng.next_f64() < pos_frac { 1 } else { 0 };
        let mut tokens: Vec<i32> =
            (0..seq).map(|_| 6 + rng.below(vocab - 6) as i32).collect();
        // plant the class pattern (flip under label noise)
        let planted = if rng.next_f64() < noise { 1 - label } else { label };
        let pat = if planted == 1 { marker } else { decoy };
        let pos = rng.below(seq - 1);
        tokens[pos] = pat[0];
        tokens[pos + 1] = pat[1];
        examples.push(Example { id: id as u64, tokens, label });
    }
    Dataset { examples, task: Task::Cls }
}

/// Dirichlet-style non-IID client split for the split-learning scenario
/// (paper App. H.6: 16 clients, concentration 0.5). Lower `alpha` means
/// more skew. Returns per-client example-index lists.
pub fn dirichlet_split(
    dataset: &Dataset,
    n_clients: usize,
    alpha: f64,
    seed: u64,
) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(seed);
    let n_classes = dataset.examples.iter().map(|e| e.label).max().unwrap_or(0) as usize + 1;
    let mut shards = vec![Vec::new(); n_clients];
    // per class, draw client proportions ~ Dirichlet(alpha) via gamma draws
    for class in 0..n_classes {
        let idxs: Vec<usize> = dataset
            .examples
            .iter()
            .enumerate()
            .filter(|(_, e)| e.label as usize == class)
            .map(|(i, _)| i)
            .collect();
        let mut weights: Vec<f64> = (0..n_clients).map(|_| gamma_draw(alpha, &mut rng)).collect();
        let total: f64 = weights.iter().sum::<f64>().max(1e-12);
        for w in weights.iter_mut() {
            *w /= total;
        }
        let mut cum = 0.0;
        let mut boundaries = Vec::with_capacity(n_clients);
        for w in &weights {
            cum += w;
            boundaries.push((cum * idxs.len() as f64).round() as usize);
        }
        let mut lo = 0usize;
        for (c, &hi) in boundaries.iter().enumerate() {
            let hi = hi.min(idxs.len());
            for &i in &idxs[lo..hi] {
                shards[c].push(i);
            }
            lo = hi;
        }
    }
    shards
}

/// Marsaglia–Tsang-ish gamma sampler (shape `a`, scale 1). Adequate for
/// Dirichlet splitting (statistical fidelity, not crypto).
fn gamma_draw(a: f64, rng: &mut Rng) -> f64 {
    if a < 1.0 {
        // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
        let u = rng.next_f64().max(1e-12);
        return gamma_draw(a + 1.0, rng) * u.powf(1.0 / a);
    }
    let d = a - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal() as f64;
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64().max(1e-12);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_patterns_mostly() {
        let d = qnli_like(256, 32, 500, 1);
        let mut correct = 0;
        for e in &d.examples {
            let has_marker = e.tokens.windows(2).any(|w| w == [2, 3]);
            if (e.label == 1) == has_marker {
                correct += 1;
            }
        }
        // noise 5% -> ~95% consistency
        assert!(correct > 440, "{correct}/500");
    }

    #[test]
    fn cola_is_imbalanced() {
        let d = cola_like(256, 32, 1000, 2);
        let pos = d.examples.iter().filter(|e| e.label == 1).count();
        assert!(pos > 600 && pos < 800, "{pos}");
    }

    #[test]
    fn dirichlet_split_covers_all_and_skews() {
        let d = qnli_like(64, 16, 400, 3);
        let shards = dirichlet_split(&d, 8, 0.5, 4);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 400);
        // non-IID: client class mixes differ
        let frac_pos = |s: &Vec<usize>| {
            if s.is_empty() {
                return 0.5;
            }
            s.iter().filter(|&&i| d.examples[i].label == 1).count() as f64 / s.len() as f64
        };
        let fracs: Vec<f64> = shards.iter().map(frac_pos).collect();
        let spread = fracs.iter().cloned().fold(0.0f64, f64::max)
            - fracs.iter().cloned().fold(1.0f64, f64::min);
        assert!(spread > 0.1, "spread {spread}, fracs {fracs:?}");
    }
}
