//! Language-modeling corpora.
//!
//! `markov_corpus` — an order-2 Markov chain over the vocabulary with a
//! sparse, seeded transition structure: enough statistical structure for
//! a small transformer to make steady progress (our "WikiText2-like" /
//! "arXiv-like" stand-ins; different seeds give different "datasets").
//!
//! `embedded_corpus` — a real public-domain English text (byte-level),
//! exercising the same code path on natural data.

use super::{Dataset, Example, Task};
use crate::util::Rng;

/// Opening of Jane Austen's "Pride and Prejudice" (public domain):
/// natural English for the byte-level LM path.
pub const EMBEDDED_TEXT: &str = "It is a truth universally acknowledged, that a single man in \
possession of a good fortune, must be in want of a wife. However little known the feelings or \
views of such a man may be on his first entering a neighbourhood, this truth is so well fixed \
in the minds of the surrounding families, that he is considered the rightful property of some \
one or other of their daughters. My dear Mr. Bennet, said his lady to him one day, have you \
heard that Netherfield Park is let at last? Mr. Bennet replied that he had not. But it is, \
returned she; for Mrs. Long has just been here, and she told me all about it. Mr. Bennet made \
no answer. Do you not want to know who has taken it? cried his wife impatiently. You want to \
tell me, and I have no objection to hearing it. This was invitation enough. Why, my dear, you \
must know, Mrs. Long says that Netherfield is taken by a young man of large fortune from the \
north of England; that he came down on Monday in a chaise and four to see the place, and was \
so much delighted with it, that he agreed with Mr. Morris immediately; that he is to take \
possession before Michaelmas, and some of his servants are to be in the house by the end of \
next week. What is his name? Bingley. Is he married or single? Oh! Single, my dear, to be \
sure! A single man of large fortune; four or five thousand a year. What a fine thing for our \
girls! How so? How can it affect them? My dear Mr. Bennet, replied his wife, how can you be \
so tiresome! You must know that I am thinking of his marrying one of them. Is that his design \
in settling here? Design! Nonsense, how can you talk so! But it is very likely that he may \
fall in love with one of them, and therefore you must visit him as soon as he comes. I see no \
occasion for that. You and the girls may go, or you may send them by themselves, which perhaps \
will be still better, for as you are as handsome as any of them, Mr. Bingley may like you the \
best of the party. My dear, you flatter me. I certainly have had my share of beauty, but I do \
not pretend to be anything extraordinary now. When a woman has five grown-up daughters, she \
ought to give over thinking of her own beauty. In such cases, a woman has not often much \
beauty to think of. But, my dear, you must indeed go and see Mr. Bingley when he comes into \
the neighbourhood. It is more than I engage for, I assure you.";

/// Token stream from a seeded order-2 Markov chain over `vocab` symbols.
pub fn markov_stream(vocab: usize, n_tokens: usize, seed: u64) -> Vec<i32> {
    assert!(vocab >= 4);
    let mut rng = Rng::new(seed);
    // each (prev2, prev1) context maps to a small candidate set derived
    // from a hash (no vocab^2 table); candidate 0 is picked with prob 1/2,
    // 1 with 1/4, ... (geometric), and candidate tokens are Zipf-skewed
    // toward small ids — low-entropy, learnable structure.
    let branch = 4u64;
    let zipf = |h: u64| -> u64 {
        let u = (h >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
        ((u * u) * vocab as f64) as u64 % vocab as u64
    };
    let mut out = Vec::with_capacity(n_tokens);
    let (mut p2, mut p1) = (0u64, 1u64);
    for _ in 0..n_tokens {
        let ctx = p2
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(p1)
            .wrapping_mul(seed | 1)
            .wrapping_add(0xD1B54A32D192ED03);
        // geometric choice over the candidate set
        let mut j = 0u64;
        while j + 1 < branch && rng.next_u64() % 2 == 0 {
            j += 1;
        }
        let h = ctx
            .wrapping_add(j.wrapping_mul(0x2545F4914F6CDD1D))
            .wrapping_mul(0x9E3779B97F4A7C15);
        let tok = zipf(h ^ (h >> 29));
        out.push(tok as i32);
        p2 = p1;
        p1 = tok;
    }
    out
}

/// Chop a token stream into non-overlapping `seq`-length examples with
/// stable ids.
pub fn stream_to_dataset(stream: &[i32], seq: usize) -> Dataset {
    let examples = stream
        .chunks_exact(seq)
        .enumerate()
        .map(|(i, w)| Example { id: i as u64, tokens: w.to_vec(), label: 0 })
        .collect();
    Dataset { examples, task: Task::Lm }
}

/// "WikiText2-like": Markov corpus with `n_examples` sequences.
pub fn markov_corpus(vocab: usize, seq: usize, n_examples: usize, seed: u64) -> Dataset {
    let stream = markov_stream(vocab, seq * n_examples, seed);
    stream_to_dataset(&stream, seq)
}

/// Byte-level dataset over the embedded real text, repeated/windowed to
/// `n_examples` sequences (vocab must be >= 256).
pub fn embedded_corpus(seq: usize, n_examples: usize) -> Dataset {
    let bytes: Vec<i32> = EMBEDDED_TEXT.bytes().map(|b| b as i32).collect();
    let mut examples = Vec::with_capacity(n_examples);
    let stride = 17; // overlapping windows so n_examples can exceed len/seq
    for i in 0..n_examples {
        let start = (i * stride) % bytes.len().saturating_sub(seq).max(1);
        let mut tokens: Vec<i32> = Vec::with_capacity(seq);
        let mut p = start;
        while tokens.len() < seq {
            tokens.push(bytes[p % bytes.len()]);
            p += 1;
        }
        examples.push(Example { id: i as u64, tokens, label: 0 });
    }
    Dataset { examples, task: Task::Lm }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markov_is_deterministic_and_low_entropy() {
        let a = markov_stream(64, 4096, 7);
        let b = markov_stream(64, 4096, 7);
        assert_eq!(a, b);
        let c = markov_stream(64, 4096, 8);
        assert_ne!(a, c);
        // unigram distribution is skewed vs uniform: top token count well
        // above vocab-uniform expectation
        let mut counts = vec![0usize; 64];
        for &t in &a {
            counts[t as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 2 * a.len() / 64, "max {max}");
    }

    #[test]
    fn dataset_shapes() {
        let d = markov_corpus(128, 32, 10, 1);
        assert_eq!(d.len(), 10);
        assert!(d.examples.iter().all(|e| e.tokens.len() == 32));
        assert!(d.examples.iter().all(|e| e.tokens.iter().all(|&t| t >= 0 && t < 128)));
        // ids stable and unique
        let ids: Vec<u64> = d.examples.iter().map(|e| e.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn embedded_corpus_bytes() {
        let d = embedded_corpus(64, 20);
        assert_eq!(d.len(), 20);
        assert!(d.examples.iter().all(|e| e.tokens.iter().all(|&t| (0..256).contains(&t))));
    }

    #[test]
    fn split_eval() {
        let d = markov_corpus(64, 16, 100, 3);
        let (train, eval) = d.split_eval(0.1);
        assert_eq!(train.len(), 90);
        assert_eq!(eval.len(), 10);
    }
}
