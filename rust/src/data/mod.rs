//! Synthetic + embedded workloads standing in for the paper's datasets
//! (WikiText2 / arXiv abstracts for language modeling, QNLI / CoLA for
//! sequence classification — see DESIGN.md §3 substitutions).

pub mod cls;
pub mod lm;
pub mod sampler;

pub use sampler::{Batch, EpochSampler};

/// A supervised example: token sequence + target (LM: the sequence
/// itself, shifted inside the loss; CLS: a label).
#[derive(Clone, Debug)]
pub struct Example {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub label: i32, // CLS only; ignored for LM
}

/// Task kind, mirroring the model config.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Lm,
    Cls,
}

impl Task {
    pub fn parse(s: &str) -> crate::util::error::Result<Self> {
        match s {
            "lm" => Ok(Task::Lm),
            "cls" => Ok(Task::Cls),
            _ => crate::bail!("unknown task {s:?}"),
        }
    }
}

/// A dataset: fixed example set with stable ids (AQ-SGD's buffers are
/// keyed by example id across epochs).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub examples: Vec<Example>,
    pub task: Task,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.examples.len()
    }
    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Split off the last `frac` as a held-out evaluation set.
    pub fn split_eval(mut self, frac: f64) -> (Dataset, Dataset) {
        let n_eval = ((self.examples.len() as f64 * frac) as usize).max(1);
        let n_train = self.examples.len().saturating_sub(n_eval);
        let eval = self.examples.split_off(n_train);
        (
            Dataset { examples: self.examples, task: self.task },
            Dataset { examples: eval, task: self.task },
        )
    }
}
