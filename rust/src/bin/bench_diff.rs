//! `bench-diff` — compare a bench JSON report against a checked-in
//! baseline and fail on throughput regressions. The CI bench job runs:
//!
//! ```sh
//! cargo bench --bench bench_codec -- --quick --json bench.json
//! cargo run --release --bin bench-diff -- BENCH_BASELINE.json bench.json
//! ```
//!
//! Exit status 1 when any bench present in both files regressed by more
//! than `--max-regress` (default 0.25 = 25%): throughput benches compare
//! GB/s (`bytes_per_iter / mean_ns`), time-only benches compare ns/iter.
//! Benches present in only one file are reported but never fail the run
//! (a renamed bench should update `BENCH_BASELINE.json` in the same PR).

use std::process::ExitCode;

use aq_sgd::util::error::{Context, Result};
use aq_sgd::util::json::Json;

struct Entry {
    name: String,
    mean_ns: f64,
    bytes_per_iter: Option<f64>,
}

fn load(path: &str) -> Result<Vec<Entry>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
    let results = doc
        .get("results")
        .and_then(|r| r.as_array())
        .with_context(|| format!("{path}: no \"results\" array"))?;
    let mut out = Vec::with_capacity(results.len());
    for (i, r) in results.iter().enumerate() {
        let name = r
            .get("name")
            .and_then(|v| v.as_str())
            .with_context(|| format!("{path}: results[{i}] has no name"))?
            .to_string();
        let mean_ns = r
            .get("mean_ns")
            .and_then(|v| v.as_f64())
            .with_context(|| format!("{path}: results[{i}] has no mean_ns"))?;
        let bytes_per_iter = r.get("bytes_per_iter").and_then(|v| v.as_f64());
        out.push(Entry { name, mean_ns, bytes_per_iter });
    }
    Ok(out)
}

fn usage() -> ! {
    eprintln!(
        "usage: bench-diff <baseline.json> <current.json> [--max-regress <frac>]\n\
         exits 1 if any shared bench regressed by more than <frac> (default 0.25)"
    );
    std::process::exit(2)
}

fn run() -> Result<bool> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut max_regress = 0.25f64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--max-regress" => {
                let v = it.next().map(|s| s.as_str()).unwrap_or_else(|| usage());
                max_regress = v
                    .parse()
                    .map_err(|_| aq_sgd::err!("bad --max-regress value {v:?}"))?;
            }
            "--help" | "-h" => usage(),
            _ => paths.push(a.clone()),
        }
    }
    if paths.len() != 2 {
        usage();
    }
    let baseline = load(&paths[0])?;
    let current = load(&paths[1])?;

    let find = |name: &str| current.iter().find(|e| e.name == name);
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    println!(
        "{:<44} {:>12} {:>12} {:>8}  status",
        "bench", "baseline", "current", "delta"
    );
    for b in &baseline {
        let Some(c) = find(&b.name) else {
            println!("{:<44} {:>12} {:>12} {:>8}  MISSING in current", b.name, "-", "-", "-");
            continue;
        };
        compared += 1;
        // throughput when both sides carry payload bytes, ns otherwise;
        // `delta` is positive when current is worse than baseline
        let (base_s, cur_s, delta) = match (b.bytes_per_iter, c.bytes_per_iter) {
            (Some(bb), Some(cb)) => {
                let (bt, ct) = (bb / b.mean_ns, cb / c.mean_ns);
                (format!("{bt:.2} GB/s"), format!("{ct:.2} GB/s"), 1.0 - ct / bt)
            }
            _ => (
                format!("{:.0} ns", b.mean_ns),
                format!("{:.0} ns", c.mean_ns),
                c.mean_ns / b.mean_ns - 1.0,
            ),
        };
        let status = if delta > max_regress {
            regressions.push((b.name.clone(), delta));
            "REGRESSED"
        } else if delta < -max_regress {
            "improved"
        } else {
            "ok"
        };
        println!("{:<44} {:>12} {:>12} {:>7.1}%  {}", b.name, base_s, cur_s, delta * 100.0, status);
    }
    for c in &current {
        if !baseline.iter().any(|b| b.name == c.name) {
            println!("{:<44} {:>12} {:>12} {:>8}  NEW (no baseline)", c.name, "-", "-", "-");
        }
    }
    println!(
        "\ncompared {compared} benches against {} baseline entries \
         (threshold {:.0}%)",
        baseline.len(),
        max_regress * 100.0
    );
    if regressions.is_empty() {
        println!("no regressions beyond the threshold");
        Ok(true)
    } else {
        println!("{} regression(s):", regressions.len());
        for (name, delta) in &regressions {
            println!("  {name}: {:.1}% worse than baseline", delta * 100.0);
        }
        Ok(false)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench-diff: {e}");
            ExitCode::FAILURE
        }
    }
}
