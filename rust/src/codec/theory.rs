//! Theorem 3.1 constants: the quantization contraction factor c_Q, the
//! constant C, and the prescribed learning rate. Used by unit tests to
//! pin the paper's tightness remark (c_Q = 0 recovers vanilla SGD) and by
//! `examples/` to print the theoretical footprint of a run.

/// c_Q for the simple rounding quantizer of footnote 3:
/// `Q(x) = ||x|| * round(x/||x||)` stochastically -> c_Q = sqrt(d) / 2^b.
pub fn c_q(dim: usize, bits: u8) -> f64 {
    (dim as f64).sqrt() / (1u64 << bits) as f64
}

/// Smallest bit-width for which the theorem's `c_Q < sqrt(1/2)` condition
/// holds at dimension `dim` (footnote 3's 6/11/16-bit examples).
pub fn min_bits(dim: usize) -> u8 {
    for b in 1..=32u8 {
        if c_q(dim, b) < (0.5f64).sqrt() {
            return b;
        }
    }
    32
}

/// Lipschitz / bound constants of Assumption A1+A2.
#[derive(Clone, Copy, Debug)]
pub struct Constants {
    pub l_f: f64,      // Lipschitz constant of grad f
    pub l_fb: f64,     // Lipschitz constant of grad (f o b)
    pub ell_a: f64,    // Lipschitz constant of a
    pub c_a: f64,      // gradient bound of a
    pub c_fb: f64,     // gradient bound of f o b
    pub sigma2: f64,   // stochastic-gradient variance bound
    pub n_samples: usize,
}

impl Constants {
    /// C = 4 c_Q ell_a (1 + C_a) L_{f o b} N / sqrt(1 - 2 c_Q^2)
    pub fn big_c(&self, cq: f64) -> f64 {
        assert!(cq * cq < 0.5, "Theorem 3.1 requires c_Q < sqrt(1/2)");
        4.0 * cq * self.ell_a * (1.0 + self.c_a) * self.l_fb * self.n_samples as f64
            / (1.0 - 2.0 * cq * cq).sqrt()
    }

    /// gamma = 1 / (3 (3 L_f + C) sqrt(T))
    pub fn learning_rate(&self, cq: f64, t: usize) -> f64 {
        1.0 / (3.0 * (3.0 * self.l_f + self.big_c(cq)) * (t as f64).sqrt())
    }

    /// RHS of (3.1): the bound on (1/T) sum E||grad f||^2.
    pub fn convergence_bound(&self, cq: f64, t: usize, f_gap: f64) -> f64 {
        let c = self.big_c(cq);
        let extra = (cq * self.c_a * self.c_fb).powi(2);
        ((c + self.l_f) * f_gap + self.sigma2 + extra) / (t as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn consts() -> Constants {
        Constants {
            l_f: 1.0,
            l_fb: 1.0,
            ell_a: 1.0,
            c_a: 1.0,
            c_fb: 1.0,
            sigma2: 1.0,
            n_samples: 100,
        }
    }

    #[test]
    fn footnote3_bit_requirements() {
        // "6 bits suffice in a low-dimensional (~10^3), 11 bits in a
        //  high-dimensional (~10^6), 16 bits in a super-high (~10^9)"
        assert_eq!(min_bits(1_000), 6);
        assert_eq!(min_bits(1_000_000), 11);
        assert_eq!(min_bits(1_000_000_000), 16);
    }

    #[test]
    fn tightness_cq_zero_recovers_sgd() {
        let c = consts();
        assert_eq!(c.big_c(0.0), 0.0);
        // bound reduces to the vanilla-SGD form (L_f f_gap + sigma^2)/sqrt(T)
        let b = c.convergence_bound(0.0, 10_000, 2.0);
        assert!((b - (1.0 * 2.0 + 1.0) / 100.0).abs() < 1e-12);
    }

    #[test]
    fn bound_monotone_in_cq_and_t() {
        let c = consts();
        assert!(c.convergence_bound(0.1, 100, 1.0) < c.convergence_bound(0.5, 100, 1.0));
        assert!(c.convergence_bound(0.1, 10_000, 1.0) < c.convergence_bound(0.1, 100, 1.0));
        // O(1/sqrt(T)) rate: quadrupling T halves the bound
        let b1 = c.convergence_bound(0.1, 1_000, 1.0);
        let b4 = c.convergence_bound(0.1, 4_000, 1.0);
        assert!((b4 * 2.0 - b1).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn cq_condition_enforced() {
        consts().big_c(0.8); // > sqrt(1/2)
    }

    #[test]
    fn lr_decreases_with_t() {
        let c = consts();
        assert!(c.learning_rate(0.1, 10_000) < c.learning_rate(0.1, 100));
    }
}
