//! The wire codecs: everything that turns activations / gradients into
//! bytes on the (simulated) network.
//!
//! Semantics mirror `python/compile/kernels/ref.py` exactly — the uniform
//! b-bit scheme of the paper (§4.1): normalize into [-1, 1] by the
//! per-tensor max-abs `scale`, uniformly partition into `2^b` codes:
//!
//! ```text
//! code = clamp(floor((x / scale + 1) / 2 * levels + u), 0, levels)
//! deq  = (code / levels * 2 - 1) * scale
//! ```
//!
//! with `levels = 2^b - 1` and rounding offset `u` (0.5 = deterministic,
//! U[0,1) = stochastic/unbiased — the Theorem 3.1 assumption on Q).

pub mod delta;
pub mod f16;
pub mod pack;
pub mod quantizer;
pub mod theory;
pub mod topk;
pub mod tp;

pub use delta::AqState;
pub use quantizer::{Rounding, UniformQuantizer};

/// How each pipeline-boundary / data-parallel message is compressed.
///
/// `fw`/`bw` are the paper's "fwX bwY" bit-widths for forward activations
/// and backward activation-gradients.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    /// Paper baseline: everything in f32.
    Fp32,
    /// Appendix H.4: half-precision wire format (no quantization).
    Fp16,
    /// DirectQ (AC-GC / TinyScript): quantize activations themselves.
    DirectQ { fw_bits: u8, bw_bits: u8 },
    /// AQ-SGD: quantize activation *changes* against the message buffer;
    /// backward gradients are directly quantized (Algorithm 1 line 11).
    AqSgd { fw_bits: u8, bw_bits: u8 },
}

impl Compression {
    pub fn parse(s: &str) -> crate::util::error::Result<Self> {
        // forms: "fp32", "fp16", "directq:fw3bw6", "aqsgd:fw2bw4"
        let s = s.trim();
        let parse_bits = |spec: &str| -> crate::util::error::Result<(u8, u8)> {
            let spec = spec.trim();
            let rest = spec
                .strip_prefix("fw")
                .ok_or_else(|| crate::err!("bad bits spec {spec:?}"))?;
            let (fw, bw) = rest
                .split_once("bw")
                .ok_or_else(|| crate::err!("bad bits spec {spec:?}"))?;
            let (fw, bw): (u8, u8) = (fw.parse()?, bw.parse()?);
            // validate here so a bad spec fails with a clear parse error
            // instead of panicking later in UniformQuantizer::new
            for bits in [fw, bw] {
                crate::ensure!(
                    (1..=8).contains(&bits),
                    "bit-width {bits} out of range in {spec:?} (quantizers support 1..=8 bits)"
                );
            }
            Ok((fw, bw))
        };
        match s {
            "fp32" => Ok(Compression::Fp32),
            "fp16" => Ok(Compression::Fp16),
            _ => {
                if let Some(spec) = s.strip_prefix("directq:") {
                    let (fw_bits, bw_bits) = parse_bits(spec)?;
                    Ok(Compression::DirectQ { fw_bits, bw_bits })
                } else if let Some(spec) = s.strip_prefix("aqsgd:") {
                    let (fw_bits, bw_bits) = parse_bits(spec)?;
                    Ok(Compression::AqSgd { fw_bits, bw_bits })
                } else {
                    crate::bail!("unknown compression {s:?}")
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            Compression::Fp32 => "FP32".into(),
            Compression::Fp16 => "FP16".into(),
            Compression::DirectQ { fw_bits, bw_bits } => {
                format!("DirectQ fw{fw_bits} bw{bw_bits}")
            }
            Compression::AqSgd { fw_bits, bw_bits } => {
                format!("AQ-SGD fw{fw_bits} bw{bw_bits}")
            }
        }
    }

    /// Wire bytes for a forward boundary message of `n` f32 elements.
    ///
    /// AQ-SGD's first-epoch messages are full precision (Algorithm 1 line
    /// 5); pass `first_visit` accordingly.
    pub fn fw_wire_bytes(&self, n: usize, first_visit: bool) -> u64 {
        match self {
            Compression::Fp32 => 4 * n as u64,
            Compression::Fp16 => 2 * n as u64,
            Compression::DirectQ { fw_bits, .. } => quant_wire_bytes(n, *fw_bits),
            Compression::AqSgd { fw_bits, .. } => {
                if first_visit {
                    4 * n as u64
                } else {
                    quant_wire_bytes(n, *fw_bits)
                }
            }
        }
    }

    /// Wire bytes for a backward boundary message of `n` f32 elements.
    pub fn bw_wire_bytes(&self, n: usize) -> u64 {
        match self {
            Compression::Fp32 => 4 * n as u64,
            Compression::Fp16 => 2 * n as u64,
            Compression::DirectQ { bw_bits, .. }
            | Compression::AqSgd { bw_bits, .. } => quant_wire_bytes(n, *bw_bits),
        }
    }
}

/// Bytes on the wire for `n` b-bit codes + the f32 scale header.
pub fn quant_wire_bytes(n: usize, bits: u8) -> u64 {
    pack::packed_len(n, bits) as u64 + 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Compression::parse("fp32").unwrap(), Compression::Fp32);
        assert_eq!(
            Compression::parse("aqsgd:fw2bw4").unwrap(),
            Compression::AqSgd { fw_bits: 2, bw_bits: 4 }
        );
        assert_eq!(
            Compression::parse("directq:fw3bw6").unwrap(),
            Compression::DirectQ { fw_bits: 3, bw_bits: 6 }
        );
        assert!(Compression::parse("nope").is_err());
        assert!(Compression::parse("aqsgd:fw2").is_err());
    }

    #[test]
    fn parse_trims_whitespace() {
        assert_eq!(Compression::parse(" fp16 ").unwrap(), Compression::Fp16);
        assert_eq!(
            Compression::parse("aqsgd: fw2bw4 ").unwrap(),
            Compression::AqSgd { fw_bits: 2, bw_bits: 4 }
        );
    }

    #[test]
    fn parse_rejects_out_of_range_bits() {
        for spec in ["aqsgd:fw0bw0", "directq:fw9bw12", "aqsgd:fw4bw0", "directq:fw0bw4"] {
            let err = Compression::parse(spec).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{spec}: {err}");
        }
        // boundary widths still accepted
        assert!(Compression::parse("aqsgd:fw1bw8").is_ok());
    }

    #[test]
    fn wire_bytes_shapes() {
        // 4 bits: two codes per byte (+4B scale)
        assert_eq!(quant_wire_bytes(8, 4), 4 + 4);
        assert_eq!(quant_wire_bytes(9, 4), 5 + 4);
        // first AQ visit is full precision
        let c = Compression::AqSgd { fw_bits: 2, bw_bits: 4 };
        assert_eq!(c.fw_wire_bytes(100, true), 400);
        assert!(c.fw_wire_bytes(100, false) < 40);
        // fp16 halves
        assert_eq!(Compression::Fp16.fw_wire_bytes(100, false), 200);
    }
}
