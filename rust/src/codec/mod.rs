//! The wire codecs: everything that turns activations / gradients into
//! bytes on the (simulated) network.
//!
//! Quantizer semantics mirror `python/compile/kernels/ref.py` exactly —
//! the uniform b-bit scheme of the paper (§4.1): normalize into [-1, 1]
//! by the per-tensor max-abs `scale`, uniformly partition into `2^b`
//! codes:
//!
//! ```text
//! code = clamp(floor((x / scale + 1) / 2 * levels + u), 0, levels)
//! deq  = (code / levels * 2 - 1) * scale
//! ```
//!
//! with `levels = 2^b - 1` and rounding offset `u` (0.5 = deterministic,
//! U[0,1) = stochastic/unbiased — the Theorem 3.1 assumption on Q).
//!
//! # The codec API
//!
//! Every compression scheme is a [`BoundaryCodec`]: a stateful
//! encoder-or-decoder half that turns activations into self-describing
//! [`Frame`] wire messages and back. The two halves of a boundary share
//! *only* the `Frame` — Algorithm 2's sender/receiver replica symmetry is
//! enforced by construction, because the decoder can only reconstruct
//! from bytes the encoder actually emitted. Schemes are constructed
//! through [`registry`] spec strings (`"aqsgd:fw2bw4"`, `"topk:0.2@8"`,
//! `"ef:directq:fw4bw4"`, `"hybrid:aq2/topk0.2@8"`, ...); adding a
//! scheme means adding one self-contained codec file and one registry
//! arm, not enum surgery across the tree. The same codecs serve every
//! traffic class — forward activations, backward activation gradients,
//! and (via the `ef:` error-feedback wrapper and `net::plane`'s ring)
//! data-parallel model gradients.

pub mod delta;
pub mod ef;
pub mod f16;
pub mod frame;
pub mod hadamard;
pub mod lowrank;
pub mod pack;
pub mod par;
pub mod quantizer;
pub mod registry;
pub mod schemes;
pub mod theory;
pub mod tile;
pub mod topk;
pub mod tp;

pub use delta::{AqCodec, AqState};
pub use ef::EfCodec;
pub use frame::{Frame, FrameBuf, FrameView};
pub use hadamard::HadCodec;
pub use lowrank::LrCodec;
pub use tile::TileCodec;
pub use par::Workers;
pub use quantizer::{Rounding, UniformQuantizer};
pub use registry::{CodecSpec, SchemeSpec};

use crate::util::error::Result;

/// Probe statistics from the most recent `encode` call (Fig. 1b's
/// |delta| trace and Algorithm 1's first-visit accounting). Codecs with
/// no delta/buffer concept report `None` / `0`.
#[derive(Clone, Copy, Debug, Default)]
pub struct EncodeStats {
    /// mean |value actually quantized| (the delta for AQ-SGD; `None`
    /// means "same as the raw activation").
    pub mean_abs_delta: Option<f64>,
    /// examples sent full-precision this message (Algorithm 1 line 5).
    pub first_visits: usize,
}

/// One half (sender *or* receiver) of a pipeline-boundary compression
/// scheme. Stateful: AQ-style codecs hold their per-example message
/// buffers, so a boundary owns one encoder and one decoder instance
/// whose states advance in lockstep through the frames alone.
///
/// `Send` is a supertrait because the threaded pipeline executor
/// (`pipeline::exec`) moves each half onto its endpoint's worker thread:
/// the encoder lives with the sending stage, the decoder with the
/// receiving stage, and only serialized [`Frame`] bytes cross between
/// them (Algorithm 2's replica split, realized as thread ownership).
pub trait BoundaryCodec: Send {
    /// Compress activation `a` (one record per id in `ids`, row-major)
    /// into a wire frame, advancing any codec state.
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame>;

    /// Reconstruct the receiver-side activation from a frame, advancing
    /// any codec state. Malformed frames are `Err`, never a panic.
    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>>;

    /// Scratch-buffer encode: build the *serialized* wire image directly
    /// in `out`, reusing its allocation across messages. Produces bytes
    /// identical to `encode(...).to_bytes()` (pinned by
    /// `prop_frames.rs`); the registered codecs override this with
    /// steady-state allocation-free implementations (pinned by
    /// `tests/zero_alloc.rs`). The default shims through [`encode`].
    ///
    /// [`encode`]: Self::encode
    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        out.copy_from_frame(&self.encode(ids, a)?)
    }

    /// Scratch-buffer decode: reconstruct into the caller-owned `out`
    /// slice, reading header/payload bytes in place through the borrowed
    /// [`FrameView`]. `out.len()` must be the expected activation length
    /// (`ids.len()` records); a frame claiming any other shape is an
    /// error. The default shims through [`decode`].
    ///
    /// [`decode`]: Self::decode
    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let v = self.decode(ids, &frame.to_frame())?;
        crate::ensure!(
            v.len() == out.len(),
            "codec decoded {} elements into a {}-element buffer",
            v.len(),
            out.len()
        );
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Human-readable scheme label (also the registry spec fragment).
    fn label(&self) -> String;

    /// Bytes of persistent codec state (message buffers etc.).
    fn state_bytes(&self) -> u64 {
        0
    }

    /// Probe stats of the most recent `encode` (encoder halves only).
    fn take_stats(&mut self) -> EncodeStats {
        EncodeStats::default()
    }

    /// Worker count for chunked encode/decode kernels on large tensors
    /// (see [`par::Workers`]). Bytes are bit-identical at any count —
    /// this is purely a throughput knob, so the default for codecs
    /// without a parallel path is a no-op.
    fn set_workers(&mut self, _threads: usize) {}
}

/// Build an owned [`Frame`] through a codec's scratch path — the shim
/// the registered codecs use to keep `encode` and `encode_into` a
/// single implementation (the scratch one).
pub fn encode_to_frame<C: BoundaryCodec + ?Sized>(
    c: &mut C,
    ids: &[u64],
    a: &[f32],
) -> Result<Frame> {
    let mut buf = FrameBuf::new();
    c.encode_into(ids, a, &mut buf)?;
    Ok(buf.to_frame())
}

/// Bytes on the wire for `n` b-bit codes + the f32 scale header (the
/// quantized-payload arithmetic used by the tensor-parallel all-reduce
/// model in `codec::tp`; framed codecs — including the DP gradient
/// path — measure their own serialized buffers instead).
pub fn quant_wire_bytes(n: usize, bits: u8) -> u64 {
    pack::packed_len(n, bits) as u64 + 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_shapes() {
        // 4 bits: two codes per byte (+4B scale)
        assert_eq!(quant_wire_bytes(8, 4), 4 + 4);
        assert_eq!(quant_wire_bytes(9, 4), 5 + 4);
    }
}
