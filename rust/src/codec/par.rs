//! First-party deterministic worker pool for chunked codec kernels.
//!
//! Large tensors are split into fixed [`CHUNK`]-element chunks; chunk
//! `i` always covers the same input/output ranges no matter how many
//! workers run, and each chunk's stochastic-rounding RNG stream is
//! derived from the message seed and the chunk index alone
//! (`UniformQuantizer::chunk_rng`). Workers only change *who* computes
//! a chunk, never *what* — encoded bytes are bit-identical at any
//! thread count, which is what keeps the executor-vs-simulator oracle
//! and the golden frame pins valid when parallel encode is on.
//!
//! No rayon: the crate stays zero-dependency. `std::thread::scope`
//! spawns short-lived workers only when a tensor spans multiple chunks
//! *and* the pool was configured with >1 thread; the small-message
//! steady state (`tests/zero_alloc.rs`) stays on the inline sequential
//! path with no spawn and no allocation.

/// Elements per parallel chunk. A multiple of 8, so chunk boundaries
/// are byte-aligned in the packed stream for every bit width 1..=8 and
/// chunks can pack into disjoint byte ranges independently.
pub const CHUNK: usize = 4096;

/// A worker-count policy for chunked kernels. `Copy` and cheap: it
/// holds no threads — workers are scoped per call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Workers {
    threads: usize,
}

impl Workers {
    /// Pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Sequential policy: everything runs inline on the caller.
    pub fn seq() -> Self {
        Self { threads: 1 }
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk_index, a_chunk, b_chunk)` over paired chunkings of
    /// `a` (read) and `b` (written): chunk `i` covers
    /// `a[i*a_chunk .. (i+1)*a_chunk]` and `b[i*b_chunk ..
    /// (i+1)*b_chunk]` (last chunks may be short). The chunk->range
    /// mapping is fixed; worker count only changes scheduling, so any
    /// deterministic per-chunk `f` yields identical buffers at any
    /// thread count. Requires `ceil(a.len()/a_chunk) ==
    /// ceil(b.len()/b_chunk)` chunks.
    pub fn for_chunks2<A, B, F>(&self, a: &[A], b: &mut [B], a_chunk: usize, b_chunk: usize, f: F)
    where
        A: Sync,
        B: Send,
        F: Fn(usize, &[A], &mut [B]) + Sync,
    {
        debug_assert!(a_chunk > 0 && b_chunk > 0);
        let n_chunks = (a.len() + a_chunk - 1) / a_chunk;
        debug_assert_eq!(n_chunks, (b.len() + b_chunk - 1) / b_chunk);
        if n_chunks <= 1 || self.threads <= 1 {
            // inline sequential path: no spawn, no alloc (the steady
            // state for per-example message buffers)
            let mut rest = &mut b[..];
            for (i, ac) in a.chunks(a_chunk).enumerate() {
                let take = b_chunk.min(rest.len());
                let (bc, tail) = rest.split_at_mut(take);
                f(i, ac, bc);
                rest = tail;
            }
            return;
        }
        let w = self.threads.min(n_chunks);
        let per = (n_chunks + w - 1) / w; // whole chunks per worker, contiguous runs
        let f = &f;
        std::thread::scope(|scope| {
            let mut rest_a = a;
            let mut rest_b = &mut b[..];
            for wi in 0..w {
                let lo = wi * per;
                let hi = (lo + per).min(n_chunks);
                if lo >= hi {
                    break;
                }
                let take_a = ((hi - lo) * a_chunk).min(rest_a.len());
                let take_b = ((hi - lo) * b_chunk).min(rest_b.len());
                let (run_a, ta) = rest_a.split_at(take_a);
                let (run_b, tb) = rest_b.split_at_mut(take_b);
                rest_a = ta;
                rest_b = tb;
                scope.spawn(move || {
                    let mut rb = run_b;
                    for (j, ac) in run_a.chunks(a_chunk).enumerate() {
                        let take = b_chunk.min(rb.len());
                        let (bc, tail) = rb.split_at_mut(take);
                        f(lo + j, ac, bc);
                        rb = tail;
                    }
                });
            }
        });
    }
}

impl Default for Workers {
    fn default() -> Self {
        Self::seq()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // per-chunk kernel: stamps (chunk index, lane, sum of inputs) so any
    // chunk->range mismatch or double-write is visible in the output
    fn stamp(i: usize, ac: &[u32], bc: &mut [u64]) {
        let sum: u64 = ac.iter().map(|&v| v as u64).sum();
        for (j, bj) in bc.iter_mut().enumerate() {
            *bj = ((i as u64) << 32) ^ (sum + j as u64);
        }
    }

    #[test]
    fn chunk_map_is_worker_count_independent() {
        // symmetric chunking (b mirrors a), assorted tails around the
        // chunk boundary, worker counts past the chunk count
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 100] {
            let a: Vec<u32> = (0..n as u32).collect();
            let mut want = vec![0u64; n];
            Workers::seq().for_chunks2(&a, &mut want, 8, 8, stamp);
            for threads in 2..=5 {
                let mut b = vec![0u64; n];
                Workers::new(threads).for_chunks2(&a, &mut b, 8, 8, stamp);
                assert_eq!(b, want, "n={n} threads={threads}");
            }
        }
        // asymmetric chunking (packed output: 4 b-slots per 8 a-elems),
        // exact multiples so chunk counts line up
        for n in [0usize, 8, 64, 128] {
            let a: Vec<u32> = (0..n as u32).collect();
            let mut want = vec![0u64; n / 2];
            Workers::seq().for_chunks2(&a, &mut want, 8, 4, stamp);
            for threads in 2..=5 {
                let mut b = vec![0u64; n / 2];
                Workers::new(threads).for_chunks2(&a, &mut b, 8, 4, stamp);
                assert_eq!(b, want, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn seq_is_default_and_clamped() {
        assert_eq!(Workers::default(), Workers::seq());
        assert_eq!(Workers::new(0).threads(), 1);
        assert_eq!(Workers::new(4).threads(), 4);
    }
}
