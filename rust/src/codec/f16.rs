//! Minimal IEEE-754 binary16 conversion (round-to-nearest-even), used by
//! the FP16 wire format (paper Appendix H.4) — no `half` crate offline.

#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal half (or zero)
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let mut v = m >> shift;
        // round to nearest even
        let rem = m & (half.wrapping_mul(2) - 1);
        if rem > half || (rem == half && (m >> shift) & 1 == 1) {
            v += 1;
        }
        return sign | v as u16;
    }
    let mut v = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (v & 1) == 1) {
        v += 1; // may carry into exponent; that is still correct
    }
    sign | v as u16
}

#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            let m = (m & 0x03FF) << 13;
            sign | (((127 - 15 + e + 1) as u32) << 23) | m
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

pub fn encode(x: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(2 * x.len());
    for &v in x {
        out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
}

pub fn decode(bytes: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.resize(bytes.len() / 2, 0.0);
    decode_slice(bytes, out);
}

/// Decode into a caller-owned slice (`bytes.len() == 2 * out.len()`) —
/// the allocation-free path `F16Codec::decode_into` uses.
pub fn decode_slice(bytes: &[u8], out: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 2 * out.len());
    for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
        *o = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
    }
}

/// Lossy round-trip through f16 (the FP16 wire applied in place).
pub fn roundtrip(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = f16_bits_to_f32(f32_to_f16_bits(*v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "{v}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut r = crate::util::Rng::new(9);
        for _ in 0..10_000 {
            let v = r.normal() * 100.0;
            let h = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((h - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {h}");
        }
    }

    #[test]
    fn specials() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e10)), f32::INFINITY); // overflow
        let tiny = f16_bits_to_f32(f32_to_f16_bits(1e-7));
        assert!(tiny >= 0.0 && tiny < 1e-6); // subnormal or flushed
    }

    #[test]
    fn vector_roundtrip() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let mut bytes = Vec::new();
        encode(&x, &mut bytes);
        assert_eq!(bytes.len(), 200);
        let mut back = Vec::new();
        decode(&bytes, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-6);
        }
    }
}
