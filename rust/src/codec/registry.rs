//! The codec registry: spec strings → boundary codec pairs.
//!
//! A full boundary configuration is a [`CodecSpec`]: one [`SchemeSpec`]
//! for the forward (activation) direction and one for the backward
//! (activation-gradient) direction. The grammar:
//!
//! ```text
//! spec     := "fp32" | "fp16"
//!           | "directq:fw<bits>bw<bits>"      DirectQ both directions
//!           | "aqsgd:fw<bits>bw<bits>"        AQ fw, DirectQ bw (Alg. 1)
//!           | "topk:<frac>@<bits>"            top-k both directions
//!           | "ef:" spec                      error feedback around both
//!           | "tile:<T>:" spec                tile-adaptive bits (DirectQ inner)
//!           | "had:" spec                     Hadamard rotation around both
//!           | "lr:<rank>:" spec               low-rank delta around both
//!           | "hybrid:<dir>/<dir>"            any fw/bw composition
//! dir      := "fp32" | "fp16" | "q<bits>" | "aq<bits>"
//!           | "topk<frac>@<bits>" | "ef:" dir
//!           | "tile:<T>:" dir | "had:" dir | "lr:<rank>:" dir
//! ```
//!
//! e.g. `"hybrid:aq2/topk0.2@8"` is Appendix H.6's split-learning scheme
//! (2-bit AQ forward, top-20% + 8-bit backward), and
//! `"ef:directq:fw4bw4"` is Fig. 5's error-compensated 4-bit gradient
//! compressor (the `--dp-codec` default regime). Bits are 1..=8, frac in
//! (0, 1]. `CodecSpec::parse` subsumes the old `Compression::parse`;
//! every boundary, the trainer, the DP gradient ring, and the examples
//! obtain codecs here.

use std::sync::Arc;

use crate::runtime::QuantRuntime;
use crate::store::{ActivationStore, MemStore};
use crate::util::error::Result;
use crate::util::Rng;

use super::delta::AqCodec;
use super::ef::EfCodec;
use super::hadamard::HadCodec;
use super::lowrank::LrCodec;
use super::quantizer::Rounding;
use super::schemes::{DirectQCodec, F16Codec, Raw32Codec, TopKCodec};
use super::tile::TileCodec;
use super::BoundaryCodec;

/// One direction's compression scheme.
#[derive(Clone, Debug, PartialEq)]
pub enum SchemeSpec {
    /// FP32 passthrough (paper baseline).
    Raw32,
    /// Half-precision wire (App. H.4).
    F16,
    /// Direct b-bit quantization (AC-GC / TinyScript style).
    DirectQ { bits: u8 },
    /// AQ-SGD delta quantization against per-example buffers.
    Aq { bits: u8 },
    /// Top-`frac` magnitude sparsification + b-bit quantization (App. H.6).
    TopK { frac: f64, bits: u8 },
    /// Error-feedback wrapper around any inner scheme (§4.3 / Fig. 5's
    /// "QuantizedAdam"-style gradient compressor; see `codec::ef`).
    Ef { inner: Box<SchemeSpec> },
    /// Tile-wise adaptive quantization: T-element tiles, per-tile scale,
    /// variance-driven bit allocation around an average `bits` budget
    /// (TAH-QUANT style; see `codec::tile`).
    Tile { t: u32, bits: u8 },
    /// Fast Walsh–Hadamard rotation applied before (and inverted after)
    /// any inner scheme (see `codec::hadamard`).
    Had { inner: Box<SchemeSpec> },
    /// CompactFusion-style low-rank delta baseline wrapping an inner
    /// residual codec (see `codec::lowrank`).
    Lr { rank: u8, inner: Box<SchemeSpec> },
}

/// Every grammar production reachable from [`SchemeSpec::parse`] —
/// the closed vocabulary the scheme-coverage CI guard checks
/// [`example_specs`] against. Adding a `SchemeSpec` variant without
/// extending this list (and the `production` match) fails to compile;
/// adding it here without an `example_specs` entry fails the guard.
pub fn grammar_productions() -> &'static [&'static str] {
    &["fp32", "fp16", "directq", "aq", "topk", "ef", "tile", "had", "lr"]
}

/// Everything a scheme needs to build its encoder/decoder halves.
pub struct BuildCtx<'a> {
    /// elements per example record — sizes AQ buffers (via the store
    /// factory) and bounds the dense length per-message codecs accept
    pub example_len: usize,
    pub rounding: Rounding,
    pub seed: u64,
    /// store key namespace (the boundary id)
    pub ns: u32,
    pub hlo: Option<Arc<QuantRuntime>>,
    /// store factory; called with a role tag ("enc" / "dec") so the two
    /// replicas get distinct backing (e.g. separate disk files)
    pub mk_store: &'a mut dyn FnMut(&str) -> Result<Box<dyn ActivationStore>>,
}

impl SchemeSpec {
    /// Parse one direction spec (the `dir` grammar above).
    pub fn parse(s: &str) -> Result<SchemeSpec> {
        let s = s.trim();
        Self::parse_at(s, s, 0)
    }

    /// The recursive worker behind [`parse`]: `s` is the fragment being
    /// parsed, `whole` the full user-supplied spec, and `off` the byte
    /// offset of `s` within `whole` — so rejection messages for malformed
    /// *nested* wrapper specs name the offending token and its position
    /// rather than re-printing the fragment as an "unknown scheme".
    ///
    /// [`parse`]: Self::parse
    fn parse_at(s: &str, whole: &str, off: usize) -> Result<SchemeSpec> {
        match s {
            "fp32" => return Ok(SchemeSpec::Raw32),
            "fp16" => return Ok(SchemeSpec::F16),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("ef:") {
            crate::ensure!(
                !rest.is_empty(),
                "ef: missing inner scheme at byte {} in {whole:?}",
                off + 3
            );
            return Ok(SchemeSpec::Ef {
                inner: Box::new(Self::parse_at(rest, whole, off + 3)?),
            });
        }
        if let Some(rest) = s.strip_prefix("had:") {
            crate::ensure!(
                !rest.is_empty(),
                "had: missing inner scheme at byte {} in {whole:?}",
                off + 4
            );
            return Ok(SchemeSpec::Had {
                inner: Box::new(Self::parse_at(rest, whole, off + 4)?),
            });
        }
        if let Some(rest) = s.strip_prefix("tile:") {
            return parse_tile(rest, whole, off + 5);
        }
        if let Some(rest) = s.strip_prefix("lr:") {
            return parse_lr(rest, whole, off + 3);
        }
        if let Some(rest) = s.strip_prefix("topk") {
            return parse_topk(rest, s);
        }
        if let Some(bits) = s.strip_prefix("aq") {
            return Ok(SchemeSpec::Aq { bits: parse_bits_value(bits, s)? });
        }
        if let Some(bits) = s.strip_prefix('q') {
            return Ok(SchemeSpec::DirectQ { bits: parse_bits_value(bits, s)? });
        }
        crate::bail!(
            "unknown scheme {s:?} at byte {off} in {whole:?} \
             (fp32|fp16|q<bits>|aq<bits>|topk<frac>@<bits>|ef:<dir>|tile:<T>:<dir>|had:<dir>|lr:<rank>:<dir>)"
        )
    }

    /// The grammar production this scheme's outermost constructor came
    /// from — exhaustive on purpose, so a new variant cannot be added
    /// without registering its production (the coverage guard then
    /// demands an [`example_specs`] entry).
    pub fn production(&self) -> &'static str {
        match self {
            SchemeSpec::Raw32 => "fp32",
            SchemeSpec::F16 => "fp16",
            SchemeSpec::DirectQ { .. } => "directq",
            SchemeSpec::Aq { .. } => "aq",
            SchemeSpec::TopK { .. } => "topk",
            SchemeSpec::Ef { .. } => "ef",
            SchemeSpec::Tile { .. } => "tile",
            SchemeSpec::Had { .. } => "had",
            SchemeSpec::Lr { .. } => "lr",
        }
    }

    /// Collect the productions of this scheme and every nested inner
    /// scheme into `out` (`ef:lr:4:q4` covers `ef`, `lr`, and `directq`).
    pub fn productions(&self, out: &mut std::collections::BTreeSet<&'static str>) {
        out.insert(self.production());
        match self {
            SchemeSpec::Ef { inner } | SchemeSpec::Had { inner } | SchemeSpec::Lr { inner, .. } => {
                inner.productions(out)
            }
            _ => {}
        }
    }

    /// Canonical spec fragment (round-trips through [`SchemeSpec::parse`]).
    pub fn spec_string(&self) -> String {
        match self {
            SchemeSpec::Raw32 => "fp32".into(),
            SchemeSpec::F16 => "fp16".into(),
            SchemeSpec::DirectQ { bits } => format!("q{bits}"),
            SchemeSpec::Aq { bits } => format!("aq{bits}"),
            SchemeSpec::TopK { frac, bits } => format!("topk{frac}@{bits}"),
            SchemeSpec::Ef { inner } => format!("ef:{}", inner.spec_string()),
            SchemeSpec::Tile { t, bits } => format!("tile:{t}:q{bits}"),
            SchemeSpec::Had { inner } => format!("had:{}", inner.spec_string()),
            SchemeSpec::Lr { rank, inner } => format!("lr:{rank}:{}", inner.spec_string()),
        }
    }

    /// Whether the scheme sends full-precision first-visit records
    /// (Algorithm 1 line 5) — what distinguishes first-epoch from
    /// steady-state wire volume in the measured-bytes cache.
    pub fn has_first_visit(&self) -> bool {
        match self {
            SchemeSpec::Aq { .. } => true,
            // lr sends lossless full records on first visit (like AQ)
            SchemeSpec::Lr { .. } => true,
            SchemeSpec::Ef { inner } | SchemeSpec::Had { inner } => inner.has_first_visit(),
            _ => false,
        }
    }

    /// Build the (encoder, decoder) halves for this scheme. The halves
    /// share no state — only the frames the encoder emits.
    pub fn build_pair(
        &self,
        ctx: &mut BuildCtx,
    ) -> Result<(Box<dyn BoundaryCodec>, Box<dyn BoundaryCodec>)> {
        Ok(match self {
            SchemeSpec::Raw32 => (
                Box::new(Raw32Codec) as Box<dyn BoundaryCodec>,
                Box::new(Raw32Codec) as Box<dyn BoundaryCodec>,
            ),
            SchemeSpec::F16 => (Box::new(F16Codec), Box::new(F16Codec)),
            SchemeSpec::DirectQ { bits } => (
                Box::new(DirectQCodec::new(*bits, ctx.rounding, ctx.seed, ctx.hlo.clone())),
                Box::new(DirectQCodec::new(*bits, ctx.rounding, ctx.seed ^ 1, ctx.hlo.clone())),
            ),
            SchemeSpec::Aq { bits } => {
                let enc_store = (ctx.mk_store)("enc")?;
                let dec_store = (ctx.mk_store)("dec")?;
                (
                    Box::new(AqCodec::new(
                        *bits,
                        ctx.rounding,
                        enc_store,
                        ctx.ns,
                        ctx.seed,
                        ctx.hlo.clone(),
                    )),
                    Box::new(AqCodec::new(
                        *bits,
                        ctx.rounding,
                        dec_store,
                        ctx.ns,
                        ctx.seed ^ 1,
                        ctx.hlo.clone(),
                    )),
                )
            }
            SchemeSpec::TopK { frac, bits } => (
                Box::new(TopKCodec::new(*frac, *bits, ctx.rounding, ctx.example_len, ctx.seed)),
                Box::new(TopKCodec::new(
                    *frac,
                    *bits,
                    ctx.rounding,
                    ctx.example_len,
                    ctx.seed ^ 1,
                )),
            ),
            SchemeSpec::Ef { inner } => {
                // The encoder needs a bit-exact replica of the receiver's
                // decoder (codec::ef feedback loop): build one extra inner
                // pair under a namespaced store role and keep its decoder.
                let example_len = ctx.example_len;
                let replica_dec = {
                    let mut mk = |role: &str| (ctx.mk_store)(&format!("ef_replica_{role}"));
                    let mut rctx = BuildCtx {
                        example_len,
                        rounding: ctx.rounding,
                        seed: ctx.seed,
                        ns: ctx.ns,
                        hlo: ctx.hlo.clone(),
                        mk_store: &mut mk,
                    };
                    inner.build_pair(&mut rctx)?.1
                };
                let (inner_enc, inner_dec) = inner.build_pair(ctx)?;
                (
                    Box::new(EfCodec::encoder(inner_enc, replica_dec, example_len)),
                    Box::new(EfCodec::decoder(inner_dec)),
                )
            }
            SchemeSpec::Tile { t, bits } => (
                Box::new(TileCodec::new(*t, *bits, ctx.rounding, ctx.example_len, ctx.seed)),
                Box::new(TileCodec::new(*t, *bits, ctx.rounding, ctx.example_len, ctx.seed ^ 1)),
            ),
            SchemeSpec::Had { inner } => {
                let example_len = ctx.example_len;
                let (inner_enc, inner_dec) = inner.build_pair(ctx)?;
                (
                    Box::new(HadCodec::new(inner_enc, example_len)),
                    Box::new(HadCodec::new(inner_dec, example_len)),
                )
            }
            SchemeSpec::Lr { rank, inner } => {
                // Like `ef:`, the encoder carries a replica of the
                // receiver's inner decoder; unlike `ef:`, both halves
                // also carry baseline stores of their own, and the
                // inner pair gets namespaced store roles so a stateful
                // inner (lr:4:aq2) cannot collide with the baselines.
                let example_len = ctx.example_len;
                let replica_dec = {
                    let mut mk = |role: &str| (ctx.mk_store)(&format!("lr_replica_{role}"));
                    let mut rctx = BuildCtx {
                        example_len,
                        rounding: ctx.rounding,
                        seed: ctx.seed,
                        ns: ctx.ns,
                        hlo: ctx.hlo.clone(),
                        mk_store: &mut mk,
                    };
                    inner.build_pair(&mut rctx)?.1
                };
                let (inner_enc, inner_dec) = {
                    let mut mk = |role: &str| (ctx.mk_store)(&format!("lr_inner_{role}"));
                    let mut ictx = BuildCtx {
                        example_len,
                        rounding: ctx.rounding,
                        seed: ctx.seed,
                        ns: ctx.ns,
                        hlo: ctx.hlo.clone(),
                        mk_store: &mut mk,
                    };
                    inner.build_pair(&mut ictx)?
                };
                let enc_store = (ctx.mk_store)("enc")?;
                let dec_store = (ctx.mk_store)("dec")?;
                (
                    Box::new(LrCodec::encoder(
                        *rank,
                        inner_enc,
                        replica_dec,
                        enc_store,
                        example_len,
                        ctx.ns,
                    )),
                    Box::new(LrCodec::decoder(*rank, inner_dec, dec_store, example_len, ctx.ns)),
                )
            }
        })
    }
}

/// Convenience: build a scheme's (encoder, decoder) pair backed by
/// in-memory stores — what tests, benches, and wire-size measurement use.
pub fn build_mem_pair(
    scheme: &SchemeSpec,
    example_len: usize,
    rounding: Rounding,
    seed: u64,
) -> Result<(Box<dyn BoundaryCodec>, Box<dyn BoundaryCodec>)> {
    let mut mk = |_role: &str| -> Result<Box<dyn ActivationStore>> {
        Ok(Box::new(MemStore::new(example_len)))
    };
    scheme.build_pair(&mut BuildCtx {
        example_len,
        rounding,
        seed,
        ns: 0,
        hlo: None,
        mk_store: &mut mk,
    })
}

// ---------------------------------------------------------------------------

/// A full boundary configuration: forward + backward schemes. Replaces
/// the old closed `Compression` enum.
#[derive(Clone, Debug, PartialEq)]
pub struct CodecSpec {
    pub fw: SchemeSpec,
    pub bw: SchemeSpec,
}

impl CodecSpec {
    pub fn fp32() -> Self {
        CodecSpec { fw: SchemeSpec::Raw32, bw: SchemeSpec::Raw32 }
    }

    pub fn fp16() -> Self {
        CodecSpec { fw: SchemeSpec::F16, bw: SchemeSpec::F16 }
    }

    pub fn directq(fw_bits: u8, bw_bits: u8) -> Self {
        CodecSpec {
            fw: SchemeSpec::DirectQ { bits: fw_bits },
            bw: SchemeSpec::DirectQ { bits: bw_bits },
        }
    }

    /// AQ-SGD: delta-quantized forward, directly quantized backward
    /// (Algorithm 1 line 11).
    pub fn aqsgd(fw_bits: u8, bw_bits: u8) -> Self {
        CodecSpec {
            fw: SchemeSpec::Aq { bits: fw_bits },
            bw: SchemeSpec::DirectQ { bits: bw_bits },
        }
    }

    pub fn topk(frac: f64, bits: u8) -> Self {
        let s = SchemeSpec::TopK { frac, bits };
        CodecSpec { fw: s.clone(), bw: s }
    }

    pub fn hybrid(fw: SchemeSpec, bw: SchemeSpec) -> Self {
        CodecSpec { fw, bw }
    }

    /// Error feedback around both directions of `inner` (the Fig. 5
    /// gradient-compression regime, e.g. `ef:directq:fw4bw4`).
    pub fn ef(inner: CodecSpec) -> Self {
        CodecSpec {
            fw: SchemeSpec::Ef { inner: Box::new(inner.fw) },
            bw: SchemeSpec::Ef { inner: Box::new(inner.bw) },
        }
    }

    /// Parse a full spec string (see the module grammar).
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let s = s.trim();
        match s {
            "fp32" => return Ok(CodecSpec::fp32()),
            "fp16" => return Ok(CodecSpec::fp16()),
            _ => {}
        }
        if let Some(spec) = s.strip_prefix("directq:") {
            let (fw, bw) = parse_fwbw(spec)?;
            return Ok(CodecSpec::directq(fw, bw));
        }
        if let Some(spec) = s.strip_prefix("aqsgd:") {
            let (fw, bw) = parse_fwbw(spec)?;
            return Ok(CodecSpec::aqsgd(fw, bw));
        }
        if let Some(spec) = s.strip_prefix("topk:") {
            let scheme = parse_topk(spec.trim(), s)?;
            return Ok(CodecSpec { fw: scheme.clone(), bw: scheme });
        }
        if let Some(spec) = s.strip_prefix("ef:") {
            // full inner spec ("ef:directq:fw4bw4") or a single direction
            // scheme applied to both ("ef:q4"); the fallback re-parses the
            // whole string so errors carry true byte positions
            if let Ok(inner) = CodecSpec::parse(spec) {
                return Ok(CodecSpec::ef(inner));
            }
            let scheme = SchemeSpec::parse(s)?;
            return Ok(CodecSpec { fw: scheme.clone(), bw: scheme });
        }
        if let Some(spec) = s.strip_prefix("had:") {
            // same shape as ef: — "had:directq:fw2bw4" wraps per
            // direction, "had:q4" applies one scheme to both
            if let Ok(inner) = CodecSpec::parse(spec) {
                return Ok(CodecSpec {
                    fw: SchemeSpec::Had { inner: Box::new(inner.fw) },
                    bw: SchemeSpec::Had { inner: Box::new(inner.bw) },
                });
            }
            let scheme = SchemeSpec::parse(s)?;
            return Ok(CodecSpec { fw: scheme.clone(), bw: scheme });
        }
        if let Some(rest) = s.strip_prefix("tile:") {
            let (t_str, inner) = rest.split_once(':').ok_or_else(|| {
                crate::err!(
                    "tile spec {s:?} needs tile:<T>:<inner>, missing inner after {rest:?} at byte 5"
                )
            })?;
            let t = parse_tile_len(t_str, s, 5)?;
            let inner_off = 5 + t_str.len() + 1;
            if let Ok(ispec) = CodecSpec::parse(inner) {
                return match (ispec.fw, ispec.bw) {
                    (SchemeSpec::DirectQ { bits: f }, SchemeSpec::DirectQ { bits: b }) => {
                        Ok(CodecSpec {
                            fw: SchemeSpec::Tile { t, bits: f },
                            bw: SchemeSpec::Tile { t, bits: b },
                        })
                    }
                    _ => crate::bail!(
                        "tile: inner must be a direct quantizer (q<bits> or directq:fwXbwY), \
                         got {inner:?} at byte {inner_off} in {s:?}"
                    ),
                };
            }
            let scheme = SchemeSpec::parse(s)?;
            return Ok(CodecSpec { fw: scheme.clone(), bw: scheme });
        }
        if let Some(rest) = s.strip_prefix("lr:") {
            let (r_str, _) = rest.split_once(':').ok_or_else(|| {
                crate::err!(
                    "lr spec {s:?} needs lr:<rank>:<inner>, missing inner after {rest:?} at byte 3"
                )
            })?;
            let rank = parse_lr_rank(r_str, s, 3)?;
            if let Ok(ispec) = CodecSpec::parse(&rest[r_str.len() + 1..]) {
                return Ok(CodecSpec {
                    fw: SchemeSpec::Lr { rank, inner: Box::new(ispec.fw) },
                    bw: SchemeSpec::Lr { rank, inner: Box::new(ispec.bw) },
                });
            }
            let scheme = SchemeSpec::parse(s)?;
            return Ok(CodecSpec { fw: scheme.clone(), bw: scheme });
        }
        if let Some(spec) = s.strip_prefix("hybrid:") {
            let (fw, bw) = spec
                .split_once('/')
                .ok_or_else(|| crate::err!("hybrid spec {s:?} needs <fw>/<bw>"))?;
            return Ok(CodecSpec { fw: SchemeSpec::parse(fw)?, bw: SchemeSpec::parse(bw)? });
        }
        crate::bail!(
            "unknown compression {s:?} (fp32 | fp16 | directq:fwXbwY | aqsgd:fwXbwY | \
             topk:<frac>@<bits> | ef:<spec> | tile:<T>:<spec> | had:<spec> | \
             lr:<rank>:<spec> | hybrid:<fw>/<bw>)"
        )
    }

    /// Canonical spec string (round-trips through [`CodecSpec::parse`]).
    pub fn spec_string(&self) -> String {
        if let (SchemeSpec::Ef { inner: f }, SchemeSpec::Ef { inner: b }) = (&self.fw, &self.bw) {
            let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
            return format!("ef:{}", inner.spec_string());
        }
        if let (SchemeSpec::Had { inner: f }, SchemeSpec::Had { inner: b }) = (&self.fw, &self.bw) {
            let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
            return format!("had:{}", inner.spec_string());
        }
        if let (SchemeSpec::Lr { rank: rf, inner: f }, SchemeSpec::Lr { rank: rb, inner: b }) =
            (&self.fw, &self.bw)
        {
            if rf == rb {
                let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
                return format!("lr:{rf}:{}", inner.spec_string());
            }
        }
        if let (SchemeSpec::Tile { t: tf, bits: f }, SchemeSpec::Tile { t: tb, bits: b }) =
            (&self.fw, &self.bw)
        {
            if tf == tb {
                return format!("tile:{tf}:directq:fw{f}bw{b}");
            }
        }
        match (&self.fw, &self.bw) {
            (SchemeSpec::Raw32, SchemeSpec::Raw32) => "fp32".into(),
            (SchemeSpec::F16, SchemeSpec::F16) => "fp16".into(),
            (SchemeSpec::DirectQ { bits: f }, SchemeSpec::DirectQ { bits: b }) => {
                format!("directq:fw{f}bw{b}")
            }
            (SchemeSpec::Aq { bits: f }, SchemeSpec::DirectQ { bits: b }) => {
                format!("aqsgd:fw{f}bw{b}")
            }
            (SchemeSpec::TopK { frac, bits }, bw) if self.fw == *bw => {
                format!("topk:{frac}@{bits}")
            }
            (fw, bw) => format!("hybrid:{}/{}", fw.spec_string(), bw.spec_string()),
        }
    }

    /// Display label (table headers, trainer logs).
    pub fn label(&self) -> String {
        if let (SchemeSpec::Ef { inner: f }, SchemeSpec::Ef { inner: b }) = (&self.fw, &self.bw) {
            let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
            return format!("EF {}", inner.label());
        }
        if let (SchemeSpec::Had { inner: f }, SchemeSpec::Had { inner: b }) = (&self.fw, &self.bw) {
            let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
            return format!("Had {}", inner.label());
        }
        if let (SchemeSpec::Lr { rank: rf, inner: f }, SchemeSpec::Lr { rank: rb, inner: b }) =
            (&self.fw, &self.bw)
        {
            if rf == rb {
                let inner = CodecSpec { fw: (**f).clone(), bw: (**b).clone() };
                return format!("LR r{rf} {}", inner.label());
            }
        }
        if let (SchemeSpec::Tile { t: tf, bits: f }, SchemeSpec::Tile { t: tb, bits: b }) =
            (&self.fw, &self.bw)
        {
            if tf == tb {
                return format!("Tile{tf} fw{f} bw{b}");
            }
        }
        match (&self.fw, &self.bw) {
            (SchemeSpec::Raw32, SchemeSpec::Raw32) => "FP32".into(),
            (SchemeSpec::F16, SchemeSpec::F16) => "FP16".into(),
            (SchemeSpec::DirectQ { bits: f }, SchemeSpec::DirectQ { bits: b }) => {
                format!("DirectQ fw{f} bw{b}")
            }
            (SchemeSpec::Aq { bits: f }, SchemeSpec::DirectQ { bits: b }) => {
                format!("AQ-SGD fw{f} bw{b}")
            }
            (SchemeSpec::TopK { frac, bits }, bw) if self.fw == *bw => {
                format!("TopK {:.0}% @{bits}", frac * 100.0)
            }
            (fw, bw) => format!("fw {} / bw {}", fw.spec_string(), bw.spec_string()),
        }
    }

    /// Wire bytes of one forward message of `n` f32 elements, *measured*
    /// by encoding a synthetic activation through the real codec (no
    /// hand-maintained arithmetic). `first_visit` charges AQ-style
    /// schemes their full-precision first epoch (Algorithm 1 line 5).
    pub fn fw_wire_bytes(&self, n: usize, first_visit: bool) -> u64 {
        measured_wire_bytes(&self.fw, n, first_visit)
    }

    /// Wire bytes of one backward message of `n` f32 elements (measured;
    /// steady state for stateful schemes).
    pub fn bw_wire_bytes(&self, n: usize) -> u64 {
        measured_wire_bytes(&self.bw, n, false)
    }
}

/// Encode a synthetic `n`-element message through a fresh codec and
/// report the frame's size. Used by the throughput/regime simulations,
/// so their byte accounting is the codec's own, not a parallel formula.
/// Deterministic, so results are memoized — the paper-regime sweeps ask
/// for the same (scheme, n) pair hundreds of times.
fn measured_wire_bytes(scheme: &SchemeSpec, n: usize, first_visit: bool) -> u64 {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    static CACHE: OnceLock<Mutex<HashMap<(String, usize, bool), u64>>> = OnceLock::new();
    // only first-visit schemes (AQ, ef:aq) distinguish first visit from
    // steady state
    let first_visit = first_visit && scheme.has_first_visit();
    let key = (scheme.spec_string(), n, first_visit);
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(&v) = cache.lock().unwrap().get(&key) {
        return v;
    }
    let (mut enc, _dec) =
        build_mem_pair(scheme, n, Rounding::Nearest, 0x5EED).expect("build measurement codec");
    let mut rng = Rng::new(0xFACE);
    let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
    let first = enc.encode(&[0], &a).expect("measurement encode");
    let v = if first_visit || !scheme.has_first_visit() {
        first.wire_bytes()
    } else {
        // steady state: second visit with a small drift
        let a2: Vec<f32> = a.iter().map(|v| v + 1e-3).collect();
        enc.encode(&[0], &a2).expect("measurement encode").wire_bytes()
    };
    cache.lock().unwrap().insert(key, v);
    v
}

/// Representative parseable specs covering every registered scheme —
/// what the frame property tests and the codec bench iterate over. The
/// tier-1 scheme-coverage guard (`tests/scheme_coverage.rs`) asserts
/// this list reaches every [`grammar_productions`] entry, so a scheme
/// cannot be registered without being fuzzed, mutation-tested, and
/// alloc-checked.
pub fn example_specs() -> Vec<&'static str> {
    vec![
        "fp32",
        "fp16",
        "directq:fw3bw6",
        "aqsgd:fw2bw4",
        "topk:0.2@8",
        "ef:directq:fw4bw4",
        "hybrid:aq2/topk0.2@8",
        "hybrid:fp16/q4",
        "tile:64:directq:fw2bw4",
        "had:tile:64:directq:fw2bw4",
        "lr:4:directq:fw4bw4",
    ]
}

// ---------------------------------------------------------------------------

fn parse_bits_value(v: &str, whole: &str) -> Result<u8> {
    let bits: u8 = v
        .trim()
        .parse()
        .map_err(|_| crate::err!("bad bit-width {v:?} in {whole:?}"))?;
    check_bits(bits, whole)?;
    Ok(bits)
}

fn check_bits(bits: u8, whole: &str) -> Result<()> {
    crate::ensure!(
        (1..=8).contains(&bits),
        "bit-width {bits} out of range in {whole:?} (quantizers support 1..=8 bits)"
    );
    Ok(())
}

/// "fwXbwY" → (X, Y), validating both widths.
fn parse_fwbw(spec: &str) -> Result<(u8, u8)> {
    let spec = spec.trim();
    let rest = spec.strip_prefix("fw").ok_or_else(|| crate::err!("bad bits spec {spec:?}"))?;
    let (fw, bw) = rest.split_once("bw").ok_or_else(|| crate::err!("bad bits spec {spec:?}"))?;
    let fw: u8 = fw.trim().parse().map_err(|_| crate::err!("bad bits spec {spec:?}"))?;
    let bw: u8 = bw.trim().parse().map_err(|_| crate::err!("bad bits spec {spec:?}"))?;
    check_bits(fw, spec)?;
    check_bits(bw, spec)?;
    Ok((fw, bw))
}

/// "<T>:<dir>" (after the `tile:` keyword; `off` is the byte offset of
/// `<T>` within `whole`) → Tile scheme. The inner must be a direct
/// quantizer: tile *is* the quantizer, with per-tile scales and bits.
fn parse_tile(rest: &str, whole: &str, off: usize) -> Result<SchemeSpec> {
    let (t_str, inner) = rest.split_once(':').ok_or_else(|| {
        crate::err!(
            "tile spec {whole:?} needs tile:<T>:<inner>, missing inner after {rest:?} at byte {off}"
        )
    })?;
    let t = parse_tile_len(t_str, whole, off)?;
    let inner_off = off + t_str.len() + 1;
    match SchemeSpec::parse_at(inner, whole, inner_off)? {
        SchemeSpec::DirectQ { bits } => Ok(SchemeSpec::Tile { t, bits }),
        other => crate::bail!(
            "tile: inner must be a direct quantizer (q<bits>), got {:?} at byte {inner_off} in {whole:?}",
            other.spec_string()
        ),
    }
}

fn parse_tile_len(t_str: &str, whole: &str, off: usize) -> Result<u32> {
    let t: u32 = t_str.trim().parse().map_err(|_| {
        crate::err!("bad tile length {t_str:?} at byte {off} in {whole:?} (want an integer >= 1)")
    })?;
    crate::ensure!(t >= 1, "tile length {t} out of range at byte {off} in {whole:?} (want >= 1)");
    Ok(t)
}

/// "<rank>:<dir>" (after the `lr:` keyword; `off` is the byte offset of
/// `<rank>` within `whole`) → Lr scheme around any inner residual codec.
fn parse_lr(rest: &str, whole: &str, off: usize) -> Result<SchemeSpec> {
    let (r_str, inner) = rest.split_once(':').ok_or_else(|| {
        crate::err!(
            "lr spec {whole:?} needs lr:<rank>:<inner>, missing inner after {rest:?} at byte {off}"
        )
    })?;
    let rank = parse_lr_rank(r_str, whole, off)?;
    let inner_off = off + r_str.len() + 1;
    let scheme = SchemeSpec::parse_at(inner, whole, inner_off)?;
    Ok(SchemeSpec::Lr { rank, inner: Box::new(scheme) })
}

fn parse_lr_rank(r_str: &str, whole: &str, off: usize) -> Result<u8> {
    let rank: u8 = r_str.trim().parse().map_err(|_| {
        crate::err!("bad lr rank {r_str:?} at byte {off} in {whole:?} (want an integer in 1..=64)")
    })?;
    crate::ensure!(
        (1..=64).contains(&rank),
        "lr rank {rank} out of range at byte {off} in {whole:?} (want 1..=64)"
    );
    Ok(rank)
}

/// "<frac>@<bits>" (after the `topk` keyword) → TopK scheme.
fn parse_topk(rest: &str, whole: &str) -> Result<SchemeSpec> {
    let (frac, bits) = rest
        .split_once('@')
        .ok_or_else(|| crate::err!("topk spec {whole:?} needs <frac>@<bits>"))?;
    let frac: f64 =
        frac.trim().parse().map_err(|_| crate::err!("bad top-k fraction in {whole:?}"))?;
    crate::ensure!(
        frac > 0.0 && frac <= 1.0,
        "top-k fraction {frac} out of range in {whole:?} (want 0 < frac <= 1)"
    );
    let bits = parse_bits_value(bits, whole)?;
    Ok(SchemeSpec::TopK { frac, bits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(CodecSpec::parse("fp32").unwrap(), CodecSpec::fp32());
        assert_eq!(CodecSpec::parse("aqsgd:fw2bw4").unwrap(), CodecSpec::aqsgd(2, 4));
        assert_eq!(CodecSpec::parse("directq:fw3bw6").unwrap(), CodecSpec::directq(3, 6));
        assert_eq!(CodecSpec::parse("topk:0.2@8").unwrap(), CodecSpec::topk(0.2, 8));
        assert_eq!(
            CodecSpec::parse("hybrid:aq2/topk0.2@8").unwrap(),
            CodecSpec::hybrid(SchemeSpec::Aq { bits: 2 }, SchemeSpec::TopK { frac: 0.2, bits: 8 })
        );
        assert!(CodecSpec::parse("nope").is_err());
        assert!(CodecSpec::parse("aqsgd:fw2").is_err());
        assert!(CodecSpec::parse("hybrid:aq2").is_err());
        assert!(CodecSpec::parse("topk:0.2").is_err());
    }

    #[test]
    fn parse_trims_whitespace() {
        assert_eq!(CodecSpec::parse(" fp16 ").unwrap(), CodecSpec::fp16());
        assert_eq!(CodecSpec::parse("aqsgd: fw2bw4 ").unwrap(), CodecSpec::aqsgd(2, 4));
    }

    #[test]
    fn parse_rejects_out_of_range() {
        for spec in ["aqsgd:fw0bw0", "directq:fw9bw12", "aqsgd:fw4bw0", "directq:fw0bw4",
                     "topk:0.2@9", "hybrid:aq0/q4"] {
            let err = CodecSpec::parse(spec).unwrap_err();
            assert!(err.to_string().contains("out of range"), "{spec}: {err}");
        }
        for spec in ["topk:0@4", "topk:1.5@4", "topk:-0.1@4"] {
            assert!(CodecSpec::parse(spec).is_err(), "{spec} should be rejected");
        }
        // boundary widths still accepted
        assert!(CodecSpec::parse("aqsgd:fw1bw8").is_ok());
    }

    #[test]
    fn parse_ef_wrapper() {
        let spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        assert_eq!(spec, CodecSpec::ef(CodecSpec::directq(4, 4)));
        assert_eq!(spec.spec_string(), "ef:directq:fw4bw4");
        assert_eq!(spec.label(), "EF DirectQ fw4 bw4");
        // scheme-level wrapper (hybrid directions, golden fixtures)
        assert_eq!(
            SchemeSpec::parse("ef:q4").unwrap(),
            SchemeSpec::Ef { inner: Box::new(SchemeSpec::DirectQ { bits: 4 }) }
        );
        assert_eq!(SchemeSpec::parse("ef:q4").unwrap().spec_string(), "ef:q4");
        // nesting and hybrids compose
        assert!(CodecSpec::parse("hybrid:ef:q4/fp16").is_ok());
        assert!(CodecSpec::parse("ef:aqsgd:fw2bw4").is_ok());
        // malformed inner specs are rejected
        assert!(CodecSpec::parse("ef:").is_err());
        assert!(CodecSpec::parse("ef:q9").is_err());
        assert!(SchemeSpec::parse("ef:nope").is_err());
    }

    #[test]
    fn ef_first_visit_tracks_inner() {
        assert!(!SchemeSpec::parse("ef:q4").unwrap().has_first_visit());
        assert!(SchemeSpec::parse("ef:aq2").unwrap().has_first_visit());
        assert!(SchemeSpec::parse("aq2").unwrap().has_first_visit());
        assert!(!SchemeSpec::parse("fp16").unwrap().has_first_visit());
    }

    #[test]
    fn ef_wire_bytes_match_inner_scheme() {
        // EF is invisible on the wire: measured bytes equal the inner
        // scheme's (the compensated values quantize to same-size frames)
        let n = 1000;
        let ef = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        let dq = CodecSpec::directq(4, 4);
        assert_eq!(ef.fw_wire_bytes(n, false), dq.fw_wire_bytes(n, false));
        assert_eq!(ef.bw_wire_bytes(n), dq.bw_wire_bytes(n));
    }

    #[test]
    fn spec_string_round_trips() {
        for s in example_specs() {
            let spec = CodecSpec::parse(s).unwrap();
            let canon = spec.spec_string();
            assert_eq!(CodecSpec::parse(&canon).unwrap(), spec, "{s} -> {canon}");
        }
    }

    #[test]
    fn labels_match_paper_vocabulary() {
        assert_eq!(CodecSpec::fp32().label(), "FP32");
        assert_eq!(CodecSpec::fp16().label(), "FP16");
        assert_eq!(CodecSpec::aqsgd(2, 4).label(), "AQ-SGD fw2 bw4");
        assert_eq!(CodecSpec::directq(3, 6).label(), "DirectQ fw3 bw6");
        assert_eq!(CodecSpec::topk(0.2, 8).label(), "TopK 20% @8");
        assert_eq!(
            CodecSpec::parse("hybrid:aq2/topk0.2@8").unwrap().label(),
            "fw aq2 / bw topk0.2@8"
        );
    }

    #[test]
    fn measured_wire_bytes_track_scheme() {
        let n = 1000;
        let fp32 = CodecSpec::fp32().fw_wire_bytes(n, false);
        assert!(fp32 >= 4 * n as u64, "fp32 {fp32}");
        assert!(fp32 < 4 * n as u64 + 64, "fp32 header overhead too large: {fp32}");
        let fp16 = CodecSpec::fp16().fw_wire_bytes(n, false);
        assert!(fp16 > 2 * n as u64 && fp16 < 2 * n as u64 + 64);
        let aq = CodecSpec::aqsgd(2, 4);
        // first epoch full precision, steady state ~2 bits/element
        assert!(aq.fw_wire_bytes(n, true) >= 4 * n as u64);
        let steady = aq.fw_wire_bytes(n, false);
        assert!(steady < n as u64, "aq2 steady {steady}");
        assert!(aq.bw_wire_bytes(n) < 4 * n as u64 / 7);
        // topk 20% @8: ~20% indices (4B) + 20% codes (1B)
        let tk = CodecSpec::topk(0.2, 8).bw_wire_bytes(n);
        assert!(tk < 4 * n as u64 / 3, "topk {tk}");
    }

    #[test]
    fn parse_adaptive_family() {
        // tile applies the same tile length with per-direction budgets
        assert_eq!(
            CodecSpec::parse("tile:64:directq:fw2bw4").unwrap(),
            CodecSpec {
                fw: SchemeSpec::Tile { t: 64, bits: 2 },
                bw: SchemeSpec::Tile { t: 64, bits: 4 },
            }
        );
        // single-direction shorthand applies one scheme to both
        assert_eq!(
            CodecSpec::parse("tile:16:q4").unwrap(),
            CodecSpec {
                fw: SchemeSpec::Tile { t: 16, bits: 4 },
                bw: SchemeSpec::Tile { t: 16, bits: 4 },
            }
        );
        assert_eq!(
            CodecSpec::parse("had:q4").unwrap().fw,
            SchemeSpec::Had { inner: Box::new(SchemeSpec::DirectQ { bits: 4 }) }
        );
        assert_eq!(
            CodecSpec::parse("lr:4:q4").unwrap().fw,
            SchemeSpec::Lr { rank: 4, inner: Box::new(SchemeSpec::DirectQ { bits: 4 }) }
        );
        // wrappers nest: rotation over tiles, ef over lr, lr in hybrids
        let spec = CodecSpec::parse("had:tile:64:directq:fw2bw4").unwrap();
        assert_eq!(spec.spec_string(), "had:tile:64:directq:fw2bw4");
        assert!(CodecSpec::parse("ef:lr:2:q4").is_ok());
        assert!(CodecSpec::parse("hybrid:lr:2:q4/fp16").is_ok());
        assert!(CodecSpec::parse("hybrid:had:q2/tile:32:q4").is_ok());
    }

    #[test]
    fn adaptive_family_labels_and_strings_round_trip() {
        for s in ["tile:64:directq:fw2bw4", "had:tile:64:directq:fw2bw4", "lr:4:directq:fw4bw4"] {
            let spec = CodecSpec::parse(s).unwrap();
            assert_eq!(spec.spec_string(), s, "canonical form is stable");
            assert!(!spec.label().is_empty());
        }
        assert_eq!(CodecSpec::parse("tile:64:directq:fw2bw4").unwrap().label(), "Tile64 fw2 bw4");
        assert_eq!(
            CodecSpec::parse("lr:4:directq:fw4bw4").unwrap().label(),
            "LR r4 DirectQ fw4 bw4"
        );
    }

    #[test]
    fn nested_wrapper_rejections_name_token_and_position() {
        // tile:0:fp32 — the zero tile length is the offending token
        let err = CodecSpec::parse("tile:0:fp32").unwrap_err().to_string();
        assert!(err.contains("tile length 0"), "{err}");
        assert!(err.contains("byte 5"), "{err}");
        // tile with a non-quantizer inner names the inner and its offset
        let err = CodecSpec::parse("tile:64:fp32").unwrap_err().to_string();
        assert!(err.contains("direct quantizer"), "{err}");
        assert!(err.contains("byte 8"), "{err}");
        // ef:lr:4 — lr's missing inner, positioned inside the ef wrapper
        let err = CodecSpec::parse("ef:lr:4").unwrap_err().to_string();
        assert!(err.contains("missing inner"), "{err}");
        assert!(err.contains("\"4\""), "{err}");
        assert!(err.contains("byte 6"), "{err}");
        // lr:4 at top level
        let err = CodecSpec::parse("lr:4").unwrap_err().to_string();
        assert!(err.contains("missing inner"), "{err}");
        // bad rank / rank out of range
        let err = CodecSpec::parse("lr:0:q4").unwrap_err().to_string();
        assert!(err.contains("lr rank 0 out of range"), "{err}");
        let err = CodecSpec::parse("lr:x:q4").unwrap_err().to_string();
        assert!(err.contains("bad lr rank \"x\""), "{err}");
        // had: with nothing after it
        let err = CodecSpec::parse("had:").unwrap_err().to_string();
        assert!(err.contains("missing inner scheme"), "{err}");
        // a typo nested two wrappers deep still names its true position
        let err = CodecSpec::parse("ef:had:nope").unwrap_err().to_string();
        assert!(err.contains("\"nope\""), "{err}");
        assert!(err.contains("byte 7"), "{err}");
    }

    #[test]
    fn adaptive_family_first_visits() {
        assert!(SchemeSpec::parse("lr:4:q4").unwrap().has_first_visit());
        assert!(SchemeSpec::parse("ef:lr:4:q4").unwrap().has_first_visit());
        assert!(!SchemeSpec::parse("tile:64:q4").unwrap().has_first_visit());
        assert!(!SchemeSpec::parse("had:q4").unwrap().has_first_visit());
        assert!(SchemeSpec::parse("had:aq2").unwrap().has_first_visit());
    }

    #[test]
    fn example_specs_cover_every_grammar_production() {
        use std::collections::BTreeSet;
        let mut covered = BTreeSet::new();
        for s in example_specs() {
            let spec = CodecSpec::parse(s).unwrap();
            spec.fw.productions(&mut covered);
            spec.bw.productions(&mut covered);
        }
        for p in grammar_productions() {
            assert!(covered.contains(p), "production {p:?} missing from example_specs");
        }
    }

    #[test]
    fn every_example_spec_builds() {
        for s in example_specs() {
            let spec = CodecSpec::parse(s).unwrap();
            for scheme in [&spec.fw, &spec.bw] {
                let (mut enc, mut dec) =
                    build_mem_pair(scheme, 16, Rounding::Nearest, 1).unwrap();
                let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
                let f = enc.encode(&[0], &a).unwrap();
                let out = dec.decode(&[0], &f).unwrap();
                assert_eq!(out.len(), a.len(), "{s}");
            }
        }
    }
}
