//! Low-rank delta codec: `lr:<rank>:<inner>` (the CompactFusion-style
//! quantized-cache + low-rank baseline, SNIPPETS.md snippet 1).
//!
//! Like AQ-SGD, both halves keep a per-record baseline `m` and ship the
//! change `Δ = x − m`; unlike AQ-SGD, the change is first projected
//! onto a rank-`r` orthonormal sketch `Q` of the recent delta stream:
//!
//! ```text
//! c = Q Δ                       r coefficients, sent as f32
//! resid = Δ − Qᵀ c              the part the sketch misses
//! frame = coeffs ++ inner.encode(resid)
//! Δ̂ = Qᵀ c + inner.decode(...)  both sides reconstruct identically
//! m ← m + Δ̂;  Q ← oja(Q, Δ̂)     replica-symmetric advance
//! ```
//!
//! Activation deltas across adjacent steps are strongly low-rank (the
//! CompactFusion observation), so the `r` exactly-transmitted
//! coefficients carry most of the energy and the inner quantizer only
//! sees the small residual. The sketch is updated by streaming power
//! iteration — one Oja step per decoded delta, then a Gram–Schmidt
//! re-orthonormalization per message — driven *only* by wire-derived
//! values (`Δ̂`, never `Δ`), which is what keeps the sender's and
//! receiver's sketches bit-identical without ever shipping `Q`. The
//! sender learns `inner.decode(...)` the same way `ef:` does: through a
//! replica of the receiver's inner decoder.
//!
//! Frame format (tag 9):
//!
//! ```text
//! header : rank: u8 | el: u32 | n_records: u32
//! payload: per record, in batch order:
//!            0x00 | el × f32          lossless first visit
//!            0x01 | rank × f32        projection coefficients
//!          then, if any delta record: one embedded inner-codec frame
//!          of all residual rows (records in delta order)
//! ```
//!
//! The basis is initialized to a deterministic orthonormal "comb"
//! (row r is uniform over positions `j % rank == r`), so both halves —
//! and the python golden-fixture generator — start identical without
//! sharing any RNG state.

use super::frame::{FrameBuf, FrameReader, FrameView, TAG_LR};
use super::quantizer::UniformQuantizer;
use super::{encode_to_frame, BoundaryCodec, EncodeStats, Frame};
use crate::store::ActivationStore;
use crate::util::error::Result;

const REC_FULL: u8 = 0;
const REC_DELTA: u8 = 1;

/// Oja step size for the streaming power iteration. Any fixed value
/// keeps the halves in lockstep (both run the same update on the same
/// wire-derived deltas); 0.5 converges in a few messages on the
/// near-stationary delta streams this codec sees.
const ETA: f32 = 0.5;

/// Rank-`r` orthonormal sketch of the delta stream. All arithmetic is
/// sequential f32 in pinned loop order — the golden fixtures depend on
/// the exact operation sequence.
struct Sketch {
    rank: usize,
    el: usize,
    /// row-major `rank × el`
    basis: Vec<f32>,
}

impl Sketch {
    fn new(rank: usize, el: usize) -> Self {
        assert!(
            rank >= 1 && rank <= el,
            "sketch rank {rank} out of range for {el}-element records"
        );
        let mut s = Sketch { rank, el, basis: vec![0.0; rank * el] };
        for r in 0..rank {
            s.reinit_row(r);
        }
        s
    }

    /// Deterministic orthonormal init (and degenerate-row fallback):
    /// row `r` is a unit-norm comb over positions `j % rank == r`.
    fn reinit_row(&mut self, r: usize) {
        let (rank, el) = (self.rank, self.el);
        let count = (el - r + rank - 1) / rank;
        let v = (count as f32).sqrt().recip();
        let row = &mut self.basis[r * el..(r + 1) * el];
        row.fill(0.0);
        let mut j = r;
        while j < el {
            row[j] = v;
            j += rank;
        }
    }

    fn dot_row(&self, r: usize, d: &[f32]) -> f32 {
        let row = &self.basis[r * self.el..(r + 1) * self.el];
        let mut acc = 0f32;
        for (b, x) in row.iter().zip(d) {
            acc += b * x;
        }
        acc
    }

    /// `row -= Σ_r c_r · basis_r`, r ascending.
    fn subtract_projection(&self, coeffs: &[f32], row: &mut [f32]) {
        for (r, &c) in coeffs.iter().enumerate() {
            let b = &self.basis[r * self.el..(r + 1) * self.el];
            for (rv, bv) in row.iter_mut().zip(b) {
                *rv -= c * bv;
            }
        }
    }

    /// `row += Σ_r c_r · basis_r`, r ascending.
    fn add_projection(&self, coeffs: &[f32], row: &mut [f32]) {
        for (r, &c) in coeffs.iter().enumerate() {
            let b = &self.basis[r * self.el..(r + 1) * self.el];
            for (rv, bv) in row.iter_mut().zip(b) {
                *rv += c * bv;
            }
        }
    }

    /// One streaming power-iteration (Oja) step toward the dominant
    /// delta directions: `b_r += η (b_r · d) d`.
    fn update(&mut self, d: &[f32]) {
        for r in 0..self.rank {
            let g = ETA * self.dot_row(r, d);
            let row = &mut self.basis[r * self.el..(r + 1) * self.el];
            for (bv, dv) in row.iter_mut().zip(d) {
                *bv += g * dv;
            }
        }
    }

    /// Modified Gram–Schmidt, run once per message after the Oja steps.
    /// A row that collapses to ~zero norm is re-seeded from the comb
    /// init — deterministic, so the halves stay in lockstep.
    fn orthonormalize(&mut self) {
        let el = self.el;
        for r in 0..self.rank {
            let degenerate = {
                let (head, tail) = self.basis.split_at_mut(r * el);
                let row = &mut tail[..el];
                for p in 0..r {
                    let prev = &head[p * el..(p + 1) * el];
                    let mut proj = 0f32;
                    for (bv, pv) in row.iter().zip(prev.iter()) {
                        proj += bv * pv;
                    }
                    for (bv, pv) in row.iter_mut().zip(prev.iter()) {
                        *bv -= proj * pv;
                    }
                }
                let mut norm2 = 0f32;
                for &bv in row.iter() {
                    norm2 += bv * bv;
                }
                if norm2 > 1e-30 {
                    let inv = norm2.sqrt().recip();
                    for bv in row.iter_mut() {
                        *bv *= inv;
                    }
                    false
                } else {
                    true
                }
            };
            if degenerate {
                self.reinit_row(r);
            }
        }
    }

    fn bytes(&self) -> u64 {
        4 * self.basis.len() as u64
    }
}

/// Encoder-only state: the inner-decoder replica plus encode scratch.
struct EncSide {
    /// Replica of the receiver's inner decoder — advances through the
    /// same embedded frames, so the sender reconstructs exactly what
    /// the receiver will (the `ef:` argument).
    replica: Box<dyn BoundaryCodec>,
    /// per-message residual rows (delta order), the inner codec's input
    resid: Vec<f32>,
    /// one delta-row scratch
    delta: Vec<f32>,
    /// embedded inner-frame scratch
    sub: FrameBuf,
    stats: EncodeStats,
}

/// The `lr:` wrapper codec. Built through the registry
/// (`lr:4:directq:fw4bw4`, `lr:2:q4`, ...).
pub struct LrCodec {
    el: usize,
    ns: u32,
    /// inner residual codec: the encoder half holds the inner encoder,
    /// the decoder half the inner decoder
    inner: Box<dyn BoundaryCodec>,
    /// per-record baselines `m` (both halves, advanced in lockstep)
    store: Box<dyn ActivationStore>,
    sketch: Sketch,
    /// per-message scratch shared by both halves, reused across messages
    ids_delta: Vec<u64>,
    delta_pos: Vec<u32>,
    coeffs: Vec<f32>,
    /// inner-decoded residual rows, overwritten in place with Δ̂
    deq: Vec<f32>,
    m: Vec<f32>,
    enc: Option<EncSide>,
}

impl LrCodec {
    /// Effective rank: a configured rank above the record length is
    /// clamped (a 4-element record cannot have 8 independent
    /// directions), never an error — the registry builds schemes at
    /// whatever `example_len` the boundary has.
    fn eff_rank(rank: u8, el: usize) -> usize {
        (rank as usize).min(el).max(1)
    }

    /// The sending half: inner encoder + receiver-decoder replica +
    /// baseline store.
    pub fn encoder(
        rank: u8,
        inner_enc: Box<dyn BoundaryCodec>,
        replica_dec: Box<dyn BoundaryCodec>,
        store: Box<dyn ActivationStore>,
        el: usize,
        ns: u32,
    ) -> Self {
        LrCodec {
            el,
            ns,
            inner: inner_enc,
            store,
            sketch: Sketch::new(Self::eff_rank(rank, el), el),
            ids_delta: Vec::new(),
            delta_pos: Vec::new(),
            coeffs: Vec::new(),
            deq: Vec::new(),
            m: Vec::new(),
            enc: Some(EncSide {
                replica: replica_dec,
                resid: Vec::new(),
                delta: Vec::new(),
                sub: FrameBuf::new(),
                stats: EncodeStats::default(),
            }),
        }
    }

    /// The receiving half.
    pub fn decoder(
        rank: u8,
        inner_dec: Box<dyn BoundaryCodec>,
        store: Box<dyn ActivationStore>,
        el: usize,
        ns: u32,
    ) -> Self {
        LrCodec {
            el,
            ns,
            inner: inner_dec,
            store,
            sketch: Sketch::new(Self::eff_rank(rank, el), el),
            ids_delta: Vec::new(),
            delta_pos: Vec::new(),
            coeffs: Vec::new(),
            deq: Vec::new(),
            m: Vec::new(),
            enc: None,
        }
    }

    fn check(&self, ids: &[u64], tag: u8, header: &[u8]) -> Result<()> {
        crate::ensure!(tag == TAG_LR, "lr codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let (rank, el, n_rec) = (h.u8()?, h.u32()? as usize, h.u32()? as usize);
        h.done()?;
        crate::ensure!(
            rank as usize == self.sketch.rank,
            "lr frame has rank {rank}, boundary is configured for {}",
            self.sketch.rank
        );
        crate::ensure!(
            el == self.el,
            "lr frame has {el}-element records, boundary expects {}",
            self.el
        );
        crate::ensure!(
            n_rec == ids.len(),
            "lr frame carries {n_rec} records, boundary expects {}",
            ids.len()
        );
        Ok(())
    }
}

/// Shared sender/receiver advance: overwrite each inner-decoded
/// residual row with the full reconstructed delta (`resid + Qᵀc`),
/// advance that record's baseline, then feed every reconstructed delta
/// through one power-iteration step. Both halves run exactly this code
/// on exactly the wire-derived values — that is the whole replica
///-symmetry argument, so keep it a single function.
fn apply_deltas(
    sketch: &mut Sketch,
    store: &mut dyn ActivationStore,
    ns: u32,
    ids_delta: &[u64],
    coeffs: &[f32],
    deq: &mut [f32],
    m: &mut Vec<f32>,
    mut emit: impl FnMut(usize, &[f32]),
) -> Result<()> {
    let el = sketch.el;
    let rank = sketch.rank;
    for (k, id) in ids_delta.iter().enumerate() {
        let dh = &mut deq[k * el..(k + 1) * el];
        sketch.add_projection(&coeffs[k * rank..(k + 1) * rank], dh);
        let key = (ns, *id);
        crate::ensure!(store.get(key, m), "lr delta for record {id} with no baseline");
        crate::ensure!(
            m.len() == el,
            "lr baseline for record {id} has {} elements, want {el}",
            m.len()
        );
        for (mv, dv) in m.iter_mut().zip(dh.iter()) {
            *mv += *dv;
        }
        store.put(key, m);
        emit(k, m);
    }
    // sketch updates run after all reconstructions: every coefficient
    // in this message was computed against the pre-message basis
    for k in 0..ids_delta.len() {
        sketch.update(&deq[k * el..(k + 1) * el]);
    }
    sketch.orthonormalize();
    Ok(())
}

impl BoundaryCodec for LrCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        let el = self.el;
        let rank = self.sketch.rank;
        let enc = self
            .enc
            .as_mut()
            .ok_or_else(|| crate::err!("lr decoder half cannot encode (build the encoder half)"))?;
        crate::ensure!(!ids.is_empty(), "lr transfer with no record ids");
        crate::ensure!(
            a.len() == ids.len() * el,
            "lr message length {} != {} ids x {} elements",
            a.len(),
            ids.len(),
            el
        );
        // fail fast on NaN/Inf before any store or sketch state advances
        UniformQuantizer::checked_scale(a)?;
        out.start(TAG_LR);
        out.u8(rank as u8).u32(el as u32).u32(ids.len() as u32);
        out.end_header();
        self.ids_delta.clear();
        self.delta_pos.clear();
        self.coeffs.clear();
        enc.resid.clear();
        let mut first_visits = 0usize;
        let mut abs_sum = 0f64;
        for (i, id) in ids.iter().enumerate() {
            let row = &a[i * el..(i + 1) * el];
            let key = (self.ns, *id);
            if self.store.get(key, &mut self.m) {
                crate::ensure!(
                    self.m.len() == el,
                    "lr baseline for record {id} has {} elements, want {el}",
                    self.m.len()
                );
                enc.delta.clear();
                enc.delta.extend(row.iter().zip(&self.m).map(|(x, m)| x - m));
                // finite x minus finite m can still overflow to ±inf
                UniformQuantizer::checked_scale(&enc.delta)?;
                out.u8(REC_DELTA);
                let c0 = self.coeffs.len();
                for r in 0..rank {
                    let c = self.sketch.dot_row(r, &enc.delta);
                    self.coeffs.push(c);
                    out.f32(c);
                }
                let start = enc.resid.len();
                enc.resid.extend_from_slice(&enc.delta);
                self.sketch.subtract_projection(&self.coeffs[c0..], &mut enc.resid[start..]);
                for &d in enc.delta.iter() {
                    abs_sum += d.abs() as f64;
                }
                self.ids_delta.push(*id);
                self.delta_pos.push(i as u32);
            } else {
                // Algorithm-1-style lossless first visit
                out.u8(REC_FULL);
                out.f32_slice(row);
                self.store.put(key, row);
                first_visits += 1;
            }
        }
        let n_delta = self.ids_delta.len();
        if n_delta == 0 {
            enc.stats = EncodeStats { mean_abs_delta: None, first_visits };
            return out.finish();
        }
        // residual rows ride through the inner codec as one embedded
        // sub-frame at the end of the payload
        self.inner.encode_into(&self.ids_delta, &enc.resid, &mut enc.sub)?;
        out.bytes(enc.sub.as_bytes());
        out.finish()?;
        // replica decode: learn the receiver's exact reconstruction,
        // then advance baselines + sketch exactly like the receiver
        self.deq.resize(n_delta * el, 0.0);
        enc.replica.decode_into(&self.ids_delta, &enc.sub.view(), &mut self.deq)?;
        enc.stats = EncodeStats {
            mean_abs_delta: Some(abs_sum / (n_delta * el) as f64),
            first_visits,
        };
        apply_deltas(
            &mut self.sketch,
            self.store.as_mut(),
            self.ns,
            &self.ids_delta,
            &self.coeffs,
            &mut self.deq,
            &mut self.m,
            |_k, _row| {},
        )
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let mut out = vec![0f32; ids.len() * self.el];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        self.check(ids, frame.tag(), frame.header())?;
        let el = self.el;
        let rank = self.sketch.rank;
        crate::ensure!(
            out.len() == ids.len() * el,
            "lr frame has {} elements, boundary expects {}",
            ids.len() * el,
            out.len()
        );
        let mut p = FrameReader::new(frame.payload());
        self.ids_delta.clear();
        self.delta_pos.clear();
        self.coeffs.clear();
        for (i, id) in ids.iter().enumerate() {
            let kind = p.u8()?;
            let row = &mut out[i * el..(i + 1) * el];
            match kind {
                REC_FULL => {
                    p.f32_into(row)?;
                    self.store.put((self.ns, *id), row);
                }
                REC_DELTA => {
                    crate::ensure!(
                        self.store.contains((self.ns, *id)),
                        "lr delta for record {id} with no baseline (no full visit decoded)"
                    );
                    for _ in 0..rank {
                        self.coeffs.push(p.f32()?);
                    }
                    self.ids_delta.push(*id);
                    self.delta_pos.push(i as u32);
                }
                other => crate::bail!("lr frame has unknown record kind {other}"),
            }
        }
        if self.ids_delta.is_empty() {
            return p.done();
        }
        let sub = p.bytes(p.remaining())?;
        let view = FrameView::parse(sub)?;
        self.deq.resize(self.ids_delta.len() * el, 0.0);
        self.inner.decode_into(&self.ids_delta, &view, &mut self.deq)?;
        let pos = &self.delta_pos;
        apply_deltas(
            &mut self.sketch,
            self.store.as_mut(),
            self.ns,
            &self.ids_delta,
            &self.coeffs,
            &mut self.deq,
            &mut self.m,
            |k, row| {
                let i = pos[k] as usize;
                out[i * el..(i + 1) * el].copy_from_slice(row);
            },
        )
    }

    fn label(&self) -> String {
        format!("lr:{}:{}", self.sketch.rank, self.inner.label())
    }

    /// Baselines + sketch + the inner codec's own state. Both halves
    /// carry the same three pieces, advanced through the same frames —
    /// the property tests pin sender/receiver equality.
    fn state_bytes(&self) -> u64 {
        self.store.resident_bytes() + self.sketch.bytes() + self.inner.state_bytes()
    }

    fn take_stats(&mut self) -> EncodeStats {
        self.enc.as_mut().map(|e| std::mem::take(&mut e.stats)).unwrap_or_default()
    }

    fn set_workers(&mut self, threads: usize) {
        self.inner.set_workers(threads);
        if let Some(enc) = &mut self.enc {
            enc.replica.set_workers(threads);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::registry::{build_mem_pair, SchemeSpec};
    use crate::codec::Rounding;
    use crate::util::Rng;

    fn pair(spec: &str, el: usize, seed: u64) -> (Box<dyn BoundaryCodec>, Box<dyn BoundaryCodec>) {
        let scheme = SchemeSpec::parse(spec).unwrap();
        build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap()
    }

    #[test]
    fn comb_init_is_orthonormal() {
        for (rank, el) in [(1usize, 5usize), (2, 6), (3, 7), (4, 4)] {
            let s = Sketch::new(rank, el);
            for r in 0..rank {
                for q in 0..rank {
                    let mut dot = 0f64;
                    for j in 0..el {
                        dot += (s.basis[r * el + j] as f64) * (s.basis[q * el + j] as f64);
                    }
                    let want = if r == q { 1.0 } else { 0.0 };
                    assert!((dot - want).abs() < 1e-6, "rank {rank} el {el}: <{r},{q}> = {dot}");
                }
            }
        }
    }

    #[test]
    fn first_visit_is_lossless_then_deltas_flow() {
        let el = 12;
        let (mut enc, mut dec) = pair("lr:3:q4", el, 5);
        let mut rng = Rng::new(2);
        let x0: Vec<f32> = (0..el).map(|_| rng.normal()).collect();
        let f0 = enc.encode(&[7], &x0).unwrap();
        assert_eq!(dec.decode(&[7], &f0).unwrap(), x0, "first visit must be exact");
        // second visit: small drift, reconstruction tracks it closely
        let x1: Vec<f32> = x0.iter().map(|v| v + 0.01).collect();
        let f1 = enc.encode(&[7], &x1).unwrap();
        let out = dec.decode(&[7], &f1).unwrap();
        for (x, y) in x1.iter().zip(&out) {
            assert!((x - y).abs() < 0.01, "{x} vs {y}");
        }
        // the delta frame is far smaller than the full visit
        assert!(f1.wire_bytes() < f0.wire_bytes(), "{} vs {}", f1.wire_bytes(), f0.wire_bytes());
    }

    #[test]
    fn replica_symmetry_over_serialized_frames() {
        // sender and receiver advance baselines AND sketches through the
        // wire alone: state_bytes equal every round, reconstructions
        // bit-identical between wire and memory paths
        let el = 10;
        let (mut enc, mut dec) = pair("lr:2:q4", el, 9);
        let mut rng = Rng::new(4);
        let mut x: Vec<f32> = (0..2 * el).map(|_| rng.normal()).collect();
        for round in 0..5 {
            let f = enc.encode(&[1, 2], &x).unwrap();
            let wire = Frame::from_bytes(&f.to_bytes()).unwrap();
            let out = dec.decode(&[1, 2], &wire).unwrap();
            assert_eq!(out.len(), x.len());
            assert_eq!(enc.state_bytes(), dec.state_bytes(), "round {round}");
            for v in x.iter_mut() {
                *v += 0.02 * rng.normal();
            }
        }
    }

    #[test]
    fn sketch_captures_a_dominant_direction() {
        // drive a rank-1 delta stream; after a few messages the sketch
        // should absorb it, shrinking the residual the inner codec sees
        let el = 16;
        let (mut enc, mut dec) = pair("lr:1:q8", el, 3);
        let mut rng = Rng::new(8);
        let dir: Vec<f32> = (0..el).map(|_| rng.normal()).collect();
        let mut x = vec![0f32; el];
        let f = enc.encode(&[0], &x).unwrap();
        dec.decode(&[0], &f).unwrap();
        let mut last_err = f64::MAX;
        for step in 1..=6 {
            for (xv, dv) in x.iter_mut().zip(&dir) {
                *xv += 0.1 * dv * (1.0 + 0.01 * step as f32);
            }
            let f = enc.encode(&[0], &x).unwrap();
            let out = dec.decode(&[0], &f).unwrap();
            let err: f64 = x.iter().zip(&out).map(|(a, b)| ((a - b) as f64).abs()).sum();
            last_err = err;
        }
        // reconstruction of a low-rank stream is tight at 8-bit residual
        assert!(last_err < 0.01 * el as f64, "final err {last_err}");
    }

    #[test]
    fn rank_clamps_to_record_length() {
        // rank 8 on 3-element records: builds, runs, header says 3
        let el = 3;
        let (mut enc, mut dec) = pair("lr:8:q4", el, 1);
        let x = vec![0.5f32, -0.25, 0.125];
        let f0 = enc.encode(&[0], &x).unwrap();
        dec.decode(&[0], &f0).unwrap();
        let f1 = enc.encode(&[0], &x).unwrap();
        assert_eq!(f1.header()[0], 3, "effective rank in header");
        assert_eq!(dec.decode(&[0], &f1).unwrap().len(), el);
    }

    #[test]
    fn hostile_frames_are_errors_not_panics() {
        let el = 8;
        let (mut enc, mut dec) = pair("lr:2:q4", el, 6);
        let x = vec![0.25f32; el];
        let f0 = enc.encode(&[0], &x).unwrap();
        dec.decode(&[0], &f0).unwrap();
        let f1 = enc.encode(&[0], &x).unwrap();
        // unknown record kind
        let mut payload = f1.payload().to_vec();
        payload[0] = 7;
        assert!(dec.decode(&[0], &Frame::new(f1.tag(), f1.header().to_vec(), payload)).is_err());
        // truncated embedded sub-frame
        let cut = f1.payload().len() - 3;
        let bad = Frame::new(f1.tag(), f1.header().to_vec(), f1.payload()[..cut].to_vec());
        assert!(dec.decode(&[0], &bad).is_err());
        // delta for a record the receiver has never seen in full
        assert!(dec.decode(&[99], &f1).is_err());
        // rank/el/count mismatches in the header
        for (off, val) in [(0usize, 5u8), (1, 99), (5, 9)] {
            let mut hdr = f1.header().to_vec();
            hdr[off] = val;
            assert!(dec.decode(&[0], &Frame::new(f1.tag(), hdr, f1.payload().to_vec())).is_err());
        }
        // non-finite input rejected before any state advances
        let before = enc.state_bytes();
        let mut nan = x.clone();
        nan[1] = f32::NAN;
        assert!(enc.encode(&[0], &nan).is_err());
        assert_eq!(enc.state_bytes(), before);
    }

    #[test]
    fn decoder_half_cannot_encode() {
        let (_, mut dec) = pair("lr:2:q4", 8, 1);
        let err = dec.encode(&[0], &vec![0.1f32; 8]).unwrap_err();
        assert!(err.to_string().contains("decoder half"), "{err}");
    }

    #[test]
    fn composes_with_stateful_and_wrapper_inners() {
        // lr over AQ (nested stores) and ef over lr both advance in
        // lockstep across serialized frames
        for spec in ["lr:2:aq4", "ef:lr:2:q4"] {
            let el = 6;
            let (mut enc, mut dec) = pair(spec, el, 11);
            let mut rng = Rng::new(13);
            let mut x: Vec<f32> = (0..el).map(|_| rng.normal()).collect();
            for round in 0..4 {
                let f = enc.encode(&[3], &x).unwrap();
                let wire = Frame::from_bytes(&f.to_bytes()).unwrap();
                let out = dec.decode(&[3], &wire).unwrap();
                assert_eq!(out.len(), el, "{spec} round {round}");
                assert_eq!(enc.state_bytes(), dec.state_bytes(), "{spec} round {round}");
                for v in x.iter_mut() {
                    *v += 0.01 * rng.normal();
                }
            }
        }
    }
}
