//! AQ-SGD delta codec (Algorithm 1 / Algorithm 2): per-example message
//! buffers `m(ξ)` on both sides of a pipeline boundary, with
//! encode = `Q(a - m)` + buffer advance, decode = replica advance.
//!
//! `AqState` is the *native* (pure-rust) implementation used by the
//! simulator, the split-learning example and the data-parallel gradient
//! path; the coordinator's runtime path can alternatively run the L1
//! Pallas `aq_encode/aq_decode` HLO artifacts — both share this exact
//! arithmetic (validated against each other in integration tests).

use super::quantizer::{Rounding, UniformQuantizer};
use crate::util::Rng;

/// One boundary-side AQ-SGD codec. Holds no buffers itself — buffers live
/// in a `store::ActivationStore` so they can be memory- or disk-backed
/// and optionally low-precision (paper Fig. 9e/f).
#[derive(Clone, Copy, Debug)]
pub struct AqState {
    pub quant: UniformQuantizer,
}

/// An encoded AQ message: quantized delta codes + scale, or the
/// first-visit full-precision activation.
#[derive(Clone, Debug)]
pub enum AqMessage {
    /// First visit of an example: full-precision activation (Alg. 1 l.5).
    Full(Vec<f32>),
    /// Subsequent visits: b-bit codes of the delta + its scale.
    Delta { codes: Vec<u8>, scale: f32 },
}

impl AqMessage {
    /// Bytes this message occupies on the wire (packed codes + header).
    pub fn wire_bytes(&self, bits: u8) -> u64 {
        match self {
            AqMessage::Full(v) => 4 * v.len() as u64,
            AqMessage::Delta { codes, .. } => super::quant_wire_bytes(codes.len(), bits),
        }
    }
}

impl AqState {
    pub fn new(bits: u8, rounding: Rounding) -> Self {
        AqState { quant: UniformQuantizer::new(bits, rounding) }
    }

    /// Sender side. `a` is the fresh activation; `m` is the stored message
    /// buffer for this example (`None` on first visit). On return `m_out`
    /// holds the advanced buffer (what the receiver will reconstruct).
    pub fn encode(&self, a: &[f32], m: Option<&[f32]>, m_out: &mut Vec<f32>, rng: &mut Rng) -> AqMessage {
        match m {
            None => {
                m_out.clear();
                m_out.extend_from_slice(a);
                AqMessage::Full(a.to_vec())
            }
            Some(m) => {
                assert_eq!(a.len(), m.len());
                let mut delta: Vec<f32> = a.iter().zip(m).map(|(x, y)| x - y).collect();
                let mut codes = vec![0u8; a.len()];
                let scale = self.quant.encode(&delta, &mut codes, rng);
                // m_new = m + deq(codes): reuse `delta` as scratch
                self.quant.decode(&codes, scale, &mut delta);
                m_out.clear();
                m_out.extend(m.iter().zip(&delta).map(|(x, d)| x + d));
                AqMessage::Delta { codes, scale }
            }
        }
    }

    /// Receiver side: advance the local replica of `m` and return the
    /// activation to feed forward. Must produce *exactly* the sender's
    /// `m_out` (bit-identical replicas — tested).
    pub fn decode(&self, msg: &AqMessage, m: Option<&[f32]>, m_out: &mut Vec<f32>) {
        match (msg, m) {
            (AqMessage::Full(a), _) => {
                m_out.clear();
                m_out.extend_from_slice(a);
            }
            (AqMessage::Delta { codes, scale }, Some(m)) => {
                assert_eq!(codes.len(), m.len());
                let mut deq = vec![0f32; codes.len()];
                self.quant.decode(codes, *scale, &mut deq);
                m_out.clear();
                m_out.extend(m.iter().zip(&deq).map(|(x, d)| x + d));
            }
            (AqMessage::Delta { .. }, None) => {
                panic!("AQ delta message for an example with no buffer")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_stay_identical() {
        let mut rng = Rng::new(1);
        let st = AqState::new(4, Rounding::Nearest);
        let n = 256;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut m_send: Option<Vec<f32>> = None;
        let mut m_recv: Option<Vec<f32>> = None;
        for _ in 0..20 {
            // activation drifts slowly, like a stabilizing model
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
            let mut ms = Vec::new();
            let msg = st.encode(&a, m_send.as_deref(), &mut ms, &mut rng);
            let mut mr = Vec::new();
            st.decode(&msg, m_recv.as_deref(), &mut mr);
            assert_eq!(ms, mr, "sender/receiver buffers diverged");
            m_send = Some(ms);
            m_recv = Some(mr);
        }
    }

    #[test]
    fn buffer_tracks_activation() {
        // the self-enforcing dynamic: with small drift, m stays within one
        // quantization step of a.
        let mut rng = Rng::new(2);
        let st = AqState::new(4, Rounding::Nearest);
        let n = 128;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut m: Option<Vec<f32>> = None;
        for it in 0..50 {
            for v in a.iter_mut() {
                *v += 0.005 * rng.normal();
            }
            let mut m2 = Vec::new();
            let msg = st.encode(&a, m.as_deref(), &mut m2, &mut rng);
            if it > 0 {
                if let AqMessage::Delta { scale, .. } = msg {
                    let bound = st.quant.error_bound(scale) + 1e-6;
                    for (x, y) in a.iter().zip(&m2) {
                        assert!((x - y).abs() <= bound);
                    }
                }
            }
            m = Some(m2);
        }
    }

    #[test]
    fn first_visit_is_lossless() {
        let mut rng = Rng::new(3);
        let st = AqState::new(2, Rounding::Nearest);
        let a: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut m = Vec::new();
        let msg = st.encode(&a, None, &mut m, &mut rng);
        assert!(matches!(msg, AqMessage::Full(_)));
        assert_eq!(m, a);
    }

    #[test]
    fn delta_beats_direct_on_drifting_signal() {
        // the paper's Figure 1b argument: after warm-up, |delta| << |a|,
        // so AQ reconstruction error is far below DirectQ's at equal bits.
        let mut rng = Rng::new(4);
        let bits = 2;
        let st = AqState::new(bits, Rounding::Nearest);
        let dq = UniformQuantizer::new(bits, Rounding::Nearest);
        let n = 512;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal() * 5.0).collect();
        let mut m: Option<Vec<f32>> = None;
        let mut aq_err = 0f64;
        let mut dq_err = 0f64;
        for it in 0..30 {
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
            let mut m2 = Vec::new();
            st.encode(&a, m.as_deref(), &mut m2, &mut rng);
            if it >= 5 {
                aq_err += a.iter().zip(&m2).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
                let xh = dq.roundtrip(&a, &mut rng);
                dq_err += a.iter().zip(&xh).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
            }
            m = Some(m2);
        }
        assert!(aq_err * 20.0 < dq_err, "aq {aq_err} vs dq {dq_err}");
    }
}
