//! AQ-SGD delta codec (Algorithm 1 / Algorithm 2): per-example message
//! buffers `m(ξ)` on both sides of a pipeline boundary, with
//! encode = `Q(a - m)` + buffer advance, decode = replica advance.
//!
//! Two layers live here:
//!  * [`AqState`] — the bare single-record arithmetic (used by the
//!    tensor-parallel all-reduce in `codec::tp` and by benches/tests).
//!  * [`AqCodec`] — the full [`BoundaryCodec`]: batches of records keyed
//!    by example id, buffers in an `ActivationStore`, framed wire
//!    messages, and the optional L1-Pallas HLO kernel path. Sender and
//!    receiver each hold their own `AqCodec`; their stores stay
//!    bit-identical because both advance through the same [`Frame`]
//!    (Algorithm 2's invariant — pinned by property tests).

use std::sync::Arc;

use super::frame::{Frame, FrameBuf, FrameReader, FrameView, TAG_AQ};
use super::par::Workers;
use super::quantizer::{Rounding, UniformQuantizer};
use super::{encode_to_frame, pack, BoundaryCodec, EncodeStats};
use crate::runtime::QuantRuntime;
use crate::store::ActivationStore;
use crate::util::error::Result;
use crate::util::Rng;

/// One boundary-side AQ-SGD codec. Holds no buffers itself — buffers live
/// in a `store::ActivationStore` so they can be memory- or disk-backed
/// and optionally low-precision (paper Fig. 9e/f).
#[derive(Clone, Copy, Debug)]
pub struct AqState {
    pub quant: UniformQuantizer,
}

/// An encoded AQ message: quantized delta codes + scale, or the
/// first-visit full-precision activation.
#[derive(Clone, Debug)]
pub enum AqMessage {
    /// First visit of an example: full-precision activation (Alg. 1 l.5).
    Full(Vec<f32>),
    /// Subsequent visits: b-bit codes of the delta + its scale.
    Delta { codes: Vec<u8>, scale: f32 },
}

impl AqMessage {
    /// Bytes this message occupies on the wire (packed codes + header).
    pub fn wire_bytes(&self, bits: u8) -> u64 {
        match self {
            AqMessage::Full(v) => 4 * v.len() as u64,
            AqMessage::Delta { codes, .. } => super::quant_wire_bytes(codes.len(), bits),
        }
    }
}

impl AqState {
    pub fn new(bits: u8, rounding: Rounding) -> Self {
        AqState { quant: UniformQuantizer::new(bits, rounding) }
    }

    /// Sender side. `a` is the fresh activation; `m` is the stored message
    /// buffer for this example (`None` on first visit). On return `m_out`
    /// holds the advanced buffer (what the receiver will reconstruct).
    pub fn encode(
        &self,
        a: &[f32],
        m: Option<&[f32]>,
        m_out: &mut Vec<f32>,
        rng: &mut Rng,
    ) -> AqMessage {
        match m {
            None => {
                m_out.clear();
                m_out.extend_from_slice(a);
                AqMessage::Full(a.to_vec())
            }
            Some(m) => {
                assert_eq!(a.len(), m.len());
                let mut delta: Vec<f32> = a.iter().zip(m).map(|(x, y)| x - y).collect();
                let mut codes = vec![0u8; a.len()];
                let scale = self.quant.encode(&delta, &mut codes, rng);
                // m_new = m + deq(codes): reuse `delta` as scratch
                self.quant.decode(&codes, scale, &mut delta);
                m_out.clear();
                m_out.extend(m.iter().zip(&delta).map(|(x, d)| x + d));
                AqMessage::Delta { codes, scale }
            }
        }
    }

    /// Receiver side: advance the local replica of `m` and return the
    /// activation to feed forward. Must produce *exactly* the sender's
    /// `m_out` (bit-identical replicas — tested). A delta message for an
    /// example with no buffer is a protocol violation from the peer and
    /// returns an error instead of aborting the process.
    pub fn decode(&self, msg: &AqMessage, m: Option<&[f32]>, m_out: &mut Vec<f32>) -> Result<()> {
        match (msg, m) {
            (AqMessage::Full(a), _) => {
                m_out.clear();
                m_out.extend_from_slice(a);
                Ok(())
            }
            (AqMessage::Delta { codes, scale }, Some(m)) => {
                crate::ensure!(
                    codes.len() == m.len(),
                    "AQ delta length {} does not match buffer length {}",
                    codes.len(),
                    m.len()
                );
                let mut deq = vec![0f32; codes.len()];
                self.quant.decode(codes, *scale, &mut deq);
                m_out.clear();
                m_out.extend(m.iter().zip(&deq).map(|(x, d)| x + d));
                Ok(())
            }
            (AqMessage::Delta { .. }, None) => {
                crate::bail!("AQ delta message for an example with no buffer")
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// Record kinds inside an AQ frame (mode-0 payload).
const REC_FULL: u8 = 0;
const REC_DELTA: u8 = 1;
/// Frame modes: per-example records vs one batch-wide scale (HLO path).
const MODE_PER_EXAMPLE: u8 = 0;
const MODE_BATCH_SCALE: u8 = 1;

/// The AQ-SGD [`BoundaryCodec`]: frame format (tag 4)
///
/// ```text
/// header:  bits: u8 | el: u32 | n_rec: u32 | mode: u8
/// payload (mode 0): per example, in id order:
///     kind: u8 (0 = full, 1 = delta)
///     full:  el × f32 LE
///     delta: scale: f32 | packed_len(el, bits) code bytes
/// payload (mode 1): scale: f32 | packed_len(n_rec · el, bits) code bytes
/// ```
///
/// Mode 1 is emitted by the Pallas-HLO kernel path (one scale per batch,
/// only when every example in the batch has a buffer); mode 0 is the
/// native per-example path that also handles mixed first-visit batches.
pub struct AqCodec {
    bits: u8,
    quant: UniformQuantizer,
    store: Box<dyn ActivationStore>,
    /// key namespace (the boundary id) for store keys
    ns: u32,
    el: usize,
    rng: Rng,
    hlo: Option<Arc<QuantRuntime>>,
    stats: EncodeStats,
    /// per-record scratch (message buffer / codes / delta), reused across
    /// records and messages so the steady-state path never allocates
    m: Vec<f32>,
    codes: Vec<u8>,
    delta: Vec<f32>,
    /// whole-batch buffer replica scratch for the batch-scale frame mode
    batch_m: Vec<f32>,
    workers: Workers,
}

impl AqCodec {
    pub fn new(
        bits: u8,
        rounding: Rounding,
        store: Box<dyn ActivationStore>,
        ns: u32,
        seed: u64,
        hlo: Option<Arc<QuantRuntime>>,
    ) -> Self {
        let el = store.record_len();
        AqCodec {
            bits,
            quant: UniformQuantizer::new(bits, rounding),
            store,
            ns,
            el,
            rng: Rng::new(seed),
            hlo,
            stats: EncodeStats::default(),
            m: Vec::new(),
            codes: Vec::new(),
            delta: Vec::new(),
            batch_m: Vec::new(),
            workers: Workers::seq(),
        }
    }

    fn check_batch(&self, ids: &[u64], n: usize) -> Result<()> {
        crate::ensure!(!ids.is_empty(), "AQ transfer with no example ids");
        crate::ensure!(
            n == ids.len() * self.el,
            "AQ activation length {n} != {} ids x {} elements",
            ids.len(),
            self.el
        );
        Ok(())
    }

    fn check_header(&self, ids: &[u64], tag: u8, header: &[u8]) -> Result<(usize, u8)> {
        crate::ensure!(tag == TAG_AQ, "AQ codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let (bits, el, n_rec, mode) = (h.u8()?, h.u32()? as usize, h.u32()? as usize, h.u8()?);
        h.done()?;
        crate::ensure!(
            bits == self.bits,
            "AQ frame is {bits}-bit but this boundary is configured for {}",
            self.bits
        );
        crate::ensure!(el == self.el, "AQ frame record length {el}, boundary has {}", self.el);
        crate::ensure!(
            n_rec == ids.len(),
            "AQ frame has {n_rec} records for {} example ids",
            ids.len()
        );
        Ok((n_rec, mode))
    }

    /// HLO batch path: one kernel call over [B·el] with a single scale,
    /// framed directly into the caller's scratch buffer like the native
    /// path (no intermediate owned frame).
    fn encode_batch_hlo(
        &mut self,
        q: &Arc<QuantRuntime>,
        ids: &[u64],
        a: &[f32],
        out: &mut FrameBuf,
    ) -> Result<()> {
        let el = self.el;
        // assemble the batch buffer replica in the codec's scratch (the
        // kernel's own outputs are runtime-owned allocations)
        self.batch_m.resize(a.len(), 0.0);
        for (i, &ex) in ids.iter().enumerate() {
            self.store.get((self.ns, ex), &mut self.m);
            self.batch_m[i * el..(i + 1) * el].copy_from_slice(&self.m);
        }
        let (codes, scale, m_new) = q.aq_encode(a, &self.batch_m, self.bits)?;
        let delta_abs_sum: f64 =
            a.iter().zip(&self.batch_m).map(|(x, y)| (x - y).abs() as f64).sum();
        self.stats = EncodeStats {
            mean_abs_delta: Some(delta_abs_sum / a.len() as f64),
            first_visits: 0,
        };
        for (i, &ex) in ids.iter().enumerate() {
            self.store.put((self.ns, ex), &m_new[i * el..(i + 1) * el]);
        }
        out.start(TAG_AQ);
        out.u8(self.bits).u32(el as u32).u32(ids.len() as u32).u8(MODE_BATCH_SCALE);
        out.end_header();
        out.f32(scale);
        let packed = out.reserve_zeroed(pack::packed_len(codes.len(), self.bits));
        pack::pack_into(&codes, self.bits, packed);
        out.finish()
    }
}

impl BoundaryCodec for AqCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        self.check_batch(ids, a.len())?;
        let el = self.el;

        // The HLO (Pallas-kernel) path works on the whole [B,S,D] tensor
        // with one scale; valid when the batch is uniformly revisit.
        // Mixed batches (partial epochs) fall back to the native path.
        let all_present = ids.iter().all(|&ex| self.store.contains((self.ns, ex)));
        if let Some(q) = self.hlo.clone() {
            if all_present && q.n_elements() == a.len() {
                return self.encode_batch_hlo(&q, ids, a, out);
            }
        }

        // native per-example path, built in the caller's scratch frame
        out.start(TAG_AQ);
        out.u8(self.bits).u32(el as u32).u32(ids.len() as u32).u8(MODE_PER_EXAMPLE);
        out.end_header();
        self.delta.resize(el, 0.0);
        let mut delta_abs_sum = 0f64;
        let mut first_visits = 0usize;
        for (i, &ex) in ids.iter().enumerate() {
            let row = &a[i * el..(i + 1) * el];
            if self.store.get((self.ns, ex), &mut self.m) {
                crate::ensure!(
                    self.m.len() == el,
                    "stored buffer for example {ex} has {} elements, want {el}",
                    self.m.len()
                );
                for j in 0..el {
                    self.delta[j] = row[j] - self.m[j];
                }
                delta_abs_sum += crate::util::stats::mean_abs(&self.delta) * el as f64;
                // fused path: validate finiteness (a NaN activation makes
                // the delta NaN), then quantize the delta straight into
                // the packed payload — no u8 staging buffer
                let scale = UniformQuantizer::checked_scale(&self.delta)?;
                out.u8(REC_DELTA).f32(scale);
                let packed = out.reserve_zeroed(pack::packed_len(el, self.bits));
                let pool = self.workers;
                let q = self.quant;
                q.encode_packed_with_scale(&self.delta, scale, packed, &mut self.rng, &pool);
                // m += deq(packed) — both replicas run this exact op
                self.quant.decode_packed_add(packed, scale, &mut self.m, &pool);
                self.store.put((self.ns, ex), &self.m);
            } else {
                // first visit: full precision (Algorithm 1 line 5;
                // lossless, so non-finite values pass through unchanged)
                first_visits += 1;
                delta_abs_sum += crate::util::stats::mean_abs(row) * el as f64;
                self.store.put((self.ns, ex), row);
                out.u8(REC_FULL).f32_slice(row);
            }
        }
        self.stats = EncodeStats {
            mean_abs_delta: Some(delta_abs_sum / a.len() as f64),
            first_visits,
        };
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let mut out = vec![0f32; ids.len() * self.el];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let (n_rec, mode) = self.check_header(ids, frame.tag(), frame.header())?;
        let el = self.el;
        crate::ensure!(
            out.len() == n_rec * el,
            "AQ frame has {n_rec} x {el} elements, boundary expects {}",
            out.len()
        );
        let mut p = FrameReader::new(frame.payload());
        match mode {
            MODE_BATCH_SCALE => {
                let scale = p.f32()?;
                let packed = p.bytes(pack::packed_len(n_rec * el, self.bits))?;
                p.done()?;
                // assemble the local buffer replica; every record must exist
                self.batch_m.resize(n_rec * el, 0.0);
                for (i, &ex) in ids.iter().enumerate() {
                    crate::ensure!(
                        self.store.get((self.ns, ex), &mut self.m),
                        "AQ delta frame for example {ex} with no message buffer"
                    );
                    crate::ensure!(
                        self.m.len() == el,
                        "stored buffer for example {ex} has {} elements, want {el}",
                        self.m.len()
                    );
                    self.batch_m[i * el..(i + 1) * el].copy_from_slice(&self.m);
                }
                match &self.hlo {
                    Some(q) if q.n_elements() == self.batch_m.len() => {
                        self.codes.resize(n_rec * el, 0);
                        pack::unpack_into(packed, self.bits, &mut self.codes);
                        let v = q.aq_decode(&self.codes, scale, &self.batch_m, self.bits)?;
                        crate::ensure!(
                            v.len() == self.batch_m.len(),
                            "hlo aq_decode returned {} elements for a {}-element batch",
                            v.len(),
                            self.batch_m.len()
                        );
                        self.batch_m.copy_from_slice(&v);
                    }
                    _ => {
                        // fused unpack + buffer advance, chunked
                        let pool = self.workers;
                        self.quant.decode_packed_add(packed, scale, &mut self.batch_m, &pool);
                    }
                }
                for (i, &ex) in ids.iter().enumerate() {
                    self.store.put((self.ns, ex), &self.batch_m[i * el..(i + 1) * el]);
                }
                out.copy_from_slice(&self.batch_m);
            }
            MODE_PER_EXAMPLE => {
                for (i, &ex) in ids.iter().enumerate() {
                    match p.u8()? {
                        REC_FULL => {
                            let dst = &mut out[i * el..(i + 1) * el];
                            p.f32_into(dst)?;
                            self.store.put((self.ns, ex), dst);
                        }
                        REC_DELTA => {
                            let scale = p.f32()?;
                            let packed = p.bytes(pack::packed_len(el, self.bits))?;
                            crate::ensure!(
                                self.store.get((self.ns, ex), &mut self.m),
                                "AQ delta frame for example {ex} with no message buffer"
                            );
                            crate::ensure!(
                                self.m.len() == el,
                                "stored buffer for example {ex} has {} elements, want {el}",
                                self.m.len()
                            );
                            let pool = self.workers;
                            self.quant.decode_packed_add(packed, scale, &mut self.m, &pool);
                            self.store.put((self.ns, ex), &self.m);
                            out[i * el..(i + 1) * el].copy_from_slice(&self.m);
                        }
                        kind => crate::bail!("unknown AQ record kind {kind}"),
                    }
                }
                p.done()?;
            }
            other => crate::bail!("unknown AQ frame mode {other}"),
        }
        Ok(())
    }

    fn label(&self) -> String {
        format!("aq{}", self.bits)
    }

    fn state_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }

    fn take_stats(&mut self) -> EncodeStats {
        std::mem::take(&mut self.stats)
    }

    fn set_workers(&mut self, threads: usize) {
        self.workers = Workers::new(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    #[test]
    fn replicas_stay_identical() {
        let mut rng = Rng::new(1);
        let st = AqState::new(4, Rounding::Nearest);
        let n = 256;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut m_send: Option<Vec<f32>> = None;
        let mut m_recv: Option<Vec<f32>> = None;
        for _ in 0..20 {
            // activation drifts slowly, like a stabilizing model
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
            let mut ms = Vec::new();
            let msg = st.encode(&a, m_send.as_deref(), &mut ms, &mut rng);
            let mut mr = Vec::new();
            st.decode(&msg, m_recv.as_deref(), &mut mr).unwrap();
            assert_eq!(ms, mr, "sender/receiver buffers diverged");
            m_send = Some(ms);
            m_recv = Some(mr);
        }
    }

    #[test]
    fn buffer_tracks_activation() {
        // the self-enforcing dynamic: with small drift, m stays within one
        // quantization step of a.
        let mut rng = Rng::new(2);
        let st = AqState::new(4, Rounding::Nearest);
        let n = 128;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mut m: Option<Vec<f32>> = None;
        for it in 0..50 {
            for v in a.iter_mut() {
                *v += 0.005 * rng.normal();
            }
            let mut m2 = Vec::new();
            let msg = st.encode(&a, m.as_deref(), &mut m2, &mut rng);
            if it > 0 {
                if let AqMessage::Delta { scale, .. } = msg {
                    let bound = st.quant.error_bound(scale) + 1e-6;
                    for (x, y) in a.iter().zip(&m2) {
                        assert!((x - y).abs() <= bound);
                    }
                }
            }
            m = Some(m2);
        }
    }

    #[test]
    fn first_visit_is_lossless() {
        let mut rng = Rng::new(3);
        let st = AqState::new(2, Rounding::Nearest);
        let a: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut m = Vec::new();
        let msg = st.encode(&a, None, &mut m, &mut rng);
        assert!(matches!(msg, AqMessage::Full(_)));
        assert_eq!(m, a);
    }

    #[test]
    fn delta_without_buffer_is_an_error_not_a_panic() {
        let st = AqState::new(4, Rounding::Nearest);
        let msg = AqMessage::Delta { codes: vec![1, 2, 3], scale: 0.5 };
        let mut m_out = Vec::new();
        let err = st.decode(&msg, None, &mut m_out).unwrap_err();
        assert!(err.to_string().contains("no buffer"), "{err}");
    }

    #[test]
    fn delta_beats_direct_on_drifting_signal() {
        // the paper's Figure 1b argument: after warm-up, |delta| << |a|,
        // so AQ reconstruction error is far below DirectQ's at equal bits.
        let mut rng = Rng::new(4);
        let bits = 2;
        let st = AqState::new(bits, Rounding::Nearest);
        let dq = UniformQuantizer::new(bits, Rounding::Nearest);
        let n = 512;
        let mut a: Vec<f32> = (0..n).map(|_| rng.normal() * 5.0).collect();
        let mut m: Option<Vec<f32>> = None;
        let mut aq_err = 0f64;
        let mut dq_err = 0f64;
        for it in 0..30 {
            for v in a.iter_mut() {
                *v += 0.01 * rng.normal();
            }
            let mut m2 = Vec::new();
            st.encode(&a, m.as_deref(), &mut m2, &mut rng);
            if it >= 5 {
                aq_err += a.iter().zip(&m2).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
                let xh = dq.roundtrip(&a, &mut rng);
                dq_err += a.iter().zip(&xh).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>();
            }
            m = Some(m2);
        }
        assert!(aq_err * 20.0 < dq_err, "aq {aq_err} vs dq {dq_err}");
    }

    // ---- AqCodec (framed) ----

    fn pair(bits: u8, el: usize) -> (AqCodec, AqCodec) {
        let mk = || Box::new(MemStore::new(el));
        (
            AqCodec::new(bits, Rounding::Nearest, mk(), 0, 1, None),
            AqCodec::new(bits, Rounding::Nearest, mk(), 0, 2, None),
        )
    }

    #[test]
    fn codec_first_visit_lossless_then_delta() {
        let (mut enc, mut dec) = pair(2, 8);
        let mut rng = Rng::new(5);
        let a: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let f1 = enc.encode(&[0, 1], &a).unwrap();
        let out1 = dec.decode(&[0, 1], &f1).unwrap();
        assert_eq!(out1, a, "first visit must be lossless");
        assert_eq!(enc.take_stats().first_visits, 2);
        let a2: Vec<f32> = a.iter().map(|x| x + 0.01).collect();
        let f2 = enc.encode(&[0, 1], &a2).unwrap();
        let out2 = dec.decode(&[0, 1], &f2).unwrap();
        let (w1, w2) = (f1.wire_bytes(), f2.wire_bytes());
        assert!(w2 * 2 < w1, "{w2} vs {w1}");
        for (x, y) in a2.iter().zip(&out2) {
            assert!((x - y).abs() < 0.02, "{x} {y}");
        }
        // replica symmetry: identical state on both sides
        assert_eq!(enc.state_bytes(), dec.state_bytes());
    }

    #[test]
    fn codec_mixed_batch_and_malformed_frames() {
        let (mut enc, mut dec) = pair(4, 8);
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let f = enc.encode(&[0, 1], &a).unwrap();
        dec.decode(&[0, 1], &f).unwrap();
        // one known + one new example
        let f2 = enc.encode(&[1, 7], &a).unwrap();
        assert_eq!(enc.take_stats().first_visits, 1);
        dec.decode(&[1, 7], &f2).unwrap();
        // delta frame for an unseen decoder is an error, not a panic
        let a3: Vec<f32> = a.iter().map(|x| x + 0.01).collect();
        let f3 = enc.encode(&[0, 1], &a3).unwrap();
        let (_, mut fresh_dec) = pair(4, 8);
        let err = fresh_dec.decode(&[0, 1], &f3).unwrap_err();
        assert!(err.to_string().contains("no message buffer"), "{err}");
        // id-count mismatch
        assert!(dec.decode(&[0], &f3).is_err());
        // truncated payload
        let cut = Frame::new(f3.tag(), f3.header().to_vec(), f3.payload()[..3].to_vec());
        assert!(dec.decode(&[0, 1], &cut).is_err());
    }

    #[test]
    fn codec_wire_bytes_are_measured_from_buffers() {
        let (mut enc, _) = pair(4, 8);
        let a: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let f = enc.encode(&[0, 1], &a).unwrap();
        assert_eq!(f.wire_bytes(), f.to_bytes().len() as u64);
        assert_eq!(
            f.wire_bytes(),
            (crate::codec::frame::FRAME_PRELUDE_BYTES + f.header().len() + f.payload().len()) as u64
        );
    }
}
