//! Error-feedback wrapper codec: `ef:<inner>` (paper §4.3 / Fig. 5).
//!
//! The "QuantizedAdam"-style gradient compressor keeps a residual `e`
//! per record and feeds quantization error back into the next round:
//!
//! ```text
//! c = g + e;   frame = inner.encode(c);   e = c - deq(frame)
//! ```
//!
//! so the compression bias vanishes over time (the 1-bit-Adam property
//! the end-to-end-compression experiments rely on). `EfCodec` composes
//! over *any* registered inner codec:
//!
//!  * the **encoder half** owns the residuals plus a bit-exact replica
//!    of the receiver's decoder — like `AqCodec`'s replica stores, the
//!    sender learns what the receiver will reconstruct by decoding its
//!    own frames, so both sides agree on `deq` without extra traffic;
//!  * the **decoder half** *is* the inner decoder — error feedback is
//!    sender-side only and invisible on the wire. Frames carry the inner
//!    scheme's tag and layout, which is why the golden gradient-frame
//!    fixtures for `ef:directq` pin plain DirectQ images of the
//!    compensated values.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use super::{encode_to_frame, BoundaryCodec, EncodeStats, Frame, FrameBuf, FrameView};
use crate::util::error::Result;

/// Encoder-side error-feedback state.
struct Feedback {
    /// Replica of the receiver's decoder half; it advances through the
    /// same frames the receiver sees, so `deq` here is bit-identical to
    /// the receiver's reconstruction.
    replica: Box<dyn BoundaryCodec>,
    /// Elements per record.
    el: usize,
    /// Residuals keyed by record id (zero until first visit).
    residual: HashMap<u64, Vec<f32>>,
    stats: EncodeStats,
    /// compensated-message scratch (`c = g + e`), reused across messages
    c: Vec<f32>,
    /// replica-reconstruction scratch (`deq`), reused across messages
    deq: Vec<f32>,
}

/// The `ef:` wrapper. Built through the registry (`ef:q4`,
/// `ef:directq:fw4bw4`, ...) like every other scheme; rounding mode and
/// rng seed flow in through the spec's `BuildCtx`, not a constructor
/// side-channel.
pub struct EfCodec {
    inner: Box<dyn BoundaryCodec>,
    fb: Option<Feedback>,
}

impl EfCodec {
    /// The sending half: inner encoder + receiver-decoder replica +
    /// residual store.
    pub fn encoder(
        inner_enc: Box<dyn BoundaryCodec>,
        replica_dec: Box<dyn BoundaryCodec>,
        el: usize,
    ) -> Self {
        EfCodec {
            inner: inner_enc,
            fb: Some(Feedback {
                replica: replica_dec,
                el,
                residual: HashMap::new(),
                stats: EncodeStats::default(),
                c: Vec::new(),
                deq: Vec::new(),
            }),
        }
    }

    /// The receiving half: error feedback is sender-side only, so this
    /// is a thin wrapper over the inner decoder.
    pub fn decoder(inner_dec: Box<dyn BoundaryCodec>) -> Self {
        EfCodec { inner: inner_dec, fb: None }
    }

    /// Bytes of sender-local residual state. Reported separately from
    /// [`BoundaryCodec::state_bytes`] — see that impl for why.
    pub fn residual_bytes(&self) -> u64 {
        self.fb
            .as_ref()
            .map_or(0, |fb| fb.residual.values().map(|v| 4 * v.len() as u64).sum())
    }
}

impl BoundaryCodec for EfCodec {
    fn encode(&mut self, ids: &[u64], g: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, g)
    }

    fn encode_into(&mut self, ids: &[u64], g: &[f32], out: &mut FrameBuf) -> Result<()> {
        // split-borrow the inner encoder away from the feedback state so
        // both can be used in one pass
        let EfCodec { inner, fb } = self;
        let fb = fb
            .as_mut()
            .ok_or_else(|| crate::err!("ef decoder half cannot encode (build the encoder half)"))?;
        crate::ensure!(!ids.is_empty(), "ef transfer with no record ids");
        crate::ensure!(
            g.len() == ids.len() * fb.el,
            "ef message length {} != {} ids x {} elements",
            g.len(),
            ids.len(),
            fb.el
        );
        let el = fb.el;
        // c = g + e (residual defaults to zero on first visit)
        fb.c.clear();
        fb.c.extend_from_slice(g);
        let mut first_visits = 0usize;
        for (i, id) in ids.iter().enumerate() {
            match fb.residual.get(id) {
                Some(e) => {
                    crate::ensure!(
                        e.len() == el,
                        "ef residual for record {id} has {} elements, want {el}",
                        e.len()
                    );
                    for (cv, ev) in fb.c[i * el..(i + 1) * el].iter_mut().zip(e) {
                        *cv += ev;
                    }
                }
                None => first_visits += 1,
            }
        }
        inner.encode_into(ids, &fb.c, out)?;
        // e = c - deq, with deq read back through the receiver replica so
        // both sides agree bit-for-bit on what crossed the wire (the
        // replica decode also validates the reconstruction shape)
        fb.deq.resize(fb.c.len(), 0.0);
        fb.replica.decode_into(ids, &out.view(), &mut fb.deq)?;
        for (i, id) in ids.iter().enumerate() {
            let cs = &fb.c[i * el..(i + 1) * el];
            let ds = &fb.deq[i * el..(i + 1) * el];
            match fb.residual.entry(*id) {
                Entry::Occupied(mut e) => {
                    // overwrite in place: the steady-state path keeps the
                    // existing row allocation
                    let row = e.get_mut();
                    row.clear();
                    row.extend(cs.iter().zip(ds).map(|(cv, dv)| cv - dv));
                }
                Entry::Vacant(v) => {
                    v.insert(cs.iter().zip(ds).map(|(cv, dv)| cv - dv).collect());
                }
            }
        }
        fb.stats = EncodeStats {
            mean_abs_delta: Some(crate::util::stats::mean_abs(&fb.c)),
            first_visits,
        };
        Ok(())
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        self.inner.decode(ids, frame)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        self.inner.decode_into(ids, frame, out)
    }

    fn label(&self) -> String {
        format!("ef:{}", self.inner.label())
    }

    /// Wire-replicated state only (the inner codec's message buffers).
    /// The encoder's residuals are sender-local — not a replica of
    /// anything on the receiver — so they live in
    /// [`EfCodec::residual_bytes`] instead, keeping the sender/receiver
    /// `state_bytes` symmetry the frame property tests pin.
    fn state_bytes(&self) -> u64 {
        self.inner.state_bytes()
    }

    fn take_stats(&mut self) -> EncodeStats {
        self.fb.as_mut().map(|fb| std::mem::take(&mut fb.stats)).unwrap_or_default()
    }

    /// Forward the worker-count knob to the inner codec — and to the
    /// receiver-decoder replica, which must run the exact same kernels
    /// (bytes are worker-count independent, so symmetry is about code
    /// paths, not correctness of the residuals).
    fn set_workers(&mut self, threads: usize) {
        self.inner.set_workers(threads);
        if let Some(fb) = &mut self.fb {
            fb.replica.set_workers(threads);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::registry::{build_mem_pair, SchemeSpec};
    use crate::codec::Rounding;
    use crate::util::Rng;

    fn pair(spec: &str, el: usize, seed: u64) -> (Box<dyn BoundaryCodec>, Box<dyn BoundaryCodec>) {
        let scheme = SchemeSpec::parse(spec).unwrap();
        build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap()
    }

    #[test]
    fn feedback_preserves_signal_over_time() {
        // summed over many rounds, deq(frames) ~ summed inputs: the error
        // feedback makes the per-round quantization bias vanish.
        let n = 64;
        let (mut enc, mut dec) = pair("ef:q4", n, 3);
        let mut rng = Rng::new(3);
        let constant: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
        let mut acc = vec![0f64; n];
        let rounds = 200;
        for _ in 0..rounds {
            let g: Vec<f32> = constant.iter().map(|&c| c + 0.001 * rng.normal()).collect();
            let frame = enc.encode(&[0], &g).unwrap();
            let deq = dec.decode(&[0], &frame).unwrap();
            for (a, &d) in acc.iter_mut().zip(&deq) {
                *a += d as f64;
            }
        }
        for (a, &c) in acc.iter().zip(&constant) {
            let avg = *a / rounds as f64;
            assert!((avg - c as f64).abs() < 3e-3, "{avg} vs {c}");
        }
    }

    #[test]
    fn wire_format_is_the_inner_frame() {
        // EF adds zero wire overhead: the first-visit frame (residual 0)
        // is byte-identical to the inner codec encoding the same values.
        let n = 32;
        let mut rng = Rng::new(5);
        let g: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        let (mut ef_enc, _) = pair("ef:q4", n, 9);
        let (mut dq_enc, _) = pair("q4", n, 9);
        let fe = ef_enc.encode(&[0], &g).unwrap();
        let fd = dq_enc.encode(&[0], &g).unwrap();
        assert_eq!(fe.to_bytes(), fd.to_bytes());
        assert_eq!(fe.tag(), crate::codec::frame::TAG_DIRECTQ);
    }

    #[test]
    fn residual_tracks_quantization_error() {
        use crate::codec::schemes::DirectQCodec;
        let n = 16;
        let mut enc = EfCodec::encoder(
            Box::new(DirectQCodec::new(2, Rounding::Nearest, 1, None)),
            Box::new(DirectQCodec::new(2, Rounding::Nearest, 2, None)),
            n,
        );
        assert_eq!(enc.residual_bytes(), 0);
        let g = vec![0.01f32; n];
        let frame = enc.encode(&[0], &g).unwrap();
        // residual now holds exactly the round-1 quantization error
        assert_eq!(enc.residual_bytes(), 4 * n as u64);
        let mut probe = DirectQCodec::new(2, Rounding::Nearest, 3, None);
        let deq = probe.decode(&[0], &frame).unwrap();
        let e: Vec<f32> = g.iter().zip(&deq).map(|(a, b)| a - b).collect();
        let fb = enc.fb.as_ref().unwrap();
        assert_eq!(fb.residual.get(&0).unwrap(), &e);
    }

    #[test]
    fn decoder_half_cannot_encode() {
        let (_, mut dec) = pair("ef:q4", 8, 1);
        let g = vec![0.1f32; 8];
        let err = dec.encode(&[0], &g).unwrap_err();
        assert!(err.to_string().contains("decoder half"), "{err}");
    }

    #[test]
    fn shape_mismatch_is_an_error() {
        let (mut enc, _) = pair("ef:q4", 8, 1);
        assert!(enc.encode(&[0, 1], &vec![0.0f32; 8]).is_err());
        assert!(enc.encode(&[], &[]).is_err());
    }

    #[test]
    fn stateful_inner_keeps_replica_symmetry_over_the_wire() {
        // ef over the stateful AQ inner: encoder-side inner store and
        // receiver store must stay byte-equal through serialized frames.
        let n = 12;
        let (mut enc, mut dec) = pair("ef:aq2", n, 7);
        let mut rng = Rng::new(11);
        let mut g: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
        for round in 0..4 {
            let frame = enc.encode(&[0], &g).unwrap();
            let wire = Frame::from_bytes(&frame.to_bytes()).unwrap();
            let out = dec.decode(&[0], &wire).unwrap();
            assert_eq!(out.len(), n);
            assert_eq!(enc.state_bytes(), dec.state_bytes(), "round {round}");
            for v in g.iter_mut() {
                *v += 0.01 * rng.normal();
            }
        }
    }
}
