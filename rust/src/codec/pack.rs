//! Bit-packing of b-bit codes into wire bytes (LSB-first within bytes).
//!
//! This is what actually puts `fw2 bw4`-style messages on the simulated
//! network: `n` codes of `bits` bits occupy `ceil(n*bits/8)` bytes. The
//! hot paths assemble whole `u64` words — 8 codes per word for the
//! generic widths, 16/32/64 codes per word for the 4/2/1-bit fast paths
//! — instead of shifting byte-at-a-time, which is what lets the
//! autovectorizer keep up with memory bandwidth (see EXPERIMENTS.md
//! §Perf). The byte-serial scalar forms are retained as
//! [`pack_scalar`] / [`unpack_scalar`]: they are the reference the
//! kernel property tests (`tests/prop_kernels.rs`) pin the word-based
//! implementations against, bit for bit.
//!
//! Robustness contract (release builds included):
//!  * every code is masked to its low `bits` bits before entering the
//!    accumulator, so an out-of-range code can never corrupt the bits of
//!    its neighbors in the packed stream;
//!  * [`packed_len`] saturates instead of wrapping, so a hostile
//!    header-claimed `n` near `usize::MAX / 8` yields a huge length that
//!    fails the frame-level payload checks rather than under-computing a
//!    buffer size.

/// Packed length in bytes for `n` codes of `bits` bits.
///
/// Uses saturating arithmetic: for hostile `n` where `n * bits` would
/// overflow `usize`, the result saturates near `usize::MAX / 8` instead
/// of wrapping small, so callers comparing it against a real payload
/// length reject the frame cleanly.
#[inline]
pub fn packed_len(n: usize, bits: u8) -> usize {
    n.saturating_mul(bits as usize).saturating_add(7) / 8
}

/// Pack `codes` into `out`; `out` must have `packed_len(codes.len(),
/// bits)` bytes. Each code is masked to its low `bits` bits — values
/// `>= 2^bits` lose their high bits but cannot bleed into neighbors.
pub fn pack_into(codes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&bits));
    debug_assert_eq!(out.len(), packed_len(codes.len(), bits));
    // §Perf fast paths: the paper's bit widths are mostly 2/4/8; whole-
    // word assembly beats the byte-serial accumulator ~4x and the old
    // byte-pair assembly ~2x.
    match bits {
        8 => out.copy_from_slice(codes),
        4 => pack_words::<16, 4>(codes, out),
        2 => pack_words::<32, 2>(codes, out),
        1 => pack_words::<64, 1>(codes, out),
        _ => pack_words_generic(codes, bits, out),
    }
}

/// Whole-word fast path: `LANES` codes of `BITS` bits fill one `u64`
/// (LANES * BITS == 64), written out as 8 little-endian bytes.
fn pack_words<const LANES: usize, const BITS: usize>(codes: &[u8], out: &mut [u8]) {
    let mask = (1u64 << BITS) - 1;
    let full = codes.len() / LANES;
    let (body, tail) = codes.split_at(full * LANES);
    let (out_body, out_tail) = out.split_at_mut(full * 8);
    for (o, c) in out_body.chunks_exact_mut(8).zip(body.chunks_exact(LANES)) {
        let mut w = 0u64;
        for (j, &cj) in c.iter().enumerate() {
            w |= ((cj as u64) & mask) << (j * BITS);
        }
        o.copy_from_slice(&w.to_le_bytes());
    }
    pack_scalar(tail, BITS as u8, out_tail);
}

/// Generic word path (3/5/6/7 bits): 8 codes of `bits` bits fill
/// exactly `bits` output bytes, so every block stays byte-aligned.
fn pack_words_generic(codes: &[u8], bits: u8, out: &mut [u8]) {
    let b = bits as usize;
    let mask = (1u64 << b) - 1;
    let full = codes.len() / 8;
    let (body, tail) = codes.split_at(full * 8);
    let (out_body, out_tail) = out.split_at_mut(full * b);
    for (o, c) in out_body.chunks_exact_mut(b).zip(body.chunks_exact(8)) {
        let mut w = 0u64;
        for (j, &cj) in c.iter().enumerate() {
            w |= ((cj as u64) & mask) << (j * b);
        }
        o.copy_from_slice(&w.to_le_bytes()[..b]);
    }
    pack_scalar(tail, bits, out_tail);
}

/// Byte-serial reference packer (any `bits` 1..=8). Overwrites all of
/// `out`, which must be `packed_len(codes.len(), bits)` bytes. Retained
/// as the property-test reference for the word-based paths; also the
/// tail handler for partial blocks.
pub fn pack_scalar(codes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&bits));
    debug_assert_eq!(out.len(), packed_len(codes.len(), bits));
    let bits = bits as usize;
    let mask = ((1u16 << bits) - 1) as u32;
    let mut acc: u32 = 0;
    let mut acc_bits = 0usize;
    let mut o = 0usize;
    for &c in codes {
        acc |= (c as u32 & mask) << acc_bits;
        acc_bits += bits;
        while acc_bits >= 8 {
            out[o] = (acc & 0xFF) as u8;
            o += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out[o] = (acc & 0xFF) as u8;
    }
}

/// Pack into a fresh buffer (allocating convenience form).
pub fn pack(codes: &[u8], bits: u8) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out);
    out
}

/// Unpack `n` codes of `bits` bits from `bytes` into `out` (length n).
pub fn unpack_into(bytes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&bits));
    debug_assert!(bytes.len() >= packed_len(out.len(), bits));
    match bits {
        8 => out.copy_from_slice(&bytes[..out.len()]),
        4 => unpack_words::<16, 4>(bytes, out),
        2 => unpack_words::<32, 2>(bytes, out),
        1 => unpack_words::<64, 1>(bytes, out),
        _ => unpack_words_generic(bytes, bits, out),
    }
}

/// Whole-word unpack fast path (LANES * BITS == 64).
fn unpack_words<const LANES: usize, const BITS: usize>(bytes: &[u8], out: &mut [u8]) {
    let mask = (1u64 << BITS) - 1;
    let full = out.len() / LANES;
    let (body, tail) = out.split_at_mut(full * LANES);
    for (o, b) in body.chunks_exact_mut(LANES).zip(bytes.chunks_exact(8)) {
        let w = u64::from_le_bytes(b.try_into().unwrap());
        for (j, oj) in o.iter_mut().enumerate() {
            *oj = ((w >> (j * BITS)) & mask) as u8;
        }
    }
    unpack_scalar(&bytes[full * 8..], BITS as u8, tail);
}

/// Generic word unpack (3/5/6/7 bits): `bits` bytes -> 8 codes.
fn unpack_words_generic(bytes: &[u8], bits: u8, out: &mut [u8]) {
    let b = bits as usize;
    let mask = (1u64 << b) - 1;
    let full = out.len() / 8;
    let (body, tail) = out.split_at_mut(full * 8);
    for (o, bs) in body.chunks_exact_mut(8).zip(bytes.chunks_exact(b)) {
        let mut wb = [0u8; 8];
        wb[..b].copy_from_slice(bs);
        let w = u64::from_le_bytes(wb);
        for (j, oj) in o.iter_mut().enumerate() {
            *oj = ((w >> (j * b)) & mask) as u8;
        }
    }
    unpack_scalar(&bytes[full * b..], bits, tail);
}

/// Byte-serial reference unpacker (any `bits` 1..=8): the property-test
/// reference for the word-based paths, and the partial-block tail
/// handler. Reads `packed_len(out.len(), bits)` bytes from `bytes`.
pub fn unpack_scalar(bytes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&bits));
    debug_assert!(bytes.len() >= packed_len(out.len(), bits));
    let bits = bits as usize;
    let mask = ((1u16 << bits) - 1) as u32;
    let mut acc: u32 = 0;
    let mut acc_bits = 0usize;
    let mut i = 0usize;
    for c in out.iter_mut() {
        while acc_bits < bits {
            acc |= (bytes[i] as u32) << acc_bits;
            i += 1;
            acc_bits += 8;
        }
        *c = (acc & mask) as u8;
        acc >>= bits;
        acc_bits -= bits;
    }
}

/// Unpack into a fresh buffer (allocating convenience form).
pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(bytes, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(7);
        for bits in 1..=8u8 {
            for n in [0usize, 1, 7, 8, 9, 64, 1000, 4097] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() as u8) & ((1u16 << bits) - 1) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), packed_len(n, bits));
                let back = unpack(&packed, bits, n);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn word_paths_match_scalar_reference() {
        let mut rng = Rng::new(23);
        for bits in 1..=8u8 {
            for n in [0usize, 1, 7, 9, 15, 16, 17, 63, 64, 65, 509] {
                let codes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let mut fast = vec![0u8; packed_len(n, bits)];
                let mut slow = vec![0u8; packed_len(n, bits)];
                pack_into(&codes, bits, &mut fast);
                pack_scalar(&codes, bits, &mut slow);
                assert_eq!(fast, slow, "pack bits={bits} n={n}");
                let mut out_fast = vec![0u8; n];
                let mut out_slow = vec![0u8; n];
                unpack_into(&fast, bits, &mut out_fast);
                unpack_scalar(&fast, bits, &mut out_slow);
                assert_eq!(out_fast, out_slow, "unpack bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn density_is_tight() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(8, 2), 2);
        assert_eq!(packed_len(3, 3), 2); // 9 bits -> 2 bytes
        assert_eq!(packed_len(4, 6), 3); // 24 bits -> 3 bytes
        assert_eq!(packed_len(5, 8), 5);
    }

    #[test]
    fn packed_len_saturates_on_hostile_lengths() {
        // a header-claimed n near usize::MAX must not wrap to a tiny
        // buffer length (the old `(n * bits + 7) / 8` wrapped for
        // n >= usize::MAX / bits); saturation keeps the result huge so
        // payload-length checks fail the frame cleanly
        for bits in 1..=8u8 {
            let hostile = usize::MAX / 2 + 3;
            assert!(packed_len(hostile, bits) >= hostile / 8, "bits={bits} wrapped");
            assert!(packed_len(usize::MAX, bits) >= usize::MAX / 8, "bits={bits} wrapped");
        }
        // small lengths are exact (saturation is invisible in range)
        assert_eq!(packed_len(9, 3), 4);
    }

    #[test]
    fn max_codes_survive() {
        for bits in 1..=8u8 {
            let max = ((1u16 << bits) - 1) as u8;
            let codes = vec![max; 33];
            assert_eq!(unpack(&pack(&codes, bits), bits, 33), codes);
        }
    }

    #[test]
    fn out_of_range_codes_cannot_bleed_into_neighbors() {
        // runs identically in debug and release (the CI release-asserts
        // job): codes with garbage high bits pack exactly like their
        // masked values, so neighbors always round-trip unharmed
        let mut rng = Rng::new(99);
        for bits in 1..=7u8 {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [1usize, 7, 9, 64, 257] {
                let dirty: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
                let clean: Vec<u8> = dirty.iter().map(|&c| c & mask).collect();
                assert_eq!(pack(&dirty, bits), pack(&clean, bits), "bits={bits} n={n}");
                assert_eq!(unpack(&pack(&dirty, bits), bits, n), clean, "bits={bits} n={n}");
            }
        }
    }
}
