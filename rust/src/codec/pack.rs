//! Bit-packing of b-bit codes into wire bytes (LSB-first within bytes).
//!
//! This is what actually puts `fw2 bw4`-style messages on the simulated
//! network: `n` codes of `bits` bits occupy `ceil(n*bits/8)` bytes. The
//! packer is branch-free per code and is one of the L3 hot paths (see
//! EXPERIMENTS.md §Perf).

/// Packed length in bytes for `n` codes of `bits` bits.
#[inline]
pub fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each < 2^bits) into `out`; `out` must have
/// `packed_len(codes.len(), bits)` bytes.
pub fn pack_into(codes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!(bits >= 1 && bits <= 8);
    debug_assert_eq!(out.len(), packed_len(codes.len(), bits));
    // §Perf fast paths: the paper's bit widths are mostly 2/4/8; direct
    // byte assembly beats the generic shift-accumulator ~3x.
    match bits {
        8 => {
            out.copy_from_slice(codes);
            return;
        }
        4 => {
            let mut it = codes.chunks_exact(2);
            for (o, c) in out.iter_mut().zip(&mut it) {
                *o = c[0] | (c[1] << 4);
            }
            if let [last] = it.remainder() {
                out[codes.len() / 2] = *last;
            }
            return;
        }
        2 => {
            let mut it = codes.chunks_exact(4);
            for (o, c) in out.iter_mut().zip(&mut it) {
                *o = c[0] | (c[1] << 2) | (c[2] << 4) | (c[3] << 6);
            }
            let rem = it.remainder();
            if !rem.is_empty() {
                let mut acc = 0u8;
                for (j, &c) in rem.iter().enumerate() {
                    acc |= c << (2 * j);
                }
                out[codes.len() / 4] = acc;
            }
            return;
        }
        _ => {}
    }
    out.fill(0);
    let bits = bits as usize;
    let mut acc: u32 = 0;
    let mut acc_bits = 0usize;
    let mut o = 0usize;
    for &c in codes {
        debug_assert!((c as u32) < (1u32 << bits));
        acc |= (c as u32) << acc_bits;
        acc_bits += bits;
        while acc_bits >= 8 {
            out[o] = (acc & 0xFF) as u8;
            o += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out[o] = (acc & 0xFF) as u8;
    }
}

pub fn pack(codes: &[u8], bits: u8) -> Vec<u8> {
    let mut out = vec![0u8; packed_len(codes.len(), bits)];
    pack_into(codes, bits, &mut out);
    out
}

/// Unpack `n` codes of `bits` bits from `bytes` into `out` (length n).
pub fn unpack_into(bytes: &[u8], bits: u8, out: &mut [u8]) {
    debug_assert!(bits >= 1 && bits <= 8);
    debug_assert!(bytes.len() >= packed_len(out.len(), bits));
    match bits {
        8 => {
            out.copy_from_slice(&bytes[..out.len()]);
            return;
        }
        4 => {
            let n_pairs = out.len() / 2;
            let mut it = out.chunks_exact_mut(2);
            for (o, &b) in (&mut it).zip(bytes) {
                o[0] = b & 0x0F;
                o[1] = b >> 4;
            }
            let rem = it.into_remainder();
            if let [last] = rem {
                *last = bytes[n_pairs] & 0x0F;
            }
            return;
        }
        2 => {
            let n_quads = out.len() / 4;
            let mut it = out.chunks_exact_mut(4);
            for (o, &b) in (&mut it).zip(bytes) {
                o[0] = b & 0x03;
                o[1] = (b >> 2) & 0x03;
                o[2] = (b >> 4) & 0x03;
                o[3] = b >> 6;
            }
            let rem = it.into_remainder();
            if !rem.is_empty() {
                let b = bytes[n_quads];
                for (j, o) in rem.iter_mut().enumerate() {
                    *o = (b >> (2 * j)) & 0x03;
                }
            }
            return;
        }
        _ => {}
    }
    let bits = bits as usize;
    let mask = ((1u32 << bits) - 1) as u32;
    let mut acc: u32 = 0;
    let mut acc_bits = 0usize;
    let mut i = 0usize;
    for c in out.iter_mut() {
        while acc_bits < bits {
            acc |= (bytes[i] as u32) << acc_bits;
            i += 1;
            acc_bits += 8;
        }
        *c = (acc & mask) as u8;
        acc >>= bits;
        acc_bits -= bits;
    }
}

pub fn unpack(bytes: &[u8], bits: u8, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n];
    unpack_into(bytes, bits, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(7);
        for bits in 1..=8u8 {
            for n in [0usize, 1, 7, 8, 9, 64, 1000, 4097] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() as u8) & ((1u16 << bits) - 1) as u8).collect();
                let packed = pack(&codes, bits);
                assert_eq!(packed.len(), packed_len(n, bits));
                let back = unpack(&packed, bits, n);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn density_is_tight() {
        assert_eq!(packed_len(8, 1), 1);
        assert_eq!(packed_len(8, 2), 2);
        assert_eq!(packed_len(3, 3), 2); // 9 bits -> 2 bytes
        assert_eq!(packed_len(4, 6), 3); // 24 bits -> 3 bytes
        assert_eq!(packed_len(5, 8), 5);
    }

    #[test]
    fn max_codes_survive() {
        for bits in 1..=8u8 {
            let max = ((1u16 << bits) - 1) as u8;
            let codes = vec![max; 33];
            assert_eq!(unpack(&pack(&codes, bits), bits, 33), codes);
        }
    }
}
