//! Tensor-parallel activation compression (paper Appendix F).
//!
//! Under tensor parallelism the output activation is a *sum* of partial
//! activations, A = A_1 + ... + A_N, and compression must be applied
//! twice around the all-reduce:
//!
//! ```text
//! A_Q = Q[ Q(A_1) + Q(A_2) + ... + Q(A_N) ]          (F.2)
//! ```
//!
//! The paper leaves delta compensation here as future work; we implement
//! both the direct double quantization of (F.2) and the AQ-style variant
//! where every Q keeps a per-shard message buffer (delta compensation
//! applied to all Q(-), as App. F conjectures), so the ablation in the
//! tests quantifies how much the conjecture buys.

use super::delta::AqState;
use super::quantizer::{Rounding, UniformQuantizer};
use crate::util::Rng;

/// Direct double quantization (F.2). Returns (reconstructed A_Q,
/// total wire bytes of one all-reduce round).
pub fn direct_tp_allreduce(shards: &[Vec<f32>], bits: u8, rng: &mut Rng) -> (Vec<f32>, u64) {
    let n = shards[0].len();
    let q = UniformQuantizer::new(bits, Rounding::Nearest);
    let mut sum = vec![0f32; n];
    let mut wire = 0u64;
    for a in shards {
        assert_eq!(a.len(), n);
        let ah = q.roundtrip(a, rng);
        wire += super::quant_wire_bytes(n, bits);
        for (s, v) in sum.iter_mut().zip(&ah) {
            *s += v;
        }
    }
    // second quantization of the reduced value (broadcast back)
    let out = q.roundtrip(&sum, rng);
    wire += super::quant_wire_bytes(n, bits) * shards.len() as u64;
    (out, wire)
}

/// AQ-style tensor-parallel all-reduce: every shard and the reduced
/// output keep message buffers; only deltas are quantized. Buffers
/// (`shard_m`, `out_m`) persist across calls (one slot per shard + one
/// for the reduced tensor).
pub struct TpAqAllreduce {
    st: AqState,
    shard_m: Vec<Option<Vec<f32>>>,
    out_m: Option<Vec<f32>>,
    bits: u8,
    rng: Rng,
}

impl TpAqAllreduce {
    pub fn new(n_shards: usize, bits: u8) -> Self {
        TpAqAllreduce {
            st: AqState::new(bits, Rounding::Nearest),
            shard_m: vec![None; n_shards],
            out_m: None,
            bits,
            rng: Rng::new(0xF0),
        }
    }

    pub fn round(&mut self, shards: &[Vec<f32>]) -> (Vec<f32>, u64) {
        assert_eq!(shards.len(), self.shard_m.len());
        let n = shards[0].len();
        let mut sum = vec![0f32; n];
        let mut wire = 0u64;
        for (i, a) in shards.iter().enumerate() {
            let mut m_new = Vec::new();
            let msg = self.st.encode(a, self.shard_m[i].as_deref(), &mut m_new, &mut self.rng);
            wire += msg.wire_bytes(self.bits);
            for (s, v) in sum.iter_mut().zip(&m_new) {
                *s += v;
            }
            self.shard_m[i] = Some(m_new);
        }
        let mut out = Vec::new();
        let msg = self.st.encode(&sum, self.out_m.as_deref(), &mut out, &mut self.rng);
        wire += msg.wire_bytes(self.bits) * shards.len() as u64;
        self.out_m = Some(out.clone());
        (out, wire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shards(n_shards: usize, n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..n_shards).map(|_| (0..n).map(|_| rng.normal()).collect()).collect()
    }

    #[test]
    fn direct_tp_error_bounded() {
        let mut rng = Rng::new(1);
        let sh = shards(4, 256, &mut rng);
        let (out, wire) = direct_tp_allreduce(&sh, 8, &mut rng);
        let true_sum: Vec<f32> =
            (0..256).map(|j| sh.iter().map(|s| s[j]).sum()).collect();
        // double 8-bit quantization: error <= shard errors + final error
        let err: f32 = out
            .iter()
            .zip(&true_sum)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.2, "err {err}");
        assert!(wire > 0);
    }

    #[test]
    fn aq_tp_beats_direct_on_drifting_activations() {
        // App F conjecture: delta compensation helps once activations
        // stabilize across rounds.
        let mut rng = Rng::new(2);
        let n = 512;
        let bits = 4;
        let mut sh = shards(4, n, &mut rng);
        let mut aq = TpAqAllreduce::new(4, bits);
        let mut direct_err = 0f64;
        let mut aq_err = 0f64;
        for round in 0..20 {
            // slow drift, like a stabilizing model
            for s in sh.iter_mut() {
                for v in s.iter_mut() {
                    *v += 0.01 * rng.normal();
                }
            }
            let true_sum: Vec<f32> = (0..n).map(|j| sh.iter().map(|s| s[j]).sum()).collect();
            let (d_out, _) = direct_tp_allreduce(&sh, bits, &mut rng);
            let (a_out, _) = aq.round(&sh);
            if round >= 3 {
                direct_err +=
                    d_out.iter().zip(&true_sum).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>();
                aq_err +=
                    a_out.iter().zip(&true_sum).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>();
            }
        }
        assert!(aq_err * 5.0 < direct_err, "aq {aq_err} vs direct {direct_err}");
    }

    #[test]
    fn aq_tp_first_round_lossless() {
        let mut rng = Rng::new(3);
        let sh = shards(2, 64, &mut rng);
        let mut aq = TpAqAllreduce::new(2, 2);
        let (out, _) = aq.round(&sh);
        let true_sum: Vec<f32> = (0..64).map(|j| sh.iter().map(|s| s[j]).sum()).collect();
        for (a, b) in out.iter().zip(&true_sum) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
