//! Uniform b-bit quantizer — the rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/quant.py`), used by the native boundary codec,
//! the data-parallel gradient compressor, and the low-precision message
//! store. Codes fit in `u8` (bits <= 8 everywhere in the paper).

use crate::util::Rng;

/// Rounding mode: `Nearest` is deterministic round-to-nearest (offset
/// 0.5); `Stochastic` draws the offset from U[0,1), making the quantizer
/// unbiased in expectation (the Theorem 3.1 requirement on Q).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    Nearest,
    Stochastic,
}

#[derive(Clone, Copy, Debug)]
pub struct UniformQuantizer {
    pub bits: u8,
    pub rounding: Rounding,
}

impl UniformQuantizer {
    pub fn new(bits: u8, rounding: Rounding) -> Self {
        assert!((1..=8).contains(&bits), "bits must be in 1..=8, got {bits}");
        UniformQuantizer { bits, rounding }
    }

    #[inline]
    pub fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Per-tensor max-abs scale (same epsilon as ref.quant_scale).
    pub fn scale(x: &[f32]) -> f32 {
        // branch-free fold vectorizes to maxps (§Perf)
        x.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12)
    }

    /// Quantize `x` into `codes` (same length). Returns the scale.
    pub fn encode(&self, x: &[f32], codes: &mut [u8], rng: &mut Rng) -> f32 {
        assert_eq!(x.len(), codes.len());
        let scale = Self::scale(x);
        self.encode_with_scale(x, scale, codes, rng);
        scale
    }

    pub fn encode_with_scale(&self, x: &[f32], scale: f32, codes: &mut [u8], rng: &mut Rng) {
        // §Perf: folded affine form y = v*k + c (2 flops/element instead
        // of 5) and truncating cast instead of floor — valid because the
        // clamp pins y into [0, levels] where trunc == floor. ~2x over
        // the naive (x/scale + 1) * 0.5 * levels form.
        let levels = self.levels();
        let k = 0.5 * levels / scale;
        match self.rounding {
            Rounding::Nearest => {
                let c0 = 0.5 * levels + 0.5;
                for (c, &v) in codes.iter_mut().zip(x) {
                    *c = (v * k + c0).clamp(0.0, levels) as u8;
                }
            }
            Rounding::Stochastic => {
                let c0 = 0.5 * levels;
                for (c, &v) in codes.iter_mut().zip(x) {
                    *c = (v * k + c0 + rng.next_f32()).clamp(0.0, levels) as u8;
                }
            }
        }
    }

    /// Dequantize codes into `out` (overwrites).
    pub fn decode(&self, codes: &[u8], scale: f32, out: &mut [f32]) {
        assert_eq!(codes.len(), out.len());
        let levels = self.levels();
        let k = 2.0 * scale / levels;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = c as f32 * k - scale;
        }
    }

    /// Dequantize and *add* into `out` (the AQ buffer-advance step).
    pub fn decode_add(&self, codes: &[u8], scale: f32, out: &mut [f32]) {
        assert_eq!(codes.len(), out.len());
        let levels = self.levels();
        let k = 2.0 * scale / levels;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o += c as f32 * k - scale;
        }
    }

    /// Convenience round-trip: returns deq(Q(x)).
    pub fn roundtrip(&self, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        let mut codes = vec![0u8; x.len()];
        let scale = self.encode(x, &mut codes, rng);
        let mut out = vec![0f32; x.len()];
        self.decode(&codes, scale, &mut out);
        out
    }

    /// Max per-element reconstruction error (half step for Nearest, one
    /// full step for Stochastic).
    pub fn error_bound(&self, scale: f32) -> f32 {
        let step = 2.0 * scale / self.levels();
        match self.rounding {
            Rounding::Nearest => 0.5 * step,
            Rounding::Stochastic => step,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn roundtrip_error_within_bound() {
        let mut r = rng();
        let x: Vec<f32> = (0..1000).map(|_| r.normal() * 3.0).collect();
        for bits in [2u8, 3, 4, 6, 8] {
            let q = UniformQuantizer::new(bits, Rounding::Nearest);
            let scale = UniformQuantizer::scale(&x);
            let xh = q.roundtrip(&x, &mut r);
            let bound = q.error_bound(scale) + 1e-6;
            for (a, b) in x.iter().zip(&xh) {
                assert!((a - b).abs() <= bound, "bits={bits} {a} vs {b}");
            }
        }
    }

    #[test]
    fn codes_cover_range() {
        let x = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let q = UniformQuantizer::new(2, Rounding::Nearest);
        let mut codes = [0u8; 5];
        let scale = q.encode(&x, &mut codes, &mut rng());
        assert_eq!(scale, 1.0);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[4], 3);
        assert!(codes.iter().all(|&c| c <= 3));
    }

    #[test]
    fn zero_vector_is_stable() {
        let x = [0f32; 16];
        let q = UniformQuantizer::new(4, Rounding::Nearest);
        let xh = q.roundtrip(&x, &mut rng());
        for v in xh {
            assert!(v.abs() < 1e-10);
        }
    }

    #[test]
    fn stochastic_is_unbiased() {
        let mut r = rng();
        let x: Vec<f32> = (0..64).map(|_| r.normal()).collect();
        let q = UniformQuantizer::new(3, Rounding::Stochastic);
        let n = 2000;
        let mut acc = vec![0f64; x.len()];
        for _ in 0..n {
            let xh = q.roundtrip(&x, &mut r);
            for (a, v) in acc.iter_mut().zip(&xh) {
                *a += *v as f64;
            }
        }
        let scale = UniformQuantizer::scale(&x);
        let step = (2.0 * scale / q.levels()) as f64;
        let se = 0.5 * step / (n as f64).sqrt();
        let bias: f64 = x
            .iter()
            .zip(&acc)
            .map(|(&xi, &a)| (a / n as f64 - xi as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(bias <= 2.0 * se * (x.len() as f64).sqrt(), "bias {bias} se {se}");
    }

    #[test]
    fn matches_paper_fig1_regime() {
        // 2-bit direct quantization destroys fine structure; 8-bit keeps it.
        let mut r = rng();
        let x: Vec<f32> = (0..4096).map(|_| r.normal()).collect();
        let err = |bits| {
            let q = UniformQuantizer::new(bits, Rounding::Nearest);
            let xh = q.roundtrip(&x, &mut rng());
            x.iter().zip(&xh).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
        };
        assert!(err(2) > 10.0 * err(8));
    }
}
