//! Uniform b-bit quantizer — the rust mirror of the L1 Pallas kernel
//! (`python/compile/kernels/quant.py`), used by the native boundary codec,
//! the data-parallel gradient compressor, and the low-precision message
//! store. Codes fit in `u8` (bits <= 8 everywhere in the paper).
//!
//! Two kernel tiers:
//!  * the original split path (`encode` -> `u8` codes -> `pack`),
//!    retained as API and as the bit-exact reference the fused kernels
//!    are property-tested against;
//!  * the fused path ([`UniformQuantizer::encode_packed_into`] /
//!    [`UniformQuantizer::decode_packed`]) that quantizes straight into
//!    the packed byte stream, 8 elements per `u64` word, with no `u8`
//!    staging buffer — and runs chunked across a [`Workers`] pool for
//!    large tensors. Stochastic rounding stays bit-reproducible at any
//!    worker count: each encode draws one message seed from the codec
//!    RNG and chunk `i` uses the derived stream
//!    [`UniformQuantizer::chunk_rng`]`(msg_seed, i)`.

use super::pack;
use super::par::{Workers, CHUNK};
use crate::util::error::Result;
use crate::util::Rng;

/// Rounding mode: `Nearest` is deterministic round-to-nearest (offset
/// 0.5); `Stochastic` draws the offset from U[0,1), making the quantizer
/// unbiased in expectation (the Theorem 3.1 requirement on Q).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    Nearest,
    Stochastic,
}

#[derive(Clone, Copy, Debug)]
pub struct UniformQuantizer {
    pub bits: u8,
    pub rounding: Rounding,
}

impl UniformQuantizer {
    pub fn new(bits: u8, rounding: Rounding) -> Self {
        assert!((1..=8).contains(&bits), "bits must be in 1..=8, got {bits}");
        UniformQuantizer { bits, rounding }
    }

    #[inline]
    pub fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }

    /// Per-tensor max-abs scale (same epsilon as ref.quant_scale).
    pub fn scale(x: &[f32]) -> f32 {
        // branch-free fold vectorizes to maxps (§Perf)
        x.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-12)
    }

    /// Quantize `x` into `codes` (same length). Returns the scale.
    pub fn encode(&self, x: &[f32], codes: &mut [u8], rng: &mut Rng) -> f32 {
        assert_eq!(x.len(), codes.len());
        let scale = Self::scale(x);
        self.encode_with_scale(x, scale, codes, rng);
        scale
    }

    pub fn encode_with_scale(&self, x: &[f32], scale: f32, codes: &mut [u8], rng: &mut Rng) {
        // §Perf: folded affine form y = v*k + c (2 flops/element instead
        // of 5) and truncating cast instead of floor — valid because the
        // clamp pins y into [0, levels] where trunc == floor. ~2x over
        // the naive (x/scale + 1) * 0.5 * levels form.
        let levels = self.levels();
        let k = 0.5 * levels / scale;
        match self.rounding {
            Rounding::Nearest => {
                let c0 = 0.5 * levels + 0.5;
                for (c, &v) in codes.iter_mut().zip(x) {
                    *c = (v * k + c0).clamp(0.0, levels) as u8;
                }
            }
            Rounding::Stochastic => {
                let c0 = 0.5 * levels;
                for (c, &v) in codes.iter_mut().zip(x) {
                    *c = (v * k + c0 + rng.next_f32()).clamp(0.0, levels) as u8;
                }
            }
        }
    }

    /// Dequantize codes into `out` (overwrites).
    pub fn decode(&self, codes: &[u8], scale: f32, out: &mut [f32]) {
        assert_eq!(codes.len(), out.len());
        let levels = self.levels();
        let k = 2.0 * scale / levels;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = c as f32 * k - scale;
        }
    }

    /// Dequantize and *add* into `out` (the AQ buffer-advance step).
    pub fn decode_add(&self, codes: &[u8], scale: f32, out: &mut [f32]) {
        assert_eq!(codes.len(), out.len());
        let levels = self.levels();
        let k = 2.0 * scale / levels;
        for (o, &c) in out.iter_mut().zip(codes) {
            *o += c as f32 * k - scale;
        }
    }

    /// Convenience round-trip: returns deq(Q(x)).
    pub fn roundtrip(&self, x: &[f32], rng: &mut Rng) -> Vec<f32> {
        let mut codes = vec![0u8; x.len()];
        let scale = self.encode(x, &mut codes, rng);
        let mut out = vec![0f32; x.len()];
        self.decode(&codes, scale, &mut out);
        out
    }

    /// Max per-element reconstruction error (half step for Nearest, one
    /// full step for Stochastic).
    pub fn error_bound(&self, scale: f32) -> f32 {
        let step = 2.0 * scale / self.levels();
        match self.rounding {
            Rounding::Nearest => 0.5 * step,
            Rounding::Stochastic => step,
        }
    }

    /// Validating scale: bit-identical to [`UniformQuantizer::scale`]
    /// for finite inputs, `Err` if any element is NaN or ±Inf.
    ///
    /// The fold maxes the sign-cleared bit patterns: for non-negative
    /// IEEE-754 floats the integer order of the bits matches the float
    /// order, so the max pattern *is* the max-abs value — and NaN/Inf
    /// patterns (`>= 0x7f80_0000`) sort above every finite one, which
    /// is what catches the old silent-swallow bug (`max` skips NaN,
    /// then `NaN.clamp(..) as u8` quantized it to code 0 with no
    /// signal).
    pub fn checked_scale(x: &[f32]) -> Result<f32> {
        let mbits = x.iter().fold(0u32, |m, &v| m.max(v.to_bits() & 0x7fff_ffff));
        if mbits >= 0x7f80_0000 {
            // cold path: find the first offender for the message
            let (i, v) = x
                .iter()
                .enumerate()
                .find(|(_, v)| !v.is_finite())
                .map(|(i, &v)| (i, v))
                .unwrap_or((0, f32::NAN));
            crate::bail!("non-finite activation at index {i} ({v}): refusing to quantize");
        }
        Ok(f32::from_bits(mbits).max(1e-12))
    }

    /// Per-chunk RNG stream for deterministic parallel stochastic
    /// rounding: depends only on the message seed and the chunk index,
    /// never on which worker runs the chunk.
    pub fn chunk_rng(msg_seed: u64, chunk: usize) -> Rng {
        Rng::new(msg_seed ^ (chunk as u64).wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Fused quantize+pack: validates finiteness, computes the scale,
    /// and writes codes straight into the packed byte stream (no `u8`
    /// staging buffer). `packed` must be `pack::packed_len(x.len(),
    /// self.bits)` bytes. Returns the scale.
    pub fn encode_packed_into(
        &self,
        x: &[f32],
        packed: &mut [u8],
        rng: &mut Rng,
        pool: &Workers,
    ) -> Result<f32> {
        let scale = Self::checked_scale(x)?;
        self.encode_packed_with_scale(x, scale, packed, rng, pool);
        Ok(scale)
    }

    /// Fused quantize+pack with a caller-supplied scale. Chunked across
    /// `pool`; bytes are identical at any worker count. For Stochastic
    /// rounding this draws exactly one `u64` message seed from `rng`
    /// (regardless of length or worker count); Nearest draws nothing.
    /// Callers wanting the non-finite check go through
    /// [`UniformQuantizer::encode_packed_into`] or
    /// [`UniformQuantizer::checked_scale`].
    pub fn encode_packed_with_scale(
        &self,
        x: &[f32],
        scale: f32,
        packed: &mut [u8],
        rng: &mut Rng,
        pool: &Workers,
    ) {
        debug_assert_eq!(packed.len(), pack::packed_len(x.len(), self.bits));
        let levels = self.levels();
        let k = 0.5 * levels / scale;
        // CHUNK is a multiple of 8, so each chunk owns a disjoint
        // byte-aligned span of the packed stream at every bit width
        let b_chunk = CHUNK * self.bits as usize / 8;
        match self.rounding {
            Rounding::Nearest => {
                let c0 = 0.5 * levels + 0.5;
                pool.for_chunks2(x, packed, CHUNK, b_chunk, |_, xc, pc| {
                    self.encode_pack_chunk_nearest(xc, k, c0, pc);
                });
            }
            Rounding::Stochastic => {
                let msg_seed = rng.next_u64();
                let c0 = 0.5 * levels;
                pool.for_chunks2(x, packed, CHUNK, b_chunk, |i, xc, pc| {
                    let mut crng = Self::chunk_rng(msg_seed, i);
                    self.encode_pack_chunk_stochastic(xc, k, c0, &mut crng, pc);
                });
            }
        }
    }

    /// Fused unpack+dequantize into `out` (overwrites), chunked across
    /// `pool`. Reads `pack::packed_len(out.len(), self.bits)` bytes.
    pub fn decode_packed(&self, packed: &[u8], scale: f32, out: &mut [f32], pool: &Workers) {
        self.decode_packed_impl::<false>(packed, scale, out, pool);
    }

    /// Fused unpack+dequantize that *adds* into `out` (the AQ
    /// buffer-advance step), chunked across `pool`.
    pub fn decode_packed_add(&self, packed: &[u8], scale: f32, out: &mut [f32], pool: &Workers) {
        self.decode_packed_impl::<true>(packed, scale, out, pool);
    }

    fn decode_packed_impl<const ADD: bool>(
        &self,
        packed: &[u8],
        scale: f32,
        out: &mut [f32],
        pool: &Workers,
    ) {
        let plen = pack::packed_len(out.len(), self.bits);
        debug_assert!(packed.len() >= plen);
        let packed = &packed[..plen];
        let k = 2.0 * scale / self.levels();
        let b_chunk = CHUNK * self.bits as usize / 8;
        pool.for_chunks2(packed, out, b_chunk, CHUNK, |_, pc, oc| {
            self.decode_unpack_chunk::<ADD>(pc, k, scale, oc);
        });
    }

    /// One chunk of the fused Nearest kernel: 8 elements quantized into
    /// one `u64` word, `bits` bytes written per word. Bit-identical to
    /// `encode_with_scale` + `pack` (the clamp pins values into
    /// `[0, levels]`, where `as u64` == `as u8` widened).
    fn encode_pack_chunk_nearest(&self, xc: &[f32], k: f32, c0: f32, out: &mut [u8]) {
        let b = self.bits as usize;
        let levels = self.levels();
        let full = xc.len() / 8;
        let (body, tail) = xc.split_at(full * 8);
        let (out_body, out_tail) = out.split_at_mut(full * b);
        for (o, xs) in out_body.chunks_exact_mut(b).zip(body.chunks_exact(8)) {
            let mut w = 0u64;
            for (j, &v) in xs.iter().enumerate() {
                w |= ((v * k + c0).clamp(0.0, levels) as u64) << (j * b);
            }
            o.copy_from_slice(&w.to_le_bytes()[..b]);
        }
        let mut codes = [0u8; 8];
        for (cj, &v) in codes.iter_mut().zip(tail) {
            *cj = (v * k + c0).clamp(0.0, levels) as u8;
        }
        pack::pack_scalar(&codes[..tail.len()], self.bits, out_tail);
    }

    /// One chunk of the fused Stochastic kernel; `rng` is the chunk's
    /// derived stream and is consumed in element order, exactly like
    /// `encode_with_scale` over the same chunk.
    fn encode_pack_chunk_stochastic(
        &self,
        xc: &[f32],
        k: f32,
        c0: f32,
        rng: &mut Rng,
        out: &mut [u8],
    ) {
        let b = self.bits as usize;
        let levels = self.levels();
        let full = xc.len() / 8;
        let (body, tail) = xc.split_at(full * 8);
        let (out_body, out_tail) = out.split_at_mut(full * b);
        for (o, xs) in out_body.chunks_exact_mut(b).zip(body.chunks_exact(8)) {
            let mut w = 0u64;
            for (j, &v) in xs.iter().enumerate() {
                w |= ((v * k + c0 + rng.next_f32()).clamp(0.0, levels) as u64) << (j * b);
            }
            o.copy_from_slice(&w.to_le_bytes()[..b]);
        }
        let mut codes = [0u8; 8];
        for (cj, &v) in codes.iter_mut().zip(tail) {
            *cj = (v * k + c0 + rng.next_f32()).clamp(0.0, levels) as u8;
        }
        pack::pack_scalar(&codes[..tail.len()], self.bits, out_tail);
    }

    /// One chunk of the fused decode kernel (shared overwrite/add
    /// form): loads one little-endian word per 8 codes, dequantizes in
    /// lane order.
    fn decode_unpack_chunk<const ADD: bool>(&self, pc: &[u8], k: f32, scale: f32, oc: &mut [f32]) {
        let b = self.bits as usize;
        let mask = (1u64 << b) - 1;
        let full = oc.len() / 8;
        let (body, tail) = oc.split_at_mut(full * 8);
        for (os, bs) in body.chunks_exact_mut(8).zip(pc.chunks_exact(b)) {
            let mut wb = [0u8; 8];
            wb[..b].copy_from_slice(bs);
            let w = u64::from_le_bytes(wb);
            for (j, o) in os.iter_mut().enumerate() {
                let v = ((w >> (j * b)) & mask) as u8 as f32 * k - scale;
                if ADD {
                    *o += v;
                } else {
                    *o = v;
                }
            }
        }
        let mut codes = [0u8; 8];
        pack::unpack_scalar(&pc[full * b..], self.bits, &mut codes[..tail.len()]);
        for (o, &c) in tail.iter_mut().zip(&codes) {
            let v = c as f32 * k - scale;
            if ADD {
                *o += v;
            } else {
                *o = v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn roundtrip_error_within_bound() {
        let mut r = rng();
        let x: Vec<f32> = (0..1000).map(|_| r.normal() * 3.0).collect();
        for bits in [2u8, 3, 4, 6, 8] {
            let q = UniformQuantizer::new(bits, Rounding::Nearest);
            let scale = UniformQuantizer::scale(&x);
            let xh = q.roundtrip(&x, &mut r);
            let bound = q.error_bound(scale) + 1e-6;
            for (a, b) in x.iter().zip(&xh) {
                assert!((a - b).abs() <= bound, "bits={bits} {a} vs {b}");
            }
        }
    }

    #[test]
    fn codes_cover_range() {
        let x = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let q = UniformQuantizer::new(2, Rounding::Nearest);
        let mut codes = [0u8; 5];
        let scale = q.encode(&x, &mut codes, &mut rng());
        assert_eq!(scale, 1.0);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[4], 3);
        assert!(codes.iter().all(|&c| c <= 3));
    }

    #[test]
    fn zero_vector_is_stable() {
        let x = [0f32; 16];
        let q = UniformQuantizer::new(4, Rounding::Nearest);
        let xh = q.roundtrip(&x, &mut rng());
        for v in xh {
            assert!(v.abs() < 1e-10);
        }
    }

    #[test]
    fn stochastic_is_unbiased() {
        let mut r = rng();
        let x: Vec<f32> = (0..64).map(|_| r.normal()).collect();
        let q = UniformQuantizer::new(3, Rounding::Stochastic);
        let n = 2000;
        let mut acc = vec![0f64; x.len()];
        for _ in 0..n {
            let xh = q.roundtrip(&x, &mut r);
            for (a, v) in acc.iter_mut().zip(&xh) {
                *a += *v as f64;
            }
        }
        let scale = UniformQuantizer::scale(&x);
        let step = (2.0 * scale / q.levels()) as f64;
        let se = 0.5 * step / (n as f64).sqrt();
        let bias: f64 = x
            .iter()
            .zip(&acc)
            .map(|(&xi, &a)| (a / n as f64 - xi as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(bias <= 2.0 * se * (x.len() as f64).sqrt(), "bias {bias} se {se}");
    }

    #[test]
    fn checked_scale_matches_scale_bit_exactly_on_finite() {
        let mut r = rng();
        for n in [1usize, 7, 64, 4097] {
            let x: Vec<f32> = (0..n).map(|_| r.normal() * 10.0).collect();
            assert_eq!(
                UniformQuantizer::checked_scale(&x).unwrap().to_bits(),
                UniformQuantizer::scale(&x).to_bits(),
                "n={n}"
            );
        }
        // -0.0 and the epsilon floor behave identically too
        for x in [&[0.0f32, -0.0][..], &[]] {
            assert_eq!(
                UniformQuantizer::checked_scale(x).unwrap().to_bits(),
                UniformQuantizer::scale(x).to_bits()
            );
        }
    }

    #[test]
    fn non_finite_inputs_error_in_both_rounding_modes() {
        for rounding in [Rounding::Nearest, Rounding::Stochastic] {
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                let mut x = vec![0.5f32; 64];
                x[17] = bad;
                let q = UniformQuantizer::new(4, rounding);
                let mut packed = vec![0u8; pack::packed_len(x.len(), 4)];
                let err = q
                    .encode_packed_into(&x, &mut packed, &mut rng(), &Workers::seq())
                    .unwrap_err()
                    .to_string();
                assert!(err.contains("non-finite"), "{rounding:?} {bad}: {err}");
                assert!(err.contains("17"), "offending index missing: {err}");
            }
        }
        // checked_scale alone flags it as well (used by validating callers)
        assert!(UniformQuantizer::checked_scale(&[1.0, f32::NAN]).is_err());
        assert!(UniformQuantizer::checked_scale(&[f32::INFINITY]).is_err());
    }

    #[test]
    fn matches_paper_fig1_regime() {
        // 2-bit direct quantization destroys fine structure; 8-bit keeps it.
        let mut r = rng();
        let x: Vec<f32> = (0..4096).map(|_| r.normal()).collect();
        let err = |bits| {
            let q = UniformQuantizer::new(bits, Rounding::Nearest);
            let xh = q.roundtrip(&x, &mut rng());
            x.iter().zip(&xh).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
        };
        assert!(err(2) > 10.0 * err(8));
    }
}
