//! Hadamard-rotation wrapper codec: `had:<inner>` (TAH-QUANT style).
//!
//! A Fast Walsh–Hadamard transform is applied in place to every example
//! row before the inner quantizer sees it, and the exact same transform
//! undoes it after the inner decoder reconstructs. Because the
//! orthonormal Hadamard matrix `H/√n` is involutory (`(H/√n)² = I`),
//! rotate and un-rotate are literally the same function — there is no
//! separate inverse path that could drift out of sync.
//!
//! Why rotate at all: uniform quantizers spend their levels on the
//! per-message max-abs scale, so a single outlier coordinate wastes
//! almost the whole code book. The rotation smears every coordinate
//! across all of them (each output is a ±1 combination of all inputs,
//! scaled by 1/√n), flattening outliers toward a near-Gaussian profile
//! that low-bit uniform quantization handles far better — the TAH-QUANT
//! observation (arxiv 2506.01352), also exploited by QuIP/QuaRot-style
//! weight quantizers.
//!
//! Rows whose length is not a power of two are decomposed greedily into
//! maximal power-of-2 blocks (e.g. 96 → 64 + 32), each rotated
//! independently; a length-1 block passes through unchanged. The
//! butterfly order and the `1/√B` scaling are pinned byte-exactly by the
//! golden fixtures (`gen_golden.py` mirrors `fwht_block` loop for loop),
//! so the wire image is stable across releases.
//!
//! Like `ef:`, the wrapper is invisible on the wire: frames carry the
//! inner codec's tag and layout, of rotated values.

use super::{encode_to_frame, BoundaryCodec, EncodeStats, Frame, FrameBuf, FrameView};
use crate::util::error::Result;

/// In-place orthonormal FWHT over one power-of-2 block: radix-2
/// butterflies at strides 1, 2, 4, …, then a `1/√n` rescale. Exactly
/// self-inverse in exact arithmetic; in f32 the round trip is a
/// contraction within a few ulp per element.
pub fn fwht_block(x: &mut [f32]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two(), "fwht block length {n} is not a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    if n > 1 {
        let s = (n as f32).sqrt().recip();
        for v in x.iter_mut() {
            *v *= s;
        }
    }
}

/// Largest power of two ≤ `n` (n ≥ 1).
fn floor_pow2(n: usize) -> usize {
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// Rotate every `el`-element row of `x` in place, decomposing each row
/// greedily into maximal power-of-2 blocks. Self-inverse: calling it
/// twice reconstructs the input (up to f32 roundoff).
pub fn rotate_rows(x: &mut [f32], el: usize) {
    debug_assert!(el >= 1 && x.len() % el == 0);
    for row in x.chunks_mut(el) {
        let mut off = 0;
        while off < row.len() {
            let b = floor_pow2(row.len() - off);
            fwht_block(&mut row[off..off + b]);
            off += b;
        }
    }
}

/// The `had:` wrapper. Both halves are the same type: the encoder half
/// wraps the inner encoder, the decoder half the inner decoder, and the
/// rotation runs on whichever side of the inner codec the data passes.
pub struct HadCodec {
    inner: Box<dyn BoundaryCodec>,
    /// elements per example record — the rotation's row stride
    el: usize,
    /// rotated-message scratch, reused across messages
    rot: Vec<f32>,
}

impl HadCodec {
    pub fn new(inner: Box<dyn BoundaryCodec>, el: usize) -> Self {
        assert!(el >= 1, "had codec needs el >= 1");
        HadCodec { inner, el, rot: Vec::new() }
    }
}

impl BoundaryCodec for HadCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        crate::ensure!(
            a.len() == ids.len() * self.el,
            "had message length {} != {} ids x {} elements",
            a.len(),
            ids.len(),
            self.el
        );
        self.rot.clear();
        self.rot.extend_from_slice(a);
        rotate_rows(&mut self.rot, self.el);
        // NaN/Inf inputs rotate to NaN/Inf and are rejected by the inner
        // quantizer's own checked_scale, like any other activation
        self.inner.encode_into(ids, &self.rot, out)
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let mut out = self.inner.decode(ids, frame)?;
        crate::ensure!(
            out.len() == ids.len() * self.el,
            "had inner codec decoded {} elements, boundary expects {} ids x {} elements",
            out.len(),
            ids.len(),
            self.el
        );
        rotate_rows(&mut out, self.el);
        Ok(out)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        crate::ensure!(
            out.len() == ids.len() * self.el,
            "had decode buffer has {} elements, boundary expects {} ids x {} elements",
            out.len(),
            ids.len(),
            self.el
        );
        self.inner.decode_into(ids, frame, out)?;
        // the orthonormal transform is its own inverse
        rotate_rows(out, self.el);
        Ok(())
    }

    fn label(&self) -> String {
        format!("had:{}", self.inner.label())
    }

    fn state_bytes(&self) -> u64 {
        self.inner.state_bytes()
    }

    fn take_stats(&mut self) -> EncodeStats {
        self.inner.take_stats()
    }

    fn set_workers(&mut self, threads: usize) {
        self.inner.set_workers(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::frame::TAG_DIRECTQ;
    use crate::codec::registry::{build_mem_pair, SchemeSpec};
    use crate::codec::Rounding;
    use crate::util::Rng;

    fn pair(spec: &str, el: usize, seed: u64) -> (Box<dyn BoundaryCodec>, Box<dyn BoundaryCodec>) {
        let scheme = SchemeSpec::parse(spec).unwrap();
        build_mem_pair(&scheme, el, Rounding::Nearest, seed).unwrap()
    }

    #[test]
    fn fwht_butterfly_order_is_pinned() {
        // n = 2: [(a+b)/√2, (a-b)/√2]
        let mut x = [3.0f32, 1.0];
        fwht_block(&mut x);
        let s = 2f32.sqrt().recip();
        assert_eq!(x, [4.0 * s, 2.0 * s]);
        // n = 4 impulse: every output = 1/√4 = 0.5
        let mut x = [1.0f32, 0.0, 0.0, 0.0];
        fwht_block(&mut x);
        assert_eq!(x, [0.5; 4]);
    }

    #[test]
    fn fwht_is_self_inverse_and_energy_preserving() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 8, 64, 256] {
            let orig: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut x = orig.clone();
            fwht_block(&mut x);
            let e0: f64 = orig.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let e1: f64 = x.iter().map(|&v| (v as f64) * (v as f64)).sum();
            assert!((e0 - e1).abs() < 1e-3 * (1.0 + e0), "n={n}: {e0} vs {e1}");
            fwht_block(&mut x);
            for (a, b) in orig.iter().zip(&x) {
                assert!((a - b).abs() < 1e-4, "n={n}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn non_pow2_rows_decompose_greedily() {
        // 12 = 8 + 4: rotating twice round-trips each block
        let mut rng = Rng::new(9);
        let orig: Vec<f32> = (0..24).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        rotate_rows(&mut x, 12);
        rotate_rows(&mut x, 12);
        for (a, b) in orig.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn wire_format_is_the_inner_frame() {
        let el = 16;
        let mut rng = Rng::new(3);
        let a: Vec<f32> = (0..el).map(|_| rng.normal()).collect();
        let (mut enc, mut dec) = pair("had:q4", el, 5);
        let f = enc.encode(&[0], &a).unwrap();
        assert_eq!(f.tag(), TAG_DIRECTQ);
        let out = dec.decode(&[0], &f).unwrap();
        assert_eq!(out.len(), el);
        // rotation + 4-bit quantization + inverse: bounded reconstruction
        let scale: f32 = a.iter().fold(0f32, |m, v| m.max(v.abs()));
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() < scale, "{x} vs {y}");
        }
    }

    #[test]
    fn rotation_tames_an_outlier() {
        // one huge coordinate among zeros: plain q2 zeroes everything
        // else; with the rotation the energy survives quantization
        let el = 64;
        let mut a = vec![0.05f32; el];
        a[11] = 50.0;
        let (mut enc, mut dec) = pair("had:q2", el, 1);
        let f = enc.encode(&[0], &a).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        let err: f64 = a
            .iter()
            .zip(&out)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let (mut enc_q, mut dec_q) = pair("q2", el, 1);
        let fq = enc_q.encode(&[0], &a).unwrap();
        let out_q = dec_q.decode(&[0], &fq).unwrap();
        let err_q: f64 = a
            .iter()
            .zip(&out_q)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err < err_q, "rotated {err} vs plain {err_q}");
    }

    #[test]
    fn shape_mismatch_and_non_finite_are_errors() {
        let (mut enc, _) = pair("had:q4", 8, 1);
        assert!(enc.encode(&[0, 1], &vec![0.0f32; 8]).is_err());
        let mut bad = vec![0.5f32; 8];
        bad[3] = f32::NAN;
        assert!(enc.encode(&[0], &bad).is_err());
    }

    #[test]
    fn scratch_matches_allocating_path() {
        let el = 24;
        let mut rng = Rng::new(13);
        let a: Vec<f32> = (0..el).map(|_| rng.normal()).collect();
        let (mut enc_a, _) = pair("had:q4", el, 21);
        let (mut enc_b, mut dec) = pair("had:q4", el, 21);
        let f = enc_a.encode(&[0], &a).unwrap();
        let mut buf = FrameBuf::new();
        enc_b.encode_into(&[0], &a, &mut buf).unwrap();
        assert_eq!(buf.as_bytes(), f.to_bytes().as_slice());
        let mut out = vec![0f32; el];
        dec.decode_into(&[0], &buf.view(), &mut out).unwrap();
        assert_eq!(out, dec.decode(&[0], &f).unwrap());
    }
}
