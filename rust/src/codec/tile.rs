//! Tile-wise adaptive quantization: `tile:<T>:<inner>` (TAH-QUANT
//! style, arxiv 2506.01352).
//!
//! Each example row is split into `T`-element tiles (the last tile of a
//! row may be shorter). Every tile gets its own max-abs scale — so one
//! outlier only burns its own tile's code book, not the whole message —
//! and its own bit width, allocated from the tiles' mean-square power
//! within a fixed *average* budget (the inner `q<bits>` spec): loud
//! tiles borrow bits from quiet ones, but the message's total payload
//! stays at the budget the operator asked for.
//!
//! Frame format (tag 8):
//!
//! ```text
//! header : budget: u8 | tile_len: u32 | n: u32
//! payload: per tile, in row-major order:
//!          bits: u8 | scale: f32 | packed codes (packed_len(len, bits))
//! ```
//!
//! The per-tile bit map travels in the payload, one byte ahead of the
//! codes it describes — the header stays fixed-size (9 bytes) and the
//! decoder needs no second pass. The allocation rule uses only
//! comparisons and exact-in-binary ×4 / ÷4 steps (no logarithms), so
//! `gen_golden.py` reproduces it bit-for-bit in python and the fixtures
//! pin the whole layout.

use crate::util::error::Result;
use crate::util::Rng;

use super::frame::{FrameBuf, FrameReader, FrameView, TAG_TILE};
use super::pack;
use super::par::Workers;
use super::quantizer::{Rounding, UniformQuantizer};
use super::{encode_to_frame, BoundaryCodec, Frame};

/// Variance-driven per-tile bit widths within a fixed average budget.
///
/// Every factor of 4 in a tile's mean-square power relative to the
/// message mean buys one bit (±6 dB per bit), clamped to ±3 around the
/// budget and to the quantizer's 1..=8 range; single bits are then
/// moved from the quietest tiles to the loudest until the total spends
/// exactly `msq.len() × budget`. Deterministic: ties break on the first
/// (lowest-index) tile.
pub fn allocate_bits(msq: &[f64], budget: u8, out: &mut Vec<u8>) {
    out.clear();
    let n = msq.len();
    if n == 0 {
        return;
    }
    let floor = 1e-24f64;
    let mean = msq.iter().sum::<f64>() / n as f64;
    let reference = if mean > floor { mean } else { floor };
    for &m in msq {
        let mut ratio = (if m > floor { m } else { floor }) / reference;
        let mut extra: i32 = 0;
        while ratio >= 4.0 && extra < 3 {
            ratio /= 4.0;
            extra += 1;
        }
        while ratio < 0.25 && extra > -3 {
            ratio *= 4.0;
            extra -= 1;
        }
        out.push((budget as i32 + extra).clamp(1, 8) as u8);
    }
    // spend exactly the average budget: move one bit at a time between
    // the extreme-power tiles until the sum matches the cap
    let cap = n as u64 * budget as u64;
    let mut sum: u64 = out.iter().map(|&b| b as u64).sum();
    while sum > cap {
        let mut pick: Option<usize> = None;
        for (i, &b) in out.iter().enumerate() {
            if b <= 1 {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => msq[i] < msq[p],
            };
            if better {
                pick = Some(i);
            }
        }
        match pick {
            Some(i) => {
                out[i] -= 1;
                sum -= 1;
            }
            None => break,
        }
    }
    while sum < cap {
        let mut pick: Option<usize> = None;
        for (i, &b) in out.iter().enumerate() {
            if b >= 8 {
                continue;
            }
            let better = match pick {
                None => true,
                Some(p) => msq[i] > msq[p],
            };
            if better {
                pick = Some(i);
            }
        }
        match pick {
            Some(i) => {
                out[i] += 1;
                sum += 1;
            }
            None => break,
        }
    }
}

/// The `tile:` codec. Stateless across messages (like DirectQ); both
/// halves are the same type.
pub struct TileCodec {
    t: u32,
    budget: u8,
    rounding: Rounding,
    /// elements per example record — bounds the length a frame may claim
    el: usize,
    rng: Rng,
    workers: Workers,
    /// per-message scratch (per-tile scale / power / bits), reused
    scales: Vec<f32>,
    msq: Vec<f64>,
    bits: Vec<u8>,
}

impl TileCodec {
    pub fn new(t: u32, budget: u8, rounding: Rounding, el: usize, seed: u64) -> Self {
        assert!(t >= 1, "tile length must be >= 1");
        assert!((1..=8).contains(&budget), "tile budget {budget} out of range (1..=8)");
        assert!(el >= 1, "tile codec needs el >= 1");
        TileCodec {
            t,
            budget,
            rounding,
            el,
            rng: Rng::new(seed),
            workers: Workers::seq(),
            scales: Vec::new(),
            msq: Vec::new(),
            bits: Vec::new(),
        }
    }

    /// Validate tag + header against the configured shape; returns the
    /// dense element count.
    fn check(&self, ids: &[u64], tag: u8, header: &[u8]) -> Result<usize> {
        crate::ensure!(tag == TAG_TILE, "tile codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let (budget, t, n) = (h.u8()?, h.u32()?, h.u32()? as usize);
        h.done()?;
        crate::ensure!(
            budget == self.budget,
            "tile frame has budget {budget}, boundary is configured for {}",
            self.budget
        );
        crate::ensure!(
            t == self.t,
            "tile frame has {t}-element tiles, boundary is configured for {}",
            self.t
        );
        // bound n by the configured batch shape before reading anything
        crate::ensure!(
            n == ids.len() * self.el,
            "tile frame claims {n} elements, boundary expects {} ids x {} elements",
            ids.len(),
            self.el
        );
        Ok(n)
    }
}

impl BoundaryCodec for TileCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        crate::ensure!(
            a.len() == ids.len() * self.el,
            "tile message length {} != {} ids x {} elements",
            a.len(),
            ids.len(),
            self.el
        );
        let t = self.t as usize;
        // pass 1: per-tile scale (rejects NaN/Inf before any wire bytes)
        // and mean-square power for the bit allocation
        self.scales.clear();
        self.msq.clear();
        for row in a.chunks(self.el) {
            for tile in row.chunks(t) {
                self.scales.push(UniformQuantizer::checked_scale(tile)?);
                let mut acc = 0f64;
                for &v in tile {
                    acc += (v as f64) * (v as f64);
                }
                self.msq.push(acc / tile.len() as f64);
            }
        }
        allocate_bits(&self.msq, self.budget, &mut self.bits);
        out.start(TAG_TILE);
        out.u8(self.budget).u32(self.t).u32(a.len() as u32);
        out.end_header();
        // pass 2: quantize each tile straight into the packed payload
        let pool = self.workers;
        let mut ti = 0usize;
        for row in a.chunks(self.el) {
            for tile in row.chunks(t) {
                let bits = self.bits[ti];
                let scale = self.scales[ti];
                ti += 1;
                out.u8(bits);
                out.f32(scale);
                let q = UniformQuantizer::new(bits, self.rounding);
                let packed = out.reserve_zeroed(pack::packed_len(tile.len(), bits));
                q.encode_packed_with_scale(tile, scale, packed, &mut self.rng, &pool);
            }
        }
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let mut out = vec![0f32; ids.len() * self.el];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let n = self.check(ids, frame.tag(), frame.header())?;
        crate::ensure!(
            n == out.len(),
            "tile frame has {n} elements, boundary expects {}",
            out.len()
        );
        let t = self.t as usize;
        let mut p = FrameReader::new(frame.payload());
        for row in out.chunks_mut(self.el) {
            for tile in row.chunks_mut(t) {
                let bits = p.u8()?;
                // a hostile bit width must be an error here — the
                // quantizer constructor asserts 1..=8
                crate::ensure!(
                    (1..=8).contains(&bits),
                    "tile frame has a {bits}-bit tile (quantizers support 1..=8 bits)"
                );
                let scale = p.f32()?;
                let packed = p.bytes(pack::packed_len(tile.len(), bits))?;
                let q = UniformQuantizer::new(bits, self.rounding);
                q.decode_packed(packed, scale, tile, &self.workers);
            }
        }
        p.done()
    }

    fn label(&self) -> String {
        format!("tile:{}:q{}", self.t, self.budget)
    }

    fn set_workers(&mut self, threads: usize) {
        self.workers = Workers::new(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(17);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn allocation_spends_exactly_the_budget() {
        // one loud tile among quiet ones gains bits; the sum stays fixed
        let msq = vec![1.0, 1.0, 1e4, 1.0];
        let mut bits = Vec::new();
        allocate_bits(&msq, 4, &mut bits);
        assert_eq!(bits.iter().map(|&b| b as u64).sum::<u64>(), 16);
        assert!(bits[2] > bits[0], "{bits:?}");
        assert!(bits.iter().all(|&b| (1..=8).contains(&b)), "{bits:?}");
        // uniform power: everyone gets exactly the budget
        allocate_bits(&[2.0, 2.0, 2.0], 3, &mut bits);
        assert_eq!(bits, vec![3, 3, 3]);
        // budget 8 pins the ceiling even under extreme spreads
        allocate_bits(&[1e-12, 1e12], 8, &mut bits);
        assert_eq!(bits, vec![8, 8]);
        // all-zero power degrades to uniform, not a division blowup
        allocate_bits(&[0.0, 0.0], 2, &mut bits);
        assert_eq!(bits, vec![2, 2]);
    }

    #[test]
    fn roundtrip_bounded_error_per_tile() {
        let el = 96;
        let a = sample(2 * el);
        let mut enc = TileCodec::new(32, 8, Rounding::Nearest, el, 1);
        let mut dec = TileCodec::new(32, 8, Rounding::Nearest, el, 2);
        let f = enc.encode(&[4, 9], &a).unwrap();
        let out = dec.decode(&[4, 9], &f).unwrap();
        assert_eq!(out.len(), a.len());
        // each tile's error is bounded by its own scale, not the global
        // max-abs — with most tiles at 8 bits the error is small
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() < 0.5, "{x} vs {y}");
        }
    }

    #[test]
    fn outlier_tile_cannot_poison_neighbours() {
        // a huge value in tile 0 leaves tile 1's scale (and error) tiny —
        // the failure mode a per-message scale suffers
        let el = 8;
        let mut a = vec![0.01f32; el];
        a[0] = 100.0;
        let mut enc = TileCodec::new(4, 4, Rounding::Nearest, el, 1);
        let mut dec = TileCodec::new(4, 4, Rounding::Nearest, el, 2);
        let f = enc.encode(&[0], &a).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        for (x, y) in a[4..].iter().zip(&out[4..]) {
            assert!((x - y).abs() < 0.02, "{x} vs {y}");
        }
    }

    #[test]
    fn hostile_frames_are_errors_not_panics() {
        let el = 16;
        let a = sample(el);
        let mut enc = TileCodec::new(8, 4, Rounding::Nearest, el, 1);
        let mut dec = TileCodec::new(8, 4, Rounding::Nearest, el, 2);
        let f = enc.encode(&[0], &a).unwrap();
        // wrong tag
        let bad = Frame::new(9, f.header().to_vec(), f.payload().to_vec());
        assert!(dec.decode(&[0], &bad).is_err());
        // zero / out-of-range per-tile bit width in the payload
        for hostile_bits in [0u8, 9, 255] {
            let mut payload = f.payload().to_vec();
            payload[0] = hostile_bits;
            let bad = Frame::new(f.tag(), f.header().to_vec(), payload);
            assert!(dec.decode(&[0], &bad).is_err(), "bits {hostile_bits}");
        }
        // truncated payload
        let bad = Frame::new(f.tag(), f.header().to_vec(), f.payload()[..3].to_vec());
        assert!(dec.decode(&[0], &bad).is_err());
        // header claiming a different shape than the boundary's
        let mut hdr = f.header().to_vec();
        hdr[5..9].copy_from_slice(&10_000u32.to_le_bytes());
        let bad = Frame::new(f.tag(), hdr, f.payload().to_vec());
        assert!(dec.decode(&[0], &bad).is_err());
        // non-finite input is rejected at encode
        let mut nan = a.clone();
        nan[3] = f32::INFINITY;
        assert!(enc.encode(&[0], &nan).is_err());
    }

    #[test]
    fn scratch_matches_allocating_path() {
        let el = 40;
        let a = sample(el);
        let mut enc_a = TileCodec::new(16, 3, Rounding::Nearest, el, 9);
        let mut enc_b = TileCodec::new(16, 3, Rounding::Nearest, el, 9);
        let mut dec = TileCodec::new(16, 3, Rounding::Nearest, el, 2);
        let f = enc_a.encode(&[0], &a).unwrap();
        let mut buf = FrameBuf::new();
        enc_b.encode_into(&[0], &a, &mut buf).unwrap();
        assert_eq!(buf.as_bytes(), f.to_bytes().as_slice());
        let mut out = vec![0f32; el];
        dec.decode_into(&[0], &buf.view(), &mut out).unwrap();
        assert_eq!(out, dec.decode(&[0], &f).unwrap());
    }
}
