//! Top-k magnitude sparsification — the split-learning backward scheme
//! `bw8[0.2]` of paper Appendix H.6 (keep the top 20% of gradient entries,
//! then quantize the kept values to 8 bits).

use super::quantizer::{Rounding, UniformQuantizer};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct TopKMessage {
    pub indices: Vec<u32>,
    pub codes: Vec<u8>,
    pub scale: f32,
    pub len: usize,
}

impl TopKMessage {
    /// Wire bytes: 4B per index + packed codes + scale header.
    pub fn wire_bytes(&self, bits: u8) -> u64 {
        4 * self.indices.len() as u64 + super::quant_wire_bytes(self.codes.len(), bits)
    }
}

/// Select the `frac` largest-|x| entries, quantize them to `bits`
/// (deterministic rounding — the paper's configuration).
pub fn encode(x: &[f32], frac: f64, bits: u8, rng: &mut Rng) -> TopKMessage {
    encode_with(x, frac, &UniformQuantizer::new(bits, Rounding::Nearest), rng)
}

/// Like [`encode`], with an explicit quantizer (rounding mode / bits come
/// from the registry-built codec).
pub fn encode_with(x: &[f32], frac: f64, q: &UniformQuantizer, rng: &mut Rng) -> TopKMessage {
    let mut indices = Vec::new();
    select_topk_into(x, frac, &mut indices);
    let vals: Vec<f32> = indices.iter().map(|&i| x[i as usize]).collect();
    let mut codes = vec![0u8; vals.len()];
    let scale = q.encode(&vals, &mut codes, rng);
    TopKMessage { indices, codes, scale, len: x.len() }
}

/// Fill `indices` with the sorted positions of the `frac`-largest-|x|
/// entries (at least one, at most all). Reuses the caller's vector so
/// the steady-state codec path (`TopKCodec::encode_into`) selects
/// without allocating.
pub fn select_topk_into(x: &[f32], frac: f64, indices: &mut Vec<u32>) {
    let k = ((x.len() as f64 * frac).ceil() as usize).clamp(1, x.len());
    indices.clear();
    indices.extend(0..x.len() as u32);
    indices.select_nth_unstable_by(k - 1, |&a, &b| {
        x[b as usize]
            .abs()
            .partial_cmp(&x[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    indices.truncate(k);
    indices.sort_unstable();
}

/// Reconstruct a dense vector (zeros outside the kept set).
pub fn decode(msg: &TopKMessage, bits: u8, out: &mut Vec<f32>) {
    let q = UniformQuantizer::new(bits, Rounding::Nearest);
    out.clear();
    out.resize(msg.len, 0.0);
    let mut vals = vec![0f32; msg.codes.len()];
    q.decode(&msg.codes, msg.scale, &mut vals);
    for (&i, &v) in msg.indices.iter().zip(&vals) {
        out[i as usize] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest() {
        let mut rng = Rng::new(5);
        let mut x = vec![0.01f32; 100];
        x[3] = 5.0;
        x[42] = -7.0;
        x[99] = 3.0;
        let msg = encode(&x, 0.03, 8, &mut rng);
        assert_eq!(msg.indices, vec![3, 42, 99]);
        let mut out = Vec::new();
        decode(&msg, 8, &mut out);
        assert!((out[42] + 7.0).abs() < 0.1);
        assert_eq!(out[0], 0.0);
    }

    #[test]
    fn wire_bytes_scale_with_k() {
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let m20 = encode(&x, 0.2, 8, &mut rng);
        let m100 = encode(&x, 1.0, 8, &mut rng);
        assert!(m20.wire_bytes(8) < m100.wire_bytes(8) / 3);
        assert_eq!(m100.codes.len(), 1000);
    }

    #[test]
    fn full_frac_is_plain_quantization() {
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let msg = encode(&x, 1.0, 8, &mut rng);
        let mut out = Vec::new();
        decode(&msg, 8, &mut out);
        let q = UniformQuantizer::new(8, Rounding::Nearest);
        let scale = UniformQuantizer::scale(&x);
        for (a, b) in x.iter().zip(&out) {
            assert!((a - b).abs() <= q.error_bound(scale) + 1e-6);
        }
    }
}
