//! The stateless / per-message boundary codecs: FP32 passthrough, FP16
//! wire (App. H.4), DirectQ (AC-GC / TinyScript-style direct activation
//! quantization), and top-k sparsification + quantization (App. H.6).
//! The stateful AQ-SGD delta codec lives in `codec::delta`.
//!
//! Each codec is one self-contained frame format:
//!
//! | codec   | tag | header                          | payload              |
//! |---------|-----|---------------------------------|----------------------|
//! | raw32   | 1   | n: u32                          | n × f32 LE           |
//! | f16     | 2   | n: u32                          | n × f16 LE           |
//! | directq | 3   | bits: u8, n: u32, scale: f32    | packed codes         |
//! | topk    | 5   | bits: u8, n: u32, k: u32, scale | k × u32 idx + codes  |

use std::sync::Arc;

use crate::runtime::QuantRuntime;
use crate::util::error::Result;
use crate::util::Rng;

use super::frame::{Frame, FrameReader, FrameWriter, TAG_DIRECTQ, TAG_F16, TAG_RAW32, TAG_TOPK};
use super::quantizer::{Rounding, UniformQuantizer};
use super::{f16, pack, topk, BoundaryCodec};

/// FP32 passthrough: the paper's no-compression baseline.
pub struct Raw32Codec;

impl BoundaryCodec for Raw32Codec {
    fn encode(&mut self, _ids: &[u64], a: &[f32]) -> Result<Frame> {
        let mut h = FrameWriter::default();
        h.u32(a.len() as u32);
        let mut p = FrameWriter::with_capacity(4 * a.len());
        p.f32_slice(a);
        Ok(Frame::new(TAG_RAW32, h.finish(), p.finish()))
    }

    fn decode(&mut self, _ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        crate::ensure!(frame.tag() == TAG_RAW32, "raw32 codec got frame tag {}", frame.tag());
        let mut h = FrameReader::new(frame.header());
        let n = h.u32()? as usize;
        h.done()?;
        let mut p = FrameReader::new(frame.payload());
        let out = p.f32_vec(n)?;
        p.done()?;
        Ok(out)
    }

    fn label(&self) -> String {
        "fp32".into()
    }
}

/// IEEE binary16 wire format (paper Appendix H.4).
pub struct F16Codec;

impl BoundaryCodec for F16Codec {
    fn encode(&mut self, _ids: &[u64], a: &[f32]) -> Result<Frame> {
        let mut h = FrameWriter::default();
        h.u32(a.len() as u32);
        let mut payload = Vec::new();
        f16::encode(a, &mut payload);
        Ok(Frame::new(TAG_F16, h.finish(), payload))
    }

    fn decode(&mut self, _ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        crate::ensure!(frame.tag() == TAG_F16, "f16 codec got frame tag {}", frame.tag());
        let mut h = FrameReader::new(frame.header());
        let n = h.u32()? as usize;
        h.done()?;
        crate::ensure!(
            frame.payload().len() == 2 * n,
            "f16 frame payload {} bytes, want {}",
            frame.payload().len(),
            2 * n
        );
        let mut out = Vec::new();
        f16::decode(frame.payload(), &mut out);
        Ok(out)
    }

    fn label(&self) -> String {
        "fp16".into()
    }
}

/// Direct b-bit quantization of the activation itself (one per-message
/// max-abs scale), optionally through the Pallas HLO kernels.
pub struct DirectQCodec {
    bits: u8,
    rounding: Rounding,
    rng: Rng,
    hlo: Option<Arc<QuantRuntime>>,
}

impl DirectQCodec {
    pub fn new(bits: u8, rounding: Rounding, seed: u64, hlo: Option<Arc<QuantRuntime>>) -> Self {
        DirectQCodec { bits, rounding, rng: Rng::new(seed), hlo }
    }
}

impl BoundaryCodec for DirectQCodec {
    fn encode(&mut self, _ids: &[u64], a: &[f32]) -> Result<Frame> {
        let (codes, scale) = match &self.hlo {
            Some(q) if q.n_elements() == a.len() => q.dq_encode(a, self.bits)?,
            _ => {
                let q = UniformQuantizer::new(self.bits, self.rounding);
                let mut codes = vec![0u8; a.len()];
                let scale = q.encode(a, &mut codes, &mut self.rng);
                (codes, scale)
            }
        };
        let mut h = FrameWriter::default();
        h.u8(self.bits).u32(a.len() as u32).f32(scale);
        Ok(Frame::new(TAG_DIRECTQ, h.finish(), pack::pack(&codes, self.bits)))
    }

    fn decode(&mut self, _ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        crate::ensure!(frame.tag() == TAG_DIRECTQ, "directq codec got frame tag {}", frame.tag());
        let mut h = FrameReader::new(frame.header());
        let (bits, n, scale) = (h.u8()?, h.u32()? as usize, h.f32()?);
        h.done()?;
        crate::ensure!(
            bits == self.bits,
            "directq frame is {bits}-bit but this boundary is configured for {}",
            self.bits
        );
        crate::ensure!(
            frame.payload().len() == pack::packed_len(n, bits),
            "directq frame payload {} bytes, want {}",
            frame.payload().len(),
            pack::packed_len(n, bits)
        );
        let codes = pack::unpack(frame.payload(), bits, n);
        match &self.hlo {
            Some(q) if q.n_elements() == n => q.dq_decode(&codes, scale, bits),
            _ => {
                let q = UniformQuantizer::new(bits, self.rounding);
                let mut out = vec![0f32; n];
                q.decode(&codes, scale, &mut out);
                Ok(out)
            }
        }
    }

    fn label(&self) -> String {
        format!("q{}", self.bits)
    }
}

/// Top-k magnitude sparsification + b-bit quantization of the kept
/// values (paper Appendix H.6's `bw8[0.2]` split-learning scheme).
pub struct TopKCodec {
    frac: f64,
    bits: u8,
    quant: UniformQuantizer,
    /// elements per example record — bounds the dense length a frame may
    /// claim, so a malformed header cannot force a huge allocation
    el: usize,
    rng: Rng,
}

impl TopKCodec {
    pub fn new(frac: f64, bits: u8, rounding: Rounding, el: usize, seed: u64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk frac must be in (0, 1], got {frac}");
        TopKCodec {
            frac,
            bits,
            quant: UniformQuantizer::new(bits, rounding),
            el,
            rng: Rng::new(seed),
        }
    }
}

impl BoundaryCodec for TopKCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        crate::ensure!(
            a.len() == ids.len() * self.el,
            "topk message length {} != {} ids x {} elements",
            a.len(),
            ids.len(),
            self.el
        );
        let msg = topk::encode_with(a, self.frac, &self.quant, &mut self.rng);
        let mut h = FrameWriter::default();
        h.u8(self.bits).u32(a.len() as u32).u32(msg.indices.len() as u32).f32(msg.scale);
        let mut p = FrameWriter::with_capacity(
            4 * msg.indices.len() + pack::packed_len(msg.codes.len(), self.bits),
        );
        for &i in &msg.indices {
            p.u32(i);
        }
        p.bytes(&pack::pack(&msg.codes, self.bits));
        Ok(Frame::new(TAG_TOPK, h.finish(), p.finish()))
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        crate::ensure!(frame.tag() == TAG_TOPK, "topk codec got frame tag {}", frame.tag());
        let mut h = FrameReader::new(frame.header());
        let (bits, n, k, scale) = (h.u8()?, h.u32()? as usize, h.u32()? as usize, h.f32()?);
        h.done()?;
        crate::ensure!(
            bits == self.bits,
            "topk frame is {bits}-bit but this boundary is configured for {}",
            self.bits
        );
        // bound n by the configured batch shape before allocating anything
        crate::ensure!(
            n == ids.len() * self.el,
            "topk frame claims {n} elements, boundary expects {} ids x {} elements",
            ids.len(),
            self.el
        );
        crate::ensure!(k <= n, "topk frame keeps {k} of {n} entries");
        let mut p = FrameReader::new(frame.payload());
        let mut indices = Vec::with_capacity(k);
        for _ in 0..k {
            let i = p.u32()? as usize;
            crate::ensure!(i < n, "topk index {i} out of range (n = {n})");
            indices.push(i);
        }
        let codes = pack::unpack(p.bytes(pack::packed_len(k, bits))?, bits, k);
        p.done()?;
        let mut vals = vec![0f32; k];
        self.quant.decode(&codes, scale, &mut vals);
        let mut out = vec![0f32; n];
        for (&i, &v) in indices.iter().zip(&vals) {
            out[i] = v;
        }
        Ok(out)
    }

    fn label(&self) -> String {
        format!("topk{}@{}", self.frac, self.bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(11);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn raw32_is_lossless_and_measured() {
        let mut enc = Raw32Codec;
        let mut dec = Raw32Codec;
        let a = sample(37);
        let f = enc.encode(&[0], &a).unwrap();
        assert_eq!(f.wire_bytes(), f.to_bytes().len() as u64);
        assert_eq!(dec.decode(&[0], &f).unwrap(), a);
    }

    #[test]
    fn f16_decode_checks_payload_length() {
        let mut enc = F16Codec;
        let a = sample(9);
        let f = enc.encode(&[0], &a).unwrap();
        let mut bad = Frame::new(f.tag(), f.header().to_vec(), f.payload()[..4].to_vec());
        assert!(F16Codec.decode(&[0], &bad).is_err());
        bad = Frame::new(TAG_RAW32, f.header().to_vec(), f.payload().to_vec());
        assert!(F16Codec.decode(&[0], &bad).is_err());
    }

    #[test]
    fn directq_roundtrip_bounded_error() {
        let a = sample(100);
        let mut enc = DirectQCodec::new(4, Rounding::Nearest, 1, None);
        let mut dec = DirectQCodec::new(4, Rounding::Nearest, 2, None);
        let f = enc.encode(&[0], &a).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        let scale = UniformQuantizer::scale(&a);
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 15.0 + 1e-6);
        }
        // bit-width mismatch between peers is an error, not UB
        let mut dec8 = DirectQCodec::new(8, Rounding::Nearest, 3, None);
        assert!(dec8.decode(&[0], &f).is_err());
    }

    #[test]
    fn topk_keeps_largest_and_rejects_bad_indices() {
        let mut x = vec![0.01f32; 50];
        x[7] = 4.0;
        x[31] = -6.0;
        let mut enc = TopKCodec::new(0.04, 8, Rounding::Nearest, 50, 1);
        let mut dec = TopKCodec::new(0.04, 8, Rounding::Nearest, 50, 2);
        let f = enc.encode(&[0], &x).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        assert!((out[31] + 6.0).abs() < 0.1);
        assert_eq!(out[0], 0.0);
        // corrupt an index beyond n
        let mut payload = f.payload().to_vec();
        payload[0..4].copy_from_slice(&200u32.to_le_bytes());
        let bad = Frame::new(f.tag(), f.header().to_vec(), payload);
        assert!(dec.decode(&[0], &bad).is_err());
    }
}
