//! The stateless / per-message boundary codecs: FP32 passthrough, FP16
//! wire (App. H.4), DirectQ (AC-GC / TinyScript-style direct activation
//! quantization), and top-k sparsification + quantization (App. H.6).
//! The stateful AQ-SGD delta codec lives in `codec::delta`.
//!
//! Each codec is one self-contained frame format:
//!
//! | codec   | tag | header                          | payload              |
//! |---------|-----|---------------------------------|----------------------|
//! | raw32   | 1   | n: u32                          | n × f32 LE           |
//! | f16     | 2   | n: u32                          | n × f16 LE           |
//! | directq | 3   | bits: u8, n: u32, scale: f32    | packed codes         |
//! | topk    | 5   | bits: u8, n: u32, k: u32, scale | k × u32 idx + codes  |
//!
//! All four implement the scratch hot path natively
//! (`encode_into`/`decode_into`): frame bytes are built in the caller's
//! [`FrameBuf`] and decoded from a borrowed [`FrameView`], with any
//! per-message working set (quantizer codes, top-k selections) held in
//! codec-owned scratch vectors whose capacity persists across messages —
//! so the steady-state path never touches the allocator. The allocating
//! `encode`/`decode` are thin wrappers over the same implementations.

use std::sync::Arc;

use crate::runtime::QuantRuntime;
use crate::util::error::Result;
use crate::util::Rng;

use super::frame::{
    FrameBuf, FrameReader, FrameView, TAG_DIRECTQ, TAG_F16, TAG_RAW32, TAG_TOPK,
};
use super::par::Workers;
use super::quantizer::{Rounding, UniformQuantizer};
use super::{encode_to_frame, f16, pack, topk, BoundaryCodec, Frame};

/// FP32 passthrough: the paper's no-compression baseline.
pub struct Raw32Codec;

impl Raw32Codec {
    /// Validate tag + header and return the element count, with the
    /// payload length checked *before* anything is allocated.
    fn check(tag: u8, header: &[u8], payload: &[u8]) -> Result<usize> {
        crate::ensure!(tag == TAG_RAW32, "raw32 codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let n = h.u32()? as usize;
        h.done()?;
        crate::ensure!(
            payload.len() == 4 * n,
            "raw32 frame payload {} bytes, want {}",
            payload.len(),
            4 * n
        );
        Ok(n)
    }
}

impl BoundaryCodec for Raw32Codec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, _ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        out.start(TAG_RAW32);
        out.u32(a.len() as u32);
        out.end_header();
        out.f32_slice(a);
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let n = Self::check(frame.tag(), frame.header(), frame.payload())?;
        let mut out = vec![0f32; n];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, _ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let n = Self::check(frame.tag(), frame.header(), frame.payload())?;
        crate::ensure!(
            n == out.len(),
            "raw32 frame has {n} elements, boundary expects {}",
            out.len()
        );
        let mut p = FrameReader::new(frame.payload());
        p.f32_into(out)?;
        p.done()
    }

    fn label(&self) -> String {
        "fp32".into()
    }
}

/// IEEE binary16 wire format (paper Appendix H.4).
pub struct F16Codec;

impl F16Codec {
    fn check(tag: u8, header: &[u8], payload: &[u8]) -> Result<usize> {
        crate::ensure!(tag == TAG_F16, "f16 codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let n = h.u32()? as usize;
        h.done()?;
        crate::ensure!(
            payload.len() == 2 * n,
            "f16 frame payload {} bytes, want {}",
            payload.len(),
            2 * n
        );
        Ok(n)
    }
}

impl BoundaryCodec for F16Codec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, _ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        out.start(TAG_F16);
        out.u32(a.len() as u32);
        out.end_header();
        out.reserve(2 * a.len());
        for &v in a {
            out.u16(f16::f32_to_f16_bits(v));
        }
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let n = Self::check(frame.tag(), frame.header(), frame.payload())?;
        let mut out = vec![0f32; n];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, _ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let n = Self::check(frame.tag(), frame.header(), frame.payload())?;
        crate::ensure!(
            n == out.len(),
            "f16 frame has {n} elements, boundary expects {}",
            out.len()
        );
        f16::decode_slice(frame.payload(), out);
        Ok(())
    }

    fn label(&self) -> String {
        "fp16".into()
    }
}

/// Direct b-bit quantization of the activation itself (one per-message
/// max-abs scale), optionally through the Pallas HLO kernels.
pub struct DirectQCodec {
    bits: u8,
    rounding: Rounding,
    rng: Rng,
    hlo: Option<Arc<QuantRuntime>>,
    /// per-message quantizer codes for the HLO arms (the native path is
    /// fused and never stages codes), reused across messages
    codes: Vec<u8>,
    workers: Workers,
}

impl DirectQCodec {
    pub fn new(bits: u8, rounding: Rounding, seed: u64, hlo: Option<Arc<QuantRuntime>>) -> Self {
        DirectQCodec {
            bits,
            rounding,
            rng: Rng::new(seed),
            hlo,
            codes: Vec::new(),
            workers: Workers::seq(),
        }
    }

    fn check(&self, tag: u8, header: &[u8], payload: &[u8]) -> Result<(usize, f32)> {
        crate::ensure!(tag == TAG_DIRECTQ, "directq codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let (bits, n, scale) = (h.u8()?, h.u32()? as usize, h.f32()?);
        h.done()?;
        crate::ensure!(
            bits == self.bits,
            "directq frame is {bits}-bit but this boundary is configured for {}",
            self.bits
        );
        crate::ensure!(
            payload.len() == pack::packed_len(n, bits),
            "directq frame payload {} bytes, want {}",
            payload.len(),
            pack::packed_len(n, bits)
        );
        Ok((n, scale))
    }
}

impl BoundaryCodec for DirectQCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, _ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        if let Some(q) = &self.hlo {
            if q.n_elements() == a.len() {
                let (codes, scale) = q.dq_encode(a, self.bits)?;
                self.codes.clear();
                self.codes.extend_from_slice(&codes);
                out.start(TAG_DIRECTQ);
                out.u8(self.bits).u32(a.len() as u32).f32(scale);
                out.end_header();
                let packed = out.reserve_zeroed(pack::packed_len(a.len(), self.bits));
                pack::pack_into(&self.codes, self.bits, packed);
                return out.finish();
            }
        }
        // native fused path: validate finiteness, then quantize straight
        // into the packed payload — no u8 staging buffer
        let q = UniformQuantizer::new(self.bits, self.rounding);
        let scale = UniformQuantizer::checked_scale(a)?;
        out.start(TAG_DIRECTQ);
        out.u8(self.bits).u32(a.len() as u32).f32(scale);
        out.end_header();
        let packed = out.reserve_zeroed(pack::packed_len(a.len(), self.bits));
        q.encode_packed_with_scale(a, scale, packed, &mut self.rng, &self.workers);
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let (n, _) = self.check(frame.tag(), frame.header(), frame.payload())?;
        let mut out = vec![0f32; n];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, _ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let (n, scale) = self.check(frame.tag(), frame.header(), frame.payload())?;
        crate::ensure!(
            n == out.len(),
            "directq frame has {n} elements, boundary expects {}",
            out.len()
        );
        match &self.hlo {
            Some(q) if q.n_elements() == n => {
                self.codes.resize(n, 0);
                pack::unpack_into(frame.payload(), self.bits, &mut self.codes);
                let v = q.dq_decode(&self.codes, scale, self.bits)?;
                crate::ensure!(
                    v.len() == out.len(),
                    "hlo dq_decode returned {} elements for an {}-element message",
                    v.len(),
                    out.len()
                );
                out.copy_from_slice(&v);
            }
            _ => {
                // fused unpack+dequantize, chunked across the pool
                let q = UniformQuantizer::new(self.bits, self.rounding);
                q.decode_packed(frame.payload(), scale, out, &self.workers);
            }
        }
        Ok(())
    }

    fn label(&self) -> String {
        format!("q{}", self.bits)
    }

    fn set_workers(&mut self, threads: usize) {
        self.workers = Workers::new(threads);
    }
}

/// Top-k magnitude sparsification + b-bit quantization of the kept
/// values (paper Appendix H.6's `bw8[0.2]` split-learning scheme).
pub struct TopKCodec {
    frac: f64,
    bits: u8,
    quant: UniformQuantizer,
    /// elements per example record — bounds the dense length a frame may
    /// claim, so a malformed header cannot force a huge allocation
    el: usize,
    rng: Rng,
    /// per-message scratch (kept indices / values), reused; codes go
    /// straight to/from the packed payload via the fused kernels
    sel: Vec<u32>,
    vals: Vec<f32>,
    workers: Workers,
}

impl TopKCodec {
    pub fn new(frac: f64, bits: u8, rounding: Rounding, el: usize, seed: u64) -> Self {
        assert!(frac > 0.0 && frac <= 1.0, "topk frac must be in (0, 1], got {frac}");
        TopKCodec {
            frac,
            bits,
            quant: UniformQuantizer::new(bits, rounding),
            el,
            rng: Rng::new(seed),
            sel: Vec::new(),
            vals: Vec::new(),
            workers: Workers::seq(),
        }
    }

    /// Validate tag + header against the configured batch shape; returns
    /// (dense length, kept count, scale).
    fn check(&self, ids: &[u64], tag: u8, header: &[u8]) -> Result<(usize, usize, f32)> {
        crate::ensure!(tag == TAG_TOPK, "topk codec got frame tag {tag}");
        let mut h = FrameReader::new(header);
        let (bits, n, k, scale) = (h.u8()?, h.u32()? as usize, h.u32()? as usize, h.f32()?);
        h.done()?;
        crate::ensure!(
            bits == self.bits,
            "topk frame is {bits}-bit but this boundary is configured for {}",
            self.bits
        );
        // bound n by the configured batch shape before allocating anything
        crate::ensure!(
            n == ids.len() * self.el,
            "topk frame claims {n} elements, boundary expects {} ids x {} elements",
            ids.len(),
            self.el
        );
        crate::ensure!(k <= n, "topk frame keeps {k} of {n} entries");
        Ok((n, k, scale))
    }
}

impl BoundaryCodec for TopKCodec {
    fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<Frame> {
        encode_to_frame(self, ids, a)
    }

    fn encode_into(&mut self, ids: &[u64], a: &[f32], out: &mut FrameBuf) -> Result<()> {
        crate::ensure!(
            a.len() == ids.len() * self.el,
            "topk message length {} != {} ids x {} elements",
            a.len(),
            ids.len(),
            self.el
        );
        // a NaN/Inf activation must error here, not vanish inside the
        // magnitude select (NaN compares false) and decode as garbage
        UniformQuantizer::checked_scale(a)?;
        topk::select_topk_into(a, self.frac, &mut self.sel);
        let k = self.sel.len();
        self.vals.clear();
        self.vals.extend(self.sel.iter().map(|&i| a[i as usize]));
        let scale = UniformQuantizer::scale(&self.vals);
        out.start(TAG_TOPK);
        out.u8(self.bits).u32(a.len() as u32).u32(k as u32).f32(scale);
        out.end_header();
        out.reserve(4 * k + pack::packed_len(k, self.bits));
        for &i in &self.sel {
            out.u32(i);
        }
        let packed = out.reserve_zeroed(pack::packed_len(k, self.bits));
        let pool = self.workers;
        self.quant.encode_packed_with_scale(&self.vals, scale, packed, &mut self.rng, &pool);
        out.finish()
    }

    fn decode(&mut self, ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        let mut out = vec![0f32; ids.len() * self.el];
        self.decode_into(ids, &frame.view(), &mut out)?;
        Ok(out)
    }

    fn decode_into(&mut self, ids: &[u64], frame: &FrameView<'_>, out: &mut [f32]) -> Result<()> {
        let (n, k, scale) = self.check(ids, frame.tag(), frame.header())?;
        crate::ensure!(
            n == out.len(),
            "topk frame has {n} elements, boundary expects {}",
            out.len()
        );
        let mut p = FrameReader::new(frame.payload());
        self.sel.clear();
        for _ in 0..k {
            let i = p.u32()?;
            crate::ensure!((i as usize) < n, "topk index {i} out of range (n = {n})");
            self.sel.push(i);
        }
        let packed = p.bytes(pack::packed_len(k, self.bits))?;
        p.done()?;
        self.vals.resize(k, 0.0);
        self.quant.decode_packed(packed, scale, &mut self.vals, &self.workers);
        out.fill(0.0);
        for (&i, &v) in self.sel.iter().zip(&self.vals) {
            out[i as usize] = v;
        }
        Ok(())
    }

    fn label(&self) -> String {
        format!("topk{}@{}", self.frac, self.bits)
    }

    fn set_workers(&mut self, threads: usize) {
        self.workers = Workers::new(threads);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(11);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn raw32_is_lossless_and_measured() {
        let mut enc = Raw32Codec;
        let mut dec = Raw32Codec;
        let a = sample(37);
        let f = enc.encode(&[0], &a).unwrap();
        assert_eq!(f.wire_bytes(), f.to_bytes().len() as u64);
        assert_eq!(dec.decode(&[0], &f).unwrap(), a);
    }

    #[test]
    fn f16_decode_checks_payload_length() {
        let mut enc = F16Codec;
        let a = sample(9);
        let f = enc.encode(&[0], &a).unwrap();
        let mut bad = Frame::new(f.tag(), f.header().to_vec(), f.payload()[..4].to_vec());
        assert!(F16Codec.decode(&[0], &bad).is_err());
        bad = Frame::new(TAG_RAW32, f.header().to_vec(), f.payload().to_vec());
        assert!(F16Codec.decode(&[0], &bad).is_err());
    }

    #[test]
    fn directq_roundtrip_bounded_error() {
        let a = sample(100);
        let mut enc = DirectQCodec::new(4, Rounding::Nearest, 1, None);
        let mut dec = DirectQCodec::new(4, Rounding::Nearest, 2, None);
        let f = enc.encode(&[0], &a).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        let scale = UniformQuantizer::scale(&a);
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 15.0 + 1e-6);
        }
        // bit-width mismatch between peers is an error, not UB
        let mut dec8 = DirectQCodec::new(8, Rounding::Nearest, 3, None);
        assert!(dec8.decode(&[0], &f).is_err());
    }

    #[test]
    fn topk_keeps_largest_and_rejects_bad_indices() {
        let mut x = vec![0.01f32; 50];
        x[7] = 4.0;
        x[31] = -6.0;
        let mut enc = TopKCodec::new(0.04, 8, Rounding::Nearest, 50, 1);
        let mut dec = TopKCodec::new(0.04, 8, Rounding::Nearest, 50, 2);
        let f = enc.encode(&[0], &x).unwrap();
        let out = dec.decode(&[0], &f).unwrap();
        assert!((out[31] + 6.0).abs() < 0.1);
        assert_eq!(out[0], 0.0);
        // corrupt an index beyond n
        let mut payload = f.payload().to_vec();
        payload[0..4].copy_from_slice(&200u32.to_le_bytes());
        let bad = Frame::new(f.tag(), f.header().to_vec(), payload);
        assert!(dec.decode(&[0], &bad).is_err());
    }

    #[test]
    fn scratch_path_reuses_buffers_and_matches_frames() {
        // same seed, two encoder instances: the allocating and scratch
        // paths must produce byte-identical images, message after message
        let a1 = sample(64);
        let a2: Vec<f32> = a1.iter().map(|v| v * 0.5 + 0.1).collect();
        let mut enc_a = DirectQCodec::new(4, Rounding::Nearest, 9, None);
        let mut enc_b = DirectQCodec::new(4, Rounding::Nearest, 9, None);
        let mut buf = FrameBuf::new();
        for a in [&a1, &a2] {
            let f = enc_a.encode(&[0], a).unwrap();
            enc_b.encode_into(&[0], a, &mut buf).unwrap();
            assert_eq!(buf.as_bytes(), f.to_bytes().as_slice());
            // and the scratch decode reconstructs into a caller buffer
            let mut dec = DirectQCodec::new(4, Rounding::Nearest, 2, None);
            let mut out = vec![0f32; a.len()];
            dec.decode_into(&[0], &buf.view(), &mut out).unwrap();
            assert_eq!(out, dec.decode(&[0], &f).unwrap());
        }
    }

    #[test]
    fn decode_into_rejects_wrong_output_shape() {
        let a = sample(16);
        let mut enc = Raw32Codec;
        let mut buf = FrameBuf::new();
        enc.encode_into(&[0], &a, &mut buf).unwrap();
        let mut small = vec![0f32; 8];
        assert!(Raw32Codec.decode_into(&[0], &buf.view(), &mut small).is_err());
        let mut enc16 = F16Codec;
        enc16.encode_into(&[0], &a, &mut buf).unwrap();
        assert!(F16Codec.decode_into(&[0], &buf.view(), &mut small).is_err());
    }
}
