//! The framed wire message every boundary codec produces and consumes.
//!
//! A [`Frame`] is self-describing: a one-byte scheme tag, a
//! scheme-specific header (shape, bit-width, scales), and the packed
//! payload bytes. Serialized layout (all integers little-endian):
//!
//! ```text
//! tag: u8 | header_len: u16 | payload_len: u32 | header | payload
//! ```
//!
//! Wire accounting is *measured from these buffers* — `wire_bytes()` is
//! exactly `to_bytes().len()` (pinned by tests), never re-derived
//! arithmetically — and `from_bytes(to_bytes(f)) == f` bit-for-bit, so
//! the in-memory fast path the trainer uses and the serialized path a
//! real deployment would ship are interchangeable.

use crate::util::error::Result;

/// Fixed serialization prelude: tag (1) + header_len (2) + payload_len (4).
pub const FRAME_PRELUDE_BYTES: usize = 7;

/// Scheme tags. One per wire format, stable across releases (golden
/// fixtures pin them).
pub const TAG_RAW32: u8 = 1;
pub const TAG_F16: u8 = 2;
pub const TAG_DIRECTQ: u8 = 3;
pub const TAG_AQ: u8 = 4;
pub const TAG_TOPK: u8 = 5;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    tag: u8,
    header: Vec<u8>,
    payload: Vec<u8>,
}

impl Frame {
    pub fn new(tag: u8, header: Vec<u8>, payload: Vec<u8>) -> Self {
        Frame { tag, header, payload }
    }

    pub fn tag(&self) -> u8 {
        self.tag
    }

    pub fn header(&self) -> &[u8] {
        &self.header
    }

    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Bytes this message occupies on the wire: prelude + header +
    /// payload, i.e. exactly `self.to_bytes().len()`.
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_PRELUDE_BYTES + self.header.len() + self.payload.len()) as u64
    }

    /// Serialize to the wire image.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() as usize);
        out.push(self.tag);
        out.extend_from_slice(&(self.header.len() as u16).to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse a wire image. Malformed input (truncation, trailing bytes,
    /// oversized header) is an error, never a panic — frames arrive from
    /// a peer.
    pub fn from_bytes(bytes: &[u8]) -> Result<Frame> {
        crate::ensure!(
            bytes.len() >= FRAME_PRELUDE_BYTES,
            "frame truncated: {} bytes, need at least {FRAME_PRELUDE_BYTES}",
            bytes.len()
        );
        let tag = bytes[0];
        let header_len = u16::from_le_bytes([bytes[1], bytes[2]]) as usize;
        let payload_len = u32::from_le_bytes([bytes[3], bytes[4], bytes[5], bytes[6]]) as usize;
        let want = FRAME_PRELUDE_BYTES + header_len + payload_len;
        crate::ensure!(
            bytes.len() == want,
            "frame length mismatch: got {} bytes, prelude says {want}",
            bytes.len()
        );
        let header = bytes[FRAME_PRELUDE_BYTES..FRAME_PRELUDE_BYTES + header_len].to_vec();
        let payload = bytes[FRAME_PRELUDE_BYTES + header_len..].to_vec();
        Ok(Frame { tag, header, payload })
    }
}

// ---------------------------------------------------------------------------

/// Little-endian writer for frame headers / payloads.
#[derive(Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    pub fn with_capacity(n: usize) -> Self {
        FrameWriter { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32_slice(&mut self, v: &[f32]) -> &mut Self {
        self.buf.reserve(4 * v.len());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    pub fn finish(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }
}

/// Cursor-based little-endian reader with `Result` errors on truncation
/// (a malformed frame from a peer must not abort the process).
pub struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        FrameReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            self.pos + n <= self.buf.len(),
            "frame truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the reader consumed everything (trailing garbage is an
    /// error: a well-formed frame has no slack).
    pub fn done(&self) -> Result<()> {
        crate::ensure!(self.remaining() == 0, "frame has {} trailing bytes", self.remaining());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialize_roundtrip_is_identity() {
        let f = Frame::new(TAG_DIRECTQ, vec![4, 1, 2, 3], vec![0xAB; 17]);
        let bytes = f.to_bytes();
        assert_eq!(bytes.len() as u64, f.wire_bytes());
        assert_eq!(
            f.wire_bytes(),
            (FRAME_PRELUDE_BYTES + f.header().len() + f.payload().len()) as u64
        );
        let back = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn truncated_and_padded_frames_error() {
        let f = Frame::new(TAG_RAW32, vec![1, 2], vec![3, 4, 5]);
        let bytes = f.to_bytes();
        assert!(Frame::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(Frame::from_bytes(&bytes[..3]).is_err());
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(Frame::from_bytes(&padded).is_err());
        assert!(Frame::from_bytes(&[]).is_err());
    }

    #[test]
    fn reader_errors_on_truncation() {
        let mut w = FrameWriter::default();
        w.u8(7).u32(1234).f32(1.5);
        let buf = w.finish();
        let mut r = FrameReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 1234);
        assert_eq!(r.f32().unwrap(), 1.5);
        r.done().unwrap();
        assert!(r.u8().is_err());
        let mut r2 = FrameReader::new(&buf);
        assert!(r2.f32_vec(3).is_err());
        assert!(r2.done().is_err()); // unconsumed bytes
    }

    #[test]
    fn writer_reader_f32_slice() {
        let x = [1.0f32, -2.5, 3.25];
        let mut w = FrameWriter::default();
        w.f32_slice(&x);
        let buf = w.finish();
        assert_eq!(buf.len(), 12);
        let mut r = FrameReader::new(&buf);
        assert_eq!(r.f32_vec(3).unwrap(), x);
    }
}
