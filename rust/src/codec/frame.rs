//! The framed wire message every boundary codec produces and consumes.
//!
//! A [`Frame`] is self-describing: a one-byte scheme tag, a
//! scheme-specific header (shape, bit-width, scales), and the packed
//! payload bytes. Serialized layout (all integers little-endian):
//!
//! ```text
//! tag: u8 | header_len: u16 | payload_len: u32 | header | payload
//! ```
//!
//! Wire accounting is *measured from these buffers* — `wire_bytes()` is
//! exactly `to_bytes().len()` (pinned by tests), never re-derived
//! arithmetically — and `from_bytes(to_bytes(f)) == f` bit-for-bit, so
//! the in-memory fast path the trainer uses and the serialized path a
//! real deployment would ship are interchangeable.
//!
//! Two shapes of the same wire image serve the two performance regimes:
//!
//!  * [`Frame`] — owned header/payload `Vec`s, the convenient allocating
//!    form the trainer-level APIs hand around;
//!  * [`FrameBuf`] (send side) and [`FrameView`] (receive side) — the
//!    steady-state hot path. A `FrameBuf` is a reusable scratch arena a
//!    codec's `encode_into` builds the *serialized* image in directly
//!    (capacity is retained across messages, so a warmed endpoint
//!    encodes without touching the allocator), and a `FrameView` borrows
//!    tag/header/payload straight out of a received byte buffer, so
//!    `decode_into` reads payload bytes in place. Both produce/accept
//!    byte-identical images to `Frame` — pinned by `prop_frames.rs`.

use crate::util::error::Result;

/// Fixed serialization prelude: tag (1) + header_len (2) + payload_len (4).
pub const FRAME_PRELUDE_BYTES: usize = 7;

/// Scheme tags. One per wire format, stable across releases (golden
/// fixtures pin them).
pub const TAG_RAW32: u8 = 1;
pub const TAG_F16: u8 = 2;
pub const TAG_DIRECTQ: u8 = 3;
pub const TAG_AQ: u8 = 4;
pub const TAG_TOPK: u8 = 5;
/// Session-layer handshake frame (`net::session`), not a codec format:
/// carries (version, link kind, peer coordinates) in the header and the
/// canonical config summary in the payload.
pub const TAG_HELLO: u8 = 6;
/// Serving-session envelope (`crate::serve`), not a codec format: the
/// header carries (kind, session, seq, example id, flags) and the
/// payload wraps an inner codec frame — many sessions multiplex one
/// transport, and this tag is how the demux tells them apart.
pub const TAG_SESSION: u8 = 7;
/// Tile-wise adaptive quantization (`codec::tile`): the header carries
/// (budget, tile length, n) and the payload is a per-tile sequence of
/// (bits, scale, packed codes) records — the variance-driven bit map
/// rides with the data it describes.
pub const TAG_TILE: u8 = 8;
/// Low-rank delta codec (`codec::lowrank`): per-record full/coefficient
/// sections followed by one embedded inner-codec residual frame.
pub const TAG_LR: u8 = 9;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Frame {
    tag: u8,
    header: Vec<u8>,
    payload: Vec<u8>,
}

impl Frame {
    pub fn new(tag: u8, header: Vec<u8>, payload: Vec<u8>) -> Self {
        Frame { tag, header, payload }
    }

    pub fn tag(&self) -> u8 {
        self.tag
    }

    pub fn header(&self) -> &[u8] {
        &self.header
    }

    pub fn payload(&self) -> &[u8] {
        &self.payload
    }

    /// Bytes this message occupies on the wire: prelude + header +
    /// payload, i.e. exactly `self.to_bytes().len()`.
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_PRELUDE_BYTES + self.header.len() + self.payload.len()) as u64
    }

    /// Serialize to the wire image.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() as usize);
        out.push(self.tag);
        out.extend_from_slice(&(self.header.len() as u16).to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.header);
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse a wire image. Malformed input (truncation, trailing bytes,
    /// oversized header) is an error, never a panic — frames arrive from
    /// a peer. Allocating form of [`FrameView::parse`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Frame> {
        Ok(FrameView::parse(bytes)?.to_frame())
    }

    /// Borrow this frame's parts as a [`FrameView`] (what the scratch
    /// decode path consumes).
    pub fn view(&self) -> FrameView<'_> {
        FrameView { tag: self.tag, header: &self.header, payload: &self.payload }
    }
}

// ---------------------------------------------------------------------------

/// A borrowed parse of one serialized frame: tag/header/payload point
/// into the receive buffer, so decoding reads payload bytes in place —
/// no header/payload copies on the hot path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameView<'a> {
    tag: u8,
    header: &'a [u8],
    payload: &'a [u8],
}

impl<'a> FrameView<'a> {
    /// Parse a wire image without copying. The prelude's claimed
    /// `header_len + payload_len` is validated against the actual slice
    /// *before* any split — a short or hostile buffer (including length
    /// sums that would overflow a 32-bit `usize`) is an `Err`, never a
    /// panic or an oversized allocation.
    pub fn parse(bytes: &'a [u8]) -> Result<FrameView<'a>> {
        crate::ensure!(
            bytes.len() >= FRAME_PRELUDE_BYTES,
            "frame truncated: {} bytes, need at least {FRAME_PRELUDE_BYTES}",
            bytes.len()
        );
        let tag = bytes[0];
        let header_len = u16::from_le_bytes([bytes[1], bytes[2]]) as usize;
        let payload_len = u32::from_le_bytes([bytes[3], bytes[4], bytes[5], bytes[6]]) as usize;
        // u64 arithmetic: the claimed total cannot overflow even where
        // usize is 32 bits, so the comparison below is always exact
        let want = FRAME_PRELUDE_BYTES as u64 + header_len as u64 + payload_len as u64;
        crate::ensure!(
            bytes.len() as u64 == want,
            "frame length mismatch: got {} bytes, prelude says {want}",
            bytes.len()
        );
        let header = &bytes[FRAME_PRELUDE_BYTES..FRAME_PRELUDE_BYTES + header_len];
        let payload = &bytes[FRAME_PRELUDE_BYTES + header_len..];
        Ok(FrameView { tag, header, payload })
    }

    pub fn tag(&self) -> u8 {
        self.tag
    }

    pub fn header(&self) -> &'a [u8] {
        self.header
    }

    pub fn payload(&self) -> &'a [u8] {
        self.payload
    }

    /// Bytes of the underlying wire image.
    pub fn wire_bytes(&self) -> u64 {
        (FRAME_PRELUDE_BYTES + self.header.len() + self.payload.len()) as u64
    }

    /// Copy out into an owned [`Frame`] (the allocating compat path).
    pub fn to_frame(&self) -> Frame {
        Frame { tag: self.tag, header: self.header.to_vec(), payload: self.payload.to_vec() }
    }
}

// ---------------------------------------------------------------------------

/// Reusable scratch arena a codec's
/// [`encode_into`](super::BoundaryCodec::encode_into) builds the
/// serialized wire image in directly. The buffer's capacity is retained
/// across messages, so a warmed endpoint re-encodes without allocating
/// (pinned by `tests/zero_alloc.rs`).
///
/// Build protocol (enforced by debug assertions — misuse is a codec
/// bug, not peer input): [`start`](Self::start) → header appends →
/// [`end_header`](Self::end_header) → payload appends →
/// [`finish`](Self::finish) → read accessors. The image produced is
/// byte-identical to `Frame::new(tag, header, payload).to_bytes()`.
pub struct FrameBuf {
    /// The full wire image: prelude + header + payload.
    bytes: Vec<u8>,
    header_len: usize,
    /// 0 = header open, 1 = payload open, 2 = sealed.
    stage: u8,
}

impl Default for FrameBuf {
    fn default() -> Self {
        FrameBuf::new()
    }
}

impl FrameBuf {
    /// A sealed empty frame (tag 0, no header, no payload); call
    /// [`start`](Self::start) before appending.
    pub fn new() -> Self {
        FrameBuf { bytes: vec![0; FRAME_PRELUDE_BYTES], header_len: 0, stage: 2 }
    }

    /// Begin a new frame with `tag`, discarding any previous content
    /// while keeping the allocation.
    pub fn start(&mut self, tag: u8) -> &mut Self {
        self.bytes.clear();
        self.bytes.resize(FRAME_PRELUDE_BYTES, 0);
        self.bytes[0] = tag;
        self.header_len = 0;
        self.stage = 0;
        self
    }

    /// Close the header region; subsequent appends are payload bytes.
    pub fn end_header(&mut self) -> &mut Self {
        debug_assert_eq!(self.stage, 0, "end_header outside the header stage");
        self.header_len = self.bytes.len() - FRAME_PRELUDE_BYTES;
        self.stage = 1;
        self
    }

    /// Seal the frame: patch the prelude's length fields. Errors if the
    /// header or payload exceeds its length field (u16 / u32).
    pub fn finish(&mut self) -> Result<()> {
        debug_assert_eq!(self.stage, 1, "finish before end_header");
        let payload_len = self.bytes.len() - FRAME_PRELUDE_BYTES - self.header_len;
        crate::ensure!(
            self.header_len <= u16::MAX as usize,
            "frame header {} bytes exceeds the u16 length field",
            self.header_len
        );
        crate::ensure!(
            payload_len <= u32::MAX as usize,
            "frame payload {payload_len} bytes exceeds the u32 length field"
        );
        self.bytes[1..3].copy_from_slice(&(self.header_len as u16).to_le_bytes());
        self.bytes[3..7].copy_from_slice(&(payload_len as u32).to_le_bytes());
        self.stage = 2;
        Ok(())
    }

    // ---- appends (header stage or payload stage) ----

    pub fn u8(&mut self, v: u8) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.push(v);
        self
    }

    pub fn u16(&mut self, v: u16) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32_slice(&mut self, v: &[f32]) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.reserve(4 * v.len());
        for x in v {
            self.bytes.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        self.bytes.extend_from_slice(v);
        self
    }

    /// Pre-size the underlying buffer for `additional` upcoming bytes.
    pub fn reserve(&mut self, additional: usize) -> &mut Self {
        self.bytes.reserve(additional);
        self
    }

    /// Append `n` zero bytes and return the new tail as a mutable slice
    /// — the in-place destination for `pack::pack_into`-style writers.
    pub fn reserve_zeroed(&mut self, n: usize) -> &mut [u8] {
        debug_assert!(self.stage < 2, "append to a sealed FrameBuf");
        let at = self.bytes.len();
        self.bytes.resize(at + n, 0);
        &mut self.bytes[at..]
    }

    /// Rebuild this buffer from an owned [`Frame`] (the default
    /// `encode_into` shim for codecs without a native scratch path).
    pub fn copy_from_frame(&mut self, f: &Frame) -> Result<()> {
        self.start(f.tag());
        self.bytes(f.header());
        self.end_header();
        self.bytes(f.payload());
        self.finish()
    }

    // ---- sealed accessors ----

    /// The serialized wire image (identical to `to_frame().to_bytes()`).
    pub fn as_bytes(&self) -> &[u8] {
        debug_assert_eq!(self.stage, 2, "read from an unsealed FrameBuf");
        &self.bytes
    }

    pub fn tag(&self) -> u8 {
        debug_assert_eq!(self.stage, 2, "read from an unsealed FrameBuf");
        self.bytes[0]
    }

    pub fn header(&self) -> &[u8] {
        debug_assert_eq!(self.stage, 2, "read from an unsealed FrameBuf");
        &self.bytes[FRAME_PRELUDE_BYTES..FRAME_PRELUDE_BYTES + self.header_len]
    }

    pub fn payload(&self) -> &[u8] {
        debug_assert_eq!(self.stage, 2, "read from an unsealed FrameBuf");
        &self.bytes[FRAME_PRELUDE_BYTES + self.header_len..]
    }

    /// Bytes this message occupies on the wire (`as_bytes().len()`).
    pub fn wire_bytes(&self) -> u64 {
        debug_assert_eq!(self.stage, 2, "read from an unsealed FrameBuf");
        self.bytes.len() as u64
    }

    /// Borrow the built image as a [`FrameView`] (feeds `decode_into`).
    pub fn view(&self) -> FrameView<'_> {
        FrameView {
            tag: self.tag(),
            header: self.header(),
            payload: self.payload(),
        }
    }

    /// Copy out into an owned [`Frame`] (the allocating compat path).
    pub fn to_frame(&self) -> Frame {
        Frame::new(self.tag(), self.header().to_vec(), self.payload().to_vec())
    }
}

// ---------------------------------------------------------------------------

/// Little-endian writer for frame headers / payloads.
#[derive(Default)]
pub struct FrameWriter {
    buf: Vec<u8>,
}

impl FrameWriter {
    pub fn with_capacity(n: usize) -> Self {
        FrameWriter { buf: Vec::with_capacity(n) }
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32(&mut self, v: f32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f32_slice(&mut self, v: &[f32]) -> &mut Self {
        self.buf.reserve(4 * v.len());
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    pub fn finish(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }
}

/// Cursor-based little-endian reader with `Result` errors on truncation
/// (a malformed frame from a peer must not abort the process).
pub struct FrameReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> FrameReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        FrameReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            self.pos + n <= self.buf.len(),
            "frame truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Read `out.len()` f32 values into a caller-owned buffer (the
    /// allocation-free twin of [`f32_vec`](Self::f32_vec)).
    pub fn f32_into(&mut self, out: &mut [f32]) -> Result<()> {
        let b = self.take(4 * out.len())?;
        for (o, c) in out.iter_mut().zip(b.chunks_exact(4)) {
            *o = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the reader consumed everything (trailing garbage is an
    /// error: a well-formed frame has no slack).
    pub fn done(&self) -> Result<()> {
        crate::ensure!(self.remaining() == 0, "frame has {} trailing bytes", self.remaining());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialize_roundtrip_is_identity() {
        let f = Frame::new(TAG_DIRECTQ, vec![4, 1, 2, 3], vec![0xAB; 17]);
        let bytes = f.to_bytes();
        assert_eq!(bytes.len() as u64, f.wire_bytes());
        assert_eq!(
            f.wire_bytes(),
            (FRAME_PRELUDE_BYTES + f.header().len() + f.payload().len()) as u64
        );
        let back = Frame::from_bytes(&bytes).unwrap();
        assert_eq!(back, f);
    }

    #[test]
    fn truncated_and_padded_frames_error() {
        let f = Frame::new(TAG_RAW32, vec![1, 2], vec![3, 4, 5]);
        let bytes = f.to_bytes();
        assert!(Frame::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        assert!(Frame::from_bytes(&bytes[..3]).is_err());
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(Frame::from_bytes(&padded).is_err());
        assert!(Frame::from_bytes(&[]).is_err());
    }

    #[test]
    fn reader_errors_on_truncation() {
        let mut w = FrameWriter::default();
        w.u8(7).u32(1234).f32(1.5);
        let buf = w.finish();
        let mut r = FrameReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 1234);
        assert_eq!(r.f32().unwrap(), 1.5);
        r.done().unwrap();
        assert!(r.u8().is_err());
        let mut r2 = FrameReader::new(&buf);
        assert!(r2.f32_vec(3).is_err());
        assert!(r2.done().is_err()); // unconsumed bytes
    }

    #[test]
    fn framebuf_image_matches_frame_serialization() {
        let f = Frame::new(TAG_TOPK, vec![8, 0, 1, 2], vec![0xCD; 23]);
        let mut buf = FrameBuf::new();
        buf.start(TAG_TOPK);
        buf.bytes(f.header());
        buf.end_header();
        buf.bytes(f.payload());
        buf.finish().unwrap();
        assert_eq!(buf.as_bytes(), f.to_bytes().as_slice());
        assert_eq!(buf.wire_bytes(), f.wire_bytes());
        assert_eq!(buf.tag(), f.tag());
        assert_eq!(buf.header(), f.header());
        assert_eq!(buf.payload(), f.payload());
        assert_eq!(buf.to_frame(), f);
        // rebuilding from an owned frame gives the same image, and the
        // capacity is reused (no fresh allocation needed for same sizes)
        let mut buf2 = FrameBuf::new();
        buf2.copy_from_frame(&f).unwrap();
        assert_eq!(buf2.as_bytes(), buf.as_bytes());
        // view round-trips through parse
        let v = FrameView::parse(buf.as_bytes()).unwrap();
        assert_eq!(v.tag(), f.tag());
        assert_eq!(v.header(), f.header());
        assert_eq!(v.payload(), f.payload());
        assert_eq!(v.to_frame(), f);
    }

    #[test]
    fn framebuf_reserve_zeroed_writes_in_place() {
        let mut buf = FrameBuf::new();
        buf.start(TAG_DIRECTQ);
        buf.u8(4).u32(6).f32(1.0);
        buf.end_header();
        buf.reserve_zeroed(3).copy_from_slice(&[0xAA, 0xBB, 0xCC]);
        buf.finish().unwrap();
        assert_eq!(buf.payload(), &[0xAA, 0xBB, 0xCC]);
        assert_eq!(buf.header().len(), 9);
    }

    #[test]
    fn frameview_validates_lengths_before_splitting() {
        let f = Frame::new(TAG_AQ, vec![1, 2, 3], vec![4, 5]);
        let bytes = f.to_bytes();
        // every strict prefix is an error, never a panic
        for cut in 0..bytes.len() {
            assert!(FrameView::parse(&bytes[..cut]).is_err(), "prefix {cut} parsed");
        }
        assert_eq!(FrameView::parse(&bytes).unwrap().to_frame(), f);
        // a hostile prelude claiming the maximum header + payload on a
        // short buffer: the u64 length check rejects it without overflow
        let mut evil = vec![0u8; FRAME_PRELUDE_BYTES];
        evil[1..3].copy_from_slice(&u16::MAX.to_le_bytes());
        evil[3..7].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(FrameView::parse(&evil).is_err());
        assert!(Frame::from_bytes(&evil).is_err());
    }

    #[test]
    fn reader_f32_into_matches_f32_vec() {
        let x = [0.25f32, -7.5, 3.0];
        let mut w = FrameWriter::default();
        w.f32_slice(&x);
        let bytes = w.finish();
        let mut out = [0f32; 3];
        let mut r = FrameReader::new(&bytes);
        r.f32_into(&mut out).unwrap();
        r.done().unwrap();
        assert_eq!(out, x);
        let mut short = [0f32; 4];
        assert!(FrameReader::new(&bytes).f32_into(&mut short).is_err());
    }

    #[test]
    fn writer_reader_f32_slice() {
        let x = [1.0f32, -2.5, 3.25];
        let mut w = FrameWriter::default();
        w.f32_slice(&x);
        let buf = w.finish();
        assert_eq!(buf.len(), 12);
        let mut r = FrameReader::new(&buf);
        assert_eq!(r.f32_vec(3).unwrap(), x);
    }
}
