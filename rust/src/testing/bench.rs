//! Criterion-style micro-bench harness for the `[[bench]]` targets
//! (harness = false). Auto-calibrates iteration counts, reports
//! median/mean ns with throughput, and honours `AQ_BENCH_FAST=1` for
//! smoke runs.

use std::time::Instant;

use crate::util::stats;

pub struct Bencher {
    pub samples: usize,
    pub min_sample_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        if std::env::var("AQ_BENCH_FAST").is_ok() {
            Bencher { samples: 5, min_sample_s: 0.01 }
        } else {
            Bencher { samples: 20, min_sample_s: 0.05 }
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<42} {:>12.0} ns/iter (median {:>12.0}, ±{:.0})",
            self.name, self.mean_ns, self.median_ns, self.stddev_ns
        );
    }

    pub fn report_throughput(&self, bytes_per_iter: u64) {
        let gbs = bytes_per_iter as f64 / self.mean_ns; // bytes/ns == GB/s
        println!(
            "bench {:<42} {:>12.0} ns/iter  {:>8.2} GB/s",
            self.name, self.mean_ns, gbs
        );
    }
}

impl Bencher {
    /// Measure `f`, auto-scaling iterations until a sample takes at least
    /// `min_sample_s`.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        // calibrate
        let mut iters = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = t.elapsed().as_secs_f64();
            if el >= self.min_sample_s || iters > 1 << 30 {
                break;
            }
            iters = if el <= 1e-9 {
                iters * 128
            } else {
                (iters as f64 * (self.min_sample_s / el).min(128.0) * 1.2) as u64 + 1
            };
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            stddev_ns: stats::stddev(&samples),
            iters_per_sample: iters,
        }
    }
}

/// A value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("AQ_BENCH_FAST", "1");
        let b = Bencher::default();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters_per_sample > 100);
    }
}
