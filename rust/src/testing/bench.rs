//! Criterion-style micro-bench harness for the `[[bench]]` targets
//! (harness = false). Auto-calibrates iteration counts, reports
//! median/mean ns with throughput, and supports two invocation modes:
//!
//!  * human: `cargo bench --bench bench_codec` — the classic text table;
//!  * machine: `cargo bench --bench bench_codec -- --quick --json out.json`
//!    — same table on stdout plus a JSON report ([`BenchSuite`]) the CI
//!    `bench-diff` comparator gates against `BENCH_BASELINE.json`.
//!
//! `--quick` (or the `AQ_BENCH_FAST=1` env var) shrinks sampling for CI
//! smoke runs; bench *names and problem sizes are identical* in both
//! modes, so quick-mode JSON is comparable against any baseline.
//!
//! JSON schema (`schema: 1`):
//!
//! ```json
//! {
//!   "suite": "bench_codec", "schema": 1, "quick": true,
//!   "results": [{
//!     "name": "frame_encode/fp32/1MB", "mean_ns": 812345.5,
//!     "median_ns": 810000.0, "stddev_ns": 4000.0,
//!     "iters_per_sample": 13, "bytes_per_iter": 1048576,
//!     "gb_per_s": 1.29
//!   }]
//! }
//! ```
//!
//! `bytes_per_iter`/`gb_per_s` are `null` for time-only benches.

use std::time::Instant;

use crate::util::error::{Context, Result};
use crate::util::{json, stats};

pub struct Bencher {
    pub samples: usize,
    pub min_sample_s: f64,
}

impl Bencher {
    /// CI smoke-run sampling (what `--quick` / `AQ_BENCH_FAST=1` select).
    pub fn quick() -> Self {
        Bencher { samples: 5, min_sample_s: 0.01 }
    }

    /// Full local sampling.
    pub fn full() -> Self {
        Bencher { samples: 20, min_sample_s: 0.05 }
    }
}

impl Default for Bencher {
    fn default() -> Self {
        if std::env::var("AQ_BENCH_FAST").is_ok() {
            Bencher::quick()
        } else {
            Bencher::full()
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub iters_per_sample: u64,
    /// Payload bytes one iteration processes (throughput benches only).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    /// Throughput in GB/s (bytes/ns), when this is a throughput bench.
    pub fn gb_per_s(&self) -> Option<f64> {
        self.bytes_per_iter.map(|b| b as f64 / self.mean_ns)
    }

    pub fn report(&self) {
        println!(
            "bench {:<42} {:>12.0} ns/iter (median {:>12.0}, ±{:.0})",
            self.name, self.mean_ns, self.median_ns, self.stddev_ns
        );
    }

    pub fn report_throughput(&self, bytes_per_iter: u64) {
        let gbs = bytes_per_iter as f64 / self.mean_ns; // bytes/ns == GB/s
        println!(
            "bench {:<42} {:>12.0} ns/iter  {:>8.2} GB/s",
            self.name, self.mean_ns, gbs
        );
    }

    /// One JSON object of the `results` array.
    fn to_json(&self) -> json::Json {
        use json::Json;
        let opt_num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("mean_ns".into(), Json::Num(self.mean_ns)),
            ("median_ns".into(), Json::Num(self.median_ns)),
            ("stddev_ns".into(), Json::Num(self.stddev_ns)),
            ("iters_per_sample".into(), Json::Num(self.iters_per_sample as f64)),
            ("bytes_per_iter".into(), opt_num(self.bytes_per_iter.map(|b| b as f64))),
            ("gb_per_s".into(), opt_num(self.gb_per_s())),
        ])
    }
}

impl Bencher {
    /// Measure `f`, auto-scaling iterations until a sample takes at least
    /// `min_sample_s`.
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> BenchResult {
        // calibrate
        let mut iters = 1u64;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let el = t.elapsed().as_secs_f64();
            if el >= self.min_sample_s || iters > 1 << 30 {
                break;
            }
            iters = if el <= 1e-9 {
                iters * 128
            } else {
                (iters as f64 * (self.min_sample_s / el).min(128.0) * 1.2) as u64 + 1
            };
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            stddev_ns: stats::stddev(&samples),
            iters_per_sample: iters,
            bytes_per_iter: None,
        }
    }
}

// ---------------------------------------------------------------------------

/// A whole bench binary's run: argument parsing (`--quick`,
/// `--json <path>`), result collection, human reporting, and the JSON
/// report. Every `[[bench]]` target builds one of these in `main`.
pub struct BenchSuite {
    pub bencher: Bencher,
    /// True in `--quick` / `AQ_BENCH_FAST` mode — bench mains may use
    /// this to skip optional extras, but must keep names/sizes stable.
    pub quick: bool,
    suite: String,
    json_path: Option<String>,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    /// Build from `std::env::args()`: `--quick` selects smoke sampling,
    /// `--json <path>` requests a machine-readable report. Unrecognized
    /// arguments (cargo's bench-filter positional, `--bench`) are
    /// ignored so `cargo bench -- <args>` stays permissive.
    pub fn from_args(suite: &str) -> Self {
        let args: Vec<String> = std::env::args().skip(1).collect();
        Self::from_arg_list(suite, &args)
    }

    /// Testable core of [`from_args`](Self::from_args).
    pub fn from_arg_list(suite: &str, args: &[String]) -> Self {
        let mut quick = std::env::var("AQ_BENCH_FAST").is_ok();
        let mut json_path = None;
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--quick" => quick = true,
                "--json" => json_path = it.next().cloned(),
                _ => {}
            }
        }
        BenchSuite {
            bencher: if quick { Bencher::quick() } else { Bencher::full() },
            quick,
            suite: suite.to_string(),
            json_path,
            results: Vec::new(),
        }
    }

    /// Run one time-only bench; prints the human line and records the
    /// result for the JSON report.
    pub fn run(&mut self, name: &str, f: impl FnMut()) -> &BenchResult {
        let r = self.bencher.run(name, f);
        r.report();
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    /// Record an externally-measured time metric (ns) under a bench
    /// name — a latency percentile or a per-item cost derived from one
    /// macro run, where re-sampling a closure is meaningless. Prints
    /// the standard human line and lands in the JSON report as a
    /// time-only result (`bytes_per_iter` null), so `bench-diff` gates
    /// it exactly like a sampled time bench.
    pub fn record(&mut self, name: &str, ns: f64) -> &BenchResult {
        let r = BenchResult {
            name: name.to_string(),
            mean_ns: ns,
            median_ns: ns,
            stddev_ns: 0.0,
            iters_per_sample: 1,
            bytes_per_iter: None,
        };
        r.report();
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    /// Run one throughput bench (`bytes_per_iter` payload bytes per
    /// iteration); prints ns + GB/s and records both for the report.
    pub fn run_throughput(
        &mut self,
        name: &str,
        bytes_per_iter: u64,
        f: impl FnMut(),
    ) -> &BenchResult {
        let mut r = self.bencher.run(name, f);
        r.report_throughput(bytes_per_iter);
        r.bytes_per_iter = Some(bytes_per_iter);
        self.results.push(r);
        self.results.last().expect("just pushed")
    }

    /// The JSON report document.
    pub fn to_json(&self) -> json::Json {
        use json::Json;
        Json::Obj(vec![
            ("suite".into(), Json::Str(self.suite.clone())),
            ("schema".into(), Json::Num(1.0)),
            ("quick".into(), Json::Bool(self.quick)),
            (
                "results".into(),
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Write the JSON report if `--json <path>` was given. Call at the
    /// end of every bench `main` (a no-op in plain human mode).
    pub fn finish(&self) -> Result<()> {
        if let Some(path) = &self.json_path {
            std::fs::write(path, self.to_json().render() + "\n")
                .with_context(|| format!("writing bench report to {path}"))?;
            println!("bench report written to {path} ({} results)", self.results.len());
        }
        Ok(())
    }
}

/// A value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn bench_measures_something() {
        let b = Bencher::quick();
        let mut acc = 0u64;
        let r = b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters_per_sample > 100);
    }

    #[test]
    fn suite_parses_args_and_renders_schema() {
        let args: Vec<String> =
            ["ignored-filter", "--quick", "--json", "/tmp/x.json", "--bench"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let mut s = BenchSuite::from_arg_list("unit", &args);
        assert!(s.quick);
        assert_eq!(s.json_path.as_deref(), Some("/tmp/x.json"));
        assert_eq!(s.bencher.samples, Bencher::quick().samples);
        let mut acc = 0u64;
        s.run_throughput("t", 1024, || {
            acc = black_box(acc.wrapping_add(1));
        });
        s.run("u", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let doc = Json::parse(&s.to_json().render()).unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("unit"));
        assert_eq!(doc.get("schema").unwrap().as_f64(), Some(1.0));
        let results = doc.get("results").unwrap().as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("t"));
        assert_eq!(results[0].get("bytes_per_iter").unwrap().as_f64(), Some(1024.0));
        assert!(results[0].get("gb_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(results[1].get("bytes_per_iter"), Some(&Json::Null));
    }

    #[test]
    fn recorded_metrics_render_as_time_only_results() {
        let mut s = BenchSuite::from_arg_list("unit", &["--quick".to_string()]);
        s.record("serve/latency_p99", 123456.0);
        let doc = Json::parse(&s.to_json().render()).unwrap();
        let results = doc.get("results").unwrap().as_array().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("serve/latency_p99"));
        assert_eq!(results[0].get("mean_ns").unwrap().as_f64(), Some(123456.0));
        assert_eq!(results[0].get("median_ns").unwrap().as_f64(), Some(123456.0));
        assert_eq!(results[0].get("bytes_per_iter"), Some(&Json::Null));
    }

    #[test]
    fn suite_without_flags_is_full_mode_no_json() {
        // NOTE: AQ_BENCH_FAST may be set by the environment; only assert
        // the flag-driven parts
        let s = BenchSuite::from_arg_list("unit", &[]);
        assert!(s.json_path.is_none());
        assert!(s.finish().is_ok());
    }
}
