//! In-tree property-testing and micro-bench helpers (the offline build has
//! no proptest/criterion; these provide the same workflow).

pub mod bench;
pub mod prop;
