//! In-tree property-testing and micro-bench helpers (the offline build has
//! no proptest/criterion; these provide the same workflow), plus the
//! artifact gate used by the integration tests.

pub mod alloc;
pub mod bench;
pub mod prop;

use std::collections::BTreeSet;
use std::sync::{Mutex, Once};

use crate::runtime::Manifest;

static SKIPPED_MODELS: Mutex<BTreeSet<String>> = Mutex::new(BTreeSet::new());
static BACKEND_NOTICE: Once = Once::new();

/// Artifact directory as seen from the current process. Integration-test
/// binaries run with cwd = the package root (rust/), while examples and
/// the CLI are usually launched from the repo root where `python -m
/// compile.aot --out-dir ../artifacts` writes — so probe both.
pub fn artifacts_root() -> &'static str {
    if std::path::Path::new("artifacts").is_dir() {
        "artifacts"
    } else if std::path::Path::new("../artifacts").is_dir() {
        "../artifacts"
    } else {
        "artifacts"
    }
}

/// Load the artifacts for `model` for a test, or record a visible skip.
///
/// Integration tests over the PJRT artifacts pass vacuously when the
/// artifacts are absent (they cannot be rebuilt in every environment) or
/// when this build cannot execute them (`runtime::xla` stub). This helper
/// makes that explicit: the first miss per model prints one consolidated
/// notice naming the real build command, and every skipped model is
/// queryable via [`skipped_artifact_models`] so harnesses can surface the
/// list instead of burying per-test lines in stderr.
pub fn require_artifacts(model: &str) -> Option<Manifest> {
    let man = match Manifest::load(artifacts_root(), model) {
        Ok(man) => man,
        Err(err) => {
            let mut seen = SKIPPED_MODELS.lock().unwrap();
            if seen.insert(model.to_string()) {
                let backend_note = if crate::runtime::xla::BACKEND_AVAILABLE {
                    ""
                } else {
                    " (note: this build also needs a real PJRT backend to execute them — \
                     runtime::xla is the offline stub)"
                };
                eprintln!(
                    "SKIP: artifacts/{model} not present — artifact-gated tests for it pass \
                     vacuously. Build with `cd python && python -m compile.aot --out-dir \
                     ../artifacts`{backend_note}. [{err}]"
                );
            }
            return None;
        }
    };
    if !crate::runtime::xla::BACKEND_AVAILABLE {
        // record the model so skipped_artifact_models() reflects this skip
        // cause too; the notice itself prints once per process
        SKIPPED_MODELS.lock().unwrap().insert(model.to_string());
        BACKEND_NOTICE.call_once(|| {
            eprintln!(
                "SKIP: artifacts present but this build cannot execute them — runtime::xla is \
                 the offline stub (PJRT backend unavailable); artifact-executing tests pass \
                 vacuously."
            );
        });
        return None;
    }
    Some(man)
}

/// Models [`require_artifacts`] skipped for any reason — missing
/// artifacts or an unavailable PJRT backend — in sorted order.
pub fn skipped_artifact_models() -> Vec<String> {
    SKIPPED_MODELS.lock().unwrap().iter().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn require_artifacts_registers_skips_once() {
        // deliberately-fake model name: the entry stays in the process-
        // global registry, so assertions are membership deltas on this key
        // only (order-independent under parallel tests)
        let model = "definitely_missing_model_xyz";
        assert!(require_artifacts(model).is_none());
        // a second miss of the same model does not duplicate the entry
        assert!(require_artifacts(model).is_none());
        let skipped = skipped_artifact_models();
        assert_eq!(skipped.iter().filter(|m| m.as_str() == model).count(), 1);
    }

    #[test]
    fn artifacts_root_is_a_plausible_path() {
        assert!(["artifacts", "../artifacts"].contains(&artifacts_root()));
    }
}
