//! Counting global allocator for the zero-allocation steady-state tests.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! `alloc`/`alloc_zeroed`/`realloc` call (and the bytes requested). A
//! test binary opts in by registering it:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: aq_sgd::testing::alloc::CountingAlloc = CountingAlloc::new();
//! ```
//!
//! Registration is per *final binary*, so the accounting only exists in
//! the test binaries that ask for it (`tests/zero_alloc.rs`) — the
//! library, CLI, and benches keep the plain system allocator. A binary
//! that measures deltas of [`allocation_count`] must run its probes on
//! a single thread with no concurrent tests in the same process (give
//! the test its own integration-test file).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Allocation calls (alloc / alloc_zeroed / realloc) since process
/// start, when a [`CountingAlloc`] is registered; 0 forever otherwise.
pub fn allocation_count() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Bytes requested by those calls.
pub fn allocated_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// System-allocator wrapper that counts allocation calls. Deallocation
/// is intentionally not counted: the steady-state invariant under test
/// is "no new memory requested", and frees pair with earlier allocs.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
