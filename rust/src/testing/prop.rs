//! Minimal property-testing harness: seeded random cases, reproducible
//! failures. Set `AQ_PROP_SEED=<n>` to replay a failing case,
//! `AQ_PROP_CASES=<n>` to change the case count.

use crate::util::Rng;

pub struct Prop {
    pub cases: usize,
    pub base_seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        let cases = std::env::var("AQ_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let base_seed = std::env::var("AQ_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xA25D);
        Prop { cases, base_seed }
    }
}

impl Prop {
    /// Run `f` over `cases` seeded RNGs; panics with the failing seed.
    pub fn check(name: &str, f: impl Fn(&mut Rng)) {
        let p = Prop::default();
        for case in 0..p.cases {
            let seed = p.base_seed.wrapping_add(case as u64 * 0x9E37);
            let mut rng = Rng::new(seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
            if let Err(e) = result {
                eprintln!(
                    "property {name:?} failed at case {case} — replay with AQ_PROP_SEED={seed} AQ_PROP_CASES=1"
                );
                std::panic::resume_unwind(e);
            }
        }
    }
}

/// Random helpers for property generators.
pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() * scale).collect()
}

pub fn len_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static N: AtomicUsize = AtomicUsize::new(0);
        Prop::check("count", |_rng| {
            N.fetch_add(1, Ordering::SeqCst);
        });
        assert!(N.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let n = len_in(&mut rng, 3, 17);
            assert!((3..=17).contains(&n));
        }
        assert_eq!(vec_f32(&mut rng, 5, 1.0).len(), 5);
    }
}
