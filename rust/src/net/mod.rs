//! Simulated slow network.
//!
//! The paper throttles AWS instance links with Linux `tc` (10 Gbps down to
//! 100 Mbps). We model each point-to-point link as a FIFO serializer with
//! a bandwidth and a per-message latency, driven by a *virtual clock*:
//! deterministic, byte-accurate, and fast enough to sweep every (bandwidth
//! x scheme x schedule) cell of Tables 2/3/5 in milliseconds.
//!
//! A real-sleep mode (`RealLink`) exists for the threaded integration test
//! so the event model is cross-checked against wall-clock behaviour, and a
//! real TCP transport ([`tcp`]) + peer handshake layer ([`session`]) run
//! the same frame traffic between separate OS processes.

pub mod channel;
pub mod plane;
pub mod session;
pub mod tcp;

pub use channel::{frame_link, Doorbell, FrameLink, FrameLinkRx, Poll};
pub use plane::{dp_rings, link_endpoints, DpRing, LinkEndpointRx, LinkEndpointTx};
pub use session::TopologyPlan;
pub use tcp::{IoDriver, LinkShape, TcpFrameRx, TcpFrameTx};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::Result;

/// Sending half of a frame transport. Implemented by the in-process
/// [`FrameLink`] (paced SPSC channel) and the socket-backed
/// [`TcpFrameTx`]; the pipeline endpoints hold `Box<dyn FrameTx>` so the
/// same executor state machines run over either.
///
/// Byte accounting is part of the contract: `bytes_sent` counts exactly
/// the frame images handed to `send`/`send_from` — transport framing
/// overhead (e.g. the TCP length prefix) is excluded, so in-process and
/// socket runs report identical per-link wire bytes.
pub trait FrameTx: Send {
    /// Queue one encoded frame. The call never blocks on the network;
    /// `Err` means the transport is dead (peer closed or I/O error).
    fn send(&mut self, frame: Vec<u8>) -> Result<()>;
    /// Like [`send`](Self::send), from a borrowed image (the transport
    /// copies into a recycled buffer where it can).
    fn send_from(&mut self, frame: &[u8]) -> Result<()>;
    /// Install the wakeup hook fired after every accepted send.
    fn set_doorbell(&mut self, bell: Doorbell);
    /// Total frame bytes accepted so far (excluding transport framing).
    fn bytes_sent(&self) -> u64;
    /// Total frames accepted so far.
    fn msgs_sent(&self) -> u64;
}

/// Receiving half of a frame transport, with the poll/doorbell readiness
/// contract the event executor runs on: `poll` never blocks or consumes,
/// `recv`/`recv_held` block honouring modeled delivery time, and the
/// doorbell fires when a new frame becomes available (or the peer goes
/// away), so a parked task gets rescheduled.
pub trait FrameRx: Send {
    /// Non-blocking, non-consuming readiness probe.
    fn poll(&mut self) -> Poll;
    /// Non-blocking dequeue of a *deliverable* frame; `Ok(None)` when
    /// nothing is ready yet, `Err` once the link is closed and drained.
    fn try_recv(&mut self) -> Result<Option<Vec<u8>>>;
    /// Blocking receive; `Err` once the link is closed and drained.
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Blocking receive into a transport-held buffer, for decode paths
    /// that only need to view the frame.
    fn recv_held(&mut self) -> Result<&[u8]>;
    /// Install the wakeup hook fired on frame arrival and on close.
    fn set_doorbell(&mut self, bell: Doorbell);
}

/// Standard bandwidth ladder of the paper's evaluation (bits/s).
pub const PAPER_BANDWIDTHS: [(f64, &str); 5] = [
    (10e9, "10 Gbps"),
    (1e9, "1 Gbps"),
    (500e6, "500 Mbps"),
    (300e6, "300 Mbps"),
    (100e6, "100 Mbps"),
];

/// A FIFO link under the virtual clock. Transmissions serialize: a message
/// begins once the link is free, occupies it for `bytes/bandwidth`, and is
/// delivered `latency` later (store-and-forward).
#[derive(Clone, Debug)]
pub struct Link {
    pub bandwidth_bps: f64, // bits per second
    pub latency_s: f64,
    busy_until: f64,
    pub bytes_sent: u64,
    pub msgs_sent: u64,
}

impl Link {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0);
        Link { bandwidth_bps, latency_s, busy_until: 0.0, bytes_sent: 0, msgs_sent: 0 }
    }

    /// Pure transmission time of `bytes` on this link.
    pub fn tx_time(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 / self.bandwidth_bps
    }

    /// Enqueue a transmission starting no earlier than `now`; returns the
    /// delivery (arrival) time at the far end.
    pub fn transmit(&mut self, now: f64, bytes: u64) -> f64 {
        let start = now.max(self.busy_until);
        let end = start + self.tx_time(bytes);
        self.busy_until = end;
        self.bytes_sent += bytes;
        self.msgs_sent += 1;
        end + self.latency_s
    }

    pub fn reset(&mut self) {
        self.busy_until = 0.0;
        self.bytes_sent = 0;
        self.msgs_sent = 0;
    }
}

/// Shared state of one first-party SPSC channel: a FIFO of
/// `(deliver_at, msg)` pairs plus the sender-dropped flag. First-party
/// (not `std::sync::mpsc`) because the receiving side needs
/// *peek-with-deadline* semantics — the event executor polls a link for
/// readiness without consuming or parking — and because `mpsc` allocates
/// a node per send, which would break the zero-allocation steady-state
/// pin at the transport boundary.
struct ChanState<T> {
    queue: VecDeque<(Instant, T)>,
    closed: bool,
    /// Receiver-installed wakeup hook, fired (outside the lock) after a
    /// push and on close — the rx half of the doorbell contract, so a
    /// parked event task learns a channel frame landed.
    bell: Option<Doorbell>,
}

struct Chan<T> {
    state: Mutex<ChanState<T>>,
    cv: Condvar,
}

/// Outcome of a non-blocking channel poll.
pub enum TryRecv<T> {
    /// A message was dequeued; it is *deliverable* at the carried instant
    /// (which may be in the future — the link models transmission time).
    Msg(Instant, T),
    /// Nothing queued, sender still alive.
    Empty,
    /// Nothing queued and the sender is gone.
    Closed,
}

/// A message with real-time delivery semantics, for the threaded and
/// event modes.
pub struct RealLink<T> {
    chan: Arc<Chan<T>>,
    bandwidth_bps: f64,
    latency: Duration,
    epoch: Instant,
    busy_until: Duration,
}

pub struct RealReceiver<T> {
    chan: Arc<Chan<T>>,
}

fn chan_lock<T>(c: &Chan<T>) -> std::sync::MutexGuard<'_, ChanState<T>> {
    c.state.lock().unwrap_or_else(|p| p.into_inner())
}

impl<T: Send> RealLink<T> {
    pub fn channel(bandwidth_bps: f64, latency: Duration) -> (RealLink<T>, RealReceiver<T>) {
        let chan = Arc::new(Chan {
            state: Mutex::new(ChanState {
                queue: VecDeque::with_capacity(16),
                closed: false,
                bell: None,
            }),
            cv: Condvar::new(),
        });
        (
            RealLink {
                chan: Arc::clone(&chan),
                bandwidth_bps,
                latency,
                epoch: Instant::now(),
                busy_until: Duration::ZERO,
            },
            RealReceiver { chan },
        )
    }

    /// Send `msg` as if it were `bytes` long: the call returns immediately
    /// (communication overlaps computation); the receiver blocks until the
    /// modeled delivery instant.
    pub fn send(&mut self, msg: T, bytes: u64) {
        let now = self.epoch.elapsed();
        let start = now.max(self.busy_until);
        let tx_t = Duration::from_secs_f64(bytes as f64 * 8.0 / self.bandwidth_bps);
        self.busy_until = start + tx_t;
        let deliver_at = self.epoch + self.busy_until + self.latency;
        let mut st = chan_lock(&self.chan);
        st.queue.push_back((deliver_at, msg));
        let bell = st.bell.clone();
        drop(st);
        self.chan.cv.notify_one();
        if let Some(b) = bell {
            b();
        }
    }
}

impl<T> Drop for RealLink<T> {
    fn drop(&mut self) {
        let mut st = chan_lock(&self.chan);
        st.closed = true;
        let bell = st.bell.clone();
        drop(st);
        self.chan.cv.notify_all();
        if let Some(b) = bell {
            b();
        }
    }
}

impl<T> RealReceiver<T> {
    /// Install the receive-side doorbell, fired after every push into the
    /// channel and when the sender drops.
    pub fn set_doorbell(&mut self, bell: Doorbell) {
        chan_lock(&self.chan).bell = Some(bell);
    }

    /// Blocking receive honouring the modeled delivery time. Messages
    /// queued before the sender dropped are still delivered; `None` only
    /// once the channel is both closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = chan_lock(&self.chan);
        let (at, msg) = loop {
            if let Some(pair) = st.queue.pop_front() {
                break pair;
            }
            if st.closed {
                return None;
            }
            st = self.chan.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        };
        drop(st);
        let now = Instant::now();
        if at > now {
            std::thread::sleep(at - now);
        }
        Some(msg)
    }

    /// Non-blocking poll: dequeue the next message if one is queued
    /// (deliverable or still in modeled flight — the instant says which).
    pub fn try_recv(&self) -> TryRecv<T> {
        let mut st = chan_lock(&self.chan);
        match st.queue.pop_front() {
            Some((at, msg)) => TryRecv::Msg(at, msg),
            None if st.closed => TryRecv::Closed,
            None => TryRecv::Empty,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tx_time_math() {
        let l = Link::new(100e6, 0.0); // 100 Mbps
        // 12.5 MB at 100Mbps = 1s
        assert!((l.tx_time(12_500_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_serialization() {
        let mut l = Link::new(8e6, 0.001); // 1 MB/s, 1ms latency
        let a1 = l.transmit(0.0, 1_000_000); // done tx at 1.0, arrive 1.001
        let a2 = l.transmit(0.0, 1_000_000); // queued: tx 1.0..2.0
        assert!((a1 - 1.001).abs() < 1e-9);
        assert!((a2 - 2.001).abs() < 1e-9);
        // a later small message after the queue drains
        let a3 = l.transmit(5.0, 1_000); // 8000 bits = 1 ms
        assert!((a3 - 5.002).abs() < 1e-9);
        assert_eq!(l.bytes_sent, 2_001_000);
        assert_eq!(l.msgs_sent, 3);
    }

    #[test]
    fn real_link_paces_delivery() {
        let (mut tx, rx) = RealLink::channel(8e6, Duration::from_millis(0)); // 1 MB/s
        let t0 = Instant::now();
        tx.send(1u32, 20_000); // 20 ms
        tx.send(2u32, 20_000); // +20 ms
        assert_eq!(rx.recv(), Some(1));
        let t1 = t0.elapsed();
        assert_eq!(rx.recv(), Some(2));
        let t2 = t0.elapsed();
        assert!(t1 >= Duration::from_millis(18), "{t1:?}");
        assert!(t2 >= Duration::from_millis(38), "{t2:?}");
    }

    #[test]
    fn try_recv_reports_empty_message_and_closed() {
        let (mut tx, rx) = RealLink::channel(f64::INFINITY, Duration::ZERO);
        assert!(matches!(rx.try_recv(), TryRecv::Empty));
        tx.send(7u32, 100);
        match rx.try_recv() {
            TryRecv::Msg(at, v) => {
                assert_eq!(v, 7);
                // unpaced link: deliverable immediately
                assert!(at <= Instant::now());
            }
            _ => panic!("expected a queued message"),
        }
        assert!(matches!(rx.try_recv(), TryRecv::Empty));
        drop(tx);
        assert!(matches!(rx.try_recv(), TryRecv::Closed));
    }

    #[test]
    fn messages_sent_before_close_still_deliver() {
        let (mut tx, rx) = RealLink::channel(f64::INFINITY, Duration::ZERO);
        tx.send(1u32, 10);
        tx.send(2u32, 10);
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn receiver_doorbell_fires_on_send_and_close() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let (mut tx, mut rx) = RealLink::channel(f64::INFINITY, Duration::ZERO);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        rx.set_doorbell(Arc::new(move || {
            h.fetch_add(1, Ordering::SeqCst);
        }));
        tx.send(1u32, 10);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        drop(tx);
        assert_eq!(hits.load(Ordering::SeqCst), 2);
        assert_eq!(rx.recv(), Some(1));
    }

    #[test]
    fn try_recv_carries_the_modeled_delivery_instant() {
        let (mut tx, rx) = RealLink::channel(8e6, Duration::ZERO); // 1 MB/s
        tx.send(9u8, 20_000); // 20 ms of modeled flight
        match rx.try_recv() {
            TryRecv::Msg(at, v) => {
                assert_eq!(v, 9);
                assert!(at > Instant::now(), "message should still be in flight");
            }
            _ => panic!("message must be queued even while in flight"),
        }
    }
}
