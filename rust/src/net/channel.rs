//! Channel-backed frame transport for the threaded pipeline executor.
//!
//! A [`FrameLink`] is the sending endpoint of one directed pipeline
//! boundary (stage s → neighbour): it owns a [`RealLink`] carrying
//! serialized [`Frame`](crate::codec::Frame) images (`Vec<u8>`), paces
//! delivery to the modeled bandwidth/latency, and counts the bytes it
//! ships. (The executor's *trajectory* numbers come from the frames
//! themselves — `Frame::wire_bytes()` via `TransferStats` — which equal
//! these link counters because `wire_bytes() == to_bytes().len()` is
//! pinned by `prop_frames.rs`; the counters are the transport's own
//! per-link view.) The receiving endpoint ([`FrameLinkRx`]) blocks until
//! the modeled delivery instant and turns a disconnected peer (a worker
//! thread that exited early) into a `Result` error instead of a hang or
//! a panic.

use std::time::Duration;

use super::{RealLink, RealReceiver};
use crate::util::error::Result;

/// Sending half of one directed boundary link.
pub struct FrameLink {
    link: RealLink<Vec<u8>>,
    /// Serialized frame bytes pushed onto this link (the transport's
    /// own accounting; equals the frame-measured trajectory sums).
    pub bytes_sent: u64,
    pub msgs_sent: u64,
}

/// Receiving half of one directed boundary link.
pub struct FrameLinkRx {
    rx: RealReceiver<Vec<u8>>,
}

/// Build one directed link: (sender for the upstream stage, receiver for
/// the downstream stage).
pub fn frame_link(bandwidth_bps: f64, latency: Duration) -> (FrameLink, FrameLinkRx) {
    let (link, rx) = RealLink::channel(bandwidth_bps, latency);
    (FrameLink { link, bytes_sent: 0, msgs_sent: 0 }, FrameLinkRx { rx })
}

impl FrameLink {
    /// Send one serialized frame. Returns immediately (sends overlap
    /// compute); the receiver blocks until the modeled delivery time of
    /// `bytes.len()` wire bytes.
    pub fn send(&mut self, bytes: Vec<u8>) {
        self.bytes_sent += bytes.len() as u64;
        self.msgs_sent += 1;
        let n = bytes.len() as u64;
        self.link.send(bytes, n);
    }
}

impl FrameLinkRx {
    /// Blocking receive honouring the modeled delivery time. A closed
    /// channel means the peer stage's worker exited (error or panic)
    /// before sending — surfaced as an error so the whole pipeline
    /// unwinds instead of deadlocking.
    pub fn recv(&self) -> Result<Vec<u8>> {
        self.rx
            .recv()
            .ok_or_else(|| crate::err!("pipeline channel closed: peer stage exited early"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_arrive_in_order_with_byte_accounting() {
        let (mut tx, rx) = frame_link(1e12, Duration::ZERO);
        tx.send(vec![1, 2, 3]);
        tx.send(vec![4, 5]);
        assert_eq!(tx.bytes_sent, 5);
        assert_eq!(tx.msgs_sent, 2);
        assert_eq!(rx.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(rx.recv().unwrap(), vec![4, 5]);
    }

    #[test]
    fn dropped_sender_is_an_error_not_a_hang() {
        let (tx, rx) = frame_link(1e12, Duration::ZERO);
        drop(tx);
        let err = rx.recv().unwrap_err();
        assert!(err.to_string().contains("channel closed"), "{err}");
    }
}
