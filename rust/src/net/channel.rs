//! Channel-backed frame transport for the threaded and event pipeline
//! executors.
//!
//! A [`FrameLink`] is the sending endpoint of one directed pipeline
//! boundary (stage s → neighbour): it owns a [`RealLink`] carrying
//! serialized [`Frame`](crate::codec::Frame) images (`Vec<u8>`), paces
//! delivery to the modeled bandwidth/latency, and counts the bytes it
//! ships. (The executor's *trajectory* numbers come from the frames
//! themselves — `Frame::wire_bytes()` via `TransferStats` — which equal
//! these link counters because `wire_bytes() == to_bytes().len()` is
//! pinned by `prop_frames.rs`; the counters are the transport's own
//! per-link view.) The receiving endpoint ([`FrameLinkRx`]) blocks until
//! the modeled delivery instant and turns a disconnected peer (a worker
//! thread that exited early) into a `Result` error instead of a hang or
//! a panic.
//!
//! Two extensions serve the event executor and the zero-allocation pin:
//!
//!  * **Poll readiness** — [`FrameLinkRx::poll`] reports whether the
//!    next frame is deliverable *now*, still in modeled flight (with its
//!    delivery instant, so a scheduler can set a timer), absent, or the
//!    peer is gone — without ever parking the caller. A frame pulled off
//!    the channel by a poll is stashed, and a subsequent `recv` consumes
//!    the stash under the exact pacing/accounting contract the blocking
//!    path has always had.
//!  * **Buffer recycling** — the two halves share a bounded pool of
//!    frame buffers: [`FrameLink::send_from`] copies a borrowed byte
//!    image into a pooled buffer instead of forcing the caller to
//!    allocate an owned `Vec` per frame, and [`FrameLinkRx::recv_held`]
//!    lends the received frame out while returning the previously lent
//!    buffer to the pool. In steady state the same few buffers circulate
//!    sender → channel → receiver → pool with zero allocator traffic
//!    (pinned by `tests/zero_alloc.rs`).
//!
//! A [`Doorbell`] installed on the sending half fires after each frame
//! is enqueued — the event executor's run queue uses it to mark the
//! receiving task runnable instead of dedicating a blocked thread to it.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::{FrameRx, FrameTx, RealLink, RealReceiver, TryRecv};
use crate::util::error::Result;

/// Callback fired by the sending half after each frame is enqueued
/// (after the channel notify — the woken side's poll will see the
/// frame). The event executor installs one per link to requeue the
/// receiving task.
pub type Doorbell = Arc<dyn Fn() + Send + Sync>;

/// Readiness of a [`FrameLinkRx`], reported without parking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Poll {
    /// The next frame has reached its modeled delivery instant; `recv`
    /// will return it without sleeping.
    Ready,
    /// No frame queued (the peer has not sent yet).
    Empty,
    /// A frame is queued but still in modeled flight; deliverable at the
    /// carried instant.
    InFlight(Instant),
    /// The peer dropped its sending half; `recv` would error.
    Closed,
}

/// Bounded pool of recycled frame buffers shared by a link's two halves.
type BufPool = Arc<Mutex<Vec<Vec<u8>>>>;

/// Buffers retained per link; beyond this, returned buffers are freed.
/// The executors keep at most a handful of frames in flight per link, so
/// a small cap bounds memory without ever recycling in steady state.
const POOL_CAP: usize = 32;

fn pool_lock(pool: &BufPool) -> std::sync::MutexGuard<'_, Vec<Vec<u8>>> {
    pool.lock().unwrap_or_else(|p| p.into_inner())
}

fn recycle(pool: &BufPool, mut buf: Vec<u8>) {
    buf.clear();
    let mut p = pool_lock(pool);
    if p.len() < POOL_CAP {
        p.push(buf);
    }
}

/// Sending half of one directed boundary link.
pub struct FrameLink {
    link: RealLink<Vec<u8>>,
    pool: BufPool,
    doorbell: Option<Doorbell>,
    /// Serialized frame bytes pushed onto this link (the transport's
    /// own accounting; equals the frame-measured trajectory sums).
    pub bytes_sent: u64,
    pub msgs_sent: u64,
}

/// Receiving half of one directed boundary link.
pub struct FrameLinkRx {
    rx: RealReceiver<Vec<u8>>,
    pool: BufPool,
    /// Next frame pulled off the channel by a poll but not yet consumed
    /// by a receive.
    stash: Option<(Instant, Vec<u8>)>,
    /// Buffer currently lent to the caller by [`recv_held`](Self::recv_held).
    held: Option<Vec<u8>>,
}

/// Build one directed link: (sender for the upstream stage, receiver for
/// the downstream stage).
pub fn frame_link(bandwidth_bps: f64, latency: Duration) -> (FrameLink, FrameLinkRx) {
    let (link, rx) = RealLink::channel(bandwidth_bps, latency);
    let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
    (
        FrameLink {
            link,
            pool: Arc::clone(&pool),
            doorbell: None,
            bytes_sent: 0,
            msgs_sent: 0,
        },
        FrameLinkRx { rx, pool, stash: None, held: None },
    )
}

impl FrameLink {
    /// Send one serialized frame. Returns immediately (sends overlap
    /// compute); the receiver blocks until the modeled delivery time of
    /// `bytes.len()` wire bytes.
    pub fn send(&mut self, bytes: Vec<u8>) {
        self.bytes_sent += bytes.len() as u64;
        self.msgs_sent += 1;
        let n = bytes.len() as u64;
        self.link.send(bytes, n);
        if let Some(bell) = &self.doorbell {
            bell();
        }
    }

    /// Send a borrowed frame image, copying it into a recycled buffer
    /// from the link's pool — the allocation-free steady-state send path
    /// (the pool refills as the receiver releases held buffers).
    pub fn send_from(&mut self, bytes: &[u8]) {
        let mut buf = pool_lock(&self.pool).pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(bytes);
        self.send(buf);
    }

    /// Install the wakeup fired after each enqueued frame.
    pub fn set_doorbell(&mut self, bell: Doorbell) {
        self.doorbell = Some(bell);
    }
}

impl FrameTx for FrameLink {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        FrameLink::send(self, frame);
        Ok(())
    }

    fn send_from(&mut self, frame: &[u8]) -> Result<()> {
        FrameLink::send_from(self, frame);
        Ok(())
    }

    fn set_doorbell(&mut self, bell: Doorbell) {
        FrameLink::set_doorbell(self, bell);
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }
}

impl FrameRx for FrameLinkRx {
    fn poll(&mut self) -> Poll {
        FrameLinkRx::poll(self)
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        FrameLinkRx::try_recv(self)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        FrameLinkRx::recv(self)
    }

    fn recv_held(&mut self) -> Result<&[u8]> {
        FrameLinkRx::recv_held(self)
    }

    fn set_doorbell(&mut self, bell: Doorbell) {
        self.rx.set_doorbell(bell);
    }
}

impl FrameLinkRx {
    fn closed_err() -> crate::util::error::Error {
        crate::err!("pipeline channel closed: peer stage exited early")
    }

    /// Non-blocking readiness probe. Pulls at most one frame off the
    /// channel into the stash; never sleeps.
    pub fn poll(&mut self) -> Poll {
        if self.stash.is_none() {
            match self.rx.try_recv() {
                TryRecv::Msg(at, bytes) => self.stash = Some((at, bytes)),
                TryRecv::Empty => return Poll::Empty,
                TryRecv::Closed => return Poll::Closed,
            }
        }
        let at = self.stash.as_ref().map(|&(at, _)| at).expect("stash populated above");
        if Instant::now() >= at {
            Poll::Ready
        } else {
            Poll::InFlight(at)
        }
    }

    /// Non-blocking receive: the next frame if it has reached its
    /// delivery instant, `None` while the link is empty or the frame is
    /// still in modeled flight, an error once the peer is gone.
    pub fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        match self.poll() {
            Poll::Ready => Ok(Some(self.stash.take().expect("polled Ready").1)),
            Poll::Empty | Poll::InFlight(_) => Ok(None),
            Poll::Closed => Err(Self::closed_err()),
        }
    }

    /// Blocking receive honouring the modeled delivery time (consumes a
    /// stashed frame first, sleeping out any residual flight time). A
    /// closed channel means the peer stage's worker exited (error or
    /// panic) before sending — surfaced as an error so the whole
    /// pipeline unwinds instead of deadlocking.
    pub fn recv(&mut self) -> Result<Vec<u8>> {
        if let Some((at, bytes)) = self.stash.take() {
            let now = Instant::now();
            if at > now {
                std::thread::sleep(at - now);
            }
            return Ok(bytes);
        }
        self.rx.recv().ok_or_else(Self::closed_err)
    }

    /// Blocking receive that lends the frame until the next `recv_held`
    /// call, recycling the previously lent buffer into the link's pool —
    /// the sender's `send_from` picks it up, closing the
    /// zero-allocation circulation loop.
    pub fn recv_held(&mut self) -> Result<&[u8]> {
        let bytes = self.recv()?;
        if let Some(prev) = self.held.replace(bytes) {
            recycle(&self.pool, prev);
        }
        Ok(self.held.as_deref().expect("held just set"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_arrive_in_order_with_byte_accounting() {
        let (mut tx, mut rx) = frame_link(1e12, Duration::ZERO);
        tx.send(vec![1, 2, 3]);
        tx.send(vec![4, 5]);
        assert_eq!(tx.bytes_sent, 5);
        assert_eq!(tx.msgs_sent, 2);
        assert_eq!(rx.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(rx.recv().unwrap(), vec![4, 5]);
    }

    #[test]
    fn dropped_sender_is_an_error_not_a_hang() {
        let (tx, mut rx) = frame_link(1e12, Duration::ZERO);
        drop(tx);
        let err = rx.recv().unwrap_err();
        assert!(err.to_string().contains("channel closed"), "{err}");
    }

    #[test]
    fn poll_then_recv_preserves_order_and_accounting() {
        let (mut tx, mut rx) = frame_link(1e12, Duration::ZERO);
        assert_eq!(rx.poll(), Poll::Empty);
        tx.send(vec![1]);
        tx.send(vec![2]);
        // poll stashes the head frame; recv consumes stash then channel
        assert_eq!(rx.poll(), Poll::Ready);
        assert_eq!(rx.recv().unwrap(), vec![1]);
        assert_eq!(rx.recv().unwrap(), vec![2]);
        assert_eq!(rx.poll(), Poll::Empty);
        drop(tx);
        assert_eq!(rx.poll(), Poll::Closed);
    }

    #[test]
    fn poll_reports_in_flight_with_a_deadline() {
        let (mut tx, mut rx) = frame_link(8e6, Duration::ZERO); // 1 MB/s
        tx.send(vec![0u8; 20_000]); // 20 ms of modeled flight
        match rx.poll() {
            Poll::InFlight(at) => assert!(at > Instant::now()),
            p => panic!("expected InFlight, got {p:?}"),
        }
        // blocking recv still honours the pacing
        let t0 = Instant::now();
        assert_eq!(rx.recv().unwrap().len(), 20_000);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn try_recv_skips_in_flight_frames_and_errors_when_closed() {
        let (mut tx, mut rx) = frame_link(8e6, Duration::ZERO);
        assert!(rx.try_recv().unwrap().is_none());
        tx.send(vec![0u8; 20_000]);
        assert!(rx.try_recv().unwrap().is_none(), "in-flight frame must not surface");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(rx.try_recv().unwrap().unwrap().len(), 20_000);
        drop(tx);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn send_from_recycles_buffers_through_the_pool() {
        let (mut tx, mut rx) = frame_link(1e12, Duration::ZERO);
        for round in 0..5u8 {
            tx.send_from(&[round; 16]);
            let got = rx.recv_held().unwrap();
            assert_eq!(got, [round; 16]);
        }
        assert_eq!(tx.bytes_sent, 5 * 16);
        // the previously held buffer went back to the pool each round
        assert!(!pool_lock(&tx.pool).is_empty());
    }

    #[test]
    fn doorbell_fires_once_per_send() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let (mut tx, mut rx) = frame_link(1e12, Duration::ZERO);
        let rings = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&rings);
        tx.set_doorbell(Arc::new(move || {
            counter.fetch_add(1, Ordering::SeqCst);
        }));
        tx.send(vec![1]);
        tx.send_from(&[2, 3]);
        assert_eq!(rings.load(Ordering::SeqCst), 2);
        assert_eq!(rx.recv().unwrap(), vec![1]);
        assert_eq!(rx.recv().unwrap(), vec![2, 3]);
    }
}
