//! The CommPlane: one codec/transport endpoint abstraction for every
//! traffic class the paper compresses end-to-end (§4.3) — forward
//! activations, backward activation gradients, and data-parallel model
//! gradients.
//!
//! A [`LinkEndpointTx`]/[`LinkEndpointRx`] pair bonds one registry-built
//! codec half to one directed [`FrameLink`]: the sender encodes into a
//! reusable [`FrameBuf`] scratch frame, ships the serialized image, and
//! reads its byte accounting off the real buffers; the receiver blocks
//! on the paced link and decodes in place through a borrowed
//! [`FrameView`]. The threaded and event pipeline executors run their
//! stage boundaries over these endpoints with real channel pacing (the
//! event mode polling readiness instead of parking); the virtual-clock
//! executor runs the *same* endpoints over unpaced links
//! (`f64::INFINITY` bandwidth, zero latency — a pure FIFO), which is
//! what keeps the executors bit-identical twins: same codec objects,
//! same call order, only the clock differs.
//!
//! [`DpRing`] builds the third traffic class on the same endpoints: an
//! all-gather ring over `degree` replicas in which each replica encodes
//! its (typically `ef:`-wrapped, error-compensated) gradient once,
//! forwards its neighbours' frames for `degree - 1` serialized hops, and
//! reconstructs every sender's contribution through per-sender decoder
//! replicas — so with synchronized updates all replicas compute the
//! bit-identical mean, and every reported DP wire byte is the serialized
//! size of a real frame.

use std::time::Duration;

use super::{frame_link, Doorbell, FrameLink, FrameLinkRx, FrameRx, FrameTx, Poll};
use crate::codec::registry::{build_mem_pair, SchemeSpec};
use crate::codec::{BoundaryCodec, FrameBuf, FrameView, Rounding};
use crate::coordinator::boundary::{BoundaryReceiver, BoundarySender, TransferStats};
use crate::util::error::{Context, Result};

/// Sending endpoint: codec encoder half + frame transport + accounting.
/// Owns a reusable [`FrameBuf`] scratch arena and ships its serialized
/// image through the transport (`send_from` recycles buffers on the
/// in-process links), so the steady-state encode+serialize+send path is
/// allocation-free end to end. The transport is a boxed [`FrameTx`]:
/// the same endpoint runs over an in-process channel or a TCP socket.
pub struct LinkEndpointTx {
    enc: BoundarySender,
    link: Box<dyn FrameTx>,
    buf: FrameBuf,
}

/// Receiving endpoint: frame transport + codec decoder half. Received
/// images are parsed as borrowing [`FrameView`]s, so header/payload
/// bytes are decoded in place — no frame copies on the receive path.
pub struct LinkEndpointRx {
    dec: BoundaryReceiver,
    link: Box<dyn FrameRx>,
}

/// Bond a codec encoder half to the sending side of an existing
/// transport link.
pub fn link_endpoint_tx(
    boundary_id: u32,
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
    link: Box<dyn FrameTx>,
) -> LinkEndpointTx {
    LinkEndpointTx {
        enc: BoundarySender::new(boundary_id, example_len, enc),
        link,
        buf: FrameBuf::new(),
    }
}

/// Bond a codec decoder half to the receiving side of an existing
/// transport link.
pub fn link_endpoint_rx(
    boundary_id: u32,
    example_len: usize,
    dec: Box<dyn BoundaryCodec>,
    link: Box<dyn FrameRx>,
) -> LinkEndpointRx {
    LinkEndpointRx { dec: BoundaryReceiver::new(boundary_id, example_len, dec), link }
}

/// Bond a codec pair to a fresh in-process directed link. `bandwidth_bps`
/// may be `f64::INFINITY` (the virtual-clock executor's unpaced FIFO
/// mode). Multi-process runs build each side separately over socket
/// transports via [`link_endpoint_tx`]/[`link_endpoint_rx`].
pub fn link_endpoints(
    boundary_id: u32,
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
    dec: Box<dyn BoundaryCodec>,
    bandwidth_bps: f64,
    latency: Duration,
) -> (LinkEndpointTx, LinkEndpointRx) {
    let (tx, rx) = frame_link(bandwidth_bps, latency);
    (
        link_endpoint_tx(boundary_id, example_len, enc, Box::new(tx)),
        link_endpoint_rx(boundary_id, example_len, dec, Box::new(rx)),
    )
}

impl LinkEndpointTx {
    /// Encode one message into the endpoint's scratch frame and ship its
    /// serialized image. The returned stats carry the measured wire
    /// bytes (the built image's length — what actually shipped).
    pub fn send(&mut self, ids: &[u64], a: &[f32]) -> Result<TransferStats> {
        let stats = self.enc.encode_into(ids, a, &mut self.buf)?;
        self.link.send_from(self.buf.as_bytes())?;
        Ok(stats)
    }

    /// Like [`send`](Self::send), but also hands back the serialized
    /// image — the DP ring decodes the sender's own frame locally so
    /// every replica reconstructs the identical mean.
    pub fn send_keep(&mut self, ids: &[u64], a: &[f32]) -> Result<(TransferStats, Vec<u8>)> {
        let stats = self.enc.encode_into(ids, a, &mut self.buf)?;
        let bytes = self.buf.as_bytes().to_vec();
        self.link.send_from(&bytes)?;
        Ok((stats, bytes))
    }

    /// Ship an already-serialized frame unchanged (ring forwarding).
    pub fn forward(&mut self, bytes: Vec<u8>) -> Result<()> {
        self.link.send(bytes)
    }

    /// Install the link's post-enqueue wakeup (see [`Doorbell`]).
    pub fn set_doorbell(&mut self, bell: Doorbell) {
        self.link.set_doorbell(bell);
    }

    /// Total serialized bytes shipped on this link.
    pub fn bytes_sent(&self) -> u64 {
        self.link.bytes_sent()
    }

    /// Encoder-side persistent codec state (message buffers etc.).
    pub fn state_bytes(&self) -> u64 {
        self.enc.state_bytes()
    }

    /// Worker count for the codec's chunked kernels on large messages.
    pub fn set_workers(&mut self, threads: usize) {
        self.enc.set_workers(threads);
    }
}

impl LinkEndpointRx {
    /// Non-blocking readiness of the next frame (never parks — the event
    /// executor's workers schedule on this).
    pub fn poll(&mut self) -> Poll {
        self.link.poll()
    }

    /// Blocking receive + decode of the next frame.
    pub fn recv(&mut self, ids: &[u64]) -> Result<Vec<f32>> {
        let bytes = self.link.recv_held()?;
        self.dec.decode_view(ids, &FrameView::parse(bytes)?)
    }

    /// Blocking receive + decode into a reusable caller buffer, resized
    /// to the expected activation shape (capacity is retained across
    /// calls — the executor's per-endpoint decode scratch). The frame is
    /// borrowed from the link's held buffer, which recycles through the
    /// sender's pool: steady state touches the allocator zero times.
    pub fn recv_into(&mut self, ids: &[u64], out: &mut Vec<f32>) -> Result<()> {
        out.resize(ids.len() * self.dec.example_len(), 0.0);
        let bytes = self.link.recv_held()?;
        self.dec.decode_into(ids, &FrameView::parse(bytes)?, out)
    }

    /// Receive the raw serialized frame (the ring decodes per sender,
    /// not per link).
    pub fn recv_raw(&mut self) -> Result<Vec<u8>> {
        self.link.recv()
    }

    /// Install a wakeup fired when a frame lands on this endpoint's
    /// receiving side (socket transports ring it from the I/O driver;
    /// in-process links ring it from the sender).
    pub fn set_doorbell(&mut self, bell: Doorbell) {
        self.link.set_doorbell(bell);
    }

    /// Decoder-side persistent codec state (the buffer replica).
    pub fn state_bytes(&self) -> u64 {
        self.dec.state_bytes()
    }

    /// Worker count for the codec's chunked kernels on large messages.
    pub fn set_workers(&mut self, threads: usize) {
        self.dec.set_workers(threads);
    }
}

// ---------------------------------------------------------------------------
// Per-session endpoints (the serving front end)
// ---------------------------------------------------------------------------

/// Link-free encoding endpoint for session-multiplexed transports: the
/// codec half + scratch frame of a [`LinkEndpointTx`] without an owned
/// link. The serving front end (`crate::serve`) runs many sessions over
/// one shared transport, so frames carry a session tag and the caller
/// routes the bytes — what stays strictly per session is the codec
/// replica in here (AQ message buffers, EF residuals, quantizer state),
/// which is exactly the isolation the `SessionTable` keys on.
pub struct SessionEndpointTx {
    enc: BoundarySender,
    buf: FrameBuf,
}

/// Link-free decoding endpoint: the receiver-side codec replica of a
/// session boundary, fed frame bytes by whoever demultiplexed them.
pub struct SessionEndpointRx {
    dec: BoundaryReceiver,
}

/// Build the encoder half of a per-session boundary endpoint.
pub fn session_endpoint_tx(
    boundary_id: u32,
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
) -> SessionEndpointTx {
    SessionEndpointTx {
        enc: BoundarySender::new(boundary_id, example_len, enc),
        buf: FrameBuf::new(),
    }
}

/// Build the decoder half of a per-session boundary endpoint.
pub fn session_endpoint_rx(
    boundary_id: u32,
    example_len: usize,
    dec: Box<dyn BoundaryCodec>,
) -> SessionEndpointRx {
    SessionEndpointRx { dec: BoundaryReceiver::new(boundary_id, example_len, dec) }
}

impl SessionEndpointTx {
    /// Encode one message into the endpoint's scratch frame and hand the
    /// serialized image back for the caller to route (borrow — copy it
    /// into the envelope before the next encode).
    pub fn encode(&mut self, ids: &[u64], a: &[f32]) -> Result<(TransferStats, &[u8])> {
        let stats = self.enc.encode_into(ids, a, &mut self.buf)?;
        Ok((stats, self.buf.as_bytes()))
    }

    /// Encoder-side persistent codec state (message buffers etc.).
    pub fn state_bytes(&self) -> u64 {
        self.enc.state_bytes()
    }
}

impl SessionEndpointRx {
    /// Decode one serialized frame image for the given example ids.
    pub fn decode(&mut self, ids: &[u64], bytes: &[u8]) -> Result<Vec<f32>> {
        self.dec.decode_view(ids, &FrameView::parse(bytes)?)
    }

    /// Decoder-side persistent codec state (the buffer replica).
    pub fn state_bytes(&self) -> u64 {
        self.dec.state_bytes()
    }
}

// ---------------------------------------------------------------------------

/// One replica's endpoint of a per-stage gradient all-gather ring.
///
/// Protocol per optimizer step (degree `d`, replica `r`):
///  1. [`send_own`](Self::send_own) — encode the local (error-compensated)
///     gradient once and ship it to replica `r+1`;
///  2. `d - 1` [`hop`](Self::hop)s — receive the next frame from `r-1`
///     and forward it to `r+1` unless it has completed the ring;
///  3. [`finish`](Self::finish) — decode all `d` frames *in sender
///     order* through per-sender decoder replicas and return the mean.
///
/// Because every replica decodes the same `d` frames with
/// identically-initialized decoders and accumulates in the same order,
/// the means are bit-identical across replicas (the synchronized-update
/// invariant `DpGroup` asserts every step).
pub struct DpRing {
    pub replica: usize,
    pub degree: usize,
    n: usize,
    ids: [u64; 1],
    /// own EF/codec encoder bonded to the outgoing ring edge
    tx: LinkEndpointTx,
    /// incoming ring edge, raw (decode happens per sender)
    rx: Box<dyn FrameRx>,
    /// per-sender decoder replicas (index = originating replica)
    dec: Vec<BoundaryReceiver>,
    /// frames of the current round, slotted by sender
    frames: Vec<Option<Vec<u8>>>,
    /// per-sender dequantization scratch, reused across rounds
    deq: Vec<f32>,
    sent_bytes: u64,
    max_frame: u64,
}

/// Build the `degree` ring endpoints for one stage's gradient exchange:
/// `n`-element gradients compressed under `scheme` (normally an `ef:`
/// wrapper). One registry build per sender seeds that sender's encoder
/// and *every* replica's decoder-for-that-sender identically, so the
/// decoder replicas start — and stay — in lockstep. Rounding and seed
/// flow in from the caller's config; there is no constructor-internal
/// rng.
pub fn dp_rings(
    scheme: &SchemeSpec,
    degree: usize,
    n: usize,
    rounding: Rounding,
    seed: u64,
    bandwidth_bps: f64,
    latency: Duration,
) -> Result<Vec<DpRing>> {
    crate::ensure!(degree >= 1, "dp ring needs at least one replica");
    crate::ensure!(n >= 1, "dp ring needs a non-empty gradient");
    // directed ring edges j -> (j+1) % degree
    let mut edge_tx: Vec<Option<FrameLink>> = (0..degree).map(|_| None).collect();
    let mut edge_rx: Vec<Option<FrameLinkRx>> = (0..degree).map(|_| None).collect();
    for j in 0..degree {
        let (tx, rx) = frame_link(bandwidth_bps, latency);
        edge_tx[j] = Some(tx);
        edge_rx[(j + 1) % degree] = Some(rx);
    }
    let mut rings = Vec::with_capacity(degree);
    for r in 0..degree {
        let tx = edge_tx[r].take().expect("edge distributed once");
        let rx = edge_rx[r].take().expect("edge distributed once");
        rings.push(dp_ring_endpoint(
            scheme,
            degree,
            r,
            n,
            rounding,
            seed,
            (Box::new(tx), Box::new(rx)),
        )?);
    }
    Ok(rings)
}

/// Build ONE replica's ring endpoint over caller-provided transport
/// halves — the multi-process path, where each OS process owns exactly
/// its own endpoint and the edges are TCP sockets. Codec construction
/// (one registry build per sender, seeded by sender index) is identical
/// to [`dp_rings`], so a socket-backed replica stays in bit-lockstep
/// with in-process ones.
pub fn dp_ring_endpoint(
    scheme: &SchemeSpec,
    degree: usize,
    replica: usize,
    n: usize,
    rounding: Rounding,
    seed: u64,
    links: (Box<dyn FrameTx>, Box<dyn FrameRx>),
) -> Result<DpRing> {
    crate::ensure!(degree >= 1, "dp ring needs at least one replica");
    crate::ensure!(
        replica < degree,
        "dp ring replica {replica} out of range for degree {degree}"
    );
    crate::ensure!(n >= 1, "dp ring needs a non-empty gradient");
    let sender_seed = |j: usize| seed ^ (0xD9D9_0000 | j as u64);
    let enc = build_mem_pair(scheme, n, rounding, sender_seed(replica))?.0;
    let mut dec = Vec::with_capacity(degree);
    for j in 0..degree {
        let half = build_mem_pair(scheme, n, rounding, sender_seed(j))?.1;
        dec.push(BoundaryReceiver::new(j as u32, n, half));
    }
    Ok(DpRing {
        replica,
        degree,
        n,
        ids: [0],
        tx: LinkEndpointTx {
            enc: BoundarySender::new(replica as u32, n, enc),
            link: links.0,
            buf: FrameBuf::new(),
        },
        rx: links.1,
        dec,
        frames: (0..degree).map(|_| None).collect(),
        deq: Vec::new(),
        sent_bytes: 0,
        max_frame: 0,
    })
}

impl DpRing {
    /// Step 1: encode this replica's gradient and ship it around the
    /// ring. Returns the encoder's transfer stats.
    pub fn send_own(&mut self, g: &[f32]) -> Result<TransferStats> {
        crate::ensure!(
            g.len() == self.n,
            "dp ring replica {}: gradient length {} != {}",
            self.replica,
            g.len(),
            self.n
        );
        let (stats, bytes) = self.tx.send_keep(&self.ids, g)?;
        self.sent_bytes += bytes.len() as u64;
        self.max_frame = self.max_frame.max(bytes.len() as u64);
        crate::ensure!(
            self.frames[self.replica].replace(bytes).is_none(),
            "dp ring replica {}: send_own called twice in one round",
            self.replica
        );
        Ok(stats)
    }

    /// Step 2, executed `degree - 1` times with `hop = 1..degree`:
    /// receive the next frame from the predecessor and forward it unless
    /// it has completed the ring.
    pub fn hop(&mut self, hop: usize) -> Result<()> {
        crate::ensure!(
            hop >= 1 && hop < self.degree,
            "dp ring hop {hop} out of range for degree {}",
            self.degree
        );
        let bytes = self.rx.recv()?;
        let origin = (self.replica + self.degree - hop) % self.degree;
        if hop + 1 < self.degree {
            // not yet at the origin's predecessor: keep it moving
            self.sent_bytes += bytes.len() as u64;
            self.max_frame = self.max_frame.max(bytes.len() as u64);
            self.tx.forward(bytes.clone())?;
        }
        crate::ensure!(
            self.frames[origin].replace(bytes).is_none(),
            "dp ring replica {}: duplicate frame from sender {origin}",
            self.replica
        );
        Ok(())
    }

    /// Step 3: decode every sender's frame in sender order and return
    /// `(mean gradient, serialized bytes this replica shipped)`. Each
    /// frame is parsed as a borrowing [`FrameView`] and dequantized into
    /// the ring's reusable scratch — per-sender hop buffers are the only
    /// per-round allocations (they are the transport's owned messages).
    pub fn finish(&mut self) -> Result<(Vec<f32>, u64)> {
        let mut acc = vec![0f32; self.n];
        self.deq.resize(self.n, 0.0);
        for j in 0..self.degree {
            let bytes = self.frames[j]
                .take()
                .with_context(|| format!("dp ring finish before the frame from sender {j}"))?;
            let view = FrameView::parse(&bytes)?;
            self.dec[j].decode_into(&self.ids, &view, &mut self.deq)?;
            for (a, d) in acc.iter_mut().zip(&self.deq) {
                *a += d;
            }
        }
        let inv = 1.0 / self.degree as f32;
        for a in acc.iter_mut() {
            *a *= inv;
        }
        Ok((acc, std::mem::take(&mut self.sent_bytes)))
    }

    /// Non-blocking readiness of the next incoming ring frame. The event
    /// executor polls this between [`hop`](Self::hop)s so a worker never
    /// parks mid-ring; a `Ready` poll stashes the frame, making the
    /// subsequent `hop` consume it without sleeping.
    pub fn poll_next(&mut self) -> Poll {
        self.rx.poll()
    }

    /// Install the outgoing edge's post-enqueue wakeup (fires toward the
    /// successor replica, see [`Doorbell`]).
    pub fn set_doorbell(&mut self, bell: Doorbell) {
        self.tx.set_doorbell(bell);
    }

    /// Install a wakeup on the *incoming* ring edge — the multi-process
    /// path, where frame arrival is signalled by the local I/O driver
    /// rather than by an in-process sender.
    pub fn set_rx_doorbell(&mut self, bell: Doorbell) {
        self.rx.set_doorbell(bell);
    }

    /// Convenience for the threaded executor (each replica runs on its
    /// own thread, so the blocking hops interleave naturally).
    pub fn all_reduce(&mut self, g: &[f32]) -> Result<(Vec<f32>, u64)> {
        self.send_own(g)?;
        for hop in 1..self.degree {
            self.hop(hop)?;
        }
        self.finish()
    }

    /// Largest serialized frame seen since the last call (sizes the
    /// virtual clock's hop rounds); resets the watermark.
    pub fn take_max_frame(&mut self) -> u64 {
        std::mem::take(&mut self.max_frame)
    }

    /// Encoder-side persistent codec state.
    pub fn state_bytes(&self) -> u64 {
        self.tx.state_bytes()
    }

    /// Worker count for the chunked codec kernels, applied to the
    /// encoder and every per-sender decoder replica (gradient vectors
    /// are the largest messages on the plane).
    pub fn set_workers(&mut self, threads: usize) {
        self.tx.set_workers(threads);
        for d in &mut self.dec {
            d.set_workers(threads);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::CodecSpec;
    use crate::util::Rng;

    fn unpaced() -> (f64, Duration) {
        (f64::INFINITY, Duration::ZERO)
    }

    /// Drive all rings through one round in the single-threaded phase
    /// order (what DpGroup and the virtual-clock executor do).
    fn round(rings: &mut [DpRing], grads: &[Vec<f32>]) -> Vec<(Vec<f32>, u64)> {
        let d = rings.len();
        for (r, ring) in rings.iter_mut().enumerate() {
            ring.send_own(&grads[r]).unwrap();
        }
        for hop in 1..d {
            for ring in rings.iter_mut() {
                ring.hop(hop).unwrap();
            }
        }
        rings.iter_mut().map(|ring| ring.finish().unwrap()).collect()
    }

    #[test]
    fn fp32_ring_is_exact_mean_with_measured_bytes() {
        let (bw, lat) = unpaced();
        let n = 32;
        let d = 4;
        let spec = CodecSpec::fp32();
        let mut rings = dp_rings(&spec.fw, d, n, Rounding::Nearest, 1, bw, lat).unwrap();
        let mut rng = Rng::new(1);
        let grads: Vec<Vec<f32>> =
            (0..d).map(|_| (0..n).map(|_| rng.normal() * 0.1).collect()).collect();
        let results = round(&mut rings, &grads);
        for j in 0..n {
            let want: f32 = grads.iter().map(|g| g[j]).sum::<f32>() / d as f32;
            for (mean, _) in &results {
                assert!((mean[j] - want).abs() < 1e-6);
            }
        }
        // every replica ships its own frame plus d-2 forwards, every one
        // a real serialized raw32 frame (prelude 7 + n:u32 + 4n payload)
        let frame = (crate::codec::frame::FRAME_PRELUDE_BYTES + 4 + 4 * n) as u64;
        for (_, sent) in &results {
            assert_eq!(*sent, (d as u64 - 1) * frame);
        }
    }

    #[test]
    fn replicas_compute_bit_identical_means() {
        let (bw, lat) = unpaced();
        let n = 64;
        let d = 3;
        let spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        let mut rings = dp_rings(&spec.fw, d, n, Rounding::Stochastic, 7, bw, lat).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..5 {
            let grads: Vec<Vec<f32>> =
                (0..d).map(|_| (0..n).map(|_| rng.normal() * 0.01).collect()).collect();
            let results = round(&mut rings, &grads);
            let (m0, _) = &results[0];
            for (m, _) in &results[1..] {
                let same =
                    m0.iter().zip(m).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "replica means diverged");
            }
        }
    }

    #[test]
    fn ring_errors_on_bad_shapes_and_missing_phases() {
        let (bw, lat) = unpaced();
        let spec = CodecSpec::fp32();
        let mut rings = dp_rings(&spec.fw, 2, 8, Rounding::Nearest, 1, bw, lat).unwrap();
        assert!(rings[0].send_own(&vec![0.0; 7]).is_err());
        // finish before the peer frame arrived: error, not a hang/panic
        rings[0].send_own(&vec![0.0; 8]).unwrap();
        assert!(rings[0].finish().is_err());
    }
}
