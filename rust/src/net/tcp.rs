//! Real TCP transport under the CommPlane.
//!
//! The paper's regime is pipeline stages separated by *actual* slow links
//! (100–500 Mbps, high RTT) — not in-process channels. This module puts a
//! std-only TCP transport behind the exact [`FrameTx`]/[`FrameRx`]
//! poll/doorbell readiness contract the event executor already runs on:
//!
//!  * **Wire format** — each frame ships as a 4-byte little-endian length
//!    prefix followed by the serialized frame image. The receive path
//!    reassembles arbitrary TCP segmentation in a [`FrameAssembler`] and
//!    revalidates every completed frame with `FrameView::parse`'s
//!    hostile-buffer length checks, so a corrupt or truncated stream is
//!    an `Err`, never a panic or an unbounded allocation.
//!  * **I/O driver** — sockets are non-blocking and serviced by one
//!    [`IoDriver`] thread per process (no thread-per-socket): it drains
//!    send queues, reassembles inbound frames, stamps each completed
//!    frame with its delivery instant, fires the receiver's [`Doorbell`],
//!    and wakes blocked `recv` callers.
//!  * **Accounting** — [`FrameTx::bytes_sent`] on [`TcpFrameTx`] counts frame bytes
//!    excluding the length prefix, so per-link wire accounting is
//!    bit-identical to the in-process [`FrameLink`](super::FrameLink).
//!  * **Link shaping** — a [`LinkShape`] adds a token-bucket bandwidth
//!    cap on writes, injected latency/jitter on deliveries (jitter is
//!    monotone per link: delivery order never reorders), and forced
//!    partial reads/writes (`max_io_chunk`), so the paper's slow-network
//!    grid runs as loopback integration tests.
//!  * **Failure** — a peer that disconnects (or dies) surfaces as
//!    [`Poll::Closed`] after the queue drains and as a descriptive `Err`
//!    from `recv`/`send`; mid-frame truncation is called out explicitly.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Shutdown, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{Doorbell, FrameRx, FrameTx, Poll};
use crate::codec::frame::{FrameView, FRAME_PRELUDE_BYTES};
use crate::util::error::{Context, Result};
use crate::util::Rng;

/// Bytes of the per-frame length prefix on the TCP stream.
pub const LEN_PREFIX_BYTES: usize = 4;

/// Default per-frame size cap enforced *before* buffering a frame's
/// bytes — a hostile length prefix cannot make the assembler allocate.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// How long a dropped [`IoDriver`] keeps flushing queued writes before
/// giving up (bounded so a dead peer cannot hang process exit).
const FLUSH_DEADLINE: Duration = Duration::from_secs(5);

/// Driver idle wait between service passes when nothing is ready.
const IDLE_WAIT: Duration = Duration::from_micros(200);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// Frame reassembly

/// Incremental reassembler for the length-prefixed frame stream.
///
/// Bytes go in via [`push`](Self::push) in whatever segmentation TCP
/// produced (1-byte reads, split preludes, coalesced frames); completed,
/// validated frames come out of [`pop`](Self::pop) in order. Validation
/// is layered: the length prefix is range-checked before any buffering
/// decision, the frame prelude is cross-checked against the prefix as
/// soon as its 7 bytes are visible (rejecting a corrupt stream early),
/// and the completed image must satisfy `FrameView::parse` exactly.
pub struct FrameAssembler {
    buf: Vec<u8>,
    out: VecDeque<Vec<u8>>,
    max_frame: usize,
}

impl FrameAssembler {
    pub fn new(max_frame: usize) -> Self {
        FrameAssembler { buf: Vec::new(), out: VecDeque::new(), max_frame }
    }

    /// Feed one received segment; queues every frame it completes.
    pub fn push(&mut self, bytes: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(bytes);
        loop {
            if self.buf.len() < LEN_PREFIX_BYTES {
                return Ok(());
            }
            let len = u32::from_le_bytes(
                self.buf[..LEN_PREFIX_BYTES].try_into().expect("4-byte slice"),
            ) as usize;
            crate::ensure!(
                len >= FRAME_PRELUDE_BYTES,
                "tcp frame length prefix {len} is shorter than a frame prelude"
            );
            crate::ensure!(
                len <= self.max_frame,
                "tcp frame length prefix {len} exceeds the {} byte cap",
                self.max_frame
            );
            // cross-check the frame's own prelude as soon as it is
            // visible — a corrupted stream dies here, before the
            // assembler commits to buffering `len` bytes
            if self.buf.len() >= LEN_PREFIX_BYTES + FRAME_PRELUDE_BYTES {
                let p = &self.buf[LEN_PREFIX_BYTES..];
                let header_len = u16::from_le_bytes([p[1], p[2]]) as u64;
                let payload_len = u32::from_le_bytes([p[3], p[4], p[5], p[6]]) as u64;
                let expect = FRAME_PRELUDE_BYTES as u64 + header_len + payload_len;
                crate::ensure!(
                    len as u64 == expect,
                    "tcp frame prefix {len} disagrees with its prelude \
                     (header {header_len} + payload {payload_len} bytes)"
                );
            }
            if self.buf.len() < LEN_PREFIX_BYTES + len {
                return Ok(());
            }
            let frame = self.buf[LEN_PREFIX_BYTES..LEN_PREFIX_BYTES + len].to_vec();
            // full structural validation (exact length match, u64 math)
            FrameView::parse(&frame)?;
            self.buf.drain(..LEN_PREFIX_BYTES + len);
            self.out.push_back(frame);
        }
    }

    /// Next completed frame, in stream order.
    pub fn pop(&mut self) -> Option<Vec<u8>> {
        self.out.pop_front()
    }

    /// True when bytes of an incomplete frame are pending — EOF here
    /// means the peer died mid-frame.
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    /// Bytes currently buffered (for tests pinning that a hostile prefix
    /// never makes the assembler allocate ahead of received data).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

// ---------------------------------------------------------------------------
// Link shaping

/// Slow-network emulation knobs for one registered socket, applied by
/// the I/O driver. `Default` is an unshaped link.
#[derive(Clone, Debug)]
pub struct LinkShape {
    /// Token-bucket bandwidth cap on writes, bits/s (`None` = unshaped).
    pub rate_bps: Option<f64>,
    /// Fixed delivery latency added to every inbound frame.
    pub latency: Duration,
    /// Extra uniform-random delivery delay in `[0, jitter)`. Deliveries
    /// stay monotone (FIFO): jitter stretches time, never reorders.
    pub jitter: Duration,
    /// Seed for the jitter stream (deterministic per link).
    pub jitter_seed: u64,
    /// Cap on bytes per read/write syscall — forces the partial-I/O
    /// paths real congested links exercise (`None` = unforced).
    pub max_io_chunk: Option<usize>,
    /// Per-frame size cap for the reassembler.
    pub max_frame: usize,
}

impl Default for LinkShape {
    fn default() -> Self {
        LinkShape {
            rate_bps: None,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            jitter_seed: 0x5EED,
            max_io_chunk: None,
            max_frame: DEFAULT_MAX_FRAME,
        }
    }
}

// ---------------------------------------------------------------------------
// Shared per-connection state

/// Send side: frames queued by `TcpFrameTx`, drained by the driver.
struct OutHalf {
    /// Pending byte chunks (each frame is queued as its 4-byte prefix
    /// followed by the frame image).
    queue: VecDeque<Vec<u8>>,
    /// Write cursor into `queue.front()`.
    cursor: usize,
    /// The `TcpFrameTx` handle was dropped: flush, then shutdown(Write).
    tx_dropped: bool,
    /// First write-side failure; later sends report it.
    err: Option<String>,
}

/// Receive side: completed frames stamped with delivery instants.
struct InHalf {
    frames: VecDeque<(Instant, Vec<u8>)>,
    /// No more frames will arrive (EOF, error, or truncation).
    closed: bool,
    /// Why, when closure was not a clean EOF.
    err: Option<String>,
    bell: Option<Doorbell>,
}

struct ConnShared {
    out: Mutex<OutHalf>,
    inq: Mutex<InHalf>,
    /// Signalled on every inbound change, for blocking `recv`.
    cv: Condvar,
}

/// Driver-private connection state.
struct DriverConn {
    sock: TcpStream,
    shared: Arc<ConnShared>,
    asm: FrameAssembler,
    shape: LinkShape,
    jitter_rng: Rng,
    /// Token-bucket fill, in bytes.
    tokens: f64,
    last_refill: Instant,
    /// Latest delivery stamp handed out (keeps jittered deliveries FIFO).
    last_deliver: Instant,
    read_done: bool,
    write_done: bool,
}

// ---------------------------------------------------------------------------
// The I/O driver

struct DriverCore {
    conns: Mutex<Vec<DriverConn>>,
    wake: Mutex<bool>,
    cv: Condvar,
    stop: AtomicBool,
}

impl DriverCore {
    fn wake_driver(&self) {
        *lock(&self.wake) = true;
        self.cv.notify_one();
    }
}

/// One background thread servicing every registered socket of this
/// process: non-blocking writes under the token bucket, non-blocking
/// reads through the frame reassembler, delivery stamping, doorbells.
/// Dropping the driver flushes pending writes (bounded by a deadline)
/// and joins the thread.
pub struct IoDriver {
    core: Arc<DriverCore>,
    thread: Option<JoinHandle<()>>,
}

impl Default for IoDriver {
    fn default() -> Self {
        Self::new()
    }
}

impl IoDriver {
    pub fn new() -> Self {
        let core = Arc::new(DriverCore {
            conns: Mutex::new(Vec::new()),
            wake: Mutex::new(false),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
        });
        let c = Arc::clone(&core);
        let thread = std::thread::Builder::new()
            .name("aq-sgd-io".into())
            .spawn(move || driver_loop(&c))
            .expect("spawn io driver thread");
        IoDriver { core, thread: Some(thread) }
    }

    /// Register one established socket; returns its transport endpoints.
    /// A simplex user keeps one half and drops the other (dropping the
    /// tx half flushes, then shuts down the write direction).
    pub fn register(&self, sock: TcpStream, shape: LinkShape) -> Result<(TcpFrameTx, TcpFrameRx)> {
        sock.set_nodelay(true).ok();
        // session-layer handshakes run the socket blocking with read
        // timeouts; the driver needs it non-blocking and untimed
        sock.set_read_timeout(None).ok();
        sock.set_write_timeout(None).ok();
        sock.set_nonblocking(true).context("switching the socket to non-blocking mode")?;
        let shared = Arc::new(ConnShared {
            out: Mutex::new(OutHalf {
                queue: VecDeque::new(),
                cursor: 0,
                tx_dropped: false,
                err: None,
            }),
            inq: Mutex::new(InHalf {
                frames: VecDeque::new(),
                closed: false,
                err: None,
                bell: None,
            }),
            cv: Condvar::new(),
        });
        let now = Instant::now();
        let conn = DriverConn {
            sock,
            shared: Arc::clone(&shared),
            asm: FrameAssembler::new(shape.max_frame),
            jitter_rng: Rng::new(shape.jitter_seed),
            shape,
            tokens: 0.0,
            last_refill: now,
            last_deliver: now,
            read_done: false,
            write_done: false,
        };
        lock(&self.core.conns).push(conn);
        self.core.wake_driver();
        Ok((
            TcpFrameTx {
                conn: Arc::clone(&shared),
                core: Arc::clone(&self.core),
                doorbell: None,
                bytes_sent: 0,
                msgs_sent: 0,
            },
            TcpFrameRx { conn: shared, stash: None, held: None },
        ))
    }
}

impl Drop for IoDriver {
    fn drop(&mut self) {
        self.core.stop.store(true, Ordering::Release);
        self.core.wake_driver();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn driver_loop(core: &DriverCore) {
    let mut scratch = vec![0u8; 64 << 10];
    let mut stop_deadline: Option<Instant> = None;
    loop {
        let stopping = core.stop.load(Ordering::Acquire);
        let mut progressed = false;
        let mut pending_writes = false;
        {
            let mut conns = lock(&core.conns);
            for c in conns.iter_mut() {
                progressed |= service_writes(c);
                // on shutdown only the flush matters; skip reads so a
                // flood of inbound bytes cannot delay process exit
                if !stopping {
                    progressed |= service_reads(c, &mut scratch);
                }
                if !c.write_done {
                    let out = lock(&c.shared.out);
                    pending_writes |= out.err.is_none() && !out.queue.is_empty();
                }
            }
        }
        if stopping {
            let dl = *stop_deadline.get_or_insert_with(|| Instant::now() + FLUSH_DEADLINE);
            if !pending_writes || Instant::now() >= dl {
                return;
            }
        }
        if !progressed {
            let mut w = lock(&core.wake);
            if !*w {
                let (g, _) = core
                    .cv
                    .wait_timeout(w, IDLE_WAIT)
                    .unwrap_or_else(|p| p.into_inner());
                w = g;
            }
            *w = false;
        }
    }
}

/// Drain this connection's send queue as far as the socket and the token
/// bucket allow. Returns true when any bytes moved.
fn service_writes(c: &mut DriverConn) -> bool {
    if c.write_done {
        return false;
    }
    let mut progressed = false;
    let mut out = lock(&c.shared.out);
    if out.err.is_none() {
        if let Some(rate) = c.shape.rate_bps {
            let now = Instant::now();
            let dt = now.duration_since(c.last_refill).as_secs_f64();
            c.last_refill = now;
            let bytes_per_s = rate / 8.0;
            // small burst allowance: enough to keep syscall counts sane
            // without letting a slow link front-load whole frames
            let burst = (bytes_per_s * 0.005).max(4096.0);
            c.tokens = (c.tokens + dt * bytes_per_s).min(burst);
        }
        loop {
            let cursor = out.cursor;
            let n = {
                let Some(front) = out.queue.front() else { break };
                let mut n = front.len() - cursor;
                if let Some(chunk) = c.shape.max_io_chunk {
                    n = n.min(chunk.max(1));
                }
                if c.shape.rate_bps.is_some() {
                    let budget = c.tokens as usize;
                    if budget == 0 {
                        break;
                    }
                    n = n.min(budget);
                }
                n
            };
            let front = out.queue.front().expect("non-empty queue");
            let res = c.sock.write(&front[cursor..cursor + n]);
            match res {
                Ok(0) => {
                    out.err = Some("tcp write accepted 0 bytes".into());
                    break;
                }
                Ok(w) => {
                    progressed = true;
                    if c.shape.rate_bps.is_some() {
                        c.tokens -= w as f64;
                    }
                    out.cursor += w;
                    if out.cursor == out.queue.front().expect("non-empty queue").len() {
                        out.queue.pop_front();
                        out.cursor = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    out.err = Some(format!("tcp write failed: {e}"));
                    break;
                }
            }
        }
    }
    if out.err.is_some() {
        out.queue.clear();
        out.cursor = 0;
        c.write_done = true;
    } else if out.tx_dropped && out.queue.is_empty() {
        let _ = c.sock.shutdown(Shutdown::Write);
        c.write_done = true;
    }
    progressed
}

/// Pull whatever the socket has, reassemble, stamp deliveries, ring the
/// doorbell. Returns true when any bytes moved.
fn service_reads(c: &mut DriverConn, scratch: &mut [u8]) -> bool {
    if c.read_done {
        return false;
    }
    let mut progressed = false;
    loop {
        let cap = c.shape.max_io_chunk.map_or(scratch.len(), |n| n.clamp(1, scratch.len()));
        match c.sock.read(&mut scratch[..cap]) {
            Ok(0) => {
                finish_read(c, None);
                break;
            }
            Ok(n) => {
                progressed = true;
                match c.asm.push(&scratch[..n]) {
                    Ok(()) => deliver_frames(c),
                    Err(e) => {
                        finish_read(c, Some(format!("tcp frame stream invalid: {e}")));
                        break;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => {
                finish_read(c, Some(format!("tcp read failed: {e}")));
                break;
            }
        }
    }
    progressed
}

/// Move completed frames to the inbound queue with shaped delivery
/// instants; wake sleepers and ring the receiver's doorbell.
fn deliver_frames(c: &mut DriverConn) {
    let bell = {
        let mut inq = lock(&c.shared.inq);
        let mut delivered = false;
        while let Some(frame) = c.asm.pop() {
            let mut at = Instant::now() + c.shape.latency;
            if c.shape.jitter > Duration::ZERO {
                let j = c.jitter_rng.next_f64() * c.shape.jitter.as_secs_f64();
                at += Duration::from_secs_f64(j);
            }
            // monotone: jitter must never reorder the stream
            if at < c.last_deliver {
                at = c.last_deliver;
            }
            c.last_deliver = at;
            inq.frames.push_back((at, frame));
            delivered = true;
        }
        if !delivered {
            return;
        }
        c.shared.cv.notify_all();
        inq.bell.clone()
    };
    if let Some(b) = bell {
        b();
    }
}

/// Mark the inbound side closed (clean EOF when `err` is `None` and no
/// frame was mid-assembly); wake sleepers and ring the doorbell.
fn finish_read(c: &mut DriverConn, err: Option<String>) {
    c.read_done = true;
    let bell = {
        let mut inq = lock(&c.shared.inq);
        inq.err = err.or_else(|| {
            c.asm.has_partial().then(|| {
                "tcp stream truncated mid-frame (peer died or closed the socket)".to_string()
            })
        });
        inq.closed = true;
        c.shared.cv.notify_all();
        inq.bell.clone()
    };
    if let Some(b) = bell {
        b();
    }
}

// ---------------------------------------------------------------------------
// Transport endpoints

/// Socket-backed [`FrameTx`]: queues frames for the driver, counts frame
/// bytes (prefix excluded — identical accounting to the in-process
/// links). Dropping it flushes the queue and half-closes the socket.
pub struct TcpFrameTx {
    conn: Arc<ConnShared>,
    core: Arc<DriverCore>,
    doorbell: Option<Doorbell>,
    bytes_sent: u64,
    msgs_sent: u64,
}

impl FrameTx for TcpFrameTx {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        crate::ensure!(
            frame.len() <= u32::MAX as usize,
            "frame of {} bytes exceeds the tcp length-prefix range",
            frame.len()
        );
        {
            let mut out = lock(&self.conn.out);
            if let Some(e) = &out.err {
                return Err(crate::err!("tcp link send failed: {e}"));
            }
            self.bytes_sent += frame.len() as u64;
            self.msgs_sent += 1;
            out.queue.push_back((frame.len() as u32).to_le_bytes().to_vec());
            out.queue.push_back(frame);
        }
        self.core.wake_driver();
        if let Some(bell) = &self.doorbell {
            bell();
        }
        Ok(())
    }

    fn send_from(&mut self, frame: &[u8]) -> Result<()> {
        self.send(frame.to_vec())
    }

    fn set_doorbell(&mut self, bell: Doorbell) {
        self.doorbell = Some(bell);
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn msgs_sent(&self) -> u64 {
        self.msgs_sent
    }
}

impl Drop for TcpFrameTx {
    fn drop(&mut self) {
        lock(&self.conn.out).tx_dropped = true;
        self.core.wake_driver();
    }
}

/// Socket-backed [`FrameRx`] with the poll/stash/recv-held contract of
/// [`FrameLinkRx`](super::FrameLinkRx).
pub struct TcpFrameRx {
    conn: Arc<ConnShared>,
    stash: Option<(Instant, Vec<u8>)>,
    held: Option<Vec<u8>>,
}

impl TcpFrameRx {
    fn closed_err(inq: &InHalf) -> crate::util::error::Error {
        match &inq.err {
            Some(e) => crate::err!("tcp link failed: {e}"),
            None => crate::err!("pipeline channel closed: tcp peer closed the connection"),
        }
    }

    fn sleep_until(at: Instant) {
        let now = Instant::now();
        if at > now {
            std::thread::sleep(at - now);
        }
    }
}

impl FrameRx for TcpFrameRx {
    fn poll(&mut self) -> Poll {
        if self.stash.is_none() {
            let mut inq = lock(&self.conn.inq);
            match inq.frames.pop_front() {
                Some(pair) => self.stash = Some(pair),
                None if inq.closed => return Poll::Closed,
                None => return Poll::Empty,
            }
        }
        let at = self.stash.as_ref().map(|&(at, _)| at).expect("stash populated above");
        if Instant::now() >= at {
            Poll::Ready
        } else {
            Poll::InFlight(at)
        }
    }

    fn try_recv(&mut self) -> Result<Option<Vec<u8>>> {
        match self.poll() {
            Poll::Ready => Ok(Some(self.stash.take().expect("polled Ready").1)),
            Poll::Empty | Poll::InFlight(_) => Ok(None),
            Poll::Closed => {
                let inq = lock(&self.conn.inq);
                Err(Self::closed_err(&inq))
            }
        }
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        if let Some((at, frame)) = self.stash.take() {
            Self::sleep_until(at);
            return Ok(frame);
        }
        let mut inq = lock(&self.conn.inq);
        loop {
            if let Some((at, frame)) = inq.frames.pop_front() {
                drop(inq);
                Self::sleep_until(at);
                return Ok(frame);
            }
            if inq.closed {
                return Err(Self::closed_err(&inq));
            }
            inq = self.conn.cv.wait(inq).unwrap_or_else(|p| p.into_inner());
        }
    }

    fn recv_held(&mut self) -> Result<&[u8]> {
        let frame = self.recv()?;
        self.held = Some(frame);
        Ok(self.held.as_deref().expect("held just set"))
    }

    fn set_doorbell(&mut self, bell: Doorbell) {
        lock(&self.conn.inq).bell = Some(bell);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::frame::{Frame, TAG_RAW32};
    use std::net::TcpListener;

    fn test_frame(fill: u8, n: usize) -> Vec<u8> {
        Frame::new(TAG_RAW32, vec![fill, 2], vec![fill; n]).to_bytes()
    }

    fn prefixed(frame: &[u8]) -> Vec<u8> {
        let mut s = (frame.len() as u32).to_le_bytes().to_vec();
        s.extend_from_slice(frame);
        s
    }

    fn sock_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = l.local_addr().expect("addr");
        let a = TcpStream::connect(addr).expect("connect");
        let (b, _) = l.accept().expect("accept");
        (a, b)
    }

    #[test]
    fn assembler_handles_every_split_point() {
        let mut stream = prefixed(&test_frame(1, 9));
        stream.extend_from_slice(&prefixed(&test_frame(2, 3)));
        for cut in 0..=stream.len() {
            let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
            asm.push(&stream[..cut]).expect("first segment");
            asm.push(&stream[cut..]).expect("second segment");
            assert_eq!(asm.pop().expect("frame 1"), test_frame(1, 9), "cut {cut}");
            assert_eq!(asm.pop().expect("frame 2"), test_frame(2, 3), "cut {cut}");
            assert!(asm.pop().is_none());
            assert!(!asm.has_partial());
        }
    }

    #[test]
    fn assembler_rejects_hostile_prefixes_without_buffering() {
        // shorter than a prelude
        let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
        assert!(asm.push(&3u32.to_le_bytes()).is_err());
        // over the cap: rejected on the 4 prefix bytes alone
        let mut asm = FrameAssembler::new(1024);
        let err = asm.push(&u32::MAX.to_le_bytes()).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(asm.buffered() <= LEN_PREFIX_BYTES);
    }

    #[test]
    fn assembler_rejects_prefix_prelude_disagreement() {
        let frame = test_frame(7, 16);
        let mut stream = ((frame.len() + 1) as u32).to_le_bytes().to_vec();
        stream.extend_from_slice(&frame);
        let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
        let err = asm.push(&stream).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
    }

    #[test]
    fn assembler_flags_truncation() {
        let mut asm = FrameAssembler::new(DEFAULT_MAX_FRAME);
        let stream = prefixed(&test_frame(5, 40));
        asm.push(&stream[..stream.len() - 3]).expect("valid prefix so far");
        assert!(asm.pop().is_none());
        assert!(asm.has_partial());
    }

    #[test]
    fn loopback_roundtrip_with_accounting() {
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        let (mut tx, _arx) = driver.register(a, LinkShape::default()).expect("register a");
        let (_btx, mut rx) = driver.register(b, LinkShape::default()).expect("register b");
        let frames: Vec<Vec<u8>> =
            (0..3u8).map(|i| test_frame(i, 64 * (i as usize + 1))).collect();
        for f in &frames {
            tx.send(f.clone()).expect("send");
        }
        let wire: u64 = frames.iter().map(|f| f.len() as u64).sum();
        assert_eq!(tx.bytes_sent(), wire, "prefix bytes must not count");
        assert_eq!(tx.msgs_sent(), 3);
        for f in &frames {
            assert_eq!(&rx.recv().expect("recv"), f);
        }
    }

    #[test]
    fn forced_one_byte_io_still_delivers_bit_identically() {
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        let shape = LinkShape { max_io_chunk: Some(1), ..LinkShape::default() };
        let (mut tx, _arx) = driver.register(a, shape.clone()).expect("register a");
        let (_btx, mut rx) = driver.register(b, shape).expect("register b");
        let f = test_frame(9, 257);
        tx.send(f.clone()).expect("send");
        assert_eq!(rx.recv().expect("recv"), f);
    }

    #[test]
    fn token_bucket_paces_writes() {
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        // ~2 Mbit/s: a 20 kB frame takes ~80 ms on the wire
        let shape = LinkShape { rate_bps: Some(2e6), ..LinkShape::default() };
        let (mut tx, _arx) = driver.register(a, shape).expect("register a");
        let (_btx, mut rx) = driver.register(b, LinkShape::default()).expect("register b");
        let f = test_frame(3, 20_000);
        let t0 = Instant::now();
        tx.send(f.clone()).expect("send");
        assert_eq!(rx.recv().expect("recv"), f);
        assert!(t0.elapsed() >= Duration::from_millis(40), "{:?}", t0.elapsed());
    }

    #[test]
    fn latency_and_jitter_delay_but_never_reorder() {
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        let shape = LinkShape {
            latency: Duration::from_millis(5),
            jitter: Duration::from_millis(5),
            ..LinkShape::default()
        };
        let (mut tx, _arx) = driver.register(a, LinkShape::default()).expect("register a");
        let (_btx, mut rx) = driver.register(b, shape).expect("register b");
        let t0 = Instant::now();
        for i in 0..8u8 {
            tx.send(test_frame(i, 32)).expect("send");
        }
        for i in 0..8u8 {
            assert_eq!(rx.recv().expect("recv"), test_frame(i, 32), "frame {i} out of order");
        }
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn peer_drop_surfaces_closed_then_error_never_hangs() {
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        let (mut tx, arx) = driver.register(a, LinkShape::default()).expect("register a");
        let (btx, mut rx) = driver.register(b, LinkShape::default()).expect("register b");
        tx.send(test_frame(1, 8)).expect("send");
        assert_eq!(rx.recv().expect("last frame"), test_frame(1, 8));
        drop(tx);
        drop(arx);
        drop(btx);
        // queued frames were drained; closure now surfaces as Closed
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match rx.poll() {
                Poll::Closed => break,
                _ if Instant::now() > deadline => panic!("close never surfaced"),
                _ => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        let err = rx.recv().unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn doorbell_rings_on_arrival_and_on_close() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let driver = IoDriver::new();
        let (a, b) = sock_pair();
        let (mut tx, _arx) = driver.register(a, LinkShape::default()).expect("register a");
        let (_btx, mut rx) = driver.register(b, LinkShape::default()).expect("register b");
        let rings = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&rings);
        rx.set_doorbell(Arc::new(move || {
            counter.fetch_add(1, Ordering::SeqCst);
        }));
        tx.send(test_frame(2, 16)).expect("send");
        assert_eq!(rx.recv().expect("recv"), test_frame(2, 16));
        let deadline = Instant::now() + Duration::from_secs(5);
        while rings.load(Ordering::SeqCst) == 0 {
            assert!(Instant::now() < deadline, "arrival doorbell never rang");
            std::thread::sleep(Duration::from_millis(1));
        }
        let before_close = rings.load(Ordering::SeqCst);
        drop(tx);
        let deadline = Instant::now() + Duration::from_secs(5);
        while rings.load(Ordering::SeqCst) == before_close {
            assert!(Instant::now() < deadline, "close doorbell never rang");
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}
