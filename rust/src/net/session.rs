//! Peer/session layer over the TCP transport: who listens where, who
//! connects to whom, and the handshake that proves both ends are running
//! the same job before any training frame moves.
//!
//! A [`TopologyPlan`] maps every (replica, stage) cell of the pipeline
//! grid to one listen address; each directed link has a canonical
//! initiator (the **sender** connects): forward activations connect
//! downstream, backward gradients connect upstream, and the DP ring
//! connects to the next replica of the same stage. [`establish`] brings
//! one process's links up in a deadlock-free order — bind, connect all
//! outbound with retry, send hellos *without waiting*, then accept and
//! answer the expected inbound set — so every process can run the same
//! code concurrently.
//!
//! The hello is a [`TAG_HELLO`] frame: header = (protocol version, link
//! kind, from-(replica,stage), to-(replica,stage)), payload = the
//! canonical config summary (codec specs, schedule, topology, seed). A
//! version or summary mismatch is answered with a reject frame carrying
//! the reason, and surfaces as a descriptive `Err` on both ends —
//! never as two processes silently training different jobs.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::tcp::{IoDriver, LinkShape, TcpFrameRx, TcpFrameTx};
use crate::codec::frame::{Frame, FrameReader, FrameView, FrameWriter, TAG_HELLO};
use crate::util::error::{Context, Result};

/// Session protocol version; bumped on any wire or handshake change.
pub const SESSION_VERSION: u32 = 1;

/// Cap on a handshake frame — hellos are small; anything bigger is a
/// confused or hostile peer.
const HELLO_MAX_BYTES: usize = 1 << 16;

/// Poll cadence while retrying connects / waiting on accepts.
const RETRY_WAIT: Duration = Duration::from_millis(25);

const KIND_FW: u8 = 0;
const KIND_BW: u8 = 1;
const KIND_RING: u8 = 2;
const KIND_REJECT: u8 = 255;

/// Which traffic class a link carries (one socket per class/direction).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// Forward activations, stage s → s+1.
    Fw,
    /// Backward gradients, stage s → s-1.
    Bw,
    /// DP all-gather ring hop, replica r → (r+1) % d.
    Ring,
}

impl LinkKind {
    fn code(self) -> u8 {
        match self {
            LinkKind::Fw => KIND_FW,
            LinkKind::Bw => KIND_BW,
            LinkKind::Ring => KIND_RING,
        }
    }

    fn parse(code: u8) -> Result<Self> {
        match code {
            KIND_FW => Ok(LinkKind::Fw),
            KIND_BW => Ok(LinkKind::Bw),
            KIND_RING => Ok(LinkKind::Ring),
            other => Err(crate::err!("unknown link kind {other} in hello frame")),
        }
    }

    fn label(self) -> &'static str {
        match self {
            LinkKind::Fw => "forward",
            LinkKind::Bw => "backward",
            LinkKind::Ring => "dp-ring",
        }
    }
}

/// Where every (replica, stage) process listens. Addresses are flattened
/// replica-major: index `replica * n_stages + stage`.
#[derive(Clone, Debug)]
pub struct TopologyPlan {
    pub n_stages: usize,
    pub dp_degree: usize,
    addrs: Vec<String>,
}

impl TopologyPlan {
    pub fn new(n_stages: usize, dp_degree: usize, addrs: Vec<String>) -> Result<Self> {
        crate::ensure!(n_stages >= 1 && dp_degree >= 1, "topology needs at least one process");
        crate::ensure!(
            addrs.len() == n_stages * dp_degree,
            "topology wants {} addresses ({} replicas x {} stages), got {}",
            n_stages * dp_degree,
            dp_degree,
            n_stages,
            addrs.len()
        );
        Ok(TopologyPlan { n_stages, dp_degree, addrs })
    }

    /// Parse the `--peers` list: comma-separated `host:port`, flattened
    /// replica-major (replica 0 stages 0..k, then replica 1, ...).
    pub fn parse(peers: &str, n_stages: usize, dp_degree: usize) -> Result<Self> {
        let addrs: Vec<String> = peers
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        Self::new(n_stages, dp_degree, addrs)
    }

    /// Listen address of the (replica, stage) process.
    pub fn addr(&self, replica: usize, stage: usize) -> &str {
        &self.addrs[replica * self.n_stages + stage]
    }
}

/// Timeouts + shaping for one process's link bring-up.
#[derive(Clone, Debug)]
pub struct SessionOpts {
    /// Applied to every registered data socket.
    pub shape: LinkShape,
    /// How long outbound connects retry before giving up (peers may not
    /// have bound yet — a retry loop is part of the protocol).
    pub connect_timeout: Duration,
    /// Read/write timeout on the blocking handshake exchanges, and the
    /// extra budget for inbound peers to show up.
    pub handshake_timeout: Duration,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts {
            shape: LinkShape::default(),
            connect_timeout: Duration::from_secs(10),
            handshake_timeout: Duration::from_secs(10),
        }
    }
}

/// One process's established, driver-registered link set. `None` where
/// the topology has no such link (edge stages, dp_degree 1).
pub struct StageSockets {
    pub fw_in: Option<TcpFrameRx>,
    pub fw_out: Option<TcpFrameTx>,
    pub bw_in: Option<TcpFrameRx>,
    pub bw_out: Option<TcpFrameTx>,
    pub ring_in: Option<TcpFrameRx>,
    pub ring_out: Option<TcpFrameTx>,
    /// Keep alive for the duration of the run; dropping it flushes and
    /// joins the I/O thread.
    pub driver: IoDriver,
}

struct Hello {
    kind: LinkKind,
    from: (usize, usize),
    to: (usize, usize),
    summary: String,
}

enum HelloMsg {
    Hello(Hello),
    Reject(String),
}

fn hello_bytes(kind: LinkKind, from: (usize, usize), to: (usize, usize), summary: &str) -> Vec<u8> {
    let mut h = FrameWriter::with_capacity(21);
    h.u32(SESSION_VERSION)
        .u8(kind.code())
        .u32(from.0 as u32)
        .u32(from.1 as u32)
        .u32(to.0 as u32)
        .u32(to.1 as u32);
    Frame::new(TAG_HELLO, h.finish(), summary.as_bytes().to_vec()).to_bytes()
}

fn reject_bytes(reason: &str) -> Vec<u8> {
    reject_session_bytes(0, 0, reason)
}

/// Session-scoped reject: same `TAG_HELLO`/`KIND_REJECT` wire shape as
/// the grid handshake's reject, with the from-fields carrying which
/// (session, request seq) is refused instead of grid coordinates. The
/// serving front end's admission gate sheds load with exactly these
/// frames, so a refused client gets a descriptive reason over the same
/// machinery a config-mismatched training peer would.
pub fn reject_session_bytes(session: u32, seq: u32, reason: &str) -> Vec<u8> {
    let mut h = FrameWriter::with_capacity(21);
    h.u32(SESSION_VERSION).u8(KIND_REJECT).u32(session).u32(seq).u32(0).u32(0);
    Frame::new(TAG_HELLO, h.finish(), reason.as_bytes().to_vec()).to_bytes()
}

/// A parsed session-scoped reject frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SessionReject {
    pub session: u32,
    pub seq: u32,
    pub reason: String,
}

/// Parse a frame as a session-scoped reject. `Ok(None)` when the frame
/// is a hello (or some other kind) rather than a reject; `Err` only on
/// malformed bytes.
pub fn decode_session_reject(bytes: &[u8]) -> Result<Option<SessionReject>> {
    let v = FrameView::parse(bytes)?;
    if v.tag() != TAG_HELLO {
        return Ok(None);
    }
    let mut r = FrameReader::new(v.header());
    let _version = r.u32()?;
    let kind = r.u8()?;
    if kind != KIND_REJECT {
        return Ok(None);
    }
    let session = r.u32()?;
    let seq = r.u32()?;
    Ok(Some(SessionReject {
        session,
        seq,
        reason: String::from_utf8_lossy(v.payload()).into_owned(),
    }))
}

fn decode_hello(bytes: &[u8]) -> Result<HelloMsg> {
    let v = FrameView::parse(bytes)?;
    crate::ensure!(
        v.tag() == TAG_HELLO,
        "handshake expected a hello frame, got tag {}",
        v.tag()
    );
    let mut r = FrameReader::new(v.header());
    let version = r.u32()?;
    let kind = r.u8()?;
    let from = (r.u32()? as usize, r.u32()? as usize);
    let to = (r.u32()? as usize, r.u32()? as usize);
    r.done()?;
    let text = String::from_utf8_lossy(v.payload()).into_owned();
    if kind == KIND_REJECT {
        return Ok(HelloMsg::Reject(text));
    }
    crate::ensure!(
        version == SESSION_VERSION,
        "session version mismatch: peer speaks v{version}, this build speaks v{SESSION_VERSION}"
    );
    Ok(HelloMsg::Hello(Hello { kind: LinkKind::parse(kind)?, from, to, summary: text }))
}

/// Blocking length-prefixed frame write (handshake phase only — data
/// sockets go through the non-blocking driver).
fn write_frame(sock: &mut TcpStream, bytes: &[u8]) -> Result<()> {
    sock.write_all(&(bytes.len() as u32).to_le_bytes())?;
    sock.write_all(bytes)?;
    sock.flush()?;
    Ok(())
}

/// Blocking length-prefixed frame read with a hard size cap.
fn read_frame(sock: &mut TcpStream) -> Result<Vec<u8>> {
    let mut prefix = [0u8; 4];
    sock.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    crate::ensure!(
        (7..=HELLO_MAX_BYTES).contains(&len),
        "handshake frame length {len} out of range"
    );
    let mut buf = vec![0u8; len];
    sock.read_exact(&mut buf)?;
    Ok(buf)
}

fn connect_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(crate::err!("connect to {addr} timed out: {e}"));
                }
                std::thread::sleep(RETRY_WAIT);
            }
        }
    }
}

/// The links this process initiates (it is the data sender) and the
/// links it expects inbound (it is the data receiver), as
/// `(kind, peer (replica, stage))` pairs.
fn link_sets(
    plan: &TopologyPlan,
    replica: usize,
    stage: usize,
) -> (Vec<(LinkKind, (usize, usize))>, Vec<(LinkKind, (usize, usize))>) {
    let (k, d) = (plan.n_stages, plan.dp_degree);
    let mut outbound = Vec::new();
    let mut expect = Vec::new();
    if stage + 1 < k {
        outbound.push((LinkKind::Fw, (replica, stage + 1)));
        expect.push((LinkKind::Bw, (replica, stage + 1)));
    }
    if stage > 0 {
        outbound.push((LinkKind::Bw, (replica, stage - 1)));
        expect.push((LinkKind::Fw, (replica, stage - 1)));
    }
    if d > 1 {
        outbound.push((LinkKind::Ring, ((replica + 1) % d, stage)));
        expect.push((LinkKind::Ring, ((replica + d - 1) % d, stage)));
    }
    (outbound, expect)
}

/// Bring up every link of the (replica, stage) process: bind its listen
/// address, connect + hello all outbound links, accept + validate +
/// answer the expected inbound set, then read the outbound replies and
/// register every socket with one I/O driver.
///
/// `summary` is the canonical config fingerprint (codec specs, schedule,
/// topology, seed); any disagreement between two peers fails the
/// handshake on both ends with the reason in the error chain.
pub fn establish(
    plan: &TopologyPlan,
    replica: usize,
    stage: usize,
    summary: &str,
    opts: &SessionOpts,
) -> Result<StageSockets> {
    let (k, d) = (plan.n_stages, plan.dp_degree);
    crate::ensure!(replica < d, "replica {replica} out of range (dp degree {d})");
    crate::ensure!(stage < k, "stage {stage} out of range ({k} stages)");
    let me = (replica, stage);
    let (outbound, expect) = link_sets(plan, replica, stage);

    let listener = TcpListener::bind(plan.addr(replica, stage))
        .with_context(|| format!("binding listen address {}", plan.addr(replica, stage)))?;
    listener.set_nonblocking(true)?;

    // Phase 1: connect all outbound links (peers may bind later — retry
    // until the deadline) and send hellos WITHOUT waiting for replies;
    // waiting here would deadlock two peers connecting to each other.
    let connect_deadline = Instant::now() + opts.connect_timeout;
    let mut out_socks = Vec::with_capacity(outbound.len());
    for &(kind, to) in &outbound {
        let mut sock = connect_retry(plan.addr(to.0, to.1), connect_deadline)
            .with_context(|| {
                format!("connecting the {} link to replica {} stage {}", kind.label(), to.0, to.1)
            })?;
        sock.set_nodelay(true).ok();
        sock.set_read_timeout(Some(opts.handshake_timeout))?;
        sock.set_write_timeout(Some(opts.handshake_timeout))?;
        write_frame(&mut sock, &hello_bytes(kind, me, to, summary))
            .with_context(|| format!("sending hello on the {} link", kind.label()))?;
        out_socks.push(sock);
    }

    // Phase 2: accept the expected inbound set, validating each hello
    // against (version, kind, peer coordinates, config summary) and
    // answering with our own hello — or a reject carrying the reason.
    let accept_deadline = Instant::now() + opts.connect_timeout + opts.handshake_timeout;
    let mut inbound: Vec<Option<TcpStream>> = expect.iter().map(|_| None).collect();
    while inbound.iter().any(Option::is_none) {
        let (mut sock, peer_addr) = match listener.accept() {
            Ok(pair) => pair,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                let have = inbound.iter().filter(|s| s.is_some()).count();
                crate::ensure!(
                    Instant::now() < accept_deadline,
                    "timed out waiting for inbound links: {have} of {} connected",
                    expect.len()
                );
                std::thread::sleep(RETRY_WAIT);
                continue;
            }
            Err(e) => return Err(crate::err!("accepting an inbound link failed: {e}")),
        };
        sock.set_nodelay(true).ok();
        sock.set_read_timeout(Some(opts.handshake_timeout))?;
        sock.set_write_timeout(Some(opts.handshake_timeout))?;
        let hello = match decode_hello(&read_frame(&mut sock).context("reading inbound hello")?)? {
            HelloMsg::Hello(h) => h,
            HelloMsg::Reject(reason) => {
                crate::bail!("peer at {peer_addr} rejected the session: {reason}")
            }
        };
        if hello.summary != summary {
            let reason = format!(
                "config mismatch: this process runs [{summary}], peer replica {} stage {} \
                 runs [{}]",
                hello.from.0, hello.from.1, hello.summary
            );
            let _ = write_frame(&mut sock, &reject_bytes(&reason));
            crate::bail!("{reason}");
        }
        let slot = expect
            .iter()
            .position(|&(kind, from)| hello.to == me && hello.kind == kind && hello.from == from);
        match slot {
            Some(i) if inbound[i].is_none() => {
                write_frame(&mut sock, &hello_bytes(hello.kind, me, hello.from, summary))
                    .context("answering inbound hello")?;
                inbound[i] = Some(sock);
            }
            _ => {
                let reason = format!(
                    "unexpected {} link from replica {} stage {} to replica {} stage {}",
                    hello.kind.label(),
                    hello.from.0,
                    hello.from.1,
                    hello.to.0,
                    hello.to.1
                );
                let _ = write_frame(&mut sock, &reject_bytes(&reason));
                crate::bail!("{reason}");
            }
        }
    }

    // Phase 3: collect the replies to our outbound hellos.
    for (sock, &(kind, to)) in out_socks.iter_mut().zip(&outbound) {
        let reply = decode_hello(
            &read_frame(sock)
                .with_context(|| format!("reading hello reply on the {} link", kind.label()))?,
        )?;
        match reply {
            HelloMsg::Hello(h) => {
                crate::ensure!(
                    h.kind == kind && h.from == to && h.to == me,
                    "hello reply on the {} link came from replica {} stage {}, expected \
                     replica {} stage {}",
                    kind.label(),
                    h.from.0,
                    h.from.1,
                    to.0,
                    to.1
                );
                crate::ensure!(
                    h.summary == summary,
                    "config mismatch on the {} link: this process runs [{summary}], peer \
                     runs [{}]",
                    kind.label(),
                    h.summary
                );
            }
            HelloMsg::Reject(reason) => {
                crate::bail!(
                    "peer replica {} stage {} rejected the {} link: {reason}",
                    to.0,
                    to.1,
                    kind.label()
                );
            }
        }
    }

    // Phase 4: hand every socket to one I/O driver. Each data link is
    // simplex: the initiator keeps the tx half, the acceptor keeps rx.
    let driver = IoDriver::new();
    let mut socks = StageSockets {
        fw_in: None,
        fw_out: None,
        bw_in: None,
        bw_out: None,
        ring_in: None,
        ring_out: None,
        driver,
    };
    for (sock, &(kind, _)) in out_socks.into_iter().zip(&outbound) {
        let (tx, _rx) = socks.driver.register(sock, opts.shape.clone())?;
        match kind {
            LinkKind::Fw => socks.fw_out = Some(tx),
            LinkKind::Bw => socks.bw_out = Some(tx),
            LinkKind::Ring => socks.ring_out = Some(tx),
        }
    }
    for (sock, &(kind, _)) in inbound.into_iter().zip(&expect) {
        let sock = sock.expect("accept loop filled every slot");
        let (_tx, rx) = socks.driver.register(sock, opts.shape.clone())?;
        match kind {
            LinkKind::Fw => socks.fw_in = Some(rx),
            LinkKind::Bw => socks.bw_in = Some(rx),
            LinkKind::Ring => socks.ring_in = Some(rx),
        }
    }
    Ok(socks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{FrameRx, FrameTx};
    use std::net::TcpListener;

    fn free_addrs(n: usize) -> Vec<String> {
        let holds: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind")).collect();
        holds
            .iter()
            .map(|l| format!("127.0.0.1:{}", l.local_addr().expect("addr").port()))
            .collect()
    }

    #[test]
    fn plan_parses_and_indexes_replica_major() {
        let p = TopologyPlan::parse("a:1, b:2,c:3,d:4", 2, 2).expect("parse");
        assert_eq!(p.addr(0, 0), "a:1");
        assert_eq!(p.addr(0, 1), "b:2");
        assert_eq!(p.addr(1, 0), "c:3");
        assert_eq!(p.addr(1, 1), "d:4");
        assert!(TopologyPlan::parse("a:1,b:2", 3, 1).is_err());
    }

    #[test]
    fn hello_roundtrips_and_rejects_bad_version() {
        let b = hello_bytes(LinkKind::Ring, (1, 2), (0, 2), "spec=x");
        match decode_hello(&b).expect("decode") {
            HelloMsg::Hello(h) => {
                assert_eq!(h.kind, LinkKind::Ring);
                assert_eq!(h.from, (1, 2));
                assert_eq!(h.to, (0, 2));
                assert_eq!(h.summary, "spec=x");
            }
            HelloMsg::Reject(r) => panic!("unexpected reject: {r}"),
        }
        match decode_hello(&reject_bytes("nope")).expect("decode reject") {
            HelloMsg::Reject(r) => assert_eq!(r, "nope"),
            HelloMsg::Hello(_) => panic!("expected reject"),
        }
        // corrupt the version field: must be a descriptive error
        let mut bad = hello_bytes(LinkKind::Fw, (0, 0), (0, 1), "s");
        bad[7] ^= 0x40; // first header byte (version lo) lives after the prelude
        let err = decode_hello(&bad).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
    }

    #[test]
    fn session_scoped_reject_roundtrips_and_back_compat() {
        let b = reject_session_bytes(42, 7, "queue full");
        let r = decode_session_reject(&b).expect("parse").expect("is a reject");
        assert_eq!(r, SessionReject { session: 42, seq: 7, reason: "queue full".into() });
        // the grid handshake still reads it as a plain reject
        match decode_hello(&b).expect("decode") {
            HelloMsg::Reject(reason) => assert_eq!(reason, "queue full"),
            HelloMsg::Hello(_) => panic!("expected reject"),
        }
        // a hello is not a reject — and not an error either
        let hello = hello_bytes(LinkKind::Fw, (0, 0), (0, 1), "s");
        assert!(decode_session_reject(&hello).expect("parse").is_none());
    }

    #[test]
    fn two_stage_session_establishes_and_moves_frames() {
        let plan = TopologyPlan::new(2, 1, free_addrs(2)).expect("plan");
        let p0 = plan.clone();
        let p1 = plan.clone();
        let t0 = std::thread::spawn(move || {
            establish(&p0, 0, 0, "job", &SessionOpts::default()).expect("stage 0 establish")
        });
        let t1 = std::thread::spawn(move || {
            establish(&p1, 0, 1, "job", &SessionOpts::default()).expect("stage 1 establish")
        });
        let mut s0 = t0.join().expect("stage 0 thread");
        let mut s1 = t1.join().expect("stage 1 thread");
        // stage 0: fw out + bw in; stage 1: fw in + bw out
        let frame = Frame::new(TAG_HELLO, vec![1], vec![2, 3]).to_bytes();
        s0.fw_out.as_mut().expect("fw_out").send(frame.clone()).expect("send fw");
        assert_eq!(s1.fw_in.as_mut().expect("fw_in").recv().expect("recv fw"), frame);
        s1.bw_out.as_mut().expect("bw_out").send(frame.clone()).expect("send bw");
        assert_eq!(s0.bw_in.as_mut().expect("bw_in").recv().expect("recv bw"), frame);
        assert!(s0.ring_out.is_none() && s0.ring_in.is_none());
    }

    #[test]
    fn config_mismatch_fails_both_sides_with_the_reason() {
        let plan = TopologyPlan::new(2, 1, free_addrs(2)).expect("plan");
        let p0 = plan.clone();
        let p1 = plan.clone();
        let short = SessionOpts {
            connect_timeout: Duration::from_secs(5),
            handshake_timeout: Duration::from_secs(5),
            ..SessionOpts::default()
        };
        let o0 = short.clone();
        let o1 = short;
        let t0 = std::thread::spawn(move || establish(&p0, 0, 0, "job-a", &o0).err());
        let t1 = std::thread::spawn(move || establish(&p1, 0, 1, "job-b", &o1).err());
        let e0 = t0.join().expect("stage 0 thread");
        let e1 = t1.join().expect("stage 1 thread");
        for (who, e) in [("stage 0", e0), ("stage 1", e1)] {
            let e = e.unwrap_or_else(|| panic!("{who} should have failed"));
            assert!(e.to_string().contains("config mismatch"), "{who}: {e}");
        }
    }
}
