//! The synchronous pipeline-parallel training loop (paper Algorithm 2,
//! K-stage generalization of Appendix A.1), executing the AOT stage
//! artifacts over the PJRT runtime with compressed boundaries.
//!
//! Numerics are *exact* for the distributed algorithm: each boundary
//! applies the same compression a multi-machine deployment would, the
//! receiver consumes the reconstructed message buffer, and backward
//! gradients are quantized before crossing back. What is simulated is
//! *time*: per-step wall time on the target network comes from the
//! event-driven `pipeline::sim` fed with measured compute times and the
//! exact wire bytes produced by the codecs (the byte counts come from the
//! real packed messages, not estimates).

use crate::util::error::{Context, Result};

use crate::codec::quantizer::Rounding;
use crate::codec::registry::BuildCtx;
use crate::config::TrainConfig;
use crate::coordinator::boundary::{BackwardBoundary, ForwardBoundary};
use crate::coordinator::dp::DpGroup;
use crate::data::{Batch, Dataset, EpochSampler, Task};
use crate::metrics::Recorder;
use crate::optim::{AdamW, LrSchedule};
use crate::pipeline::{PipelineSim, SimConfig, StageTimes};
use crate::runtime::{Engine, Manifest, QuantRuntime, StageInput, StageRuntime};
use crate::store::{ActivationStore, DiskStore, MemStore, QuantizedMemStore};
use crate::util::stats::Ema;

/// Fig. 1b probe: running averages of |activation| and |delta|.
#[derive(Clone, Debug, Default)]
pub struct Probe {
    pub rows: Vec<(usize, f64, f64)>, // (step, mean|a|, mean|delta|)
    acc_a: f64,
    acc_d: f64,
    n: usize,
}

impl Probe {
    fn push(&mut self, a: f64, d: f64) {
        self.acc_a += a;
        self.acc_d += d;
        self.n += 1;
    }
    fn flush(&mut self, step: usize) {
        if self.n > 0 {
            self.rows.push((step, self.acc_a / self.n as f64, self.acc_d / self.n as f64));
            self.acc_a = 0.0;
            self.acc_d = 0.0;
            self.n = 0;
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub steps: usize,
    pub comm_bytes: u64,
    pub sim_time_s: f64,
    pub final_train_loss: f64,
    pub final_eval_loss: f64,
    pub buffer_bytes: u64,
}

pub struct Trainer {
    pub cfg: TrainConfig,
    pub man: Manifest,
    stages: Vec<StageRuntime>,
    fw_bounds: Vec<ForwardBoundary>,
    bw_bounds: Vec<BackwardBoundary>,
    opts: Vec<AdamW>,
    schedule: LrSchedule,
    pub recorder: Recorder,
    pub probe: Probe,
    dp: Option<DpGroup>,
    // measured per-stage compute times (seconds, EMA)
    fwd_time: Vec<Ema>,
    bwd_time: Vec<Ema>,
    step_count: usize,
    pub use_hlo_adamw: bool,
    eval_every_steps: usize,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let engine = Engine::cpu()?;
        Self::with_engine(cfg, engine)
    }

    pub fn with_engine(cfg: TrainConfig, engine: Engine) -> Result<Self> {
        let man = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
        let k = man.n_stages()?;
        let mut stages = Vec::with_capacity(k);
        for s in 0..k {
            stages.push(StageRuntime::load(&engine, &man, s)
                .with_context(|| format!("loading stage {s}"))?);
        }
        let hlo = if cfg.hlo_codec {
            Some(std::sync::Arc::new(QuantRuntime::load(&engine, &man)?))
        } else {
            None
        };
        let el = man.example_len()?;
        let rounding =
            if cfg.stochastic_rounding { Rounding::Stochastic } else { Rounding::Nearest };
        let mut fw_bounds = Vec::new();
        let mut bw_bounds = Vec::new();
        for b in 0..k.saturating_sub(1) {
            // buffers keyed (replica-shard, example): with dp, each
            // replica trains a disjoint shard, so one store per boundary
            // still keys uniquely by example id. The registry asks the
            // factory for one store per codec half ("enc"/"dec") so the
            // sender and receiver replicas share nothing but the frames.
            let mut mk_store = |role: &str| -> Result<Box<dyn ActivationStore>> {
                if cfg.m_bits.is_some() && cfg.store != "quant" {
                    return Ok(Box::new(QuantizedMemStore::new(el, cfg.m_bits.unwrap())));
                }
                Ok(match cfg.store.as_str() {
                    "mem" => Box::new(MemStore::new(el)),
                    "disk" => {
                        let dir = std::env::temp_dir()
                            .join(format!("aqsgd_m_{}_{b}_{role}", std::process::id()));
                        Box::new(DiskStore::new(dir, el)?)
                    }
                    "quant" => Box::new(QuantizedMemStore::new(el, cfg.m_bits.unwrap_or(8))),
                    other => crate::bail!("unknown store {other:?} (mem|disk|quant)"),
                })
            };
            let (fw_enc, fw_dec) = cfg.compression.fw.build_pair(&mut BuildCtx {
                example_len: el,
                rounding,
                seed: 0xB0D1 + b as u64,
                ns: b as u32,
                hlo: hlo.clone(),
                mk_store: &mut mk_store,
            })?;
            let mut mk_bw_store = |role: &str| -> Result<Box<dyn ActivationStore>> {
                mk_store(&format!("bw_{role}"))
            };
            let (bw_enc, bw_dec) = cfg.compression.bw.build_pair(&mut BuildCtx {
                example_len: el,
                rounding,
                seed: 0xBACC + b as u64,
                ns: b as u32,
                hlo: hlo.clone(),
                mk_store: &mut mk_bw_store,
            })?;
            fw_bounds.push(ForwardBoundary::new(b as u32, el, fw_enc, fw_dec));
            bw_bounds.push(BackwardBoundary::new(el, bw_enc, bw_dec));
        }
        let opts = stages.iter().map(|s| AdamW::new(s.n_params)).collect();
        let schedule = LrSchedule {
            base_lr: cfg.lr,
            warmup_steps: cfg.warmup_steps,
            total_steps: cfg.total_steps,
        };
        let dp = if cfg.dp_degree > 1 {
            let sizes: Vec<usize> = stages.iter().map(|s| s.n_params).collect();
            Some(DpGroup::new(
                cfg.dp_degree,
                &cfg.dp_codec,
                &sizes,
                rounding,
                cfg.seed ^ 0xD9,
            )?)
        } else {
            None
        };
        let label = format!("{} {}", cfg.model, cfg.compression.label());
        Ok(Trainer {
            recorder: Recorder::new(label),
            probe: Probe::default(),
            fwd_time: (0..k).map(|_| Ema::new(0.2)).collect(),
            bwd_time: (0..k).map(|_| Ema::new(0.2)).collect(),
            cfg,
            man,
            stages,
            fw_bounds,
            bw_bounds,
            opts,
            schedule,
            dp,
            step_count: 0,
            use_hlo_adamw: false,
            eval_every_steps: usize::MAX,
        })
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    pub fn set_eval_every(&mut self, steps: usize) {
        self.eval_every_steps = steps;
    }

    /// Run one microbatch through the pipeline: forward with boundary
    /// compression, loss+backward with gradient quantization. Adds the
    /// per-stage gradients into `grad_acc`. Returns (loss, fw wire bytes
    /// per boundary message, bw wire bytes of the first boundary) — both
    /// byte counts read off the actual frames.
    fn run_microbatch(
        &mut self,
        batch: &Batch,
        grad_acc: &mut [Vec<f32>],
    ) -> Result<(f32, Vec<u64>, u64)> {
        let k = self.stages.len();
        // cached stage inputs for the backward pass (stage 0: tokens)
        let mut hidden_inputs: Vec<Vec<f32>> = Vec::with_capacity(k.saturating_sub(1));
        let mut fw_bytes = Vec::with_capacity(k.saturating_sub(1));

        // ---- forward ----
        let mut x: Vec<f32> = Vec::new();
        for s in 0..k - 1 {
            let t0 = std::time::Instant::now();
            let h = if s == 0 {
                self.stages[0].forward(&StageInput::Tokens(&batch.tokens))?
            } else {
                self.stages[s].forward(&StageInput::Hidden(&x))?
            };
            self.fwd_time[s].update(t0.elapsed().as_secs_f64());
            let (recv, stats) = self.fw_bounds[s].transfer(&batch.example_ids, &h)?;
            self.probe.push(stats.mean_abs_act, stats.mean_abs_delta);
            self.recorder.comm_bytes += stats.wire_bytes;
            fw_bytes.push(stats.wire_bytes);
            hidden_inputs.push(recv.clone());
            x = recv;
        }

        // ---- last stage: loss + backward ----
        let t0 = std::time::Instant::now();
        let last = k - 1;
        let (loss, gp_last, mut gx) = if k == 1 {
            let (l, gp, gx) =
                self.stages[0].loss_backward(&StageInput::Tokens(&batch.tokens), &batch.targets)?;
            (l, gp, gx)
        } else {
            self.stages[last]
                .loss_backward(&StageInput::Hidden(&x), &batch.targets)?
        };
        self.bwd_time[last].update(t0.elapsed().as_secs_f64());
        for (a, g) in grad_acc[last].iter_mut().zip(&gp_last) {
            *a += g;
        }

        // ---- backward through earlier stages ----
        let mut bw0_bytes = 0u64;
        for s in (0..k.saturating_sub(1)).rev() {
            let g_out = gx.take().context("missing boundary gradient")?;
            let (g_recv, bytes) = self.bw_bounds[s].transfer(&batch.example_ids, &g_out)?;
            self.recorder.comm_bytes += bytes;
            if s == 0 {
                bw0_bytes = bytes;
            }
            let t0 = std::time::Instant::now();
            let input_owned;
            let input = if s == 0 {
                StageInput::Tokens(&batch.tokens)
            } else {
                input_owned = std::mem::take(&mut hidden_inputs[s - 1]);
                StageInput::Hidden(&input_owned)
            };
            let (gp, gx_next) = self.stages[s].backward(&input, &g_recv)?;
            self.bwd_time[s].update(t0.elapsed().as_secs_f64());
            for (a, g) in grad_acc[s].iter_mut().zip(&gp) {
                *a += g;
            }
            gx = gx_next;
        }
        Ok((loss, fw_bytes, bw0_bytes))
    }

    /// One optimizer step over `n_micro` microbatches (one replica) or
    /// `dp_degree` shards of `n_micro` microbatches each.
    fn train_step(&mut self, shards: &[&[Batch]]) -> Result<f64> {
        let k = self.stages.len();
        let mut all_fw_bytes: Vec<u64> = Vec::new();
        let mut max_bw_bytes = 0u64;
        let mut loss_sum = 0f64;
        let mut n_micro_total = 0usize;

        let mut replica_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(shards.len());
        for shard in shards {
            let mut grads: Vec<Vec<f32>> =
                self.stages.iter().map(|s| vec![0f32; s.n_params]).collect();
            for batch in shard.iter() {
                let (loss, fw_bytes, bw_bytes) = self.run_microbatch(batch, &mut grads)?;
                loss_sum += loss as f64;
                n_micro_total += 1;
                // per-boundary bytes of the first boundary represent the
                // message size for the step-time simulation
                if let Some(&b) = fw_bytes.first() {
                    all_fw_bytes.push(b);
                }
                max_bw_bytes = max_bw_bytes.max(bw_bytes);
            }
            let inv = 1.0 / shard.len() as f32;
            for g in grads.iter_mut() {
                for v in g.iter_mut() {
                    *v *= inv;
                }
            }
            replica_grads.push(grads);
        }

        // ---- data-parallel reduction (framed codec ring, measured) ----
        let (mean_grads, dp_wire) = match &mut self.dp {
            Some(dp) => {
                let (m, w) = dp.reduce(&replica_grads)?;
                self.recorder.comm_bytes += w.total_bytes;
                (m, w)
            }
            None => (replica_grads.pop().unwrap(), crate::coordinator::dp::DpWire::default()),
        };

        // ---- optimizer ----
        self.step_count += 1;
        let lr = self.schedule.lr(self.step_count);
        for s in 0..k {
            if self.use_hlo_adamw {
                self.stages[s].adamw_step_hlo(&mean_grads[s], self.step_count, lr)?;
                self.opts[s].step += 1;
            } else {
                let params = &mut self.stages[s].params;
                self.opts[s].update(params, &mean_grads[s], lr as f32);
            }
        }

        // ---- simulated step time on the target network ----
        self.recorder.sim_time_s += self.simulate_step_time(&all_fw_bytes, max_bw_bytes, dp_wire);

        Ok(loss_sum / n_micro_total.max(1) as f64)
    }

    /// Build the event simulation for this step from measured compute
    /// times + actual wire bytes (all three traffic classes come
    /// straight from the frames this step produced — nothing is
    /// re-derived).
    fn simulate_step_time(
        &self,
        fw_bytes: &[u64],
        bw_bytes: u64,
        dp_wire: crate::coordinator::dp::DpWire,
    ) -> f64 {
        let k = self.stages.len();
        let n_micro = fw_bytes.len().max(1);
        let stage_times: Vec<StageTimes> = (0..k)
            .map(|s| StageTimes {
                fwd_s: self.fwd_time[s]
                    .get()
                    .unwrap_or(self.bwd_time[s].get().unwrap_or(0.01) / 3.0),
                bwd_s: self.bwd_time[s].get().unwrap_or(0.01),
            })
            .collect();
        let sim = SimConfig {
            n_stages: k,
            n_micro,
            stage_times,
            fw_bytes: fw_bytes.to_vec(),
            bw_bytes,
            bandwidth_bps: self.cfg.bandwidth_bps,
            link_bandwidths: None,
            latency_s: self.cfg.latency_s,
            schedule: self.cfg.schedule,
            step_overhead_s: 0.0,
        };
        let mut t = if k > 1 || n_micro > 0 { PipelineSim::run(&sim).step_time_s } else { 0.0 };
        if self.cfg.dp_degree > 1 {
            // per-stage rings run concurrently; the largest frame gates
            // each of the ring's serialized hop rounds
            t += PipelineSim::ring_allgather_time(
                dp_wire.max_frame_bytes,
                self.cfg.dp_degree,
                self.cfg.bandwidth_bps,
                self.cfg.latency_s,
            );
        }
        t
    }

    /// Evaluation loss over a dataset (FP32 boundaries — measures model
    /// quality, not wire effects).
    pub fn eval(&mut self, data: &Dataset) -> Result<f64> {
        let b = self.man.micro_batch()?;
        let mut sampler = EpochSampler::new(data.len(), b, 1234, false);
        let batches = sampler.epoch_batches(data);
        let k = self.stages.len();
        let mut loss_sum = 0f64;
        let mut n = 0usize;
        for batch in &batches {
            let mut x: Vec<f32> = Vec::new();
            for s in 0..k - 1 {
                x = if s == 0 {
                    self.stages[0].forward(&StageInput::Tokens(&batch.tokens))?
                } else {
                    self.stages[s].forward(&StageInput::Hidden(&x))?
                };
            }
            let loss = if k == 1 {
                self.stages[0].eval_loss(&StageInput::Tokens(&batch.tokens), &batch.targets)?
            } else {
                self.stages[k - 1].eval_loss(&StageInput::Hidden(&x), &batch.targets)?
            };
            loss_sum += loss as f64;
            n += 1;
        }
        Ok(loss_sum / n.max(1) as f64)
    }

    /// Full training run. Returns summary stats.
    pub fn train(
        &mut self,
        train_data: &Dataset,
        eval_data: Option<&Dataset>,
    ) -> Result<TrainStats> {
        let all: Vec<usize> = (0..train_data.len()).collect();
        self.train_subset(train_data, &all, eval_data)
    }

    /// Train on an index view into `train_data` — the shard path (split
    /// learning, federated rounds) where many clients hold slices of one
    /// parent dataset. Only the listed rows are sampled; nothing is
    /// cloned out of the parent.
    pub fn train_subset(
        &mut self,
        train_data: &Dataset,
        subset: &[usize],
        eval_data: Option<&Dataset>,
    ) -> Result<TrainStats> {
        crate::ensure!(
            (train_data.task == Task::Lm) == (self.man.task()? == "lm"),
            "dataset task does not match model task"
        );
        if let Some(&bad) = subset.iter().find(|&&i| i >= train_data.len()) {
            crate::bail!(
                "subset index {bad} out of range for a {}-example dataset",
                train_data.len()
            );
        }
        let micro_b = self.man.micro_batch()?;
        let shard_examples = self.cfg.n_micro * micro_b;
        let total_needed = shard_examples * self.cfg.dp_degree;
        crate::ensure!(
            subset.len() >= total_needed,
            "dataset too small: {} examples < {total_needed} per step",
            subset.len()
        );
        let mut sampler = EpochSampler::subset(
            subset.to_vec(),
            micro_b,
            self.cfg.seed,
            self.cfg.shuffle_every_epoch,
        );
        let micro_per_step = self.cfg.n_micro * self.cfg.dp_degree;
        'epochs: for epoch in 0..self.cfg.epochs {
            let batches = sampler.epoch_batches(train_data);
            for step_batches in batches.chunks_exact(micro_per_step) {
                let shards: Vec<&[Batch]> =
                    step_batches.chunks(self.cfg.n_micro).collect();
                let loss = self.train_step(&shards)?;
                self.recorder.record(self.step_count, epoch, loss);
                self.probe.flush(self.step_count);
                if self.step_count % self.eval_every_steps == 0 {
                    if let Some(ed) = eval_data {
                        let el = self.eval(ed)?;
                        eprintln!(
                            "[{}] step {} epoch {} train {:.4} eval {:.4}",
                            self.recorder.label, self.step_count, epoch, loss, el
                        );
                    }
                }
                if self.step_count >= self.cfg.total_steps {
                    break 'epochs;
                }
            }
        }
        let final_eval = match eval_data {
            Some(ed) => self.eval(ed)?,
            None => f64::NAN,
        };
        Ok(TrainStats {
            steps: self.step_count,
            comm_bytes: self.recorder.comm_bytes,
            sim_time_s: self.recorder.sim_time_s,
            final_train_loss: self.recorder.final_loss(),
            final_eval_loss: final_eval,
            buffer_bytes: self.fw_bounds.iter().map(|b| b.resident_bytes()).sum(),
        })
    }

    /// Direct access for tests/examples.
    pub fn stage(&self, i: usize) -> &StageRuntime {
        &self.stages[i]
    }
    pub fn stage_mut(&mut self, i: usize) -> &mut StageRuntime {
        &mut self.stages[i]
    }
    pub fn steps_done(&self) -> usize {
        self.step_count
    }

    /// Optimizer moments of stage `i` (native AdamW state — the default
    /// update path; the HLO AdamW keeps its state in the StageRuntime).
    pub fn opt_state(&self, i: usize) -> (&[f32], &[f32]) {
        (&self.opts[i].m, &self.opts[i].v)
    }
    pub fn set_opt_state(&mut self, i: usize, m: Vec<f32>, v: Vec<f32>) {
        assert_eq!(m.len(), self.stages[i].n_params);
        assert_eq!(v.len(), self.stages[i].n_params);
        self.opts[i].m = m;
        self.opts[i].v = v;
    }

    /// Restore the global step counter (checkpoint resume).
    pub fn restore_step(&mut self, step: usize) {
        self.step_count = step;
        for o in self.opts.iter_mut() {
            o.step = step;
        }
    }
}
