//! Split learning (paper Appendix H.6): N clients hold the cut layer
//! (stage 0) and their private non-IID data shards; the server holds the
//! remaining stages. In each communication round, clients train
//! sequentially for a few local epochs, exchanging (compressed)
//! activations and activation-gradients at the cut — exactly the
//! pipeline-boundary path, so AQ-SGD drops in unchanged: message buffers
//! are keyed by (boundary, example id) and example ids are globally
//! unique across clients.
//!
//! Substitution note (DESIGN.md §3): the paper uses ResNet34 on CIFAR;
//! we use the transformer classifier on the synthetic QNLI-like task and
//! report eval *loss* (no accuracy head is exported).

use crate::util::error::Result;

use crate::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::data::cls::dirichlet_split;
use crate::data::Dataset;

pub struct SplitRound {
    pub round: usize,
    pub eval_loss: f64,
    pub comm_bytes: u64,
    pub sim_time_s: f64,
}

pub struct SplitLearning {
    pub trainer: Trainer,
    /// The undivided training split; clients hold index views into it.
    train: Dataset,
    /// Per-client shards as indices into `train` — a view, not a copy,
    /// so N clients over an M-example corpus cost M resident examples,
    /// not ~2M (message buffers still key on the globally-unique ids).
    shards: Vec<Vec<usize>>,
    eval: Dataset,
    local_epochs: usize,
}

impl SplitLearning {
    /// Partition `data` across `n_clients` with Dirichlet(alpha) skew.
    pub fn new(
        mut cfg: TrainConfig,
        data: Dataset,
        n_clients: usize,
        alpha: f64,
        local_epochs: usize,
    ) -> Result<Self> {
        let (train, eval) = data.split_eval(0.15);
        let shards = dirichlet_split(&train, n_clients, alpha, cfg.seed + 17);
        // sequential local training: one microbatch per step keeps even
        // tiny shards trainable
        cfg.n_micro = 1;
        cfg.epochs = local_epochs;
        let trainer = Trainer::new(cfg)?;
        Ok(SplitLearning { trainer, train, shards, eval, local_epochs })
    }

    /// One communication round: every client trains `local_epochs` on its
    /// shard (sequentially, like the paper's protocol).
    pub fn round(&mut self, round: usize) -> Result<SplitRound> {
        let micro_b = self.trainer.man.micro_batch()?;
        for c in 0..self.shards.len() {
            if self.shards[c].len() < micro_b {
                continue; // client with too little data sits the round out
            }
            self.trainer.cfg.epochs = self.local_epochs;
            self.trainer.train_subset(&self.train, &self.shards[c], None)?;
        }
        let eval_loss = self.trainer.eval(&self.eval)?;
        Ok(SplitRound {
            round,
            eval_loss,
            comm_bytes: self.trainer.recorder.comm_bytes,
            sim_time_s: self.trainer.recorder.sim_time_s,
        })
    }

    pub fn n_clients(&self) -> usize {
        self.shards.len()
    }
}
