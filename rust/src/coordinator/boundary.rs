//! Pipeline-boundary compression.
//!
//! The unit of ownership is an *endpoint half*: a [`BoundarySender`]
//! (encoder side) or [`BoundaryReceiver`] (decoder side), each wrapping
//! one half of a registry-built [`BoundaryCodec`] pair plus the
//! shape-validation every transfer needs. The two halves share *no*
//! state — only [`Frame`]s cross between them — so Algorithm 2's
//! sender/receiver replica invariant holds by construction (pinned by
//! `tests/prop_frames.rs`). The threaded executor (`pipeline::exec`)
//! moves each half onto its stage's worker thread; the single-process
//! trainer composes the same two halves back into a [`ForwardBoundary`] /
//! [`BackwardBoundary`], so both execution modes run the identical
//! encode/validate/decode sequence.

use crate::codec::{BoundaryCodec, Frame, FrameBuf, FrameView};
use crate::util::error::Result;

/// What a transfer did: the receiver-side activation plus accounting.
#[derive(Clone, Debug, Default)]
pub struct TransferStats {
    /// serialized frame size — `Frame::wire_bytes()`, i.e. measured from
    /// the actual header/payload buffers
    pub wire_bytes: u64,
    /// mean |activation| over the message (Fig. 1b probe)
    pub mean_abs_act: f64,
    /// mean |delta| (AQ-SGD only; equals mean_abs_act otherwise)
    pub mean_abs_delta: f64,
    pub first_visits: usize,
}

/// Encoder endpoint of one directed boundary: validates the outgoing
/// batch shape, runs the codec, and reads the wire accounting off the
/// produced frame.
pub struct BoundarySender {
    pub boundary_id: u32,
    /// elements per example record — validates batch shape on every
    /// transfer, codec-independent
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
}

impl BoundarySender {
    pub fn new(boundary_id: u32, example_len: usize, enc: Box<dyn BoundaryCodec>) -> Self {
        BoundarySender { boundary_id, example_len, enc }
    }

    /// Encode activation `a` ([B, S, D] row-major, one record per example
    /// id) into its wire frame. Returns (frame, stats). Allocating form
    /// of [`encode_into`](Self::encode_into).
    pub fn encode(&mut self, example_ids: &[u64], a: &[f32]) -> Result<(Frame, TransferStats)> {
        let mut buf = FrameBuf::new();
        let stats = self.encode_into(example_ids, a, &mut buf)?;
        Ok((buf.to_frame(), stats))
    }

    /// Scratch-path encode: build the serialized frame in the caller's
    /// reusable [`FrameBuf`] (steady-state allocation-free for the
    /// registered codecs). Returns the transfer stats, whose wire bytes
    /// are the built image's length.
    pub fn encode_into(
        &mut self,
        example_ids: &[u64],
        a: &[f32],
        out: &mut FrameBuf,
    ) -> Result<TransferStats> {
        crate::ensure!(
            a.len() == example_ids.len() * self.example_len,
            "boundary {}: activation length {} != {} ids x {} elements",
            self.boundary_id,
            a.len(),
            example_ids.len(),
            self.example_len
        );
        let mean_abs_act = crate::util::stats::mean_abs(a);
        self.enc.encode_into(example_ids, a, out)?;
        let es = self.enc.take_stats();
        Ok(TransferStats {
            wire_bytes: out.wire_bytes(),
            mean_abs_act,
            mean_abs_delta: es.mean_abs_delta.unwrap_or(mean_abs_act),
            first_visits: es.first_visits,
        })
    }

    /// Encoder-side persistent state (message buffers), i.e. what one
    /// replica of this boundary keeps resident.
    pub fn state_bytes(&self) -> u64 {
        self.enc.state_bytes()
    }

    pub fn label(&self) -> String {
        self.enc.label()
    }

    /// Worker count for the codec's chunked kernels on large messages
    /// (throughput only — frame bytes are identical at any count).
    pub fn set_workers(&mut self, threads: usize) {
        self.enc.set_workers(threads);
    }
}

/// Decoder endpoint of one directed boundary: reconstructs the
/// receiver-side activation from a frame and validates the result shape.
pub struct BoundaryReceiver {
    pub boundary_id: u32,
    example_len: usize,
    dec: Box<dyn BoundaryCodec>,
}

impl BoundaryReceiver {
    pub fn new(boundary_id: u32, example_len: usize, dec: Box<dyn BoundaryCodec>) -> Self {
        BoundaryReceiver { boundary_id, example_len, dec }
    }

    /// Elements per example record this endpoint validates against.
    pub fn example_len(&self) -> usize {
        self.example_len
    }

    /// Reconstruct the activation for `example_ids` from `frame`,
    /// advancing any receiver-replica codec state.
    pub fn decode(&mut self, example_ids: &[u64], frame: &Frame) -> Result<Vec<f32>> {
        self.decode_view(example_ids, &frame.view())
    }

    /// Like [`decode`](Self::decode), from a borrowed [`FrameView`]
    /// (what the serialized receive path parses).
    pub fn decode_view(&mut self, example_ids: &[u64], frame: &FrameView<'_>) -> Result<Vec<f32>> {
        let mut out = vec![0f32; example_ids.len() * self.example_len];
        self.decode_into(example_ids, frame, &mut out)?;
        Ok(out)
    }

    /// Scratch-path decode into a caller-owned buffer of the expected
    /// activation shape (`ids × example_len`); steady-state
    /// allocation-free for the registered codecs.
    pub fn decode_into(
        &mut self,
        example_ids: &[u64],
        frame: &FrameView<'_>,
        out: &mut [f32],
    ) -> Result<()> {
        crate::ensure!(
            out.len() == example_ids.len() * self.example_len,
            "boundary {}: decode buffer holds {} elements for {} ids x {} elements",
            self.boundary_id,
            out.len(),
            example_ids.len(),
            self.example_len
        );
        self.dec.decode_into(example_ids, frame, out)
    }

    /// Receiver-side persistent state (the buffer replica).
    pub fn state_bytes(&self) -> u64 {
        self.dec.state_bytes()
    }

    /// Worker count for the codec's chunked kernels on large messages
    /// (throughput only — reconstruction is identical at any count).
    pub fn set_workers(&mut self, threads: usize) {
        self.dec.set_workers(threads);
    }
}

// ---------------------------------------------------------------------------

/// Forward boundary between stage `s` and `s+1` for the single-process
/// trainer: both endpoint halves in one place, `transfer` = encode →
/// frame → decode.
pub struct ForwardBoundary {
    send: BoundarySender,
    recv: BoundaryReceiver,
    /// frame scratch reused across transfers (steady state: no frame
    /// allocations per message)
    buf: FrameBuf,
}

impl ForwardBoundary {
    pub fn new(
        boundary_id: u32,
        example_len: usize,
        enc: Box<dyn BoundaryCodec>,
        dec: Box<dyn BoundaryCodec>,
    ) -> Self {
        ForwardBoundary {
            send: BoundarySender::new(boundary_id, example_len, enc),
            recv: BoundaryReceiver::new(boundary_id, example_len, dec),
            buf: FrameBuf::new(),
        }
    }

    pub fn boundary_id(&self) -> u32 {
        self.send.boundary_id
    }

    /// Transfer activation `a` across the boundary. Returns (receiver
    /// activation, stats). Runs the scratch path end to end: encode into
    /// the reusable frame buffer, decode in place off its view.
    pub fn transfer(
        &mut self,
        example_ids: &[u64],
        a: &[f32],
    ) -> Result<(Vec<f32>, TransferStats)> {
        let stats = self.send.encode_into(example_ids, a, &mut self.buf)?;
        let out = self.recv.decode_view(example_ids, &self.buf.view())?;
        Ok((out, stats))
    }

    /// Encoder-side persistent state (message buffers).
    pub fn resident_bytes(&self) -> u64 {
        self.send.state_bytes()
    }

    pub fn label(&self) -> String {
        self.send.label()
    }

    /// Worker count for both halves' chunked codec kernels.
    pub fn set_workers(&mut self, threads: usize) {
        self.send.set_workers(threads);
        self.recv.set_workers(threads);
    }

    /// Split into the two endpoint halves (threaded deployment: the
    /// sender half moves to stage `s`'s thread, the receiver half to
    /// stage `s+1`'s).
    pub fn into_halves(self) -> (BoundarySender, BoundaryReceiver) {
        (self.send, self.recv)
    }
}

// ---------------------------------------------------------------------------

/// Backward-gradient boundary: same endpoint machinery for the
/// activation-gradient direction.
pub struct BackwardBoundary {
    send: BoundarySender,
    recv: BoundaryReceiver,
    buf: FrameBuf,
}

impl BackwardBoundary {
    pub fn new(
        example_len: usize,
        enc: Box<dyn BoundaryCodec>,
        dec: Box<dyn BoundaryCodec>,
    ) -> Self {
        BackwardBoundary {
            send: BoundarySender::new(0, example_len, enc),
            recv: BoundaryReceiver::new(0, example_len, dec),
            buf: FrameBuf::new(),
        }
    }

    /// Returns (receiver-side gradient, wire bytes).
    pub fn transfer(&mut self, example_ids: &[u64], g: &[f32]) -> Result<(Vec<f32>, u64)> {
        let stats = self.send.encode_into(example_ids, g, &mut self.buf)?;
        let out = self.recv.decode_view(example_ids, &self.buf.view())?;
        Ok((out, stats.wire_bytes))
    }

    /// Worker count for both halves' chunked codec kernels.
    pub fn set_workers(&mut self, threads: usize) {
        self.send.set_workers(threads);
        self.recv.set_workers(threads);
    }

    pub fn into_halves(self) -> (BoundarySender, BoundaryReceiver) {
        (self.send, self.recv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::frame::FRAME_PRELUDE_BYTES;
    use crate::codec::registry::{build_mem_pair, CodecSpec};
    use crate::codec::{quant_wire_bytes, Rounding, UniformQuantizer};

    fn mk_fw(spec: &str, el: usize) -> ForwardBoundary {
        let spec = CodecSpec::parse(spec).unwrap();
        let (enc, dec) = build_mem_pair(&spec.fw, el, Rounding::Nearest, 0xB0D1).unwrap();
        ForwardBoundary::new(0, el, enc, dec)
    }

    fn mk_bw(spec: &str, el: usize) -> BackwardBoundary {
        let spec = CodecSpec::parse(spec).unwrap();
        let (enc, dec) = build_mem_pair(&spec.bw, el, Rounding::Nearest, 0xBACC).unwrap();
        BackwardBoundary::new(el, enc, dec)
    }

    #[test]
    fn fp32_is_lossless_and_bytes_are_measured() {
        let mut b = mk_fw("fp32", 8);
        let a: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out, a);
        // frame prelude + 4-byte shape header + 16 f32 payload — measured,
        // not the bare 4n arithmetic
        assert_eq!(st.wire_bytes, (FRAME_PRELUDE_BYTES + 4 + 64) as u64);
    }

    #[test]
    fn aq_first_epoch_full_then_delta() {
        let mut b = mk_fw("aqsgd:fw2bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let (out1, st1) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out1, a); // first visit lossless
        assert_eq!(st1.first_visits, 2);
        assert!(st1.wire_bytes > 64, "{}", st1.wire_bytes);
        // revisit: small delta, tiny wire
        let a2: Vec<f32> = a.iter().map(|x| x + 0.01).collect();
        let (out2, st2) = b.transfer(&[0, 1], &a2).unwrap();
        assert_eq!(st2.first_visits, 0);
        assert!(st2.wire_bytes * 2 < st1.wire_bytes, "{}", st2.wire_bytes);
        assert!(st2.mean_abs_delta < 0.02);
        // reconstruction close to a2 (within delta quant error)
        for (x, y) in a2.iter().zip(&out2) {
            assert!((x - y).abs() < 0.02, "{x} {y}");
        }
    }

    #[test]
    fn aq_handles_mixed_batches() {
        let mut b = mk_fw("aqsgd:fw4bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.transfer(&[0, 1], &a).unwrap();
        // batch with one known + one new example
        let (_, st) = b.transfer(&[1, 7], &a).unwrap();
        assert_eq!(st.first_visits, 1);
    }

    #[test]
    fn directq_bounded_error() {
        let mut b = mk_fw("directq:fw4bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).cos()).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        // measured frame: prelude + (bits,n,scale) header + packed payload
        assert_eq!(
            st.wire_bytes,
            (FRAME_PRELUDE_BYTES + 9) as u64 + crate::codec::pack::packed_len(16, 4) as u64
        );
        let scale = UniformQuantizer::scale(&a);
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 15.0 + 1e-6);
        }
    }

    #[test]
    fn backward_quantizes() {
        let mut bw = mk_bw("aqsgd:fw2bw8", 64);
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin() * 0.01).collect();
        let (out, bytes) = bw.transfer(&[0], &g).unwrap();
        // measured: strictly more than the bare packed arithmetic (frame
        // prelude + header), strictly less than fp32
        assert!(bytes > quant_wire_bytes(64, 8));
        assert!(bytes < 4 * 64);
        let scale = UniformQuantizer::scale(&g);
        for (x, y) in g.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 255.0 + 1e-9);
        }
    }

    #[test]
    fn backward_topk_scheme_from_registry() {
        // App. H.6's split-learning backward: top-20% + 8-bit quantization
        let mut bw = mk_bw("hybrid:aq2/topk0.2@8", 100);
        let mut g = vec![0.001f32; 100];
        g[17] = 0.9;
        g[56] = -1.1;
        let (out, bytes) = bw.transfer(&[0], &g).unwrap();
        assert!(bytes < 4 * 100 / 2, "topk should beat fp32: {bytes}");
        assert!((out[56] + 1.1).abs() < 0.02);
    }

    #[test]
    fn scratch_endpoint_path_matches_the_allocating_one() {
        // two identically-seeded boundaries: one driven through the owned
        // Frame API, one through the FrameBuf/FrameView scratch API —
        // frames, stats, and outputs must agree bit for bit
        let (mut tx_a, mut rx_a) = mk_fw("aqsgd:fw2bw4", 8).into_halves();
        let (mut tx_b, mut rx_b) = mk_fw("aqsgd:fw2bw4", 8).into_halves();
        let mut buf = crate::codec::FrameBuf::new();
        let mut out_b = vec![0f32; 16];
        let mut a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.4).sin()).collect();
        for round in 0..3 {
            let (frame, st_a) = tx_a.encode(&[0, 1], &a).unwrap();
            let st_b = tx_b.encode_into(&[0, 1], &a, &mut buf).unwrap();
            assert_eq!(buf.as_bytes(), frame.to_bytes().as_slice(), "round {round}");
            assert_eq!(st_a.wire_bytes, st_b.wire_bytes);
            assert_eq!(st_a.first_visits, st_b.first_visits);
            let out_a = rx_a.decode(&[0, 1], &frame).unwrap();
            rx_b.decode_into(&[0, 1], &buf.view(), &mut out_b).unwrap();
            assert_eq!(out_a, out_b, "round {round}");
            for v in a.iter_mut() {
                *v += 0.01;
            }
        }
        // shape mismatch on the scratch path is an error, not a panic
        let mut small = vec![0f32; 8];
        assert!(rx_b.decode_into(&[0, 1], &buf.view(), &mut small).is_err());
    }

    #[test]
    fn halves_carry_replica_state_independently() {
        // split a boundary into its endpoint halves and run the wire path
        // by hand: encode on one half, serialize, decode on the other —
        // exactly what the threaded executor does across threads.
        let b = mk_fw("aqsgd:fw2bw4", 8);
        let (mut tx, mut rx) = b.into_halves();
        let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.4).sin()).collect();
        for round in 0..3 {
            let (frame, _) = tx.encode(&[0, 1], &a).unwrap();
            let bytes = frame.to_bytes();
            let wire = crate::codec::Frame::from_bytes(&bytes).unwrap();
            let out = rx.decode(&[0, 1], &wire).unwrap();
            assert_eq!(out.len(), a.len());
            // Algorithm 2 replica symmetry across the serialized path
            assert_eq!(tx.state_bytes(), rx.state_bytes(), "round {round}");
        }
    }
}
