//! Pipeline-boundary compression.
//!
//! A `ForwardBoundary` sits between stage `s` and `s+1`: it takes the
//! sender's fresh activation, produces the bytes that would cross the
//! wire, and returns the activation the *receiver* actually sees (the
//! reconstructed `m(ξ)` for AQ-SGD, `deq(Q(a))` for DirectQ, `a` for
//! FP32). Both sides' message buffers are bit-identical by construction
//! (the paper's Algorithm 2 invariant), so one store instance represents
//! both replicas; the replica property itself is pinned by tests in
//! `codec::delta` and `tests/integration_runtime.rs`.
//!
//! Two interchangeable code paths:
//!  * native  — `codec::*` (per-example scale; fastest)
//!  * hlo     — the L1 Pallas kernels via PJRT (per-batch scale), proving
//!    the three-layer composition on the real artifact path.

use std::rc::Rc;

use crate::codec::quantizer::{Rounding, UniformQuantizer};
use crate::codec::{f16, pack, quant_wire_bytes, Compression};
use crate::runtime::QuantRuntime;
use crate::store::ActivationStore;
use crate::util::error::Result;
use crate::util::Rng;

/// What a transfer did: the receiver-side activation plus accounting.
#[derive(Clone, Debug, Default)]
pub struct TransferStats {
    pub wire_bytes: u64,
    /// mean |activation| over the message (Fig. 1b probe)
    pub mean_abs_act: f64,
    /// mean |delta| (AQ-SGD only; equals mean_abs_act otherwise)
    pub mean_abs_delta: f64,
    pub first_visits: usize,
}

pub struct ForwardBoundary {
    pub boundary_id: u32,
    compression: Compression,
    rounding: Rounding,
    store: Box<dyn ActivationStore>,
    example_len: usize,
    rng: Rng,
    hlo: Option<Rc<QuantRuntime>>,
}

impl ForwardBoundary {
    pub fn new(
        boundary_id: u32,
        compression: Compression,
        rounding: Rounding,
        store: Box<dyn ActivationStore>,
        hlo: Option<Rc<QuantRuntime>>,
    ) -> Self {
        let example_len = store.record_len();
        ForwardBoundary {
            boundary_id,
            compression,
            rounding,
            store,
            example_len,
            rng: Rng::new(0xB0D1 + boundary_id as u64),
            hlo,
        }
    }

    /// Transfer activation `a` ([B, S, D] row-major, one record per
    /// example id) across the boundary. Returns (receiver activation,
    /// stats).
    pub fn transfer(&mut self, example_ids: &[u64], a: &[f32]) -> Result<(Vec<f32>, TransferStats)> {
        assert_eq!(a.len(), example_ids.len() * self.example_len);
        let mut stats = TransferStats {
            mean_abs_act: crate::util::stats::mean_abs(a),
            ..Default::default()
        };
        let out = match self.compression {
            Compression::Fp32 => {
                stats.wire_bytes = 4 * a.len() as u64;
                stats.mean_abs_delta = stats.mean_abs_act;
                a.to_vec()
            }
            Compression::Fp16 => {
                stats.wire_bytes = 2 * a.len() as u64;
                stats.mean_abs_delta = stats.mean_abs_act;
                let mut v = a.to_vec();
                f16::roundtrip(&mut v);
                v
            }
            Compression::DirectQ { fw_bits, .. } => {
                stats.mean_abs_delta = stats.mean_abs_act;
                stats.wire_bytes = quant_wire_bytes(a.len(), fw_bits);
                match &self.hlo {
                    Some(q) => {
                        let (codes, scale) = q.dq_encode(a, fw_bits)?;
                        q.dq_decode(&codes, scale, fw_bits)?
                    }
                    None => {
                        let q = UniformQuantizer::new(fw_bits, self.rounding);
                        q.roundtrip(a, &mut self.rng)
                    }
                }
            }
            Compression::AqSgd { fw_bits, .. } => {
                return self.transfer_aq(example_ids, a, fw_bits, stats);
            }
        };
        Ok((out, stats))
    }

    fn transfer_aq(
        &mut self,
        example_ids: &[u64],
        a: &[f32],
        bits: u8,
        mut stats: TransferStats,
    ) -> Result<(Vec<f32>, TransferStats)> {
        let el = self.example_len;
        let bid = self.boundary_id;
        let present: Vec<bool> =
            example_ids.iter().map(|&ex| self.store.contains((bid, ex))).collect();
        let all_present = present.iter().all(|&p| p);
        let none_present = present.iter().all(|&p| !p);

        // The HLO (Pallas-kernel) path works on the whole [B,S,D] tensor
        // with one scale; valid when the batch is uniformly revisit.
        // Mixed batches (partial epochs) fall back to the native
        // per-example path.
        if let (Some(q), true) = (self.hlo.clone(), all_present) {
            let mut m = vec![0f32; a.len()];
            let mut rec = Vec::new();
            for (i, &ex) in example_ids.iter().enumerate() {
                self.store.get((bid, ex), &mut rec);
                m[i * el..(i + 1) * el].copy_from_slice(&rec);
            }
            let (codes, _scale, m_new) = q.aq_encode(a, &m, bits)?;
            // pack to count true wire bytes (codes cross the wire packed)
            let packed = pack::pack(&codes, bits);
            stats.wire_bytes = packed.len() as u64 + 4;
            let delta: Vec<f32> = a.iter().zip(&m).map(|(x, y)| x - y).collect();
            stats.mean_abs_delta = crate::util::stats::mean_abs(&delta);
            for (i, &ex) in example_ids.iter().enumerate() {
                self.store.put((bid, ex), &m_new[i * el..(i + 1) * el]);
            }
            return Ok((m_new, stats));
        }
        if let (Some(_), false, false) = (&self.hlo, all_present, none_present) {
            // mixed batch on the HLO path: documented native fallback
        }

        // native per-example path
        let q = UniformQuantizer::new(bits, self.rounding);
        let mut out = vec![0f32; a.len()];
        let mut m = Vec::new();
        let mut codes = vec![0u8; el];
        let mut delta = vec![0f32; el];
        let mut delta_abs_sum = 0f64;
        for (i, &ex) in example_ids.iter().enumerate() {
            let row = &a[i * el..(i + 1) * el];
            if self.store.get((bid, ex), &mut m) {
                for j in 0..el {
                    delta[j] = row[j] - m[j];
                }
                delta_abs_sum += crate::util::stats::mean_abs(&delta) * el as f64;
                let scale = q.encode(&delta, &mut codes, &mut self.rng);
                // m += deq(codes) — both replicas run this exact op
                q.decode_add(&codes, scale, &mut m);
                stats.wire_bytes += quant_wire_bytes(el, bits);
                out[i * el..(i + 1) * el].copy_from_slice(&m);
                self.store.put((bid, ex), &m);
            } else {
                // first visit: full precision (Algorithm 1 line 5)
                stats.first_visits += 1;
                stats.wire_bytes += 4 * el as u64;
                delta_abs_sum += crate::util::stats::mean_abs(row) * el as f64;
                out[i * el..(i + 1) * el].copy_from_slice(row);
                self.store.put((bid, ex), row);
            }
        }
        stats.mean_abs_delta = delta_abs_sum / a.len() as f64;
        Ok((out, stats))
    }

    pub fn resident_bytes(&self) -> u64 {
        self.store.resident_bytes()
    }
}

// ---------------------------------------------------------------------------

/// Backward-gradient boundary: direct quantization (Algorithm 1 line 11)
/// at `bw_bits`, or FP16/FP32 passthrough.
pub struct BackwardBoundary {
    compression: Compression,
    rounding: Rounding,
    rng: Rng,
    hlo: Option<Rc<QuantRuntime>>,
}

impl BackwardBoundary {
    pub fn new(compression: Compression, rounding: Rounding, hlo: Option<Rc<QuantRuntime>>) -> Self {
        BackwardBoundary { compression, rounding, rng: Rng::new(0xBACC), hlo }
    }

    /// Returns (receiver-side gradient, wire bytes).
    pub fn transfer(&mut self, g: &[f32]) -> Result<(Vec<f32>, u64)> {
        match self.compression {
            Compression::Fp32 => Ok((g.to_vec(), 4 * g.len() as u64)),
            Compression::Fp16 => {
                let mut v = g.to_vec();
                f16::roundtrip(&mut v);
                Ok((v, 2 * g.len() as u64))
            }
            Compression::DirectQ { bw_bits, .. } | Compression::AqSgd { bw_bits, .. } => {
                let bytes = quant_wire_bytes(g.len(), bw_bits);
                let out = match &self.hlo {
                    Some(q) => {
                        let (codes, scale) = q.dq_encode(g, bw_bits)?;
                        q.dq_decode(&codes, scale, bw_bits)?
                    }
                    None => {
                        let q = UniformQuantizer::new(bw_bits, self.rounding);
                        q.roundtrip(g, &mut self.rng)
                    }
                };
                Ok((out, bytes))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemStore;

    fn mk(compression: Compression) -> ForwardBoundary {
        ForwardBoundary::new(0, compression, Rounding::Nearest, Box::new(MemStore::new(8)), None)
    }

    #[test]
    fn fp32_is_lossless() {
        let mut b = mk(Compression::Fp32);
        let a: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out, a);
        assert_eq!(st.wire_bytes, 64);
    }

    #[test]
    fn aq_first_epoch_full_then_delta() {
        let mut b = mk(Compression::AqSgd { fw_bits: 2, bw_bits: 4 });
        let a: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let (out1, st1) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out1, a); // first visit lossless
        assert_eq!(st1.first_visits, 2);
        assert_eq!(st1.wire_bytes, 64);
        // revisit: small delta, tiny wire
        let a2: Vec<f32> = a.iter().map(|x| x + 0.01).collect();
        let (out2, st2) = b.transfer(&[0, 1], &a2).unwrap();
        assert_eq!(st2.first_visits, 0);
        assert!(st2.wire_bytes < 20, "{}", st2.wire_bytes);
        assert!(st2.mean_abs_delta < 0.02);
        // reconstruction close to a2 (within delta quant error)
        for (x, y) in a2.iter().zip(&out2) {
            assert!((x - y).abs() < 0.02, "{x} {y}");
        }
    }

    #[test]
    fn aq_handles_mixed_batches() {
        let mut b = mk(Compression::AqSgd { fw_bits: 4, bw_bits: 4 });
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.transfer(&[0, 1], &a).unwrap();
        // batch with one known + one new example
        let (_, st) = b.transfer(&[1, 7], &a).unwrap();
        assert_eq!(st.first_visits, 1);
    }

    #[test]
    fn directq_bounded_error() {
        let mut b = mk(Compression::DirectQ { fw_bits: 4, bw_bits: 4 });
        let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).cos()).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(st.wire_bytes, quant_wire_bytes(16, 4));
        let scale = UniformQuantizer::scale(&a);
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 15.0 + 1e-6);
        }
    }

    #[test]
    fn backward_quantizes() {
        let mut bw = BackwardBoundary::new(
            Compression::AqSgd { fw_bits: 2, bw_bits: 8 },
            Rounding::Nearest,
            None,
        );
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin() * 0.01).collect();
        let (out, bytes) = bw.transfer(&g).unwrap();
        assert_eq!(bytes, quant_wire_bytes(64, 8));
        let scale = UniformQuantizer::scale(&g);
        for (x, y) in g.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 255.0 + 1e-9);
        }
    }
}
