//! Pipeline-boundary compression.
//!
//! A `ForwardBoundary` sits between stage `s` and `s+1`: it owns the two
//! halves of a [`BoundaryCodec`] pair — the sender-side encoder and the
//! receiver-side decoder, built from the same registry scheme but
//! sharing *no* state. `transfer` runs activation → [`Frame`] → receiver
//! activation; wire bytes are read off the frame's actual buffers, and
//! Algorithm 2's sender/receiver replica invariant holds by construction
//! because the decoder reconstructs only from frame bytes (pinned by
//! `tests/prop_frames.rs`).
//!
//! `BackwardBoundary` is the same machine for the activation-gradient
//! direction (direct quantization under the paper's `aqsgd:` spec,
//! top-k + quantization under App. H.6's split-learning scheme, or any
//! other registry scheme via `hybrid:`).

use crate::codec::BoundaryCodec;
use crate::util::error::Result;

/// What a transfer did: the receiver-side activation plus accounting.
#[derive(Clone, Debug, Default)]
pub struct TransferStats {
    /// serialized frame size — `Frame::wire_bytes()`, i.e. measured from
    /// the actual header/payload buffers
    pub wire_bytes: u64,
    /// mean |activation| over the message (Fig. 1b probe)
    pub mean_abs_act: f64,
    /// mean |delta| (AQ-SGD only; equals mean_abs_act otherwise)
    pub mean_abs_delta: f64,
    pub first_visits: usize,
}

pub struct ForwardBoundary {
    pub boundary_id: u32,
    /// elements per example record — validates batch shape on every
    /// transfer, codec-independent
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
    dec: Box<dyn BoundaryCodec>,
}

impl ForwardBoundary {
    pub fn new(
        boundary_id: u32,
        example_len: usize,
        enc: Box<dyn BoundaryCodec>,
        dec: Box<dyn BoundaryCodec>,
    ) -> Self {
        ForwardBoundary { boundary_id, example_len, enc, dec }
    }

    /// Transfer activation `a` ([B, S, D] row-major, one record per
    /// example id) across the boundary. Returns (receiver activation,
    /// stats).
    pub fn transfer(
        &mut self,
        example_ids: &[u64],
        a: &[f32],
    ) -> Result<(Vec<f32>, TransferStats)> {
        crate::ensure!(
            a.len() == example_ids.len() * self.example_len,
            "boundary {}: activation length {} != {} ids x {} elements",
            self.boundary_id,
            a.len(),
            example_ids.len(),
            self.example_len
        );
        let mean_abs_act = crate::util::stats::mean_abs(a);
        let frame = self.enc.encode(example_ids, a)?;
        let es = self.enc.take_stats();
        let out = self.dec.decode(example_ids, &frame)?;
        crate::ensure!(
            out.len() == a.len(),
            "boundary {} codec returned {} elements for a {}-element activation",
            self.boundary_id,
            out.len(),
            a.len()
        );
        let stats = TransferStats {
            wire_bytes: frame.wire_bytes(),
            mean_abs_act,
            mean_abs_delta: es.mean_abs_delta.unwrap_or(mean_abs_act),
            first_visits: es.first_visits,
        };
        Ok((out, stats))
    }

    /// Encoder-side persistent state (message buffers), i.e. what one
    /// replica of this boundary keeps resident.
    pub fn resident_bytes(&self) -> u64 {
        self.enc.state_bytes()
    }

    pub fn label(&self) -> String {
        self.enc.label()
    }
}

// ---------------------------------------------------------------------------

/// Backward-gradient boundary: same encoder/decoder machinery for the
/// activation-gradient direction.
pub struct BackwardBoundary {
    /// elements per example record (gradients share the boundary shape)
    example_len: usize,
    enc: Box<dyn BoundaryCodec>,
    dec: Box<dyn BoundaryCodec>,
}

impl BackwardBoundary {
    pub fn new(
        example_len: usize,
        enc: Box<dyn BoundaryCodec>,
        dec: Box<dyn BoundaryCodec>,
    ) -> Self {
        BackwardBoundary { example_len, enc, dec }
    }

    /// Returns (receiver-side gradient, wire bytes).
    pub fn transfer(&mut self, example_ids: &[u64], g: &[f32]) -> Result<(Vec<f32>, u64)> {
        crate::ensure!(
            g.len() == example_ids.len() * self.example_len,
            "backward boundary: gradient length {} != {} ids x {} elements",
            g.len(),
            example_ids.len(),
            self.example_len
        );
        let frame = self.enc.encode(example_ids, g)?;
        let out = self.dec.decode(example_ids, &frame)?;
        crate::ensure!(
            out.len() == g.len(),
            "backward codec returned {} elements for a {}-element gradient",
            out.len(),
            g.len()
        );
        Ok((out, frame.wire_bytes()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::frame::FRAME_PRELUDE_BYTES;
    use crate::codec::registry::{build_mem_pair, CodecSpec};
    use crate::codec::{quant_wire_bytes, Rounding, UniformQuantizer};

    fn mk_fw(spec: &str, el: usize) -> ForwardBoundary {
        let spec = CodecSpec::parse(spec).unwrap();
        let (enc, dec) = build_mem_pair(&spec.fw, el, Rounding::Nearest, 0xB0D1).unwrap();
        ForwardBoundary::new(0, el, enc, dec)
    }

    fn mk_bw(spec: &str, el: usize) -> BackwardBoundary {
        let spec = CodecSpec::parse(spec).unwrap();
        let (enc, dec) = build_mem_pair(&spec.bw, el, Rounding::Nearest, 0xBACC).unwrap();
        BackwardBoundary::new(el, enc, dec)
    }

    #[test]
    fn fp32_is_lossless_and_bytes_are_measured() {
        let mut b = mk_fw("fp32", 8);
        let a: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out, a);
        // frame prelude + 4-byte shape header + 16 f32 payload — measured,
        // not the bare 4n arithmetic
        assert_eq!(st.wire_bytes, (FRAME_PRELUDE_BYTES + 4 + 64) as u64);
    }

    #[test]
    fn aq_first_epoch_full_then_delta() {
        let mut b = mk_fw("aqsgd:fw2bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let (out1, st1) = b.transfer(&[0, 1], &a).unwrap();
        assert_eq!(out1, a); // first visit lossless
        assert_eq!(st1.first_visits, 2);
        assert!(st1.wire_bytes > 64, "{}", st1.wire_bytes);
        // revisit: small delta, tiny wire
        let a2: Vec<f32> = a.iter().map(|x| x + 0.01).collect();
        let (out2, st2) = b.transfer(&[0, 1], &a2).unwrap();
        assert_eq!(st2.first_visits, 0);
        assert!(st2.wire_bytes * 2 < st1.wire_bytes, "{}", st2.wire_bytes);
        assert!(st2.mean_abs_delta < 0.02);
        // reconstruction close to a2 (within delta quant error)
        for (x, y) in a2.iter().zip(&out2) {
            assert!((x - y).abs() < 0.02, "{x} {y}");
        }
    }

    #[test]
    fn aq_handles_mixed_batches() {
        let mut b = mk_fw("aqsgd:fw4bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        b.transfer(&[0, 1], &a).unwrap();
        // batch with one known + one new example
        let (_, st) = b.transfer(&[1, 7], &a).unwrap();
        assert_eq!(st.first_visits, 1);
    }

    #[test]
    fn directq_bounded_error() {
        let mut b = mk_fw("directq:fw4bw4", 8);
        let a: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).cos()).collect();
        let (out, st) = b.transfer(&[0, 1], &a).unwrap();
        // measured frame: prelude + (bits,n,scale) header + packed payload
        assert_eq!(
            st.wire_bytes,
            (FRAME_PRELUDE_BYTES + 9) as u64 + crate::codec::pack::packed_len(16, 4) as u64
        );
        let scale = UniformQuantizer::scale(&a);
        for (x, y) in a.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 15.0 + 1e-6);
        }
    }

    #[test]
    fn backward_quantizes() {
        let mut bw = mk_bw("aqsgd:fw2bw8", 64);
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.3).sin() * 0.01).collect();
        let (out, bytes) = bw.transfer(&[0], &g).unwrap();
        // measured: strictly more than the bare packed arithmetic (frame
        // prelude + header), strictly less than fp32
        assert!(bytes > quant_wire_bytes(64, 8));
        assert!(bytes < 4 * 64);
        let scale = UniformQuantizer::scale(&g);
        for (x, y) in g.iter().zip(&out) {
            assert!((x - y).abs() <= scale / 255.0 + 1e-9);
        }
    }

    #[test]
    fn backward_topk_scheme_from_registry() {
        // App. H.6's split-learning backward: top-20% + 8-bit quantization
        let mut bw = mk_bw("hybrid:aq2/topk0.2@8", 100);
        let mut g = vec![0.001f32; 100];
        g[17] = 0.9;
        g[56] = -1.1;
        let (out, bytes) = bw.transfer(&[0], &g).unwrap();
        assert!(bytes < 4 * 100 / 2, "topk should beat fp32: {bytes}");
        assert!((out[56] + 1.1).abs() < 0.02);
    }
}
