//! Checkpointing: persist / restore the full trainer state (per-stage
//! parameters + AdamW moments + step counter) so long fine-tuning runs
//! survive restarts — table-stakes for a deployable trainer. Flat f32-LE
//! tensors + a kv metadata file (same formats as the AOT artifacts).

use std::path::{Path, PathBuf};

use crate::coordinator::trainer::Trainer;
use crate::util::error::{Context, Result};
use crate::util::kv::Kv;

fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

fn read_f32(path: &Path, expect: usize) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    crate::ensure!(bytes.len() == expect * 4, "checkpoint tensor size mismatch");
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

impl Trainer {
    /// Write a checkpoint directory.
    pub fn save_checkpoint(&self, dir: impl Into<PathBuf>) -> Result<()> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let mut meta = String::new();
        meta.push_str(&format!("model {}\n", self.cfg.model));
        meta.push_str(&format!("step {}\n", self.steps_done()));
        meta.push_str(&format!("n_stages {}\n", self.n_stages()));
        // codec specs are soft state (AQ buffers / EF residuals are not
        // checkpointed); recorded so a resume under a different codec is
        // caught instead of silently changing the compression dynamics
        meta.push_str(&format!("compression {}\n", self.cfg.compression.spec_string()));
        meta.push_str(&format!("dp_codec {}\n", self.cfg.dp_codec.spec_string()));
        for s in 0..self.n_stages() {
            let n = self.stage(s).n_params;
            meta.push_str(&format!("stage{s}.params {n}\n"));
            write_f32(&dir.join(format!("stage{s}_params.bin")), &self.stage(s).params)?;
            let (m, v) = self.opt_state(s);
            write_f32(&dir.join(format!("stage{s}_m.bin")), m)?;
            write_f32(&dir.join(format!("stage{s}_v.bin")), v)?;
        }
        std::fs::write(dir.join("checkpoint.txt"), meta)?;
        Ok(())
    }

    /// Restore parameters + optimizer state from a checkpoint directory.
    /// The trainer must have been built from the same model config.
    pub fn load_checkpoint(&mut self, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        let meta = Kv::load(&dir.join("checkpoint.txt"))?;
        crate::ensure!(
            meta.get("model")? == self.cfg.model,
            "checkpoint is for model {:?}, trainer is {:?}",
            meta.get("model")?,
            self.cfg.model
        );
        crate::ensure!(meta.usize("n_stages")? == self.n_stages());
        // spec keys are absent in pre-CommPlane checkpoints; validate
        // only when present
        if let Some(spec) = meta.get_opt("compression") {
            crate::ensure!(
                spec == self.cfg.compression.spec_string(),
                "checkpoint was written with compression {spec:?}, trainer is configured \
                 for {:?} (AQ message buffers are not checkpointed, so resuming under a \
                 different boundary codec would silently change the compression dynamics)",
                self.cfg.compression.spec_string()
            );
        }
        if let Some(spec) = meta.get_opt("dp_codec") {
            crate::ensure!(
                spec == self.cfg.dp_codec.spec_string(),
                "checkpoint was written with dp codec {spec:?}, trainer is configured \
                 for {:?} (EF residuals are not checkpointed, so resuming under a \
                 different DP codec would silently change the compensation dynamics)",
                self.cfg.dp_codec.spec_string()
            );
        }
        let step = meta.usize("step")?;
        for s in 0..self.n_stages() {
            let n = self.stage(s).n_params;
            crate::ensure!(meta.usize(&format!("stage{s}.params"))? == n);
            let params = read_f32(&dir.join(format!("stage{s}_params.bin")), n)?;
            let m = read_f32(&dir.join(format!("stage{s}_m.bin")), n)?;
            let v = read_f32(&dir.join(format!("stage{s}_v.bin")), n)?;
            self.stage_mut(s).params = params;
            self.set_opt_state(s, m, v);
        }
        self.restore_step(step);
        Ok(())
    }
}
