//! The training coordinator — the paper's system contribution.
//!
//! * `boundary` — per-pipeline-boundary compression: each boundary owns
//!   a registry-built `BoundaryCodec` encoder/decoder pair (FP32 / FP16 /
//!   DirectQ / AQ-SGD / top-k / hybrid compositions) exchanging framed
//!   wire messages, in both a native rust path and an L1-Pallas-kernel
//!   (HLO artifact) path.
//! * `trainer`  — the synchronous pipeline training loop over the PJRT
//!   stage artifacts: microbatch schedule, gradient accumulation, AdamW,
//!   simulated-network time accounting, eval.
//! * `dp`       — data-parallel gradient averaging over the CommPlane's
//!   framed all-gather ring, with registry-built `ef:` error-feedback
//!   codecs ("QuantizedAdam", §4.3 / Fig. 5).
//! * `split`    — the split-learning scenario of Appendix H.6.

pub mod boundary;
pub mod checkpoint;
pub mod generate;
pub mod dp;
pub mod split;
pub mod trainer;

pub use boundary::{
    BackwardBoundary, BoundaryReceiver, BoundarySender, ForwardBoundary, TransferStats,
};
pub use dp::{DpGroup, DpWire};
pub use trainer::{Probe, TrainStats, Trainer};
