//! Autoregressive text generation over the pipeline artifacts — the
//! paper's Appendix I case study (comparing continuations of FP32 /
//! DirectQ / AQ-SGD fine-tuned models on the same prompt).
//!
//! Decoding runs the full pipeline forward per emitted token over a
//! sliding window of the last `seq` tokens (the artifacts are
//! fixed-shape), greedy or temperature sampling. Only row 0 of the
//! micro-batch is used for the prompt; the other rows are padding.

use crate::util::error::Result;

use crate::coordinator::trainer::Trainer;
use crate::util::Rng;

pub struct GenerateCfg {
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f32,
    pub seed: u64,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg { max_new_tokens: 32, temperature: 0.0, seed: 0 }
    }
}

impl Trainer {
    /// Generate a continuation of `prompt` (token ids). Returns only the
    /// newly generated tokens.
    pub fn generate(&self, prompt: &[i32], gcfg: &GenerateCfg) -> Result<Vec<i32>> {
        crate::ensure!(self.man.task()? == "lm", "generation needs an LM model");
        let seq = self.man.seq()?;
        let b = self.man.micro_batch()?;
        let vocab = self.man.vocab()?;
        crate::ensure!(!prompt.is_empty(), "empty prompt");
        let mut rng = Rng::new(gcfg.seed);

        let mut ctx: Vec<i32> = prompt.to_vec();
        let mut out = Vec::with_capacity(gcfg.max_new_tokens);
        for _ in 0..gcfg.max_new_tokens {
            // sliding window, left-padded with the first prompt token
            let window: Vec<i32> = if ctx.len() >= seq {
                ctx[ctx.len() - seq..].to_vec()
            } else {
                let mut w = vec![ctx[0]; seq - ctx.len()];
                w.extend_from_slice(&ctx);
                w
            };
            // the logits position to read: last filled slot
            let pos = seq - 1;
            // batch: row 0 = window, rows 1.. replicate (shape padding)
            let mut tokens = Vec::with_capacity(b * seq);
            for _ in 0..b {
                tokens.extend_from_slice(&window);
            }
            let logits = self.pipeline_logits(&tokens)?;
            // row 0, position `pos`
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let next = if gcfg.temperature <= 0.0 {
                argmax(row)
            } else {
                sample(row, gcfg.temperature, &mut rng)
            };
            out.push(next as i32);
            ctx.push(next as i32);
        }
        Ok(out)
    }

    /// Full-pipeline forward to logits (row-major [B, S, V]; returns
    /// row 0 = [S, V]).
    fn pipeline_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let k = self.n_stages();
        let seq = self.man.seq()?;
        let vocab = self.man.vocab()?;
        let mut x: Vec<f32> = Vec::new();
        for s in 0..k - 1 {
            x = if s == 0 {
                self.stage(0).forward(&crate::runtime::StageInput::Tokens(tokens))?
            } else {
                self.stage(s).forward(&crate::runtime::StageInput::Hidden(&x))?
            };
        }
        let logits = if k == 1 {
            self.stage(0).logits(&crate::runtime::StageInput::Tokens(tokens))?
        } else {
            self.stage(k - 1).logits(&crate::runtime::StageInput::Hidden(&x))?
        };
        Ok(logits[..seq * vocab].to_vec())
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn sample(row: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|&v| ((v - max) / temperature).exp()).collect();
    let total: f32 = exps.iter().sum();
    let mut u = rng.next_f32() * total;
    for (i, &e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i;
        }
    }
    exps.len() - 1
}

/// Decode byte-level tokens to a printable string (embedded corpus).
pub fn detokenize_bytes(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let b = (t.clamp(0, 255)) as u8;
            if b.is_ascii_graphic() || b == b' ' {
                b as char
            } else {
                '?'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sample_bounds() {
        let row = [0.1f32, 5.0, -2.0, 1.0];
        assert_eq!(argmax(&row), 1);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = sample(&row, 0.5, &mut rng);
            assert!(s < 4);
        }
        // low temperature concentrates on the argmax
        let mut hits = 0;
        for _ in 0..100 {
            if sample(&row, 0.05, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 95);
    }

    #[test]
    fn detokenize_is_safe() {
        assert_eq!(detokenize_bytes(&[72, 105, 33]), "Hi!");
        assert_eq!(detokenize_bytes(&[0, 300, -5]), "???");
    }
}
