//! Autoregressive text generation over the pipeline artifacts — the
//! paper's Appendix I case study (comparing continuations of FP32 /
//! DirectQ / AQ-SGD fine-tuned models on the same prompt).
//!
//! Decoding runs the full pipeline forward per emitted token over a
//! sliding window of the last `seq` tokens (the artifacts are
//! fixed-shape), greedy or temperature sampling. Only row 0 of the
//! micro-batch is used for the prompt; the other rows are padding.

use crate::util::error::Result;

use crate::coordinator::trainer::Trainer;
use crate::util::Rng;

pub struct GenerateCfg {
    pub max_new_tokens: usize,
    /// 0.0 = greedy; otherwise softmax temperature.
    pub temperature: f32,
    pub seed: u64,
}

impl Default for GenerateCfg {
    fn default() -> Self {
        GenerateCfg { max_new_tokens: 32, temperature: 0.0, seed: 0 }
    }
}

/// Write one prompt's sliding decode window into a `seq`-long token row:
/// the last `seq` context tokens, left-padded with the first context
/// token when the context is still short. Pure, allocation-free — the
/// per-token hot path of generation.
fn write_window(ctx: &[i32], row: &mut [i32]) {
    let seq = row.len();
    if ctx.len() >= seq {
        row.copy_from_slice(&ctx[ctx.len() - seq..]);
    } else {
        let pad = seq - ctx.len();
        row[..pad].fill(ctx[0]);
        row[pad..].copy_from_slice(ctx);
    }
}

impl Trainer {
    /// Generate a continuation of `prompt` (token ids). Returns only the
    /// newly generated tokens.
    pub fn generate(&self, prompt: &[i32], gcfg: &GenerateCfg) -> Result<Vec<i32>> {
        let mut outs = self.generate_many(&[prompt], gcfg)?;
        Ok(outs.pop().expect("one prompt in, one continuation out"))
    }

    /// Generate continuations for up to `micro_batch` prompts in one
    /// pass, one prompt per batch row, decoding in lockstep — the
    /// artifacts are fixed-shape, so `n` prompts cost the same pipeline
    /// forwards as one. The token buffer is allocated once: padding rows
    /// (`n..b`) are written once up front and per decode step only the
    /// `n` live windows are rewritten in place (per-row logits depend
    /// only on that row, so stale padding never leaks into an answer).
    pub fn generate_many(&self, prompts: &[&[i32]], gcfg: &GenerateCfg) -> Result<Vec<Vec<i32>>> {
        crate::ensure!(self.man.task()? == "lm", "generation needs an LM model");
        let seq = self.man.seq()?;
        let b = self.man.micro_batch()?;
        let vocab = self.man.vocab()?;
        let n = prompts.len();
        crate::ensure!(n >= 1, "no prompts");
        crate::ensure!(n <= b, "{n} prompts but the artifact batches {b} rows");
        for p in prompts {
            crate::ensure!(!p.is_empty(), "empty prompt");
        }
        let mut rng = Rng::new(gcfg.seed);

        let mut ctxs: Vec<Vec<i32>> = prompts.iter().map(|p| p.to_vec()).collect();
        let mut outs: Vec<Vec<i32>> =
            (0..n).map(|_| Vec::with_capacity(gcfg.max_new_tokens)).collect();
        let mut tokens = vec![0i32; b * seq];
        for r in n..b {
            tokens[r * seq..(r + 1) * seq].fill(prompts[0][0]);
        }
        // the logits position to read: last filled slot of each window
        let pos = seq - 1;
        for _ in 0..gcfg.max_new_tokens {
            for (r, ctx) in ctxs.iter().enumerate() {
                write_window(ctx, &mut tokens[r * seq..(r + 1) * seq]);
            }
            let logits = self.pipeline_logits(&tokens)?;
            crate::ensure!(
                logits.len() >= b * seq * vocab,
                "logits artifact returned {} values, expected {}",
                logits.len(),
                b * seq * vocab
            );
            for (r, ctx) in ctxs.iter_mut().enumerate() {
                let at = (r * seq + pos) * vocab;
                let row = &logits[at..at + vocab];
                let next = if gcfg.temperature <= 0.0 {
                    argmax(row)
                } else {
                    sample(row, gcfg.temperature, &mut rng)
                };
                outs[r].push(next as i32);
                ctx.push(next as i32);
            }
        }
        Ok(outs)
    }

    /// Full-pipeline forward to logits, row-major `[B, S, V]`.
    fn pipeline_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let k = self.n_stages();
        let mut x: Vec<f32> = Vec::new();
        for s in 0..k - 1 {
            x = if s == 0 {
                self.stage(0).forward(&crate::runtime::StageInput::Tokens(tokens))?
            } else {
                self.stage(s).forward(&crate::runtime::StageInput::Hidden(&x))?
            };
        }
        if k == 1 {
            self.stage(0).logits(&crate::runtime::StageInput::Tokens(tokens))
        } else {
            self.stage(k - 1).logits(&crate::runtime::StageInput::Hidden(&x))
        }
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

fn sample(row: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|&v| ((v - max) / temperature).exp()).collect();
    let total: f32 = exps.iter().sum();
    let mut u = rng.next_f32() * total;
    for (i, &e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i;
        }
    }
    exps.len() - 1
}

/// Decode byte-level tokens to a printable string (embedded corpus).
pub fn detokenize_bytes(tokens: &[i32]) -> String {
    tokens
        .iter()
        .map(|&t| {
            let b = (t.clamp(0, 255)) as u8;
            if b.is_ascii_graphic() || b == b' ' {
                b as char
            } else {
                '?'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sample_bounds() {
        let row = [0.1f32, 5.0, -2.0, 1.0];
        assert_eq!(argmax(&row), 1);
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = sample(&row, 0.5, &mut rng);
            assert!(s < 4);
        }
        // low temperature concentrates on the argmax
        let mut hits = 0;
        for _ in 0..100 {
            if sample(&row, 0.05, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 95);
    }

    #[test]
    fn write_window_takes_the_context_tail() {
        let mut row = [0i32; 4];
        write_window(&[1, 2, 3, 4, 5, 6], &mut row);
        assert_eq!(row, [3, 4, 5, 6]);
        write_window(&[7, 8, 9, 10], &mut row);
        assert_eq!(row, [7, 8, 9, 10], "exact fit copies verbatim");
    }

    #[test]
    fn write_window_left_pads_short_contexts() {
        let mut row = [0i32; 5];
        write_window(&[42, 43], &mut row);
        assert_eq!(row, [42, 42, 42, 42, 43], "pad with the first token");
        write_window(&[9], &mut row);
        assert_eq!(row, [9, 9, 9, 9, 9]);
    }

    #[test]
    fn detokenize_is_safe() {
        assert_eq!(detokenize_bytes(&[72, 105, 33]), "Hi!");
        assert_eq!(detokenize_bytes(&[0, 300, -5]), "???");
    }
}
