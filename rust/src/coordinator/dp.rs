//! Data-parallel gradient exchange over the CommPlane — the third
//! traffic class of the paper's end-to-end compression story (§4.3 /
//! Figure 5).
//!
//! [`DpGroup`] simulates `degree` replicas in one process, but the
//! gradients travel exactly the way a deployment would ship them: each
//! replica owns a registry-built codec endpoint (typically an
//! `ef:<inner>` error-feedback wrapper, whose residuals live *in the
//! codec* — see `codec::ef`), encodes its per-stage gradient into a
//! [`Frame`](crate::codec::Frame), and the frames circulate an
//! all-gather ring ([`DpRing`]) whose per-sender decoder replicas
//! reconstruct every contribution. Wire bytes are the serialized frame
//! sizes — no `quant_wire_bytes`-style parallel arithmetic — and the
//! synchronized-update invariant (all replicas compute the bit-identical
//! mean, so one parameter copy represents them all) is *asserted* every
//! step instead of assumed.

use std::time::Duration;

use crate::codec::quantizer::Rounding;
use crate::codec::CodecSpec;
use crate::net::plane::{dp_rings, DpRing};
use crate::util::error::Result;

/// Measured wire accounting of one reduce round.
#[derive(Clone, Copy, Debug, Default)]
pub struct DpWire {
    /// Serialized frame bytes shipped across all ring edges (what the
    /// trainer's comm counter records).
    pub total_bytes: u64,
    /// Largest single frame of the round — gates one serialized hop in
    /// the ring time model (`PipelineSim::ring_allgather_time`).
    pub max_frame_bytes: u64,
}

pub struct DpGroup {
    pub degree: usize,
    spec: CodecSpec,
    /// [replica][stage] ring endpoints, wired by unpaced in-process links.
    rings: Vec<Vec<DpRing>>,
    stage_sizes: Vec<usize>,
}

impl DpGroup {
    /// Build the exchange group. `spec` names the gradient codec (the
    /// `--dp-codec` knob, e.g. `ef:directq:fw4bw4`; `fp32` for
    /// uncompressed exchange); `rounding` and `seed` flow into every
    /// codec half through the registry, so stochastic-rounding
    /// determinism is configured here and nowhere else.
    pub fn new(
        degree: usize,
        spec: &CodecSpec,
        stage_sizes: &[usize],
        rounding: Rounding,
        seed: u64,
    ) -> Result<Self> {
        crate::ensure!(degree >= 1, "dp group needs at least one replica");
        crate::ensure!(!stage_sizes.is_empty(), "dp group needs at least one stage");
        // [stage] -> per-replica rings, then transpose to [replica][stage]
        let mut per_stage = Vec::with_capacity(stage_sizes.len());
        for (s, &n) in stage_sizes.iter().enumerate() {
            crate::ensure!(n >= 1, "dp stage {s} has an empty gradient");
            per_stage.push(dp_rings(
                &spec.fw,
                degree,
                n,
                rounding,
                seed ^ ((s as u64) << 8),
                f64::INFINITY,
                Duration::ZERO,
            )?);
        }
        let mut rings: Vec<Vec<DpRing>> = (0..degree).map(|_| Vec::new()).collect();
        for stage_rings in per_stage {
            for (r, ring) in stage_rings.into_iter().enumerate() {
                rings[r].push(ring);
            }
        }
        Ok(DpGroup { degree, spec: spec.clone(), rings, stage_sizes: stage_sizes.to_vec() })
    }

    pub fn spec(&self) -> &CodecSpec {
        &self.spec
    }

    /// Average per-replica per-stage gradients through the ring. Returns
    /// `(mean gradients, measured wire accounting)`. Shape mismatches
    /// are errors, never panics — gradients arrive from per-replica
    /// compute that a deployment cannot assume well-formed.
    pub fn reduce(&mut self, grads: &[Vec<Vec<f32>>]) -> Result<(Vec<Vec<f32>>, DpWire)> {
        crate::ensure!(
            grads.len() == self.degree,
            "dp reduce got {} replicas, group has {}",
            grads.len(),
            self.degree
        );
        let n_stages = self.stage_sizes.len();
        for (r, g) in grads.iter().enumerate() {
            crate::ensure!(
                g.len() == n_stages,
                "dp replica {r} has {} stages, group has {n_stages}",
                g.len()
            );
            for (s, v) in g.iter().enumerate() {
                crate::ensure!(
                    v.len() == self.stage_sizes[s],
                    "dp replica {r} stage {s}: gradient length {} != {}",
                    v.len(),
                    self.stage_sizes[s]
                );
            }
        }

        let mut mean = Vec::with_capacity(n_stages);
        let mut wire = DpWire::default();
        for s in 0..n_stages {
            // single-threaded phase order (the virtual twin of the
            // per-thread blocking ring in pipeline::exec)
            for (row, g) in self.rings.iter_mut().zip(grads) {
                row[s].send_own(&g[s])?;
            }
            for hop in 1..self.degree {
                for row in self.rings.iter_mut() {
                    row[s].hop(hop)?;
                }
            }
            let mut stage_mean: Option<Vec<f32>> = None;
            for (r, row) in self.rings.iter_mut().enumerate() {
                let (m, sent) = row[s].finish()?;
                wire.total_bytes += sent;
                wire.max_frame_bytes = wire.max_frame_bytes.max(row[s].take_max_frame());
                match &stage_mean {
                    None => stage_mean = Some(m),
                    Some(m0) => crate::ensure!(
                        bits_equal(m0, &m),
                        "synchronized-update invariant violated: replica {r} mean \
                         diverged at stage {s}"
                    ),
                }
            }
            mean.push(stage_mean.expect("degree >= 1"));
        }
        Ok((mean, wire))
    }
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::frame::FRAME_PRELUDE_BYTES;
    use crate::util::Rng;

    fn grads(degree: usize, n: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Rng::new(seed);
        (0..degree)
            .map(|_| vec![(0..n).map(|_| rng.normal() * 0.1).collect::<Vec<f32>>()])
            .collect()
    }

    fn group(degree: usize, spec: &str, sizes: &[usize]) -> DpGroup {
        DpGroup::new(degree, &CodecSpec::parse(spec).unwrap(), sizes, Rounding::Nearest, 0)
            .unwrap()
    }

    #[test]
    fn uncompressed_is_exact_mean_with_measured_frames() {
        let g = grads(4, 32, 1);
        let mut dp = group(4, "fp32", &[32]);
        let (mean, wire) = dp.reduce(&g).unwrap();
        for j in 0..32 {
            let want: f32 = g.iter().map(|r| r[0][j]).sum::<f32>() / 4.0;
            assert!((mean[0][j] - want).abs() < 1e-6);
        }
        // every byte is a serialized raw32 frame: prelude + n:u32 + 4n
        let frame = (FRAME_PRELUDE_BYTES + 4 + 4 * 32) as u64;
        // 4 replicas each ship own frame + 2 forwards
        assert_eq!(wire.total_bytes, 4 * 3 * frame);
        assert_eq!(wire.max_frame_bytes, frame);
    }

    #[test]
    fn error_feedback_preserves_signal_over_time() {
        // summed over many rounds, compressed mean ~ true mean (error
        // feedback makes the bias vanish) — the 1-bit-Adam property,
        // now through ef: codec frames on the ring.
        let degree = 2;
        let n = 64;
        let mut dp = group(degree, "ef:directq:fw4bw4", &[n]);
        let mut rng = Rng::new(3);
        let constant: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
        let mut acc = vec![0f64; n];
        let rounds = 200;
        for _ in 0..rounds {
            let g: Vec<Vec<Vec<f32>>> = (0..degree)
                .map(|_| {
                    vec![constant
                        .iter()
                        .map(|&c| c + 0.001 * rng.normal())
                        .collect::<Vec<f32>>()]
                })
                .collect();
            let (mean, _) = dp.reduce(&g).unwrap();
            for (a, &m) in acc.iter_mut().zip(&mean[0]) {
                *a += m as f64;
            }
        }
        for (a, &c) in acc.iter().zip(&constant) {
            let avg = *a / rounds as f64;
            assert!((avg - c as f64).abs() < 3e-3, "{avg} vs {c}");
        }
    }

    #[test]
    fn compressed_wire_is_smaller() {
        let g = grads(2, 1000, 5);
        let mut fp = group(2, "fp32", &[1000]);
        let mut q4 = group(2, "ef:directq:fw4bw4", &[1000]);
        let (_, w_fp) = fp.reduce(&g).unwrap();
        let (_, w_q) = q4.reduce(&g).unwrap();
        assert!(w_q.total_bytes * 7 < w_fp.total_bytes, "{w_q:?} vs {w_fp:?}");
    }

    #[test]
    fn shape_mismatches_are_errors_not_panics() {
        let mut dp = group(2, "ef:directq:fw4bw4", &[16, 8]);
        // wrong replica count
        assert!(dp.reduce(&grads(3, 16, 1)).is_err());
        // wrong stage count
        assert!(dp.reduce(&grads(2, 16, 1)).is_err());
        // wrong stage length
        let bad: Vec<Vec<Vec<f32>>> =
            (0..2).map(|_| vec![vec![0.0; 16], vec![0.0; 9]]).collect();
        assert!(dp.reduce(&bad).is_err());
        // a well-formed round still works afterwards
        let ok: Vec<Vec<Vec<f32>>> =
            (0..2).map(|_| vec![vec![0.01; 16], vec![0.02; 8]]).collect();
        assert!(dp.reduce(&ok).is_ok());
    }

    #[test]
    fn stochastic_rounding_is_seeded_through_the_registry() {
        // same seed -> identical trajectories; different seed -> different
        // (determinism is configured in one place, not a hidden rng)
        let spec = CodecSpec::parse("ef:directq:fw2bw2").unwrap();
        let mk = |seed: u64| {
            DpGroup::new(2, &spec, &[64], Rounding::Stochastic, seed).unwrap()
        };
        let g = grads(2, 64, 9);
        let (m1, _) = mk(7).reduce(&g).unwrap();
        let (m2, _) = mk(7).reduce(&g).unwrap();
        let (m3, _) = mk(8).reduce(&g).unwrap();
        assert!(bits_equal(&m1[0], &m2[0]), "same seed must reproduce");
        assert!(!bits_equal(&m1[0], &m3[0]), "different seed must differ");
    }
}
