//! Data-parallel gradient averaging with error-compensated quantization —
//! the "QuantizedAdam"-style compressor of §4.3 / Figure 5.
//!
//! Each replica keeps an error-feedback residual e_r per stage:
//!     e_r += g_r;  q_r = Q(e_r);  e_r -= deq(q_r)
//! the replicas exchange deq(q_r) (ring all-reduce on the wire) and apply
//! the mean to a shared AdamW state. With synchronized updates and
//! identical initialization the replica parameters stay equal, so a
//! single parameter copy represents all replicas exactly.

use crate::codec::quantizer::{Rounding, UniformQuantizer};
use crate::codec::quant_wire_bytes;
use crate::util::Rng;

pub struct DpGroup {
    pub degree: usize,
    /// None = uncompressed (fp32) gradient exchange.
    pub bits: Option<u8>,
    /// error-feedback residuals: [replica][stage] -> flat residual
    err: Vec<Vec<Vec<f32>>>,
    rounding: Rounding,
    rng: Rng,
}

impl DpGroup {
    pub fn new(degree: usize, bits: Option<u8>, stage_sizes: &[usize], rounding: Rounding) -> Self {
        let err = (0..degree)
            .map(|_| stage_sizes.iter().map(|&n| vec![0f32; n]).collect())
            .collect();
        DpGroup { degree, bits, err, rounding, rng: Rng::new(0xD9) }
    }

    /// Average per-replica per-stage gradients; returns (mean gradients,
    /// wire bytes each replica sends in the all-reduce).
    pub fn reduce(&mut self, grads: &[Vec<Vec<f32>>]) -> (Vec<Vec<f32>>, u64) {
        assert_eq!(grads.len(), self.degree);
        let n_stages = grads[0].len();
        let mut wire = 0u64;
        let mut mean: Vec<Vec<f32>> =
            grads[0].iter().map(|g| vec![0f32; g.len()]).collect();
        match self.bits {
            None => {
                for r in grads {
                    for (s, g) in r.iter().enumerate() {
                        for (m, &v) in mean[s].iter_mut().zip(g) {
                            *m += v;
                        }
                    }
                }
                for s in 0..n_stages {
                    wire += 4 * grads[0][s].len() as u64;
                }
            }
            Some(bits) => {
                let q = UniformQuantizer::new(bits, self.rounding);
                for (ri, r) in grads.iter().enumerate() {
                    for (s, g) in r.iter().enumerate() {
                        let e = &mut self.err[ri][s];
                        assert_eq!(e.len(), g.len());
                        // e += g
                        for (ei, &gi) in e.iter_mut().zip(g) {
                            *ei += gi;
                        }
                        // q = Q(e); e -= deq(q); mean += deq(q)
                        let mut codes = vec![0u8; e.len()];
                        let scale = q.encode(e, &mut codes, &mut self.rng);
                        let mut deq = vec![0f32; e.len()];
                        q.decode(&codes, scale, &mut deq);
                        for j in 0..e.len() {
                            e[j] -= deq[j];
                            mean[s][j] += deq[j];
                        }
                        if ri == 0 {
                            // every replica sends the same volume
                        }
                    }
                }
                for s in 0..n_stages {
                    wire += quant_wire_bytes(grads[0][s].len(), bits);
                }
            }
        }
        let inv = 1.0 / self.degree as f32;
        for s in mean.iter_mut() {
            for v in s.iter_mut() {
                *v *= inv;
            }
        }
        (mean, wire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads(degree: usize, n: usize, seed: u64) -> Vec<Vec<Vec<f32>>> {
        let mut rng = Rng::new(seed);
        (0..degree)
            .map(|_| vec![(0..n).map(|_| rng.normal() * 0.1).collect::<Vec<f32>>()])
            .collect()
    }

    #[test]
    fn uncompressed_is_exact_mean() {
        let g = grads(4, 32, 1);
        let mut dp = DpGroup::new(4, None, &[32], Rounding::Nearest);
        let (mean, wire) = dp.reduce(&g);
        for j in 0..32 {
            let want: f32 = g.iter().map(|r| r[0][j]).sum::<f32>() / 4.0;
            assert!((mean[0][j] - want).abs() < 1e-6);
        }
        assert_eq!(wire, 128);
    }

    #[test]
    fn error_feedback_preserves_signal_over_time() {
        // summed over many rounds, compressed mean ~ true mean (error
        // feedback makes the bias vanish) — the 1-bit-Adam property.
        let degree = 2;
        let n = 64;
        let mut dp = DpGroup::new(degree, Some(4), &[n], Rounding::Nearest);
        let mut rng = Rng::new(3);
        let constant: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
        let mut acc = vec![0f64; n];
        let rounds = 200;
        for _ in 0..rounds {
            let g: Vec<Vec<Vec<f32>>> = (0..degree)
                .map(|_| {
                    vec![constant
                        .iter()
                        .map(|&c| c + 0.001 * rng.normal())
                        .collect::<Vec<f32>>()]
                })
                .collect();
            let (mean, _) = dp.reduce(&g);
            for (a, &m) in acc.iter_mut().zip(&mean[0]) {
                *a += m as f64;
            }
        }
        for (a, &c) in acc.iter().zip(&constant) {
            let avg = *a / rounds as f64;
            assert!((avg - c as f64).abs() < 3e-3, "{avg} vs {c}");
        }
    }

    #[test]
    fn compressed_wire_is_smaller() {
        let g = grads(2, 1000, 5);
        let mut fp = DpGroup::new(2, None, &[1000], Rounding::Nearest);
        let mut q4 = DpGroup::new(2, Some(4), &[1000], Rounding::Nearest);
        let (_, w_fp) = fp.reduce(&g);
        let (_, w_q) = q4.reduce(&g);
        assert!(w_q * 7 < w_fp, "{w_q} vs {w_fp}");
    }
}
