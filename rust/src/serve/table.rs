//! Per-session codec state for the serving front end.
//!
//! The training stack keys codec replicas per *link* — fine when one
//! trusted pipeline owns the link. A serving front end multiplexes many
//! mutually-invisible clients over shared stages, and AQ-SGD's
//! per-example buffers are *state*: if two sessions shared a replica,
//! one client's activations would become another client's delta
//! baseline (a correctness bug and a data leak). So the table keys an
//! independent (encoder, decoder) replica set per (session, boundary),
//! seeded by a derivation both ends compute from (base seed, session
//! id) alone — a client's numerics depend only on its own traffic.

use std::collections::BTreeMap;

use crate::codec::quantizer::Rounding;
use crate::codec::registry::{build_mem_pair, CodecSpec};
use crate::net::plane::{
    session_endpoint_rx, session_endpoint_tx, SessionEndpointRx, SessionEndpointTx,
};
use crate::util::error::Result;

/// Splitmix-style seed derivation shared by client and server, so the
/// two halves of each replica pair are built from identical inputs
/// without any seed exchange on the wire.
fn mix(base: u64, salt: u64, session: u32) -> u64 {
    (base ^ salt).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(session as u64)
}

/// Seed for the forward (client→server activation) boundary of `session`.
pub fn session_fw_seed(base: u64, session: u32) -> u64 {
    mix(base, 0xF00D_FACE, session)
}

/// Seed for the backward (server→client gradient) boundary of `session`.
pub fn session_bw_seed(base: u64, session: u32) -> u64 {
    mix(base, 0xBACC_FACE, session)
}

/// Seed of a session's private data shard (client-side only; listed here
/// so every per-session seed derivation lives in one place).
pub fn session_data_seed(base: u64, session: u32) -> u64 {
    mix(base, 0xDA7A_DA7A, session)
}

/// Seed of a session's trainable cut-layer parameters (client-side only).
pub fn session_cut_seed(base: u64, session: u32) -> u64 {
    mix(base, 0xC117_C117, session)
}

/// Build the *client* halves for one session: the forward encoder it
/// sends activations through and the backward decoder it reads
/// gradients with. Mirrors [`SessionTable::open`] exactly — same
/// registry builds, same seeds — so the pairs stay bit-lockstep.
pub fn client_endpoints(
    spec: &CodecSpec,
    example_len: usize,
    rounding: Rounding,
    base_seed: u64,
    session: u32,
) -> Result<(SessionEndpointTx, SessionEndpointRx)> {
    let fw_enc =
        build_mem_pair(&spec.fw, example_len, rounding, session_fw_seed(base_seed, session))?.0;
    let bw_dec =
        build_mem_pair(&spec.bw, example_len, rounding, session_bw_seed(base_seed, session))?.1;
    Ok((
        session_endpoint_tx(session, example_len, fw_enc),
        session_endpoint_rx(session, example_len, bw_dec),
    ))
}

/// Server-side state for one live session.
pub struct SessionEntry {
    pub finetune: bool,
    /// Decodes this session's incoming activations (replica of the
    /// client's forward encoder).
    pub fw: SessionEndpointRx,
    /// Encodes this session's outgoing gradients / head rows (the
    /// client holds the matching decoder).
    pub bw: SessionEndpointTx,
    /// Requests served so far (monotone, for reporting).
    pub requests: u64,
}

/// All live sessions' codec replicas, keyed by session id.
pub struct SessionTable {
    spec: CodecSpec,
    example_len: usize,
    rounding: Rounding,
    base_seed: u64,
    entries: BTreeMap<u32, SessionEntry>,
    /// High-water mark of concurrently open sessions.
    pub peak: usize,
}

impl SessionTable {
    pub fn new(spec: CodecSpec, example_len: usize, rounding: Rounding, base_seed: u64) -> Self {
        SessionTable {
            spec,
            example_len,
            rounding,
            base_seed,
            entries: BTreeMap::new(),
            peak: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Open a session: build its replica set (server keeps the forward
    /// decoder + backward encoder). Duplicate ids are a protocol error.
    pub fn open(&mut self, session: u32, finetune: bool) -> Result<()> {
        crate::ensure!(
            !self.entries.contains_key(&session),
            "session {session} already open"
        );
        let fw_dec = build_mem_pair(
            &self.spec.fw,
            self.example_len,
            self.rounding,
            session_fw_seed(self.base_seed, session),
        )?
        .1;
        let bw_enc = build_mem_pair(
            &self.spec.bw,
            self.example_len,
            self.rounding,
            session_bw_seed(self.base_seed, session),
        )?
        .0;
        self.entries.insert(
            session,
            SessionEntry {
                finetune,
                fw: session_endpoint_rx(session, self.example_len, fw_dec),
                bw: session_endpoint_tx(session, self.example_len, bw_enc),
                requests: 0,
            },
        );
        self.peak = self.peak.max(self.entries.len());
        Ok(())
    }

    pub fn get_mut(&mut self, session: u32) -> Option<&mut SessionEntry> {
        self.entries.get_mut(&session)
    }

    /// Drop a session's replicas, returning the entry so the caller can
    /// report its final codec state to the client.
    pub fn close(&mut self, session: u32) -> Option<SessionEntry> {
        self.entries.remove(&session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CodecSpec {
        CodecSpec::parse("aqsgd:fw2bw4").expect("spec")
    }

    #[test]
    fn open_duplicate_and_close() {
        let mut t = SessionTable::new(spec(), 8, Rounding::Stochastic, 11);
        t.open(1, true).expect("open 1");
        t.open(2, false).expect("open 2");
        assert!(t.open(1, true).is_err(), "duplicate open must fail");
        assert_eq!(t.len(), 2);
        assert_eq!(t.peak, 2);
        assert!(t.close(1).is_some());
        assert!(t.close(1).is_none());
        assert_eq!(t.len(), 1);
        assert_eq!(t.peak, 2, "peak is a high-water mark");
    }

    #[test]
    fn client_and_server_halves_are_lockstep_replicas() {
        let mut t = SessionTable::new(spec(), 4, Rounding::Stochastic, 7);
        t.open(3, true).expect("open");
        let (mut ctx, mut crx) =
            client_endpoints(&spec(), 4, Rounding::Stochastic, 7, 3).expect("client");
        let e = t.get_mut(3).expect("entry");

        let ids = [42u64];
        let a = [0.5f32, -1.0, 0.25, 2.0];
        // forward: client encodes, server decodes; a revisit must ride the
        // delta path, which only works if the buffer replicas agree.
        for round in 0..3 {
            let (_, bytes) = ctx.encode(&ids, &a).expect("enc");
            let owned = bytes.to_vec();
            let got = e.fw.decode(&ids, &owned).expect("dec");
            assert_eq!(got.len(), 4, "round {round}");
        }
        assert_eq!(
            ctx.state_bytes(),
            e.fw.state_bytes(),
            "fw replica buffers must hold identical state"
        );
        // backward: server encodes, client decodes.
        let g = [0.1f32, 0.2, -0.3, 0.4];
        let (_, bytes) = e.bw.encode(&ids, &g).expect("enc");
        let owned = bytes.to_vec();
        let got = crx.decode(&ids, &owned).expect("dec");
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn sessions_do_not_share_codec_state() {
        let mut t = SessionTable::new(spec(), 4, Rounding::Stochastic, 7);
        t.open(1, true).expect("open 1");
        t.open(2, true).expect("open 2");
        let (mut c1, _) = client_endpoints(&spec(), 4, Rounding::Stochastic, 7, 1).expect("c1");
        let (mut c2, _) = client_endpoints(&spec(), 4, Rounding::Stochastic, 7, 2).expect("c2");

        // Both sessions send the SAME example id: if replicas were shared,
        // session 2's first visit would wrongly take the delta path after
        // session 1 populated the buffer.
        let ids = [7u64];
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let f1 = c1.encode(&ids, &a).expect("enc1").1.to_vec();
        let f2 = c2.encode(&ids, &a).expect("enc2").1.to_vec();
        assert_eq!(f1, f2, "identical first visits must encode identically");
        t.get_mut(1).unwrap().fw.decode(&ids, &f1).expect("dec1");
        t.get_mut(2).unwrap().fw.decode(&ids, &f2).expect("dec2");

        // Second visit: still identical across sessions (each against its
        // OWN buffer), and a delta frame differs from the first visit.
        let a2 = [1.5f32, 2.5, 3.5, 4.5];
        let d1 = c1.encode(&ids, &a2).expect("enc1b").1.to_vec();
        let d2 = c2.encode(&ids, &a2).expect("enc2b").1.to_vec();
        assert_eq!(d1, d2, "isolated sessions with equal traffic stay bit-equal");
        assert_ne!(d1, f1, "revisit takes the delta path");
    }
}
