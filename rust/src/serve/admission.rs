//! Admission control for the serving front end: a token-bucket rate
//! limit + hard cap on concurrent sessions, and a queue-depth shed for
//! requests once the batcher backs up. Refusals are *descriptive* — the
//! returned reason ships to the client in a session-scoped reject frame
//! (`net::session::reject_session_bytes`), never as a silent drop.
//!
//! A shed request is safe by construction: it is refused *before* the
//! server-side codec replica decodes the frame, and the client
//! retransmits the cached bytes — so sender and receiver buffer state
//! never desynchronize (the replica-symmetry invariant of Algorithm 2).

use std::time::Instant;

/// Knobs. Defaults are deliberately permissive: a modest fleet (the CI
/// smoke runs 64 sessions, the acceptance test 1000) must see zero
/// false rejects without tuning.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionCfg {
    /// Hard cap on concurrently open sessions.
    pub max_sessions: usize,
    /// Token-bucket refill rate for session opens, tokens per second.
    pub open_rate: f64,
    /// Token-bucket capacity (burst of opens admitted from a full
    /// bucket).
    pub open_burst: f64,
    /// Shed incoming requests once this many rows wait in the batcher.
    pub queue_depth: usize,
}

impl Default for AdmissionCfg {
    fn default() -> Self {
        AdmissionCfg {
            max_sessions: 4096,
            open_rate: 1e6,
            open_burst: 4096.0,
            queue_depth: 8192,
        }
    }
}

/// The gate itself. Time is passed in (never read from a clock inside),
/// so tests drive it with synthetic instants.
pub struct Admission {
    cfg: AdmissionCfg,
    tokens: f64,
    last: Option<Instant>,
    /// Sessions refused at open (cap or rate).
    pub rejected_opens: u64,
    /// Requests shed on queue depth.
    pub shed_requests: u64,
}

impl Admission {
    pub fn new(cfg: AdmissionCfg) -> Self {
        Admission { cfg, tokens: cfg.open_burst, last: None, rejected_opens: 0, shed_requests: 0 }
    }

    fn refill(&mut self, now: Instant) {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens = (self.tokens + dt * self.cfg.open_rate).min(self.cfg.open_burst);
        }
        self.last = Some(now);
    }

    /// May a new session open, given `live` already in the table?
    /// `None` = admitted (one token consumed); `Some(reason)` = refused.
    pub fn admit_open(&mut self, now: Instant, live: usize) -> Option<String> {
        if live >= self.cfg.max_sessions {
            self.rejected_opens += 1;
            return Some(format!(
                "session table full: {live} live sessions (cap {})",
                self.cfg.max_sessions
            ));
        }
        self.refill(now);
        if self.tokens < 1.0 {
            self.rejected_opens += 1;
            return Some(format!(
                "session open rate exceeded: {:.1} opens/s sustained, burst {}",
                self.cfg.open_rate, self.cfg.open_burst
            ));
        }
        self.tokens -= 1.0;
        None
    }

    /// May a request enter the batcher, given its current depth?
    pub fn admit_request(&mut self, depth: usize) -> Option<String> {
        if depth >= self.cfg.queue_depth {
            self.shed_requests += 1;
            return Some(format!(
                "server overloaded: {depth} rows queued (shed threshold {})",
                self.cfg.queue_depth
            ));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn session_cap_refuses_with_the_cap_in_the_reason() {
        let mut a = Admission::new(AdmissionCfg { max_sessions: 2, ..AdmissionCfg::default() });
        let t0 = Instant::now();
        assert!(a.admit_open(t0, 0).is_none());
        assert!(a.admit_open(t0, 1).is_none());
        let why = a.admit_open(t0, 2).expect("over cap");
        assert!(why.contains("cap 2"), "{why}");
        assert_eq!(a.rejected_opens, 1);
    }

    #[test]
    fn token_bucket_drains_and_refills() {
        let mut a = Admission::new(AdmissionCfg {
            open_rate: 10.0,
            open_burst: 2.0,
            ..AdmissionCfg::default()
        });
        let t0 = Instant::now();
        assert!(a.admit_open(t0, 0).is_none());
        assert!(a.admit_open(t0, 0).is_none());
        let why = a.admit_open(t0, 0).expect("bucket empty");
        assert!(why.contains("rate exceeded"), "{why}");
        // 150 ms at 10 tokens/s = 1.5 tokens: one more open fits
        let t1 = t0 + Duration::from_millis(150);
        assert!(a.admit_open(t1, 0).is_none());
        assert!(a.admit_open(t1, 0).is_some());
        assert_eq!(a.rejected_opens, 2);
    }

    #[test]
    fn queue_depth_sheds_requests() {
        let mut a = Admission::new(AdmissionCfg { queue_depth: 4, ..AdmissionCfg::default() });
        assert!(a.admit_request(3).is_none());
        let why = a.admit_request(4).expect("at threshold");
        assert!(why.contains("4 rows queued"), "{why}");
        assert_eq!(a.shed_requests, 1);
    }

    #[test]
    fn defaults_admit_a_thousand_session_fleet_instantly() {
        let mut a = Admission::new(AdmissionCfg::default());
        let t0 = Instant::now();
        for live in 0..1000 {
            assert!(a.admit_open(t0, live).is_none(), "false reject at {live}");
        }
        assert_eq!(a.rejected_opens, 0);
    }
}
