//! The serving wire envelope: many sessions multiplex one frame
//! transport, so every client⇄server message is a [`TAG_SESSION`] frame
//! whose header says which session/request it belongs to and whose
//! payload wraps the inner codec frame (or handshake text). Rejects ride
//! the `net::session` session-scoped reject machinery unchanged, so a
//! shed request and a config-mismatched handshake speak the same frame.
//!
//! Header layout (fixed for every kind):
//! `kind u8 | session u32 | seq u32 | example u64 (2×u32 LE) | flags u8 |
//! loss f32 | aux u32`.

use crate::codec::frame::{Frame, FrameReader, FrameView, FrameWriter, TAG_HELLO, TAG_SESSION};
use crate::net::session::{decode_session_reject, SessionReject};
use crate::util::error::Result;

/// Envelope kinds. `seq` is 0 only during the open handshake, so a
/// reject with `seq == 0` refuses the session itself while `seq > 0`
/// sheds one request (the client retransmits the cached frame).
pub const ENV_OPEN: u8 = 1;
pub const ENV_ACCEPT: u8 = 2;
pub const ENV_REQ: u8 = 3;
pub const ENV_REP: u8 = 4;
pub const ENV_CLOSE: u8 = 5;
pub const ENV_CLOSED: u8 = 6;

/// Flag bit: this session fine-tunes its cut layer (requests carry
/// targets, replies carry the cut gradient + loss). Clear = inference.
pub const FLAG_FINETUNE: u8 = 1;

/// One parsed envelope header + borrowed payload.
#[derive(Clone, Copy, Debug)]
pub struct Envelope<'a> {
    pub kind: u8,
    pub session: u32,
    pub seq: u32,
    pub example: u64,
    pub flags: u8,
    pub loss: f32,
    /// Kind-specific scalar: `ENV_REQ` = number of target f32s at the
    /// front of the payload; unused otherwise.
    pub aux: u32,
    pub payload: &'a [u8],
}

/// Everything a serve transport can deliver.
pub enum ServeMsg<'a> {
    Env(Envelope<'a>),
    Reject(SessionReject),
}

/// Owned header fields for building an envelope.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnvHead {
    pub kind: u8,
    pub session: u32,
    pub seq: u32,
    pub example: u64,
    pub flags: u8,
    pub loss: f32,
    pub aux: u32,
}

/// Serialize one envelope frame.
pub fn env_bytes(h: &EnvHead, payload: &[u8]) -> Vec<u8> {
    let mut w = FrameWriter::with_capacity(26);
    w.u8(h.kind)
        .u32(h.session)
        .u32(h.seq)
        .u32(h.example as u32)
        .u32((h.example >> 32) as u32)
        .u8(h.flags)
        .f32(h.loss)
        .u32(h.aux);
    Frame::new(TAG_SESSION, w.finish(), payload.to_vec()).to_bytes()
}

/// Parse one serve-transport frame: a session envelope or a
/// session-scoped reject. Anything else is a protocol error.
pub fn parse(bytes: &[u8]) -> Result<ServeMsg<'_>> {
    let v = FrameView::parse(bytes)?;
    if v.tag() == TAG_HELLO {
        if let Some(r) = decode_session_reject(bytes)? {
            return Ok(ServeMsg::Reject(r));
        }
        crate::bail!("serve transport got a non-reject handshake frame");
    }
    crate::ensure!(
        v.tag() == TAG_SESSION,
        "serve transport expected a session envelope, got tag {}",
        v.tag()
    );
    let mut r = FrameReader::new(v.header());
    let kind = r.u8()?;
    let session = r.u32()?;
    let seq = r.u32()?;
    let example = r.u32()? as u64 | ((r.u32()? as u64) << 32);
    let flags = r.u8()?;
    let loss = r.f32()?;
    let aux = r.u32()?;
    r.done()?;
    crate::ensure!(
        (ENV_OPEN..=ENV_CLOSED).contains(&kind),
        "unknown serve envelope kind {kind}"
    );
    Ok(ServeMsg::Env(Envelope {
        kind,
        session,
        seq,
        example,
        flags,
        loss,
        aux,
        payload: v.payload(),
    }))
}

/// `ENV_CLOSED` payload: the server-side codec replica state the session
/// table held for this session at close, for the client to pin.
pub fn closed_payload(fw_dec_state: u64, bw_enc_state: u64) -> Vec<u8> {
    let mut w = FrameWriter::with_capacity(16);
    w.u32(fw_dec_state as u32)
        .u32((fw_dec_state >> 32) as u32)
        .u32(bw_enc_state as u32)
        .u32((bw_enc_state >> 32) as u32);
    w.finish()
}

/// Parse an `ENV_CLOSED` payload back into (fw decoder, bw encoder)
/// resident state bytes.
pub fn parse_closed_payload(payload: &[u8]) -> Result<(u64, u64)> {
    let mut r = FrameReader::new(payload);
    let fw = r.u32()? as u64 | ((r.u32()? as u64) << 32);
    let bw = r.u32()? as u64 | ((r.u32()? as u64) << 32);
    r.done()?;
    Ok((fw, bw))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::session::reject_session_bytes;

    #[test]
    fn envelope_roundtrips_every_field() {
        let h = EnvHead {
            kind: ENV_REQ,
            session: 77,
            seq: 3,
            example: 0xDEAD_BEEF_0000_0042,
            flags: FLAG_FINETUNE,
            loss: 1.25,
            aux: 64,
        };
        let b = env_bytes(&h, &[9, 8, 7]);
        match parse(&b).expect("parse") {
            ServeMsg::Env(e) => {
                assert_eq!(e.kind, ENV_REQ);
                assert_eq!(e.session, 77);
                assert_eq!(e.seq, 3);
                assert_eq!(e.example, 0xDEAD_BEEF_0000_0042);
                assert_eq!(e.flags, FLAG_FINETUNE);
                assert_eq!(e.loss.to_bits(), 1.25f32.to_bits());
                assert_eq!(e.aux, 64);
                assert_eq!(e.payload, &[9, 8, 7]);
            }
            ServeMsg::Reject(_) => panic!("expected envelope"),
        }
    }

    #[test]
    fn rejects_parse_through_the_session_machinery() {
        let b = reject_session_bytes(5, 2, "overloaded");
        match parse(&b).expect("parse") {
            ServeMsg::Reject(r) => {
                assert_eq!(r.session, 5);
                assert_eq!(r.seq, 2);
                assert_eq!(r.reason, "overloaded");
            }
            ServeMsg::Env(_) => panic!("expected reject"),
        }
    }

    #[test]
    fn closed_payload_roundtrips_u64s() {
        let p = closed_payload(u64::MAX - 7, 0x0102_0304_0506_0708);
        assert_eq!(parse_closed_payload(&p).expect("parse"), (u64::MAX - 7, 0x0102_0304_0506_0708));
    }

    #[test]
    fn unknown_kind_is_a_descriptive_error() {
        let b = env_bytes(&EnvHead { kind: 200, ..EnvHead::default() }, &[]);
        let err = parse(&b).unwrap_err().to_string();
        assert!(err.contains("kind 200"), "{err}");
    }
}
