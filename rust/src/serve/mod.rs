//! Session-multiplexed serving front end: many concurrent clients drive
//! split-inference and split-fine-tune sessions against ONE shared set
//! of frozen server pipeline stages, over compressed links.
//!
//! Layout (every box is one event task on the PR-6 worker pool):
//!
//! ```text
//!  client 0 ─┐                         ┌─ stage 1 ─ … ─ stage k (head)
//!  client 1 ─┤ shared ingress          │     ▲ fwd batches   │ bwd
//!     ⋮      ├───────────────▶ gateway ┘     └───────────────┘
//!  client n ─┘   per-client    │  session table · admission · batcher
//!      ▲         reply links   │
//!      └───────────────────────┘
//! ```
//!
//! * The **gateway** owns the [`SessionTable`] (per-(session, boundary)
//!   codec replicas — never shared across clients), the [`Admission`]
//!   gate, and the [`Batcher`] that coalesces decoded rows from distinct
//!   sessions into fixed-size microbatches for the stages.
//! * **Server stages** are frozen `ToyStage`s: forward + `grad_input`
//!   only, no parameter updates — one client's traffic cannot move the
//!   model another client sees.
//! * **Clients** are closed-loop: own trainable cut layer + private
//!   shard; fine-tune sessions upload cut activations and apply the
//!   returned cut gradient locally, inference sessions digest head rows.
//!
//! **Per-session bit-identity.** A session's numerics depend only on
//! (config, session id): stage compute is elementwise per row, AQ frames
//! carry one scale per example record, codec replicas are per-session,
//! server stages are frozen, padding rows never touch codecs, and a shed
//! request is refused *before* the server replica decodes it (the client
//! retransmits the cached bytes). So any interleaving of sessions —
//! alone, batched with strangers, shed and resent — produces the same
//! loss bits, parameter digest, and codec state per session. Pinned by
//! `tests/prop_serve.rs`.

pub mod admission;
pub mod batch;
pub mod table;
pub mod wire;

use std::collections::{HashMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::codec::registry::CodecSpec;
use crate::codec::Rounding;
use crate::net::channel::frame_link;
use crate::net::plane::{SessionEndpointRx, SessionEndpointTx};
use crate::net::tcp::LinkShape;
use crate::net::{Doorbell, FrameLink, FrameRx, FrameTx, IoDriver, Poll, RealLink, RealReceiver, TryRecv};
use crate::pipeline::exec::{run_event_pool, PoolTask, TaskAdvance, ToyStage};
use crate::util::error::Result;
use crate::util::Rng;

use admission::{Admission, AdmissionCfg};
use batch::{BatchCfg, Batcher, PendingRow};
use table::{client_endpoints, session_cut_seed, session_data_seed, SessionTable};
use wire::{
    env_bytes, EnvHead, Envelope, ServeMsg, ENV_ACCEPT, ENV_CLOSE, ENV_CLOSED, ENV_OPEN, ENV_REP,
    ENV_REQ, FLAG_FINETUNE,
};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// Configuration

/// One serving run: fleet shape, codec, knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Concurrent client sessions (each is one event task).
    pub sessions: usize,
    /// Frozen server stages after the client-held cut layer.
    pub server_stages: usize,
    /// Elements per activation row (the boundary width).
    pub example_len: usize,
    pub spec: CodecSpec,
    pub rounding: Rounding,
    pub seed: u64,
    /// Client-side cut-layer SGD step per reply.
    pub lr: f32,
    /// Examples in each session's private shard.
    pub shard: usize,
    /// Passes over the shard (>= 2 exercises the AQ delta path).
    pub epochs: usize,
    /// Every Nth session runs split inference instead of fine-tuning
    /// (0 = every session fine-tunes).
    pub infer_every: usize,
    pub batch: BatchCfg,
    pub admission: AdmissionCfg,
    /// Event-pool worker threads.
    pub workers: usize,
    /// Pacing of the client⇄gateway links.
    pub bandwidth_bps: f64,
    pub latency: Duration,
    /// `None` for in-process runs (a stalled pool is a bug); `Some` when
    /// frames arrive from other processes over sockets.
    pub stall_timeout: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            sessions: 64,
            server_stages: 2,
            example_len: 8,
            spec: CodecSpec::aqsgd(2, 4),
            rounding: Rounding::Stochastic,
            seed: 7,
            lr: 0.05,
            shard: 4,
            epochs: 2,
            infer_every: 4,
            batch: BatchCfg::default(),
            admission: AdmissionCfg::default(),
            workers: 4,
            bandwidth_bps: 1e9,
            latency: Duration::from_micros(50),
            stall_timeout: None,
        }
    }
}

/// Config fingerprint a client presents at `ENV_OPEN`: everything that
/// must agree for the two ends' codec replicas and stage math to match.
/// Mismatch ⇒ descriptive reject. Learning rate as raw bits — text
/// formatting must not make two unequal configs look equal.
pub fn serve_summary(cfg: &ServeConfig) -> String {
    format!(
        "serve k={} el={} spec={} round={:?} seed={} lr={:08x} shard={} epochs={}",
        cfg.server_stages,
        cfg.example_len,
        cfg.spec.label(),
        cfg.rounding,
        cfg.seed,
        cfg.lr.to_bits(),
        cfg.shard,
        cfg.epochs,
    )
}

fn is_infer(cfg: &ServeConfig, session: u32) -> bool {
    cfg.infer_every > 0 && (session as usize) % cfg.infer_every == 0
}

/// Seed of frozen server stage `s` — depends on config alone, never on
/// the session fleet, so every client sees the same model.
fn server_stage_seed(seed: u64, s: usize) -> u64 {
    seed.wrapping_mul(0x9E37_79B9) ^ (0x5EA7_0000 + ((s as u64) << 8))
}

fn validate(cfg: &ServeConfig) -> Result<()> {
    crate::ensure!(cfg.server_stages >= 1, "serve needs at least one server stage");
    crate::ensure!(cfg.example_len >= 1, "serve needs a non-empty activation row");
    crate::ensure!(cfg.shard >= 1 && cfg.epochs >= 1, "serve sessions need work to do");
    crate::ensure!(cfg.batch.rows >= 1, "serve batcher needs at least one row per batch");
    crate::ensure!(cfg.workers >= 1, "serve needs at least one pool worker");
    Ok(())
}

// ---------------------------------------------------------------------------
// Inter-stage batch messages (typed channel, unpaced: stages share the
// server host; the modeled slow network is the client links)

#[derive(Clone, Copy, Debug, Default)]
struct RowMeta {
    session: u32,
    seq: u32,
    example: u64,
    finetune: bool,
    pad: bool,
}

#[derive(Debug, Default)]
struct BatchMsg {
    id: u64,
    rows: Vec<RowMeta>,
    /// fwd: stage input activations `[b*el]`; bwd: grad wrt stage input.
    data: Vec<f32>,
    /// fwd only: target rows `[b*el]` (zeros for inference/pad rows).
    targets: Vec<f32>,
    /// bwd only: per-row loss (0 for inference/pad rows).
    losses: Vec<f32>,
    /// bwd only: head-stage outputs `[b*el]` (inference replies).
    head: Vec<f32>,
    /// Last message of the run: relayed down the chain and bounced back,
    /// retiring each stage in order.
    shutdown: bool,
}

fn unpaced<T>() -> (RealLink<T>, RealReceiver<T>) {
    RealLink::channel(f64::INFINITY, Duration::ZERO)
}

fn earlier(a: Option<Instant>, b: Instant) -> Option<Instant> {
    Some(a.map_or(b, |a| a.min(b)))
}

// ---------------------------------------------------------------------------
// Server stage task (frozen)

struct StageTask {
    stage: ToyStage,
    head: bool,
    el: usize,
    fwd_in: RealReceiver<BatchMsg>,
    /// `None` on the head stage.
    fwd_out: Option<RealLink<BatchMsg>>,
    /// `None` on the head stage (it originates the bwd direction).
    bwd_in: Option<RealReceiver<BatchMsg>>,
    bwd_out: RealLink<BatchMsg>,
    /// Saved forward outputs per batch, FIFO — the fwd and bwd chains
    /// are FIFO links, so batches retire in emission order.
    saved: VecDeque<(u64, Vec<f32>)>,
    fwd_done: bool,
    finished: bool,
}

impl StageTask {
    fn on_fwd(&mut self, m: BatchMsg) -> Result<()> {
        if m.shutdown {
            match &mut self.fwd_out {
                Some(out) => {
                    out.send(m, 0);
                    self.fwd_done = true;
                }
                None => {
                    // head: bounce the shutdown into the bwd chain
                    self.bwd_out.send(m, 0);
                    self.finished = true;
                }
            }
            return Ok(());
        }
        let y = self.stage.forward(&m.data);
        match &mut self.fwd_out {
            Some(out) => {
                self.saved.push_back((m.id, y.clone()));
                out.send(BatchMsg { data: y, ..m }, 0);
            }
            None => {
                // head: per-row MSE loss + cut-direction gradient for
                // fine-tune rows; inference and pad rows get zeros (and a
                // zero gradient contributes nothing anywhere)
                let el = self.el;
                let mut losses = vec![0f32; m.rows.len()];
                let mut g = vec![0f32; y.len()];
                for (r, meta) in m.rows.iter().enumerate() {
                    if meta.pad || !meta.finetune {
                        continue;
                    }
                    let o = r * el;
                    let mut acc = 0f32;
                    for i in 0..el {
                        let d = y[o + i] - m.targets[o + i];
                        acc += d * d;
                        g[o + i] = 2.0 * d / el as f32;
                    }
                    losses[r] = acc / el as f32;
                }
                let dx = self.stage.grad_input(&y, &g);
                self.bwd_out.send(
                    BatchMsg {
                        id: m.id,
                        rows: m.rows,
                        data: dx,
                        targets: Vec::new(),
                        losses,
                        head: y,
                        shutdown: false,
                    },
                    0,
                );
            }
        }
        Ok(())
    }

    fn on_bwd(&mut self, m: BatchMsg) -> Result<()> {
        if m.shutdown {
            crate::ensure!(self.fwd_done, "serve stage: bwd shutdown before fwd shutdown");
            self.bwd_out.send(m, 0);
            self.finished = true;
            return Ok(());
        }
        let (id, y) = self
            .saved
            .pop_front()
            .ok_or_else(|| crate::err!("serve stage: gradient for a batch never forwarded"))?;
        crate::ensure!(id == m.id, "serve stage: batch retirement out of order ({id} vs {})", m.id);
        let dx = self.stage.grad_input(&y, &m.data);
        self.bwd_out.send(BatchMsg { data: dx, ..m }, 0);
        Ok(())
    }

    fn advance(&mut self) -> Result<TaskAdvance> {
        loop {
            let mut progress = false;
            if !self.fwd_done && !self.finished {
                match self.fwd_in.try_recv() {
                    TryRecv::Msg(_, m) => {
                        self.on_fwd(m)?;
                        progress = true;
                    }
                    TryRecv::Empty => {}
                    TryRecv::Closed => {
                        crate::bail!("serve stage: upstream closed before shutdown")
                    }
                }
            }
            if !self.finished {
                if let Some(bwd_in) = &self.bwd_in {
                    match bwd_in.try_recv() {
                        TryRecv::Msg(_, m) => {
                            self.on_bwd(m)?;
                            progress = true;
                        }
                        TryRecv::Empty => {}
                        TryRecv::Closed => {
                            crate::bail!("serve stage: downstream closed before shutdown")
                        }
                    }
                }
            }
            if self.finished {
                return Ok(TaskAdvance::Finished);
            }
            if !progress {
                return Ok(TaskAdvance::Pending(None));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Gateway task

/// Aggregate front-end counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatewayStats {
    pub batches: u64,
    /// Real rows batched (excludes padding).
    pub rows: u64,
    pub padded_rows: u64,
    pub shed_requests: u64,
    pub rejected_opens: u64,
    /// Opens refused on config-fingerprint mismatch.
    pub config_rejects: u64,
    /// High-water mark of concurrently open sessions.
    pub peak_sessions: usize,
}

struct GatewayTask {
    el: usize,
    summary: String,
    ingress: Vec<Box<dyn FrameRx>>,
    ingress_closed: Vec<bool>,
    reply: Vec<Box<dyn FrameTx>>,
    /// session id -> reply index. Prefilled in-process; learned from the
    /// originating connection at `ENV_OPEN` in socket mode.
    route: HashMap<u32, usize>,
    learn_route: bool,
    table: SessionTable,
    admission: Admission,
    batcher: Batcher,
    fwd_out: RealLink<BatchMsg>,
    grad_in: RealReceiver<BatchMsg>,
    expected_opens: usize,
    opens_seen: usize,
    accepted: usize,
    closed: usize,
    /// Decoded rows admitted but not yet replied (queued or in stages).
    in_flight: usize,
    next_batch: u64,
    shutdown_sent: bool,
    finished: bool,
    stats: GatewayStats,
}

impl GatewayTask {
    fn send_to(&mut self, idx: usize, frame: Vec<u8>) -> Result<()> {
        self.reply[idx].send(frame)
    }

    fn reply_idx(&self, session: u32) -> Result<usize> {
        self.route
            .get(&session)
            .copied()
            .ok_or_else(|| crate::err!("no reply route for session {session}"))
    }

    fn on_open(&mut self, ingress_idx: usize, e: Envelope<'_>) -> Result<()> {
        self.opens_seen += 1;
        if self.learn_route {
            self.route.insert(e.session, ingress_idx);
        }
        let idx = self.reply_idx(e.session)?;
        let got = String::from_utf8_lossy(e.payload).into_owned();
        if got != self.summary {
            self.stats.config_rejects += 1;
            let frame = crate::net::session::reject_session_bytes(
                e.session,
                0,
                &format!("config mismatch: client ran {got:?}, server runs {:?}", self.summary),
            );
            return self.send_to(idx, frame);
        }
        if let Some(reason) = self.admission.admit_open(Instant::now(), self.table.len()) {
            let frame = crate::net::session::reject_session_bytes(e.session, 0, &reason);
            return self.send_to(idx, frame);
        }
        self.table.open(e.session, e.flags & FLAG_FINETUNE != 0)?;
        self.accepted += 1;
        let head = EnvHead { kind: ENV_ACCEPT, session: e.session, ..EnvHead::default() };
        self.send_to(idx, env_bytes(&head, &[]))
    }

    fn on_req(&mut self, e: Envelope<'_>) -> Result<()> {
        let idx = self.reply_idx(e.session)?;
        crate::ensure!(e.seq > 0, "serve request with handshake seq 0");
        // Shed BEFORE the session's decoder replica sees the frame: the
        // client's encoder already advanced, so it retransmits the same
        // bytes and both replicas stay in sync.
        if let Some(reason) = self.admission.admit_request(self.batcher.depth()) {
            let frame = crate::net::session::reject_session_bytes(e.session, e.seq, &reason);
            return self.send_to(idx, frame);
        }
        let el = self.el;
        let n_t = e.aux as usize;
        crate::ensure!(
            e.payload.len() >= 4 * n_t,
            "serve request payload shorter than its {n_t} declared targets"
        );
        let entry = self
            .table
            .get_mut(e.session)
            .ok_or_else(|| crate::err!("request for session {} which is not open", e.session))?;
        if entry.finetune {
            crate::ensure!(n_t == el, "fine-tune request carries {n_t} targets, expected {el}");
        } else {
            crate::ensure!(n_t == 0, "inference request carries {n_t} targets");
        }
        let mut target = vec![0f32; el];
        for (i, chunk) in e.payload[..4 * n_t].chunks_exact(4).enumerate() {
            target[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        let x = entry.fw.decode(&[e.example], &e.payload[4 * n_t..])?;
        let finetune = entry.finetune;
        self.batcher.push(PendingRow {
            session: e.session,
            seq: e.seq,
            example: e.example,
            finetune,
            x,
            target,
            enqueued: Instant::now(),
        });
        self.in_flight += 1;
        Ok(())
    }

    fn on_close(&mut self, e: Envelope<'_>) -> Result<()> {
        let idx = self.reply_idx(e.session)?;
        let entry = self
            .table
            .close(e.session)
            .ok_or_else(|| crate::err!("close for session {} which is not open", e.session))?;
        self.closed += 1;
        let head = EnvHead { kind: ENV_CLOSED, session: e.session, seq: e.seq, ..EnvHead::default() };
        let payload = wire::closed_payload(entry.fw.state_bytes(), entry.bw.state_bytes());
        self.send_to(idx, env_bytes(&head, &payload))
    }

    fn handle(&mut self, ingress_idx: usize, bytes: &[u8]) -> Result<()> {
        match wire::parse(bytes)? {
            ServeMsg::Reject(r) => {
                crate::bail!("gateway received a reject frame for session {}", r.session)
            }
            ServeMsg::Env(e) => match e.kind {
                ENV_OPEN => self.on_open(ingress_idx, e),
                ENV_REQ => self.on_req(e),
                ENV_CLOSE => self.on_close(e),
                k => crate::bail!("unexpected serve envelope kind {k} at the gateway"),
            },
        }
    }

    fn emit_batch(&mut self) {
        let b = self.batcher.rows();
        let el = self.el;
        let rows = self.batcher.take();
        let mut meta = Vec::with_capacity(b);
        let mut data = vec![0f32; b * el];
        let mut targets = vec![0f32; b * el];
        for (r, row) in rows.iter().enumerate() {
            meta.push(RowMeta {
                session: row.session,
                seq: row.seq,
                example: row.example,
                finetune: row.finetune,
                pad: false,
            });
            data[r * el..(r + 1) * el].copy_from_slice(&row.x);
            targets[r * el..(r + 1) * el].copy_from_slice(&row.target);
        }
        self.stats.rows += rows.len() as u64;
        self.stats.padded_rows += (b - rows.len()) as u64;
        self.stats.batches += 1;
        for _ in rows.len()..b {
            meta.push(RowMeta { pad: true, ..RowMeta::default() });
        }
        let id = self.next_batch;
        self.next_batch += 1;
        self.fwd_out.send(
            BatchMsg {
                id,
                rows: meta,
                data,
                targets,
                losses: Vec::new(),
                head: Vec::new(),
                shutdown: false,
            },
            0,
        );
    }

    fn finish_batch(&mut self, m: BatchMsg) -> Result<()> {
        let el = self.el;
        for (r, meta) in m.rows.iter().enumerate() {
            if meta.pad {
                continue;
            }
            let o = r * el;
            let payload = {
                let entry = self.table.get_mut(meta.session).ok_or_else(|| {
                    crate::err!("session {} closed with requests in flight", meta.session)
                })?;
                let row = if meta.finetune { &m.data[o..o + el] } else { &m.head[o..o + el] };
                let (_, bytes) = entry.bw.encode(&[meta.example], row)?;
                entry.requests += 1;
                bytes.to_vec()
            };
            let head = EnvHead {
                kind: ENV_REP,
                session: meta.session,
                seq: meta.seq,
                example: meta.example,
                flags: if meta.finetune { FLAG_FINETUNE } else { 0 },
                loss: m.losses[r],
                aux: 0,
            };
            let idx = self.reply_idx(meta.session)?;
            self.send_to(idx, env_bytes(&head, &payload))?;
            self.in_flight -= 1;
        }
        Ok(())
    }

    fn advance(&mut self) -> Result<TaskAdvance> {
        loop {
            let mut progress = false;
            let mut deadline: Option<Instant> = None;
            // 1. retire batches coming back from the head
            loop {
                match self.grad_in.try_recv() {
                    TryRecv::Msg(_, m) => {
                        if m.shutdown {
                            self.finished = true;
                        } else {
                            self.finish_batch(m)?;
                        }
                        progress = true;
                    }
                    TryRecv::Empty => break,
                    TryRecv::Closed => crate::bail!("serve gateway: stage chain closed early"),
                }
            }
            if self.finished {
                self.stats.shed_requests = self.admission.shed_requests;
                self.stats.rejected_opens = self.admission.rejected_opens;
                self.stats.peak_sessions = self.table.peak;
                return Ok(TaskAdvance::Finished);
            }
            // 2. drain client frames
            for i in 0..self.ingress.len() {
                if self.ingress_closed[i] {
                    continue;
                }
                loop {
                    match self.ingress[i].poll() {
                        Poll::Ready => {
                            if let Some(bytes) = self.ingress[i].try_recv()? {
                                self.handle(i, &bytes)?;
                                progress = true;
                            }
                        }
                        Poll::Empty => break,
                        Poll::InFlight(at) => {
                            deadline = earlier(deadline, at);
                            break;
                        }
                        // a peer that closed after its sessions finished
                        // is fine; if sessions are still outstanding the
                        // stall detector reports the hang
                        Poll::Closed => {
                            self.ingress_closed[i] = true;
                            break;
                        }
                    }
                }
            }
            // 3. emit every batch that is due
            let now = Instant::now();
            while !self.batcher.is_empty() && self.batcher.ready(now) {
                self.emit_batch();
                progress = true;
            }
            // 4. all sessions done and nothing in flight: retire the run
            if !self.shutdown_sent
                && self.opens_seen == self.expected_opens
                && self.closed == self.accepted
                && self.in_flight == 0
                && self.batcher.is_empty()
            {
                let id = self.next_batch;
                self.fwd_out.send(BatchMsg { id, shutdown: true, ..BatchMsg::default() }, 0);
                self.shutdown_sent = true;
                continue;
            }
            if !progress {
                if let Some(at) = self.batcher.deadline() {
                    deadline = earlier(deadline, at);
                }
                return Ok(TaskAdvance::Pending(deadline));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client session task

/// Everything one session observed, for reports and bit-identity tests.
#[derive(Clone, Debug)]
pub struct SessionRecord {
    pub session: u32,
    pub finetune: bool,
    /// Per-request head loss (fine-tune sessions; empty for inference).
    pub losses: Vec<f32>,
    /// Request→reply round-trip per request, wall nanoseconds.
    pub latencies_ns: Vec<u64>,
    /// Requests shed by admission and retransmitted.
    pub shed: u64,
    /// `Some(reason)` if the session itself was refused at open.
    pub rejected: Option<String>,
    /// (fw encoder, bw decoder) resident codec state at close.
    pub client_state: (u64, u64),
    /// (fw decoder, bw encoder) resident state the server reported.
    pub server_state: (u64, u64),
    /// Cut-layer parameter digest at close.
    pub digest: u64,
    /// FNV over decoded head rows (inference sessions).
    pub infer_digest: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

fn fnv1a(h: u64, bits: u32) -> u64 {
    (h ^ bits as u64).wrapping_mul(0x0000_0100_0000_01B3)
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum ClientState {
    Opening,
    AwaitAccept,
    Running,
    AwaitClosed,
    Done,
}

struct PendingReq {
    bytes: Vec<u8>,
    x_idx: usize,
    y0: Vec<f32>,
    example: u64,
    seq: u32,
    sent: Instant,
}

struct ClientTask {
    session: u32,
    finetune: bool,
    summary: String,
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
    fw: SessionEndpointTx,
    bw: SessionEndpointRx,
    cut: ToyStage,
    data: Vec<Vec<f32>>,
    targets: Vec<Vec<f32>>,
    lr: f32,
    total: usize,
    next: usize,
    seq: u32,
    state: ClientState,
    pending: Option<PendingReq>,
    rec: SessionRecord,
}

impl ClientTask {
    fn send_next(&mut self) -> Result<()> {
        if self.next == self.total {
            self.seq += 1;
            let head =
                EnvHead { kind: ENV_CLOSE, session: self.session, seq: self.seq, ..EnvHead::default() };
            self.tx.send(env_bytes(&head, &[]))?;
            self.state = ClientState::AwaitClosed;
            return Ok(());
        }
        let idx = self.next % self.data.len();
        let y0 = self.cut.forward(&self.data[idx]);
        let example = ((self.session as u64 + 1) << 32) | idx as u64;
        self.seq += 1;
        let codec = {
            let (_, bytes) = self.fw.encode(&[example], &y0)?;
            bytes.to_vec()
        };
        let (payload, aux) = if self.finetune {
            let t = &self.targets[idx];
            let mut p = Vec::with_capacity(4 * t.len() + codec.len());
            for v in t {
                p.extend_from_slice(&v.to_le_bytes());
            }
            p.extend_from_slice(&codec);
            (p, t.len() as u32)
        } else {
            (codec, 0)
        };
        let head = EnvHead {
            kind: ENV_REQ,
            session: self.session,
            seq: self.seq,
            example,
            flags: if self.finetune { FLAG_FINETUNE } else { 0 },
            loss: 0.0,
            aux,
        };
        let bytes = env_bytes(&head, &payload);
        self.tx.send_from(&bytes)?;
        self.pending = Some(PendingReq {
            bytes,
            x_idx: idx,
            y0,
            example,
            seq: self.seq,
            sent: Instant::now(),
        });
        self.next += 1;
        Ok(())
    }

    fn on_rep(&mut self, e: Envelope<'_>) -> Result<()> {
        let p = self
            .pending
            .take()
            .ok_or_else(|| crate::err!("session {}: reply with no request in flight", self.session))?;
        crate::ensure!(
            e.seq == p.seq && e.example == p.example,
            "session {}: reply for seq {} example {:#x}, expected seq {} example {:#x}",
            self.session,
            e.seq,
            e.example,
            p.seq,
            p.example
        );
        self.rec.latencies_ns.push(p.sent.elapsed().as_nanos() as u64);
        let row = self.bw.decode(&[p.example], e.payload)?;
        if self.finetune {
            self.cut.backward(&self.data[p.x_idx], &p.y0, &row);
            let g = self.cut.take_step_grad(1.0);
            self.cut.apply_grad(self.lr, &g);
            self.rec.losses.push(e.loss);
        } else {
            for v in &row {
                self.rec.infer_digest = fnv1a(self.rec.infer_digest, v.to_bits());
            }
        }
        self.send_next()
    }

    fn on_frame(&mut self, bytes: &[u8]) -> Result<()> {
        match wire::parse(bytes)? {
            ServeMsg::Reject(r) => {
                crate::ensure!(
                    r.session == self.session,
                    "session {}: reject routed for session {}",
                    self.session,
                    r.session
                );
                if r.seq == 0 {
                    self.rec.rejected = Some(r.reason);
                    self.state = ClientState::Done;
                } else {
                    // one request shed: retransmit the SAME cached bytes —
                    // the fw encoder already advanced on this frame
                    self.rec.shed += 1;
                    let p = self.pending.as_ref().ok_or_else(|| {
                        crate::err!("session {}: shed reject with nothing in flight", self.session)
                    })?;
                    crate::ensure!(
                        p.seq == r.seq,
                        "session {}: shed reject for seq {}, in flight is {}",
                        self.session,
                        r.seq,
                        p.seq
                    );
                    let frame = p.bytes.clone();
                    self.tx.send(frame)?;
                }
                Ok(())
            }
            ServeMsg::Env(e) => {
                crate::ensure!(
                    e.session == self.session,
                    "session {}: frame routed for session {}",
                    self.session,
                    e.session
                );
                match e.kind {
                    ENV_ACCEPT => {
                        crate::ensure!(
                            self.state == ClientState::AwaitAccept,
                            "session {}: unexpected ACCEPT in state {:?}",
                            self.session,
                            self.state
                        );
                        self.state = ClientState::Running;
                        self.send_next()
                    }
                    ENV_REP => self.on_rep(e),
                    ENV_CLOSED => {
                        crate::ensure!(
                            self.state == ClientState::AwaitClosed,
                            "session {}: unexpected CLOSED in state {:?}",
                            self.session,
                            self.state
                        );
                        self.rec.server_state = wire::parse_closed_payload(e.payload)?;
                        self.rec.client_state = (self.fw.state_bytes(), self.bw.state_bytes());
                        self.rec.digest = self.cut.digest();
                        self.state = ClientState::Done;
                        Ok(())
                    }
                    k => crate::bail!("session {}: unexpected envelope kind {k}", self.session),
                }
            }
        }
    }

    fn advance(&mut self) -> Result<TaskAdvance> {
        loop {
            match self.state {
                ClientState::Opening => {
                    let head = EnvHead {
                        kind: ENV_OPEN,
                        session: self.session,
                        flags: if self.finetune { FLAG_FINETUNE } else { 0 },
                        ..EnvHead::default()
                    };
                    let frame = env_bytes(&head, self.summary.as_bytes());
                    self.tx.send(frame)?;
                    self.state = ClientState::AwaitAccept;
                }
                ClientState::Done => return Ok(TaskAdvance::Finished),
                _ => {}
            }
            match self.rx.poll() {
                Poll::Ready => {
                    if let Some(bytes) = self.rx.try_recv()? {
                        self.on_frame(&bytes)?;
                    }
                }
                Poll::Empty => return Ok(TaskAdvance::Pending(None)),
                Poll::InFlight(at) => return Ok(TaskAdvance::Pending(Some(at))),
                Poll::Closed => {
                    crate::bail!("session {}: server closed the link mid-session", self.session)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Socket-mode demultiplexer (client process): one socket carries every
// local session; route frames to per-session in-memory links.

struct DemuxTask {
    rx: Box<dyn FrameRx>,
    out: Vec<FrameLink>,
    idx_of: HashMap<u32, usize>,
    /// Terminal frames seen (session CLOSED or refused at open).
    done: usize,
    n: usize,
    finished: bool,
}

impl DemuxTask {
    fn route(&mut self, bytes: &[u8]) -> Result<()> {
        let (session, terminal) = match wire::parse(bytes)? {
            ServeMsg::Reject(r) => (r.session, r.seq == 0),
            ServeMsg::Env(e) => (e.session, e.kind == ENV_CLOSED),
        };
        let i = *self
            .idx_of
            .get(&session)
            .ok_or_else(|| crate::err!("demux: frame for unknown session {session}"))?;
        FrameTx::send_from(&mut self.out[i], bytes)?;
        if terminal {
            self.done += 1;
            if self.done == self.n {
                self.finished = true;
            }
        }
        Ok(())
    }

    fn advance(&mut self) -> Result<TaskAdvance> {
        loop {
            match self.rx.poll() {
                Poll::Ready => {
                    if let Some(bytes) = self.rx.try_recv()? {
                        self.route(&bytes)?;
                    }
                }
                Poll::Empty | Poll::Closed if self.finished => return Ok(TaskAdvance::Finished),
                Poll::Empty => return Ok(TaskAdvance::Pending(None)),
                Poll::InFlight(at) => return Ok(TaskAdvance::Pending(Some(at))),
                Poll::Closed => {
                    crate::bail!("demux: server closed with {} sessions outstanding", self.n - self.done)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Task wrapper + shared sending half

enum ServeTask {
    Gateway(Box<GatewayTask>),
    Stage(Box<StageTask>),
    Client(Box<ClientTask>),
    Demux(Box<DemuxTask>),
}

impl PoolTask for ServeTask {
    fn advance(&mut self) -> Result<TaskAdvance> {
        match self {
            ServeTask::Gateway(t) => t.advance(),
            ServeTask::Stage(t) => t.advance(),
            ServeTask::Client(t) => t.advance(),
            ServeTask::Demux(t) => t.advance(),
        }
    }
}

/// Many clients share one uplink to the gateway: a mutex-wrapped sending
/// half each client clones. FIFO per session is preserved (each session
/// is closed-loop), which is all the protocol needs.
struct SharedTx<T: FrameTx>(Arc<Mutex<T>>);

impl<T: FrameTx> SharedTx<T> {
    fn fan_out(inner: T, n: usize) -> Vec<SharedTx<T>> {
        let inner = Arc::new(Mutex::new(inner));
        (0..n).map(|_| SharedTx(Arc::clone(&inner))).collect()
    }
}

impl<T: FrameTx> FrameTx for SharedTx<T> {
    fn send(&mut self, frame: Vec<u8>) -> Result<()> {
        lock(&self.0).send(frame)
    }

    fn send_from(&mut self, frame: &[u8]) -> Result<()> {
        lock(&self.0).send_from(frame)
    }

    fn set_doorbell(&mut self, bell: Doorbell) {
        lock(&self.0).set_doorbell(bell);
    }

    fn bytes_sent(&self) -> u64 {
        lock(&self.0).bytes_sent()
    }

    fn msgs_sent(&self) -> u64 {
        lock(&self.0).msgs_sent()
    }
}

// ---------------------------------------------------------------------------
// Reports

/// What a serving run produced: per-session records (client side) and
/// aggregate gateway counters (server side).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub sessions: Vec<SessionRecord>,
    pub gateway: GatewayStats,
    pub wall_s: f64,
}

impl ServeReport {
    /// `p`-th percentile (0.0..=1.0) of per-request round-trip latency
    /// across every session, nearest-rank. `None` with no replies.
    pub fn latency_ns_percentile(&self, p: f64) -> Option<u64> {
        let mut all: Vec<u64> =
            self.sessions.iter().flat_map(|s| s.latencies_ns.iter().copied()).collect();
        if all.is_empty() {
            return None;
        }
        all.sort_unstable();
        let i = ((all.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
        Some(all[i])
    }

    /// Total replied rows across sessions.
    pub fn replied_rows(&self) -> u64 {
        self.sessions.iter().map(|s| s.latencies_ns.len() as u64).sum()
    }

    /// Aggregate serving throughput, replied rows per wall second.
    pub fn rows_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.replied_rows() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn rejected_sessions(&self) -> usize {
        self.sessions.iter().filter(|s| s.rejected.is_some()).count()
    }

    pub fn shed_total(&self) -> u64 {
        self.sessions.iter().map(|s| s.shed).sum()
    }
}

// ---------------------------------------------------------------------------
// Builders + run entry points

fn build_client(
    cfg: &ServeConfig,
    session: u32,
    tx: Box<dyn FrameTx>,
    rx: Box<dyn FrameRx>,
) -> Result<ClientTask> {
    let finetune = !is_infer(cfg, session);
    let el = cfg.example_len;
    let (fw, bw) = client_endpoints(&cfg.spec, el, cfg.rounding, cfg.seed, session)?;
    let cut = ToyStage::new(el, session_cut_seed(cfg.seed, session));
    let mut rng = Rng::new(session_data_seed(cfg.seed, session));
    let data: Vec<Vec<f32>> =
        (0..cfg.shard).map(|_| (0..el).map(|_| 0.5 * rng.normal()).collect()).collect();
    let targets: Vec<Vec<f32>> = if finetune {
        (0..cfg.shard).map(|_| (0..el).map(|_| 0.3 * rng.normal()).collect()).collect()
    } else {
        Vec::new()
    };
    Ok(ClientTask {
        session,
        finetune,
        summary: serve_summary(cfg),
        tx,
        rx,
        fw,
        bw,
        cut,
        data,
        targets,
        lr: cfg.lr,
        total: cfg.shard * cfg.epochs,
        next: 0,
        seq: 0,
        state: ClientState::Opening,
        pending: None,
        rec: SessionRecord {
            session,
            finetune,
            losses: Vec::new(),
            latencies_ns: Vec::new(),
            shed: 0,
            rejected: None,
            client_state: (0, 0),
            server_state: (0, 0),
            digest: 0,
            infer_digest: FNV_OFFSET,
        },
    })
}

/// Build the server side (gateway + stage tasks) over the given client
/// transports. `route` prefilled for in-process runs; learned per
/// connection in socket mode.
fn build_server(
    cfg: &ServeConfig,
    ingress: Vec<Box<dyn FrameRx>>,
    reply: Vec<Box<dyn FrameTx>>,
    route: HashMap<u32, usize>,
    learn_route: bool,
    expected_opens: usize,
) -> Vec<ServeTask> {
    let k = cfg.server_stages;
    let el = cfg.example_len;
    // fwd[i]: (gateway if i == 0 else stage i) -> stage i+1
    // bwd[i]: stage i+1 -> (gateway if i == 0 else stage i)
    let mut fwd: Vec<Option<(RealLink<BatchMsg>, RealReceiver<BatchMsg>)>> =
        (0..k).map(|_| Some(unpaced())).collect();
    let mut bwd: Vec<Option<(RealLink<BatchMsg>, RealReceiver<BatchMsg>)>> =
        (0..k).map(|_| Some(unpaced())).collect();

    let (gw_fwd_tx, s1_fwd_in) = fwd[0].take().expect("taken once");
    let (s1_bwd_tx, gw_grad_in) = bwd[0].take().expect("taken once");
    let n_ingress = ingress.len();
    let gateway = GatewayTask {
        el,
        summary: serve_summary(cfg),
        ingress,
        ingress_closed: vec![false; n_ingress],
        reply,
        route,
        learn_route,
        table: SessionTable::new(cfg.spec.clone(), el, cfg.rounding, cfg.seed),
        admission: Admission::new(cfg.admission),
        batcher: Batcher::new(cfg.batch),
        fwd_out: gw_fwd_tx,
        grad_in: gw_grad_in,
        expected_opens,
        opens_seen: 0,
        accepted: 0,
        closed: 0,
        in_flight: 0,
        next_batch: 0,
        shutdown_sent: false,
        finished: false,
        stats: GatewayStats::default(),
    };

    let mut tasks = Vec::with_capacity(1 + k);
    tasks.push(ServeTask::Gateway(Box::new(gateway)));
    let mut fwd_in = Some(s1_fwd_in);
    let mut bwd_out = Some(s1_bwd_tx);
    for s in 1..=k {
        let head = s == k;
        let (fwd_out, next_fwd_in) = if head {
            (None, None)
        } else {
            let (tx, rx) = fwd[s].take().expect("taken once");
            (Some(tx), Some(rx))
        };
        let (next_bwd_out, bwd_in) = if head {
            (None, None)
        } else {
            let (tx, rx) = bwd[s].take().expect("taken once");
            (Some(tx), Some(rx))
        };
        tasks.push(ServeTask::Stage(Box::new(StageTask {
            stage: ToyStage::new(el, server_stage_seed(cfg.seed, s)),
            head,
            el,
            fwd_in: fwd_in.take().expect("chained"),
            fwd_out,
            bwd_in,
            bwd_out: bwd_out.take().expect("chained"),
            saved: VecDeque::new(),
            fwd_done: false,
            finished: false,
        })));
        fwd_in = next_fwd_in;
        bwd_out = next_bwd_out;
    }
    tasks
}

fn install_doorbells(sched: &Arc<crate::pipeline::exec::EventSched>, tasks: &mut [ServeTask]) {
    for (t, task) in tasks.iter_mut().enumerate() {
        let mk = |sc: &Arc<crate::pipeline::exec::EventSched>| -> Doorbell {
            let sc = Arc::clone(sc);
            Arc::new(move || sc.wake(t))
        };
        match task {
            ServeTask::Gateway(g) => {
                for rx in &mut g.ingress {
                    rx.set_doorbell(mk(sched));
                }
                g.grad_in.set_doorbell(mk(sched));
            }
            ServeTask::Stage(s) => {
                s.fwd_in.set_doorbell(mk(sched));
                if let Some(bwd_in) = &mut s.bwd_in {
                    bwd_in.set_doorbell(mk(sched));
                }
            }
            ServeTask::Client(c) => c.rx.set_doorbell(mk(sched)),
            ServeTask::Demux(d) => d.rx.set_doorbell(mk(sched)),
        }
    }
}

fn collect(done: Vec<ServeTask>, wall_s: f64) -> ServeReport {
    let mut sessions = Vec::new();
    let mut gateway = GatewayStats::default();
    for t in done {
        match t {
            ServeTask::Gateway(g) => gateway = g.stats,
            ServeTask::Client(c) => sessions.push(c.rec),
            ServeTask::Stage(_) | ServeTask::Demux(_) => {}
        }
    }
    ServeReport { sessions, gateway, wall_s }
}

/// Run the whole fleet in-process: gateway + stages + one event task per
/// session in `ids`, client links paced at the configured
/// bandwidth/latency. A session's numerics depend only on (config,
/// session id) — `run_serve_sessions(cfg, &[a])` and a run that includes
/// `a` among others produce bit-identical records for `a`.
pub fn run_serve_sessions(cfg: &ServeConfig, ids: &[u32]) -> Result<ServeReport> {
    validate(cfg)?;
    crate::ensure!(!ids.is_empty(), "serve needs at least one session");
    {
        let mut seen = std::collections::BTreeSet::new();
        for &s in ids {
            crate::ensure!(seen.insert(s), "duplicate session id {s}");
        }
    }
    let n = ids.len();
    let k = cfg.server_stages;

    // shared paced uplink (all clients -> gateway)
    let (ing_tx, ing_rx) = frame_link(cfg.bandwidth_bps, cfg.latency);
    let uplinks = SharedTx::fan_out(ing_tx, n);
    // per-client paced reply links
    let mut reply: Vec<Box<dyn FrameTx>> = Vec::with_capacity(n);
    let mut reply_rx = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = frame_link(cfg.bandwidth_bps, cfg.latency);
        reply.push(Box::new(tx));
        reply_rx.push(rx);
    }
    let route: HashMap<u32, usize> = ids.iter().enumerate().map(|(i, &s)| (s, i)).collect();

    let mut tasks = build_server(cfg, vec![Box::new(ing_rx)], reply, route, false, n);
    for (up, (&session, rx)) in uplinks.into_iter().zip(ids.iter().zip(reply_rx)) {
        tasks.push(ServeTask::Client(Box::new(build_client(
            cfg,
            session,
            Box::new(up),
            Box::new(rx),
        )?)));
    }
    debug_assert_eq!(tasks.len(), 1 + k + n);

    let start = Instant::now();
    let done = run_event_pool(tasks, cfg.workers, cfg.stall_timeout, install_doorbells)?;
    Ok(collect(done, start.elapsed().as_secs_f64()))
}

/// In-process fleet over session ids `0..cfg.sessions`.
pub fn run_serve(cfg: &ServeConfig) -> Result<ServeReport> {
    let ids: Vec<u32> = (0..cfg.sessions as u32).collect();
    run_serve_sessions(cfg, &ids)
}

fn serve_shape(cfg: &ServeConfig) -> LinkShape {
    LinkShape {
        rate_bps: if cfg.bandwidth_bps.is_finite() { Some(cfg.bandwidth_bps) } else { None },
        latency: cfg.latency,
        ..LinkShape::default()
    }
}

fn socket_stall(cfg: &ServeConfig) -> Duration {
    cfg.stall_timeout.unwrap_or(Duration::from_secs(30))
}

/// Socket-mode server: accept `conns` client processes, serve
/// `cfg.sessions` total sessions across them, return gateway stats.
pub fn run_serve_listen(cfg: &ServeConfig, addr: &str, conns: usize) -> Result<ServeReport> {
    validate(cfg)?;
    crate::ensure!(conns >= 1, "serve listener needs at least one connection");
    let listener = TcpListener::bind(addr)
        .map_err(|e| crate::err!("serve: failed to bind {addr}: {e}"))?;
    let driver = IoDriver::new();
    let shape = serve_shape(cfg);
    let mut ingress: Vec<Box<dyn FrameRx>> = Vec::with_capacity(conns);
    let mut reply: Vec<Box<dyn FrameTx>> = Vec::with_capacity(conns);
    for _ in 0..conns {
        let (sock, _) = listener
            .accept()
            .map_err(|e| crate::err!("serve: accept on {addr} failed: {e}"))?;
        let (tx, rx) = driver.register(sock, shape.clone())?;
        ingress.push(Box::new(rx));
        reply.push(Box::new(tx));
    }

    let tasks = build_server(cfg, ingress, reply, HashMap::new(), true, cfg.sessions);
    let start = Instant::now();
    let done = run_event_pool(tasks, cfg.workers, Some(socket_stall(cfg)), install_doorbells)?;
    let report = collect(done, start.elapsed().as_secs_f64());
    // endpoint drop marked the tx halves closed; joining the driver
    // flushes their tails to the clients
    drop(driver);
    Ok(report)
}

/// Socket-mode client process: run sessions `base..base + cfg.sessions`
/// over ONE connection to the server, demultiplexing replies locally.
pub fn run_serve_connect(cfg: &ServeConfig, addr: &str, base: u32) -> Result<ServeReport> {
    validate(cfg)?;
    crate::ensure!(cfg.sessions >= 1, "serve client needs at least one session");
    let n = cfg.sessions;
    // bounded retry: the server process may still be binding its listener
    let deadline = Instant::now() + socket_stall(cfg);
    let sock = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(_) if Instant::now() < deadline => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => crate::bail!("serve: failed to connect {addr}: {e}"),
        }
    };
    let driver = IoDriver::new();
    let (sock_tx, sock_rx) = driver.register(sock, serve_shape(cfg))?;

    let uplinks = SharedTx::fan_out(sock_tx, n);
    let mut out = Vec::with_capacity(n);
    let mut idx_of = HashMap::with_capacity(n);
    let mut tasks = Vec::with_capacity(1 + n);
    let mut session_rx = Vec::with_capacity(n);
    for i in 0..n {
        let session = base + i as u32;
        let (tx, rx) = frame_link(f64::INFINITY, Duration::ZERO);
        out.push(tx);
        session_rx.push(rx);
        idx_of.insert(session, i);
    }
    tasks.push(ServeTask::Demux(Box::new(DemuxTask {
        rx: Box::new(sock_rx),
        out,
        idx_of,
        done: 0,
        n,
        finished: false,
    })));
    for (i, (up, rx)) in uplinks.into_iter().zip(session_rx).enumerate() {
        let session = base + i as u32;
        tasks.push(ServeTask::Client(Box::new(build_client(
            cfg,
            session,
            Box::new(up),
            Box::new(rx),
        )?)));
    }

    let start = Instant::now();
    let done = run_event_pool(tasks, cfg.workers, Some(socket_stall(cfg)), |sched, tasks| {
        install_doorbells(sched, tasks);
        // the demux's per-session links also need their doorbells: the
        // demux task sends, the owning client task (1 + i) wakes
        if let ServeTask::Demux(d) = &mut tasks[0] {
            for (i, link) in d.out.iter_mut().enumerate() {
                let sc = Arc::clone(sched);
                link.set_doorbell(Arc::new(move || sc.wake(1 + i)));
            }
        }
    })?;
    let report = collect(done, start.elapsed().as_secs_f64());
    drop(driver);
    Ok(report)
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            sessions: 8,
            server_stages: 2,
            example_len: 8,
            shard: 3,
            epochs: 2,
            infer_every: 4,
            batch: BatchCfg { rows: 4, max_wait: Duration::from_micros(200) },
            workers: 2,
            latency: Duration::from_micros(20),
            ..ServeConfig::default()
        }
    }

    #[test]
    fn eight_sessions_roundtrip_cleanly() {
        let cfg = small_cfg();
        let report = run_serve(&cfg).expect("serve");
        assert_eq!(report.sessions.len(), 8);
        assert_eq!(report.rejected_sessions(), 0, "no admission false rejects");
        assert_eq!(report.gateway.rejected_opens, 0);
        assert_eq!(report.gateway.peak_sessions, 8, "fleet was concurrent");
        assert_eq!(report.gateway.rows, 8 * 6, "every request batched exactly once");
        for s in &report.sessions {
            assert_eq!(s.latencies_ns.len(), 6, "session {}: all replies arrived", s.session);
            if s.finetune {
                assert_eq!(s.losses.len(), 6);
                assert_ne!(s.digest, 0, "fine-tune session updated its cut layer");
            } else {
                assert_ne!(s.infer_digest, FNV_OFFSET, "inference session digested head rows");
                assert!(s.losses.is_empty());
            }
            // AQ replica symmetry: client fw encoder and server fw decoder
            // hold identical resident buffer state
            assert_eq!(s.client_state.0, s.server_state.0, "session {} fw replicas", s.session);
            assert_eq!(s.client_state.1, s.server_state.1, "session {} bw replicas", s.session);
        }
        assert!(report.latency_ns_percentile(0.5) <= report.latency_ns_percentile(0.99));
        assert!(report.rows_per_s() > 0.0);
    }

    #[test]
    fn session_cap_rejects_surplus_descriptively() {
        // workers=1 makes the admission outcome deterministic: every
        // client's OPEN is sent (in task order) before the gateway's
        // second run, so it sees all six opens with the table empty.
        let cfg = ServeConfig {
            sessions: 6,
            shard: 1,
            epochs: 1,
            infer_every: 0,
            admission: AdmissionCfg { max_sessions: 2, ..AdmissionCfg::default() },
            workers: 1,
            ..small_cfg()
        };
        let report = run_serve(&cfg).expect("serve");
        assert_eq!(report.rejected_sessions(), 4);
        assert_eq!(report.gateway.rejected_opens, 4);
        assert_eq!(report.gateway.peak_sessions, 2);
        let mut served = 0;
        for s in &report.sessions {
            match &s.rejected {
                Some(reason) => assert!(reason.contains("cap 2"), "{reason}"),
                None => {
                    assert_eq!(s.latencies_ns.len(), 1);
                    served += 1;
                }
            }
        }
        assert_eq!(served, 2);
    }

    #[test]
    fn shed_and_resend_do_not_change_session_numerics() {
        // queue_depth 1 forces sheds + retransmits; the records must be
        // bit-identical to an unshed run (replica-sync invariant).
        let base = ServeConfig {
            sessions: 4,
            server_stages: 1,
            shard: 2,
            epochs: 2,
            infer_every: 3,
            batch: BatchCfg { rows: 4, max_wait: Duration::from_micros(500) },
            workers: 2,
            ..small_cfg()
        };
        let strangled = ServeConfig {
            admission: AdmissionCfg { queue_depth: 1, ..AdmissionCfg::default() },
            ..base.clone()
        };
        let a = run_serve(&base).expect("unshed run");
        let b = run_serve(&strangled).expect("strangled run");
        assert_eq!(a.rejected_sessions(), 0);
        assert_eq!(b.rejected_sessions(), 0, "sheds retry, they never kill a session");
        for (x, y) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(x.session, y.session);
            let xb: Vec<u32> = x.losses.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.losses.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "session {} loss bits", x.session);
            assert_eq!(x.digest, y.digest, "session {} cut digest", x.session);
            assert_eq!(x.infer_digest, y.infer_digest, "session {}", x.session);
            assert_eq!(x.client_state, y.client_state, "session {}", x.session);
            assert_eq!(x.server_state, y.server_state, "session {}", x.session);
        }
    }

    #[test]
    fn latency_percentiles_use_nearest_rank() {
        let mk = |lat: Vec<u64>| SessionRecord {
            session: 0,
            finetune: true,
            losses: Vec::new(),
            latencies_ns: lat,
            shed: 0,
            rejected: None,
            client_state: (0, 0),
            server_state: (0, 0),
            digest: 0,
            infer_digest: FNV_OFFSET,
        };
        let report = ServeReport {
            sessions: vec![mk(vec![30, 10]), mk(vec![20, 40, 50])],
            gateway: GatewayStats::default(),
            wall_s: 1.0,
        };
        assert_eq!(report.latency_ns_percentile(0.5), Some(30));
        assert_eq!(report.latency_ns_percentile(0.0), Some(10));
        assert_eq!(report.latency_ns_percentile(1.0), Some(50));
        assert_eq!(report.replied_rows(), 5);
        let empty = ServeReport { sessions: Vec::new(), ..report };
        assert_eq!(empty.latency_ns_percentile(0.5), None);
    }
}
