//! Cross-session request batching: decoded rows from *distinct*
//! sessions coalesce into fixed-row shared microbatches so the server's
//! stages run once per batch instead of once per request. A batch is
//! emitted the moment it fills, or when the oldest waiting row hits the
//! max-wait deadline (latency floor under light load); short batches are
//! padded by the caller with inert rows.
//!
//! Batching never touches numerics: stage compute is row-wise, rows are
//! session-tagged with globally-unique example ids, and codec state
//! lives per session in the [`SessionTable`](super::table::SessionTable)
//! — so which rows share a batch changes only *when* work happens,
//! never what any session computes (pinned by `tests/prop_serve.rs`).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One decoded request waiting for a batch slot.
pub struct PendingRow {
    pub session: u32,
    pub seq: u32,
    pub example: u64,
    pub finetune: bool,
    /// Decoded cut activation, `example_len` long.
    pub x: Vec<f32>,
    /// Target row (`example_len` long) for fine-tune rows; empty for
    /// inference rows.
    pub target: Vec<f32>,
    pub enqueued: Instant,
}

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchCfg {
    /// Fixed rows per emitted microbatch.
    pub rows: usize,
    /// Emit a partial batch once the oldest row has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchCfg {
    fn default() -> Self {
        BatchCfg { rows: 8, max_wait: Duration::from_micros(200) }
    }
}

/// FIFO of ready rows + the emit policy.
pub struct Batcher {
    cfg: BatchCfg,
    q: VecDeque<PendingRow>,
}

impl Batcher {
    pub fn new(cfg: BatchCfg) -> Self {
        Batcher { cfg, q: VecDeque::new() }
    }

    pub fn rows(&self) -> usize {
        self.cfg.rows
    }

    pub fn push(&mut self, row: PendingRow) {
        self.q.push_back(row);
    }

    pub fn depth(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// When the oldest waiting row must go out even in a short batch.
    /// `None` while the queue is empty.
    pub fn deadline(&self) -> Option<Instant> {
        self.q.front().map(|r| r.enqueued + self.cfg.max_wait)
    }

    /// Should a batch be emitted now? Full batch, or deadline hit.
    pub fn ready(&self, now: Instant) -> bool {
        self.q.len() >= self.cfg.rows || self.deadline().is_some_and(|at| now >= at)
    }

    /// Pop up to one batch worth of rows, FIFO (the caller pads short
    /// batches). Empty vec only if called while empty.
    pub fn take(&mut self) -> Vec<PendingRow> {
        let n = self.q.len().min(self.cfg.rows);
        self.q.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(session: u32, at: Instant) -> PendingRow {
        PendingRow {
            session,
            seq: 1,
            example: session as u64,
            finetune: true,
            x: vec![0.0; 4],
            target: vec![0.0; 4],
            enqueued: at,
        }
    }

    #[test]
    fn full_batch_is_ready_immediately() {
        let t0 = Instant::now();
        let mut b = Batcher::new(BatchCfg { rows: 2, max_wait: Duration::from_secs(3600) });
        b.push(row(1, t0));
        assert!(!b.ready(t0), "one row of two, fresh: must wait");
        b.push(row(2, t0));
        assert!(b.ready(t0), "full batch: ready regardless of deadline");
        let got = b.take();
        assert_eq!(got.len(), 2);
        assert_eq!((got[0].session, got[1].session), (1, 2), "FIFO order");
        assert!(b.is_empty());
    }

    #[test]
    fn deadline_flushes_a_short_batch() {
        let t0 = Instant::now();
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(BatchCfg { rows: 8, max_wait: wait });
        b.push(row(3, t0));
        assert_eq!(b.deadline(), Some(t0 + wait));
        assert!(!b.ready(t0 + wait / 2));
        assert!(b.ready(t0 + wait), "deadline hit: short batch goes out");
        assert_eq!(b.take().len(), 1);
        assert_eq!(b.deadline(), None, "empty queue has no deadline");
    }

    #[test]
    fn take_caps_at_one_batch_and_keeps_the_rest() {
        let t0 = Instant::now();
        let mut b = Batcher::new(BatchCfg { rows: 2, max_wait: Duration::ZERO });
        for s in 0..5 {
            b.push(row(s, t0));
        }
        assert_eq!(b.take().len(), 2);
        assert_eq!(b.depth(), 3, "remaining rows stay queued");
        assert_eq!(b.take().len(), 2);
        assert_eq!(b.take().len(), 1);
    }
}
