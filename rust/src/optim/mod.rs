//! Optimizer: native AdamW (bit-compatible with the HLO artifact's baked
//! hyper-parameters) + the paper's warmup-then-linear-decay LR schedule
//! (Appendix C).

/// Hyper-parameters matching `python/compile/optimizer.py`.
pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.01;

/// Native AdamW state for one flat parameter vector.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
}

impl AdamW {
    pub fn new(n: usize) -> Self {
        AdamW { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// In-place update of `params` with gradient `g` at learning rate `lr`.
    pub fn update(&mut self, params: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(params.len(), g.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - BETA1.powf(t);
        let bc2 = 1.0 - BETA2.powf(t);
        for i in 0..params.len() {
            let gi = g[i];
            self.m[i] = BETA1 * self.m[i] + (1.0 - BETA1) * gi;
            self.v[i] = BETA2 * self.v[i] + (1.0 - BETA2) * gi * gi;
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            params[i] -= lr * (m_hat / (v_hat.sqrt() + EPS) + WEIGHT_DECAY * params[i]);
        }
    }
}

/// Warmup + linear decay over `total_steps` (paper App. C).
#[derive(Clone, Copy, Debug)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

impl LrSchedule {
    /// Learning rate at 1-based step `step`.
    pub fn lr(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step <= self.warmup_steps {
            return self.base_lr * step as f64 / self.warmup_steps as f64;
        }
        if self.total_steps == usize::MAX || self.total_steps <= self.warmup_steps {
            return self.base_lr;
        }
        let rem = (self.total_steps - step) as f64;
        let span = (self.total_steps - self.warmup_steps) as f64;
        self.base_lr * (rem / span).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_minimizes_quadratic() {
        let mut p = vec![5.0f32; 8];
        let mut opt = AdamW::new(8);
        for _ in 0..300 {
            let g: Vec<f32> = p.clone(); // grad of ||p||^2/2
            opt.update(&mut p, &g, 0.05);
        }
        assert!(p.iter().all(|x| x.abs() < 1.0), "{p:?}");
    }

    #[test]
    fn matches_closed_form_first_step() {
        // step 1: m_hat = g, v_hat = g^2 -> update ~ lr*(sign(g) + wd*p)
        let mut p = vec![1.0f32];
        let mut opt = AdamW::new(1);
        opt.update(&mut p, &[2.0], 0.1);
        let expect = 1.0 - 0.1 * (2.0 / (2.0 + EPS) + WEIGHT_DECAY * 1.0);
        assert!((p[0] - expect).abs() < 1e-5, "{} vs {expect}", p[0]);
    }

    #[test]
    fn schedule_shape() {
        let s = LrSchedule { base_lr: 1.0, warmup_steps: 10, total_steps: 110 };
        assert!((s.lr(1) - 0.1).abs() < 1e-12);
        assert!((s.lr(10) - 1.0).abs() < 1e-12);
        assert!((s.lr(60) - 0.5).abs() < 1e-12);
        assert!(s.lr(110) < 1e-12);
        // open-ended: constant after warmup
        let c = LrSchedule { base_lr: 0.5, warmup_steps: 5, total_steps: usize::MAX };
        assert_eq!(c.lr(100), 0.5);
    }
}
