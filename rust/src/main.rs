//! `aq-sgd` launcher: train / evaluate / inspect over the AOT artifacts.
//!
//! Subcommands:
//!   train        run a training job (see --help for flags)
//!   info         print a model manifest summary
//!   throughput   one-off pipeline-throughput simulation
//!   serve-stage  run one (replica, stage) as this OS process over TCP
//!   serve        session-multiplexed serving front end (split
//!                inference / fine-tune fleet over compressed links)
//!
//! Examples:
//!   aq-sgd train --model tiny --compression aqsgd:fw2bw4 --epochs 4 \
//!                --bandwidth 100mbps --dataset markov
//!   aq-sgd info --model small
//!   aq-sgd throughput --stages 8 --micro 32 --bandwidth 100mbps
//!   aq-sgd serve-stage --role stage:0 --peers 127.0.0.1:7101,127.0.0.1:7102 \
//!                      --stages 2 --compression aqsgd:fw2bw4 --steps 3
//!   aq-sgd serve --sessions 1000 --batch-rows 32 --bandwidth 100mbps

use std::time::Duration;

use aq_sgd::util::error::Result;

use aq_sgd::codec::CodecSpec;
use aq_sgd::config::{parse_bandwidth, Cli, TrainConfig};
use aq_sgd::coordinator::Trainer;
use aq_sgd::exp::{self, make_dataset};
use aq_sgd::metrics::Table;
use aq_sgd::net::session::TopologyPlan;
use aq_sgd::net::tcp::LinkShape;
use aq_sgd::pipeline::{serve_stage, ExecConfig, PipelineSim, ServeOpts, SimConfig};
use aq_sgd::runtime::Manifest;
use aq_sgd::util::fmt;

const HELP: &str = "aq-sgd <train|info|throughput|serve-stage|serve> [--key value ...]

train flags:
  --model NAME            artifacts/<NAME> (default tiny)
  --compression SPEC      fp32 | fp16 | directq:fwXbwY | aqsgd:fwXbwY |
                          topk:F@B | ef:SPEC | hybrid:FW/BW
                          (e.g. hybrid:aq2/topk0.2@8)
  --dataset NAME          markov | arxiv | embedded | qnli | cola
  --examples N            dataset size (default 64)
  --epochs N --n-micro N --lr F --warmup N --steps N --seed N
  --bandwidth B           e.g. 100mbps, 10gbps (simulated-time accounting)
  --schedule S            gpipe | 1f1b
  --executor E            sim (virtual-clock trainer, default) | threads
                          (one worker thread per stage over channel links) |
                          events (fixed worker pool over a run queue; both
                          self-contained — need no artifacts)
  --workers N             worker-pool size for --executor events (default 4;
                          any pool size gives the identical trajectory)
  --stages K --el N --micro-batch B
                          pipeline shape for --executor threads|events
                          (default 4/64/2)
  --dp N                  data-parallel replicas (ring gradient exchange)
  --dp-codec SPEC         DP gradient codec, same grammar as --compression
                          (ef:directq:fw4bw4 = Fig. 5's error-compensated
                          regime; default fp32; --dp-bits B is shorthand
                          for ef:directq:fwBbwB)
  --m-bits B              low-precision message buffers (Fig 9e/f)
  --store S               mem | disk | quant
  --hlo-codec             compress boundaries via the Pallas HLO kernels
  --stochastic            stochastic (unbiased) rounding
  --eval-every N          eval cadence
  --csv PATH              write the loss trace

serve-stage flags (plus the train job flags: --compression, --dp,
--dp-codec, --schedule, --seed, --steps, --n-micro, --lr, --stages,
--el, --micro-batch):
  --role stage:<i>        which pipeline stage this process runs
  --replica R             which data-parallel replica (default 0)
  --peers A,B,...         listen addresses of every (replica, stage)
                          process, flattened replica-major (replica 0
                          stages 0..k, then replica 1, ...)
  --shape-rate B          token-bucket bandwidth cap per socket
                          (e.g. 100mbps; default unshaped)
  --shape-latency-ms F    injected delivery latency per frame
  --shape-jitter-ms F     extra uniform-random delay in [0, F) —
                          monotone, never reorders
  --shape-seed N          jitter rng seed (default 0x5EED)
  --shape-chunk N         cap bytes per read/write syscall (forces
                          partial I/O; 0 = unforced)
  --stall-timeout-ms N    give up when no frame arrives for N ms
                          (default 5000)
  --connect-timeout-ms N  outbound connect retry budget (default 10000)
  --skip-oracle           skip the local virtual-clock bit-identity
                          check after the run

serve flags:
  --sessions N            concurrent client sessions (default 64)
  --stages K              frozen server stages behind the gateway (default 2)
  --el N                  activation row width (default 8)
  --compression SPEC      boundary codec (default aqsgd:fw2bw4)
  --shard N --epochs N    per-session workload: N examples x N passes
  --infer-every N         every Nth session runs split inference instead
                          of fine-tuning (0 = all fine-tune; default 4)
  --batch-rows N          rows per shared microbatch (default 8)
  --batch-wait-us N       max wait before a short batch flushes (default 200)
  --max-sessions N        admission: concurrent-session cap (default 4096)
  --open-rate F           admission: session opens/s refill rate
  --open-burst F          admission: open token-bucket capacity
  --queue-depth N         shed requests past this many queued rows
  --workers N             event-pool worker threads (default 4)
  --bandwidth B --latency-ms F
                          pacing of the client links (default 1gbps, 0.05)
  --seed N --lr F         fleet seed / client cut-layer step size
  --nearest               nearest rounding (default stochastic)
  --listen ADDR --conns N serve over TCP: accept N client processes
  --connect ADDR --session-base N
                          client process: run sessions base..base+N
  --stall-timeout-ms N    abort when idle this long (default: instant
                          stall detection in-process, 30000 over TCP)
  --expect-no-rejects     exit non-zero if admission refused anything
";

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = TrainConfig::from_cli(cli)?;
    if cfg.executor != aq_sgd::pipeline::Executor::Sim {
        return cmd_train_executor(cli, &cfg);
    }
    let man = Manifest::load(&cfg.artifacts_dir, &cfg.model)?;
    let data = make_dataset(&cfg, &man)?;
    let (train, eval) = data.split_eval(0.125);
    let mut trainer = Trainer::new(cfg)?;
    trainer.set_eval_every(cli.usize("eval-every", 10)?);
    println!(
        "model={} params={} stages={} compression={} bandwidth={}",
        man.name(),
        man.total_params()?,
        man.n_stages()?,
        trainer.cfg.compression.label(),
        fmt::bandwidth(trainer.cfg.bandwidth_bps)
    );
    let stats = trainer.train(&train, Some(&eval))?;
    println!(
        "steps={} train_loss={:.4} eval_loss={:.4} comm={} sim_time={} buffers={}",
        stats.steps,
        stats.final_train_loss,
        stats.final_eval_loss,
        fmt::bytes(stats.comm_bytes),
        fmt::duration_s(stats.sim_time_s),
        fmt::bytes(stats.buffer_bytes),
    );
    if let Some(path) = cli.flags.get("csv") {
        trainer.recorder.save_csv(path)?;
        println!("trace written to {path}");
    }
    Ok(())
}

/// `--executor threads|events`: run the self-contained real-numerics
/// pipeline (first-party stage model + registry codecs over channel
/// links — thread-per-stage or worker-pool run queue) and cross-check
/// its loss/wire trajectory against the virtual-clock twin.
fn cmd_train_executor(cli: &Cli, cfg: &TrainConfig) -> Result<()> {
    let stages = cli.usize("stages", 4)?;
    let el = cli.usize("el", 64)?;
    let micro_b = cli.usize("micro-batch", 2)?;
    let steps = if cfg.total_steps == usize::MAX { 20 } else { cfg.total_steps };
    println!(
        "executor={} stages={stages} n_micro={} micro_batch={micro_b} el={el} \
         compression={} dp={} dp_codec={} schedule={:?} bandwidth={}",
        cfg.executor.label(),
        cfg.n_micro,
        cfg.compression.label(),
        cfg.dp_degree,
        cfg.dp_codec.label(),
        cfg.schedule,
        fmt::bandwidth(cfg.bandwidth_bps)
    );
    let t0 = std::time::Instant::now();
    let (real, oracle) = exp::run_executor_with_oracle(cfg, stages, micro_b, el, steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut t = Table::new(&[
        "step", "loss", "fw wire", "bw wire", "dp wire", "wall step", "oracle step",
    ]);
    for (i, rec) in real.steps.iter().enumerate() {
        t.row(vec![
            format!("{i}"),
            format!("{:.5}", rec.loss),
            fmt::bytes(rec.fw_wire_bytes.iter().sum::<u64>()),
            fmt::bytes(rec.bw_wire_bytes.iter().sum::<u64>()),
            fmt::bytes(rec.dp_wire_bytes.iter().sum::<u64>()),
            fmt::duration_s(real.step_time_s[i]),
            fmt::duration_s(oracle.step_time_s[i]),
        ]);
    }
    print!("{}", t.render());
    let identical = real.bit_identical(&oracle);
    println!(
        "wall time {} ({} + oracle) — trajectory vs virtual-clock oracle: {}",
        fmt::duration_s(wall),
        cfg.executor.label(),
        if identical { "bit-identical" } else { "DIVERGED (bug!)" }
    );
    exp::check_matches_oracle(&real, &oracle)
}

/// `serve-stage`: run one (replica, stage) of a multi-process job as
/// this OS process over real TCP sockets, then verify the trajectory
/// bit-identical to the local virtual-clock oracle (unless
/// --skip-oracle). Every process of the job must be launched with the
/// same job flags and the same --peers list; they find each other, shake
/// hands (rejecting config mismatches), train, and exit.
fn cmd_serve_stage(cli: &Cli) -> Result<()> {
    let cfg = TrainConfig::from_cli(cli)?;
    let stages = cli.usize("stages", 4)?;
    let el = cli.usize("el", 64)?;
    let micro_b = cli.usize("micro-batch", 2)?;
    let steps = if cfg.total_steps == usize::MAX { 4 } else { cfg.total_steps };
    let ecfg = ExecConfig::from_train(&cfg, stages, micro_b, el, steps);

    let role = cli.str("role", "");
    let stage = role
        .strip_prefix("stage:")
        .and_then(|i| i.parse::<usize>().ok())
        .ok_or_else(|| aq_sgd::err!("--role must be stage:<i>, got {role:?}"))?;
    let replica = cli.usize("replica", 0)?;
    let peers = cli.str("peers", "");
    aq_sgd::ensure!(
        !peers.is_empty(),
        "--peers is required: comma-separated listen addresses for all {} processes",
        stages * ecfg.dp_degree
    );
    let plan = TopologyPlan::parse(&peers, stages, ecfg.dp_degree)?;

    let mut shape = LinkShape::default();
    if let Some(v) = cli.flags.get("shape-rate") {
        shape.rate_bps = Some(parse_bandwidth(v)?);
    }
    shape.latency = Duration::from_secs_f64(cli.f64("shape-latency-ms", 0.0)? / 1e3);
    shape.jitter = Duration::from_secs_f64(cli.f64("shape-jitter-ms", 0.0)? / 1e3);
    shape.jitter_seed = cli.usize("shape-seed", 0x5EED)? as u64;
    let chunk = cli.usize("shape-chunk", 0)?;
    if chunk > 0 {
        shape.max_io_chunk = Some(chunk);
    }

    let connect = Duration::from_millis(cli.usize("connect-timeout-ms", 10_000)? as u64);
    let opts = ServeOpts {
        replica,
        stage,
        plan,
        shape,
        stall_timeout: Duration::from_millis(cli.usize("stall-timeout-ms", 5_000)? as u64),
        connect_timeout: connect,
        handshake_timeout: connect,
        check_oracle: !cli.bool("skip-oracle"),
    };
    println!(
        "serve-stage replica={replica} stage={stage}/{stages} dp={} compression={} \
         dp_codec={} schedule={:?} steps={steps}",
        ecfg.dp_degree,
        ecfg.spec.label(),
        ecfg.dp_spec.label(),
        ecfg.schedule,
    );
    let summary = serve_stage(&ecfg, &opts)?;

    let mut t = Table::new(&["step", "loss", "fw wire", "bw wire", "dp wire", "digest", "wall"]);
    for (i, rec) in summary.per_step.iter().enumerate() {
        t.row(vec![
            format!("{i}"),
            rec.loss.map_or_else(|| "-".into(), |l| format!("{l:.5}")),
            fmt::bytes(rec.fw_wire),
            fmt::bytes(rec.bw_wire),
            fmt::bytes(rec.dp_wire),
            format!("{:016x}", rec.digest),
            fmt::duration_s(summary.wall_s[i]),
        ]);
    }
    print!("{}", t.render());
    println!(
        "SERVE-OK replica={} stage={} steps={} oracle={}",
        summary.replica,
        summary.stage,
        summary.per_step.len(),
        if summary.oracle_checked { "bit-identical" } else { "skipped" }
    );
    Ok(())
}

/// `serve`: the session-multiplexed serving front end — thousands of
/// split-inference / split-fine-tune clients over compressed links
/// against one shared set of frozen stages. In-process by default
/// (clients are event tasks in this process); `--listen`/`--connect`
/// split server and client fleets across OS processes over TCP.
fn cmd_serve(cli: &Cli) -> Result<()> {
    use aq_sgd::serve::admission::AdmissionCfg;
    use aq_sgd::serve::batch::BatchCfg;
    use aq_sgd::serve::{
        run_serve, run_serve_connect, run_serve_listen, serve_summary, ServeConfig,
    };

    let d = ServeConfig::default();
    let stall_ms = cli.usize("stall-timeout-ms", 0)?;
    let cfg = ServeConfig {
        sessions: cli.usize("sessions", d.sessions)?,
        server_stages: cli.usize("stages", d.server_stages)?,
        example_len: cli.usize("el", d.example_len)?,
        spec: CodecSpec::parse(&cli.str("compression", "aqsgd:fw2bw4"))?,
        rounding: if cli.bool("nearest") {
            aq_sgd::codec::Rounding::Nearest
        } else {
            aq_sgd::codec::Rounding::Stochastic
        },
        seed: cli.usize("seed", 7)? as u64,
        lr: cli.f64("lr", f64::from(d.lr))? as f32,
        shard: cli.usize("shard", d.shard)?,
        epochs: cli.usize("epochs", d.epochs)?,
        infer_every: cli.usize("infer-every", d.infer_every)?,
        batch: BatchCfg {
            rows: cli.usize("batch-rows", d.batch.rows)?,
            max_wait: Duration::from_micros(cli.usize("batch-wait-us", 200)? as u64),
        },
        admission: AdmissionCfg {
            max_sessions: cli.usize("max-sessions", d.admission.max_sessions)?,
            open_rate: cli.f64("open-rate", d.admission.open_rate)?,
            open_burst: cli.f64("open-burst", d.admission.open_burst)?,
            queue_depth: cli.usize("queue-depth", d.admission.queue_depth)?,
        },
        workers: cli.usize("workers", d.workers)?,
        bandwidth_bps: match cli.flags.get("bandwidth") {
            Some(v) => parse_bandwidth(v)?,
            None => d.bandwidth_bps,
        },
        latency: Duration::from_secs_f64(cli.f64("latency-ms", 0.05)? / 1e3),
        stall_timeout: (stall_ms > 0).then(|| Duration::from_millis(stall_ms as u64)),
    };
    println!(
        "{} sessions={} infer_every={} batch={}rows/{:?} bandwidth={} workers={}",
        serve_summary(&cfg),
        cfg.sessions,
        cfg.infer_every,
        cfg.batch.rows,
        cfg.batch.max_wait,
        fmt::bandwidth(cfg.bandwidth_bps),
        cfg.workers,
    );

    let report = if let Some(addr) = cli.flags.get("listen") {
        run_serve_listen(&cfg, addr, cli.usize("conns", 1)?)?
    } else if let Some(addr) = cli.flags.get("connect") {
        run_serve_connect(&cfg, addr, cli.usize("session-base", 0)? as u32)?
    } else {
        run_serve(&cfg)?
    };

    let served = report.sessions.iter().filter(|s| s.rejected.is_none()).count();
    println!(
        "gateway: batches={} rows={} padded={} shed={} rejected_opens={} peak_sessions={}",
        report.gateway.batches,
        report.gateway.rows,
        report.gateway.padded_rows,
        report.gateway.shed_requests,
        report.gateway.rejected_opens,
        report.gateway.peak_sessions,
    );
    if let (Some(p50), Some(p99)) =
        (report.latency_ns_percentile(0.5), report.latency_ns_percentile(0.99))
    {
        println!(
            "latency p50={:.1}us p99={:.1}us  throughput={:.0} rows/s  wall={}",
            p50 as f64 / 1e3,
            p99 as f64 / 1e3,
            report.rows_per_s(),
            fmt::duration_s(report.wall_s),
        );
    }
    let finals: Vec<f32> = report
        .sessions
        .iter()
        .filter_map(|s| s.losses.last().copied())
        .collect();
    if !finals.is_empty() {
        let mean = finals.iter().map(|&v| f64::from(v)).sum::<f64>() / finals.len() as f64;
        println!("fine-tune: {} sessions, mean final loss {mean:.4}", finals.len());
    }
    if cli.bool("expect-no-rejects") {
        let client_rejects = report.rejected_sessions();
        let shed = report.shed_total() + report.gateway.shed_requests;
        aq_sgd::ensure!(
            client_rejects == 0 && report.gateway.rejected_opens == 0 && shed == 0,
            "admission gate fired under nominal load: {client_rejects} rejected sessions, \
             {} rejected opens, {shed} shed requests",
            report.gateway.rejected_opens
        );
        println!("no-rejects assertion passed");
    }
    println!(
        "SERVE-OK sessions={} served={} replied_rows={} gateway_rows={}",
        report.sessions.len(),
        served,
        report.replied_rows(),
        report.gateway.rows,
    );
    Ok(())
}

fn cmd_info(cli: &Cli) -> Result<()> {
    let model = cli.str("model", "tiny");
    let man = Manifest::load(&cli.str("artifacts", "artifacts"), &model)?;
    println!("model       {}", man.name());
    println!("task        {}", man.task()?);
    println!("stages      {}", man.n_stages()?);
    println!("params      {}", man.total_params()?);
    println!("boundary    {:?}", man.boundary()?);
    println!("vocab/seq   {}/{}", man.vocab()?, man.seq()?);
    let n = man.boundary_len()?;
    let mut t = Table::new(&["scheme", "fw bytes/microbatch", "vs fp32"]);
    for spec in ["fp32", "fp16", "directq:fw3bw6", "aqsgd:fw2bw4", "topk:0.2@8"] {
        let c = CodecSpec::parse(spec)?;
        let b = c.fw_wire_bytes(n, false);
        t.row(vec![c.label(), fmt::bytes(b), format!("{:.1}x", 4.0 * n as f64 / b as f64)]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_throughput(cli: &Cli) -> Result<()> {
    let stages = cli.usize("stages", 8)?;
    let micro = cli.usize("micro", 32)?;
    let fw_mb = cli.f64("fw-mb", 6.4)?;
    let fwd_ms = cli.f64("fwd-ms", 45.0)?;
    let bwd_ms = cli.f64("bwd-ms", 135.0)?;
    let micro_batch = cli.usize("micro-batch", 1)?;
    let bw = parse_bandwidth(&cli.str("bandwidth", "100mbps"))?;
    let fp32_bytes = (fw_mb * 1e6) as u64;
    let mut t = Table::new(&["scheme", "step time", "throughput (seq/s)"]);
    for (label, fw, bw_bytes) in [
        ("FP32", fp32_bytes, fp32_bytes),
        ("fw4 bw8", fp32_bytes / 8, fp32_bytes / 4),
        (
            "fw3 bw6",
            (fp32_bytes as f64 * 3.0 / 32.0) as u64,
            (fp32_bytes as f64 * 6.0 / 32.0) as u64,
        ),
        ("fw2 bw4", fp32_bytes / 16, fp32_bytes / 8),
    ] {
        let cfg = SimConfig::uniform(stages, micro, fwd_ms / 1e3, bwd_ms / 1e3, fw, bw_bytes, bw);
        let r = PipelineSim::run(&cfg);
        t.row(vec![
            label.to_string(),
            fmt::duration_s(r.step_time_s),
            format!("{:.2}", r.throughput(micro, micro_batch)),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn main() -> Result<()> {
    let cli = Cli::from_env();
    match cli.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&cli),
        Some("info") => cmd_info(&cli),
        Some("throughput") => cmd_throughput(&cli),
        Some("serve-stage") => cmd_serve_stage(&cli),
        Some("serve") => cmd_serve(&cli),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}
