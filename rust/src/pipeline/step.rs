//! The step-state core: dependency-driven retirement of one optimizer
//! step's per-stage op lists under a virtual clock.
//!
//! Both pipeline execution modes are built on this one engine:
//!
//!  * [`sim::PipelineSim`](super::sim::PipelineSim) plugs in a
//!    timing-only driver (per-stage compute times + fixed message sizes)
//!    to regenerate the paper's throughput tables, and
//!  * [`exec`](super::exec)'s virtual-clock executor plugs in a driver
//!    that runs *real numerics* — stage compute, codec encode/decode,
//!    serialized [`Frame`](crate::codec::Frame) bytes — so the virtual
//!    clock and the threaded runtime share one op-retirement order.
//!
//! The invariant that makes the sharing sound: ops retire **in each
//! stage's schedule order** (`op_idx[s]` only advances), exactly the
//! order a per-stage worker thread ([`exec::run_threads`]) or a
//! run-queue task ([`exec::run_events`], resuming a [`StageScript`]
//! cursor) executes them. A driver that carries per-stage state
//! therefore sees the identical call sequence under every executor,
//! which is what the `tests/exec_vs_sim.rs` determinism harness pins.
//!
//! [`exec::run_threads`]: super::exec::run_threads
//! [`exec::run_events`]: super::exec::run_events

use super::schedule::{Op, Schedule};
use crate::net::Link;
use crate::util::error::Result;

/// Timing/topology parameters of one pipeline step.
#[derive(Clone, Debug)]
pub struct StepConfig {
    pub n_stages: usize,
    pub n_micro: usize,
    pub bandwidth_bps: f64,
    /// Per-boundary bandwidth override (length n_stages-1, App. E
    /// heterogeneous networks); falls back to `bandwidth_bps` when None.
    pub link_bandwidths: Option<Vec<f64>>,
    pub latency_s: f64,
    pub schedule: Schedule,
}

/// The next event in a stage's multi-step script.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageEvent {
    /// Execute this schedule op (in per-stage schedule order).
    Op(Op),
    /// All of the current step's ops retired: exchange/apply the step
    /// gradient and record the step.
    CloseStep,
    /// Every step retired.
    Done,
}

/// One (replica, stage)'s resumable position in its op script across
/// the whole run — the retirement core every executor drives:
///
///  * the threaded mode walks it with blocking receives,
///  * the event mode walks it as far as link readiness allows, parks,
///    and resumes exactly where it stopped,
///  * the virtual clock retires the same per-stage order through
///    [`run_step`]'s dependency engine.
///
/// Ops always retire in schedule order (`advance` only moves forward),
/// which is the invariant that keeps every executor's per-codec-object
/// call sequence — and therefore its numeric trajectory — bit-identical
/// to the oracle's (pinned by `tests/exec_vs_sim.rs`).
#[derive(Clone, Debug)]
pub struct StageScript {
    ops: Vec<Op>,
    steps: usize,
    step: usize,
    idx: usize,
}

impl StageScript {
    /// A script running `ops` once per step for `steps` steps.
    pub fn new(ops: Vec<Op>, steps: usize) -> Self {
        StageScript { ops, steps, step: 0, idx: 0 }
    }

    /// The next event. Stable until [`advance`](Self::advance) is called.
    pub fn peek(&self) -> StageEvent {
        if self.step >= self.steps {
            StageEvent::Done
        } else if self.idx < self.ops.len() {
            StageEvent::Op(self.ops[self.idx])
        } else {
            StageEvent::CloseStep
        }
    }

    /// Retire the current event (a no-op once `Done`).
    pub fn advance(&mut self) {
        if self.step >= self.steps {
            return;
        }
        if self.idx < self.ops.len() {
            self.idx += 1;
        } else {
            self.idx = 0;
            self.step += 1;
        }
    }

    /// The optimizer step the cursor is currently inside.
    pub fn step(&self) -> usize {
        self.step
    }
}

/// What executes when an op retires. `exec` runs the op's work and
/// returns `(compute_seconds, wire_bytes)`: the virtual compute time the
/// op occupies its stage, and the size of the message it emits toward
/// its neighbour (`None` when the op sends nothing — last-stage forward,
/// first-stage backward, or a single-stage pipeline).
pub trait StepDriver {
    fn exec(&mut self, stage: usize, op: Op) -> Result<(f64, Option<u64>)>;
}

/// Virtual-clock accounting of one retired step.
#[derive(Clone, Debug)]
pub struct StepTiming {
    /// End-to-end time of the step (max over stage completion times).
    pub step_time_s: f64,
    /// Per-stage busy (compute) time.
    pub stage_busy_s: Vec<f64>,
    /// Per-stage stall time (waiting on the network).
    pub stall_s: Vec<f64>,
    /// Bytes crossing each forward / backward link.
    pub fw_link_bytes: Vec<u64>,
    pub bw_link_bytes: Vec<u64>,
}

/// Retire every op of one optimizer step, advancing the virtual clock.
///
/// Dependencies: `Fwd(mb)` at stage `s` waits for the forward message of
/// `mb` from `s-1` (stage 0 reads local data at t=0); `Bwd(mb)` waits for
/// the backward message from `s+1` (the last stage depends on its own
/// `Fwd(mb)` instead). Sends are asynchronous — a stage never blocks on
/// its own transmission, only on *receiving* input — and each link is a
/// FIFO serializer (see [`Link`]).
pub fn run_step(cfg: &StepConfig, driver: &mut dyn StepDriver) -> Result<StepTiming> {
    let k = cfg.n_stages;
    let m = cfg.n_micro;
    let link_bw = |b: usize| -> f64 {
        cfg.link_bandwidths.as_ref().map(|v| v[b]).unwrap_or(cfg.bandwidth_bps)
    };
    let mut fw_links: Vec<Link> =
        (0..k.saturating_sub(1)).map(|b| Link::new(link_bw(b), cfg.latency_s)).collect();
    let mut bw_links: Vec<Link> =
        (0..k.saturating_sub(1)).map(|b| Link::new(link_bw(b), cfg.latency_s)).collect();

    let ops: Vec<Vec<Op>> = (0..k).map(|s| cfg.schedule.ops(s, k, m)).collect();
    let mut op_idx = vec![0usize; k];
    let mut stage_free = vec![0f64; k];
    let mut stage_busy = vec![0f64; k];
    let mut stall = vec![0f64; k];

    const PENDING: f64 = f64::INFINITY;
    // fwd_arrival[s][mb]: when stage s's input activation for microbatch
    // mb is available. Stage 0 reads local data (time 0).
    let mut fwd_arrival = vec![vec![PENDING; m]; k];
    let mut bwd_arrival = vec![vec![PENDING; m]; k];
    let mut fwd_done = vec![vec![PENDING; m]; k];
    for t in fwd_arrival[0].iter_mut() {
        *t = 0.0;
    }

    let total_ops: usize = ops.iter().map(|o| o.len()).sum();
    let mut done_ops = 0usize;

    while done_ops < total_ops {
        let mut progressed = false;
        for s in 0..k {
            // retire as many ready ops of stage s as possible, in the
            // stage's schedule order (the same order a worker thread
            // executes them)
            while op_idx[s] < ops[s].len() {
                let op = ops[s][op_idx[s]];
                let dep = match op {
                    Op::Fwd(mb) => fwd_arrival[s][mb],
                    Op::Bwd(mb) => {
                        if s == k - 1 {
                            fwd_done[s][mb]
                        } else {
                            bwd_arrival[s][mb]
                        }
                    }
                };
                if dep == PENDING {
                    break;
                }
                let start = stage_free[s].max(dep);
                stall[s] += start - stage_free[s];
                let (comp, bytes) = driver.exec(s, op)?;
                let end = start + comp;
                stage_free[s] = end;
                stage_busy[s] += comp;
                match op {
                    Op::Fwd(mb) => {
                        fwd_done[s][mb] = end;
                        if s + 1 < k {
                            if let Some(b) = bytes {
                                fwd_arrival[s + 1][mb] = fw_links[s].transmit(end, b);
                            }
                        }
                    }
                    Op::Bwd(mb) => {
                        if s > 0 {
                            if let Some(b) = bytes {
                                bwd_arrival[s - 1][mb] = bw_links[s - 1].transmit(end, b);
                            }
                        }
                    }
                }
                op_idx[s] += 1;
                done_ops += 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline deadlock: schedule has a dependency cycle");
    }

    Ok(StepTiming {
        step_time_s: stage_free.iter().cloned().fold(0.0f64, f64::max),
        stage_busy_s: stage_busy,
        stall_s: stall,
        fw_link_bytes: fw_links.iter().map(|l| l.bytes_sent).collect(),
        bw_link_bytes: bw_links.iter().map(|l| l.bytes_sent).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Uniform {
        k: usize,
        fwd_s: f64,
        bwd_s: f64,
        bytes: u64,
    }

    impl StepDriver for Uniform {
        fn exec(&mut self, stage: usize, op: Op) -> Result<(f64, Option<u64>)> {
            Ok(match op {
                Op::Fwd(_) => {
                    (self.fwd_s, (stage + 1 < self.k).then_some(self.bytes))
                }
                Op::Bwd(_) => (self.bwd_s, (stage > 0).then_some(self.bytes)),
            })
        }
    }

    fn cfg(k: usize, m: usize, schedule: Schedule) -> StepConfig {
        StepConfig {
            n_stages: k,
            n_micro: m,
            bandwidth_bps: 1e12,
            link_bandwidths: None,
            latency_s: 0.0,
            schedule,
        }
    }

    #[test]
    fn gpipe_flush_formula_at_infinite_bandwidth() {
        let (k, m, f, b) = (4usize, 8usize, 0.01, 0.02);
        let mut d = Uniform { k, fwd_s: f, bwd_s: b, bytes: 0 };
        let t = run_step(&cfg(k, m, Schedule::GPipe), &mut d).unwrap();
        let ideal = (m + k - 1) as f64 * (f + b);
        assert!((t.step_time_s - ideal).abs() < 1e-6, "{} vs {ideal}", t.step_time_s);
    }

    #[test]
    fn link_bytes_are_per_message_sums() {
        let (k, m) = (3usize, 4usize);
        let mut d = Uniform { k, fwd_s: 0.01, bwd_s: 0.01, bytes: 1000 };
        let t = run_step(&cfg(k, m, Schedule::OneFOneB), &mut d).unwrap();
        assert_eq!(t.fw_link_bytes, vec![4000, 4000]);
        assert_eq!(t.bw_link_bytes, vec![4000, 4000]);
    }

    #[test]
    fn stage_script_walks_ops_then_close_per_step() {
        let ops = vec![Op::Fwd(0), Op::Bwd(0)];
        let mut sc = StageScript::new(ops.clone(), 2);
        for step in 0..2 {
            assert_eq!(sc.step(), step);
            for &op in &ops {
                assert_eq!(sc.peek(), StageEvent::Op(op));
                sc.advance();
            }
            assert_eq!(sc.peek(), StageEvent::CloseStep);
            sc.advance();
        }
        assert_eq!(sc.peek(), StageEvent::Done);
        sc.advance(); // no-op past the end
        assert_eq!(sc.peek(), StageEvent::Done);
        assert_eq!(sc.step(), 2);
    }

    #[test]
    fn empty_op_list_still_closes_each_step() {
        // a 1-stage 0-micro script cannot occur, but the cursor's
        // contract should not depend on that
        let mut sc = StageScript::new(Vec::new(), 1);
        assert_eq!(sc.peek(), StageEvent::CloseStep);
        sc.advance();
        assert_eq!(sc.peek(), StageEvent::Done);
    }

    #[test]
    fn driver_errors_propagate() {
        struct Failing;
        impl StepDriver for Failing {
            fn exec(&mut self, _s: usize, _op: Op) -> Result<(f64, Option<u64>)> {
                crate::bail!("boom")
            }
        }
        assert!(run_step(&cfg(2, 2, Schedule::GPipe), &mut Failing).is_err());
    }
}
