//! Multi-process serving: one (replica, stage) of the pipeline grid as
//! its own OS process, exchanging serialized frames over real TCP
//! sockets — the `aq-sgd serve-stage` CLI mode.
//!
//! The process brings up its links through `net::session` (handshake
//! with config-fingerprint validation), bonds the same registry-built
//! codec halves the in-process executors would build — same seeds, same
//! construction order, via the helpers `exec` exports — to the socket
//! transports, and drives its one `EventTask` on the shared
//! event-executor machinery (`run_event_pool`) with socket doorbells
//! fired by the I/O driver thread.
//!
//! **Determinism contract.** A TCP connection is FIFO, per-stage ops
//! retire in schedule order, and the ring decodes per sender — exactly
//! the properties that make the in-process executors bit-identical
//! twins. So a multi-process run is bit-identical to the virtual-clock
//! oracle too: per-step loss bits, per-link wire bytes (the length
//! prefix is transport framing and is *not* accounted), codec state,
//! and parameter digests. Link shaping (bandwidth caps, latency,
//! jitter, forced partial reads) changes only *when* frames arrive,
//! never their bytes or order. Each process re-runs the virtual-clock
//! oracle locally after its run and verifies its own (replica, stage)
//! column unless told not to.

use std::sync::Arc;
use std::time::Duration;

use crate::codec::registry::build_mem_pair;
use crate::net::plane::{dp_ring_endpoint, link_endpoint_rx, link_endpoint_tx};
use crate::net::session::{establish, SessionOpts, StageSockets, TopologyPlan};
use crate::net::tcp::LinkShape;
use crate::util::error::Result;

use super::exec::{
    build_workers, bw_boundary_seed, fw_boundary_seed, replica_plane_seed, ring_stage_seed,
    run_event_pool, run_virtual_detailed, EventTask, ExecConfig, StageEndpoints, StageStep,
};
use super::step::StageScript;

/// Canonical config fingerprint exchanged in the session handshake: two
/// peers whose summaries differ are running different jobs and must not
/// train together. Everything that affects the trajectory is in here
/// (the learning rate as raw f32 bits — text formatting must not make
/// two unequal configs look equal).
pub fn config_summary(cfg: &ExecConfig) -> String {
    format!(
        "k={} m={} bsz={} el={} spec={} round={:?} sched={:?} seed={} steps={} lr={:08x} \
         dp={} dpspec={}",
        cfg.n_stages,
        cfg.n_micro,
        cfg.micro_batch,
        cfg.example_len,
        cfg.spec.label(),
        cfg.rounding,
        cfg.schedule,
        cfg.seed,
        cfg.steps,
        cfg.lr.to_bits(),
        cfg.dp_degree,
        cfg.dp_spec.label(),
    )
}

/// Where this process sits in the grid and how its links behave.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub replica: usize,
    pub stage: usize,
    /// Listen/connect addresses for every (replica, stage) process.
    pub plan: TopologyPlan,
    /// Shaping applied to every data socket (token-bucket bandwidth,
    /// injected latency/jitter, forced partial I/O).
    pub shape: LinkShape,
    /// How long the event pool waits with no arriving frame before
    /// declaring the remote peers gone (see `EventSched`).
    pub stall_timeout: Duration,
    pub connect_timeout: Duration,
    pub handshake_timeout: Duration,
    /// Re-run the virtual-clock oracle locally after the run and verify
    /// this process's (replica, stage) column bit-for-bit.
    pub check_oracle: bool,
}

/// What one serve-stage process reports at exit.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub replica: usize,
    pub stage: usize,
    /// This stage's per-step records (loss on the loss head, wire bytes,
    /// post-update parameter digest).
    pub per_step: Vec<StageStep>,
    /// Measured wall time per step.
    pub wall_s: Vec<f64>,
    /// (fw encoder, fw decoder) resident codec state after the run.
    pub fw_state: (u64, u64),
    pub oracle_checked: bool,
}

/// First field where two per-step records disagree, described.
fn step_divergence(got: &StageStep, want: &StageStep) -> Option<String> {
    if got.loss.map(f32::to_bits) != want.loss.map(f32::to_bits) {
        return Some(format!("loss {:?} vs oracle {:?}", got.loss, want.loss));
    }
    if got.fw_wire != want.fw_wire {
        return Some(format!("fw wire bytes {} vs oracle {}", got.fw_wire, want.fw_wire));
    }
    if got.bw_wire != want.bw_wire {
        return Some(format!("bw wire bytes {} vs oracle {}", got.bw_wire, want.bw_wire));
    }
    if got.dp_wire != want.dp_wire {
        return Some(format!("dp wire bytes {} vs oracle {}", got.dp_wire, want.dp_wire));
    }
    if got.digest != want.digest {
        return Some(format!(
            "parameter digest {:016x} vs oracle {:016x}",
            got.digest, want.digest
        ));
    }
    None
}

/// Run one (replica, stage) of the job as this OS process: establish the
/// sessioned TCP links, drive the stage's event task to completion, and
/// (by default) prove the result bit-identical to the virtual-clock
/// oracle.
pub fn serve_stage(cfg: &ExecConfig, opts: &ServeOpts) -> Result<ServeSummary> {
    let (k, d) = (cfg.n_stages, cfg.dp_degree);
    let (r, s) = (opts.replica, opts.stage);
    crate::ensure!(
        opts.plan.n_stages == k && opts.plan.dp_degree == d,
        "topology plan is {} replicas x {} stages but the job is {} x {}",
        opts.plan.dp_degree,
        opts.plan.n_stages,
        d,
        k
    );

    let summary = config_summary(cfg);
    let session = SessionOpts {
        shape: opts.shape.clone(),
        connect_timeout: opts.connect_timeout,
        handshake_timeout: opts.handshake_timeout,
    };
    let StageSockets {
        fw_in: sock_fw_in,
        fw_out: sock_fw_out,
        bw_in: sock_bw_in,
        bw_out: sock_bw_out,
        ring_in: sock_ring_in,
        ring_out: sock_ring_out,
        driver,
    } = establish(&opts.plan, r, s, &summary, &session)?;

    // This process's worker — carved out of the same full-grid
    // construction the in-process executors use, so data shards, ids,
    // and model init are bit-identical.
    let w = build_workers(cfg)?
        .into_iter()
        .nth(r)
        .expect("replica bounds checked by establish")
        .into_iter()
        .nth(s)
        .expect("stage bounds checked by establish");

    // Endpoints: same boundary ids and codec seeds as build_planes, each
    // half bonded to its socket transport. Forward boundary b sits
    // between stages b and b+1; backward traffic reuses b's id.
    let el = cfg.example_len;
    let base = replica_plane_seed(cfg, r);
    let fw_tx = sock_fw_out
        .map(|link| -> Result<_> {
            let enc = build_mem_pair(&cfg.spec.fw, el, cfg.rounding, fw_boundary_seed(base, s))?.0;
            Ok(link_endpoint_tx(s as u32, el, enc, Box::new(link)))
        })
        .transpose()?;
    let fw_rx = sock_fw_in
        .map(|link| -> Result<_> {
            let seed = fw_boundary_seed(base, s - 1);
            let dec = build_mem_pair(&cfg.spec.fw, el, cfg.rounding, seed)?.1;
            Ok(link_endpoint_rx((s - 1) as u32, el, dec, Box::new(link)))
        })
        .transpose()?;
    let bw_tx = sock_bw_out
        .map(|link| -> Result<_> {
            let seed = bw_boundary_seed(base, s - 1);
            let enc = build_mem_pair(&cfg.spec.bw, el, cfg.rounding, seed)?.0;
            Ok(link_endpoint_tx((s - 1) as u32, el, enc, Box::new(link)))
        })
        .transpose()?;
    let bw_rx = sock_bw_in
        .map(|link| -> Result<_> {
            let dec = build_mem_pair(&cfg.spec.bw, el, cfg.rounding, bw_boundary_seed(base, s))?.1;
            Ok(link_endpoint_rx(s as u32, el, dec, Box::new(link)))
        })
        .transpose()?;
    let dp = match (sock_ring_out, sock_ring_in) {
        (Some(tx), Some(rx)) => Some(dp_ring_endpoint(
            &cfg.dp_spec.fw,
            d,
            r,
            2 * el, // flat [dw, db]
            cfg.rounding,
            ring_stage_seed(cfg, s),
            (Box::new(tx), Box::new(rx)),
        )?),
        (None, None) => None,
        _ => crate::bail!("internal error: dp ring socket halves out of sync"),
    };
    let ep = StageEndpoints {
        fw_tx,
        fw_rx,
        bw_tx,
        bw_rx,
        dp,
        fw_in: Vec::new(),
        bw_in: Vec::new(),
    };

    let script = StageScript::new(cfg.schedule.ops(s, k, cfg.n_micro), cfg.steps);
    let task = EventTask::new(w, ep, script, cfg.steps);
    let done = run_event_pool(vec![task], 1, Some(opts.stall_timeout), |sched, tasks| {
        // socket doorbells: the I/O driver thread rings these when a
        // frame finishes reassembly (or the peer closes) — all three
        // wake the one local task
        let t = &mut tasks[0];
        if let Some(rx) = t.ep.fw_rx.as_mut() {
            let sc = Arc::clone(sched);
            rx.set_doorbell(Arc::new(move || sc.wake(0)));
        }
        if let Some(rx) = t.ep.bw_rx.as_mut() {
            let sc = Arc::clone(sched);
            rx.set_doorbell(Arc::new(move || sc.wake(0)));
        }
        if let Some(ring) = t.ep.dp.as_mut() {
            let sc = Arc::clone(sched);
            ring.set_rx_doorbell(Arc::new(move || sc.wake(0)));
        }
    })?;
    // Endpoint drop marked the tx halves closed; joining the driver
    // flushes their tails to the peers (bounded by its flush deadline)
    // before we report success.
    let report = done.into_iter().next().expect("one task, one report").into_report();
    drop(driver);

    let mut oracle_checked = false;
    if opts.check_oracle {
        let (trace, detail) = run_virtual_detailed(cfg)?;
        crate::ensure!(
            report.per_step.len() == detail.len(),
            "ran {} steps, oracle ran {}",
            report.per_step.len(),
            detail.len()
        );
        for (step, (got, row)) in report.per_step.iter().zip(&detail).enumerate() {
            if let Some(why) = step_divergence(got, &row[r][s]) {
                crate::bail!(
                    "replica {r} stage {s} diverged from the virtual-clock oracle at step \
                     {step}: {why}"
                );
            }
        }
        let want_state = trace.fw_state_bytes[r * k + s];
        crate::ensure!(
            report.fw_state == want_state,
            "replica {r} stage {s} codec state {:?} != oracle {:?}",
            report.fw_state,
            want_state
        );
        oracle_checked = true;
    }

    Ok(ServeSummary {
        replica: r,
        stage: s,
        per_step: report.per_step,
        wall_s: report.wall_s,
        fw_state: report.fw_state,
        oracle_checked,
    })
}
