//! Pipeline-parallel machinery: microbatch schedules, the shared
//! step-state core, the event-driven virtual-time simulator that
//! regenerates the paper's throughput tables, and the threaded executor
//! that runs real concurrent stages over channel-backed links (with the
//! simulator as its verified determinism oracle — `tests/exec_vs_sim.rs`).

pub mod exec;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod step;

pub use exec::{ExecConfig, ExecTrace, Executor, StepRecord};
pub use schedule::{Op, Schedule};
pub use serve::{serve_stage, ServeOpts, ServeSummary};
pub use sim::{PipelineSim, SimConfig, SimResult, StageTimes};
pub use step::{run_step, StepConfig, StepDriver, StepTiming};
