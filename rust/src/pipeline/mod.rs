//! Pipeline-parallel machinery: microbatch schedules and the event-driven
//! virtual-time simulator that regenerates the paper's throughput tables.

pub mod schedule;
pub mod sim;

pub use schedule::{Op, Schedule};
pub use sim::{PipelineSim, SimConfig, SimResult, StageTimes};
