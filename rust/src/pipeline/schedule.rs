//! Microbatch schedules for synchronous pipeline parallelism.
//!
//! `GPipe`: all forwards, then all backwards (flush style) — the paper's
//! setting (synchronous macro-batch SGD over micro-batches).
//! `OneFOneB`: PipeDream-flush / 1F1B, which bounds in-flight activations
//! to the stage depth — implemented as the ablation the DESIGN.md §4
//! schedule comparison uses.

/// One unit of stage work on a microbatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Fwd(usize),
    Bwd(usize),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    GPipe,
    OneFOneB,
}

impl Schedule {
    /// Parse a schedule name. Trims surrounding whitespace and matches
    /// case-insensitively ("1F1B", " GPipe " are fine), mirroring
    /// `CodecSpec::parse`'s tolerance for CLI-sourced strings.
    pub fn parse(s: &str) -> crate::util::error::Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "gpipe" => Ok(Schedule::GPipe),
            "1f1b" => Ok(Schedule::OneFOneB),
            _ => crate::bail!("unknown schedule {s:?} (gpipe|1f1b)"),
        }
    }

    /// Ordered op list for `stage` out of `n_stages`, over `n_micro`
    /// microbatches. Every stage executes each Fwd and Bwd exactly once.
    pub fn ops(&self, stage: usize, n_stages: usize, n_micro: usize) -> Vec<Op> {
        match self {
            Schedule::GPipe => {
                let mut ops: Vec<Op> = (0..n_micro).map(Op::Fwd).collect();
                // backwards drain in reverse (LIFO), matching recompute
                // pipelines where the last forward is the first backward
                ops.extend((0..n_micro).rev().map(Op::Bwd));
                ops
            }
            Schedule::OneFOneB => {
                let warmup = (n_stages - 1 - stage).min(n_micro);
                let mut ops = Vec::with_capacity(2 * n_micro);
                for m in 0..warmup {
                    ops.push(Op::Fwd(m));
                }
                let mut next_f = warmup;
                let mut next_b = 0;
                // steady state: one forward, one backward
                while next_f < n_micro {
                    ops.push(Op::Fwd(next_f));
                    next_f += 1;
                    ops.push(Op::Bwd(next_b));
                    next_b += 1;
                }
                // drain the remaining backwards
                while next_b < n_micro {
                    ops.push(Op::Bwd(next_b));
                    next_b += 1;
                }
                ops
            }
        }
    }

    /// Peak number of microbatch activations a stage must hold.
    pub fn peak_in_flight(&self, stage: usize, n_stages: usize, n_micro: usize) -> usize {
        match self {
            Schedule::GPipe => n_micro,
            Schedule::OneFOneB => (n_stages - stage).min(n_micro),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_complete(ops: &[Op], n_micro: usize) {
        let mut fwd = vec![false; n_micro];
        let mut bwd = vec![false; n_micro];
        for op in ops {
            match *op {
                Op::Fwd(m) => {
                    assert!(!fwd[m], "double fwd {m}");
                    fwd[m] = true;
                }
                Op::Bwd(m) => {
                    assert!(fwd[m], "bwd before fwd {m}");
                    assert!(!bwd[m], "double bwd {m}");
                    bwd[m] = true;
                }
            }
        }
        assert!(fwd.iter().all(|&b| b) && bwd.iter().all(|&b| b));
    }

    #[test]
    fn gpipe_complete() {
        for k in 1..=8 {
            for m in 1..=16 {
                for s in 0..k {
                    check_complete(&Schedule::GPipe.ops(s, k, m), m);
                }
            }
        }
    }

    #[test]
    fn ofob_complete() {
        for k in 1..=8 {
            for m in 1..=16 {
                for s in 0..k {
                    check_complete(&Schedule::OneFOneB.ops(s, k, m), m);
                }
            }
        }
    }

    #[test]
    fn ofob_bounds_in_flight() {
        // max simultaneously-held activations on stage 0 of a deep pipe
        let k = 8;
        let m = 32;
        let ops = Schedule::OneFOneB.ops(0, k, m);
        let mut held = 0i64;
        let mut peak = 0i64;
        for op in ops {
            match op {
                Op::Fwd(_) => {
                    held += 1;
                    peak = peak.max(held);
                }
                Op::Bwd(_) => held -= 1,
            }
        }
        assert!(peak as usize <= Schedule::OneFOneB.peak_in_flight(0, k, m));
        assert!(peak < m as i64); // strictly better than GPipe
    }

    #[test]
    fn parse_trims_and_ignores_case() {
        for s in ["gpipe", "GPipe", " GPIPE ", "\tgpipe\n"] {
            assert_eq!(Schedule::parse(s).unwrap(), Schedule::GPipe, "{s:?}");
        }
        for s in ["1f1b", "1F1B", " 1f1B "] {
            assert_eq!(Schedule::parse(s).unwrap(), Schedule::OneFOneB, "{s:?}");
        }
    }

    #[test]
    fn parse_rejection_names_the_alternatives() {
        for s in ["", "pipedream", "gpipe2", "1f-1b"] {
            let err = Schedule::parse(s).unwrap_err().to_string();
            assert!(err.contains("unknown schedule"), "{s:?}: {err}");
            assert!(err.contains("gpipe|1f1b"), "{s:?}: {err}");
            // the offending input is echoed back for CLI users
            assert!(err.contains(&format!("{s:?}")), "{s:?}: {err}");
        }
    }

    #[test]
    fn last_stage_alternates() {
        let ops = Schedule::OneFOneB.ops(3, 4, 6);
        assert_eq!(ops[0], Op::Fwd(0));
        assert_eq!(ops[1], Op::Bwd(0));
        assert_eq!(ops[2], Op::Fwd(1));
    }
}
