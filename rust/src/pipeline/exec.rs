//! Threaded pipeline executor + its virtual-clock twin.
//!
//! This is the first code path that actually *runs* concurrent pipeline
//! stages: each stage is a worker thread executing its
//! [`Schedule`](super::Schedule) op list, connected to its neighbours by
//! channel-backed links ([`net::channel`](crate::net::channel)) that
//! carry real serialized [`Frame`] bytes through registry-built
//! [`BoundaryCodec`](crate::codec::BoundaryCodec) halves — the encoder
//! half lives on the sending thread, the decoder half on the receiving
//! thread, and AC-SGD message-buffer state advances on each side of each
//! link through the frames alone (Algorithm 2's replica symmetry,
//! realized as thread ownership).
//!
//! The same per-stage workers also run under the virtual clock
//! ([`run_virtual`], built on [`super::step`]'s op-retirement core, the
//! engine `PipelineSim` uses). Because ops retire in each stage's
//! schedule order in both modes, the two executors are
//! **seed-deterministic twins**: given the same [`ExecConfig`], their
//! per-step loss and wire-byte trajectories are bit-identical — pinned
//! by `tests/exec_vs_sim.rs`, which is what turns the virtual-clock
//! simulator into a verified oracle instead of an unchecked model.
//!
//! Stage compute is a first-party deterministic model (elementwise
//! affine + tanh regression), so the executor runs end-to-end with zero
//! external dependencies — no AOT artifacts, no PJRT backend.

use std::collections::VecDeque;
use std::thread;
use std::time::{Duration, Instant};

use crate::codec::registry::build_mem_pair;
use crate::codec::{CodecSpec, Frame, Rounding};
use crate::config::TrainConfig;
use crate::coordinator::{BoundaryReceiver, BoundarySender};
use crate::net::{frame_link, FrameLink, FrameLinkRx};
use crate::util::error::{Context, Result};
use crate::util::Rng;

use super::schedule::{Op, Schedule};
use super::step::{run_step, StepConfig, StepDriver};

/// Which pipeline runtime executes a training run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Executor {
    /// Single-threaded virtual-clock execution (the verified oracle).
    Sim,
    /// One worker thread per stage, frames over channel-backed links.
    Threads,
}

impl Executor {
    /// Parse an executor name ("threads" | "sim"). Trims whitespace and
    /// matches case-insensitively, like `Schedule::parse`.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sim" => Ok(Executor::Sim),
            "threads" => Ok(Executor::Threads),
            _ => crate::bail!("unknown executor {s:?} (threads|sim)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Executor::Sim => "sim",
            Executor::Threads => "threads",
        }
    }
}

/// Configuration of one executor run: pipeline shape, codec spec, and
/// the modeled network/compute parameters for the virtual clock (the
/// threaded mode uses bandwidth/latency to pace its links).
#[derive(Clone, Debug)]
pub struct ExecConfig {
    pub n_stages: usize,
    /// Microbatches per optimizer step.
    pub n_micro: usize,
    /// Examples per microbatch.
    pub micro_batch: usize,
    /// Elements per example record (the boundary width).
    pub example_len: usize,
    pub spec: CodecSpec,
    pub rounding: Rounding,
    pub schedule: Schedule,
    pub seed: u64,
    /// Optimizer steps to run.
    pub steps: usize,
    pub lr: f32,
    pub bandwidth_bps: f64,
    pub latency_s: f64,
    /// Modeled per-microbatch compute times (virtual clock only — the
    /// threaded mode's compute time is whatever the host takes).
    pub fwd_s: f64,
    pub bwd_s: f64,
}

impl ExecConfig {
    /// Small self-contained default: 4 stages, 4 microbatches of 2
    /// examples x 64 elements, 4 steps — what the integration tests and
    /// the CLI demo start from.
    pub fn small(spec: CodecSpec) -> Self {
        ExecConfig {
            n_stages: 4,
            n_micro: 4,
            micro_batch: 2,
            example_len: 64,
            spec,
            rounding: Rounding::Nearest,
            schedule: Schedule::GPipe,
            seed: 0,
            steps: 4,
            lr: 0.05,
            bandwidth_bps: 1e11,
            latency_s: 0.0,
            fwd_s: 0.01,
            bwd_s: 0.02,
        }
    }

    /// Derive an executor config from a [`TrainConfig`] (the
    /// `--executor` switch): compression / schedule / seed / n_micro /
    /// lr / network come from the config; the pipeline shape — which the
    /// artifact manifest would normally dictate — is passed explicitly.
    pub fn from_train(
        cfg: &TrainConfig,
        n_stages: usize,
        micro_batch: usize,
        example_len: usize,
        steps: usize,
    ) -> Self {
        ExecConfig {
            n_stages,
            n_micro: cfg.n_micro,
            micro_batch,
            example_len,
            spec: cfg.compression.clone(),
            rounding: if cfg.stochastic_rounding {
                Rounding::Stochastic
            } else {
                Rounding::Nearest
            },
            schedule: cfg.schedule,
            seed: cfg.seed,
            steps,
            lr: cfg.lr as f32,
            bandwidth_bps: cfg.bandwidth_bps,
            latency_s: cfg.latency_s,
            fwd_s: 0.01,
            bwd_s: 0.02,
        }
    }
}

/// One optimizer step of the trajectory both executors must agree on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepRecord {
    /// Mean microbatch loss (accumulated in backward op order — the same
    /// order in both modes, so equality is exact, not approximate).
    pub loss: f32,
    /// Serialized frame bytes crossing each forward boundary this step.
    pub fw_wire_bytes: Vec<u64>,
    /// Same for the backward (gradient) direction.
    pub bw_wire_bytes: Vec<u64>,
}

/// Full trajectory of one executor run.
#[derive(Clone, Debug)]
pub struct ExecTrace {
    pub executor: Executor,
    pub steps: Vec<StepRecord>,
    /// Virtual mode: modeled step time under the clock. Threaded mode:
    /// measured wall time of stage 0's step loop (the stage that starts
    /// first and drains last under a flush schedule).
    pub step_time_s: Vec<f64>,
    /// Per stage: resident state bytes of its (fw encoder, fw decoder)
    /// codec halves after the run — `fw_state_bytes[s].0` must equal
    /// `fw_state_bytes[s+1].1` for stateful schemes (replica symmetry).
    pub fw_state_bytes: Vec<(u64, u64)>,
    /// Peak simultaneously-held microbatch activations per stage (the
    /// memory bound 1F1B exists to provide).
    pub peak_in_flight: Vec<usize>,
}

impl ExecTrace {
    pub fn losses(&self) -> Vec<f32> {
        self.steps.iter().map(|s| s.loss).collect()
    }

    /// True when the per-step loss and wire-byte trajectories of the two
    /// runs are identical. Losses compare as raw f32 bits, so a run that
    /// diverges to NaN identically in both modes still counts as
    /// identical (float `==` would not: NaN != NaN).
    pub fn bit_identical(&self, other: &ExecTrace) -> bool {
        self.steps.len() == other.steps.len()
            && self.steps.iter().zip(&other.steps).all(|(a, b)| {
                a.loss.to_bits() == b.loss.to_bits()
                    && a.fw_wire_bytes == b.fw_wire_bytes
                    && a.bw_wire_bytes == b.bw_wire_bytes
            })
    }
}

/// Run one executor end-to-end.
pub fn run(cfg: &ExecConfig, executor: Executor) -> Result<ExecTrace> {
    match executor {
        Executor::Sim => run_virtual(cfg),
        Executor::Threads => run_threads(cfg),
    }
}

// ---------------------------------------------------------------------------
// Stage compute: a first-party deterministic model
// ---------------------------------------------------------------------------

/// Elementwise affine + tanh stage: `y = tanh(w ⊙ x + b)` with the
/// matching backward. Small enough to be exactly reproducible (plain
/// sequential f32 loops, identical on every host), rich enough that
/// parameters drift step to step — which is what gives AC-SGD's delta
/// codec a real signal to compress.
struct ToyStage {
    el: usize,
    w: Vec<f32>,
    b: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

impl ToyStage {
    fn new(el: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let w = (0..el).map(|_| 0.8 + 0.2 * rng.normal()).collect();
        let b = (0..el).map(|_| 0.05 * rng.normal()).collect();
        ToyStage { el, w, b, dw: vec![0.0; el], db: vec![0.0; el] }
    }

    fn forward(&self, x: &[f32]) -> Vec<f32> {
        let el = self.el;
        x.iter()
            .enumerate()
            .map(|(i, &v)| (self.w[i % el] * v + self.b[i % el]).tanh())
            .collect()
    }

    /// Accumulate parameter gradients; return the input gradient.
    fn backward(&mut self, x: &[f32], y: &[f32], g: &[f32]) -> Vec<f32> {
        let el = self.el;
        let mut dx = vec![0f32; x.len()];
        for i in 0..x.len() {
            let j = i % el;
            let t = g[i] * (1.0 - y[i] * y[i]);
            self.dw[j] += t * x[i];
            self.db[j] += t;
            dx[i] = t * self.w[j];
        }
        dx
    }

    /// SGD step over the microbatch-mean gradient; resets accumulators.
    fn apply(&mut self, lr: f32, inv_micro: f32) {
        for j in 0..self.el {
            self.w[j] -= lr * self.dw[j] * inv_micro;
            self.b[j] -= lr * self.db[j] * inv_micro;
            self.dw[j] = 0.0;
            self.db[j] = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Stage worker: everything one stage owns, in either execution mode
// ---------------------------------------------------------------------------

/// Per-step accounting one stage produces.
#[derive(Clone, Debug, Default)]
struct StageStep {
    loss: Option<f32>,
    fw_wire: u64,
    bw_wire: u64,
}

/// One pipeline stage: its model, its codec endpoint halves (encoder
/// toward the next stage, decoder from the previous, and the reverse
/// pair for gradients), and the saved per-microbatch activations its
/// backward passes need. Owned by a worker thread in threaded mode, by
/// the virtual-clock driver otherwise — the op call sequence is the same.
struct StageWorker {
    stage: usize,
    n_stages: usize,
    n_micro: usize,
    lr: f32,
    model: ToyStage,
    fw_send: Option<BoundarySender>,
    fw_recv: Option<BoundaryReceiver>,
    bw_send: Option<BoundarySender>,
    bw_recv: Option<BoundaryReceiver>,
    /// Stage 0 only: the local training inputs, one per microbatch.
    inputs: Vec<Vec<f32>>,
    /// Last stage only: regression targets, one per microbatch.
    targets: Vec<Vec<f32>>,
    /// Example ids per microbatch (keys the AC-SGD buffers).
    ids: Vec<Vec<u64>>,
    saved_x: Vec<Option<Vec<f32>>>,
    saved_y: Vec<Option<Vec<f32>>>,
    in_flight: usize,
    peak_in_flight: usize,
    cur: StageStep,
}

impl StageWorker {
    /// Forward one microbatch. `incoming` is the serialized frame from
    /// stage-1 (None on stage 0). Returns the serialized frame for
    /// stage+1 (None on the last stage).
    fn fwd(&mut self, mb: usize, incoming: Option<Vec<u8>>) -> Result<Option<Vec<u8>>> {
        let x = if self.stage == 0 {
            self.inputs[mb].clone()
        } else {
            let bytes = incoming
                .with_context(|| format!("stage {}: no forward frame for mb {mb}", self.stage))?;
            let frame = Frame::from_bytes(&bytes)?;
            self.fw_recv
                .as_mut()
                .context("interior stage without a forward decoder")?
                .decode(&self.ids[mb], &frame)?
        };
        let y = self.model.forward(&x);
        let out = if let Some(tx) = self.fw_send.as_mut() {
            let (frame, stats) = tx.encode(&self.ids[mb], &y)?;
            self.cur.fw_wire += stats.wire_bytes;
            Some(frame.to_bytes())
        } else {
            None
        };
        self.saved_x[mb] = Some(x);
        self.saved_y[mb] = Some(y);
        self.in_flight += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        Ok(out)
    }

    /// Backward one microbatch. `incoming` is the serialized gradient
    /// frame from stage+1 (None on the last stage, which starts from the
    /// loss). Returns the serialized gradient frame for stage-1 (None on
    /// stage 0).
    fn bwd(&mut self, mb: usize, incoming: Option<Vec<u8>>) -> Result<Option<Vec<u8>>> {
        let x = self.saved_x[mb]
            .take()
            .with_context(|| format!("stage {}: backward before forward (mb {mb})", self.stage))?;
        let y = self.saved_y[mb]
            .take()
            .with_context(|| format!("stage {}: backward before forward (mb {mb})", self.stage))?;
        let g = if self.stage + 1 == self.n_stages {
            // loss head: 0.5 * mean squared error against the target
            let t = &self.targets[mb];
            crate::ensure!(
                t.len() == y.len(),
                "target length {} != activation length {}",
                t.len(),
                y.len()
            );
            let n = y.len() as f32;
            let mut loss = 0f32;
            let mut g = vec![0f32; y.len()];
            for i in 0..y.len() {
                let d = y[i] - t[i];
                loss += d * d;
                g[i] = d / n;
            }
            self.cur.loss = Some(self.cur.loss.unwrap_or(0.0) + loss / (2.0 * n));
            g
        } else {
            let bytes = incoming
                .with_context(|| format!("stage {}: no backward frame for mb {mb}", self.stage))?;
            let frame = Frame::from_bytes(&bytes)?;
            self.bw_recv
                .as_mut()
                .context("interior stage without a backward decoder")?
                .decode(&self.ids[mb], &frame)?
        };
        let dx = self.model.backward(&x, &y, &g);
        self.in_flight -= 1;
        if let Some(tx) = self.bw_send.as_mut() {
            let (frame, stats) = tx.encode(&self.ids[mb], &dx)?;
            self.cur.bw_wire += stats.wire_bytes;
            Ok(Some(frame.to_bytes()))
        } else {
            Ok(None)
        }
    }

    /// Close one optimizer step: apply the SGD update and hand back this
    /// step's accounting.
    fn end_step(&mut self) -> StageStep {
        self.model.apply(self.lr, 1.0 / self.n_micro as f32);
        let mut rec = std::mem::take(&mut self.cur);
        if let Some(l) = rec.loss.as_mut() {
            *l /= self.n_micro as f32;
        }
        rec
    }
}

/// Build the per-stage workers: models, data, and both codec halves of
/// every boundary, with the sender/receiver halves sharing only their
/// construction seed (never state). Both execution modes start from this
/// one function, which is what makes them comparable bit for bit.
fn build_workers(cfg: &ExecConfig) -> Result<Vec<StageWorker>> {
    crate::ensure!(cfg.n_stages >= 1, "executor needs at least one stage");
    crate::ensure!(cfg.n_micro >= 1, "executor needs at least one microbatch");
    crate::ensure!(
        cfg.micro_batch >= 1 && cfg.example_len >= 1,
        "executor needs a non-empty microbatch shape"
    );
    crate::ensure!(cfg.steps >= 1, "executor needs at least one step");
    let k = cfg.n_stages;
    let m = cfg.n_micro;
    let el = cfg.example_len;
    let bsz = cfg.micro_batch;

    let mut fw_send: Vec<Option<BoundarySender>> = (0..k).map(|_| None).collect();
    let mut fw_recv: Vec<Option<BoundaryReceiver>> = (0..k).map(|_| None).collect();
    let mut bw_send: Vec<Option<BoundarySender>> = (0..k).map(|_| None).collect();
    let mut bw_recv: Vec<Option<BoundaryReceiver>> = (0..k).map(|_| None).collect();
    for b in 0..k.saturating_sub(1) {
        // same seed namespaces the trainer uses; the spec seed folds in
        // the run seed so changing it re-randomizes stochastic rounding
        let base = cfg.seed.wrapping_mul(0x9E37_79B9);
        let (enc, dec) =
            build_mem_pair(&cfg.spec.fw, el, cfg.rounding, base.wrapping_add(0xB0D1 + b as u64))?;
        fw_send[b] = Some(BoundarySender::new(b as u32, el, enc));
        fw_recv[b + 1] = Some(BoundaryReceiver::new(b as u32, el, dec));
        let (enc, dec) =
            build_mem_pair(&cfg.spec.bw, el, cfg.rounding, base.wrapping_add(0xBACC + b as u64))?;
        bw_send[b + 1] = Some(BoundarySender::new(b as u32, el, enc));
        bw_recv[b] = Some(BoundaryReceiver::new(b as u32, el, dec));
    }

    // deterministic dataset: stable example ids so AC-SGD buffers are
    // revisited every step (first step full precision, then deltas)
    let mut data_rng = Rng::new(cfg.seed ^ 0xDA7A_0001);
    let inputs: Vec<Vec<f32>> =
        (0..m).map(|_| (0..bsz * el).map(|_| 0.8 * data_rng.normal()).collect()).collect();
    let mut tgt_rng = Rng::new(cfg.seed ^ 0x7A46_0002);
    let targets: Vec<Vec<f32>> =
        (0..m).map(|_| (0..bsz * el).map(|_| 0.5 * tgt_rng.normal()).collect()).collect();
    let ids: Vec<Vec<u64>> =
        (0..m).map(|mb| ((mb * bsz) as u64..((mb + 1) * bsz) as u64).collect()).collect();

    let mut workers = Vec::with_capacity(k);
    for s in 0..k {
        workers.push(StageWorker {
            stage: s,
            n_stages: k,
            n_micro: m,
            lr: cfg.lr,
            model: ToyStage::new(el, cfg.seed.wrapping_add(0xC0DE + 131 * s as u64)),
            fw_send: fw_send[s].take(),
            fw_recv: fw_recv[s].take(),
            bw_send: bw_send[s].take(),
            bw_recv: bw_recv[s].take(),
            inputs: if s == 0 { inputs.clone() } else { Vec::new() },
            targets: if s == k - 1 { targets.clone() } else { Vec::new() },
            ids: ids.clone(),
            saved_x: (0..m).map(|_| None).collect(),
            saved_y: (0..m).map(|_| None).collect(),
            in_flight: 0,
            peak_in_flight: 0,
            cur: StageStep::default(),
        });
    }
    Ok(workers)
}

/// Fold per-stage step accounting into one [`StepRecord`]: forward wire
/// bytes indexed by sending stage (boundary b = stage b), backward by
/// receiving boundary (stage b+1 sends across boundary b), loss from the
/// last stage. Both execution modes assemble through this one function.
fn assemble_record(stage_steps: &[StageStep]) -> StepRecord {
    let k = stage_steps.len();
    let mut rec = StepRecord::default();
    for (s, st) in stage_steps.iter().enumerate() {
        if s + 1 < k {
            rec.fw_wire_bytes.push(st.fw_wire);
        }
        if s > 0 {
            rec.bw_wire_bytes.push(st.bw_wire);
        }
        if let Some(l) = st.loss {
            rec.loss = l;
        }
    }
    rec
}

fn collect_step(workers: &mut [StageWorker]) -> StepRecord {
    let stage_steps: Vec<StageStep> = workers.iter_mut().map(|w| w.end_step()).collect();
    assemble_record(&stage_steps)
}

// ---------------------------------------------------------------------------
// Virtual-clock mode (the oracle)
// ---------------------------------------------------------------------------

/// [`StepDriver`] that runs the real numerics under the virtual clock:
/// frames queue in per-link FIFOs exactly as the channel transport
/// delivers them (one producer, one consumer, schedule order on both
/// ends), and the modeled compute/transmit times drive the clock.
struct VirtualDriver<'a> {
    workers: &'a mut [StageWorker],
    fw_q: Vec<VecDeque<Vec<u8>>>,
    bw_q: Vec<VecDeque<Vec<u8>>>,
    fwd_s: f64,
    bwd_s: f64,
}

impl StepDriver for VirtualDriver<'_> {
    fn exec(&mut self, stage: usize, op: Op) -> Result<(f64, Option<u64>)> {
        let k = self.workers.len();
        match op {
            Op::Fwd(mb) => {
                let incoming = if stage > 0 {
                    Some(self.fw_q[stage - 1].pop_front().with_context(|| {
                        format!("virtual clock: forward frame for stage {stage} mb {mb} missing")
                    })?)
                } else {
                    None
                };
                let out = self.workers[stage].fwd(mb, incoming)?;
                let bytes = out.as_ref().map(|b| b.len() as u64);
                if let Some(b) = out {
                    self.fw_q[stage].push_back(b);
                }
                Ok((self.fwd_s, bytes))
            }
            Op::Bwd(mb) => {
                let incoming = if stage + 1 < k {
                    Some(self.bw_q[stage].pop_front().with_context(|| {
                        format!("virtual clock: backward frame for stage {stage} mb {mb} missing")
                    })?)
                } else {
                    None
                };
                let out = self.workers[stage].bwd(mb, incoming)?;
                let bytes = out.as_ref().map(|b| b.len() as u64);
                if let Some(b) = out {
                    self.bw_q[stage - 1].push_back(b);
                }
                Ok((self.bwd_s, bytes))
            }
        }
    }
}

/// Run the full training loop single-threaded under the virtual clock.
pub fn run_virtual(cfg: &ExecConfig) -> Result<ExecTrace> {
    let mut workers = build_workers(cfg)?;
    let k = cfg.n_stages;
    let step_cfg = StepConfig {
        n_stages: k,
        n_micro: cfg.n_micro,
        bandwidth_bps: cfg.bandwidth_bps,
        link_bandwidths: None,
        latency_s: cfg.latency_s,
        schedule: cfg.schedule,
    };
    let mut trace = ExecTrace {
        executor: Executor::Sim,
        steps: Vec::with_capacity(cfg.steps),
        step_time_s: Vec::with_capacity(cfg.steps),
        fw_state_bytes: Vec::new(),
        peak_in_flight: Vec::new(),
    };
    for _ in 0..cfg.steps {
        let timing = {
            let mut driver = VirtualDriver {
                workers: &mut workers,
                fw_q: (0..k.saturating_sub(1)).map(|_| VecDeque::new()).collect(),
                bw_q: (0..k.saturating_sub(1)).map(|_| VecDeque::new()).collect(),
                fwd_s: cfg.fwd_s,
                bwd_s: cfg.bwd_s,
            };
            run_step(&step_cfg, &mut driver)?
        };
        trace.step_time_s.push(timing.step_time_s);
        trace.steps.push(collect_step(&mut workers));
    }
    trace.fw_state_bytes = workers
        .iter()
        .map(|w| {
            (
                w.fw_send.as_ref().map_or(0, |h| h.state_bytes()),
                w.fw_recv.as_ref().map_or(0, |h| h.state_bytes()),
            )
        })
        .collect();
    trace.peak_in_flight = workers.iter().map(|w| w.peak_in_flight).collect();
    Ok(trace)
}

// ---------------------------------------------------------------------------
// Threaded mode (the real runtime)
// ---------------------------------------------------------------------------

/// What one stage's worker thread hands back at join.
struct StageReport {
    per_step: Vec<StageStep>,
    wall_s: Vec<f64>,
    fw_state: (u64, u64),
    peak_in_flight: usize,
}

/// Run the full training loop with one worker thread per stage,
/// exchanging serialized frames over channel-backed links.
pub fn run_threads(cfg: &ExecConfig) -> Result<ExecTrace> {
    let workers = build_workers(cfg)?;
    let k = cfg.n_stages;
    let latency = Duration::from_secs_f64(cfg.latency_s);

    let mut fw_tx: Vec<Option<FrameLink>> = (0..k).map(|_| None).collect();
    let mut fw_rx: Vec<Option<FrameLinkRx>> = (0..k).map(|_| None).collect();
    let mut bw_tx: Vec<Option<FrameLink>> = (0..k).map(|_| None).collect();
    let mut bw_rx: Vec<Option<FrameLinkRx>> = (0..k).map(|_| None).collect();
    for b in 0..k.saturating_sub(1) {
        let (tx, rx) = frame_link(cfg.bandwidth_bps, latency);
        fw_tx[b] = Some(tx); // stage b sends forward
        fw_rx[b + 1] = Some(rx); // stage b+1 receives
        let (tx, rx) = frame_link(cfg.bandwidth_bps, latency);
        bw_tx[b + 1] = Some(tx); // stage b+1 sends gradients back
        bw_rx[b] = Some(rx);
    }

    let mut handles = Vec::with_capacity(k);
    for (s, mut w) in workers.into_iter().enumerate() {
        let ops = cfg.schedule.ops(s, k, cfg.n_micro);
        let steps = cfg.steps;
        let mut my_fw_tx = fw_tx[s].take();
        let my_fw_rx = fw_rx[s].take();
        let mut my_bw_tx = bw_tx[s].take();
        let my_bw_rx = bw_rx[s].take();
        let spawned = thread::Builder::new()
            .name(format!("aq-stage{s}"))
            .spawn(move || -> Result<StageReport> {
                let mut per_step = Vec::with_capacity(steps);
                let mut wall_s = Vec::with_capacity(steps);
                for _ in 0..steps {
                    let t0 = Instant::now();
                    for &op in &ops {
                        match op {
                            Op::Fwd(mb) => {
                                let incoming = match &my_fw_rx {
                                    Some(rx) => Some(rx.recv()?),
                                    None => None,
                                };
                                if let Some(bytes) = w.fwd(mb, incoming)? {
                                    my_fw_tx
                                        .as_mut()
                                        .context("non-last stage without a forward link")?
                                        .send(bytes);
                                }
                            }
                            Op::Bwd(mb) => {
                                let incoming = match &my_bw_rx {
                                    Some(rx) => Some(rx.recv()?),
                                    None => None,
                                };
                                if let Some(bytes) = w.bwd(mb, incoming)? {
                                    my_bw_tx
                                        .as_mut()
                                        .context("non-first stage without a backward link")?
                                        .send(bytes);
                                }
                            }
                        }
                    }
                    per_step.push(w.end_step());
                    wall_s.push(t0.elapsed().as_secs_f64());
                }
                Ok(StageReport {
                    per_step,
                    wall_s,
                    fw_state: (
                        w.fw_send.as_ref().map_or(0, |h| h.state_bytes()),
                        w.fw_recv.as_ref().map_or(0, |h| h.state_bytes()),
                    ),
                    peak_in_flight: w.peak_in_flight,
                })
            });
        match spawned {
            Ok(h) => handles.push(h),
            Err(e) => {
                // the failed stage's closure (and its links) was dropped,
                // so every already-spawned neighbour unwinds with a
                // channel-closed error; drain them before reporting
                for h in handles {
                    let _ = h.join();
                }
                return Err(crate::err!("failed to spawn stage {s} worker thread: {e}"));
            }
        }
    }

    let mut results: Vec<Result<StageReport>> = Vec::with_capacity(k);
    for h in handles {
        results.push(match h.join() {
            Ok(r) => r,
            Err(_) => Err(crate::err!("stage worker thread panicked")),
        });
    }
    if results.iter().any(|r| r.is_err()) {
        // a failing stage drops its channels, which unwinds its
        // neighbours with "channel closed" errors — report the root
        // cause, not the cascade
        let mut cascade = None;
        for r in results {
            if let Err(e) = r {
                if !e.to_string().contains("pipeline channel closed") {
                    return Err(e);
                }
                cascade.get_or_insert(e);
            }
        }
        return Err(cascade.expect("at least one error present"));
    }
    let reports: Vec<StageReport> = results.into_iter().map(|r| r.unwrap()).collect();

    let mut trace = ExecTrace {
        executor: Executor::Threads,
        steps: Vec::with_capacity(cfg.steps),
        step_time_s: Vec::with_capacity(cfg.steps),
        fw_state_bytes: reports.iter().map(|r| r.fw_state).collect(),
        peak_in_flight: reports.iter().map(|r| r.peak_in_flight).collect(),
    };
    for step in 0..cfg.steps {
        let stage_steps: Vec<StageStep> =
            reports.iter().map(|r| r.per_step[step].clone()).collect();
        trace.steps.push(assemble_record(&stage_steps));
        trace.step_time_s.push(reports[0].wall_s[step]);
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_parse_trims_and_ignores_case() {
        assert_eq!(Executor::parse(" Threads ").unwrap(), Executor::Threads);
        assert_eq!(Executor::parse("SIM").unwrap(), Executor::Sim);
        let err = Executor::parse("gpu").unwrap_err().to_string();
        assert!(err.contains("threads|sim"), "{err}");
    }

    #[test]
    fn virtual_executor_trains_and_accounts_bytes() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.steps = 6;
        let t = run_virtual(&cfg).unwrap();
        assert_eq!(t.steps.len(), 6);
        for rec in &t.steps {
            assert!(rec.loss.is_finite());
            assert_eq!(rec.fw_wire_bytes.len(), cfg.n_stages - 1);
            assert_eq!(rec.bw_wire_bytes.len(), cfg.n_stages - 1);
            for &b in rec.fw_wire_bytes.iter().chain(&rec.bw_wire_bytes) {
                assert!(b > 0);
            }
        }
        // the toy regression learns: loss drops over the run
        assert!(
            t.steps.last().unwrap().loss < t.steps[0].loss,
            "{:?}",
            t.losses()
        );
    }

    #[test]
    fn aq_wire_bytes_collapse_after_first_epoch() {
        let mut cfg = ExecConfig::small(CodecSpec::aqsgd(2, 4));
        cfg.steps = 3;
        let t = run_virtual(&cfg).unwrap();
        // step 0 sends full-precision first-visit records; steady state
        // sends 2-bit deltas
        let first: u64 = t.steps[0].fw_wire_bytes.iter().sum();
        let steady: u64 = t.steps[2].fw_wire_bytes.iter().sum();
        assert!(steady * 4 < first, "first {first} steady {steady}");
        // Algorithm 2 replica symmetry across each boundary
        for s in 0..cfg.n_stages - 1 {
            assert!(t.fw_state_bytes[s].0 > 0);
            assert_eq!(t.fw_state_bytes[s].0, t.fw_state_bytes[s + 1].1, "boundary {s}");
        }
    }

    #[test]
    fn single_stage_pipeline_works_in_both_modes() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_stages = 1;
        cfg.steps = 2;
        let v = run_virtual(&cfg).unwrap();
        let t = run_threads(&cfg).unwrap();
        assert_eq!(v.losses(), t.losses());
        assert!(v.steps[0].fw_wire_bytes.is_empty());
    }

    #[test]
    fn ofob_respects_the_memory_bound() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_micro = 8;
        cfg.schedule = Schedule::OneFOneB;
        cfg.steps = 2;
        let t = run_virtual(&cfg).unwrap();
        for (s, &peak) in t.peak_in_flight.iter().enumerate() {
            let bound = cfg.schedule.peak_in_flight(s, cfg.n_stages, cfg.n_micro);
            assert!(peak <= bound, "stage {s}: peak {peak} > bound {bound}");
        }
    }
}
