//! Threaded pipeline executor + its virtual-clock twin, over the
//! unified CommPlane.
//!
//! Every message class travels the same way: a registry-built
//! [`BoundaryCodec`](crate::codec::BoundaryCodec) half bonded to a
//! directed frame link — a [`LinkEndpointTx`]/[`LinkEndpointRx`] pair
//! (`net::plane`). Stage workers are pure compute (first-party
//! deterministic tanh-affine stages + SGD, per-microbatch saved
//! activations); the endpoints own the codecs and the byte accounting,
//! and serialized [`Frame`](crate::codec::Frame) images are the only
//! thing that crosses between stages *or* between data-parallel
//! replicas:
//!
//!  * **forward activations / backward gradients** — per-boundary
//!    endpoint pairs, encoder on the sending stage, decoder on the
//!    receiving stage;
//!  * **DP model gradients** (`dp_degree > 1`) — a per-stage
//!    [`DpRing`]: each replica's stage encodes its error-compensated
//!    gradient (the `ef:` codec of `dp_spec`) once, frames circulate
//!    `degree - 1` serialized hops, and every replica reconstructs the
//!    bit-identical mean through per-sender decoder replicas.
//!
//! The three execution modes share one worker/endpoint construction:
//! `run_threads` runs one thread per (replica, stage) with link pacing
//! at the configured bandwidth/latency; `run_events` drives the same
//! (replica, stage) tasks as resumable [`StageScript`] state machines
//! from a run queue on a small fixed worker pool — tasks park when a
//! link polls not-ready instead of blocking a thread, and link doorbells
//! requeue them; `run_virtual` runs the same endpoints over unpaced
//! links (infinite bandwidth — a pure FIFO) under [`super::step`]'s
//! op-retirement clock, modeling the ring's serialized hops separately.
//! Because ops retire in per-stage schedule order in every mode, links
//! are SPSC FIFOs, and the ring decodes per *sender* (never per
//! arrival), every codec object sees the identical call sequence no
//! matter how tasks interleave: the executors are **seed-deterministic
//! twins** — per-step loss, per-link wire bytes, DP ring bytes, and
//! per-replica parameter digests are bit-identical for any worker-pool
//! size — pinned by `tests/exec_vs_sim.rs` and `tests/prop_sched.rs`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread;
use std::time::{Duration, Instant};

use crate::codec::registry::build_mem_pair;
use crate::codec::{CodecSpec, Rounding};
use crate::config::TrainConfig;
use crate::net::plane::{dp_rings, link_endpoints, DpRing, LinkEndpointRx, LinkEndpointTx};
use crate::net::Poll;
use crate::util::error::{Context, Result};
use crate::util::Rng;

use super::schedule::{Op, Schedule};
use super::sim::PipelineSim;
use super::step::{run_step, StageEvent, StageScript, StepConfig, StepDriver};

/// Which pipeline runtime executes a training run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Executor {
    /// Single-threaded virtual-clock execution (the verified oracle).
    Sim,
    /// One worker thread per (replica, stage), frames over channel links.
    Threads,
    /// Fixed worker pool driving ready (replica, stage) tasks from a run
    /// queue — the scale mode (hundreds of stages on a handful of
    /// threads).
    Events,
}

impl Executor {
    /// Parse an executor name ("threads" | "events" | "sim"). Trims
    /// whitespace and matches case-insensitively, like `Schedule::parse`.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sim" => Ok(Executor::Sim),
            "threads" => Ok(Executor::Threads),
            "events" => Ok(Executor::Events),
            _ => crate::bail!("unknown executor {s:?} (threads|events|sim)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Executor::Sim => "sim",
            Executor::Threads => "threads",
            Executor::Events => "events",
        }
    }
}

/// Configuration of one executor run: pipeline shape, codec specs, and
/// the modeled network/compute parameters for the virtual clock (the
/// threaded mode uses bandwidth/latency to pace its links).
#[derive(Clone, Debug)]
pub struct ExecConfig {
    pub n_stages: usize,
    /// Microbatches per optimizer step.
    pub n_micro: usize,
    /// Examples per microbatch.
    pub micro_batch: usize,
    /// Elements per example record (the boundary width).
    pub example_len: usize,
    pub spec: CodecSpec,
    pub rounding: Rounding,
    pub schedule: Schedule,
    pub seed: u64,
    /// Optimizer steps to run.
    pub steps: usize,
    pub lr: f32,
    pub bandwidth_bps: f64,
    pub latency_s: f64,
    /// Modeled per-microbatch compute times (virtual clock only — the
    /// threaded mode's compute time is whatever the host takes).
    pub fwd_s: f64,
    pub bwd_s: f64,
    /// Data-parallel replicas (1 = no DP). Each replica runs the full
    /// pipeline on a disjoint shard and exchanges model gradients over
    /// the per-stage ring after every step.
    pub dp_degree: usize,
    /// Gradient codec for the DP ring (`--dp-codec`; `ef:directq:fw4bw4`
    /// is Fig. 5's error-compensated regime).
    pub dp_spec: CodecSpec,
    /// Worker threads for [`Executor::Events`] (`--workers`; capped at
    /// the task count, ignored by the other modes). Any pool size ≥ 1
    /// produces the identical trajectory.
    pub workers: usize,
}

impl ExecConfig {
    /// Small self-contained default: 4 stages, 4 microbatches of 2
    /// examples x 64 elements, 4 steps, no DP — what the integration
    /// tests and the CLI demo start from.
    pub fn small(spec: CodecSpec) -> Self {
        ExecConfig {
            n_stages: 4,
            n_micro: 4,
            micro_batch: 2,
            example_len: 64,
            spec,
            rounding: Rounding::Nearest,
            schedule: Schedule::GPipe,
            seed: 0,
            steps: 4,
            lr: 0.05,
            bandwidth_bps: 1e11,
            latency_s: 0.0,
            fwd_s: 0.01,
            bwd_s: 0.02,
            dp_degree: 1,
            dp_spec: CodecSpec::fp32(),
            workers: 4,
        }
    }

    /// Derive an executor config from a [`TrainConfig`] (the
    /// `--executor` switch): compression / dp codec / schedule / seed /
    /// n_micro / lr / network come from the config; the pipeline shape —
    /// which the artifact manifest would normally dictate — is passed
    /// explicitly.
    pub fn from_train(
        cfg: &TrainConfig,
        n_stages: usize,
        micro_batch: usize,
        example_len: usize,
        steps: usize,
    ) -> Self {
        ExecConfig {
            n_stages,
            n_micro: cfg.n_micro,
            micro_batch,
            example_len,
            spec: cfg.compression.clone(),
            rounding: if cfg.stochastic_rounding {
                Rounding::Stochastic
            } else {
                Rounding::Nearest
            },
            schedule: cfg.schedule,
            seed: cfg.seed,
            steps,
            lr: cfg.lr as f32,
            bandwidth_bps: cfg.bandwidth_bps,
            latency_s: cfg.latency_s,
            fwd_s: 0.01,
            bwd_s: 0.02,
            dp_degree: cfg.dp_degree,
            dp_spec: cfg.dp_codec.clone(),
            workers: cfg.workers,
        }
    }
}

/// One optimizer step of the trajectory both executors must agree on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepRecord {
    /// Mean microbatch loss across replicas (accumulated in fixed
    /// replica/backward-op order — the same order in both modes, so
    /// equality is exact, not approximate).
    pub loss: f32,
    /// Serialized frame bytes crossing each forward boundary this step
    /// (summed over replicas).
    pub fw_wire_bytes: Vec<u64>,
    /// Same for the backward (activation-gradient) direction.
    pub bw_wire_bytes: Vec<u64>,
    /// Serialized DP ring frame bytes shipped per stage this step
    /// (summed over replicas; all zeros when `dp_degree == 1`).
    pub dp_wire_bytes: Vec<u64>,
    /// Per-replica parameter digest after the step's update (FNV-1a
    /// over all stage parameter bits, stage order). With error-feedback
    /// compression and synchronized updates these must all be equal —
    /// the replica-equality invariant.
    pub replica_digests: Vec<u64>,
}

/// Full trajectory of one executor run.
#[derive(Clone, Debug)]
pub struct ExecTrace {
    pub executor: Executor,
    pub steps: Vec<StepRecord>,
    /// Virtual mode: modeled step time under the clock (pipeline + DP
    /// ring hops). Threaded mode: measured wall time of replica 0 /
    /// stage 0's step loop.
    pub step_time_s: Vec<f64>,
    /// Per (replica, stage), flattened `replica * n_stages + stage`:
    /// resident state bytes of the (fw encoder, fw decoder) endpoint
    /// halves after the run — the encoder entry of boundary `s` must
    /// equal the decoder entry of stage `s+1` for stateful schemes
    /// (replica symmetry).
    pub fw_state_bytes: Vec<(u64, u64)>,
    /// Peak simultaneously-held microbatch activations per (replica,
    /// stage), flattened like `fw_state_bytes`.
    pub peak_in_flight: Vec<usize>,
}

impl ExecTrace {
    pub fn losses(&self) -> Vec<f32> {
        self.steps.iter().map(|s| s.loss).collect()
    }

    /// True when the per-step loss, wire-byte, DP ring, and
    /// replica-digest trajectories of the two runs are identical. Losses
    /// compare as raw f32 bits, so a run that diverges to NaN
    /// identically in both modes still counts as identical (float `==`
    /// would not: NaN != NaN).
    pub fn bit_identical(&self, other: &ExecTrace) -> bool {
        self.steps.len() == other.steps.len()
            && self.steps.iter().zip(&other.steps).all(|(a, b)| {
                a.loss.to_bits() == b.loss.to_bits()
                    && a.fw_wire_bytes == b.fw_wire_bytes
                    && a.bw_wire_bytes == b.bw_wire_bytes
                    && a.dp_wire_bytes == b.dp_wire_bytes
                    && a.replica_digests == b.replica_digests
            })
    }
}

/// Run one executor end-to-end.
pub fn run(cfg: &ExecConfig, executor: Executor) -> Result<ExecTrace> {
    match executor {
        Executor::Sim => run_virtual(cfg),
        Executor::Threads => run_threads(cfg),
        Executor::Events => run_events(cfg),
    }
}

// ---------------------------------------------------------------------------
// Stage compute: a first-party deterministic model
// ---------------------------------------------------------------------------

/// Elementwise affine + tanh stage: `y = tanh(w ⊙ x + b)` with the
/// matching backward. Small enough to be exactly reproducible (plain
/// sequential f32 loops, identical on every host), rich enough that
/// parameters drift step to step — which is what gives AC-SGD's delta
/// codec and the EF gradient compressor a real signal to work with.
pub(crate) struct ToyStage {
    el: usize,
    w: Vec<f32>,
    b: Vec<f32>,
    dw: Vec<f32>,
    db: Vec<f32>,
}

impl ToyStage {
    pub(crate) fn new(el: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let w = (0..el).map(|_| 0.8 + 0.2 * rng.normal()).collect();
        let b = (0..el).map(|_| 0.05 * rng.normal()).collect();
        ToyStage { el, w, b, dw: vec![0.0; el], db: vec![0.0; el] }
    }

    pub(crate) fn forward(&self, x: &[f32]) -> Vec<f32> {
        let el = self.el;
        x.iter()
            .enumerate()
            .map(|(i, &v)| (self.w[i % el] * v + self.b[i % el]).tanh())
            .collect()
    }

    /// Accumulate parameter gradients; return the input gradient.
    pub(crate) fn backward(&mut self, x: &[f32], y: &[f32], g: &[f32]) -> Vec<f32> {
        let el = self.el;
        let mut dx = vec![0f32; x.len()];
        for i in 0..x.len() {
            let j = i % el;
            let t = g[i] * (1.0 - y[i] * y[i]);
            self.dw[j] += t * x[i];
            self.db[j] += t;
            dx[i] = t * self.w[j];
        }
        dx
    }

    /// The microbatch-mean step gradient as one flat `[dw, db]` vector —
    /// what crosses the DP ring. Resets the accumulators.
    pub(crate) fn take_step_grad(&mut self, inv_micro: f32) -> Vec<f32> {
        let mut g = Vec::with_capacity(2 * self.el);
        g.extend(self.dw.iter().map(|v| v * inv_micro));
        g.extend(self.db.iter().map(|v| v * inv_micro));
        for v in self.dw.iter_mut() {
            *v = 0.0;
        }
        for v in self.db.iter_mut() {
            *v = 0.0;
        }
        g
    }

    /// SGD step over a flat `[dw, db]` gradient (local or ring-mean).
    pub(crate) fn apply_grad(&mut self, lr: f32, g: &[f32]) {
        debug_assert_eq!(g.len(), 2 * self.el);
        for j in 0..self.el {
            self.w[j] -= lr * g[j];
            self.b[j] -= lr * g[self.el + j];
        }
    }

    /// Input gradient only, parameters untouched — the frozen-backbone
    /// backward the serving front end runs on its shared stages (no
    /// server-side update, so every session sees identical stage bits
    /// regardless of what other sessions do).
    pub(crate) fn grad_input(&self, y: &[f32], g: &[f32]) -> Vec<f32> {
        let el = self.el;
        let mut dx = vec![0f32; y.len()];
        for i in 0..y.len() {
            let t = g[i] * (1.0 - y[i] * y[i]);
            dx[i] = t * self.w[i % el];
        }
        dx
    }

    /// FNV-1a over the parameter bits — the replica-equality probe.
    pub(crate) fn digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for v in self.w.iter().chain(&self.b) {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        h
    }
}

// ---------------------------------------------------------------------------
// Stage worker (pure compute) + its CommPlane endpoints
// ---------------------------------------------------------------------------

/// Per-step byte accounting one stage's endpoints produce.
#[derive(Clone, Copy, Debug, Default)]
struct StageAcct {
    fw_wire: u64,
    bw_wire: u64,
    dp_wire: u64,
}

/// Per-step record one stage hands back at step close. Public because
/// the multi-process serve path (`pipeline::serve`, the `serve-stage`
/// CLI) reports and oracle-checks exactly these per-stage values.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStep {
    /// Mean microbatch loss (loss-head stage only; `None` elsewhere).
    pub loss: Option<f32>,
    /// Serialized forward-activation bytes this stage shipped.
    pub fw_wire: u64,
    /// Serialized backward-gradient bytes this stage shipped.
    pub bw_wire: u64,
    /// Serialized DP ring bytes this stage shipped.
    pub dp_wire: u64,
    /// FNV-1a over the stage's post-update parameter bits.
    pub digest: u64,
}

/// One pipeline stage's compute: its model, local data shard, and the
/// saved per-microbatch activations its backward passes need. Codecs and
/// transport live in the stage's [`StageEndpoints`] — the worker only
/// sees decoded activations, which is what lets both execution modes
/// (and the virtual/threaded transports) share this one type.
pub(crate) struct StageWorker {
    replica: usize,
    stage: usize,
    n_stages: usize,
    n_micro: usize,
    lr: f32,
    model: ToyStage,
    /// Stage 0 only: the replica's training inputs, one per microbatch.
    inputs: Vec<Vec<f32>>,
    /// Last stage only: regression targets, one per microbatch.
    targets: Vec<Vec<f32>>,
    /// Example ids per microbatch (keys the AC-SGD buffers; disjoint
    /// across replicas, which train disjoint shards).
    ids: Vec<Vec<u64>>,
    saved_x: Vec<Option<Vec<f32>>>,
    saved_y: Vec<Option<Vec<f32>>>,
    in_flight: usize,
    peak_in_flight: usize,
    loss_acc: Option<f32>,
}

impl StageWorker {
    /// Forward one microbatch over the already-decoded input activation
    /// (None on stage 0, which reads its local shard). `incoming` is a
    /// borrowed view of the endpoint's decode scratch — the worker copies
    /// it into its saved-activation slot. Returns the activation to ship
    /// to stage+1 (None on the last stage).
    fn fwd(&mut self, mb: usize, incoming: Option<&[f32]>) -> Result<Option<Vec<f32>>> {
        let x = if self.stage == 0 {
            self.inputs[mb].clone()
        } else {
            incoming
                .with_context(|| {
                    format!(
                        "replica {} stage {}: no forward activation for mb {mb}",
                        self.replica, self.stage
                    )
                })?
                .to_vec()
        };
        let y = self.model.forward(&x);
        let out = (self.stage + 1 < self.n_stages).then(|| y.clone());
        self.saved_x[mb] = Some(x);
        self.saved_y[mb] = Some(y);
        self.in_flight += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.in_flight);
        Ok(out)
    }

    /// Backward one microbatch. `incoming` is the decoded gradient from
    /// stage+1, borrowed from the endpoint's decode scratch (None on the
    /// last stage, which starts from the loss). Returns the gradient to
    /// ship to stage-1 (None on stage 0).
    fn bwd(&mut self, mb: usize, incoming: Option<&[f32]>) -> Result<Option<Vec<f32>>> {
        let x = self.saved_x[mb].take().with_context(|| {
            format!(
                "replica {} stage {}: backward before forward (mb {mb})",
                self.replica, self.stage
            )
        })?;
        let y = self.saved_y[mb].take().with_context(|| {
            format!(
                "replica {} stage {}: backward before forward (mb {mb})",
                self.replica, self.stage
            )
        })?;
        let g = if self.stage + 1 == self.n_stages {
            // loss head: 0.5 * mean squared error against the target
            let t = &self.targets[mb];
            crate::ensure!(
                t.len() == y.len(),
                "target length {} != activation length {}",
                t.len(),
                y.len()
            );
            let n = y.len() as f32;
            let mut loss = 0f32;
            let mut g = vec![0f32; y.len()];
            for i in 0..y.len() {
                let d = y[i] - t[i];
                loss += d * d;
                g[i] = d / n;
            }
            self.loss_acc = Some(self.loss_acc.unwrap_or(0.0) + loss / (2.0 * n));
            g
        } else {
            incoming
                .with_context(|| {
                    format!(
                        "replica {} stage {}: no backward gradient for mb {mb}",
                        self.replica, self.stage
                    )
                })?
                .to_vec()
        };
        let dx = self.model.backward(&x, &y, &g);
        self.in_flight -= 1;
        Ok(if self.stage > 0 { Some(dx) } else { None })
    }

    fn take_step_grad(&mut self) -> Vec<f32> {
        self.model.take_step_grad(1.0 / self.n_micro as f32)
    }

    fn apply_grad(&mut self, g: &[f32]) {
        self.model.apply_grad(self.lr, g);
    }

    /// Close one optimizer step: hand back loss + accounting + the
    /// post-update parameter digest.
    fn end_step(&mut self, acct: StageAcct) -> StageStep {
        StageStep {
            loss: self.loss_acc.take().map(|l| l / self.n_micro as f32),
            fw_wire: acct.fw_wire,
            bw_wire: acct.bw_wire,
            dp_wire: acct.dp_wire,
            digest: self.model.digest(),
        }
    }
}

/// The CommPlane endpoints one (replica, stage) owns: boundary codec
/// halves bonded to their links, plus the stage's DP ring endpoint.
/// The endpoints persist across microbatches and steps, so every piece
/// of encode/decode scratch they carry — the senders' [`FrameBuf`]
/// arenas (inside [`LinkEndpointTx`]) and the receive-side activation
/// buffers below — is warmed once and reused for the whole run.
///
/// [`FrameBuf`]: crate::codec::FrameBuf
#[derive(Default)]
pub(crate) struct StageEndpoints {
    pub(crate) fw_tx: Option<LinkEndpointTx>,
    pub(crate) fw_rx: Option<LinkEndpointRx>,
    pub(crate) bw_tx: Option<LinkEndpointTx>,
    pub(crate) bw_rx: Option<LinkEndpointRx>,
    pub(crate) dp: Option<DpRing>,
    /// decode scratch for incoming forward activations
    pub(crate) fw_in: Vec<f32>,
    /// decode scratch for incoming backward gradients
    pub(crate) bw_in: Vec<f32>,
}

/// Build the per-replica per-stage workers: models (identically
/// initialized across replicas — the synchronized-update premise), data
/// shards (disjoint per replica), and bookkeeping. Both execution modes
/// start from this one function.
pub(crate) fn build_workers(cfg: &ExecConfig) -> Result<Vec<Vec<StageWorker>>> {
    crate::ensure!(cfg.n_stages >= 1, "executor needs at least one stage");
    crate::ensure!(cfg.n_micro >= 1, "executor needs at least one microbatch");
    crate::ensure!(
        cfg.micro_batch >= 1 && cfg.example_len >= 1,
        "executor needs a non-empty microbatch shape"
    );
    crate::ensure!(cfg.steps >= 1, "executor needs at least one step");
    crate::ensure!(cfg.dp_degree >= 1, "executor needs at least one replica");
    let k = cfg.n_stages;
    let m = cfg.n_micro;
    let el = cfg.example_len;
    let bsz = cfg.micro_batch;

    let mut workers = Vec::with_capacity(cfg.dp_degree);
    for r in 0..cfg.dp_degree {
        // deterministic per-replica shard: stable, replica-disjoint
        // example ids so AC-SGD buffers key uniquely and are revisited
        // every step (first step full precision, then deltas)
        let mut data_rng = Rng::new(cfg.seed ^ (0xDA7A_0001 + ((r as u64) << 16)));
        let inputs: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..bsz * el).map(|_| 0.8 * data_rng.normal()).collect())
            .collect();
        let mut tgt_rng = Rng::new(cfg.seed ^ (0x7A46_0002 + ((r as u64) << 16)));
        let targets: Vec<Vec<f32>> = (0..m)
            .map(|_| (0..bsz * el).map(|_| 0.5 * tgt_rng.normal()).collect())
            .collect();
        let base_id = (r * m * bsz) as u64;
        let ids: Vec<Vec<u64>> = (0..m)
            .map(|mb| (base_id + (mb * bsz) as u64..base_id + ((mb + 1) * bsz) as u64).collect())
            .collect();

        let mut row = Vec::with_capacity(k);
        for s in 0..k {
            row.push(StageWorker {
                replica: r,
                stage: s,
                n_stages: k,
                n_micro: m,
                lr: cfg.lr,
                // model seed deliberately replica-independent: replicas
                // start equal, and the synchronized (ring-mean) updates
                // keep them equal — the invariant the digests pin
                model: ToyStage::new(el, cfg.seed.wrapping_add(0xC0DE + 131 * s as u64)),
                inputs: if s == 0 { inputs.clone() } else { Vec::new() },
                targets: if s == k - 1 { targets.clone() } else { Vec::new() },
                ids: ids.clone(),
                saved_x: (0..m).map(|_| None).collect(),
                saved_y: (0..m).map(|_| None).collect(),
                in_flight: 0,
                peak_in_flight: 0,
                loss_acc: None,
            });
        }
        workers.push(row);
    }
    Ok(workers)
}

/// Base of replica `r`'s boundary-codec seed namespace. Extracted so the
/// multi-process serve path seeds its socket-backed endpoints exactly
/// like the in-process executors seed theirs — the precondition for
/// bit-identity across process boundaries.
pub(crate) fn replica_plane_seed(cfg: &ExecConfig, r: usize) -> u64 {
    // same seed namespaces the trainer uses, offset per replica; the
    // run seed folds in so changing it re-randomizes stochastic
    // rounding everywhere at once
    cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add((r as u64) << 32)
}

/// Seed of forward boundary `b`'s codec pair within a replica namespace.
pub(crate) fn fw_boundary_seed(base: u64, b: usize) -> u64 {
    base.wrapping_add(0xB0D1 + b as u64)
}

/// Seed of backward boundary `b`'s codec pair within a replica namespace.
pub(crate) fn bw_boundary_seed(base: u64, b: usize) -> u64 {
    base.wrapping_add(0xBACC + b as u64)
}

/// Seed of stage `s`'s DP ring (shared by all replicas — each sender's
/// encoder/decoder replicas derive from it by sender index).
pub(crate) fn ring_stage_seed(cfg: &ExecConfig, s: usize) -> u64 {
    cfg.seed.wrapping_mul(0x9E37_79B9) ^ (0xDD00 + ((s as u64) << 8))
}

/// Build every CommPlane endpoint: boundary codec pairs per replica
/// (sender/receiver halves sharing only their construction seed, never
/// state) and the per-stage DP rings. The two execution modes differ
/// only in the pacing passed here — real bandwidth/latency for threads,
/// `f64::INFINITY` / zero (a pure FIFO) for the virtual clock — so the
/// codec objects and their call order are identical.
fn build_planes(
    cfg: &ExecConfig,
    bandwidth_bps: f64,
    latency: Duration,
) -> Result<Vec<Vec<StageEndpoints>>> {
    let d = cfg.dp_degree;
    let k = cfg.n_stages;
    let el = cfg.example_len;
    let mut planes: Vec<Vec<StageEndpoints>> =
        (0..d).map(|_| (0..k).map(|_| StageEndpoints::default()).collect()).collect();
    for (r, plane) in planes.iter_mut().enumerate() {
        let base = replica_plane_seed(cfg, r);
        for b in 0..k.saturating_sub(1) {
            let seed = fw_boundary_seed(base, b);
            let (enc, dec) = build_mem_pair(&cfg.spec.fw, el, cfg.rounding, seed)?;
            let (tx, rx) = link_endpoints(b as u32, el, enc, dec, bandwidth_bps, latency);
            plane[b].fw_tx = Some(tx);
            plane[b + 1].fw_rx = Some(rx);
            let seed = bw_boundary_seed(base, b);
            let (enc, dec) = build_mem_pair(&cfg.spec.bw, el, cfg.rounding, seed)?;
            let (tx, rx) = link_endpoints(b as u32, el, enc, dec, bandwidth_bps, latency);
            plane[b + 1].bw_tx = Some(tx);
            plane[b].bw_rx = Some(rx);
        }
    }
    if d > 1 {
        let grad_len = 2 * el; // flat [dw, db]
        for s in 0..k {
            let seed = ring_stage_seed(cfg, s);
            let rings =
                dp_rings(&cfg.dp_spec.fw, d, grad_len, cfg.rounding, seed, bandwidth_bps, latency)?;
            for (r, ring) in rings.into_iter().enumerate() {
                planes[r][s].dp = Some(ring);
            }
        }
    }
    Ok(planes)
}

/// Execute one schedule op through the stage's endpoints: receive +
/// decode the input frame (if any), run the compute, encode + ship the
/// output frame (if any). Returns the shipped wire bytes. Both execution
/// modes funnel through this one function — the identical call sequence
/// per codec object is what makes them bit-identical twins.
fn exec_op(
    w: &mut StageWorker,
    ep: &mut StageEndpoints,
    acct: &mut StageAcct,
    op: Op,
) -> Result<Option<u64>> {
    match op {
        Op::Fwd(mb) => {
            let incoming = match ep.fw_rx.as_mut() {
                Some(rx) => {
                    rx.recv_into(&w.ids[mb], &mut ep.fw_in)?;
                    Some(ep.fw_in.as_slice())
                }
                None => None,
            };
            match w.fwd(mb, incoming)? {
                Some(y) => {
                    let tx =
                        ep.fw_tx.as_mut().context("non-last stage without a forward endpoint")?;
                    let st = tx.send(&w.ids[mb], &y)?;
                    acct.fw_wire += st.wire_bytes;
                    Ok(Some(st.wire_bytes))
                }
                None => Ok(None),
            }
        }
        Op::Bwd(mb) => {
            let incoming = match ep.bw_rx.as_mut() {
                Some(rx) => {
                    rx.recv_into(&w.ids[mb], &mut ep.bw_in)?;
                    Some(ep.bw_in.as_slice())
                }
                None => None,
            };
            match w.bwd(mb, incoming)? {
                Some(dx) => {
                    let tx =
                        ep.bw_tx.as_mut().context("non-first stage without a backward endpoint")?;
                    let st = tx.send(&w.ids[mb], &dx)?;
                    acct.bw_wire += st.wire_bytes;
                    Ok(Some(st.wire_bytes))
                }
                None => Ok(None),
            }
        }
    }
}

/// Close one optimizer step for one (replica, stage): exchange the step
/// gradient over the DP ring when one exists (blocking — the threaded
/// mode's replica threads interleave the hops), apply the update.
fn close_step(w: &mut StageWorker, ep: &mut StageEndpoints, acct: &mut StageAcct) -> Result<()> {
    let g = w.take_step_grad();
    match ep.dp.as_mut() {
        Some(ring) => {
            let (mean, sent) = ring.all_reduce(&g)?;
            acct.dp_wire += sent;
            w.apply_grad(&mean);
        }
        None => w.apply_grad(&g),
    }
    Ok(())
}

/// Fold per-(replica, stage) step records into one [`StepRecord`]:
/// forward wire bytes indexed by sending stage, backward by receiving
/// boundary, DP bytes by stage, loss averaged over replicas in replica
/// order, one parameter digest per replica. Both execution modes
/// assemble through this one function.
fn assemble_record(stage_steps: &[Vec<StageStep>]) -> StepRecord {
    let k = stage_steps.first().map_or(0, |row| row.len());
    let mut rec = StepRecord {
        loss: 0.0,
        fw_wire_bytes: vec![0; k.saturating_sub(1)],
        bw_wire_bytes: vec![0; k.saturating_sub(1)],
        dp_wire_bytes: vec![0; k],
        replica_digests: Vec::with_capacity(stage_steps.len()),
    };
    let mut loss_sum = 0f32;
    let mut n_loss = 0u32;
    for row in stage_steps {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (s, st) in row.iter().enumerate() {
            if s + 1 < k {
                rec.fw_wire_bytes[s] += st.fw_wire;
            }
            if s > 0 {
                rec.bw_wire_bytes[s - 1] += st.bw_wire;
            }
            rec.dp_wire_bytes[s] += st.dp_wire;
            if let Some(l) = st.loss {
                loss_sum += l;
                n_loss += 1;
            }
            h ^= st.digest;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        rec.replica_digests.push(h);
    }
    rec.loss = loss_sum / n_loss.max(1) as f32;
    rec
}

// ---------------------------------------------------------------------------
// Virtual-clock mode (the oracle)
// ---------------------------------------------------------------------------

/// [`StepDriver`] running one replica's real numerics under the virtual
/// clock: the same endpoints as the threaded mode, over unpaced FIFO
/// links, with the modeled compute/transmit times driving the clock.
struct VirtualDriver<'a> {
    workers: &'a mut [StageWorker],
    plane: &'a mut [StageEndpoints],
    acct: &'a mut [StageAcct],
    fwd_s: f64,
    bwd_s: f64,
}

impl StepDriver for VirtualDriver<'_> {
    fn exec(&mut self, stage: usize, op: Op) -> Result<(f64, Option<u64>)> {
        let bytes =
            exec_op(&mut self.workers[stage], &mut self.plane[stage], &mut self.acct[stage], op)?;
        let comp = match op {
            Op::Fwd(_) => self.fwd_s,
            Op::Bwd(_) => self.bwd_s,
        };
        Ok((comp, bytes))
    }
}

/// Per-(step, replica, stage) records of one oracle run, indexed
/// `[step][replica][stage]` — what a multi-process peer compares its own
/// `(replica, stage)` column against to prove bit-identity.
pub type StepDetail = Vec<Vec<Vec<StageStep>>>;

/// Run the full training loop single-threaded under the virtual clock.
pub fn run_virtual(cfg: &ExecConfig) -> Result<ExecTrace> {
    run_virtual_detailed(cfg).map(|(trace, _)| trace)
}

/// Like [`run_virtual`], but also return the per-(step, replica, stage)
/// record grid the trace was assembled from. The serve path's oracle
/// check reads one (replica, stage) column out of it.
pub fn run_virtual_detailed(cfg: &ExecConfig) -> Result<(ExecTrace, StepDetail)> {
    let mut workers = build_workers(cfg)?;
    let mut planes = build_planes(cfg, f64::INFINITY, Duration::ZERO)?;
    let d = cfg.dp_degree;
    let k = cfg.n_stages;
    let step_cfg = StepConfig {
        n_stages: k,
        n_micro: cfg.n_micro,
        bandwidth_bps: cfg.bandwidth_bps,
        link_bandwidths: None,
        latency_s: cfg.latency_s,
        schedule: cfg.schedule,
    };
    let mut trace = ExecTrace {
        executor: Executor::Sim,
        steps: Vec::with_capacity(cfg.steps),
        step_time_s: Vec::with_capacity(cfg.steps),
        fw_state_bytes: Vec::new(),
        peak_in_flight: Vec::new(),
    };
    let mut detail: StepDetail = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let mut acct: Vec<Vec<StageAcct>> = vec![vec![StageAcct::default(); k]; d];
        // replicas run concurrently in a deployment; under the virtual
        // clock each runs its own step independently (no shared state
        // until the ring), and the step time is the slowest replica's
        let mut pipe_time = 0f64;
        for ((wrow, prow), arow) in
            workers.iter_mut().zip(planes.iter_mut()).zip(acct.iter_mut())
        {
            let timing = run_step(
                &step_cfg,
                &mut VirtualDriver {
                    workers: wrow.as_mut_slice(),
                    plane: prow.as_mut_slice(),
                    acct: arow.as_mut_slice(),
                    fwd_s: cfg.fwd_s,
                    bwd_s: cfg.bwd_s,
                },
            )?;
            pipe_time = pipe_time.max(timing.step_time_s);
        }
        // DP ring, phase-ordered (the single-threaded twin of the
        // per-thread blocking exchange): sends, then hop rounds, then
        // decode + apply — identical per-object call order either way
        let mut dp_time = 0f64;
        if d > 1 {
            for s in 0..k {
                for (wrow, prow) in workers.iter_mut().zip(planes.iter_mut()) {
                    let g = wrow[s].take_step_grad();
                    prow[s].dp.as_mut().context("replica without a dp ring")?.send_own(&g)?;
                }
                for hop in 1..d {
                    for prow in planes.iter_mut() {
                        prow[s].dp.as_mut().context("replica without a dp ring")?.hop(hop)?;
                    }
                }
                let mut max_frame = 0u64;
                for ((wrow, prow), arow) in
                    workers.iter_mut().zip(planes.iter_mut()).zip(acct.iter_mut())
                {
                    let ring = prow[s].dp.as_mut().context("replica without a dp ring")?;
                    let (mean, sent) = ring.finish()?;
                    arow[s].dp_wire += sent;
                    max_frame = max_frame.max(ring.take_max_frame());
                    wrow[s].apply_grad(&mean);
                }
                // per-stage rings run concurrently; each costs d-1
                // serialized hop rounds gated by its largest frame
                dp_time = dp_time.max(PipelineSim::ring_allgather_time(
                    max_frame,
                    d,
                    cfg.bandwidth_bps,
                    cfg.latency_s,
                ));
            }
        } else {
            for (w, (ep, a)) in workers[0]
                .iter_mut()
                .zip(planes[0].iter_mut().zip(acct[0].iter_mut()))
            {
                close_step(w, ep, a)?;
            }
        }
        trace.step_time_s.push(pipe_time + dp_time);
        let stage_steps: Vec<Vec<StageStep>> = workers
            .iter_mut()
            .zip(&acct)
            .map(|(wrow, arow)| {
                wrow.iter_mut().zip(arow).map(|(w, &a)| w.end_step(a)).collect()
            })
            .collect();
        trace.steps.push(assemble_record(&stage_steps));
        detail.push(stage_steps);
    }
    trace.fw_state_bytes = planes
        .iter()
        .flat_map(|row| {
            row.iter().map(|ep| {
                (
                    ep.fw_tx.as_ref().map_or(0, |h| h.state_bytes()),
                    ep.fw_rx.as_ref().map_or(0, |h| h.state_bytes()),
                )
            })
        })
        .collect();
    trace.peak_in_flight =
        workers.iter().flat_map(|row| row.iter().map(|w| w.peak_in_flight)).collect();
    Ok((trace, detail))
}

// ---------------------------------------------------------------------------
// Threaded mode (the real runtime)
// ---------------------------------------------------------------------------

/// What one (replica, stage) worker thread hands back at join.
pub(crate) struct StageReport {
    pub(crate) per_step: Vec<StageStep>,
    pub(crate) wall_s: Vec<f64>,
    pub(crate) fw_state: (u64, u64),
    pub(crate) peak_in_flight: usize,
}

/// Fold per-(replica, stage) reports (indexed `replica * n_stages +
/// stage`) into the run's trace — shared by the threaded and event
/// modes, which only differ in *who* produced the reports.
fn trace_from_reports(
    executor: Executor,
    cfg: &ExecConfig,
    reports: Vec<StageReport>,
) -> ExecTrace {
    let d = cfg.dp_degree;
    let k = cfg.n_stages;
    let mut trace = ExecTrace {
        executor,
        steps: Vec::with_capacity(cfg.steps),
        step_time_s: Vec::with_capacity(cfg.steps),
        fw_state_bytes: reports.iter().map(|r| r.fw_state).collect(),
        peak_in_flight: reports.iter().map(|r| r.peak_in_flight).collect(),
    };
    for step in 0..cfg.steps {
        let stage_steps: Vec<Vec<StageStep>> = (0..d)
            .map(|r| (0..k).map(|s| reports[r * k + s].per_step[step]).collect())
            .collect();
        trace.steps.push(assemble_record(&stage_steps));
        trace.step_time_s.push(reports[0].wall_s[step]);
    }
    trace
}

/// Run the full training loop with one worker thread per (replica,
/// stage), exchanging serialized frames over paced channel links — and,
/// with `dp_degree > 1`, blocking ring hops between replica threads.
pub fn run_threads(cfg: &ExecConfig) -> Result<ExecTrace> {
    let workers = build_workers(cfg)?;
    let planes = build_planes(cfg, cfg.bandwidth_bps, Duration::from_secs_f64(cfg.latency_s))?;
    let d = cfg.dp_degree;
    let k = cfg.n_stages;

    let mut handles = Vec::with_capacity(d * k);
    for (r, (wrow, prow)) in workers.into_iter().zip(planes.into_iter()).enumerate() {
        for (s, (mut w, mut ep)) in wrow.into_iter().zip(prow.into_iter()).enumerate() {
            let mut script = StageScript::new(cfg.schedule.ops(s, k, cfg.n_micro), cfg.steps);
            let steps = cfg.steps;
            let spawned = thread::Builder::new()
                .name(format!("aq-r{r}s{s}"))
                .spawn(move || -> Result<StageReport> {
                    let mut per_step = Vec::with_capacity(steps);
                    let mut wall_s = Vec::with_capacity(steps);
                    let mut acct = StageAcct::default();
                    let mut t0 = Instant::now();
                    loop {
                        match script.peek() {
                            StageEvent::Op(op) => {
                                exec_op(&mut w, &mut ep, &mut acct, op)?;
                            }
                            StageEvent::CloseStep => {
                                close_step(&mut w, &mut ep, &mut acct)?;
                                per_step.push(w.end_step(std::mem::take(&mut acct)));
                                wall_s.push(t0.elapsed().as_secs_f64());
                                t0 = Instant::now();
                            }
                            StageEvent::Done => break,
                        }
                        script.advance();
                    }
                    Ok(StageReport {
                        per_step,
                        wall_s,
                        fw_state: (
                            ep.fw_tx.as_ref().map_or(0, |h| h.state_bytes()),
                            ep.fw_rx.as_ref().map_or(0, |h| h.state_bytes()),
                        ),
                        peak_in_flight: w.peak_in_flight,
                    })
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // the failed stage's closure (and its links) was
                    // dropped, so every already-spawned neighbour unwinds
                    // with a channel-closed error; drain them before
                    // reporting
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(crate::err!(
                        "failed to spawn replica {r} stage {s} worker thread: {e}"
                    ));
                }
            }
        }
    }

    let mut results: Vec<Result<StageReport>> = Vec::with_capacity(d * k);
    for h in handles {
        results.push(match h.join() {
            Ok(r) => r,
            Err(_) => Err(crate::err!("stage worker thread panicked")),
        });
    }
    if results.iter().any(|r| r.is_err()) {
        // a failing stage drops its channels, which unwinds its
        // neighbours (and ring peers) with "channel closed" errors —
        // report the root cause, not the cascade
        let mut cascade = None;
        for r in results {
            if let Err(e) = r {
                if !e.to_string().contains("pipeline channel closed") {
                    return Err(e);
                }
                cascade.get_or_insert(e);
            }
        }
        return Err(cascade.expect("at least one error present"));
    }
    let reports: Vec<StageReport> = results.into_iter().map(|r| r.unwrap()).collect();
    Ok(trace_from_reports(Executor::Threads, cfg, reports))
}

// ---------------------------------------------------------------------------
// Event-driven mode: fixed worker pool over a run queue
// ---------------------------------------------------------------------------
//
// Thread-per-stage burns `degree x stages` OS threads, most of them
// parked in a blocking `recv` — fatal at the topologies the slow-network
// tables are about. Here every (replica, stage) is a task: a
// `StageScript` cursor plus its worker/endpoints. A worker pops a task
// off the run queue and retires its events until the next one would
// block on a link (`Poll::Empty` / `Poll::InFlight`), then parks it. A
// doorbell on every link's sending half requeues the receiving task, and
// in-flight frames (queued but still inside their modeled transmission
// window) park with a deadline a worker's timed condvar wait promotes.
//
// Determinism: a task's events retire in script order, links are SPSC
// FIFOs, and the DP ring decodes per *sender* — so no matter which
// worker runs a task or how tasks interleave, every codec object sees
// the same call sequence as under the other executors. The pool size can
// change only *when* work happens, never *what* it computes.

/// Task scheduling states (one atomic per task).
const T_IDLE: u8 = 0; // parked, waiting for a doorbell/timer
const T_QUEUED: u8 = 1; // on the ready queue
const T_RUNNING: u8 = 2; // owned by a worker
const T_DIRTY: u8 = 3; // doorbell rang while running: requeue on release
const T_DONE: u8 = 4; // script finished; doorbells are no-ops

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// What a task run returned: park (optionally with a pacing deadline) or
/// retire the task.
pub(crate) enum TaskAdvance {
    Pending(Option<Instant>),
    Finished,
}

/// A resumable state machine the event pool can drive: advance until the
/// next park point (a link with nothing deliverable) or completion.
/// [`EventTask`] is the pipeline-training instance; the serving front
/// end (`crate::serve`) runs its session/stage tasks through the same
/// pool, scheduler, and doorbell protocol via this trait.
pub(crate) trait PoolTask: Send {
    fn advance(&mut self) -> Result<TaskAdvance>;
}

impl PoolTask for EventTask {
    fn advance(&mut self) -> Result<TaskAdvance> {
        self.run()
    }
}

/// One (replica, stage) as a resumable state machine: compute + endpoints
/// + script cursor + the per-step records it accumulates. `ring_hop`
/// carries the mid-close position — the ring's `degree - 1` hops are
/// each a potential park point.
pub(crate) struct EventTask {
    w: StageWorker,
    pub(crate) ep: StageEndpoints,
    script: StageScript,
    acct: StageAcct,
    /// `Some(h)`: step close in progress, next ring hop to receive is
    /// `h` (`h == degree` means all hops done — finish and apply).
    ring_hop: Option<usize>,
    per_step: Vec<StageStep>,
    wall_s: Vec<f64>,
    step_t0: Instant,
}

impl EventTask {
    pub(crate) fn new(
        w: StageWorker,
        ep: StageEndpoints,
        script: StageScript,
        steps: usize,
    ) -> Self {
        EventTask {
            w,
            ep,
            script,
            acct: StageAcct::default(),
            ring_hop: None,
            per_step: Vec::with_capacity(steps),
            wall_s: Vec::with_capacity(steps),
            step_t0: Instant::now(),
        }
    }

    fn close_record(&mut self) {
        self.per_step.push(self.w.end_step(std::mem::take(&mut self.acct)));
        self.wall_s.push(self.step_t0.elapsed().as_secs_f64());
        self.step_t0 = Instant::now();
        self.script.advance();
    }

    fn poll_input(&mut self, op: Op) -> Poll {
        let rx = match op {
            Op::Fwd(_) => self.ep.fw_rx.as_mut(),
            Op::Bwd(_) => self.ep.bw_rx.as_mut(),
        };
        // no endpoint = local input (stage 0 fwd / loss-head bwd)
        rx.map_or(Poll::Ready, |rx| rx.poll())
    }

    /// Retire events until the next one would park on a link. Every
    /// receive is poll-gated, so this never sleeps in a blocking recv —
    /// the stash a `Ready` poll fills makes the subsequent recv
    /// immediate (and pacing is already honoured by the poll's deadline).
    fn run(&mut self) -> Result<TaskAdvance> {
        loop {
            if let Some(hop) = self.ring_hop {
                let ring = self.ep.dp.as_mut().context("ring close without a dp ring")?;
                if hop < ring.degree {
                    match ring.poll_next() {
                        Poll::Ready => {
                            ring.hop(hop)?;
                            self.ring_hop = Some(hop + 1);
                        }
                        Poll::Empty => return Ok(TaskAdvance::Pending(None)),
                        Poll::InFlight(at) => return Ok(TaskAdvance::Pending(Some(at))),
                        Poll::Closed => {
                            crate::bail!("pipeline channel closed: ring peer exited early")
                        }
                    }
                    continue;
                }
                let (mean, sent) = ring.finish()?;
                self.acct.dp_wire += sent;
                self.w.apply_grad(&mean);
                self.ring_hop = None;
                self.close_record();
                continue;
            }
            match self.script.peek() {
                StageEvent::Op(op) => match self.poll_input(op) {
                    Poll::Ready => {
                        exec_op(&mut self.w, &mut self.ep, &mut self.acct, op)?;
                        self.script.advance();
                    }
                    Poll::Empty => return Ok(TaskAdvance::Pending(None)),
                    Poll::InFlight(at) => return Ok(TaskAdvance::Pending(Some(at))),
                    Poll::Closed => {
                        crate::bail!("pipeline channel closed: peer stage exited early")
                    }
                },
                StageEvent::CloseStep => {
                    if self.ep.dp.is_some() {
                        // enter the resumable ring close: send own frame,
                        // then poll through the hops (the record is
                        // written when the ring finishes)
                        let g = self.w.take_step_grad();
                        let ring = self.ep.dp.as_mut().expect("checked dp above");
                        ring.send_own(&g)?;
                        self.ring_hop = Some(1);
                    } else {
                        close_step(&mut self.w, &mut self.ep, &mut self.acct)?;
                        self.close_record();
                    }
                }
                StageEvent::Done => return Ok(TaskAdvance::Finished),
            }
        }
    }

    pub(crate) fn into_report(self) -> StageReport {
        StageReport {
            per_step: self.per_step,
            wall_s: self.wall_s,
            fw_state: (
                self.ep.fw_tx.as_ref().map_or(0, |h| h.state_bytes()),
                self.ep.fw_rx.as_ref().map_or(0, |h| h.state_bytes()),
            ),
            peak_in_flight: self.w.peak_in_flight,
        }
    }
}

/// The run queue and its bookkeeping, under one mutex.
struct EventQueue {
    ready: VecDeque<usize>,
    /// `(deadline, task)` for frames still inside their modeled
    /// transmission window; a worker promotes due entries. Stale entries
    /// (task already requeued by a doorbell) are harmless — promotion is
    /// a state-gated wake, not a direct push.
    timers: Vec<(Instant, usize)>,
    /// Tasks currently owned by a worker.
    running: usize,
    /// Tasks not yet `Finished`.
    live: usize,
    /// First error any worker hit; everyone drains out once set.
    error: Option<crate::util::error::Error>,
    /// Bumped on every ready-queue push. Starvation detection compares
    /// snapshots of this: progress moved means a frame arrived (or a
    /// timer fired) since the snapshot, so the pool is not stalled.
    progress: u64,
}

pub(crate) struct EventSched {
    state: Vec<AtomicU8>,
    q: Mutex<EventQueue>,
    cv: Condvar,
    /// `None` (in-process executors): an empty queue with nothing
    /// running and no timers is a schedule bug — error instantly, every
    /// frame source lives in this process. `Some(dt)` (socket-backed
    /// serve mode): frames arrive from *other processes*, so an idle
    /// pool is normal — only error after `dt` passes with no arrival,
    /// which distinguishes "frame still crossing the wire" from "peer
    /// gone without closing the socket".
    stall_timeout: Option<Duration>,
}

impl EventSched {
    /// Make task `t` runnable. Must hold the queue lock (all DIRTY
    /// transitions happen under it, which is what makes the
    /// release-path CAS below race-free).
    fn wake_locked(&self, q: &mut EventQueue, t: usize) {
        loop {
            match self.state[t].compare_exchange(
                T_IDLE,
                T_QUEUED,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    q.ready.push_back(t);
                    q.progress = q.progress.wrapping_add(1);
                    self.cv.notify_one();
                    return;
                }
                Err(T_RUNNING) => {
                    if self.state[t]
                        .compare_exchange(T_RUNNING, T_DIRTY, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        return; // the releasing worker will requeue it
                    }
                    // raced with the release path: retry from the top
                }
                Err(_) => return, // QUEUED / DIRTY / DONE: nothing to do
            }
        }
    }

    /// Doorbell entry point — called from inside a sender's `run` by the
    /// in-process executors, or from the I/O driver thread when a frame
    /// lands on a socket.
    pub(crate) fn wake(&self, t: usize) {
        let mut q = lock(&self.q);
        self.wake_locked(&mut q, t);
    }

    fn abort(&self, e: crate::util::error::Error) {
        let mut q = lock(&self.q);
        if q.error.is_none() {
            q.error = Some(e);
        }
        self.cv.notify_all();
    }
}

/// Flags a worker panic to the scheduler so the siblings drain instead
/// of waiting forever (disarmed by `mem::forget` on the normal path).
struct PanicSignal<'a> {
    sched: &'a EventSched,
}

impl Drop for PanicSignal<'_> {
    fn drop(&mut self) {
        self.sched.abort(crate::err!("event executor worker panicked"));
    }
}

/// One pool worker: pop ready tasks, run them to their next park point,
/// release. Exits when every task finished or any error/panic surfaced.
fn event_worker<T: PoolTask>(sched: &EventSched, tasks: &[Mutex<T>]) {
    loop {
        // -- acquire a ready task ------------------------------------
        let t = {
            let mut q = lock(&sched.q);
            // starvation tracker: (progress snapshot, give-up deadline),
            // armed only while the queue is starved under Some(stall_timeout)
            let mut starve: Option<(u64, Instant)> = None;
            loop {
                if q.error.is_some() || q.live == 0 {
                    return;
                }
                let now = Instant::now();
                let mut i = 0;
                while i < q.timers.len() {
                    if q.timers[i].0 <= now {
                        let (_, due) = q.timers.swap_remove(i);
                        sched.wake_locked(&mut q, due);
                    } else {
                        i += 1;
                    }
                }
                if let Some(t) = q.ready.pop_front() {
                    q.running += 1;
                    break t;
                }
                let mut starve_deadline = None;
                if q.running == 0 && q.timers.is_empty() {
                    // nothing runnable, nothing running that could send,
                    // no modeled frame in flight
                    match sched.stall_timeout {
                        None => {
                            // in-process: every frame source lives here
                            // (doorbells fire inside a sender's run(),
                            // i.e. while it still counts as running), so
                            // this is a genuine schedule dependency bug —
                            // error out instead of hanging
                            q.error = Some(crate::err!(
                                "event executor stalled: {} tasks parked with no frames in flight",
                                q.live
                            ));
                            sched.cv.notify_all();
                            return;
                        }
                        Some(dt) => {
                            // socket-backed: an idle pool waiting on the
                            // wire is normal — only give up after dt with
                            // no arrival (arrivals bump q.progress)
                            match starve {
                                Some((seen, deadline)) if seen == q.progress => {
                                    if now >= deadline {
                                        q.error = Some(crate::err!(
                                            "event executor stalled: {} tasks parked and no \
                                             frame arrived within {:.1}s — remote peer gone?",
                                            q.live,
                                            dt.as_secs_f64()
                                        ));
                                        sched.cv.notify_all();
                                        return;
                                    }
                                    starve_deadline = Some(deadline);
                                }
                                _ => {
                                    let deadline = now + dt;
                                    starve = Some((q.progress, deadline));
                                    starve_deadline = Some(deadline);
                                }
                            }
                        }
                    }
                } else {
                    starve = None;
                }
                let next_deadline = q
                    .timers
                    .iter()
                    .map(|&(at, _)| at)
                    .chain(starve_deadline)
                    .min();
                q = match next_deadline {
                    Some(at) => {
                        let wait = at.saturating_duration_since(now);
                        sched.cv.wait_timeout(q, wait).unwrap_or_else(|p| p.into_inner()).0
                    }
                    None => sched.cv.wait(q).unwrap_or_else(|p| p.into_inner()),
                };
            }
        };

        // -- run it (queue lock dropped) -----------------------------
        sched.state[t].store(T_RUNNING, Ordering::Release);
        let advance = {
            let guard = PanicSignal { sched };
            let r = lock(&tasks[t]).advance();
            std::mem::forget(guard);
            r
        };

        // -- release -------------------------------------------------
        let mut q = lock(&sched.q);
        q.running -= 1;
        match advance {
            Err(e) => {
                if q.error.is_none() {
                    q.error = Some(e);
                }
                sched.cv.notify_all();
                return;
            }
            Ok(TaskAdvance::Finished) => {
                sched.state[t].store(T_DONE, Ordering::Release);
                q.live -= 1;
                if q.live == 0 {
                    sched.cv.notify_all();
                }
            }
            // park or, if a doorbell rang mid-run (DIRTY), requeue. Both
            // CASes happen under the queue lock, same as every wake —
            // exactly one of them wins.
            Ok(TaskAdvance::Pending(deadline)) => loop {
                if sched.state[t]
                    .compare_exchange(T_RUNNING, T_IDLE, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    if let Some(at) = deadline {
                        q.timers.push((at, t));
                        // a sleeping sibling may need the new, earlier
                        // deadline
                        sched.cv.notify_one();
                    }
                    break;
                }
                if sched.state[t]
                    .compare_exchange(T_DIRTY, T_QUEUED, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
                {
                    q.ready.push_back(t);
                    q.progress = q.progress.wrapping_add(1);
                    sched.cv.notify_one();
                    break;
                }
            },
        }
        drop(q);
    }
}

/// Spin up a worker pool, drive `tasks` to completion, and hand the
/// finished tasks back in task order (callers extract their own report
/// type). `install` runs after the scheduler exists but before any
/// worker starts — it is where the caller wires doorbells (in-process:
/// sender halves waking the receiving task; serve mode: socket receive
/// halves waking the one local task). `stall_timeout` selects the
/// starvation policy (see [`EventSched`]).
pub(crate) fn run_event_pool<T: PoolTask + 'static>(
    tasks: Vec<T>,
    pool: usize,
    stall_timeout: Option<Duration>,
    install: impl FnOnce(&Arc<EventSched>, &mut [T]),
) -> Result<Vec<T>> {
    crate::ensure!(pool >= 1, "event executor needs at least one worker");
    let n_tasks = tasks.len();
    crate::ensure!(n_tasks >= 1, "event executor needs at least one task");
    let mut tasks = tasks;

    let sched = Arc::new(EventSched {
        // every task starts queued: stage 0 can run immediately, the
        // rest park themselves on their first not-ready poll
        state: (0..n_tasks).map(|_| AtomicU8::new(T_QUEUED)).collect(),
        q: Mutex::new(EventQueue {
            ready: (0..n_tasks).collect(),
            timers: Vec::new(),
            running: 0,
            live: n_tasks,
            error: None,
            progress: 0,
        }),
        cv: Condvar::new(),
        stall_timeout,
    });

    install(&sched, &mut tasks);
    let tasks: Arc<Vec<Mutex<T>>> = Arc::new(tasks.into_iter().map(Mutex::new).collect());

    let pool = pool.min(n_tasks);
    let mut handles = Vec::with_capacity(pool);
    for i in 0..pool {
        let sched = Arc::clone(&sched);
        let tasks = Arc::clone(&tasks);
        let spawned = thread::Builder::new()
            .name(format!("aq-ev{i}"))
            .spawn(move || event_worker(&sched, &tasks));
        match spawned {
            Ok(h) => handles.push(h),
            Err(e) => {
                let err = crate::err!("failed to spawn event worker {i}: {e}");
                sched.abort(crate::err!("spawn failure, draining pool"));
                for h in handles {
                    let _ = h.join();
                }
                return Err(err);
            }
        }
    }
    let mut panicked = false;
    for h in handles {
        panicked |= h.join().is_err();
    }
    {
        let mut q = lock(&sched.q);
        if let Some(e) = q.error.take() {
            return Err(e);
        }
        crate::ensure!(!panicked, "event worker thread panicked");
        crate::ensure!(q.live == 0, "event executor exited with {} unfinished tasks", q.live);
    }
    let tasks = Arc::try_unwrap(tasks)
        .map_err(|_| crate::err!("event task pool still shared after join"))?;
    Ok(tasks
        .into_iter()
        .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
        .collect())
}

/// Run the full training loop on a fixed pool of `cfg.workers` threads
/// driving every (replica, stage) task from a shared run queue —
/// bit-identical to the other executors at any pool size, but with a
/// thread count independent of the topology (a 64-stage pipeline runs
/// fine on 4 workers; thread-per-stage would need 64+).
pub fn run_events(cfg: &ExecConfig) -> Result<ExecTrace> {
    crate::ensure!(cfg.workers >= 1, "event executor needs at least one worker");
    let workers = build_workers(cfg)?;
    let planes = build_planes(cfg, cfg.bandwidth_bps, Duration::from_secs_f64(cfg.latency_s))?;
    let d = cfg.dp_degree;
    let k = cfg.n_stages;

    let mut tasks = Vec::with_capacity(d * k);
    for (wrow, prow) in workers.into_iter().zip(planes) {
        for (s, (w, ep)) in wrow.into_iter().zip(prow).enumerate() {
            let script = StageScript::new(cfg.schedule.ops(s, k, cfg.n_micro), cfg.steps);
            tasks.push(EventTask::new(w, ep, script, cfg.steps));
        }
    }

    let done = run_event_pool(tasks, cfg.workers, None, |sched, tasks| {
        // doorbells: every link's sending half wakes the task owning the
        // receiving half — fw to stage s+1, bw to stage s-1, ring edge to
        // the successor replica's same stage
        for (i, task) in tasks.iter_mut().enumerate() {
            let (r, s) = (i / k, i % k);
            if let Some(tx) = task.ep.fw_tx.as_mut() {
                let sc = Arc::clone(sched);
                let t = r * k + s + 1;
                tx.set_doorbell(Arc::new(move || sc.wake(t)));
            }
            if let Some(tx) = task.ep.bw_tx.as_mut() {
                let sc = Arc::clone(sched);
                let t = r * k + s - 1;
                tx.set_doorbell(Arc::new(move || sc.wake(t)));
            }
            if let Some(ring) = task.ep.dp.as_mut() {
                let sc = Arc::clone(sched);
                let t = ((r + 1) % d) * k + s;
                ring.set_doorbell(Arc::new(move || sc.wake(t)));
            }
        }
    })?;
    let reports = done.into_iter().map(EventTask::into_report).collect();
    Ok(trace_from_reports(Executor::Events, cfg, reports))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executor_parse_trims_and_ignores_case() {
        assert_eq!(Executor::parse(" Threads ").unwrap(), Executor::Threads);
        assert_eq!(Executor::parse("SIM").unwrap(), Executor::Sim);
        assert_eq!(Executor::parse(" Events\n").unwrap(), Executor::Events);
        assert_eq!(Executor::parse("EVENTS").unwrap(), Executor::Events);
        assert_eq!(Executor::Events.label(), "events");
    }

    #[test]
    fn executor_parse_rejection_lists_every_mode() {
        // the rejection message is user-facing: it must advertise the
        // full set of accepted names, like Schedule::parse does
        let err = Executor::parse("gpu").unwrap_err().to_string();
        assert!(err.contains("gpu"), "{err}");
        assert!(err.contains("threads|events|sim"), "{err}");
    }

    #[test]
    fn event_executor_runs_without_dp() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.steps = 3;
        cfg.workers = 2;
        let v = run_virtual(&cfg).unwrap();
        let e = run_events(&cfg).unwrap();
        assert!(e.bit_identical(&v), "events diverged from the oracle");
        assert_eq!(e.executor, Executor::Events);
        assert_eq!(e.fw_state_bytes, v.fw_state_bytes);
    }

    #[test]
    fn event_executor_matches_oracle_with_dp_ring() {
        let mut cfg = ExecConfig::small(CodecSpec::aqsgd(2, 4));
        cfg.n_stages = 2;
        cfg.dp_degree = 2;
        cfg.dp_spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        cfg.steps = 3;
        cfg.workers = 3;
        let v = run_virtual(&cfg).unwrap();
        let e = run_events(&cfg).unwrap();
        assert!(e.bit_identical(&v), "events+dp diverged from the oracle");
        assert!(e.steps.iter().all(|r| r.dp_wire_bytes.iter().all(|&b| b > 0)));
    }

    #[test]
    fn event_executor_single_worker_single_stage() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_stages = 1;
        cfg.steps = 2;
        cfg.workers = 1;
        let v = run_virtual(&cfg).unwrap();
        let e = run_events(&cfg).unwrap();
        assert!(e.bit_identical(&v));
    }

    #[test]
    fn event_executor_rejects_a_zero_worker_pool() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.workers = 0;
        let err = run_events(&cfg).unwrap_err().to_string();
        assert!(err.contains("at least one worker"), "{err}");
    }

    #[test]
    fn event_executor_paces_links_like_threads() {
        // finite bandwidth: in-flight frames park tasks on timers; the
        // trajectory still matches the oracle and the run takes at least
        // the serialized wire time of the slowest link
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_micro = 2;
        cfg.steps = 2;
        cfg.bandwidth_bps = 40e6; // ~5 MB/s: mb frames ~ 0.1 ms each
        let v = run_virtual(&cfg).unwrap();
        let e = run_events(&cfg).unwrap();
        assert!(e.bit_identical(&v), "paced events diverged from the oracle");
    }

    #[test]
    fn virtual_executor_trains_and_accounts_bytes() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.steps = 6;
        let t = run_virtual(&cfg).unwrap();
        assert_eq!(t.steps.len(), 6);
        for rec in &t.steps {
            assert!(rec.loss.is_finite());
            assert_eq!(rec.fw_wire_bytes.len(), cfg.n_stages - 1);
            assert_eq!(rec.bw_wire_bytes.len(), cfg.n_stages - 1);
            for &b in rec.fw_wire_bytes.iter().chain(&rec.bw_wire_bytes) {
                assert!(b > 0);
            }
            // no DP: the ring column stays zero
            assert!(rec.dp_wire_bytes.iter().all(|&b| b == 0));
            assert_eq!(rec.replica_digests.len(), 1);
        }
        // the toy regression learns: loss drops over the run
        assert!(
            t.steps.last().unwrap().loss < t.steps[0].loss,
            "{:?}",
            t.losses()
        );
    }

    #[test]
    fn aq_wire_bytes_collapse_after_first_epoch() {
        let mut cfg = ExecConfig::small(CodecSpec::aqsgd(2, 4));
        cfg.steps = 3;
        let t = run_virtual(&cfg).unwrap();
        // step 0 sends full-precision first-visit records; steady state
        // sends 2-bit deltas
        let first: u64 = t.steps[0].fw_wire_bytes.iter().sum();
        let steady: u64 = t.steps[2].fw_wire_bytes.iter().sum();
        assert!(steady * 4 < first, "first {first} steady {steady}");
        // Algorithm 2 replica symmetry across each boundary
        for s in 0..cfg.n_stages - 1 {
            assert!(t.fw_state_bytes[s].0 > 0);
            assert_eq!(t.fw_state_bytes[s].0, t.fw_state_bytes[s + 1].1, "boundary {s}");
        }
    }

    #[test]
    fn single_stage_pipeline_works_in_both_modes() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_stages = 1;
        cfg.steps = 2;
        let v = run_virtual(&cfg).unwrap();
        let t = run_threads(&cfg).unwrap();
        assert_eq!(v.losses(), t.losses());
        assert!(v.steps[0].fw_wire_bytes.is_empty());
    }

    #[test]
    fn ofob_respects_the_memory_bound() {
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_micro = 8;
        cfg.schedule = Schedule::OneFOneB;
        cfg.steps = 2;
        let t = run_virtual(&cfg).unwrap();
        for (s, &peak) in t.peak_in_flight.iter().enumerate() {
            let bound = cfg.schedule.peak_in_flight(s, cfg.n_stages, cfg.n_micro);
            assert!(peak <= bound, "stage {s}: peak {peak} > bound {bound}");
        }
    }

    #[test]
    fn dp_replicas_stay_bit_identical_every_step() {
        let mut cfg = ExecConfig::small(CodecSpec::aqsgd(2, 4));
        cfg.dp_degree = 2;
        cfg.dp_spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        cfg.steps = 5;
        let t = run_virtual(&cfg).unwrap();
        for (i, rec) in t.steps.iter().enumerate() {
            assert_eq!(rec.replica_digests.len(), 2);
            assert_eq!(
                rec.replica_digests[0], rec.replica_digests[1],
                "step {i}: replica parameters diverged"
            );
            // every stage shipped real ring frames
            assert!(rec.dp_wire_bytes.iter().all(|&b| b > 0), "step {i}: {rec:?}");
        }
        assert!(t.steps.iter().all(|r| r.loss.is_finite()));
    }

    /// Build a stage-1 task whose only input is the given receive half —
    /// the harness for the starvation-policy tests below.
    fn lonely_stage1_task(cfg: &ExecConfig, rx: LinkEndpointRx) -> EventTask {
        let workers = build_workers(cfg).unwrap();
        let w = workers.into_iter().next().unwrap().into_iter().nth(1).unwrap();
        let ep = StageEndpoints { fw_rx: Some(rx), ..Default::default() };
        let script = StageScript::new(cfg.schedule.ops(1, 2, cfg.n_micro), cfg.steps);
        EventTask::new(w, ep, script, cfg.steps)
    }

    #[test]
    fn stall_timeout_distinguishes_waiting_from_stuck() {
        // a live sender that never sends: under the serve-mode policy the
        // pool waits out the timeout, then errors descriptively instead
        // of hanging (the in-process policy would error instantly, which
        // is wrong when frames come from another OS process)
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_stages = 2;
        cfg.steps = 1;
        let (enc, dec) =
            build_mem_pair(&cfg.spec.fw, cfg.example_len, cfg.rounding, 1).unwrap();
        let (_tx, rx) =
            link_endpoints(0, cfg.example_len, enc, dec, f64::INFINITY, Duration::ZERO);
        let task = lonely_stage1_task(&cfg, rx);
        let t0 = Instant::now();
        let err = run_event_pool(vec![task], 1, Some(Duration::from_millis(150)), |_, _| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("no frame arrived"), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(150), "gave up before the deadline");
    }

    #[test]
    fn dropped_sender_surfaces_closed_not_a_stall_timeout() {
        // peer gone (sender dropped): the task's poll sees Closed and the
        // run errors immediately with the channel-closed cause — it must
        // NOT sit out the (long) stall timeout first
        let mut cfg = ExecConfig::small(CodecSpec::fp32());
        cfg.n_stages = 2;
        cfg.steps = 1;
        let (enc, dec) =
            build_mem_pair(&cfg.spec.fw, cfg.example_len, cfg.rounding, 1).unwrap();
        let (tx, rx) =
            link_endpoints(0, cfg.example_len, enc, dec, f64::INFINITY, Duration::ZERO);
        drop(tx);
        let task = lonely_stage1_task(&cfg, rx);
        let t0 = Instant::now();
        let err = run_event_pool(vec![task], 1, Some(Duration::from_secs(30)), |_, _| {})
            .unwrap_err()
            .to_string();
        assert!(err.contains("pipeline channel closed"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(10), "waited out the stall timeout");
    }

    #[test]
    fn dp_ring_compression_shrinks_the_gradient_wire() {
        let mut fp = ExecConfig::small(CodecSpec::fp32());
        fp.dp_degree = 2;
        fp.steps = 2;
        let mut ef = fp.clone();
        ef.dp_spec = CodecSpec::parse("ef:directq:fw4bw4").unwrap();
        let t_fp = run_virtual(&fp).unwrap();
        let t_ef = run_virtual(&ef).unwrap();
        let b_fp: u64 = t_fp.steps[1].dp_wire_bytes.iter().sum();
        let b_ef: u64 = t_ef.steps[1].dp_wire_bytes.iter().sum();
        assert!(b_ef * 6 < b_fp, "ef {b_ef} vs fp32 {b_fp}");
    }
}
